# L2 — the t-SNE gradient-descent iteration as a JAX computation.
#
# One `tsne_step` is the paper's full per-iteration pipeline (Fig. 4):
#
#   bbox -> field grid placement -> Pallas field evaluation (L1)
#        -> bilinear field query -> Zhat (Eq. 13) -> F_rep (Eq. 14)
#        -> Pallas attractive forces (L1, Eq. 12)
#        -> gradient (Eq. 9) -> gains/momentum update -> recentre
#
# The adaptive-resolution policy (the paper's rho, SS4.2) lives in the Rust
# coordinator: it reads the returned bbox and picks the next iteration's
# *grid size* G among the AOT-compiled variants; the grid *placement*
# (origin, pixel size) is derived here, inside the step, from the current
# bounding box — so a single artifact stays correct as the embedding
# expands, and G only controls accuracy.
#
# Everything is shape-static: N, K and G are baked per artifact (see
# aot.py); real jobs are padded with mask=0 phantom points that contribute
# nothing anywhere and never move.
import functools

import jax
import jax.numpy as jnp

from compile.kernels import attractive as attractive_k
from compile.kernels import fields as fields_k
from compile.kernels import ref

# Gradient-descent constants (van der Maaten 2008 / HDI defaults, used by
# the paper's evaluation).
GAIN_ADD = 0.2
GAIN_MUL = 0.8
GAIN_MIN = 0.01
# Extra margin (in pixels) around the bounding box so border points keep a
# full bilinear neighbourhood.
GRID_MARGIN_PX = 1.5


def bbox_of(y, mask):
    """(min_x, min_y, max_x, max_y) over real (mask=1) points."""
    big = jnp.float32(3.4e38)
    mx = jnp.where(mask > 0, y[:, 0], big)
    my = jnp.where(mask > 0, y[:, 1], big)
    min_x = jnp.min(mx)
    min_y = jnp.min(my)
    mx = jnp.where(mask > 0, y[:, 0], -big)
    my = jnp.where(mask > 0, y[:, 1], -big)
    return jnp.stack([min_x, min_y, jnp.max(mx), jnp.max(my)])


def grid_placement(bbox, grid):
    """Square field-domain (origin, pixel) covering bbox with margin.

    The domain is the bbox inflated by GRID_MARGIN_PX pixels on each side,
    made square (the paper's textures are square), with a small floor on
    the extent so a degenerate all-points-coincident embedding still
    yields a valid grid.
    """
    g = jnp.float32(grid)
    span_x = bbox[2] - bbox[0]
    span_y = bbox[3] - bbox[1]
    span = jnp.maximum(jnp.maximum(span_x, span_y), 1e-3)
    pixel = span / (g - 2.0 * GRID_MARGIN_PX)
    cx = 0.5 * (bbox[0] + bbox[2])
    cy = 0.5 * (bbox[1] + bbox[3])
    half = 0.5 * g * pixel
    origin = jnp.stack([cx - half, cy - half])
    return origin, pixel.reshape(1)


def repulsive(y, mask, origin, pixel, *, grid):
    """F_rep (Eq. 14) and Zhat (Eq. 13) via the L1 field kernel."""
    tex = fields_k.fields(y, mask, origin, pixel, grid=grid)
    svv = ref.bilinear_ref(tex, y, origin, pixel)  # (N, 3) — jnp gather, fused by XLA
    s = svv[:, 0]
    v = svv[:, 1:3]
    # Eq. 13: each real point's own kernel contributes exactly 1 to S(y_i).
    zhat = jnp.maximum(jnp.sum((s - 1.0) * mask), jnp.float32(1e-12))
    rep = v / zhat
    return rep, zhat


def tsne_step(y, vel, gains, mask, nbr_idx, nbr_p, eta, momentum, exaggeration, *, grid):
    """One t-SNE gradient-descent iteration (the paper's Fig. 4).

    All arrays f32 unless noted. Scalars are rank-0 f32.
      y, vel, gains: (N, 2)  state
      mask:          (N,)    1 real / 0 padding
      nbr_idx:       (N, K)  i32
      nbr_p:         (N, K)  joint P, unexaggerated, 0 on padding
      eta, momentum, exaggeration: learning rate, momentum alpha,
                     early-exaggeration multiplier for this iteration
    Returns (y', vel', gains', zhat, kl, bbox[4]).
    kl is the neighbour-restricted KL estimate (uses UNexaggerated P).
    """
    bbox = bbox_of(y, mask)
    origin, pixel = grid_placement(bbox, grid)

    rep, zhat = repulsive(y, mask, origin, pixel, grid=grid)
    attr, kl_pairs = attractive_k.attractive(y, nbr_idx, nbr_p)

    # Eq. 9: the early-exaggeration multiplier scales P, hence F_attr,
    # linearly — apply it outside the kernel so KL sees the true P.
    #
    # Sign note: Eq. 8's repulsive numerator is sum_j t^2 (y_i - y_j),
    # while the field of Eq. 11 is V(y_i) = sum_j t^2 (y_j - y_i) — the
    # *negative*. Taking Eq. 9 + Eq. 14 literally flips the repulsion
    # (a known erratum; the reference tfjs-tsne code negates it), so the
    # repulsion enters the gradient with a + sign here.
    grad = 4.0 * (exaggeration * attr + rep) * mask[:, None]

    # van der Maaten gains + momentum update.
    same_sign = (grad * vel) > 0.0
    gains = jnp.where(same_sign, gains * GAIN_MUL, gains + GAIN_ADD)
    gains = jnp.maximum(gains, GAIN_MIN) * mask[:, None]
    vel = momentum * vel - eta * gains * grad
    y = y + vel

    # Recentre over real points (keeps the field domain from drifting).
    n_real = jnp.maximum(jnp.sum(mask), 1.0)
    centre = jnp.sum(y * mask[:, None], axis=0) / n_real
    y = (y - centre[None, :]) * mask[:, None]

    kl = jnp.sum(kl_pairs) + jnp.log(zhat) * jnp.sum(nbr_p)
    return y, vel, gains, zhat, kl, bbox_of(y, mask)


def tsne_steps(y, vel, gains, mask, nbr_idx, nbr_p, eta, momentum, exaggeration, *, grid, steps):
    """`steps` fused iterations under lax.scan (fixed G within the call).

    Amortises the per-execute host boundary; the grid *placement* still
    re-adapts every inner iteration. Returns the same tuple as tsne_step
    with zhat/kl from the final iteration.
    """

    def body(carry, _):
        y, vel, gains = carry
        y, vel, gains, zhat, kl, bbox = tsne_step(
            y, vel, gains, mask, nbr_idx, nbr_p, eta, momentum, exaggeration, grid=grid
        )
        return (y, vel, gains), (zhat, kl, bbox)

    (y, vel, gains), (zhats, kls, bboxes) = jax.lax.scan(
        body, (y, vel, gains), None, length=steps
    )
    return y, vel, gains, zhats[-1], kls[-1], bboxes[-1]


def step_fn(grid):
    """The single-step function with G baked, ready for jax.jit().lower()."""
    return functools.partial(tsne_step, grid=grid)


def steps_fn(grid, steps):
    """The fused multi-step function with G and step count baked."""
    return functools.partial(tsne_steps, grid=grid, steps=steps)
