# AOT compile path: lower the L2 t-SNE step to HLO *text* artifacts.
#
# HLO text (NOT lowered.compile()/.serialize()) is the interchange format:
# jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the
# Rust side's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the
# HLO text parser reassigns ids, so text round-trips cleanly. See
# /opt/xla-example/gen_hlo.py.
#
# Usage (normally via `make artifacts`):
#   python -m compile.aot --out-dir ../artifacts [--full-matrix]
#
# Emits one artifact per (N, K, G[, S]) variant plus manifest.json
# describing shapes / argument order for the Rust runtime, plus
# selfcheck.json with expected outputs of a deterministic micro problem so
# Rust integration tests can verify numerics end to end.
import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model

# Default build matrix (DESIGN.md SS5). K = 96: perplexity 30 -> 3*mu = 90
# neighbours, padded to a lane-friendly 96.
# Power-of-two buckets; 2048 halves the padding waste for the common
# 1k-2k interactive jobs (§Perf: a padded phantom point costs exactly as
# much as a real one in the fields kernel).
DEFAULT_NS = [1024, 2048, 4096]
FULL_NS = [1024, 2048, 4096, 16384]
DEFAULT_GRIDS = [32, 64, 128, 256]
DEFAULT_K = 96
SCAN_STEPS = 10  # fused-steps variant (ablation: host-boundary amortisation)

ARG_NAMES = ["y", "vel", "gains", "mask", "nbr_idx", "nbr_p", "eta", "momentum", "exaggeration"]
OUT_NAMES = ["y", "vel", "gains", "zhat", "kl", "bbox"]


def example_args(n, k):
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((n, 2), f32),   # y
        jax.ShapeDtypeStruct((n, 2), f32),   # vel
        jax.ShapeDtypeStruct((n, 2), f32),   # gains
        jax.ShapeDtypeStruct((n,), f32),     # mask
        jax.ShapeDtypeStruct((n, k), jnp.int32),  # nbr_idx
        jax.ShapeDtypeStruct((n, k), f32),   # nbr_p
        jax.ShapeDtypeStruct((), f32),       # eta
        jax.ShapeDtypeStruct((), f32),       # momentum
        jax.ShapeDtypeStruct((), f32),       # exaggeration
    )


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(fn, n, k):
    return jax.jit(fn).lower(*example_args(n, k))


def selfcheck_case(n, k, grid):
    """Deterministic micro problem + expected step outputs (for Rust tests)."""
    rng = np.random.RandomState(7)
    n_real = min(n, 48)
    y = np.zeros((n, 2), np.float32)
    y[:n_real] = rng.randn(n_real, 2).astype(np.float32) * 0.9
    mask = np.zeros((n,), np.float32)
    mask[:n_real] = 1.0
    vel = np.zeros((n, 2), np.float32)
    gains = np.ones((n, 2), np.float32) * mask[:, None]
    nbr_idx = np.zeros((n, k), np.int32)
    nbr_p = np.zeros((n, k), np.float32)
    kk = min(k, 4)
    for i in range(n_real):
        for j in range(kk):
            nbr_idx[i, j] = (i + j + 1) % n_real
            nbr_p[i, j] = 1.0 / (n_real * kk)
    out = model.tsne_step(
        jnp.asarray(y), jnp.asarray(vel), jnp.asarray(gains), jnp.asarray(mask),
        jnp.asarray(nbr_idx), jnp.asarray(nbr_p),
        jnp.float32(200.0), jnp.float32(0.5), jnp.float32(12.0), grid=grid,
    )
    y2, vel2, gains2, zhat, kl, bbox = (np.asarray(o) for o in out)
    return {
        "n": n, "k": k, "grid": grid, "n_real": n_real, "kk": kk, "seed": 7,
        "eta": 200.0, "momentum": 0.5, "exaggeration": 12.0,
        # Inputs (so the Rust round-trip test can reconstruct them exactly).
        "y_init": [float(v) for v in y[:n_real].reshape(-1)],
        # Expected outputs.
        "zhat": float(zhat), "kl": float(kl), "bbox": [float(b) for b in bbox],
        "y_out": [float(v) for v in y2[:n_real].reshape(-1)],
        "vel_out": [float(v) for v in vel2[:n_real].reshape(-1)],
        "gains_out": [float(v) for v in gains2[:n_real].reshape(-1)],
    }


def main() -> None:
    ap = argparse.ArgumentParser(description="AOT-lower t-SNE step artifacts")
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--ns", type=int, nargs="*", default=None, help="N buckets")
    ap.add_argument("--grids", type=int, nargs="*", default=None)
    ap.add_argument("--k", type=int, default=DEFAULT_K)
    ap.add_argument("--full-matrix", action="store_true", help="include N=16384")
    ap.add_argument("--scan-steps", type=int, default=SCAN_STEPS)
    ap.add_argument("--no-scan", action="store_true", help="skip fused-steps variants")
    args = ap.parse_args()

    ns = args.ns if args.ns else (FULL_NS if args.full_matrix else DEFAULT_NS)
    grids = args.grids if args.grids else DEFAULT_GRIDS
    os.makedirs(args.out_dir, exist_ok=True)

    artifacts = []
    for n in ns:
        for g in grids:
            name = f"step_n{n}_k{args.k}_g{g}"
            path = os.path.join(args.out_dir, name + ".hlo.txt")
            text = to_hlo_text(lower_variant(model.step_fn(g), n, args.k))
            with open(path, "w") as f:
                f.write(text)
            artifacts.append({
                "name": name, "file": name + ".hlo.txt", "kind": "step",
                "n": n, "k": args.k, "grid": g, "steps": 1,
            })
            print(f"wrote {path} ({len(text)} chars)")
        if not args.no_scan:
            # One fused variant per N at a mid grid (ablation artifact).
            g = 128 if 128 in grids else grids[-1]
            name = f"steps_n{n}_k{args.k}_g{g}_s{args.scan_steps}"
            path = os.path.join(args.out_dir, name + ".hlo.txt")
            text = to_hlo_text(lower_variant(model.steps_fn(g, args.scan_steps), n, args.k))
            with open(path, "w") as f:
                f.write(text)
            artifacts.append({
                "name": name, "file": name + ".hlo.txt", "kind": "steps",
                "n": n, "k": args.k, "grid": g, "steps": args.scan_steps,
            })
            print(f"wrote {path} ({len(text)} chars)")

    manifest = {
        "version": 1,
        "arg_names": ARG_NAMES,
        "out_names": OUT_NAMES,
        "artifacts": artifacts,
    }
    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {mpath} ({len(artifacts)} artifacts)")

    check = selfcheck_case(ns[0], args.k, grids[0])
    cpath = os.path.join(args.out_dir, "selfcheck.json")
    with open(cpath, "w") as f:
        json.dump(check, f, indent=1)
    print(f"wrote {cpath}")


if __name__ == "__main__":
    main()
