# L1 — Pallas kernel: restricted-neighbourhood attractive forces (Eq. 12).
#
# F_attr_i = sum_{l in kNN(i)} p_il * t_il * (y_i - y_l),  t = 1/(1+d^2).
# (Eq. 12 writes Zhat * q_il * p_il * (y_i - y_l); Zhat * q_il == t_il, so
# no normalisation enters the attractive term at all.)
#
# Alongside the force the kernel emits the per-point KL pair terms
# sum_l p_il (ln p_il - ln t_il), so the coordinator gets a free
# neighbour-restricted KL estimate every iteration (add ln Zhat once).
#
# Tiling: the grid runs over blocks of BLOCK_ROWS points; each invocation
# sees its own (BLOCK_ROWS, K) neighbour slab plus the *full* y array
# (N*2*4 bytes — 128 KiB at N=16384, comfortably VMEM-resident) from which
# it gathers neighbour positions.
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 256


def _attractive_kernel(yi_ref, yfull_ref, idx_ref, p_ref, attr_ref, kl_ref):
    yi = yi_ref[...]        # (B, 2) this block's points
    yall = yfull_ref[...]   # (N, 2) all points (gather source)
    idx = idx_ref[...]      # (B, K) int32
    p = p_ref[...]          # (B, K) joint probabilities, 0 on padding

    yj = yall[idx]          # (B, K, 2)
    d = yi[:, None, :] - yj
    d2 = jnp.sum(d * d, axis=-1)
    t = 1.0 / (1.0 + d2)
    w = p * t
    attr_ref[...] = jnp.sum(w[..., None] * d, axis=1)
    safe_p = jnp.where(p > 0, p, 1.0)
    kl_ref[...] = jnp.sum(jnp.where(p > 0, p * (jnp.log(safe_p) - jnp.log(t)), 0.0), axis=1)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def attractive(y, nbr_idx, nbr_p, *, block_rows=BLOCK_ROWS):
    """Attractive forces and KL pair terms over padded neighbour lists.

    y:       (N, 2) f32; N must be a multiple of block_rows.
    nbr_idx: (N, K) i32 neighbour indices (padding may alias any index).
    nbr_p:   (N, K) f32 p_ij, exactly 0.0 on padded slots.
    Returns (attr (N, 2), kl (N,)).
    """
    n, k = nbr_idx.shape
    block_rows = min(block_rows, n)
    assert n % block_rows == 0, f"N={n} not a multiple of block_rows={block_rows}"
    return pl.pallas_call(
        _attractive_kernel,
        grid=(n // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, 2), lambda i: (i, 0)),
            pl.BlockSpec((n, 2), lambda i: (0, 0)),
            pl.BlockSpec((block_rows, k), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, k), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, 2), lambda i: (i, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, 2), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,
    )(y, y, nbr_idx, nbr_p)
