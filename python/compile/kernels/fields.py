# L1 — Pallas kernel: field evaluation (the paper's hot spot).
#
# Computes the scalar field S (Eq. 10) and vector field V (Eq. 11) of
# Pezzotti et al. 2018 on a G x G pixel grid. The paper splats per-point
# kernel textures with additive blending (a rasteriser scatter-add); the
# TPU-idiomatic mapping follows the paper's own compute-shader formulation
# (SS5.2): for every output pixel, *gather* every point's contribution.
#
# Tiling (DESIGN.md SSHardware-Adaptation):
#   grid = (pixel row tiles, point blocks)
#   each invocation computes a dense (TILE_ROWS x G) x BLOCK_PTS
#   interaction entirely in VMEM-resident blocks and accumulates over the
#   point-block grid dimension (the additive-blend replacement).
#
# This is the "unbounded function support" variant, exact w.r.t. Eq. 10/11
# at pixel centres — the paper notes it is *more accurate* than bounded
# splats. interpret=True everywhere: CPU PJRT cannot run Mosaic
# custom-calls; real-TPU VMEM/MXU estimates live in DESIGN.md SS9.
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile shapes. VMEM estimate per invocation (f32):
#   points block  BLOCK_PTS * 3            (y block + mask)
#   out tile      3 * TILE_ROWS * G
#   live temps    TILE_ROWS * G * BLOCK_PTS * ~3 (dx, dy, t)
# With TILE_ROWS=16, G=256, BLOCK_PTS=512: ~25 MiB of f32 temps in the
# worst case — above a single-core VMEM budget, so the real-TPU mapping
# would halve TILE_ROWS at G=256 (DESIGN.md §9); on the CPU interpret
# path larger tiles amortise the per-grid-step overhead (§Perf log in
# EXPERIMENTS.md). Overridable for perf experiments via env.
import os as _os

# Perf-pass result (EXPERIMENTS.md §Perf): on the compiled XLA-CPU path
# small pixel tiles win — (4, 256) beat (8, 256) by ~10% and (16, 1024)
# by ~31%; three further refinements changed <5%, so this is the
# practical roofline for tile shape on this backend.
TILE_ROWS = int(_os.environ.get("GPGPU_SNE_TILE_ROWS", "4"))
BLOCK_PTS = int(_os.environ.get("GPGPU_SNE_BLOCK_PTS", "256"))


def _fields_kernel(y_ref, mask_ref, origin_ref, pixel_ref, out_ref, *, grid, tile_rows):
    """One (pixel-row-tile, point-block) cell of the interaction."""
    i = pl.program_id(0)  # pixel row tile
    b = pl.program_id(1)  # point block
    y = y_ref[...]        # (B, 2)
    m = mask_ref[...]     # (B,)
    ox = origin_ref[0]
    oy = origin_ref[1]
    h = pixel_ref[0]

    # Pixel-centre coordinates of this tile: rows are y, columns are x.
    col = jnp.arange(grid, dtype=jnp.float32) + 0.5            # (G,)
    row = jnp.arange(tile_rows, dtype=jnp.float32) + 0.5       # (TR,)
    row = row + (i * tile_rows).astype(jnp.float32)
    px = ox + col * h                                          # (G,)
    py = oy + row * h                                          # (TR,)

    # d = y_i - p, evaluated for every (row, col, point) triple.
    dx = y[:, 0][None, None, :] - px[None, :, None]            # (TR, G, B) via bcast
    dy = y[:, 1][None, None, :] - py[:, None, None]
    t = (1.0 / (1.0 + dx * dx + dy * dy)) * m[None, None, :]
    s = jnp.sum(t, axis=-1)                                    # (TR, G)
    t2 = t * t
    vx = jnp.sum(t2 * dx, axis=-1)
    vy = jnp.sum(t2 * dy, axis=-1)
    acc = jnp.stack([s, vx, vy], axis=0)                       # (3, TR, G)

    # Additive blending: accumulate over the point-block grid dimension.
    @pl.when(b == 0)
    def _init():
        out_ref[...] = acc

    @pl.when(b > 0)
    def _accum():
        out_ref[...] = out_ref[...] + acc


def default_tile_rows(grid):
    """Grid-dependent tile choice (§Perf): large grids favour small pixel
    tiles on the XLA-CPU path; small grids amortise better at 8 rows."""
    return TILE_ROWS if grid >= 128 else max(TILE_ROWS, 8)


@functools.partial(jax.jit, static_argnames=("grid", "tile_rows", "block_pts"))
def fields(y, mask, origin, pixel, *, grid, tile_rows=None, block_pts=BLOCK_PTS):
    """Field texture (3, grid, grid): channels S, V_x, V_y.

    y:      (N, 2) f32 embedding positions; N must be a multiple of
            block_pts (the AOT path always pads).
    mask:   (N,)   f32 1.0/0.0 point validity.
    origin: (2,)   f32 lower-left corner of the field domain.
    pixel:  (1,)   f32 pixel side length h.
    """
    n = y.shape[0]
    if tile_rows is None:
        tile_rows = default_tile_rows(grid)
    block_pts = min(block_pts, n)
    tile_rows = min(tile_rows, grid)
    assert n % block_pts == 0, f"N={n} not a multiple of block_pts={block_pts}"
    assert grid % tile_rows == 0, f"grid={grid} not a multiple of tile_rows={tile_rows}"
    kernel = functools.partial(_fields_kernel, grid=grid, tile_rows=tile_rows)
    return pl.pallas_call(
        kernel,
        grid=(grid // tile_rows, n // block_pts),
        in_specs=[
            pl.BlockSpec((block_pts, 2), lambda i, b: (b, 0)),
            pl.BlockSpec((block_pts,), lambda i, b: (b,)),
            pl.BlockSpec((2,), lambda i, b: (0,)),
            pl.BlockSpec((1,), lambda i, b: (0,)),
        ],
        out_specs=pl.BlockSpec((3, tile_rows, grid), lambda i, b: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((3, grid, grid), jnp.float32),
        interpret=True,
    )(y, mask, origin, pixel)
