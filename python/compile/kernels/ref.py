# Pure-jnp correctness oracles for the Pallas kernels (L1).
#
# These implement Eq. 10/11 (scalar field S, vector field V) and Eq. 12
# (restricted-neighbourhood attractive force) of Pezzotti et al. 2018
# directly, with no tiling, no accumulation tricks and no Pallas — they are
# the ground truth that python/tests/ checks the kernels against, and the
# reference the Rust `embed::fieldcpu` engine mirrors.
import jax.numpy as jnp


def pixel_centers(origin, pixel, grid):
    """Pixel-centre coordinates of a grid x grid field texture.

    origin: (2,) lower-left corner of the field domain (x, y).
    pixel:  scalar pixel side length h.
    Returns (xs, ys): each (grid,), xs[j] = origin_x + (j + 1/2) h.
    """
    idx = jnp.arange(grid, dtype=jnp.float32) + 0.5
    return origin[0] + idx * pixel, origin[1] + idx * pixel


def fields_ref(y, mask, origin, pixel, grid):
    """Exact S and V fields at pixel centres (Eq. 10, 11).

    y:      (N, 2) embedding positions.
    mask:   (N,)   1.0 for real points, 0.0 for padding.
    Returns (3, grid, grid): channel 0 = S, 1 = V_x, 2 = V_y.
    Row i of the texture corresponds to the y-coordinate, column j to x
    (image convention used by the Rust side as well).
    """
    xs, ys = pixel_centers(origin, pixel, grid)
    px = xs[None, :, None]  # (1, G, 1)
    py = ys[:, None, None]  # (G, 1, 1)
    dx = y[:, 0][None, None, :] - px  # (G, G, N): y_i - p
    dy = y[:, 1][None, None, :] - py
    t = 1.0 / (1.0 + dx * dx + dy * dy) * mask[None, None, :]
    s = jnp.sum(t, axis=-1)
    vx = jnp.sum(t * t * dx, axis=-1)
    vy = jnp.sum(t * t * dy, axis=-1)
    return jnp.stack([s, vx, vy], axis=0)


def attractive_ref(y, nbr_idx, nbr_p):
    """Restricted-neighbourhood attractive force and KL pair terms (Eq. 12).

    y:       (N, 2) positions.
    nbr_idx: (N, K) int32 neighbour indices (padded slots may point
             anywhere; their p must be 0).
    nbr_p:   (N, K) joint probabilities p_ij (UNexaggerated; padded = 0).
    Returns:
      attr: (N, 2)  sum_l p_il * t_il * (y_i - y_l)   with t = 1/(1+d^2)
            (this equals Zhat * q_il * p_il * (y_i - y_l) of Eq. 12).
      kl:   (N,)    sum_l p_il * (ln p_il - ln t_il); adding ln(Zhat) *
            sum(p) to the total gives the neighbour-restricted KL estimate.
    """
    yj = y[nbr_idx]  # (N, K, 2)
    d = y[:, None, :] - yj
    d2 = jnp.sum(d * d, axis=-1)
    t = 1.0 / (1.0 + d2)
    w = nbr_p * t
    attr = jnp.sum(w[..., None] * d, axis=1)
    safe_p = jnp.where(nbr_p > 0, nbr_p, 1.0)
    kl = jnp.sum(jnp.where(nbr_p > 0, nbr_p * (jnp.log(safe_p) - jnp.log(t)), 0.0), axis=1)
    return attr, kl


def bilinear_ref(fields, y, origin, pixel):
    """Bilinear interpolation of the (3, G, G) field texture at points y.

    Matches OpenGL-style texture sampling at pixel centres: a point that
    sits exactly on pixel centre (i, j) returns fields[:, i, j].
    Returns (N, 3): columns S, V_x, V_y.
    """
    grid = fields.shape[-1]
    u = (y[:, 0] - origin[0]) / pixel - 0.5  # continuous column coord
    v = (y[:, 1] - origin[1]) / pixel - 0.5  # continuous row coord
    u = jnp.clip(u, 0.0, grid - 1.000001)
    v = jnp.clip(v, 0.0, grid - 1.000001)
    j0 = jnp.clip(jnp.floor(u).astype(jnp.int32), 0, grid - 2)
    i0 = jnp.clip(jnp.floor(v).astype(jnp.int32), 0, grid - 2)
    fu = u - j0.astype(jnp.float32)
    fv = v - i0.astype(jnp.float32)
    f00 = fields[:, i0, j0]      # (3, N)
    f01 = fields[:, i0, j0 + 1]
    f10 = fields[:, i0 + 1, j0]
    f11 = fields[:, i0 + 1, j0 + 1]
    top = f00 * (1.0 - fu) + f01 * fu
    bot = f10 * (1.0 - fu) + f11 * fu
    return (top * (1.0 - fv) + bot * fv).T
