# L1 kernel correctness: Pallas kernels vs the pure-jnp oracles in ref.py.
#
# hypothesis sweeps shapes, masks, tiling parameters and degenerate point
# configurations; assert_allclose against ref.py is the core correctness
# signal of the whole build (the Rust side loads exactly these kernels).
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import attractive as attractive_k
from compile.kernels import fields as fields_k
from compile.kernels import ref

SETTLE = dict(max_examples=25, deadline=None)


def mk_points(seed, n, extent=5.0, mask_prob=0.85):
    rng = np.random.RandomState(seed)
    y = (rng.randn(n, 2) * extent / 3).astype(np.float32)
    mask = (rng.rand(n) < mask_prob).astype(np.float32)
    y *= mask[:, None]  # padded points parked at the origin, like Rust does
    return jnp.asarray(y), jnp.asarray(mask)


class TestFieldsKernel:
    @settings(**SETTLE)
    @given(
        seed=st.integers(0, 2**31 - 1),
        n_blocks=st.integers(1, 3),
        grid_pow=st.integers(2, 5),  # G in {32..256} via 8*2^p? keep small: 4..32 rows
    )
    def test_matches_ref_random(self, seed, n_blocks, grid_pow):
        block = 64
        grid = 8 * (2 ** (grid_pow - 2))  # 8,16,32,64
        y, mask = mk_points(seed, block * n_blocks)
        origin = jnp.array([-6.0, -6.0], jnp.float32)
        pixel = jnp.array([12.0 / grid], jnp.float32)
        out = fields_k.fields(y, mask, origin, pixel, grid=grid, tile_rows=4, block_pts=block)
        expect = ref.fields_ref(y, mask, origin, pixel, grid)
        assert_allclose(np.asarray(out), np.asarray(expect), rtol=2e-4, atol=2e-4)

    def test_tiling_invariance(self):
        # The same field must come out for every legal tiling choice.
        y, mask = mk_points(3, 512)
        origin = jnp.array([-5.0, -5.0], jnp.float32)
        pixel = jnp.array([10.0 / 32], jnp.float32)
        base = None
        for tile_rows, block_pts in [(4, 512), (8, 256), (16, 128), (32, 64)]:
            out = np.asarray(
                fields_k.fields(y, mask, origin, pixel, grid=32, tile_rows=tile_rows, block_pts=block_pts)
            )
            if base is None:
                base = out
            else:
                assert_allclose(out, base, rtol=1e-5, atol=1e-5)

    def test_all_masked_gives_zero_field(self):
        y = jnp.zeros((128, 2), jnp.float32)
        mask = jnp.zeros((128,), jnp.float32)
        out = fields_k.fields(
            y, mask, jnp.array([-1.0, -1.0], jnp.float32), jnp.array([0.1], jnp.float32), grid=16,
            tile_rows=4, block_pts=64,
        )
        assert float(jnp.abs(out).max()) == 0.0

    def test_single_point_field_shape(self):
        # One point at the origin: S peaks at the nearest pixel centre and
        # V points away from the point (V(p) = t^2 (y - p)).
        y = jnp.zeros((64, 2), jnp.float32)
        mask = jnp.zeros((64,), jnp.float32).at[0].set(1.0)
        g = 16
        origin = jnp.array([-2.0, -2.0], jnp.float32)
        pixel = jnp.array([4.0 / g], jnp.float32)
        out = np.asarray(fields_k.fields(y, mask, origin, pixel, grid=g, tile_rows=4, block_pts=64))
        s = out[0]
        centre = np.unravel_index(np.argmax(s), s.shape)
        assert abs(centre[0] - g / 2) <= 1 and abs(centre[1] - g / 2) <= 1
        # V_x is positive left of the point (pushes... points right of p feel +x).
        assert out[1][g // 2, 2] > 0 > out[1][g // 2, g - 3]
        # Symmetry: S is (approximately) symmetric about the centre.
        assert_allclose(s, s[::-1, ::-1], rtol=1e-3, atol=1e-5)

    def test_coincident_points_superpose(self):
        # m copies of the same point produce exactly m * single-point field.
        n, g = 64, 16
        y = jnp.zeros((n, 2), jnp.float32).at[:, 0].set(0.3).at[:, 1].set(-0.2)
        origin = jnp.array([-2.0, -2.0], jnp.float32)
        pixel = jnp.array([4.0 / g], jnp.float32)
        m1 = jnp.zeros((n,), jnp.float32).at[0].set(1.0)
        m5 = jnp.zeros((n,), jnp.float32).at[:5].set(1.0)
        f1 = np.asarray(fields_k.fields(y, m1, origin, pixel, grid=g, tile_rows=4, block_pts=64))
        f5 = np.asarray(fields_k.fields(y, m5, origin, pixel, grid=g, tile_rows=4, block_pts=64))
        assert_allclose(f5, 5.0 * f1, rtol=1e-5, atol=1e-6)


class TestAttractiveKernel:
    @settings(**SETTLE)
    @given(seed=st.integers(0, 2**31 - 1), k=st.integers(1, 16), blocks=st.integers(1, 3))
    def test_matches_ref_random(self, seed, k, blocks):
        n = 64 * blocks
        rng = np.random.RandomState(seed)
        y = jnp.asarray(rng.randn(n, 2).astype(np.float32))
        idx = jnp.asarray(rng.randint(0, n, (n, k)).astype(np.int32))
        p = rng.rand(n, k).astype(np.float32)
        p *= rng.rand(n, k) > 0.3  # sprinkle exact zeros (padding)
        p = jnp.asarray(p / max(p.sum(), 1e-9))
        a1, kl1 = attractive_k.attractive(y, idx, p, block_rows=64)
        a2, kl2 = ref.attractive_ref(y, idx, p)
        assert_allclose(np.asarray(a1), np.asarray(a2), rtol=1e-4, atol=1e-6)
        assert_allclose(np.asarray(kl1), np.asarray(kl2), rtol=1e-4, atol=1e-6)

    def test_zero_p_gives_zero_force(self):
        n, k = 128, 8
        y = jnp.asarray(np.random.RandomState(0).randn(n, 2).astype(np.float32))
        idx = jnp.zeros((n, k), jnp.int32)
        p = jnp.zeros((n, k), jnp.float32)
        attr, kl = attractive_k.attractive(y, idx, p, block_rows=64)
        assert float(jnp.abs(attr).max()) == 0.0
        assert float(jnp.abs(kl).max()) == 0.0

    def test_two_point_analytic(self):
        # Two points at distance d: F_attr on 0 = p * t * (y0 - y1).
        n, k = 64, 4
        y = jnp.zeros((n, 2), jnp.float32).at[1, 0].set(2.0)
        idx = jnp.zeros((n, k), jnp.int32).at[0, 0].set(1)
        p = jnp.zeros((n, k), jnp.float32).at[0, 0].set(0.5)
        attr, _ = attractive_k.attractive(y, idx, p, block_rows=64)
        t = 1.0 / (1.0 + 4.0)
        assert_allclose(np.asarray(attr)[0], [0.5 * t * (-2.0), 0.0], rtol=1e-6)

    def test_symmetric_pair_forces_cancel(self):
        # Symmetric p and mutual neighbours: total attractive force is zero.
        n, k = 64, 4
        rng = np.random.RandomState(5)
        y = jnp.asarray(rng.randn(n, 2).astype(np.float32))
        idx = np.zeros((n, k), np.int32)
        p = np.zeros((n, k), np.float32)
        for i in range(n):
            j = (i + 1) % n
            idx[i, 0] = j
            p[i, 0] = 1.0 / n
            idx[i, 1] = (i - 1) % n
            p[i, 1] = 1.0 / n
        attr, _ = attractive_k.attractive(y, jnp.asarray(idx), jnp.asarray(p), block_rows=64)
        total = np.asarray(attr).sum(axis=0)
        assert_allclose(total, [0.0, 0.0], atol=1e-4)


class TestBilinear:
    def test_exact_at_pixel_centres(self):
        g = 8
        rng = np.random.RandomState(1)
        tex = jnp.asarray(rng.rand(3, g, g).astype(np.float32))
        origin = jnp.array([0.0, 0.0], jnp.float32)
        pixel = 0.5
        # Query every pixel centre.
        ii, jj = np.meshgrid(range(g), range(g), indexing="ij")
        pts = np.stack(
            [(jj.ravel() + 0.5) * pixel, (ii.ravel() + 0.5) * pixel], axis=1
        ).astype(np.float32)
        out = ref.bilinear_ref(tex, jnp.asarray(pts), origin, jnp.float32(pixel))
        expect = np.asarray(tex)[:, ii.ravel(), jj.ravel()].T
        assert_allclose(np.asarray(out), expect, rtol=1e-5, atol=1e-6)

    def test_interpolates_linearly_between_centres(self):
        g = 4
        tex = jnp.zeros((3, g, g), jnp.float32).at[0, 1, 1].set(1.0).at[0, 1, 2].set(3.0)
        origin = jnp.array([0.0, 0.0], jnp.float32)
        pixel = 1.0
        # Midway between pixel centres (1,1) and (1,2) in x.
        pt = jnp.asarray([[2.0, 1.5]], jnp.float32)
        out = ref.bilinear_ref(tex, pt, origin, jnp.float32(pixel))
        assert_allclose(float(out[0, 0]), 2.0, rtol=1e-6)
