# L2 model semantics: the fused tsne_step against a transparent numpy
# re-implementation of the same gradient-descent update, plus invariants
# (padding inertia, recentring, exaggeration linearity, scan consistency).
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model
from compile.kernels import ref


def make_problem(seed=0, n=256, n_real=100, k=8):
    rng = np.random.RandomState(seed)
    y = np.zeros((n, 2), np.float32)
    y[:n_real] = rng.randn(n_real, 2).astype(np.float32)
    mask = np.zeros((n,), np.float32)
    mask[:n_real] = 1.0
    vel = np.zeros((n, 2), np.float32)
    vel[:n_real] = rng.randn(n_real, 2).astype(np.float32) * 0.1
    gains = np.ones((n, 2), np.float32) * mask[:, None]
    idx = np.zeros((n, k), np.int32)
    p = np.zeros((n, k), np.float32)
    for i in range(n_real):
        nbrs = rng.choice([j for j in range(n_real) if j != i], k, replace=False)
        idx[i] = nbrs
        p[i] = rng.rand(k)
    p /= max(p.sum(), 1e-9)
    return (jnp.asarray(y), jnp.asarray(vel), jnp.asarray(gains), jnp.asarray(mask),
            jnp.asarray(idx), jnp.asarray(p))


def numpy_step(y, vel, gains, mask, idx, p, eta, mom, ex, grid):
    """Transparent numpy mirror of model.tsne_step."""
    y, vel, gains = (np.array(a, np.float64) for a in (y, vel, gains))
    mask_np = np.asarray(mask, np.float64)
    bbox = model.bbox_of(jnp.asarray(y, jnp.float32), jnp.asarray(mask_np, jnp.float32))
    origin, pixel = model.grid_placement(bbox, grid)
    tex = ref.fields_ref(jnp.asarray(y, jnp.float32), jnp.asarray(mask_np, jnp.float32),
                         origin, pixel, grid)
    svv = np.asarray(ref.bilinear_ref(tex, jnp.asarray(y, jnp.float32), origin, pixel), np.float64)
    zhat = max(((svv[:, 0] - 1.0) * mask_np).sum(), 1e-12)
    rep = svv[:, 1:3] / zhat
    attr, klp = ref.attractive_ref(jnp.asarray(y, jnp.float32), idx, p)
    attr = np.asarray(attr, np.float64)
    grad = 4.0 * (ex * attr + rep) * mask_np[:, None]
    same = (grad * vel) > 0
    gains = np.where(same, gains * model.GAIN_MUL, gains + model.GAIN_ADD)
    gains = np.maximum(gains, model.GAIN_MIN) * mask_np[:, None]
    vel = mom * vel - eta * gains * grad
    y = y + vel
    centre = (y * mask_np[:, None]).sum(0) / max(mask_np.sum(), 1.0)
    y = (y - centre[None, :]) * mask_np[:, None]
    kl = float(np.asarray(klp).sum() + np.log(zhat) * np.asarray(p).sum())
    return y, vel, gains, zhat, kl


class TestStep:
    def test_matches_numpy_mirror(self):
        args = make_problem()
        out = model.tsne_step(*args, jnp.float32(100.0), jnp.float32(0.5), jnp.float32(4.0), grid=32)
        exp = numpy_step(*args, 100.0, 0.5, 4.0, 32)
        for got, want, tol, name in [
            (out[0], exp[0], 1e-3, "y"),
            (out[1], exp[1], 1e-3, "vel"),
            (out[2], exp[2], 1e-5, "gains"),
        ]:
            assert_allclose(np.asarray(got), want, rtol=tol, atol=tol, err_msg=name)
        assert_allclose(float(out[3]), exp[3], rtol=1e-4)
        assert_allclose(float(out[4]), exp[4], rtol=1e-4)

    def test_padding_is_inert(self):
        args = make_problem(n=256, n_real=60)
        y0 = np.asarray(args[0])
        for _ in range(3):
            out = model.tsne_step(*args, jnp.float32(200.0), jnp.float32(0.8), jnp.float32(1.0), grid=32)
            args = (out[0], out[1], out[2], args[3], args[4], args[5])
        y = np.asarray(args[0])
        assert np.all(y[60:] == 0.0), "padded rows must stay parked at the origin"
        assert not np.allclose(y[:60], y0[:60]), "real rows must move"

    def test_recentred(self):
        args = make_problem()
        out = model.tsne_step(*args, jnp.float32(100.0), jnp.float32(0.5), jnp.float32(1.0), grid=32)
        y, mask = np.asarray(out[0]), np.asarray(args[3])
        centre = (y * mask[:, None]).sum(0) / mask.sum()
        assert np.abs(centre).max() < 1e-4

    def test_bbox_covers_real_points(self):
        args = make_problem()
        out = model.tsne_step(*args, jnp.float32(100.0), jnp.float32(0.5), jnp.float32(1.0), grid=32)
        y, mask, bbox = np.asarray(out[0]), np.asarray(args[3]), np.asarray(out[5])
        real = y[mask > 0]
        assert bbox[0] <= real[:, 0].min() + 1e-5 and bbox[2] >= real[:, 0].max() - 1e-5
        assert bbox[1] <= real[:, 1].min() + 1e-5 and bbox[3] >= real[:, 1].max() - 1e-5

    def test_grid_size_changes_only_approximation(self):
        # Finer grids must converge to the same gradient: compare the y
        # update between G=64 and G=128 — they should be close, and much
        # closer than G=8 vs G=128.
        args = make_problem(seed=3)
        outs = {}
        for g in (8, 64, 128):
            outs[g] = np.asarray(
                model.tsne_step(*args, jnp.float32(100.0), jnp.float32(0.5), jnp.float32(1.0), grid=g)[0]
            )
        err_fine = np.abs(outs[64] - outs[128]).max()
        err_coarse = np.abs(outs[8] - outs[128]).max()
        assert err_fine < err_coarse
        assert err_fine < 0.15 * max(err_coarse, 1e-9) or err_fine < 1e-3

    def test_exaggeration_scales_attraction_linearly(self):
        # With zero repulsion influence removed we can't isolate attr, but
        # the *difference* between ex=2 and ex=1 steps equals the ex=3 minus
        # ex=2 difference (linearity in the exaggeration multiplier), for
        # fixed gains response. Use fresh zero velocity so gains branch is
        # the same sign pattern.
        y, vel, gains, mask, idx, p = make_problem(seed=9)
        vel = jnp.zeros_like(vel)
        outs = {}
        for ex in (1.0, 2.0, 3.0):
            outs[ex] = np.asarray(
                model.tsne_step(y, vel, gains, mask, idx, p,
                                jnp.float32(50.0), jnp.float32(0.0), jnp.float32(ex), grid=64)[1]
            )
        d21 = outs[2.0] - outs[1.0]
        d32 = outs[3.0] - outs[2.0]
        assert_allclose(d21, d32, rtol=1e-3, atol=1e-5)


class TestScan:
    def test_scan_equals_repeated_steps(self):
        args = make_problem(seed=4, n=128, n_real=50, k=6)
        eta, mom, ex = jnp.float32(80.0), jnp.float32(0.5), jnp.float32(2.0)
        # 4 single steps
        s = args
        for _ in range(4):
            out = model.tsne_step(*s, eta, mom, ex, grid=32)
            s = (out[0], out[1], out[2], s[3], s[4], s[5])
        # fused scan of 4
        fused = model.tsne_steps(*args, eta, mom, ex, grid=32, steps=4)
        assert_allclose(np.asarray(fused[0]), np.asarray(s[0]), rtol=1e-4, atol=1e-5)
        assert_allclose(float(fused[3]), float(out[3]), rtol=1e-4)
        assert_allclose(float(fused[4]), float(out[4]), rtol=1e-4)


class TestGridPlacement:
    def test_covers_bbox_with_margin(self):
        bbox = jnp.asarray([-3.0, -1.0, 5.0, 2.0], jnp.float32)
        origin, pixel = model.grid_placement(bbox, 64)
        origin, pixel = np.asarray(origin), float(pixel[0])
        assert origin[0] < -3.0 and origin[1] < -1.0
        assert origin[0] + 64 * pixel > 5.0 and origin[1] + 64 * pixel > 2.0
        # Domain is square and centred.
        cx = origin[0] + 32 * pixel
        assert abs(cx - 1.0) < 1e-5

    def test_degenerate_bbox_survives(self):
        bbox = jnp.asarray([0.5, 0.5, 0.5, 0.5], jnp.float32)
        origin, pixel = model.grid_placement(bbox, 32)
        assert float(pixel[0]) > 0.0
        assert np.all(np.isfinite(np.asarray(origin)))
