# AOT path sanity: HLO text emission, manifest structure, selfcheck
# stability. (The Rust integration test runtime_roundtrip.rs verifies the
# same artifacts execute correctly through PJRT.)
import json
import os

import jax
import numpy as np
import pytest

from compile import aot, model


class TestLowering:
    def test_hlo_text_parses_as_module(self):
        text = aot.to_hlo_text(aot.lower_variant(model.step_fn(16), 128, 8))
        assert text.startswith("HloModule"), text[:64]
        # All six outputs present in the root tuple.
        assert "tuple(" in text

    def test_hlo_is_deterministic(self):
        a = aot.to_hlo_text(aot.lower_variant(model.step_fn(16), 128, 8))
        b = aot.to_hlo_text(aot.lower_variant(model.step_fn(16), 128, 8))
        assert a == b

    def test_scan_variant_lowers(self):
        text = aot.to_hlo_text(aot.lower_variant(model.steps_fn(16, 3), 128, 8))
        assert text.startswith("HloModule")

    def test_arg_order_matches_manifest_names(self):
        # The Rust runtime feeds buffers positionally in ARG_NAMES order;
        # lock the contract.
        assert aot.ARG_NAMES == [
            "y", "vel", "gains", "mask", "nbr_idx", "nbr_p",
            "eta", "momentum", "exaggeration",
        ]
        assert aot.OUT_NAMES == ["y", "vel", "gains", "zhat", "kl", "bbox"]
        spec = aot.example_args(128, 8)
        assert len(spec) == len(aot.ARG_NAMES)
        assert spec[4].dtype == np.int32


class TestSelfcheck:
    def test_selfcheck_deterministic_and_finite(self):
        a = aot.selfcheck_case(256, 16, 32)
        b = aot.selfcheck_case(256, 16, 32)
        assert a == b
        assert np.isfinite(a["zhat"]) and a["zhat"] > 0
        assert np.isfinite(a["kl"])
        assert len(a["y_init"]) == 2 * a["n_real"]
        assert len(a["y_out"]) == 2 * a["n_real"]

    def test_selfcheck_json_serialisable(self):
        c = aot.selfcheck_case(256, 16, 32)
        text = json.dumps(c)
        assert json.loads(text) == c


class TestEndToEndArtifacts:
    def test_emit_to_tmpdir(self, tmp_path):
        import subprocess, sys
        env = dict(os.environ)
        r = subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path),
             "--ns", "128", "--grids", "16", "--no-scan", "--k", "8"],
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            capture_output=True, text=True, env=env,
        )
        assert r.returncode == 0, r.stderr
        manifest = json.loads((tmp_path / "manifest.json").read_text())
        assert len(manifest["artifacts"]) == 1
        art = manifest["artifacts"][0]
        assert (tmp_path / art["file"]).exists()
        assert art["n"] == 128 and art["grid"] == 16 and art["k"] == 8
        assert (tmp_path / "selfcheck.json").exists()
