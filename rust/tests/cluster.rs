//! Cluster integration harness: one in-process [`Router`] fronting two
//! real `serve` workers over TCP, pinning the sharded coordinator's
//! contract —
//!
//! * **sticky routing**: a dataset fingerprint always lands on the HRW
//!   owner, so repeat submits hit that shard's similarity caches
//!   (`sim_cache_hit=true` on the second wait);
//! * **live migration ≡ uninterrupted**: `migrate` (checkpoint → stop →
//!   resume elsewhere) finishes with final positions bit-identical to a
//!   single-node run that was never touched;
//! * **failover ≡ uninterrupted**: killing the owner of a running job
//!   re-admits it from the replicated checkpoint on the survivor, again
//!   bit-identically, and the same fingerprint then routes to (and
//!   cache-hits on) the survivor.
//!
//! The fault registry is process-global; tests that arm faults (or
//! depend on none being armed) serialise on one lock, and the CI
//! `cluster` job runs this binary with `--test-threads=1`.

use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use gpgpu_sne::cluster::{Router, RouterConfig};
use gpgpu_sne::coordinator::progress::JobState;
use gpgpu_sne::coordinator::{
    faultinject, protocol, run_pipeline, EmbeddingService, JobSpec, KnnMethod, ServiceConfig,
};
use gpgpu_sne::embed::OptParams;
use gpgpu_sne::util::json::{self, Json};

static CLUSTER_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    let guard = CLUSTER_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    faultinject::disarm_all();
    guard
}

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("gsne-cluster-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// One in-process worker: a real `EmbeddingService` served over TCP.
struct Worker {
    svc: Arc<EmbeddingService>,
    addr: std::net::SocketAddr,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Worker {
    fn start() -> Self {
        let svc = Arc::new(EmbeddingService::with_config(
            None,
            ServiceConfig { max_concurrent: 2, ..Default::default() },
        ));
        let (tx, rx) = std::sync::mpsc::channel();
        let svc2 = svc.clone();
        let handle = std::thread::spawn(move || {
            let _ = protocol::serve_with(svc2, "127.0.0.1:0", 64, move |a| {
                let _ = tx.send(a);
            });
        });
        let addr = rx.recv_timeout(Duration::from_secs(10)).expect("worker bind");
        Worker { svc, addr, handle: Some(handle) }
    }

    /// Kill the worker: stop computing (live jobs park mid-run) and
    /// close the listener, so heartbeats see connection-refused — from
    /// the router's side this is indistinguishable from a crash.
    fn kill(&mut self) {
        self.svc.drain(Duration::from_secs(30));
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn call(router: &Router, req: &str) -> Json {
    let (resp, _) = router.handle_line(req);
    json::parse(&resp).unwrap_or_else(|e| panic!("bad router response '{resp}': {e}"))
}

fn assert_ok(v: &Json) {
    assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{v}");
}

fn submit_line(n: usize, iters: usize, seed: u64) -> String {
    format!(
        r#"{{"cmd":"submit","dataset":"gaussians","n":{n},"engine":"bh-0.5","iters":{iters},"perplexity":8,"knn":"brute","seed":{seed},"snapshot_every":1}}"#
    )
}

/// The in-process twin of [`submit_line`] — field-for-field what
/// `spec_from_json` builds, so reference runs are comparable.
fn submit_spec(n: usize, iters: usize, seed: u64) -> JobSpec {
    JobSpec {
        dataset: "gaussians".into(),
        n,
        engine: "bh-0.5".into(),
        perplexity: 8.0,
        knn: KnnMethod::Brute,
        params: OptParams { iters, seed, ..Default::default() },
        snapshot_every: 1,
        auto_stop: None,
        priority: Default::default(),
        seed,
        y0: None,
        resume_from: None,
    }
}

/// Poll the router's `status` proxy until the job reports at least
/// `min_iter` optimisation steps.
fn wait_until_iter(router: &Router, job: u64, min_iter: u64) {
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        let v = call(router, &format!(r#"{{"cmd":"status","job":{job}}}"#));
        if v.get("ok") == Some(&Json::Bool(true))
            && v.num_field("iter").unwrap_or(0.0) as u64 >= min_iter
        {
            return;
        }
        assert!(Instant::now() < deadline, "job {job} never reached iter {min_iter}: {v}");
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// The routing entry for `job` from `cluster_stats`: (worker, worker_job,
/// replicated_iter).
fn placement(router: &Router, job: u64) -> (u64, u64, u64) {
    let v = call(router, r#"{"cmd":"cluster_stats"}"#);
    let jobs = v.get("jobs").and_then(Json::as_arr).expect("jobs array");
    let j = jobs
        .iter()
        .find(|j| j.num_field("job") == Some(job as f64))
        .unwrap_or_else(|| panic!("job {job} missing from cluster_stats: {v}"));
    (
        j.num_field("worker").unwrap() as u64,
        j.num_field("worker_job").unwrap() as u64,
        j.num_field("replicated_iter").unwrap_or(0.0) as u64,
    )
}

#[test]
fn fingerprint_routing_is_sticky_and_matches_hrw() {
    let _l = lock();
    let w1 = Worker::start();
    let w2 = Worker::start();
    let router = Router::new(RouterConfig { heartbeat_interval: None, ..Default::default() });
    router.register_worker(&w1.addr.to_string());
    router.register_worker(&w2.addr.to_string());

    for seed in 0..6u64 {
        let v = call(&router, &submit_line(80, 10, seed));
        assert_ok(&v);
        let worker = v.num_field("worker").unwrap() as u64;
        // The reported owner is the HRW decision for the dataset's
        // content fingerprint — recomputable by anyone.
        let fp = u64::from_str_radix(v.str_field("fingerprint").unwrap(), 16).unwrap();
        let expect = gpgpu_sne::data::by_name("gaussians", 80, seed).unwrap().fingerprint();
        assert_eq!(fp, expect, "router fingerprint disagrees with the dataset's");
        assert_eq!(router.membership.owner_of(fp).unwrap().0, worker);
        // Sticky: the same spec routes to the same shard every time.
        let v2 = call(&router, &submit_line(80, 10, seed));
        assert_ok(&v2);
        assert_eq!(v2.num_field("worker"), Some(worker as f64), "resubmit moved shards");
    }
}

#[test]
fn repeat_submit_hits_the_owning_shards_sim_cache() {
    let _l = lock();
    let w1 = Worker::start();
    let w2 = Worker::start();
    let router = Router::new(RouterConfig { heartbeat_interval: None, ..Default::default() });
    router.register_worker(&w1.addr.to_string());
    router.register_worker(&w2.addr.to_string());

    let v = call(&router, &submit_line(100, 20, 3));
    assert_ok(&v);
    let a = v.num_field("job").unwrap() as u64;
    let first = call(&router, &format!(r#"{{"cmd":"wait","job":{a}}}"#));
    assert_ok(&first);
    assert_eq!(first.num_field("iters"), Some(20.0), "{first}");
    assert_eq!(first.get("sim_cache_hit"), Some(&Json::Bool(false)), "{first}");

    let v = call(&router, &submit_line(100, 20, 3));
    assert_ok(&v);
    let b = v.num_field("job").unwrap() as u64;
    assert_ne!(a, b, "router ids are cluster-unique");
    let second = call(&router, &format!(r#"{{"cmd":"wait","job":{b}}}"#));
    assert_ok(&second);
    assert_eq!(
        second.get("sim_cache_hit"),
        Some(&Json::Bool(true)),
        "repeat submit must hit the owning shard's warm similarity cache: {second}"
    );
}

#[test]
fn live_migration_is_bit_identical_to_uninterrupted() {
    let _l = lock();
    let reference =
        run_pipeline(&submit_spec(300, 250, 7), None, &JobState::default()).unwrap();

    let workers = [Worker::start(), Worker::start()];
    let router = Router::new(RouterConfig { heartbeat_interval: None, ..Default::default() });
    for w in &workers {
        router.register_worker(&w.addr.to_string());
    }

    let v = call(&router, &submit_line(300, 250, 7));
    assert_ok(&v);
    let job = v.num_field("job").unwrap() as u64;
    let src = v.num_field("worker").unwrap() as u64;

    // Let it do real optimisation work before moving it.
    wait_until_iter(&router, job, 40);
    let m = call(&router, &format!(r#"{{"cmd":"migrate","job":{job}}}"#));
    assert_ok(&m);
    assert_eq!(m.num_field("from"), Some(src as f64), "{m}");
    let dst = m.num_field("to").unwrap() as u64;
    assert_ne!(dst, src, "migration must change shards: {m}");
    assert!(m.num_field("resumed_iter").unwrap() >= 40.0, "{m}");

    let done = call(&router, &format!(r#"{{"cmd":"wait","job":{job}}}"#));
    assert_ok(&done);
    assert_eq!(done.num_field("iters"), Some(250.0), "{done}");

    // Bit-identical: read the final embedding straight off the target
    // worker's service (no JSON round trip in the comparison).
    let (owner, worker_job, _) = placement(&router, job);
    assert_eq!(owner, dst);
    let res = workers[(dst - 1) as usize].svc.wait(worker_job).expect("migrated job result");
    assert_eq!(res.iters_run, 250);
    assert_eq!(
        res.embedding, reference.embedding,
        "migrated run diverged from the uninterrupted reference"
    );
}

#[test]
fn killing_the_owner_fails_over_bit_identically_and_reroutes_its_keys() {
    let _l = lock();
    let reference =
        run_pipeline(&submit_spec(300, 300, 11), None, &JobState::default()).unwrap();

    let dir = tmp_dir("failover");
    let mut workers = [Worker::start(), Worker::start()];
    let router = Arc::new(Router::new(RouterConfig {
        heartbeat_interval: None, // driven by the test for determinism
        heartbeat_timeout: Duration::from_millis(250),
        state_dir: Some(dir.clone()),
        ..Default::default()
    }));
    for w in &workers {
        router.register_worker(&w.addr.to_string());
    }

    let v = call(&router, &submit_line(300, 300, 11));
    assert_ok(&v);
    let job = v.num_field("job").unwrap() as u64;
    let owner = v.num_field("worker").unwrap() as u64;
    let survivor = if owner == 1 { 2u64 } else { 1u64 };

    // Heartbeat until the router holds a replicated checkpoint (the
    // failover replica) for the running job.
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        router.heartbeat_once();
        let (_, _, replicated) = placement(&router, job);
        if replicated >= 1 {
            break;
        }
        assert!(Instant::now() < deadline, "no checkpoint replicated");
        std::thread::sleep(Duration::from_millis(20));
    }

    // Kill the owner mid-run, then keep heartbeating (as the background
    // loop would) until the router declares it dead and re-admits the
    // job on the survivor.
    workers[(owner - 1) as usize].kill();
    let stop = Arc::new(AtomicBool::new(false));
    let driver = {
        let (router, stop) = (router.clone(), stop.clone());
        std::thread::spawn(move || {
            while !stop.load(Ordering::SeqCst) {
                router.heartbeat_once();
                std::thread::sleep(Duration::from_millis(50));
            }
        })
    };

    let done = call(&router, &format!(r#"{{"cmd":"wait","job":{job}}}"#));
    assert_ok(&done);
    assert_eq!(done.num_field("iters"), Some(300.0), "{done}");

    let (new_owner, worker_job, _) = placement(&router, job);
    assert_eq!(new_owner, survivor, "job must land on the survivor");
    let res = workers[(survivor - 1) as usize].svc.wait(worker_job).expect("failover result");
    assert_eq!(
        res.embedding, reference.embedding,
        "failed-over run diverged from the uninterrupted reference"
    );

    // The dead shard's keys now route to the survivor, whose caches the
    // failover replay just warmed: a repeat submit cache-hits there.
    let v = call(&router, &submit_line(300, 300, 11));
    assert_ok(&v);
    assert_eq!(v.num_field("worker"), Some(survivor as f64), "{v}");
    let again = v.num_field("job").unwrap() as u64;
    let rerun = call(&router, &format!(r#"{{"cmd":"wait","job":{again}}}"#));
    assert_ok(&rerun);
    assert_eq!(
        rerun.get("sim_cache_hit"),
        Some(&Json::Bool(true)),
        "post-failover repeat submit must hit the survivor's warm cache: {rerun}"
    );

    stop.store(true, Ordering::SeqCst);
    driver.join().unwrap();

    // Terminal jobs leave the replication journal (nothing to revive).
    let journal = gpgpu_sne::coordinator::JobJournal::open(&dir.join("cluster-journal")).unwrap();
    assert!(
        journal.read_all().iter().all(|e| e.id != job),
        "terminal job must be dropped from the cluster journal"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn drain_shutdown_migrates_a_shards_jobs_off() {
    let _l = lock();
    let workers = [Worker::start(), Worker::start()];
    let router = Router::new(RouterConfig { heartbeat_interval: None, ..Default::default() });
    for w in &workers {
        router.register_worker(&w.addr.to_string());
    }

    let v = call(&router, &submit_line(300, 400, 13));
    assert_ok(&v);
    let job = v.num_field("job").unwrap() as u64;
    let owner = v.num_field("worker").unwrap() as u64;
    wait_until_iter(&router, job, 20);

    // Drain the owning shard: its live job migrates to the other
    // worker before the worker itself shuts down.
    let (resp, keep) = router.handle_line(&format!(r#"{{"cmd":"shutdown","worker":{owner}}}"#));
    assert!(keep, "per-worker drain keeps the router serving");
    let v = json::parse(&resp).unwrap();
    assert_ok(&v);
    assert_eq!(v.num_field("migrated_jobs"), Some(1.0), "{v}");

    let (new_owner, _, _) = placement(&router, job);
    assert_ne!(new_owner, owner, "drained shard must not keep the job");
    let done = call(&router, &format!(r#"{{"cmd":"wait","job":{job}}}"#));
    assert_ok(&done);
    assert_eq!(done.num_field("iters"), Some(400.0), "{done}");

    let stats = call(&router, r#"{"cmd":"cluster_stats"}"#);
    assert_eq!(stats.num_field("workers_up"), Some(1.0), "{stats}");
    assert_eq!(stats.num_field("migrations"), Some(1.0), "{stats}");
}

#[test]
fn router_journal_survives_restart_and_readmits() {
    let _l = lock();
    let dir = tmp_dir("recover");
    let workers = [Worker::start(), Worker::start()];
    let addrs: Vec<String> = workers.iter().map(|w| w.addr.to_string()).collect();
    let mk = || {
        let r = Router::new(RouterConfig {
            heartbeat_interval: None,
            state_dir: Some(dir.clone()),
            ..Default::default()
        });
        for a in &addrs {
            r.register_worker(a);
        }
        r
    };

    let router = mk();
    let v = call(&router, &submit_line(300, 400, 17));
    assert_ok(&v);
    let job = v.num_field("job").unwrap() as u64;
    wait_until_iter(&router, job, 30);
    router.heartbeat_once(); // replicate a checkpoint into the journal
    let (_, _, replicated) = placement(&router, job);
    assert!(replicated >= 1, "journal must hold a replica before the 'crash'");
    drop(router); // router "crashes"; workers keep running

    // A fresh router over the same state dir re-admits the job under
    // its original id (resuming from the replica — the worker-side copy
    // keeps running too, but the new submit is what the route tracks).
    let router = mk();
    assert_eq!(router.recover(), 1, "one journalled job to re-admit");
    let done = call(&router, &format!(r#"{{"cmd":"wait","job":{job}}}"#));
    assert_ok(&done);
    assert_eq!(done.num_field("iters"), Some(400.0), "{done}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Structured-error assertion: `ok:false` with a non-empty `error`
/// message containing `needle` — and, because `call` already parsed a
/// full response line, the request demonstrably did not hang.
fn assert_err_containing(v: &Json, needle: &str) {
    assert_eq!(v.get("ok"), Some(&Json::Bool(false)), "{v}");
    let msg = v.str_field("error").expect("structured error carries a message");
    assert!(!msg.is_empty(), "{v}");
    assert!(msg.contains(needle), "error '{msg}' should mention '{needle}'");
}

#[test]
fn empty_fleet_answers_structurally_instead_of_hanging() {
    let _l = lock();
    let router = Router::new(RouterConfig { heartbeat_interval: None, ..Default::default() });

    // `cluster_stats` on a fleet of zero workers: a complete, well-typed
    // answer — empty arrays, zero counters — not an error and not a hang.
    let stats = call(&router, r#"{"cmd":"cluster_stats"}"#);
    assert_ok(&stats);
    assert_eq!(stats.get("workers").and_then(Json::as_arr).map(Vec::len), Some(0), "{stats}");
    assert_eq!(stats.get("jobs").and_then(Json::as_arr).map(Vec::len), Some(0), "{stats}");
    assert_eq!(stats.num_field("workers_up"), Some(0.0), "{stats}");
    assert_eq!(stats.num_field("migrations"), Some(0.0), "{stats}");
    assert_eq!(stats.num_field("failovers"), Some(0.0), "{stats}");

    // Submitting into the void is a *retriable* structured error.
    let v = call(&router, &submit_line(80, 10, 1));
    assert_eq!(v.str_field("code"), Some("no_workers"), "{v}");
    assert_eq!(v.get("retriable"), Some(&Json::Bool(true)), "{v}");

    // Migrating a job that was never routed.
    let v = call(&router, r#"{"cmd":"migrate","job":42}"#);
    assert_err_containing(&v, "unknown job");
    let v = call(&router, r#"{"cmd":"migrate"}"#);
    assert_err_containing(&v, "requires a job id");

    // `hello` without an addr is a usage error, not a registration.
    let v = call(&router, r#"{"cmd":"hello"}"#);
    assert_err_containing(&v, "requires the worker's addr");
    assert_eq!(router.membership.up_count(), 0);
}

#[test]
fn migrate_error_paths_are_structured_and_hello_reanimates() {
    let _l = lock();
    let w1 = Worker::start();
    let w2 = Worker::start();
    let router = Router::new(RouterConfig { heartbeat_interval: None, ..Default::default() });
    let id1 = router.register_worker(&w1.addr.to_string());
    let id2 = router.register_worker(&w2.addr.to_string());

    // A long-running routed job to aim the migrations at.
    let v = call(&router, &submit_line(200, 100_000, 5));
    assert_ok(&v);
    let job = v.num_field("job").unwrap() as u64;
    let owner = v.num_field("worker").unwrap() as u64;
    let other = if owner == id1 { id2 } else { id1 };
    wait_until_iter(&router, job, 5);

    // Target worker id that was never registered.
    let v = call(&router, &format!(r#"{{"cmd":"migrate","job":{job},"to":99}}"#));
    assert_err_containing(&v, "unknown target worker 99");

    // Migrating a job onto the worker it already occupies.
    let v = call(&router, &format!(r#"{{"cmd":"migrate","job":{job},"to":{owner}}}"#));
    assert_err_containing(&v, &format!("already on worker {owner}"));

    // A Draining target is alive but not eligible.
    router.membership.mark_draining(other);
    let v = call(&router, &format!(r#"{{"cmd":"migrate","job":{job},"to":{other}}}"#));
    assert_err_containing(&v, &format!("target worker {other} is not up"));

    // A Dead target is no better — and with every alternative down the
    // untargeted form reports the fleet-wide condition.
    router.membership.mark_dead(other);
    let v = call(&router, &format!(r#"{{"cmd":"migrate","job":{job},"to":{other}}}"#));
    assert_err_containing(&v, &format!("target worker {other} is not up"));
    let v = call(&router, &format!(r#"{{"cmd":"migrate","job":{job}}}"#));
    assert_err_containing(&v, "no alternative alive worker");

    // None of the failed migrations moved the route or counted.
    let (still_owner, _, _) = placement(&router, job);
    assert_eq!(still_owner, owner, "failed migrations must not move the job");
    let stats = call(&router, r#"{"cmd":"cluster_stats"}"#);
    assert_eq!(stats.num_field("migrations"), Some(0.0), "{stats}");

    // Duplicate-addr `hello` reanimates the dead worker under its
    // original id (idempotent registration), and the fleet heals.
    let addr_other = if other == id1 { &w1 } else { &w2 };
    let v = call(&router, &format!(r#"{{"cmd":"hello","addr":"{}"}}"#, addr_other.addr));
    assert_ok(&v);
    assert_eq!(v.num_field("worker"), Some(other as f64), "same addr keeps its worker id");
    assert_eq!(router.membership.up_count(), 2);

    // With the target healthy again the same migrate now succeeds...
    let v = call(&router, &format!(r#"{{"cmd":"migrate","job":{job},"to":{other}}}"#));
    assert_ok(&v);
    assert_eq!(v.num_field("to"), Some(other as f64), "{v}");

    // ...and once the job is terminal, migrating it is an error again.
    let v = call(&router, &format!(r#"{{"cmd":"stop","job":{job}}}"#));
    assert_ok(&v);
    let done = call(&router, &format!(r#"{{"cmd":"wait","job":{job}}}"#));
    assert_ok(&done);
    let v = call(&router, &format!(r#"{{"cmd":"migrate","job":{job}}}"#));
    assert_err_containing(&v, "job is terminal");
}
