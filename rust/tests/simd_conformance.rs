//! SIMD dispatch conformance (ARCHITECTURE.md §SIMD): every vector
//! tier must reproduce the scalar reference kernels **bit-for-bit** —
//! across random sizes (including non-lane-multiple tails), subnormals
//! and signed zeros — and the full engines must produce the same
//! embedding under forced-scalar dispatch as under auto. This is the
//! contract that makes `PALLAS_SIMD` a pure performance switch and
//! keeps checkpoint replay exact across machines with different vector
//! units.

use std::sync::Mutex;

use gpgpu_sne::embed::{self, OptParams};
use gpgpu_sne::hd::{bruteforce, perplexity, Dataset};
use gpgpu_sne::util::prop::{self, usize_in};
use gpgpu_sne::util::rng::Rng;
use gpgpu_sne::util::simd::{self, GdArgs, Kernels, SpectralArgs, Tier};

/// The supported vector tiers (beyond scalar) on this machine. Empty on
/// targets with no vector kernels — the properties then just pin the
/// scalar kernels against themselves, which keeps the suite portable.
fn vector_tiers() -> Vec<&'static Kernels> {
    Tier::ALL
        .iter()
        .copied()
        .filter(|&t| t != Tier::Scalar && simd::supported(t))
        .map(Kernels::for_tier)
        .collect()
}

/// Deterministic test vector: Gaussian values with special values
/// (signed zeros, subnormals) sprinkled at fixed offsets so every
/// workload exercises the edge cases the determinism contract names.
fn test_vec(len: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed.wrapping_mul(0x9E37_79B9).wrapping_add(1));
    let mut v: Vec<f32> = (0..len).map(|_| rng.gauss_f32(0.0, 2.0)).collect();
    for (i, x) in v.iter_mut().enumerate() {
        match i % 11 {
            3 => *x = 0.0,
            5 => *x = -0.0,
            7 => *x = 1.0e-41,  // positive subnormal
            9 => *x = -7.5e-42, // negative subnormal
            _ => {}
        }
    }
    v
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn dot_and_dot4_match_scalar_bitwise() {
    // Also the ISSUE 8 tail-handling pin: dot4's lanes must equal dot on
    // the same rows bit-for-bit on EVERY tier, so quad-scored and
    // tail-scored candidates in scan_candidates cannot drift.
    prop::check("simd dot/dot4 vs scalar", &usize_in(0, 133), |&d| {
        let q = test_vec(d, d as u64 + 1);
        let b: Vec<Vec<f32>> = (0..4u64).map(|j| test_vec(d, 100 + j + d as u64)).collect();
        let scalar = Kernels::for_tier(Tier::Scalar);
        let want: Vec<u32> = b.iter().map(|bj| (scalar.dot)(&q, bj).to_bits()).collect();
        for k in std::iter::once(scalar).chain(vector_tiers()) {
            for (j, bj) in b.iter().enumerate() {
                if (k.dot)(&q, bj).to_bits() != want[j] {
                    return Err(format!("dot: tier {} row {j} d={d}", k.tier.name()));
                }
            }
            let quad = (k.dot4)(&q, &b[0], &b[1], &b[2], &b[3]);
            for (j, v) in quad.iter().enumerate() {
                if v.to_bits() != want[j] {
                    return Err(format!("dot4 lane {j} != dot: tier {} d={d}", k.tier.name()));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn rank1_update_matches_scalar_bitwise() {
    prop::check("simd rank1_update vs scalar", &usize_in(0, 133), |&n| {
        let row = test_vec(n, 7 + n as u64);
        let acc0 = test_vec(n, 900 + n as u64);
        let qv = -1.75f32;
        let mut want = acc0.clone();
        (Kernels::for_tier(Tier::Scalar).rank1_update)(&mut want, &row, qv);
        for k in vector_tiers() {
            let mut got = acc0.clone();
            (k.rank1_update)(&mut got, &row, qv);
            if bits(&got) != bits(&want) {
                return Err(format!("tier {} n={n}", k.tier.name()));
            }
        }
        Ok(())
    });
}

#[test]
fn butterflies_match_scalar_bitwise() {
    prop::check("simd butterflies vs scalar", &usize_in(0, 67), |&half| {
        let wr = test_vec(half, 1 + half as u64);
        let wi = test_vec(half, 2 + half as u64);
        for inverse in [false, true] {
            let run = |k: &Kernels| {
                let mut ra = test_vec(half, 3 + half as u64);
                let mut ia = test_vec(half, 4 + half as u64);
                let mut rb = test_vec(half, 5 + half as u64);
                let mut ib = test_vec(half, 6 + half as u64);
                (k.butterflies)(&mut ra, &mut ia, &mut rb, &mut ib, &wr, &wi, inverse);
                [bits(&ra), bits(&ia), bits(&rb), bits(&ib)]
            };
            let want = run(Kernels::for_tier(Tier::Scalar));
            for k in vector_tiers() {
                if run(k) != want {
                    return Err(format!(
                        "tier {} half={half} inverse={inverse}",
                        k.tier.name()
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn transpose4x4_matches_scalar() {
    prop::check2("simd transpose4x4", &usize_in(4, 13), &usize_in(4, 13), |&ss, &ds| {
        let src = test_vec(3 * ss + 4, ss as u64);
        let mut want = vec![0.0f32; 3 * ds + 4];
        (Kernels::for_tier(Tier::Scalar).transpose4x4)(&src, ss, &mut want, ds);
        for k in vector_tiers() {
            let mut got = vec![0.0f32; 3 * ds + 4];
            (k.transpose4x4)(&src, ss, &mut got, ds);
            if bits(&got) != bits(&want) {
                return Err(format!("tier {} ss={ss} ds={ds}", k.tier.name()));
            }
        }
        Ok(())
    });
}

#[test]
fn deposit4x4_matches_scalar_bitwise() {
    prop::check("simd deposit4x4 vs scalar", &usize_in(4, 40), |&stride| {
        let base = stride / 3;
        let size = base + 3 * stride + 4 + 5;
        let out0 = test_vec(size, stride as u64);
        let wu: [f32; 4] = test_vec(4, 11 + stride as u64).try_into().unwrap();
        let wv: [f32; 4] = test_vec(4, 12 + stride as u64).try_into().unwrap();
        let mut want = out0.clone();
        (Kernels::for_tier(Tier::Scalar).deposit4x4)(&mut want, base, stride, &wu, &wv);
        for k in vector_tiers() {
            let mut got = out0.clone();
            (k.deposit4x4)(&mut got, base, stride, &wu, &wv);
            if bits(&got) != bits(&want) {
                return Err(format!("tier {} stride={stride}", k.tier.name()));
            }
        }
        Ok(())
    });
}

#[test]
fn cauchy_row_matches_scalar_bitwise() {
    prop::check("simd cauchy_row vs scalar", &usize_in(0, 133), |&g| {
        let px = test_vec(g, 3 + g as u64);
        let run = |k: &Kernels| {
            let mut s = test_vec(g, 21 + g as u64);
            let mut vx = test_vec(g, 22 + g as u64);
            let mut vy = test_vec(g, 23 + g as u64);
            (k.cauchy_row)(&px, 0.7, -1.3, 2.1, &mut s, &mut vx, &mut vy);
            [bits(&s), bits(&vx), bits(&vy)]
        };
        let want = run(Kernels::for_tier(Tier::Scalar));
        for k in vector_tiers() {
            if run(k) != want {
                return Err(format!("tier {} g={g}", k.tier.name()));
            }
        }
        Ok(())
    });
}

#[test]
fn gd_update_matches_scalar_bitwise() {
    prop::check("simd gd_update vs scalar", &usize_in(0, 67), |&pairs| {
        let m = 2 * pairs;
        for track_bbox in [false, true] {
            let run = |k: &Kernels| {
                let mut y = test_vec(m, 31 + m as u64);
                let mut vel = test_vec(m, 32 + m as u64);
                let mut gains = test_vec(m, 33 + m as u64);
                let attr = test_vec(m, 34 + m as u64);
                let rep = test_vec(m, 35 + m as u64);
                let part = (k.gd_update)(GdArgs {
                    y: &mut y,
                    vel: &mut vel,
                    gains: &mut gains,
                    attr: &attr,
                    rep: &rep,
                    exaggeration: 4.0,
                    inv_z: 0.25,
                    eta: 180.0,
                    momentum: 0.6,
                    track_bbox,
                });
                (
                    bits(&y),
                    bits(&vel),
                    bits(&gains),
                    part.sx.to_bits(),
                    part.sy.to_bits(),
                    part.bbox.map(f32::to_bits),
                )
            };
            let want = run(Kernels::for_tier(Tier::Scalar));
            for k in vector_tiers() {
                if run(k) != want {
                    return Err(format!(
                        "tier {} pairs={pairs} track_bbox={track_bbox}",
                        k.tier.name()
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn spectral_mul_matches_scalar_bitwise() {
    // The ISSUE 9 satellite pin: the FFT field backend's fused
    // three-channel spectral multiply must not depend on the tier, or
    // fieldfft checkpoints stop replaying across machines.
    prop::check("simd spectral_mul vs scalar", &usize_in(0, 133), |&n| {
        let ks_re = test_vec(n, 41 + n as u64);
        let ks_im = test_vec(n, 42 + n as u64);
        let kx_re = test_vec(n, 43 + n as u64);
        let kx_im = test_vec(n, 44 + n as u64);
        let ky_re = test_vec(n, 45 + n as u64);
        let ky_im = test_vec(n, 46 + n as u64);
        let run = |k: &Kernels| {
            let mut sre = test_vec(n, 51 + n as u64);
            let mut sim = test_vec(n, 52 + n as u64);
            let mut xre = test_vec(n, 53 + n as u64);
            let mut xim = test_vec(n, 54 + n as u64);
            let mut yre = test_vec(n, 55 + n as u64);
            let mut yim = test_vec(n, 56 + n as u64);
            (k.spectral_mul)(SpectralArgs {
                sre: &mut sre,
                sim: &mut sim,
                xre: &mut xre,
                xim: &mut xim,
                yre: &mut yre,
                yim: &mut yim,
                ks_re: &ks_re,
                ks_im: &ks_im,
                kx_re: &kx_re,
                kx_im: &kx_im,
                ky_re: &ky_re,
                ky_im: &ky_im,
            });
            [bits(&sre), bits(&sim), bits(&xre), bits(&xim), bits(&yre), bits(&yim)]
        };
        let want = run(Kernels::for_tier(Tier::Scalar));
        for k in vector_tiers() {
            if run(k) != want {
                return Err(format!("tier {} n={n}", k.tier.name()));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Engine-level golden runs. `set_tier` is process-global, so every test
// that flips it serialises on this lock (libtest runs tests on threads).
// ---------------------------------------------------------------------

static TIER_LOCK: Mutex<()> = Mutex::new(());

#[test]
fn brute_knn_graph_identical_across_tiers() {
    let _guard = TIER_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let (n, d, k) = (300usize, 48usize, 12usize);
    let mut rng = Rng::new(77);
    let x: Vec<f32> = (0..n * d).map(|_| rng.gauss_f32(0.0, 1.0)).collect();
    let ds = Dataset::new("simd-conf", n, d, x, vec![]);
    simd::set_tier(Some(Tier::Scalar));
    let g_scalar = bruteforce::knn(&ds, k);
    simd::set_tier(None);
    let g_auto = bruteforce::knn(&ds, k);
    assert_eq!(g_scalar.idx, g_auto.idx, "neighbour sets must not depend on the simd tier");
    assert_eq!(bits(&g_scalar.d2), bits(&g_auto.d2), "panel distances must be bit-identical");
    assert_eq!(g_auto.recall_against(&g_scalar), 1.0);
}

fn golden_embedding(engine: &str, tier: Option<Tier>) -> Vec<f32> {
    simd::set_tier(tier);
    let data = gpgpu_sne::data::by_name("gaussians", 400, 5).unwrap();
    let g = bruteforce::knn(&data, 15);
    let p = perplexity::joint_p(&g, 5.0);
    let prm = OptParams { iters: 150, exaggeration_iters: 50, seed: 11, ..Default::default() };
    embed::by_name(engine, None).unwrap().run(&p, &prm, None).unwrap()
}

#[test]
fn engines_match_forced_scalar_vs_auto_dispatch() {
    // The ISSUE 8 golden run: a BH session and a fieldfft session under
    // forced-scalar vs auto dispatch. The acceptance criterion is ≤1e-5
    // embedding divergence; the kernels are built bit-identical, so we
    // assert that too (strictly stronger, and what keeps checkpoint
    // replay tier-independent).
    let _guard = TIER_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    for engine in ["bh-0.5", "fieldfft"] {
        let ys = golden_embedding(engine, Some(Tier::Scalar));
        let ya = golden_embedding(engine, None);
        simd::set_tier(None);
        let max_dev =
            ys.iter().zip(&ya).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
        assert!(max_dev <= 1e-5, "{engine}: scalar vs auto diverged by {max_dev}");
        assert_eq!(bits(&ys), bits(&ya), "{engine}: tiers must be bit-identical");
    }
}
