//! End-to-end TCP serve-mode test: bind an ephemeral port, speak the
//! line protocol over a real socket, exercise submit/status/snapshot/
//! stop/wait/quit.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

use gpgpu_sne::coordinator::{protocol, EmbeddingService};
use gpgpu_sne::util::json::{self, Json};

fn start_server() -> std::net::SocketAddr {
    let svc = Arc::new(EmbeddingService::new(None, 2));
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let _ = protocol::serve(svc, "127.0.0.1:0", move |addr| {
            let _ = tx.send(addr);
        });
    });
    rx.recv_timeout(std::time::Duration::from_secs(10)).expect("server bind")
}

struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Self {
        let stream = TcpStream::connect(addr).expect("connect");
        stream.set_read_timeout(Some(std::time::Duration::from_secs(60))).unwrap();
        Self { reader: BufReader::new(stream.try_clone().unwrap()), writer: stream }
    }

    fn call(&mut self, req: &str) -> Json {
        self.writer.write_all(req.as_bytes()).unwrap();
        self.writer.write_all(b"\n").unwrap();
        let mut line = String::new();
        self.reader.read_line(&mut line).unwrap();
        json::parse(line.trim()).unwrap_or_else(|e| panic!("bad response '{line}': {e}"))
    }
}

#[test]
fn full_session_over_tcp() {
    let addr = start_server();
    let mut c = Client::connect(addr);

    let v = c.call(
        r#"{"cmd":"submit","dataset":"gaussians","n":150,"engine":"bh-0.5","iters":60,"perplexity":10,"knn":"brute","snapshot_every":10}"#,
    );
    assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{v}");
    let id = v.num_field("job").unwrap() as u64;

    let v = c.call(&format!(r#"{{"cmd":"wait","job":{id}}}"#));
    assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{v}");
    assert_eq!(v.num_field("iters").unwrap() as usize, 60);
    assert!(v.num_field("kl").unwrap().is_finite());
    assert!(v.num_field("optimize_s").unwrap() > 0.0);

    let v = c.call(&format!(r#"{{"cmd":"snapshot","job":{id}}}"#));
    assert_eq!(v.get("positions").unwrap().as_arr().unwrap().len(), 300);

    let v = c.call(r#"{"cmd":"list"}"#);
    assert_eq!(v.get("jobs").unwrap().as_arr().unwrap().len(), 1);

    let v = c.call(r#"{"cmd":"quit"}"#);
    assert_eq!(v.get("bye"), Some(&Json::Bool(true)));
}

#[test]
fn two_clients_share_the_service() {
    let addr = start_server();
    let mut a = Client::connect(addr);
    let mut b = Client::connect(addr);

    let v = a.call(
        r#"{"cmd":"submit","dataset":"gaussians","n":100,"engine":"bh-0.5","iters":30,"perplexity":8,"knn":"brute"}"#,
    );
    let id = v.num_field("job").unwrap() as u64;
    // Client B can see and wait on client A's job.
    let v = b.call(&format!(r#"{{"cmd":"wait","job":{id}}}"#));
    assert_eq!(v.get("ok"), Some(&Json::Bool(true)));
    let v = b.call(&format!(r#"{{"cmd":"status","job":{id}}}"#));
    assert_eq!(v.str_field("phase"), Some("done"));
}

#[test]
fn stop_over_tcp_terminates_early() {
    let addr = start_server();
    let mut c = Client::connect(addr);
    let v = c.call(
        r#"{"cmd":"submit","dataset":"gaussians","n":200,"engine":"bh-0.5","iters":100000,"perplexity":10,"knn":"brute","snapshot_every":1}"#,
    );
    let id = v.num_field("job").unwrap() as u64;
    // Poll until it's optimising, then stop.
    loop {
        let v = c.call(&format!(r#"{{"cmd":"status","job":{id}}}"#));
        if v.str_field("phase").unwrap_or("").starts_with("optimizing") {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(20));
    }
    let v = c.call(&format!(r#"{{"cmd":"stop","job":{id}}}"#));
    assert_eq!(v.get("ok"), Some(&Json::Bool(true)));
    let v = c.call(&format!(r#"{{"cmd":"wait","job":{id}}}"#));
    assert_eq!(v.get("stopped_early"), Some(&Json::Bool(true)));
}

#[test]
fn repeat_job_reports_similarity_cache_hit_over_tcp() {
    let addr = start_server();
    let mut c = Client::connect(addr);
    let submit = r#"{"cmd":"submit","dataset":"gaussians","n":120,"engine":"bh-0.5","iters":20,"perplexity":8,"knn":"brute"}"#;

    let id = c.call(submit).num_field("job").unwrap() as u64;
    let v = c.call(&format!(r#"{{"cmd":"wait","job":{id}}}"#));
    assert_eq!(v.get("sim_cache_hit"), Some(&Json::Bool(false)), "{v}");
    assert!(v.num_field("knn_s").unwrap() > 0.0);

    let id = c.call(submit).num_field("job").unwrap() as u64;
    let v = c.call(&format!(r#"{{"cmd":"wait","job":{id}}}"#));
    assert_eq!(v.get("sim_cache_hit"), Some(&Json::Bool(true)), "{v}");
    assert_eq!(v.num_field("perplexity_s").unwrap(), 0.0);

    let v = c.call(r#"{"cmd":"stats"}"#);
    assert_eq!(v.num_field("sim_cache_hits").unwrap() as u64, 1, "{v}");
    assert_eq!(v.num_field("sim_cache_misses").unwrap() as u64, 1);
}

#[test]
fn malformed_lines_keep_the_connection_alive() {
    let addr = start_server();
    let mut c = Client::connect(addr);
    let v = c.call("this is not json");
    assert_eq!(v.get("ok"), Some(&Json::Bool(false)));
    // Connection still usable.
    let v = c.call(r#"{"cmd":"list"}"#);
    assert_eq!(v.get("ok"), Some(&Json::Bool(true)));
}
