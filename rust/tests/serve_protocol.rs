//! End-to-end TCP serve-mode test: bind an ephemeral port, speak the
//! line protocol over a real socket, exercise submit/status/snapshot/
//! stop/wait/quit.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

use gpgpu_sne::coordinator::{protocol, EmbeddingService};
use gpgpu_sne::util::json::{self, Json};

fn start_server() -> std::net::SocketAddr {
    let svc = Arc::new(EmbeddingService::new(None, 2));
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let _ = protocol::serve(svc, "127.0.0.1:0", move |addr| {
            let _ = tx.send(addr);
        });
    });
    rx.recv_timeout(std::time::Duration::from_secs(10)).expect("server bind")
}

struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Self {
        let stream = TcpStream::connect(addr).expect("connect");
        stream.set_read_timeout(Some(std::time::Duration::from_secs(60))).unwrap();
        Self { reader: BufReader::new(stream.try_clone().unwrap()), writer: stream }
    }

    fn call(&mut self, req: &str) -> Json {
        self.writer.write_all(req.as_bytes()).unwrap();
        self.writer.write_all(b"\n").unwrap();
        let mut line = String::new();
        self.reader.read_line(&mut line).unwrap();
        json::parse(line.trim()).unwrap_or_else(|e| panic!("bad response '{line}': {e}"))
    }
}

#[test]
fn full_session_over_tcp() {
    let addr = start_server();
    let mut c = Client::connect(addr);

    let v = c.call(
        r#"{"cmd":"submit","dataset":"gaussians","n":150,"engine":"bh-0.5","iters":60,"perplexity":10,"knn":"brute","snapshot_every":10}"#,
    );
    assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{v}");
    let id = v.num_field("job").unwrap() as u64;

    let v = c.call(&format!(r#"{{"cmd":"wait","job":{id}}}"#));
    assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{v}");
    assert_eq!(v.num_field("iters").unwrap() as usize, 60);
    assert!(v.num_field("kl").unwrap().is_finite());
    assert!(v.num_field("optimize_s").unwrap() > 0.0);

    let v = c.call(&format!(r#"{{"cmd":"snapshot","job":{id}}}"#));
    assert_eq!(v.get("positions").unwrap().as_arr().unwrap().len(), 300);

    let v = c.call(r#"{"cmd":"list"}"#);
    assert_eq!(v.get("jobs").unwrap().as_arr().unwrap().len(), 1);

    let v = c.call(r#"{"cmd":"quit"}"#);
    assert_eq!(v.get("bye"), Some(&Json::Bool(true)));
}

#[test]
fn two_clients_share_the_service() {
    let addr = start_server();
    let mut a = Client::connect(addr);
    let mut b = Client::connect(addr);

    let v = a.call(
        r#"{"cmd":"submit","dataset":"gaussians","n":100,"engine":"bh-0.5","iters":30,"perplexity":8,"knn":"brute"}"#,
    );
    let id = v.num_field("job").unwrap() as u64;
    // Client B can see and wait on client A's job.
    let v = b.call(&format!(r#"{{"cmd":"wait","job":{id}}}"#));
    assert_eq!(v.get("ok"), Some(&Json::Bool(true)));
    let v = b.call(&format!(r#"{{"cmd":"status","job":{id}}}"#));
    assert_eq!(v.str_field("phase"), Some("done"));
}

#[test]
fn stop_over_tcp_terminates_early() {
    let addr = start_server();
    let mut c = Client::connect(addr);
    let v = c.call(
        r#"{"cmd":"submit","dataset":"gaussians","n":200,"engine":"bh-0.5","iters":100000,"perplexity":10,"knn":"brute","snapshot_every":1}"#,
    );
    let id = v.num_field("job").unwrap() as u64;
    // Poll until it's optimising, then stop.
    loop {
        let v = c.call(&format!(r#"{{"cmd":"status","job":{id}}}"#));
        if v.str_field("phase").unwrap_or("").starts_with("optimizing") {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(20));
    }
    let v = c.call(&format!(r#"{{"cmd":"stop","job":{id}}}"#));
    assert_eq!(v.get("ok"), Some(&Json::Bool(true)));
    let v = c.call(&format!(r#"{{"cmd":"wait","job":{id}}}"#));
    assert_eq!(v.get("stopped_early"), Some(&Json::Bool(true)));
}

#[test]
fn repeat_job_reports_similarity_cache_hit_over_tcp() {
    let addr = start_server();
    let mut c = Client::connect(addr);
    let submit = r#"{"cmd":"submit","dataset":"gaussians","n":120,"engine":"bh-0.5","iters":20,"perplexity":8,"knn":"brute"}"#;

    let id = c.call(submit).num_field("job").unwrap() as u64;
    let v = c.call(&format!(r#"{{"cmd":"wait","job":{id}}}"#));
    assert_eq!(v.get("sim_cache_hit"), Some(&Json::Bool(false)), "{v}");
    assert!(v.num_field("knn_s").unwrap() > 0.0);

    let id = c.call(submit).num_field("job").unwrap() as u64;
    let v = c.call(&format!(r#"{{"cmd":"wait","job":{id}}}"#));
    assert_eq!(v.get("sim_cache_hit"), Some(&Json::Bool(true)), "{v}");
    assert_eq!(v.num_field("perplexity_s").unwrap(), 0.0);

    let v = c.call(r#"{"cmd":"stats"}"#);
    assert_eq!(v.num_field("sim_cache_hits").unwrap() as u64, 1, "{v}");
    assert_eq!(v.num_field("sim_cache_misses").unwrap() as u64, 1);
}

#[test]
fn pause_resume_update_over_tcp() {
    let addr = start_server();
    let mut c = Client::connect(addr);
    let v = c.call(
        r#"{"cmd":"submit","dataset":"gaussians","n":200,"engine":"bh-0.5","iters":100000,"perplexity":10,"knn":"brute"}"#,
    );
    let id = v.num_field("job").unwrap() as u64;

    // Wait until the scheduler is stepping it.
    loop {
        let v = c.call(&format!(r#"{{"cmd":"status","job":{id}}}"#));
        if v.str_field("phase").unwrap_or("").starts_with("optimizing") {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    }

    // Pause parks the session at the next step boundary.
    let v = c.call(&format!(r#"{{"cmd":"pause","job":{id}}}"#));
    assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{v}");
    let paused_iter = loop {
        let v = c.call(&format!(r#"{{"cmd":"status","job":{id}}}"#));
        if v.str_field("phase").unwrap_or("").starts_with("paused") {
            break v.num_field("iter").unwrap_or(0.0) as usize;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    };
    // Parked means parked: the iteration counter stops moving.
    std::thread::sleep(std::time::Duration::from_millis(100));
    let v = c.call(&format!(r#"{{"cmd":"status","job":{id}}}"#));
    assert!(v.str_field("phase").unwrap_or("").starts_with("paused"), "{v}");
    assert_eq!(v.num_field("iter").unwrap_or(0.0) as usize, paused_iter, "{v}");
    // A paused job still serves its latest live snapshot.
    let v = c.call(&format!(r#"{{"cmd":"snapshot","job":{id}}}"#));
    assert_eq!(v.get("positions").unwrap().as_arr().unwrap().len(), 400, "{v}");

    // Re-parameterise mid-run (while parked), then resume: the session
    // picks up the new schedule and finishes at the reduced horizon.
    let cut = paused_iter + 5;
    let v = c.call(&format!(r#"{{"cmd":"update","job":{id},"iters":{cut},"eta":80}}"#));
    assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{v}");
    let v = c.call(&format!(r#"{{"cmd":"resume","job":{id}}}"#));
    assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{v}");
    let v = c.call(&format!(r#"{{"cmd":"wait","job":{id}}}"#));
    assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{v}");
    assert_eq!(v.get("stopped_early"), Some(&Json::Bool(false)), "{v}");
    let iters = v.num_field("iters").unwrap() as usize;
    assert!(iters <= cut && iters >= paused_iter, "ran {iters}, horizon {cut}: {v}");
}

#[test]
fn concurrent_identical_submits_coalesce_on_one_knn() {
    let addr = start_server();
    let mut c = Client::connect(addr);
    // Big enough that the two prepare stages realistically overlap on
    // the two workers; correctness does not depend on the overlap —
    // either way exactly one kNN+P computation may run.
    let submit = r#"{"cmd":"submit","dataset":"gaussians","n":1200,"engine":"bh-0.5","iters":10,"perplexity":12,"knn":"brute"}"#;
    let a = c.call(submit).num_field("job").unwrap() as u64;
    let b = c.call(submit).num_field("job").unwrap() as u64;
    let va = c.call(&format!(r#"{{"cmd":"wait","job":{a}}}"#));
    let vb = c.call(&format!(r#"{{"cmd":"wait","job":{b}}}"#));
    assert_eq!(va.get("ok"), Some(&Json::Bool(true)), "{va}");
    assert_eq!(vb.get("ok"), Some(&Json::Bool(true)), "{vb}");
    let hits = [&va, &vb]
        .iter()
        .filter(|v| v.get("sim_cache_hit") == Some(&Json::Bool(true)))
        .count();
    assert_eq!(hits, 1, "one leader, one coalesced/ready hit: {va} {vb}");

    let v = c.call(r#"{"cmd":"stats"}"#);
    assert_eq!(v.num_field("sim_cache_computes").unwrap() as u64, 1, "{v}");
    assert_eq!(v.num_field("sim_cache_hits").unwrap() as u64, 1, "{v}");
    assert_eq!(v.num_field("sim_cache_misses").unwrap() as u64, 1, "{v}");
}

#[test]
fn malformed_lines_keep_the_connection_alive() {
    let addr = start_server();
    let mut c = Client::connect(addr);
    let v = c.call("this is not json");
    assert_eq!(v.get("ok"), Some(&Json::Bool(false)));
    // Connection still usable.
    let v = c.call(r#"{"cmd":"list"}"#);
    assert_eq!(v.get("ok"), Some(&Json::Bool(true)));
}

#[test]
fn checkpoint_and_resume_over_tcp() {
    let addr = start_server();
    let mut c = Client::connect(addr);
    let v = c.call(
        r#"{"cmd":"submit","dataset":"gaussians","n":300,"engine":"bh-0.5","iters":100000,"perplexity":10,"knn":"brute"}"#,
    );
    let id = v.num_field("job").unwrap() as u64;
    // Wait until the scheduler is stepping it, then snapshot its state.
    loop {
        let v = c.call(&format!(r#"{{"cmd":"status","job":{id}}}"#));
        if v.str_field("phase").unwrap_or("").starts_with("optimizing") {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    let v = c.call(&format!(r#"{{"cmd":"checkpoint","job":{id}}}"#));
    assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{v}");
    assert_eq!(v.str_field("engine"), Some("bh-0.5"), "{v}");
    let iter = v.num_field("iter").unwrap() as usize;
    assert!(iter > 0, "{v}");
    let blob = v.str_field("checkpoint").unwrap().to_string();
    assert!(!blob.is_empty());
    c.call(&format!(r#"{{"cmd":"stop","job":{id}}}"#));
    c.call(&format!(r#"{{"cmd":"wait","job":{id}}}"#));

    // Resume the blob in a fresh job with a slightly longer horizon:
    // it continues from `iter` instead of restarting.
    let horizon = iter + 7;
    let v = c.call(&format!(
        r#"{{"cmd":"submit","dataset":"gaussians","n":300,"engine":"bh-0.5","iters":{horizon},"perplexity":10,"knn":"brute","resume_from":"{blob}"}}"#
    ));
    assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{v}");
    let rid = v.num_field("job").unwrap() as u64;
    let v = c.call(&format!(r#"{{"cmd":"wait","job":{rid}}}"#));
    assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{v}");
    assert_eq!(v.num_field("iters").unwrap() as usize, horizon, "{v}");
    assert_eq!(v.get("stopped_early"), Some(&Json::Bool(false)), "{v}");
    // The repeat submit also hit the similarity store.
    assert_eq!(v.get("sim_cache_hit"), Some(&Json::Bool(true)), "{v}");

    // A garbage blob is rejected at submit time.
    let v = c.call(
        r#"{"cmd":"submit","dataset":"gaussians","n":300,"engine":"bh-0.5","iters":10,"perplexity":10,"knn":"brute","resume_from":"AAAA"}"#,
    );
    assert_eq!(v.get("ok"), Some(&Json::Bool(false)), "{v}");
}

#[test]
fn stats_reports_both_store_levels() {
    let addr = start_server();
    let mut c = Client::connect(addr);
    let submit = r#"{"cmd":"submit","dataset":"gaussians","n":100,"engine":"bh-0.5","iters":10,"perplexity":8,"knn":"brute"}"#;
    let id = c.call(submit).num_field("job").unwrap() as u64;
    c.call(&format!(r#"{{"cmd":"wait","job":{id}}}"#));
    let v = c.call(r#"{"cmd":"stats"}"#);
    assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{v}");
    for field in [
        "sim_cache_hits",
        "sim_cache_misses",
        "sim_cache_computes",
        "sim_cache_entries",
        "sim_cache_disk_hits",
        "knn_cache_hits",
        "knn_cache_computes",
        "knn_cache_entries",
        "knn_cache_disk_hits",
    ] {
        assert!(v.num_field(field).is_some(), "stats lost `{field}`: {v}");
    }
    assert_eq!(v.num_field("knn_cache_computes").unwrap() as u64, 1, "{v}");
    assert_eq!(v.num_field("sim_cache_entries").unwrap() as u64, 1, "{v}");
}
