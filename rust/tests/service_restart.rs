//! Durable-coordinator integration: kill a service mid-job and restart
//! it over the same `--state-dir`.
//!
//! Pins the PR's acceptance criteria end to end:
//! * an interrupted job is re-admitted from its journalled checkpoint
//!   and finishes **bit-identically** to an uninterrupted run;
//! * a restarted service serves a repeat submit from the on-disk
//!   similarity store (`sim_cache_hit=true`, zero recomputed kNN
//!   graphs);
//! * corrupt store entries degrade to graceful recomputation.

use std::path::PathBuf;

use gpgpu_sne::coordinator::progress::JobState;
use gpgpu_sne::coordinator::{
    run_pipeline, EmbeddingService, JobPhase, JobSpec, KnnMethod, ServiceConfig, SubmitError,
};
use gpgpu_sne::embed::OptParams;

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("gsne-restart-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn spec(iters: usize) -> JobSpec {
    JobSpec {
        // Big enough that a several-hundred-iteration job comfortably
        // outlives the first journal write + the pause round-trip.
        dataset: "gaussians".into(),
        n: 1000,
        engine: "bh-0.5".into(),
        perplexity: 10.0,
        knn: KnnMethod::Brute,
        params: OptParams { iters, exaggeration_iters: 30, ..Default::default() },
        snapshot_every: 10,
        auto_stop: None,
        priority: Default::default(),
        seed: 11,
        y0: None,
        resume_from: None,
    }
}

fn durable(dir: &PathBuf, journal_every: usize) -> EmbeddingService {
    EmbeddingService::with_config(
        None,
        ServiceConfig {
            max_concurrent: 1,
            state_dir: Some(dir.clone()),
            journal_every,
            ..Default::default()
        },
    )
}

fn wait_until(what: &str, mut cond: impl FnMut() -> bool) {
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(60);
    while !cond() {
        assert!(std::time::Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
}

#[test]
fn killed_service_resumes_job_bit_identically() {
    let dir = tmp_dir("resume");
    const ITERS: usize = 800;

    // Reference: the same job, uninterrupted (pipeline == service step
    // path, pinned by the session-conformance suite).
    let reference = run_pipeline(&spec(ITERS), None, &JobState::default()).unwrap();
    assert_eq!(reference.iters_run, ITERS);

    // Durable service: run past the journal interval, park, "kill".
    let (id, paused_iter) = {
        let svc = durable(&dir, 10);
        let id = svc.submit(spec(ITERS));
        // Admission journals immediately (spec-only record) ...
        let journal_path = dir.join("jobs").join(format!("job-{id}.job"));
        wait_until("admit-time journal write", || journal_path.exists());
        // ... and stepping past the journal interval upgrades it to a
        // checkpoint-carrying record; make sure we interrupt *after*
        // that so the restart resumes mid-run rather than from scratch.
        wait_until("progress past the journal interval", || {
            svc.latest_snapshot(id).map(|s| s.iter >= 10).unwrap_or(false)
        });
        assert!(svc.pause(id));
        wait_until("park", || matches!(svc.phase(id), Some(JobPhase::Paused { .. })));
        let Some(JobPhase::Paused { iter, .. }) = svc.phase(id) else {
            unreachable!()
        };
        assert!(iter < ITERS, "job must be interrupted mid-run, not finished");
        (id, iter)
        // svc dropped here: the "kill". The journal entry survives.
    };

    // Restart over the same state dir: the job is re-admitted under the
    // same id and runs to completion from its checkpoint.
    let svc = durable(&dir, 10);
    let phase = svc.phase(id).expect("interrupted job re-admitted");
    assert!(!phase.is_terminal(), "re-admitted as runnable: {phase:?}");
    let res = svc.wait(id).expect("resumed job completes");
    assert_eq!(res.iters_run, ITERS, "resumed from iter {paused_iter}, ran to the horizon");
    assert!(!res.stopped_early);
    assert_eq!(
        res.embedding, reference.embedding,
        "final positions must be bit-identical to the uninterrupted run"
    );
    // Terminal jobs drain their journal entries: a second restart must
    // not re-run anything.
    let svc2 = durable(&dir, 10);
    assert!(svc2.phase(id).is_none(), "journal drained after completion");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn drained_service_restarts_bit_identically() {
    let dir = tmp_dir("drain");
    const ITERS: usize = 600;
    let reference = run_pipeline(&spec(ITERS), None, &JobState::default()).unwrap();

    // Journal cadence far beyond the horizon: the only checkpoint the
    // journal can carry is the one the drain itself writes at park.
    let (id, parked) = {
        let svc = durable(&dir, 1_000_000);
        let id = svc.submit(spec(ITERS));
        wait_until("job starts stepping", || {
            svc.latest_snapshot(id).map(|s| s.iter >= 5).unwrap_or(false)
        });
        let parked = svc.drain(std::time::Duration::from_secs(60));
        assert_eq!(parked, 1, "the one live job is parked, not dropped");
        // Draining is sticky: admission is shut for good.
        assert!(matches!(svc.try_submit(spec(10)), Err(SubmitError::Draining)));
        let Some(JobPhase::Paused { iter, .. }) = svc.phase(id) else {
            panic!("drained job must be parked mid-run, got {:?}", svc.phase(id))
        };
        assert!(0 < iter && iter < ITERS, "parked mid-run at iter {iter}");
        (id, parked)
        // svc dropped: the graceful half of a drain+exit.
    };
    assert_eq!(parked, 1);

    // Restart over the same state dir: the drain-parked checkpoint is
    // the resume point, and the result matches an uninterrupted run.
    let svc = durable(&dir, 1_000_000);
    let res = svc.wait(id).expect("drained job resumes after restart");
    assert_eq!(res.iters_run, ITERS);
    assert_eq!(
        res.embedding, reference.embedding,
        "drain shutdown + restart must be bit-identical to an uninterrupted run"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn restarted_service_serves_similarities_from_disk() {
    let dir = tmp_dir("simstore");
    let first = {
        let svc = durable(&dir, 50);
        let id = svc.submit(spec(30));
        let res = svc.wait(id).unwrap();
        assert!(!res.timings.sim_cache_hit, "first run computes");
        res
    };

    // Restart: same submit is served from the on-disk store — no kNN,
    // no P build.
    let svc = durable(&dir, 50);
    let id = svc.submit(spec(30));
    let res = svc.wait(id).unwrap();
    assert!(res.timings.sim_cache_hit, "restart must hit the on-disk similarity store");
    assert_eq!(res.timings.perplexity_s, 0.0);
    assert_eq!(svc.sim_cache().computes(), 0, "zero P builds after restart");
    assert_eq!(svc.sim_cache().graph_stats().computes, 0, "zero recomputed kNN graphs");
    assert_eq!(svc.sim_cache().p_stats().disk_hits, 1);
    assert_eq!(
        res.embedding, first.embedding,
        "store-served similarities reproduce the original embedding bit-for-bit"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_store_entries_fall_back_to_recomputation() {
    let dir = tmp_dir("corrupt");
    let first = {
        let svc = durable(&dir, 50);
        let id = svc.submit(spec(25));
        svc.wait(id).unwrap()
    };
    // Scribble over every record in the similarity store.
    let simstore = dir.join("simstore");
    let mut clobbered = 0;
    for entry in std::fs::read_dir(&simstore).unwrap().flatten() {
        std::fs::write(entry.path(), b"flipped bits everywhere").unwrap();
        clobbered += 1;
    }
    assert!(clobbered >= 2, "graph + P records were persisted");

    let svc = durable(&dir, 50);
    let id = svc.submit(spec(25));
    let res = svc.wait(id).expect("corruption must degrade to recomputation, not failure");
    assert!(!res.timings.sim_cache_hit, "corrupt records are misses");
    assert_eq!(svc.sim_cache().graph_stats().computes, 1, "kNN recomputed once");
    assert_eq!(svc.sim_cache().p_stats().disk_hits, 0);
    assert_eq!(res.embedding, first.embedding, "recomputation reproduces the result");
    let _ = std::fs::remove_dir_all(&dir);
}
