//! Golden tests for the FFT field backend and the `fieldfft` engine:
//! textures and per-point repulsive forces against the exact gather
//! oracle (within 1% relative error on random and clustered layouts),
//! and end-to-end optimisation behaviour mirroring the fieldcpu checks.

use gpgpu_sne::coordinator::pipeline::compute_knn;
use gpgpu_sne::coordinator::KnnMethod;
use gpgpu_sne::data;
use gpgpu_sne::embed::common::Repulsion;
use gpgpu_sne::embed::fieldcpu::FieldRepulsion;
use gpgpu_sne::embed::{self, Control, IterStats, OptParams};
use gpgpu_sne::field::conv::FftBackend;
use gpgpu_sne::field::gather::GatherBackend;
use gpgpu_sne::field::{bbox_of, place, FieldBackend};
use gpgpu_sne::hd::perplexity;
use gpgpu_sne::util::rng::Rng;

fn random_layout(n: usize, seed: u64, spread: f32) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..2 * n).map(|_| rng.gauss_f32(0.0, spread)).collect()
}

/// k Gaussian blobs — the post-convergence shape fields actually see.
fn clustered_layout(n: usize, seed: u64, k: usize, spread: f32, std: f32) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let centers: Vec<(f32, f32)> =
        (0..k).map(|_| (rng.gauss_f32(0.0, spread), rng.gauss_f32(0.0, spread))).collect();
    let mut y = Vec::with_capacity(2 * n);
    for i in 0..n {
        let (cx, cy) = centers[i % k];
        y.push(cx + rng.gauss_f32(0.0, std));
        y.push(cy + rng.gauss_f32(0.0, std));
    }
    y
}

/// max |a−b| / max |a| over a slice pair.
fn max_rel_err(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    let scale = a.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-9);
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max) / scale
}

fn assert_textures_match(y: &[f32], grid: usize, label: &str) {
    let p = place(bbox_of(y), grid);
    let oracle = GatherBackend.compute(y, p, grid);
    let t = FftBackend::new().compute(y, p, grid);
    assert_eq!(t.grid, grid);
    assert_eq!(t.origin, oracle.origin);
    let plane = grid * grid;
    for (ch, name) in ["S", "Vx", "Vy"].iter().enumerate() {
        let err = max_rel_err(
            &oracle.tex[ch * plane..(ch + 1) * plane],
            &t.tex[ch * plane..(ch + 1) * plane],
        );
        assert!(err < 0.01, "{label}: channel {name} rel err {err} (G={grid})");
    }
}

#[test]
fn golden_texture_random_layouts() {
    for (grid, seed) in [(64usize, 2u64), (128, 3)] {
        assert_textures_match(&random_layout(400, seed, 5.0), grid, "random");
    }
}

#[test]
fn golden_texture_clustered_layouts() {
    assert_textures_match(&clustered_layout(600, 4, 8, 12.0, 0.8), 128, "clustered");
    assert_textures_match(&clustered_layout(800, 5, 5, 20.0, 0.5), 256, "clustered-tight");
}

fn assert_forces_match(y: &[f32], grid: usize, label: &str) {
    let n = y.len() / 2;
    let mut rep_gather = FieldRepulsion { min_grid: grid, max_grid: grid, ..Default::default() };
    let mut rep_fft = FieldRepulsion {
        min_grid: grid,
        max_grid: grid,
        ..FieldRepulsion::with_backend(Box::new(FftBackend::new()))
    };
    let mut num_gather = vec![0.0f32; 2 * n];
    let mut num_fft = vec![0.0f32; 2 * n];
    let z_gather = rep_gather.compute(y, &mut num_gather);
    let z_fft = rep_fft.compute(y, &mut num_fft);
    let ferr = max_rel_err(&num_gather, &num_fft);
    assert!(ferr < 0.01, "{label}: per-point force rel err {ferr} (G={grid})");
    let zerr = (z_gather - z_fft).abs() / z_gather.abs().max(1e-9);
    assert!(zerr < 0.01, "{label}: Ẑ rel err {zerr} ({z_gather} vs {z_fft})");
}

#[test]
fn golden_forces_random_layout() {
    assert_forces_match(&random_layout(500, 7, 5.0), 128, "random");
}

#[test]
fn golden_forces_clustered_layout() {
    assert_forces_match(&clustered_layout(600, 8, 8, 12.0, 0.8), 128, "clustered");
}

#[test]
fn fieldfft_reduces_kl_on_gaussians() {
    // Mirrors integration.rs::all_cpu_engines_reduce_kl_on_gaussians for
    // the new engine specifically.
    let ds = data::by_name("gaussians", 200, 1).unwrap();
    let knn = compute_knn(&ds, KnnMethod::Brute, 30, 1);
    let p = perplexity::joint_p(&knn, 10.0);
    let params = OptParams { iters: 120, exaggeration_iters: 30, seed: 11, ..Default::default() };
    let mut first = f64::NAN;
    let mut last = f64::NAN;
    let mut obs = |s: &IterStats, _: &[f32]| {
        if s.iter == 0 {
            first = s.kl_est;
        }
        last = s.kl_est;
        Control::Continue
    };
    let mut engine = embed::by_name("fieldfft", None).unwrap();
    let y = engine.run(&p, &params, Some(&mut obs)).unwrap();
    assert!(last < 0.7 * first, "fieldfft: KL should drop substantially ({first:.3} -> {last:.3})");
    assert!(y.iter().all(|v| v.is_finite()), "fieldfft: non-finite output");
}

#[test]
fn fieldfft_matches_fieldcpu_quality() {
    // Same maths, different evaluation: final objective values of the two
    // field engines must track each other closely.
    let ds = data::by_name("gaussians", 250, 2).unwrap();
    let knn = compute_knn(&ds, KnnMethod::Brute, 30, 2);
    let p = perplexity::joint_p(&knn, 10.0);
    let params = OptParams { iters: 250, exaggeration_iters: 60, seed: 11, ..Default::default() };
    let run = |name: &str| {
        let y = embed::by_name(name, None).unwrap().run(&p, &params, None).unwrap();
        gpgpu_sne::metrics::kl::kl_divergence_exact(&p, &y)
    };
    let kl_cpu = run("fieldcpu");
    let kl_fft = run("fieldfft");
    // Same tolerance the device-vs-mirror test uses: trajectories may
    // diverge point-wise, the objective value must not.
    assert!(
        (kl_fft - kl_cpu).abs() < 0.15 * kl_cpu.abs().max(0.1),
        "fieldfft {kl_fft:.4} should track fieldcpu {kl_cpu:.4}"
    );
}
