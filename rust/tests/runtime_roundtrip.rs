//! End-to-end numeric round-trip: the Rust runtime executes the AOT
//! artifact on the exact problem `python/compile/aot.py selfcheck_case`
//! solved at build time, and the outputs must match the JAX results.
//!
//! Skips (loudly) when `make artifacts` has not been run.

use gpgpu_sne::runtime::{self, Runtime, StepState};
use gpgpu_sne::util::json;

fn f32s(v: &json::Json, key: &str) -> Vec<f32> {
    v.get(key)
        .and_then(json::Json::as_arr)
        .unwrap_or_else(|| panic!("selfcheck missing '{key}'"))
        .iter()
        .map(|x| x.as_f64().unwrap() as f32)
        .collect()
}

#[test]
fn step_matches_jax_selfcheck() {
    let Some(dir) = runtime::locate_artifacts() else {
        eprintln!("SKIP: no artifacts/ — run `make artifacts` first");
        return;
    };
    let check_path = std::path::Path::new(&dir).join("selfcheck.json");
    let text = std::fs::read_to_string(&check_path).expect("selfcheck.json");
    let v = json::parse(&text).unwrap();

    let n = v.num_field("n").unwrap() as usize;
    let k = v.num_field("k").unwrap() as usize;
    let grid = v.num_field("grid").unwrap() as usize;
    let n_real = v.num_field("n_real").unwrap() as usize;
    let kk = v.num_field("kk").unwrap() as usize;
    let eta = v.num_field("eta").unwrap() as f32;
    let momentum = v.num_field("momentum").unwrap() as f32;
    let exaggeration = v.num_field("exaggeration").unwrap() as f32;
    let y_init = f32s(&v, "y_init");
    assert_eq!(y_init.len(), 2 * n_real);

    // Reconstruct the selfcheck inputs exactly as aot.selfcheck_case does.
    let mut y = vec![0.0f32; 2 * n];
    y[..2 * n_real].copy_from_slice(&y_init);
    let mut mask = vec![0.0f32; n];
    mask[..n_real].fill(1.0);
    let mut nbr_idx = vec![0i32; n * k];
    let mut nbr_p = vec![0.0f32; n * k];
    for i in 0..n_real {
        for j in 0..kk {
            nbr_idx[i * k + j] = ((i + j + 1) % n_real) as i32;
            nbr_p[i * k + j] = 1.0 / (n_real * kk) as f32;
        }
    }

    let rt = Runtime::new(&dir).expect("runtime");
    let exe = rt.step_executable(n, grid).expect("step executable");
    let statics = rt.upload_static(&mask, &nbr_idx, &nbr_p, k).expect("upload");
    let mut state = StepState::new(y, &mask);
    let out = rt
        .run_step(&exe, &mut state, &statics, eta, momentum, exaggeration)
        .expect("run_step");

    let zhat_exp = v.num_field("zhat").unwrap() as f32;
    let kl_exp = v.num_field("kl").unwrap() as f32;
    let bbox_exp = f32s(&v, "bbox");
    let y_exp = f32s(&v, "y_out");
    let vel_exp = f32s(&v, "vel_out");
    let gains_exp = f32s(&v, "gains_out");

    let rel = |a: f32, b: f32| (a - b).abs() / b.abs().max(1e-3);
    assert!(rel(out.zhat, zhat_exp) < 1e-4, "zhat {} vs {}", out.zhat, zhat_exp);
    assert!(rel(out.kl, kl_exp) < 1e-4, "kl {} vs {}", out.kl, kl_exp);
    for i in 0..4 {
        assert!(
            (out.bbox[i] - bbox_exp[i]).abs() < 1e-2 * bbox_exp[i].abs().max(1.0),
            "bbox[{i}] {} vs {}",
            out.bbox[i],
            bbox_exp[i]
        );
    }
    let max_err = |a: &[f32], b: &[f32]| -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
    };
    let scale = y_exp.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1.0);
    assert!(
        max_err(&state.y[..2 * n_real], &y_exp) < 1e-3 * scale,
        "y mismatch: {}",
        max_err(&state.y[..2 * n_real], &y_exp)
    );
    assert!(max_err(&state.vel[..2 * n_real], &vel_exp) < 1e-3 * scale);
    assert!(max_err(&state.gains[..2 * n_real], &gains_exp) < 1e-5);

    // Padding must be inert: phantom rows stay exactly zero.
    assert!(state.y[2 * n_real..].iter().all(|&v| v == 0.0), "padding moved");
    assert!(state.vel[2 * n_real..].iter().all(|&v| v == 0.0));
}

#[test]
fn executable_cache_hits() {
    let Some(dir) = runtime::locate_artifacts() else {
        eprintln!("SKIP: no artifacts/ — run `make artifacts` first");
        return;
    };
    let rt = Runtime::new(&dir).unwrap();
    let name = rt.manifest.artifacts[0].name.clone();
    let a = rt.executable(&name).unwrap();
    let b = rt.executable(&name).unwrap();
    assert!(std::sync::Arc::ptr_eq(&a, &b), "second lookup must hit the cache");
    assert_eq!(rt.compiled_count(), 1);
}
