//! Property-based tests over the coordinator/substrate invariants
//! (DESIGN.md §11), driven by the in-repo prop framework (util::prop).

use gpgpu_sne::embed::exact::ExactRepulsion;
use gpgpu_sne::embed::quadtree::QuadTree;
use gpgpu_sne::embed::common::Repulsion;
use gpgpu_sne::embed::fieldcpu;
use gpgpu_sne::embed::gpgpu::GridPolicy;
use gpgpu_sne::hd::{bruteforce, dataset::Dataset, kdforest, knn::KBest, perplexity, vptree};
use gpgpu_sne::util::prop::{self, points2d, usize_in, vec_f32};
use gpgpu_sne::util::rng::Rng;

fn dataset_from(seed: u64, n: usize, d: usize) -> Dataset {
    let mut rng = Rng::new(seed);
    let x: Vec<f32> = (0..n * d).map(|_| rng.gauss_f32(0.0, 1.0)).collect();
    Dataset::new("p", n, d, x, vec![])
}

#[test]
fn prop_quadtree_conserves_mass_and_com() {
    prop::check("quadtree mass/COM", &points2d(2, 300, 10.0), |pts| {
        let n = pts.len() / 2;
        let t = QuadTree::build(pts);
        if t.total_count() as usize != n {
            return Err(format!("mass {} != {}", t.total_count(), n));
        }
        let (mx, my) = t.root_com();
        let (mut ex, mut ey) = (0.0f64, 0.0f64);
        for i in 0..n {
            ex += pts[2 * i] as f64;
            ey += pts[2 * i + 1] as f64;
        }
        ex /= n as f64;
        ey /= n as f64;
        if (mx - ex).abs() > 1e-3 || (my - ey).abs() > 1e-3 {
            return Err(format!("COM ({mx},{my}) != ({ex},{ey})"));
        }
        Ok(())
    });
}

#[test]
fn prop_bh_theta0_equals_exact() {
    prop::check("BH θ=0 exactness", &points2d(2, 120, 5.0), |pts| {
        let n = pts.len() / 2;
        let tree = QuadTree::build(pts);
        for i in (0..n).step_by(1 + n / 7) {
            let (fx, fy, z) = tree.accumulate(pts[2 * i], pts[2 * i + 1], 0.0);
            let (mut efx, mut efy, mut ez) = (0.0f64, 0.0f64, 0.0f64);
            for j in 0..n {
                let dx = (pts[2 * i] - pts[2 * j]) as f64;
                let dy = (pts[2 * i + 1] - pts[2 * j + 1]) as f64;
                let t = 1.0 / (1.0 + dx * dx + dy * dy);
                ez += t;
                efx += t * t * dx;
                efy += t * t * dy;
            }
            if (z - ez).abs() > 1e-6 * ez.max(1.0) {
                return Err(format!("z {z} != {ez}"));
            }
            // Summation-order differences (tree traversal vs linear scan)
            // leave ~1e-8 absolute noise; tolerate 1e-5 relative.
            if (fx - efx).abs() > 1e-5 * efx.abs().max(1e-2)
                || (fy - efy).abs() > 1e-5 * efy.abs().max(1e-2)
            {
                return Err(format!("force ({fx},{fy}) != ({efx},{efy})"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_vptree_equals_bruteforce() {
    prop::check("vptree == brute", &usize_in(10, 200), |&n| {
        let data = dataset_from(n as u64 * 31 + 7, n, 6);
        let k = 5.min(n - 1);
        let a = vptree::VpTree::build(&data, 3).knn(k);
        let e = bruteforce::knn(&data, k);
        let recall = a.recall_against(&e);
        if recall < 0.999 {
            return Err(format!("recall {recall} at n={n}"));
        }
        Ok(())
    });
}

#[test]
fn prop_kdforest_recall_bound() {
    prop::check("kdforest recall ≥ 0.8", &usize_in(50, 400), |&n| {
        let data = dataset_from(n as u64 * 13 + 1, n, 12);
        let k = 8.min(n - 1);
        let f = kdforest::KdForest::build(&data, kdforest::ForestParams::default(), 2);
        let recall = f.knn(k).recall_against(&bruteforce::knn(&data, k));
        if recall < 0.8 {
            return Err(format!("recall {recall} at n={n}"));
        }
        Ok(())
    });
}

#[test]
fn prop_perplexity_row_invariants() {
    // Rows normalise to 1, probabilities non-increasing in distance, and
    // the realised perplexity hits the target.
    prop::check("perplexity calibration", &vec_f32(8, 64, 0.01, 25.0), |d2s| {
        let mut d2s = d2s.clone();
        d2s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mu = (d2s.len() as f64 / 3.0).max(2.0);
        let (_beta, probs) = perplexity::calibrate_row(&d2s, mu);
        let sum: f64 = probs.iter().map(|&p| p as f64).sum();
        if (sum - 1.0).abs() > 1e-4 {
            return Err(format!("sum {sum}"));
        }
        for w in probs.windows(2) {
            if w[0] < w[1] - 1e-6 {
                return Err("probs not non-increasing".into());
            }
        }
        let h: f64 = probs
            .iter()
            .filter(|&&p| p > 0.0)
            .map(|&p| -(p as f64) * (p as f64).ln())
            .sum();
        let perp = h.exp();
        if (perp - mu).abs() > 0.05 * mu {
            return Err(format!("perplexity {perp} != {mu}"));
        }
        Ok(())
    });
}

#[test]
fn prop_blocked_knn_equals_scalar_reference() {
    // The blocked ‖x‖²+‖y‖²−2x·y panel kernel must recover exactly the
    // same neighbour sets as the seed's per-pair scalar scan, across
    // dimensions that exercise every unroll remainder path.
    prop::check("blocked == scalar kNN", &usize_in(20, 250), |&n| {
        let d = 3 + (n % 19); // 3..21, rarely a multiple of 4
        let data = dataset_from(n as u64 * 7 + 3, n, d);
        let k = 8.min(n - 1);
        let blocked = bruteforce::knn(&data, k);
        let scalar = bruteforce::knn_scalar_reference(&data, k);
        // The strong, tie-insensitive statement: identical sorted
        // neighbour *distances* (the two paths differ only by f32
        // rounding, so a near-tie can swap neighbour identity without
        // being wrong — same convention as the vptree exactness test).
        for i in 0..n {
            for j in 0..k {
                let (a, b) = (blocked.row_d2(i)[j], scalar.row_d2(i)[j]);
                if (a - b).abs() > 1e-4 * b.max(1.0) {
                    return Err(format!("d2[{i}][{j}]: {a} vs {b}"));
                }
            }
        }
        let recall = blocked.recall_against(&scalar);
        if recall < 0.999 {
            return Err(format!("recall {recall} at n={n}, d={d}"));
        }
        Ok(())
    });
}

#[test]
fn prop_fused_joint_p_matches_reference() {
    // The fused one-pass P build must reproduce the seed's
    // calibrate→transpose→merge→normalise path: identical sparsity
    // structure, values within 1e-6, plus the joint-P invariants
    // (symmetry, Σ = 1, non-negativity).
    prop::check("fused P == reference P", &usize_in(20, 150), |&n| {
        let data = dataset_from(n as u64 * 5 + 11, n, 6);
        let k = 12.min(n - 1);
        let g = bruteforce::knn(&data, k);
        let mu = (k as f32 / 3.0).max(2.0);
        let fused = perplexity::joint_p(&g, mu);
        let reference = perplexity::joint_p_reference(&g, mu);
        if fused.csr.row_ptr != reference.csr.row_ptr {
            return Err("row_ptr mismatch".into());
        }
        if fused.csr.col != reference.csr.col {
            return Err("column structure mismatch".into());
        }
        for (i, (a, b)) in fused.csr.val.iter().zip(&reference.csr.val).enumerate() {
            if (a - b).abs() > 1e-6 {
                return Err(format!("val[{i}]: fused {a} vs reference {b}"));
            }
            if *a < 0.0 {
                return Err(format!("val[{i}] negative: {a}"));
            }
        }
        let total = fused.csr.sum();
        if (total - 1.0).abs() > 1e-4 {
            return Err(format!("ΣP = {total}"));
        }
        Ok(())
    });
}

#[test]
fn prop_joint_p_symmetric_normalised() {
    prop::check("joint P invariants", &usize_in(20, 150), |&n| {
        let data = dataset_from(n as u64 + 1000, n, 5);
        let k = 10.min(n - 1);
        let g = bruteforce::knn(&data, k);
        let p = perplexity::joint_p(&g, (k as f32 / 3.0).max(2.0));
        let total = p.csr.sum();
        if (total - 1.0).abs() > 1e-4 {
            return Err(format!("ΣP = {total}"));
        }
        // Symmetry spot checks.
        let get = |i: usize, j: usize| -> f32 {
            let (cs, vs) = p.csr.row(i);
            cs.iter().zip(vs).find(|(c, _)| **c == j as u32).map(|(_, v)| *v).unwrap_or(0.0)
        };
        for i in (0..n).step_by(1 + n / 5) {
            let (cs, _) = p.csr.row(i);
            for &j in cs.iter().take(3) {
                let a = get(i, j as usize);
                let b = get(j as usize, i);
                if (a - b).abs() > 1e-7 {
                    return Err(format!("P[{i}][{j}]={a} != P[{j}][{i}]={b}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_field_repulsion_tracks_exact() {
    // At high resolution the field numerator must approximate the exact
    // repulsion numerator to a few percent of its magnitude scale.
    prop::check("field ≈ exact repulsion", &points2d(5, 80, 3.0), |pts| {
        let n = pts.len() / 2;
        let mut exact = vec![0.0f32; 2 * n];
        let z_exact = ExactRepulsion.compute(pts, &mut exact);
        let mut rep = fieldcpu::FieldRepulsion { min_grid: 256, max_grid: 256, ..Default::default() };
        let mut num = vec![0.0f32; 2 * n];
        let z = rep.compute(pts, &mut num);
        if (z - z_exact).abs() > 0.05 * z_exact.max(1.0) {
            return Err(format!("Z {z} vs {z_exact}"));
        }
        let scale = exact.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-3);
        for i in 0..2 * n {
            if (num[i] - exact[i]).abs() > 0.08 * scale {
                return Err(format!("num[{i}] {} vs {} (scale {scale})", num[i], exact[i]));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_rfft2d_roundtrip_and_half_spectrum() {
    // r2c/c2r forward-inverse is the identity on random real planes of
    // every power-of-two size, and the half-spectrum agrees with the
    // full complex transform bin-for-bin.
    use gpgpu_sne::field::fft::{fft2d, half_width, irfft2d, rfft2d, Fft};
    prop::check("r2c/c2r roundtrip", &usize_in(1, 6), |&e| {
        let m = 1usize << e; // 2..64
        let hw = half_width(m);
        let plan = Fft::new(m);
        let mut rng = Rng::new(0xF0 + m as u64);
        let x: Vec<f32> = (0..m * m).map(|_| rng.gauss_f32(0.0, 2.0)).collect();
        let mut plane = x.clone();
        let mut sre = vec![0.0f32; hw * m];
        let mut sim = vec![0.0f32; hw * m];
        let mut tre = vec![0.0f32; m * hw];
        let mut tim = vec![0.0f32; m * hw];
        rfft2d(&plan, &mut plane, &mut sre, &mut sim, &mut tre, &mut tim);
        // Half-spectrum vs full-complex golden equivalence.
        let mut fre = x.clone();
        let mut fim = vec![0.0f32; m * m];
        fft2d(&plan, &mut fre, &mut fim, false);
        let scale = fre.iter().chain(fim.iter()).fold(1.0f32, |a, v| a.max(v.abs()));
        for k in 0..hw {
            for j in 0..m {
                let dr = (sre[k * m + j] - fre[j * m + k]).abs();
                let di = (sim[k * m + j] - fim[j * m + k]).abs();
                if dr > 2e-4 * scale || di > 2e-4 * scale {
                    return Err(format!("m={m} bin({j},{k}) off by ({dr},{di})"));
                }
            }
        }
        // Roundtrip identity.
        irfft2d(&plan, &mut sre, &mut sim, &mut plane, &mut tre, &mut tim, 1.0 / (m * m) as f32);
        for i in 0..m * m {
            if (plane[i] - x[i]).abs() > 1e-4 {
                return Err(format!("m={m} i={i}: {} vs {}", plane[i], x[i]));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_kbest_matches_sort() {
    prop::check("KBest == full sort", &vec_f32(1, 200, 0.0, 100.0), |ds| {
        let k = 7.min(ds.len());
        let mut kb = KBest::new(k);
        for (i, &d) in ds.iter().enumerate() {
            kb.push(d, i as u32);
        }
        let got: Vec<f32> = kb.into_sorted().into_iter().map(|(d, _)| d).collect();
        let mut want = ds.clone();
        want.sort_by(|a, b| a.partial_cmp(b).unwrap());
        want.truncate(k);
        for (g, w) in got.iter().zip(&want) {
            if (g - w).abs() > 1e-9 {
                return Err(format!("{got:?} != {want:?}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_grid_policy_total_switches_bounded() {
    // Under any diameter walk, hysteresis must keep the switch count well
    // below the number of observations (no thrash).
    prop::check("grid policy no-thrash", &vec_f32(50, 200, 5.0, 120.0), |diams| {
        let mut policy = GridPolicy::new(0.5, vec![32, 64, 128, 256]);
        let mut switches = 0;
        let mut last = 0usize;
        // Smooth the walk like a real optimisation (diameter drifts).
        let mut d = diams[0];
        for &target in diams {
            d = 0.9 * d + 0.1 * target;
            let g = policy.choose(d);
            if last != 0 && g != last {
                switches += 1;
            }
            last = g;
        }
        if switches > diams.len() / 5 {
            return Err(format!("{switches} switches in {} steps", diams.len()));
        }
        Ok(())
    });
}

#[test]
fn prop_gd_state_padding_free_determinism() {
    // Engine determinism: same seed -> identical embedding.
    prop::check("engine determinism", &usize_in(30, 120), |&n| {
        let data = dataset_from(n as u64, n, 4);
        let k = 8.min(n - 1);
        let g = bruteforce::knn(&data, k);
        let p = perplexity::joint_p(&g, 4.0);
        let params = gpgpu_sne::embed::OptParams {
            iters: 30,
            exaggeration_iters: 10,
            seed: 5,
            ..Default::default()
        };
        let a = gpgpu_sne::embed::by_name("bh-0.5", None).unwrap().run(&p, &params, None).unwrap();
        let b = gpgpu_sne::embed::by_name("bh-0.5", None).unwrap().run(&p, &params, None).unwrap();
        if a != b {
            return Err("same-seed runs differ".into());
        }
        Ok(())
    });
}
