//! Cross-module integration: engines against each other, the field
//! approximation against the exact gradient, metrics against engines, and
//! the device (gpgpu) engine against its CPU mirror when artifacts exist.

use std::sync::Arc;

use gpgpu_sne::coordinator::pipeline::compute_knn;
use gpgpu_sne::coordinator::KnnMethod;
use gpgpu_sne::data;
use gpgpu_sne::embed::{self, Control, IterStats, OptParams};
use gpgpu_sne::hd::perplexity;
use gpgpu_sne::metrics::{kl, nnp};
use gpgpu_sne::runtime::{self, Runtime};

fn problem(n: usize, seed: u64) -> (gpgpu_sne::hd::Dataset, gpgpu_sne::hd::SparseP) {
    let ds = data::by_name("gaussians", n, seed).unwrap();
    let k = 30.min(n - 1);
    let knn = compute_knn(&ds, KnnMethod::Brute, k, seed);
    let p = perplexity::joint_p(&knn, 10.0);
    (ds, p)
}

fn quick_params(iters: usize) -> OptParams {
    OptParams { iters, exaggeration_iters: iters / 4, seed: 11, ..Default::default() }
}

#[test]
fn all_cpu_engines_reduce_kl_on_gaussians() {
    let (_ds, p) = problem(200, 1);
    for name in ["exact", "bh-0.1", "bh-0.5", "tsne-cuda-0.5", "fieldcpu", "fieldfft"] {
        let mut engine = embed::by_name(name, None).unwrap();
        let mut first = f64::NAN;
        let mut last = f64::NAN;
        let mut obs = |s: &IterStats, _: &[f32]| {
            if s.iter == 0 {
                first = s.kl_est;
            }
            last = s.kl_est;
            Control::Continue
        };
        let y = engine.run(&p, &quick_params(120), Some(&mut obs)).unwrap();
        assert!(
            last < 0.7 * first,
            "{name}: KL should drop substantially ({first:.3} -> {last:.3})"
        );
        assert!(y.iter().all(|v| v.is_finite()), "{name}: non-finite output");
    }
}

#[test]
fn field_engine_matches_exact_engine_quality() {
    // The paper's claim: the field approximation optimises the objective
    // as well as (or better than) BH. Verify final exact-KL of fieldcpu is
    // within 10% of the exact engine and <= BH θ=0.5 + 10%.
    let (_ds, p) = problem(300, 2);
    let params = quick_params(250);
    let run = |name: &str| {
        let y = embed::by_name(name, None).unwrap().run(&p, &params, None).unwrap();
        kl::kl_divergence_exact(&p, &y)
    };
    let kl_exact = run("exact");
    let kl_field = run("fieldcpu");
    let kl_bh = run("bh-0.5");
    assert!(
        kl_field < kl_exact * 1.10,
        "fieldcpu {kl_field:.4} should track exact {kl_exact:.4}"
    );
    assert!(kl_field < kl_bh * 1.10, "fieldcpu {kl_field:.4} vs bh {kl_bh:.4}");
}

#[test]
fn embeddings_cluster_labelled_data() {
    // 10-cluster Gaussian data must produce an embedding where same-class
    // mean distance << cross-class mean distance.
    let ds = data::by_name("gaussians", 300, 5).unwrap();
    let knn = compute_knn(&ds, KnnMethod::Brute, 30, 5);
    let p = perplexity::joint_p(&knn, 10.0);
    let y = embed::by_name("fieldcpu", None)
        .unwrap()
        .run(&p, &quick_params(300), None)
        .unwrap();
    let (mut within, mut wn, mut between, mut bn) = (0.0f64, 0usize, 0.0f64, 0usize);
    for i in 0..ds.n {
        for j in (i + 1..ds.n).step_by(3) {
            let dx = (y[2 * i] - y[2 * j]) as f64;
            let dy = (y[2 * i + 1] - y[2 * j + 1]) as f64;
            let d = (dx * dx + dy * dy).sqrt();
            if ds.labels[i] == ds.labels[j] {
                within += d;
                wn += 1;
            } else {
                between += d;
                bn += 1;
            }
        }
    }
    let (w, b) = (within / wn as f64, between / bn as f64);
    assert!(b > 2.0 * w, "embedding failed to separate clusters: within={w:.2} between={b:.2}");
}

#[test]
fn nnp_of_converged_embedding_beats_random() {
    let ds = data::by_name("mnist", 250, 3).unwrap();
    let knn = compute_knn(&ds, KnnMethod::Brute, 30, 3);
    let p = perplexity::joint_p(&knn, 10.0);
    let y = embed::by_name("fieldcpu", None)
        .unwrap()
        .run(&p, &quick_params(300), None)
        .unwrap();
    let curve = nnp::nnp_curve(&ds, &y, 0, 0);
    let mut rng = gpgpu_sne::util::rng::Rng::new(9);
    let y_rand: Vec<f32> = (0..2 * ds.n).map(|_| rng.gauss_f32(0.0, 3.0)).collect();
    let curve_rand = nnp::nnp_curve(&ds, &y_rand, 0, 0);
    assert!(
        curve.mean_precision() > 2.0 * curve_rand.mean_precision(),
        "converged NNP {:.3} vs random {:.3}",
        curve.mean_precision(),
        curve_rand.mean_precision()
    );
}

#[test]
fn gpgpu_engine_tracks_fieldcpu() {
    let Some(dir) = runtime::locate_artifacts() else {
        eprintln!("SKIP: no artifacts/ — run `make artifacts`");
        return;
    };
    let rt = Arc::new(Runtime::new(&dir).unwrap());
    let (_ds, p) = problem(400, 7);
    let params = quick_params(150);

    let y_dev = embed::by_name("gpgpu", Some(rt)).unwrap().run(&p, &params, None).unwrap();
    let y_cpu = embed::by_name("fieldcpu", None).unwrap().run(&p, &params, None).unwrap();

    // Same init seed + same math (different grid sets and f32 ordering):
    // final objective values must agree closely even if trajectories
    // diverge point-wise.
    let kl_dev = kl::kl_divergence_exact(&p, &y_dev);
    let kl_cpu = kl::kl_divergence_exact(&p, &y_cpu);
    assert!(
        (kl_dev - kl_cpu).abs() < 0.15 * kl_cpu.abs().max(0.1),
        "device {kl_dev:.4} vs cpu {kl_cpu:.4}"
    );
}

#[test]
fn gpgpu_engine_bucket_padding_is_inert() {
    let Some(dir) = runtime::locate_artifacts() else {
        eprintln!("SKIP: no artifacts/");
        return;
    };
    let rt = Arc::new(Runtime::new(&dir).unwrap());
    // 123 points pad into a 1024 bucket; result must still be exactly 123
    // finite rows and reduce KL.
    let (_ds, p) = problem(123, 9);
    let mut first = f64::NAN;
    let mut last = f64::NAN;
    let mut obs = |s: &IterStats, _: &[f32]| {
        if s.iter == 0 {
            first = s.kl_est;
        }
        last = s.kl_est;
        Control::Continue
    };
    let y = embed::by_name("gpgpu", Some(rt))
        .unwrap()
        .run(&p, &quick_params(100), Some(&mut obs))
        .unwrap();
    assert_eq!(y.len(), 2 * 123);
    assert!(y.iter().all(|v| v.is_finite()));
    assert!(last < first, "KL {first:.3} -> {last:.3}");
}

#[test]
fn engine_registry_and_const_list_cannot_drift() {
    // Every name in embed::ENGINES must round-trip through embed::by_name,
    // so the const list and the registry can never diverge. `gpgpu` is
    // exercised only when artifacts are present (otherwise its by_name
    // error must be the artifact hint, not "unknown engine").
    let rt = runtime::locate_artifacts().and_then(|d| Runtime::new(&d).ok()).map(Arc::new);
    for &name in embed::ENGINES {
        let runtime = if name == "gpgpu" { rt.clone() } else { None };
        if name == "gpgpu" && runtime.is_none() {
            match embed::by_name(name, None) {
                Ok(_) => panic!("gpgpu without runtime must fail to construct"),
                Err(err) => assert!(
                    format!("{err:#}").contains("artifacts"),
                    "gpgpu without runtime must explain artifacts, got: {err:#}"
                ),
            }
            eprintln!("SKIP gpgpu construction: no artifacts/");
            continue;
        }
        let engine = embed::by_name(name, runtime)
            .unwrap_or_else(|e| panic!("ENGINES lists '{name}' but by_name failed: {e:#}"));
        assert_eq!(engine.name(), name, "engine renames itself");
    }
    // And by_name must still reject names that are not in the list.
    assert!(embed::by_name("not-an-engine", None).is_err());
}

#[test]
fn knn_methods_feed_equivalent_p_quality() {
    // Approximate kNN (kdforest) must yield a P whose optimised embedding
    // is nearly as good as exact kNN's — the A-tSNE premise.
    let ds = data::by_name("gaussians", 250, 4).unwrap();
    let params = quick_params(200);
    let mut kls = Vec::new();
    for method in [KnnMethod::Brute, KnnMethod::KdForest] {
        let knn = compute_knn(&ds, method, 30, 4);
        let p = perplexity::joint_p(&knn, 10.0);
        let y = embed::by_name("bh-0.5", None).unwrap().run(&p, &params, None).unwrap();
        kls.push(kl::kl_divergence_exact(&p, &y));
    }
    assert!(
        kls[1] < kls[0] * 1.25,
        "approx-kNN embedding quality degraded: exact {:.4} vs kdforest {:.4}",
        kls[0],
        kls[1]
    );
}
