//! Pipeline/service-level integration: full jobs through the coordinator,
//! including device-backed jobs when artifacts are present.

use std::sync::Arc;

use gpgpu_sne::coordinator::job::AutoStop;
use gpgpu_sne::coordinator::progress::JobState;
use gpgpu_sne::coordinator::{run_pipeline, EmbeddingService, JobPhase, JobSpec, KnnMethod};
use gpgpu_sne::embed::OptParams;
use gpgpu_sne::runtime::{self, Runtime};

fn spec(dataset: &str, n: usize, engine: &str, iters: usize) -> JobSpec {
    JobSpec {
        dataset: dataset.into(),
        n,
        engine: engine.into(),
        perplexity: 15.0,
        knn: KnnMethod::KdForest,
        params: OptParams { iters, exaggeration_iters: iters / 4, ..Default::default() },
        snapshot_every: 25,
        auto_stop: None,
        priority: Default::default(),
        seed: 2,
        y0: None,
        resume_from: None,
    }
}

#[test]
fn every_table1_dataset_flows_through_the_pipeline() {
    for name in ["mnist", "wikiword", "word2vec", "imagenet-mixed3a", "imagenet-head0"] {
        let state = JobState::default();
        let res = run_pipeline(&spec(name, 160, "bh-0.5", 40), None, &state)
            .unwrap_or_else(|e| panic!("{name}: {e:#}"));
        assert_eq!(res.embedding.len(), 320, "{name}");
        assert!(res.embedding.iter().all(|v| v.is_finite()), "{name}");
        assert!(res.kl_est.is_finite(), "{name}");
    }
}

#[test]
fn service_runs_gpgpu_job_when_artifacts_exist() {
    let Some(dir) = runtime::locate_artifacts() else {
        eprintln!("SKIP: no artifacts/");
        return;
    };
    let rt = Arc::new(Runtime::new(&dir).unwrap());
    let svc = EmbeddingService::new(Some(rt), 2);
    assert!(svc.has_runtime());
    let id = svc.submit(spec("mnist", 300, "gpgpu", 60));
    let res = svc.wait(id).unwrap();
    assert_eq!(res.embedding.len(), 600);
    assert_eq!(svc.phase(id), Some(JobPhase::Done));
    // Progressive snapshots were produced.
    assert!(svc.latest_snapshot(id).is_some());
}

#[test]
fn service_multiplexes_cpu_and_device_jobs() {
    let rt = runtime::locate_artifacts().and_then(|d| Runtime::new(&d).ok()).map(Arc::new);
    let svc = EmbeddingService::new(rt.clone(), 2);
    let mut ids = vec![svc.submit(spec("gaussians", 120, "bh-0.5", 30))];
    ids.push(svc.submit(spec("gaussians", 120, "fieldcpu", 30)));
    if rt.is_some() {
        ids.push(svc.submit(spec("gaussians", 120, "gpgpu", 30)));
    }
    for id in ids {
        let res = svc.wait(id).unwrap();
        assert!(res.embedding.iter().all(|v| v.is_finite()));
    }
}

#[test]
fn snapshots_arrive_in_iteration_order_with_falling_kl_trend() {
    let state = JobState::default();
    let rx = state.snapshots.subscribe();
    let res = run_pipeline(&spec("gaussians", 200, "fieldcpu", 120), None, &state).unwrap();
    let snaps: Vec<_> = rx.try_iter().collect();
    assert!(snaps.len() >= 4);
    for w in snaps.windows(2) {
        assert!(w[1].iter > w[0].iter, "snapshots out of order");
    }
    // KL at the end must be below KL at the start (trend, not monotone).
    assert!(snaps.last().unwrap().kl_est < snaps[0].kl_est);
    assert_eq!(res.iters_run, 120);
}

#[test]
fn auto_stop_saves_iterations_on_small_problems() {
    let state = JobState::default();
    let mut s = spec("gaussians", 120, "bh-0.5", 2000);
    s.auto_stop = Some(AutoStop { window: 25, rel_eps: 5e-5 });
    let res = run_pipeline(&s, None, &state).unwrap();
    assert!(res.stopped_early);
    assert!(
        res.iters_run < 1500,
        "plateau detection should fire well before 2000 iters, ran {}",
        res.iters_run
    );
}

#[test]
fn similarity_cache_hit_and_miss_through_the_service() {
    let svc = EmbeddingService::new(None, 2);
    let base = spec("gaussians", 400, "bh-0.5", 30);

    // Miss: first job computes kNN + P.
    let id = svc.submit(base.clone());
    let first = svc.wait(id).unwrap();
    assert!(!first.timings.sim_cache_hit);
    assert!(first.timings.similarities_s() > 0.0);

    // Hit: identical job skips the similarity stage entirely. The stage
    // timings collapse to the fingerprint+lookup cost (perplexity_s is
    // exactly 0 — no P build ran; no wall-clock comparison, which would
    // flake under CI load).
    let id = svc.submit(base.clone());
    let second = svc.wait(id).unwrap();
    assert!(second.timings.sim_cache_hit, "identical job must hit");
    assert_eq!(second.timings.perplexity_s, 0.0);
    assert_eq!(first.embedding, second.embedding, "hit must not change the result");

    // Miss again: different perplexity ⇒ different k ⇒ different key.
    let mut other = base.clone();
    other.perplexity = 25.0;
    let id = svc.submit(other);
    let third = svc.wait(id).unwrap();
    assert!(!third.timings.sim_cache_hit, "different k must miss");

    assert_eq!(svc.sim_cache().stats(), (1, 2));
    assert_eq!(svc.sim_cache().len(), 2);
}

#[test]
fn perplexity_larger_than_k_is_clamped_not_fatal() {
    let state = JobState::default();
    let mut s = spec("gaussians", 50, "bh-0.5", 20);
    s.perplexity = 500.0; // absurd for n=50
    let res = run_pipeline(&s, None, &state).unwrap();
    assert!(res.embedding.iter().all(|v| v.is_finite()));
}
