//! Chaos harness: drive a real `serve` instance over TCP with
//! concurrent submit / meddle / garbage / subscriber clients while
//! fault points fire, and pin the hardened stack's contract —
//! **no hangs** (every client call bounded by a read timeout, every
//! thread joined under a deadline), **no lost jobs** (every admitted id
//! reaches a terminal state and stays visible), **no escaped panics**
//! (an injected engine panic fails one job, never the service), and
//! **bit-identical survivors** (a drain shutdown journals every live
//! session; a restart resumes them to the same embedding an
//! uninterrupted run produces).
//!
//! The fault registry is process-global, so every test that arms or
//! depends on disarmed faults serialises on one lock. Integration
//! binaries run one process per file — the lock is local to this file.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use gpgpu_sne::coordinator::progress::JobState;
use gpgpu_sne::coordinator::store::JobJournal;
use gpgpu_sne::coordinator::{
    faultinject, protocol, run_pipeline, EmbeddingService, JobSpec, KnnMethod, ServiceConfig,
};
use gpgpu_sne::embed::OptParams;
use gpgpu_sne::util::json::{self, Json};

static CHAOS_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    let guard = CHAOS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    // A previous (possibly panicked) test must not leak armed faults.
    faultinject::disarm_all();
    guard
}

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("gsne-chaos-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Bind an ephemeral port and serve `svc` on a background thread.
fn start_server(
    svc: Arc<EmbeddingService>,
    max_conns: usize,
) -> (std::net::SocketAddr, std::thread::JoinHandle<()>) {
    let (tx, rx) = std::sync::mpsc::channel();
    let handle = std::thread::spawn(move || {
        let _ = protocol::serve_with(svc, "127.0.0.1:0", max_conns, move |addr| {
            let _ = tx.send(addr);
        });
    });
    let addr = rx.recv_timeout(Duration::from_secs(10)).expect("server bind");
    (addr, handle)
}

struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Self {
        let stream = TcpStream::connect(addr).expect("connect");
        // The no-hang contract: every read is bounded. A server that
        // stops responding fails the test instead of wedging it.
        stream.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
        Self { reader: BufReader::new(stream.try_clone().unwrap()), writer: stream }
    }

    fn call(&mut self, req: &str) -> Json {
        self.writer.write_all(req.as_bytes()).unwrap();
        self.writer.write_all(b"\n").unwrap();
        let mut line = String::new();
        self.reader.read_line(&mut line).expect("response within the read timeout");
        json::parse(line.trim()).unwrap_or_else(|e| panic!("bad response '{line}': {e}"))
    }
}

fn submit_line(n: usize, iters: usize, seed: u64) -> String {
    format!(
        r#"{{"cmd":"submit","dataset":"gaussians","n":{n},"engine":"bh-0.5","iters":{iters},"perplexity":8,"knn":"brute","seed":{seed},"snapshot_every":1}}"#
    )
}

/// The in-process twin of [`submit_line`] — field-for-field what
/// `spec_from_json` builds, so reference runs are comparable.
fn submit_spec(n: usize, iters: usize, seed: u64) -> JobSpec {
    JobSpec {
        dataset: "gaussians".into(),
        n,
        engine: "bh-0.5".into(),
        perplexity: 8.0,
        knn: KnnMethod::Brute,
        params: OptParams { iters, seed, ..Default::default() },
        snapshot_every: 1,
        auto_stop: None,
        priority: Default::default(),
        seed,
        y0: None,
        resume_from: None,
    }
}

#[test]
fn protocol_storm_survives_faults() {
    let _l = lock();
    let svc = Arc::new(EmbeddingService::with_config(
        None,
        ServiceConfig { max_concurrent: 2, ..Default::default() },
    ));
    let (addr, server) = start_server(svc.clone(), 64);

    // Arm the chaos over the wire, exactly as an operator would:
    // connection stalls, periodic engine panics, a slow snapshot
    // subscriber. (Store faults get their own deterministic tests.)
    let mut admin = Client::connect(addr);
    let v = admin.call(
        r#"{"cmd":"fault","spec":"net.stall=every:5,engine.step_panic=every:150,snapshot.slow_subscriber=every:3"}"#,
    );
    assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{v}");

    // One long-running job with an in-process slow subscriber, so the
    // bounded-fanout path (drop-oldest, lagging, eviction) runs hot
    // while the storm rages.
    let long_id = admin.call(&submit_line(120, 5000, 99)).num_field("job").unwrap() as u64;
    let subscriber = {
        let svc = svc.clone();
        std::thread::spawn(move || {
            let rx = loop {
                if let Some(rx) = svc.subscribe(long_id) {
                    break rx;
                }
                std::thread::sleep(Duration::from_millis(5));
            };
            let mut seen = 0u64;
            loop {
                match rx.recv_timeout(Duration::from_millis(500)) {
                    Ok(_) => seen += 1,
                    Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                        if svc.phase(long_id).map_or(true, |p| p.is_terminal()) {
                            break;
                        }
                    }
                }
            }
            seen
        })
    };

    // Submit fleet: 3 clients × 4 jobs, all waited to a terminal state.
    let mut submitters = Vec::new();
    for t in 0..3u64 {
        submitters.push(std::thread::spawn(move || {
            let mut c = Client::connect(addr);
            let mut ids = Vec::new();
            for j in 0..4u64 {
                let v = c.call(&submit_line(80, 40, 1000 + t * 10 + j));
                assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{v}");
                ids.push(v.num_field("job").unwrap() as u64);
            }
            ids.into_iter()
                .map(|id| {
                    let v = c.call(&format!(r#"{{"cmd":"wait","job":{id}}}"#));
                    // ok:false = the job failed (e.g. injected panic):
                    // a terminal, *accounted* outcome — not a lost job.
                    let failed = v.get("ok") == Some(&Json::Bool(false));
                    if failed {
                        assert!(v.str_field("error").is_some(), "{v}");
                    }
                    (id, failed)
                })
                .collect::<Vec<_>>()
        }));
    }

    // Garbage client: hostile lines never panic the dispatcher and the
    // connection stays usable throughout.
    let garbage = std::thread::spawn(move || {
        let mut c = Client::connect(addr);
        for line in [
            "not json",
            "[]",
            r#"{"cmd":"frobnicate"}"#,
            r#"{"cmd":"status","job":"x"}"#,
            r#"{"cmd":"submit","n":1e300}"#,
            r#"{"cmd":"update","job":0}"#,
            r#"{"cmd":"fault","spec":"no.such.point=once"}"#,
        ]
        .iter()
        .cycle()
        .take(40)
        {
            let v = c.call(line);
            assert_eq!(v.get("ok"), Some(&Json::Bool(false)), "{line} -> {v}");
        }
        let v = c.call(r#"{"cmd":"list"}"#);
        assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{v}");
    });

    // Meddler: checkpoint / pause+resume / stop whatever is running.
    let meddler = std::thread::spawn(move || {
        let mut c = Client::connect(addr);
        for round in 0..50usize {
            let v = c.call(r#"{"cmd":"list"}"#);
            let jobs = v.get("jobs").and_then(Json::as_arr).map(<[Json]>::to_vec).unwrap_or_default();
            if let Some(job) = jobs.get(round % jobs.len().max(1)) {
                let id = job.num_field("job").unwrap_or(0.0) as u64;
                match round % 4 {
                    0 => {
                        c.call(&format!(r#"{{"cmd":"checkpoint","job":{id}}}"#));
                    }
                    1 => {
                        // Always paired, so no job is left parked.
                        c.call(&format!(r#"{{"cmd":"pause","job":{id}}}"#));
                        c.call(&format!(r#"{{"cmd":"resume","job":{id}}}"#));
                    }
                    2 => {
                        c.call(&format!(r#"{{"cmd":"status","job":{id}}}"#));
                    }
                    _ => {
                        // The "kill" client: stopped jobs are a terminal,
                        // accounted outcome for whoever waits on them.
                        c.call(&format!(r#"{{"cmd":"stop","job":{id}}}"#));
                    }
                }
            }
            std::thread::sleep(Duration::from_millis(20));
        }
    });

    // Join everything under the no-hang contract (the per-call read
    // timeouts bound each thread; a panic inside any of them fails the
    // test here).
    let mut outcomes = Vec::new();
    for s in submitters {
        outcomes.extend(s.join().expect("submitter thread survives the storm"));
    }
    garbage.join().expect("garbage client survives");
    meddler.join().expect("meddler survives");

    // End the long job, then the subscriber must terminate too.
    admin.call(&format!(r#"{{"cmd":"stop","job":{long_id}}}"#));
    admin.call(&format!(r#"{{"cmd":"wait","job":{long_id}}}"#));
    subscriber.join().expect("subscriber loop terminates");

    // No lost jobs: every admitted id is still visible and terminal.
    assert_eq!(outcomes.len(), 12);
    let listed = svc.list();
    for (id, _) in &outcomes {
        let phase = listed.iter().find(|(lid, _)| lid == id).map(|(_, p)| p.clone());
        let phase = phase.unwrap_or_else(|| panic!("job {id} vanished from list"));
        assert!(phase.is_terminal(), "job {id} not terminal after wait: {phase:?}");
    }
    // No escaped panics: injected step panics may have failed *some*
    // jobs, but the service kept serving every other one (all twelve
    // reached wait, the server thread is still alive).
    let failed = outcomes.iter().filter(|(_, f)| *f).count();
    assert!(failed < outcomes.len(), "every job failed — faults escaped containment");

    // Clear faults over the wire, then drain: idle service, clean exit.
    let v = admin.call(r#"{"cmd":"fault","clear":true}"#);
    assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{v}");
    let v = admin.call(r#"{"cmd":"shutdown","timeout_s":30}"#);
    assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{v}");
    assert_eq!(v.num_field("parked_jobs"), Some(0.0), "{v}");
    server.join().expect("accept loop exits after shutdown");
    faultinject::disarm_all();
}

#[test]
fn drain_shutdown_then_restart_resumes_bit_identically() {
    let _l = lock();
    let dir = tmp_dir("drain");
    // Journal cadence too large to ever fire: the only checkpoints the
    // journal can carry are the ones the drain parks write.
    let cfg = || ServiceConfig {
        max_concurrent: 2,
        state_dir: Some(dir.clone()),
        journal_every: 1_000_000,
        ..Default::default()
    };

    // Uninterrupted references for both survivors.
    let ref_a = run_pipeline(&submit_spec(600, 400, 5), None, &JobState::default()).unwrap();
    let ref_b = run_pipeline(&submit_spec(600, 400, 6), None, &JobState::default()).unwrap();

    let svc = Arc::new(EmbeddingService::with_config(None, cfg()));
    let (addr, server) = start_server(svc.clone(), 64);
    let mut c = Client::connect(addr);
    let a = c.call(&submit_line(600, 400, 5)).num_field("job").unwrap() as u64;
    let b = c.call(&submit_line(600, 400, 6)).num_field("job").unwrap() as u64;

    // Let both jobs run some real iterations before pulling the plug.
    let deadline = Instant::now() + Duration::from_secs(60);
    for id in [a, b] {
        while svc.latest_snapshot(id).map(|s| s.iter).unwrap_or(0) < 20 {
            assert!(Instant::now() < deadline, "job {id} never started stepping");
            std::thread::sleep(Duration::from_millis(10));
        }
    }

    // The drain handshake over the wire: both live jobs parked +
    // journalled by the time the response arrives; accept loop exits.
    let v = c.call(r#"{"cmd":"shutdown","timeout_s":60}"#);
    assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{v}");
    assert_eq!(v.num_field("parked_jobs"), Some(2.0), "{v}");
    server.join().expect("accept loop exits after drain");
    assert!(svc.is_draining());
    drop(c);
    drop(svc);

    // Restart over the same state dir: both jobs re-admitted under
    // their original ids, resumed from their drain-park checkpoints,
    // and — determinism end to end — bit-identical to uninterrupted.
    let svc = EmbeddingService::with_config(None, cfg());
    let res_a = svc.wait(a).expect("job a resumes");
    let res_b = svc.wait(b).expect("job b resumes");
    assert_eq!(res_a.iters_run, 400);
    assert_eq!(res_b.iters_run, 400);
    assert_eq!(res_a.embedding, ref_a.embedding, "job a diverged across drain/restart");
    assert_eq!(res_b.embedding, ref_b.embedding, "job b diverged across drain/restart");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn crash_between_tmp_write_and_rename_is_a_clean_miss() {
    let _l = lock();
    let dir = tmp_dir("crash");
    let journal = JobJournal::open(&dir).unwrap();
    journal.write(1, r#"{"n":80}"#, b"ckpt-one");
    assert_eq!(journal.read_all().len(), 1);

    // Crash injected between the tmp write and the rename — the caller
    // (like a killed process) never learns. The record must be
    // invisible: next read is a clean miss, not garbage.
    {
        let _g = faultinject::guard("store.write_crash=once").unwrap();
        journal.write(2, r#"{"n":90}"#, b"ckpt-two");
    }
    let entries = journal.read_all();
    assert_eq!(entries.len(), 1, "half-written record must not surface");
    assert_eq!(entries[0].id, 1);
    let tmps = |dir: &PathBuf| {
        std::fs::read_dir(dir)
            .unwrap()
            .flatten()
            .filter(|e| e.file_name().to_string_lossy().contains(".tmp."))
            .count()
    };
    assert_eq!(tmps(&dir), 1, "the orphaned tmp file is on disk");

    // Startup reaps the orphan and the surviving record is intact.
    let journal = JobJournal::open(&dir).unwrap();
    assert_eq!(tmps(&dir), 0, "open() reaps orphaned tmp files");
    let entries = journal.read_all();
    assert_eq!(entries.len(), 1);
    assert_eq!(entries[0].checkpoint, b"ckpt-one");

    // Read-side corruption: one flipped byte = checksum miss, and the
    // poisoned file is deleted rather than ever trusted.
    {
        let _g = faultinject::guard("store.read_corrupt=once").unwrap();
        assert_eq!(journal.read_all().len(), 0, "corrupt record must read as absent");
    }
    assert_eq!(journal.read_all().len(), 0, "corrupt record was deleted, not retried");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn oversized_request_is_rejected_and_connection_closed() {
    // No lock: touches no fault points, no jobs.
    let svc = Arc::new(EmbeddingService::with_config(
        None,
        ServiceConfig { max_concurrent: 1, ..Default::default() },
    ));
    let (addr, _server) = start_server(svc, 4);
    let stream = TcpStream::connect(addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);

    // Stream just over the cap without a newline. The server must
    // answer with a structured error and close — writes may start
    // failing once it does, which is the point.
    let chunk = vec![b'a'; 1 << 20];
    for _ in 0..(protocol::MAX_REQUEST_BYTES / chunk.len() + 2) {
        if writer.write_all(&chunk).is_err() {
            // The server already hung up on us mid-flood — that IS the
            // rejection taking effect.
            break;
        }
    }
    let mut line = String::new();
    match reader.read_line(&mut line) {
        // EOF without a readable line: closed, which is the contract.
        Ok(0) => {}
        Ok(_) => {
            let v = json::parse(line.trim()).expect("structured error line");
            assert_eq!(v.get("ok"), Some(&Json::Bool(false)), "{v}");
            assert_eq!(v.str_field("code"), Some("request_too_large"), "{v}");
            assert_eq!(v.get("retriable"), Some(&Json::Bool(false)), "{v}");
            // And the connection is done: next read is EOF.
            line.clear();
            assert_eq!(reader.read_line(&mut line).unwrap_or(0), 0, "connection must close");
        }
        // Reset before the response could be read — still a close.
        Err(_) => {}
    }
}

#[test]
fn router_storm_survives_a_worker_death_and_flaky_heartbeats() {
    let _l = lock();
    // Two real workers behind one router, served over real TCP, with
    // the router's own fault points armed: heartbeat probes drop with
    // p=0.1 (failure detection must tolerate flake without spurious
    // failovers wedging anything) and replication pulls fail with
    // p=0.3 (failovers resume from older replicas, or from scratch).
    let mk_worker = || {
        let svc = Arc::new(EmbeddingService::with_config(
            None,
            ServiceConfig { max_concurrent: 2, ..Default::default() },
        ));
        let (addr, handle) = start_server(svc.clone(), 64);
        (svc, addr, handle)
    };
    let (w1, a1, h1) = mk_worker();
    let (_w2, a2, _h2) = mk_worker();
    let router = Arc::new(gpgpu_sne::cluster::Router::new(gpgpu_sne::cluster::RouterConfig {
        heartbeat_interval: Some(Duration::from_millis(50)),
        heartbeat_timeout: Duration::from_millis(400),
        ..Default::default()
    }));
    router.register_worker(&a1.to_string());
    router.register_worker(&a2.to_string());
    router.spawn_heartbeat();
    let (tx, rx) = std::sync::mpsc::channel();
    let router_thread = {
        let router = router.clone();
        std::thread::spawn(move || {
            let _ = router.serve("127.0.0.1:0", move |a| {
                let _ = tx.send(a);
            });
        })
    };
    let raddr = rx.recv_timeout(Duration::from_secs(10)).expect("router bind");

    let mut admin = Client::connect(raddr);
    let v = admin
        .call(r#"{"cmd":"fault","spec":"cluster.heartbeat.drop=prob:0.1@7,cluster.replicate.fail=prob:0.3@9"}"#);
    assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{v}");

    // Six clients storm the router: submit (retrying retriable shed or
    // worker_unavailable errors, as a well-behaved client would), then
    // wait. Every admitted job must reach a terminal ok — including the
    // ones stranded on the worker we kill mid-storm.
    let storm: Vec<_> = (0..6u64)
        .map(|t| {
            std::thread::spawn(move || {
                let mut c = Client::connect(raddr);
                for j in 0..2u64 {
                    let seed = 100 + t * 2 + j;
                    let id = {
                        let deadline = Instant::now() + Duration::from_secs(30);
                        loop {
                            let v = c.call(&submit_line(100, 200, seed));
                            if v.get("ok") == Some(&Json::Bool(true)) {
                                break v.num_field("job").unwrap() as u64;
                            }
                            assert_eq!(
                                v.get("retriable"),
                                Some(&Json::Bool(true)),
                                "non-retriable submit failure: {v}"
                            );
                            assert!(Instant::now() < deadline, "submit never admitted: {v}");
                            std::thread::sleep(Duration::from_millis(50));
                        }
                    };
                    let done = c.call(&format!(r#"{{"cmd":"wait","job":{id}}}"#));
                    assert_eq!(
                        done.get("ok"),
                        Some(&Json::Bool(true)),
                        "job {id} (seed {seed}) lost in the storm: {done}"
                    );
                    assert_eq!(done.num_field("iters"), Some(200.0), "{done}");
                }
            })
        })
        .collect();

    // Pull the plug on worker 1 while the storm rages: stop computing,
    // close the listener — a crash as the router sees it.
    std::thread::sleep(Duration::from_millis(300));
    w1.drain(Duration::from_secs(30));
    let _ = TcpStream::connect(a1);
    h1.join().expect("worker 1 accept loop exits");

    let deadline = Instant::now() + Duration::from_secs(120);
    for t in storm {
        assert!(Instant::now() < deadline, "storm clients wedged");
        t.join().expect("storm client");
    }

    // The router saw the death (missed heartbeats are guaranteed by the
    // kill, never mind the injected drops) and kept exactly one shard.
    let stats = admin.call(r#"{"cmd":"cluster_stats"}"#);
    assert_eq!(stats.num_field("workers_up"), Some(1.0), "{stats}");
    assert!(stats.num_field("heartbeats_missed").unwrap() >= 1.0, "{stats}");

    let v = admin.call(r#"{"cmd":"fault","clear":true}"#);
    assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{v}");
    let v = admin.call(r#"{"cmd":"shutdown"}"#);
    assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{v}");
    router_thread.join().expect("router accept loop exits after shutdown");
    faultinject::disarm_all();
}

#[test]
fn connection_cap_sheds_with_server_busy() {
    // No lock: touches no fault points, no jobs.
    let svc = Arc::new(EmbeddingService::with_config(
        None,
        ServiceConfig { max_concurrent: 1, ..Default::default() },
    ));
    let (addr, _server) = start_server(svc, 1);

    let mut first = Client::connect(addr);
    let v = first.call(r#"{"cmd":"list"}"#);
    assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{v}");

    // Second connection: shed at accept time with one retriable error.
    let shed = TcpStream::connect(addr).expect("connect");
    shed.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    let mut line = String::new();
    BufReader::new(shed).read_line(&mut line).expect("shed response");
    let v = json::parse(line.trim()).expect("structured shed line");
    assert_eq!(v.get("ok"), Some(&Json::Bool(false)), "{v}");
    assert_eq!(v.str_field("code"), Some("server_busy"), "{v}");
    assert_eq!(v.get("retriable"), Some(&Json::Bool(true)), "{v}");

    // Freeing the slot re-opens the door (the handler notices the
    // close asynchronously — retry until the slot drains).
    drop(first);
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let mut c = Client::connect(addr);
        let mut line = String::new();
        c.writer.write_all(b"{\"cmd\":\"list\"}\n").unwrap();
        c.reader.read_line(&mut line).expect("response");
        let v = json::parse(line.trim()).unwrap();
        if v.get("ok") == Some(&Json::Bool(true)) {
            break;
        }
        assert_eq!(v.str_field("code"), Some("server_busy"), "{v}");
        assert!(Instant::now() < deadline, "slot never freed after client close");
        std::thread::sleep(Duration::from_millis(50));
    }
}
