//! Session/run conformance: for every CPU engine the stepwise session
//! API must be *bit-identical* to the one-shot `run()` contract —
//! looping `step()` to completion, pausing, checkpoint/restore (through
//! the byte codec) and resuming must all reproduce an uninterrupted run
//! exactly. This is what makes the coordinator's cooperative scheduler
//! safe: slicing, parking and migrating a job across workers cannot
//! change its result.

use std::sync::Arc;

use gpgpu_sne::embed::{self, Checkpoint, OptParams};
use gpgpu_sne::hd::sparse::Csr;
use gpgpu_sne::hd::SparseP;

/// Every self-contained engine (`gpgpu` needs AOT artifacts and is
/// covered by the artifact-gated integration tests).
fn cpu_engines() -> impl Iterator<Item = &'static str> {
    embed::ENGINES.iter().copied().filter(|&n| n != "gpgpu")
}

fn ring_p(n: usize, k: usize) -> SparseP {
    let mut col = Vec::new();
    let mut val = Vec::new();
    for i in 0..n {
        for j in 1..=k {
            col.push(((i + j) % n) as u32);
            val.push(1.0 / (n * k) as f32);
        }
    }
    SparseP { csr: Csr::from_rows(n, n, k, col, val), perplexity: k as f32 }
}

fn params(iters: usize) -> OptParams {
    OptParams { iters, exaggeration_iters: 15, seed: 7, ..Default::default() }
}

#[test]
fn step_loop_is_bit_identical_to_run() {
    let p = ring_p(120, 3);
    let prm = params(40);
    for name in cpu_engines() {
        let y_run = embed::by_name(name, None).unwrap().run(&p, &prm, None).unwrap();
        let mut engine = embed::by_name(name, None).unwrap();
        let mut session = engine.begin(Arc::new(p.clone()), &prm).unwrap();
        assert_eq!(session.engine_name(), name, "session names its engine");
        let mut steps = 0usize;
        while !session.is_done() {
            let stats = session.step().unwrap();
            assert_eq!(stats.iter, steps, "{name}: stats carry the iteration index");
            steps += 1;
        }
        assert_eq!(steps, 40, "{name}");
        assert_eq!(session.iter(), 40, "{name}");
        assert_eq!(
            session.positions(),
            &y_run[..],
            "{name}: stepping to completion must be bit-identical to run()"
        );
        assert!(session.step().is_err(), "{name}: stepping a finished session errors");
    }
}

#[test]
fn checkpoint_restore_resumes_bit_identically() {
    // Pause + checkpoint (through the byte codec, i.e. fully
    // serialisable state) + restore into a *fresh* session — cold
    // scratch, cold caches, possibly another worker/process — then
    // resume: the final embedding must equal an uninterrupted run
    // bit-for-bit, for every CPU engine.
    let p = ring_p(100, 3);
    let prm = params(50);
    for name in cpu_engines() {
        let y_full = embed::by_name(name, None).unwrap().run(&p, &prm, None).unwrap();

        let mut engine = embed::by_name(name, None).unwrap();
        let mut first = engine.begin(Arc::new(p.clone()), &prm).unwrap();
        for _ in 0..23 {
            first.step().unwrap();
        }
        let bytes = first.checkpoint().to_bytes();
        drop(first);
        drop(engine);

        let ck = Checkpoint::from_bytes(&bytes).unwrap();
        assert_eq!(ck.engine, name);
        assert_eq!(ck.iter, 23);

        let mut engine = embed::by_name(name, None).unwrap();
        let mut resumed = engine.begin(Arc::new(p.clone()), &prm).unwrap();
        resumed.restore(&ck).unwrap();
        assert_eq!(resumed.iter(), 23, "{name}: restore rewinds the clock");
        while !resumed.is_done() {
            resumed.step().unwrap();
        }
        assert_eq!(
            resumed.positions(),
            &y_full[..],
            "{name}: pause + checkpoint/restore + resume must reproduce the run"
        );
    }
}

#[test]
fn warm_start_re_embeds_from_a_given_layout() {
    let p = ring_p(80, 3);
    let prm = params(30);
    let mut engine = embed::by_name("bh-0.5", None).unwrap();
    let mut session = engine.begin(Arc::new(p.clone()), &prm).unwrap();
    for _ in 0..30 {
        session.step().unwrap();
    }
    let converged = session.positions().to_vec();

    // Re-embed from the converged layout (the A-tSNE "data changed a
    // little, keep the picture" workflow).
    session.warm_start(&converged).unwrap();
    assert_eq!(session.iter(), 0, "warm start rewinds the schedule");
    assert_eq!(session.positions(), &converged[..], "layout adopted verbatim");
    let stats = session.step().unwrap();
    assert_eq!(stats.iter, 0);
    assert!(session.positions().iter().all(|v| v.is_finite()));

    // Wrong length is an error, not UB.
    assert!(session.warm_start(&converged[..10]).is_err());
}

#[test]
fn set_params_extends_and_shortens_runs() {
    let p = ring_p(60, 2);
    let mut engine = embed::by_name("exact", None).unwrap();
    let mut session = engine.begin(Arc::new(p.clone()), &params(10)).unwrap();
    while !session.is_done() {
        session.step().unwrap();
    }
    assert!(session.step().is_err(), "done at 10");

    // Extend: the session keeps going with the new schedule.
    let mut prm = session.params().clone();
    prm.iters = 14;
    prm.eta = 50.0;
    session.set_params(prm);
    assert!(!session.is_done(), "raising iters revives the session");
    let mut extra = 0;
    while !session.is_done() {
        let stats = session.step().unwrap();
        assert!(stats.iter >= 10);
        extra += 1;
    }
    assert_eq!(extra, 4);

    // Shorten below the current iteration: done immediately.
    let mut prm = session.params().clone();
    prm.iters = 3;
    session.set_params(prm);
    assert!(session.is_done());
    assert!(session.step().is_err());
}

#[test]
fn checkpoints_hand_off_across_engines() {
    // The checkpoint tensors are engine-agnostic: rough in cheaply with
    // BH, hand the state to the exact engine to finish. (No bit-equality
    // claim here — the engines differ; the claim is the handoff works
    // and keeps optimising the same objective.)
    let p = ring_p(90, 3);
    let prm = params(40);
    let mut bh = embed::by_name("bh-0.5", None).unwrap();
    let mut rough = bh.begin(Arc::new(p.clone()), &prm).unwrap();
    for _ in 0..20 {
        rough.step().unwrap();
    }
    let ck = rough.checkpoint();

    let mut exact = embed::by_name("exact", None).unwrap();
    let mut fine = exact.begin(Arc::new(p.clone()), &prm).unwrap();
    fine.restore(&ck).unwrap();
    assert_eq!(fine.iter(), 20);
    assert_eq!(fine.positions(), &ck.y[..]);
    let kl_at_handoff = fine.step().unwrap().kl_est;
    let mut kl_final = kl_at_handoff;
    while !fine.is_done() {
        kl_final = fine.step().unwrap().kl_est;
    }
    // Trend, not monotone: allow momentum wobble around a plateau.
    assert!(
        kl_final <= kl_at_handoff + 0.05 * kl_at_handoff.abs().max(0.1),
        "handoff keeps minimising: {kl_at_handoff} -> {kl_final}"
    );
    assert!(fine.positions().iter().all(|v| v.is_finite()));

    // A mis-sized checkpoint is rejected.
    let mut other = embed::by_name("exact", None).unwrap();
    let mut small = other.begin(Arc::new(ring_p(30, 2)), &prm).unwrap();
    assert!(small.restore(&ck).is_err());
}
