//! CPU implementation of the paper's field-based repulsion (DESIGN.md
//! S13) — the same mathematics as the L1 Pallas kernel (compute-shader
//! formulation, §5.2: exact kernels at pixel centres, unbounded support),
//! with the paper's adaptive-resolution policy ρ = diameter/G applied
//! continuously rather than over a discrete artifact set.
//!
//! Field-texture computation itself lives in `crate::field` behind the
//! [`FieldBackend`] trait; this module owns the *repulsion adapter*
//! (bbox → grid choice → backend → bilinear queries) shared by every
//! field engine, plus the exact-gather engine `fieldcpu`. The historical
//! entry points (`compute_fields`, `grid_placement`, …) are re-exported
//! so existing benches/examples keep working.

use std::sync::Arc;

use super::common::{EmbeddingSession, Engine, GdSession, OptParams, Repulsion};
use crate::field::gather::GatherBackend;
use crate::field::{bbox_of, FieldBackend, Placement};
use crate::hd::SparseP;

pub use crate::field::gather::{compute_fields, compute_fields_splat};
pub use crate::field::{bilinear, grid_placement, FieldTexture, GRID_MARGIN_PX};

/// Field-based repulsion with the continuous adaptive-ρ policy, generic
/// over the texture backend (exact gather, FFT convolution, …).
pub struct FieldRepulsion {
    /// Embedding-units per pixel (the paper's ρ = 0.5 default).
    pub rho: f32,
    pub min_grid: usize,
    pub max_grid: usize,
    /// Grid size used on the last iteration (observable for tests/benches).
    pub last_grid: usize,
    /// How the texture is computed (default: exact gather).
    pub backend: Box<dyn FieldBackend + Send>,
}

impl Default for FieldRepulsion {
    fn default() -> Self {
        Self::with_backend(Box::new(GatherBackend))
    }
}

impl FieldRepulsion {
    pub fn with_backend(backend: Box<dyn FieldBackend + Send>) -> Self {
        Self { rho: 0.5, min_grid: 32, max_grid: 512, last_grid: 0, backend }
    }

    /// The ρ policy: G ≈ diameter / ρ, clamped.
    pub fn choose_grid(&self, diameter: f32) -> usize {
        let g = (diameter / self.rho).ceil() as usize;
        g.clamp(self.min_grid, self.max_grid)
    }

    /// A same-configuration repulsion with cold backend caches — how the
    /// engines stamp out per-session scratch (sessions own their FFT
    /// plans/kernel caches; cold caches recompute identical values).
    pub fn fresh(&self) -> Self {
        Self {
            rho: self.rho,
            min_grid: self.min_grid,
            max_grid: self.max_grid,
            last_grid: 0,
            backend: self.backend.fresh(),
        }
    }
}

impl Repulsion for FieldRepulsion {
    fn compute(&mut self, y: &[f32], num: &mut [f32]) -> f64 {
        let n = y.len() / 2;
        let bbox = bbox_of(y);
        let diameter = (bbox[2] - bbox[0]).max(bbox[3] - bbox[1]);
        let grid = self.choose_grid(diameter);
        self.last_grid = grid;
        let (origin, pixel) = grid_placement(bbox, grid);
        let tex = self.backend.compute(y, Placement { origin, pixel }, grid);
        // Query: Ẑ = Σ (S(y_i) − 1). The gradient's repulsion numerator is
        // Σ_j t²(y_i − y_j) = −V(y_i) (Eq. 11 defines V with y_j − p; the
        // paper's Eq. 14 sign is an erratum — see model.py).
        let mut z = 0.0f64;
        for i in 0..n {
            let svv = tex.sample(y[2 * i], y[2 * i + 1]);
            z += (svv[0] - 1.0) as f64;
            num[2 * i] = -svv[1];
            num[2 * i + 1] = -svv[2];
        }
        z
    }
}

/// The field-based CPU engine (the paper's algorithm, host-side, exact
/// gather fields).
#[derive(Default)]
pub struct FieldCpu {
    pub rep: FieldRepulsion,
}

impl Engine for FieldCpu {
    fn name(&self) -> &'static str {
        "fieldcpu"
    }

    fn begin(
        &mut self,
        p: Arc<SparseP>,
        params: &OptParams,
    ) -> anyhow::Result<Box<dyn EmbeddingSession>> {
        Ok(GdSession::boxed("fieldcpu", p, params, Box::new(self.rep.fresh())))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embed::exact::ExactRepulsion;
    use crate::util::rng::Rng;

    fn random_y(n: usize, seed: u64, spread: f32) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..2 * n).map(|_| rng.gauss_f32(0.0, spread)).collect()
    }

    #[test]
    fn fields_converge_to_exact_repulsion_with_resolution() {
        let n = 100;
        let y = random_y(n, 1, 2.0);
        let mut exact = vec![0.0f32; 2 * n];
        let z_exact = ExactRepulsion.compute(&y, &mut exact);

        let mut errs = Vec::new();
        for grid in [32usize, 128, 512] {
            let mut rep = FieldRepulsion { min_grid: grid, max_grid: grid, ..Default::default() };
            let mut num = vec![0.0f32; 2 * n];
            let z = rep.compute(&y, &mut num);
            let zerr = (z - z_exact).abs() / z_exact;
            let mut ferr = 0.0f32;
            for i in 0..2 * n {
                ferr = ferr.max((num[i] - exact[i]).abs());
            }
            errs.push((zerr, ferr));
        }
        // Monotone improvement and tight at G=512.
        assert!(errs[2].0 < errs[0].0, "Z err must shrink: {errs:?}");
        assert!(errs[2].0 < 5e-3, "Z err at G=512: {}", errs[2].0);
        assert!(errs[2].1 < 0.02, "force err at G=512: {}", errs[2].1);
    }

    #[test]
    fn rho_policy_scales_grid_with_diameter() {
        let rep = FieldRepulsion::default();
        assert_eq!(rep.choose_grid(10.0), 32); // clamped at min
        assert_eq!(rep.choose_grid(100.0), 200);
        assert_eq!(rep.choose_grid(1e6), 512); // clamped at max
    }

    #[test]
    fn backend_swap_changes_math_not_contract() {
        // Gather and FFT backends plugged into the same adapter agree.
        let n = 150;
        let y = random_y(n, 9, 4.0);
        let mut num_a = vec![0.0f32; 2 * n];
        let mut num_b = vec![0.0f32; 2 * n];
        let mut rep_a = FieldRepulsion { min_grid: 64, max_grid: 64, ..Default::default() };
        let mut rep_b = FieldRepulsion {
            min_grid: 64,
            max_grid: 64,
            ..FieldRepulsion::with_backend(Box::new(crate::field::conv::FftBackend::new()))
        };
        let za = rep_a.compute(&y, &mut num_a);
        let zb = rep_b.compute(&y, &mut num_b);
        assert!((za - zb).abs() < 0.01 * za.abs().max(1.0), "Z: {za} vs {zb}");
        let scale = num_a.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-6);
        for i in 0..2 * n {
            assert!(
                (num_a[i] - num_b[i]).abs() < 0.01 * scale,
                "num[{i}]: {} vs {}",
                num_a[i],
                num_b[i]
            );
        }
    }
}
