//! CPU implementation of the paper's field-based repulsion (DESIGN.md
//! S13) — the same mathematics as the L1 Pallas kernel (compute-shader
//! formulation, §5.2: exact kernels at pixel centres, unbounded support),
//! with the paper's adaptive-resolution policy ρ = diameter/G applied
//! continuously rather than over a discrete artifact set.
//!
//! Serves three roles: the test oracle the GPGPU engine is validated
//! against, the no-artifact fallback engine, and the reference point for
//! the ablation benches (grid resolution, splat-vs-gather).

use super::common::{run_gd_loop, Control, Engine, IterStats, OptParams, Repulsion};
use crate::hd::SparseP;
use crate::util::parallel;

/// Margin in pixels around the bbox (matches `model.GRID_MARGIN_PX`).
const GRID_MARGIN_PX: f32 = 1.5;

/// The field texture: S, V_x, V_y on a G×G grid plus its placement.
pub struct FieldTexture {
    pub grid: usize,
    pub origin: [f32; 2],
    pub pixel: f32,
    /// Channel-major `(3, G, G)`: S, Vx, Vy.
    pub tex: Vec<f32>,
}

/// Square grid placement covering `bbox` with margin (mirrors
/// `python/compile/model.py::grid_placement`).
pub fn grid_placement(bbox: [f32; 4], grid: usize) -> ([f32; 2], f32) {
    let g = grid as f32;
    let span = (bbox[2] - bbox[0]).max(bbox[3] - bbox[1]).max(1e-3);
    let pixel = span / (g - 2.0 * GRID_MARGIN_PX);
    let cx = 0.5 * (bbox[0] + bbox[2]);
    let cy = 0.5 * (bbox[1] + bbox[3]);
    let half = 0.5 * g * pixel;
    ([cx - half, cy - half], pixel)
}

/// Evaluate the fields exactly at every pixel centre (Eq. 10/11), i.e.
/// the compute-shader / gather formulation with unbounded support.
/// Threaded over pixel rows.
pub fn compute_fields(y: &[f32], origin: [f32; 2], pixel: f32, grid: usize) -> Vec<f32> {
    let n = y.len() / 2;
    let mut tex = vec![0.0f32; 3 * grid * grid];
    let plane = grid * grid;
    {
        let slots = parallel::SyncSlice::new(&mut tex);
        parallel::par_chunks(grid, 4, |rows| {
            for r in rows {
                let py = origin[1] + (r as f32 + 0.5) * pixel;
                for c in 0..grid {
                    let px = origin[0] + (c as f32 + 0.5) * pixel;
                    let (mut s, mut vx, mut vy) = (0.0f32, 0.0f32, 0.0f32);
                    for i in 0..n {
                        let dx = y[2 * i] - px;
                        let dy = y[2 * i + 1] - py;
                        let t = 1.0 / (1.0 + dx * dx + dy * dy);
                        s += t;
                        let t2 = t * t;
                        vx += t2 * dx;
                        vy += t2 * dy;
                    }
                    unsafe {
                        *slots.get_mut(r * grid + c) = s;
                        *slots.get_mut(plane + r * grid + c) = vx;
                        *slots.get_mut(2 * plane + r * grid + c) = vy;
                    }
                }
            }
        });
    }
    tex
}

/// Bounded-support splat-style field accumulation — the paper's §5.1.2
/// rasterisation variant: each point only touches pixels within `support`
/// embedding-units (the texture-quad footprint). Kept for the ablation
/// bench (accuracy/speed vs the unbounded gather above).
pub fn compute_fields_splat(
    y: &[f32],
    origin: [f32; 2],
    pixel: f32,
    grid: usize,
    support: f32,
) -> Vec<f32> {
    let n = y.len() / 2;
    let mut tex = vec![0.0f32; 3 * grid * grid];
    let plane = grid * grid;
    let rad_px = (support / pixel).ceil() as isize;
    for i in 0..n {
        let (yx, yy) = (y[2 * i], y[2 * i + 1]);
        let ci = (((yy - origin[1]) / pixel) - 0.5).round() as isize;
        let cj = (((yx - origin[0]) / pixel) - 0.5).round() as isize;
        for r in (ci - rad_px).max(0)..=(ci + rad_px).min(grid as isize - 1) {
            let py = origin[1] + (r as f32 + 0.5) * pixel;
            for c in (cj - rad_px).max(0)..=(cj + rad_px).min(grid as isize - 1) {
                let px = origin[0] + (c as f32 + 0.5) * pixel;
                let dx = yx - px;
                let dy = yy - py;
                let d2 = dx * dx + dy * dy;
                if d2 > support * support {
                    continue;
                }
                let t = 1.0 / (1.0 + d2);
                let idx = (r as usize) * grid + c as usize;
                tex[idx] += t;
                let t2 = t * t;
                tex[plane + idx] += t2 * dx;
                tex[2 * plane + idx] += t2 * dy;
            }
        }
    }
    tex
}

/// Bilinear sample of the 3-channel texture at `(x, y)` (mirrors
/// `ref.bilinear_ref`): returns (S, Vx, Vy).
#[inline]
pub fn bilinear(tex: &[f32], grid: usize, origin: [f32; 2], pixel: f32, x: f32, y: f32) -> [f32; 3] {
    let plane = grid * grid;
    let u = ((x - origin[0]) / pixel - 0.5).clamp(0.0, grid as f32 - 1.000001);
    let v = ((y - origin[1]) / pixel - 0.5).clamp(0.0, grid as f32 - 1.000001);
    let j0 = (u.floor() as usize).min(grid - 2);
    let i0 = (v.floor() as usize).min(grid - 2);
    let fu = u - j0 as f32;
    let fv = v - i0 as f32;
    let mut out = [0.0f32; 3];
    for (ch, o) in out.iter_mut().enumerate() {
        let base = ch * plane;
        let f00 = tex[base + i0 * grid + j0];
        let f01 = tex[base + i0 * grid + j0 + 1];
        let f10 = tex[base + (i0 + 1) * grid + j0];
        let f11 = tex[base + (i0 + 1) * grid + j0 + 1];
        let top = f00 * (1.0 - fu) + f01 * fu;
        let bot = f10 * (1.0 - fu) + f11 * fu;
        *o = top * (1.0 - fv) + bot * fv;
    }
    out
}

/// Field-based repulsion with the continuous adaptive-ρ policy.
pub struct FieldRepulsion {
    /// Embedding-units per pixel (the paper's ρ = 0.5 default).
    pub rho: f32,
    pub min_grid: usize,
    pub max_grid: usize,
    /// Grid size used on the last iteration (observable for tests/benches).
    pub last_grid: usize,
}

impl Default for FieldRepulsion {
    fn default() -> Self {
        Self { rho: 0.5, min_grid: 32, max_grid: 512, last_grid: 0 }
    }
}

impl FieldRepulsion {
    /// The ρ policy: G ≈ diameter / ρ, clamped.
    pub fn choose_grid(&self, diameter: f32) -> usize {
        let g = (diameter / self.rho).ceil() as usize;
        g.clamp(self.min_grid, self.max_grid)
    }
}

impl Repulsion for FieldRepulsion {
    fn compute(&mut self, y: &[f32], num: &mut [f32]) -> f64 {
        let n = y.len() / 2;
        let mut bbox = [f32::INFINITY, f32::INFINITY, f32::NEG_INFINITY, f32::NEG_INFINITY];
        for i in 0..n {
            bbox[0] = bbox[0].min(y[2 * i]);
            bbox[1] = bbox[1].min(y[2 * i + 1]);
            bbox[2] = bbox[2].max(y[2 * i]);
            bbox[3] = bbox[3].max(y[2 * i + 1]);
        }
        let diameter = (bbox[2] - bbox[0]).max(bbox[3] - bbox[1]);
        let grid = self.choose_grid(diameter);
        self.last_grid = grid;
        let (origin, pixel) = grid_placement(bbox, grid);
        let tex = compute_fields(y, origin, pixel, grid);
        // Query: Ẑ = Σ (S(y_i) − 1). The gradient's repulsion numerator is
        // Σ_j t²(y_i − y_j) = −V(y_i) (Eq. 11 defines V with y_j − p; the
        // paper's Eq. 14 sign is an erratum — see model.py).
        let mut z = 0.0f64;
        for i in 0..n {
            let svv = bilinear(&tex, grid, origin, pixel, y[2 * i], y[2 * i + 1]);
            z += (svv[0] - 1.0) as f64;
            num[2 * i] = -svv[1];
            num[2 * i + 1] = -svv[2];
        }
        z
    }
}

/// The field-based CPU engine (the paper's algorithm, host-side).
#[derive(Default)]
pub struct FieldCpu {
    pub rep: FieldRepulsion,
}

impl Engine for FieldCpu {
    fn name(&self) -> &'static str {
        "fieldcpu"
    }

    fn run(
        &mut self,
        p: &SparseP,
        params: &OptParams,
        observer: Option<&mut dyn FnMut(&IterStats, &[f32]) -> Control>,
    ) -> anyhow::Result<Vec<f32>> {
        run_gd_loop("fieldcpu", &mut self.rep, p, params, observer)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embed::exact::ExactRepulsion;
    use crate::util::rng::Rng;

    fn random_y(n: usize, seed: u64, spread: f32) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..2 * n).map(|_| rng.gauss_f32(0.0, spread)).collect()
    }

    #[test]
    fn fields_converge_to_exact_repulsion_with_resolution() {
        let n = 100;
        let y = random_y(n, 1, 2.0);
        let mut exact = vec![0.0f32; 2 * n];
        let z_exact = ExactRepulsion.compute(&y, &mut exact);

        let mut errs = Vec::new();
        for grid in [32usize, 128, 512] {
            let mut rep = FieldRepulsion { min_grid: grid, max_grid: grid, ..Default::default() };
            let mut num = vec![0.0f32; 2 * n];
            let z = rep.compute(&y, &mut num);
            let zerr = (z - z_exact).abs() / z_exact;
            let mut ferr = 0.0f32;
            for i in 0..2 * n {
                ferr = ferr.max((num[i] - exact[i]).abs());
            }
            errs.push((zerr, ferr));
        }
        // Monotone improvement and tight at G=512.
        assert!(errs[2].0 < errs[0].0, "Z err must shrink: {errs:?}");
        assert!(errs[2].0 < 5e-3, "Z err at G=512: {}", errs[2].0);
        assert!(errs[2].1 < 0.02, "force err at G=512: {}", errs[2].1);
    }

    #[test]
    fn splat_with_wide_support_matches_gather() {
        let n = 60;
        let y = random_y(n, 2, 1.0);
        let bbox = {
            let mut b = [f32::INFINITY, f32::INFINITY, f32::NEG_INFINITY, f32::NEG_INFINITY];
            for i in 0..n {
                b[0] = b[0].min(y[2 * i]);
                b[1] = b[1].min(y[2 * i + 1]);
                b[2] = b[2].max(y[2 * i]);
                b[3] = b[3].max(y[2 * i + 1]);
            }
            b
        };
        let grid = 64;
        let (origin, pixel) = grid_placement(bbox, grid);
        let a = compute_fields(&y, origin, pixel, grid);
        let b = compute_fields_splat(&y, origin, pixel, grid, 1e6);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    }

    #[test]
    fn splat_with_narrow_support_underestimates_s() {
        let n = 40;
        let y = random_y(n, 3, 1.0);
        let grid = 32;
        let (origin, pixel) = grid_placement([-3.0, -3.0, 3.0, 3.0], grid);
        let full = compute_fields(&y, origin, pixel, grid);
        let cut = compute_fields_splat(&y, origin, pixel, grid, 0.5);
        let s_full: f32 = full[..grid * grid].iter().sum();
        let s_cut: f32 = cut[..grid * grid].iter().sum();
        assert!(s_cut < s_full, "bounded support must lose mass");
        assert!(s_cut > 0.0);
    }

    #[test]
    fn rho_policy_scales_grid_with_diameter() {
        let rep = FieldRepulsion::default();
        assert_eq!(rep.choose_grid(10.0), 32); // clamped at min
        assert_eq!(rep.choose_grid(100.0), 200);
        assert_eq!(rep.choose_grid(1e6), 512); // clamped at max
    }

    #[test]
    fn bilinear_matches_python_convention() {
        // Exact at pixel centres.
        let grid = 4;
        let mut tex = vec![0.0f32; 3 * 16];
        tex[1 * 16 + 2 * 4 + 1] = 7.0; // Vx at (row 2, col 1)
        let origin = [0.0f32, 0.0];
        let pixel = 1.0;
        let out = bilinear(&tex, grid, origin, pixel, 1.5, 2.5);
        assert!((out[1] - 7.0).abs() < 1e-6);
        // Halfway to the next column: linear halving.
        let out = bilinear(&tex, grid, origin, pixel, 2.0, 2.5);
        assert!((out[1] - 3.5).abs() < 1e-6);
    }
}
