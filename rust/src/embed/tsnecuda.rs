//! Simulated t-SNE-CUDA comparator (Chan et al. [7]; DESIGN.md S15, §7).
//!
//! t-SNE-CUDA is a CUDA re-implementation of the BH-SNE force core on top
//! of FAISS kNN; its *embedding quality* is therefore the BH quality at
//! the chosen θ (the paper's own framing: "an acceleration based on the
//! approximation of BH-SNE"). We reproduce quality exactly by running our
//! BH force core, and report its *wall time* through a calibrated GPU
//! speed model: the paper measures t-SNE-CUDA 2–5× faster than GPGPU-SNE
//! and ~3× on the full ImageNet datasets, so the bench harness divides
//! the measured BH CPU time by a documented speedup envelope rather than
//! pretending a CUDA device exists. Both numbers (measured CPU, modelled
//! GPU) are printed; EXPERIMENTS.md reports the substitution.

use std::sync::Arc;

use super::bh::BhRepulsion;
use super::common::{EmbeddingSession, Engine, GdSession, OptParams};
use crate::hd::SparseP;

/// Speedup of t-SNE-CUDA over our *measured BH-SNE θ=0.5 CPU time*,
/// calibrated from the paper's Fig. 6: BH θ=0.5 takes ~8 min on MNIST
/// where t-SNE-CUDA takes a few seconds — a ~100× envelope (GTX Titan,
/// 2688 cores vs 8 CPU threads).
pub const GPU_SPEEDUP_MODEL: f64 = 100.0;

pub struct TsneCudaSim {
    theta: f32,
    name: &'static str,
}

impl TsneCudaSim {
    pub fn new(theta: f32) -> Self {
        let name = if theta <= 0.05 { "tsne-cuda-0.0" } else { "tsne-cuda-0.5" };
        Self { theta, name }
    }

    pub fn theta(&self) -> f32 {
        self.theta
    }

    /// Modelled GPU wall time from a measured CPU wall time.
    pub fn modelled_time(cpu_seconds: f64) -> f64 {
        cpu_seconds / GPU_SPEEDUP_MODEL
    }
}

impl Engine for TsneCudaSim {
    fn name(&self) -> &'static str {
        self.name
    }

    fn begin(
        &mut self,
        p: Arc<SparseP>,
        params: &OptParams,
    ) -> anyhow::Result<Box<dyn EmbeddingSession>> {
        // Quality path: identical to BH at this θ (by construction —
        // that IS the simulation, per DESIGN.md §7).
        Ok(GdSession::boxed(self.name, p, params, Box::new(BhRepulsion::new(self.theta))))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embed::bh::BarnesHut;
    use crate::hd::sparse::Csr;

    fn ring_p(n: usize) -> SparseP {
        let k = 2;
        let mut col = Vec::new();
        let mut val = Vec::new();
        for i in 0..n {
            for j in 1..=k {
                col.push(((i + j) % n) as u32);
                val.push(1.0 / (n * k) as f32);
            }
        }
        SparseP { csr: Csr::from_rows(n, n, k, col, val), perplexity: k as f32 }
    }

    #[test]
    fn quality_identical_to_bh_same_theta_and_seed() {
        let p = ring_p(50);
        let params = OptParams { iters: 40, ..Default::default() };
        let a = TsneCudaSim::new(0.5).run(&p, &params, None).unwrap();
        let b = BarnesHut::new(0.5).run(&p, &params, None).unwrap();
        assert_eq!(a, b, "simulated t-SNE-CUDA must be bit-identical to BH quality");
    }

    #[test]
    fn speed_model_documented_and_applied() {
        assert_eq!(TsneCudaSim::modelled_time(200.0), 2.0);
    }

    #[test]
    fn names() {
        assert_eq!(TsneCudaSim::new(0.0).name(), "tsne-cuda-0.0");
        assert_eq!(TsneCudaSim::new(0.5).name(), "tsne-cuda-0.5");
    }
}
