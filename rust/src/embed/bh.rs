//! Barnes-Hut-SNE (van der Maaten [41]) — the paper's principal baseline
//! (DESIGN.md S12). Repulsion via the quadtree at opening angle θ
//! (θ = 0.5 default speed/accuracy trade-off, θ = 0.1 high quality).

use super::common::{run_gd_loop, Control, Engine, IterStats, OptParams, Repulsion};
use super::quadtree::QuadTree;
use crate::hd::SparseP;
use crate::util::parallel;

/// Quadtree-approximated repulsion (rebuilds the tree every iteration, as
/// BH-SNE must — point positions change each step).
pub struct BhRepulsion {
    pub theta: f32,
}

impl Repulsion for BhRepulsion {
    fn compute(&mut self, y: &[f32], num: &mut [f32]) -> f64 {
        let n = y.len() / 2;
        let tree = QuadTree::build(y);
        let z_total = std::sync::Mutex::new(0.0f64);
        {
            let slots = parallel::SyncSlice::new(num);
            parallel::par_chunks(n, 64, |range| {
                let mut local_z = 0.0f64;
                for i in range {
                    let (fx, fy, z) = tree.accumulate(y[2 * i], y[2 * i + 1], self.theta);
                    // z includes the query's own t(0)=1 (Eq. 13's S−1).
                    local_z += z - 1.0;
                    unsafe {
                        *slots.get_mut(2 * i) = fx as f32;
                        *slots.get_mut(2 * i + 1) = fy as f32;
                    }
                }
                *z_total.lock().unwrap() += local_z;
            });
        }
        z_total.into_inner().unwrap()
    }
}

/// The BH-SNE engine.
pub struct BarnesHut {
    theta: f32,
    name: &'static str,
}

impl BarnesHut {
    pub fn new(theta: f32) -> Self {
        // Static names so Engine::name can return &'static str.
        let name = if theta <= 0.05 {
            "bh-0.0"
        } else if theta <= 0.3 {
            "bh-0.1"
        } else {
            "bh-0.5"
        };
        Self { theta, name }
    }

    pub fn theta(&self) -> f32 {
        self.theta
    }
}

impl Engine for BarnesHut {
    fn name(&self) -> &'static str {
        self.name
    }

    fn run(
        &mut self,
        p: &SparseP,
        params: &OptParams,
        observer: Option<&mut dyn FnMut(&IterStats, &[f32]) -> Control>,
    ) -> anyhow::Result<Vec<f32>> {
        run_gd_loop(&mut BhRepulsion { theta: self.theta }, p, params, observer)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embed::exact::ExactRepulsion;
    use crate::hd::sparse::Csr;
    use crate::util::rng::Rng;

    #[test]
    fn bh_theta0_matches_exact_repulsion() {
        let mut rng = Rng::new(6);
        let n = 150;
        let y: Vec<f32> = (0..2 * n).map(|_| rng.gauss_f32(0.0, 2.0)).collect();
        let mut a = vec![0.0f32; 2 * n];
        let mut b = vec![0.0f32; 2 * n];
        let za = BhRepulsion { theta: 0.0 }.compute(&y, &mut a);
        let zb = ExactRepulsion.compute(&y, &mut b);
        assert!((za - zb).abs() / zb < 1e-5, "Z: {za} vs {zb}");
        for i in 0..2 * n {
            assert!((a[i] - b[i]).abs() < 1e-4 * b[i].abs().max(1e-2), "num[{i}]");
        }
    }

    #[test]
    fn bh_theta05_close_to_exact() {
        let mut rng = Rng::new(9);
        let n = 300;
        let y: Vec<f32> = (0..2 * n).map(|_| rng.gauss_f32(0.0, 3.0)).collect();
        let mut a = vec![0.0f32; 2 * n];
        let mut b = vec![0.0f32; 2 * n];
        let za = BhRepulsion { theta: 0.5 }.compute(&y, &mut a);
        let zb = ExactRepulsion.compute(&y, &mut b);
        assert!((za - zb).abs() / zb < 0.02, "Z rel err: {}", (za - zb).abs() / zb);
    }

    #[test]
    fn bh_engine_reduces_kl() {
        let n = 80;
        let mut col = Vec::new();
        let mut val = Vec::new();
        for i in 0..n {
            for j in 1..=3usize {
                col.push(((i + j) % n) as u32);
                val.push(1.0 / (n * 3) as f32);
            }
        }
        let p = SparseP { csr: Csr::from_rows(n, n, 3, col, val), perplexity: 3.0 };
        let params = OptParams { iters: 120, exaggeration_iters: 30, ..Default::default() };
        let mut first = f64::NAN;
        let mut last = f64::NAN;
        let mut obs = |s: &IterStats, _: &[f32]| {
            if s.iter == 0 {
                first = s.kl_est;
            }
            last = s.kl_est;
            Control::Continue
        };
        BarnesHut::new(0.5).run(&p, &params, Some(&mut obs)).unwrap();
        assert!(last < first, "KL {first} -> {last}");
    }

    #[test]
    fn names_follow_theta() {
        assert_eq!(BarnesHut::new(0.5).name(), "bh-0.5");
        assert_eq!(BarnesHut::new(0.1).name(), "bh-0.1");
        assert_eq!(BarnesHut::new(0.0).name(), "bh-0.0");
    }
}
