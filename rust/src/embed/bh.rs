//! Barnes-Hut-SNE (van der Maaten [41]) — the paper's principal baseline
//! (DESIGN.md S12). Repulsion via the quadtree at opening angle θ
//! (θ = 0.5 default speed/accuracy trade-off, θ = 0.1 high quality).

use std::sync::Arc;

use super::common::{EmbeddingSession, Engine, GdSession, OptParams, Repulsion};
use super::quadtree::QuadTree;
use crate::hd::SparseP;
use crate::util::parallel;

const CHUNK: usize = 64;

/// Quadtree-approximated repulsion. The tree is rebuilt every iteration
/// (BH-SNE must — point positions change each step) but its node storage
/// is session-owned scratch, reused across steps; each worker chunk also
/// reuses one traversal stack across its queries. The Z partials land in
/// chunk-indexed slots and combine in chunk order — deterministic
/// regardless of thread scheduling, so a checkpointed session replays
/// identically on any worker.
pub struct BhRepulsion {
    pub theta: f32,
    /// Reused tree storage (None until the first step).
    tree: Option<QuadTree>,
}

impl BhRepulsion {
    pub fn new(theta: f32) -> Self {
        Self { theta, tree: None }
    }
}

impl Repulsion for BhRepulsion {
    fn compute(&mut self, y: &[f32], num: &mut [f32]) -> f64 {
        let n = y.len() / 2;
        let theta = self.theta;
        let tree = self.tree.get_or_insert_with(QuadTree::empty);
        tree.rebuild(y);
        let tree = &*tree;
        let nchunks = n.div_ceil(CHUNK).max(1);
        let mut z_parts = vec![0.0f64; nchunks];
        {
            let parts = parallel::SyncSlice::new(&mut z_parts);
            let slots = parallel::SyncSlice::new(num);
            parallel::par_chunks(n, CHUNK, |range| {
                let ci = range.start / CHUNK;
                let mut local_z = 0.0f64;
                let mut stack: Vec<u32> = Vec::with_capacity(64);
                for i in range {
                    let (fx, fy, z) =
                        tree.accumulate_with(y[2 * i], y[2 * i + 1], theta, &mut stack);
                    // z includes the query's own t(0)=1 (Eq. 13's S−1).
                    local_z += z - 1.0;
                    unsafe {
                        *slots.get_mut(2 * i) = fx as f32;
                        *slots.get_mut(2 * i + 1) = fy as f32;
                    }
                }
                unsafe {
                    *parts.get_mut(ci) = local_z;
                }
            });
        }
        z_parts.iter().sum()
    }
}

/// The BH-SNE engine.
pub struct BarnesHut {
    theta: f32,
    name: &'static str,
}

impl BarnesHut {
    pub fn new(theta: f32) -> Self {
        // Static names so Engine::name can return &'static str.
        let name = if theta <= 0.05 {
            "bh-0.0"
        } else if theta <= 0.3 {
            "bh-0.1"
        } else {
            "bh-0.5"
        };
        Self { theta, name }
    }

    pub fn theta(&self) -> f32 {
        self.theta
    }
}

impl Engine for BarnesHut {
    fn name(&self) -> &'static str {
        self.name
    }

    fn begin(
        &mut self,
        p: Arc<SparseP>,
        params: &OptParams,
    ) -> anyhow::Result<Box<dyn EmbeddingSession>> {
        Ok(GdSession::boxed(self.name, p, params, Box::new(BhRepulsion::new(self.theta))))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embed::common::{Control, IterStats};
    use crate::embed::exact::ExactRepulsion;
    use crate::hd::sparse::Csr;
    use crate::util::rng::Rng;

    #[test]
    fn reused_tree_scratch_matches_fresh_build() {
        // A session reuses the quadtree storage across steps; rebuilding
        // into warm scratch must be bit-identical to a cold build, and
        // the chunk-indexed Z must not depend on scheduling.
        let mut rng = Rng::new(11);
        let n = 400;
        let mut rep = BhRepulsion::new(0.5);
        let mut warm = vec![0.0f32; 2 * n];
        let mut cold = vec![0.0f32; 2 * n];
        for round in 0..3 {
            let y: Vec<f32> = (0..2 * n).map(|_| rng.gauss_f32(0.0, 2.0)).collect();
            let zw = rep.compute(&y, &mut warm);
            let zc = BhRepulsion::new(0.5).compute(&y, &mut cold);
            assert_eq!(zw, zc, "round {round}");
            assert_eq!(warm, cold, "round {round}");
        }
    }

    #[test]
    fn bh_theta0_matches_exact_repulsion() {
        let mut rng = Rng::new(6);
        let n = 150;
        let y: Vec<f32> = (0..2 * n).map(|_| rng.gauss_f32(0.0, 2.0)).collect();
        let mut a = vec![0.0f32; 2 * n];
        let mut b = vec![0.0f32; 2 * n];
        let za = BhRepulsion::new(0.0).compute(&y, &mut a);
        let zb = ExactRepulsion.compute(&y, &mut b);
        assert!((za - zb).abs() / zb < 1e-5, "Z: {za} vs {zb}");
        for i in 0..2 * n {
            assert!((a[i] - b[i]).abs() < 1e-4 * b[i].abs().max(1e-2), "num[{i}]");
        }
    }

    #[test]
    fn bh_theta05_close_to_exact() {
        let mut rng = Rng::new(9);
        let n = 300;
        let y: Vec<f32> = (0..2 * n).map(|_| rng.gauss_f32(0.0, 3.0)).collect();
        let mut a = vec![0.0f32; 2 * n];
        let mut b = vec![0.0f32; 2 * n];
        let za = BhRepulsion::new(0.5).compute(&y, &mut a);
        let zb = ExactRepulsion.compute(&y, &mut b);
        assert!((za - zb).abs() / zb < 0.02, "Z rel err: {}", (za - zb).abs() / zb);
    }

    #[test]
    fn bh_engine_reduces_kl() {
        let n = 80;
        let mut col = Vec::new();
        let mut val = Vec::new();
        for i in 0..n {
            for j in 1..=3usize {
                col.push(((i + j) % n) as u32);
                val.push(1.0 / (n * 3) as f32);
            }
        }
        let p = SparseP { csr: Csr::from_rows(n, n, 3, col, val), perplexity: 3.0 };
        let params = OptParams { iters: 120, exaggeration_iters: 30, ..Default::default() };
        let mut first = f64::NAN;
        let mut last = f64::NAN;
        let mut obs = |s: &IterStats, _: &[f32]| {
            if s.iter == 0 {
                first = s.kl_est;
            }
            last = s.kl_est;
            Control::Continue
        };
        BarnesHut::new(0.5).run(&p, &params, Some(&mut obs)).unwrap();
        assert!(last < first, "KL {first} -> {last}");
    }

    #[test]
    fn names_follow_theta() {
        assert_eq!(BarnesHut::new(0.5).name(), "bh-0.5");
        assert_eq!(BarnesHut::new(0.1).name(), "bh-0.1");
        assert_eq!(BarnesHut::new(0.0).name(), "bh-0.0");
    }
}
