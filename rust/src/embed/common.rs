//! Shared gradient-descent machinery (DESIGN.md S16): the van der Maaten
//! update rule (gains, momentum), the early-exaggeration and momentum
//! schedules the paper's evaluation uses, the engine trait, and the
//! *stepwise session* machinery every engine runs through.
//!
//! The paper's headline is interactive minimisation — watching the
//! embedding evolve and steering it live (Fig. 1, the A-tSNE lineage).
//! The unit of optimisation is therefore not a run but a *session*
//! ([`EmbeddingSession`]): an object owning the optimiser state
//! ([`GdState`]) plus all engine scratch (force buffers, FFT plans,
//! quadtrees, device tensors) that advances one iteration per
//! [`EmbeddingSession::step`] call. Sessions can be paused (just stop
//! calling `step`), resumed, re-parameterised mid-run
//! ([`EmbeddingSession::set_params`]), warm-started from an existing
//! layout ([`EmbeddingSession::warm_start`]) and checkpointed to bytes
//! ([`Checkpoint`]) — the coordinator's cooperative scheduler time-slices
//! many such sessions over a small worker pool. [`Engine::run`] survives
//! as a thin convenience loop over a session ([`run_session`]), so batch
//! callers and benches are unchanged.
//!
//! The checkpoint byte codec ([`Checkpoint::to_bytes`] /
//! [`Checkpoint::from_bytes`]) is the durability currency of the whole
//! system: the TCP protocol frames it in base64 (`checkpoint` /
//! `submit.resume_from`), the service journal persists it per running
//! job, and `coordinator::store` wraps it in checksummed records. Its
//! tensors are engine-agnostic; engine-specific extras (the gpgpu
//! grid-policy hysteresis, [`GridCheckpoint`]) ride in a versioned
//! extension block, so restores are bit-identical on the device path
//! too and legacy (v1) blobs stay readable.

use std::sync::Arc;

use crate::hd::SparseP;
use crate::util::parallel::{self, SyncSlice};
use crate::util::rng::Rng;
use crate::util::simd::{self, GdArgs, GdPartial};

/// Optimisation hyperparameters (HDI defaults, §6 of the paper).
#[derive(Debug, Clone)]
pub struct OptParams {
    pub iters: usize,
    pub eta: f32,
    pub momentum0: f32,
    pub momentum1: f32,
    /// Iteration at which momentum switches 0.5 → 0.8.
    pub momentum_switch: usize,
    /// Early-exaggeration multiplier on P.
    pub exaggeration: f32,
    /// Iterations during which exaggeration applies.
    pub exaggeration_iters: usize,
    pub seed: u64,
    /// Initial embedding std-dev.
    pub init_std: f32,
}

impl Default for OptParams {
    fn default() -> Self {
        Self {
            iters: 1000,
            eta: 200.0,
            momentum0: 0.5,
            momentum1: 0.8,
            momentum_switch: 250,
            exaggeration: 12.0,
            exaggeration_iters: 250,
            seed: 42,
            init_std: 0.1,
        }
    }
}

impl OptParams {
    pub fn momentum_at(&self, iter: usize) -> f32 {
        if iter < self.momentum_switch {
            self.momentum0
        } else {
            self.momentum1
        }
    }

    pub fn exaggeration_at(&self, iter: usize) -> f32 {
        if iter < self.exaggeration_iters {
            self.exaggeration
        } else {
            1.0
        }
    }
}

/// Per-iteration statistics delivered to observers.
#[derive(Debug, Clone, Copy)]
pub struct IterStats {
    pub iter: usize,
    /// Neighbour-restricted KL estimate (comparable across engines).
    pub kl_est: f64,
    /// Normalisation term (exact or field-estimated Z).
    pub z: f64,
    /// Embedding diameter (bbox max side).
    pub diameter: f32,
    pub elapsed_s: f64,
    /// This step's attractive-force pass, seconds. Phase timings are
    /// 0.0 when [`crate::obs::enabled`] is off, or when the engine's
    /// step is fused (the device path cannot split phases).
    pub attr_s: f64,
    /// This step's repulsive-field pass (splat·conv·gather or
    /// tree/exact equivalent), seconds.
    pub rep_s: f64,
    /// This step's fused gradient update (gains + momentum + apply),
    /// seconds.
    pub grad_s: f64,
}

/// Observer verdict: keep optimising or stop early (the A-tSNE
/// user-driven early termination the coordinator exposes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Control {
    Continue,
    Stop,
}

/// Serialisable optimiser state: everything a session needs to resume an
/// optimisation exactly where it left off, on this process or another.
/// The tensors are engine-agnostic (positions, velocity, gains), so a
/// checkpoint taken from one engine can be restored into any other whose
/// state length matches — e.g. rough in early iterations on a cheap
/// engine and hand off to a precise one.
///
/// For the device engine the vectors are the *padded* bucket tensors
/// (restore validates the length either way), and `grid` carries the
/// adaptive-resolution policy's hysteresis state so a restored device
/// session replays **bit-identically** — without it the restored session
/// re-derives its grid from the positions alone and can sit on the other
/// side of a hysteresis band, changing the field approximation for the
/// next few iterations. CPU engines leave `grid` as `None`.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    /// Engine that produced the checkpoint (informational).
    pub engine: String,
    /// Next iteration to run (i.e. `iter` steps are already applied).
    pub iter: usize,
    /// Active optimisation seconds accumulated so far.
    pub elapsed_s: f64,
    pub y: Vec<f32>,
    pub vel: Vec<f32>,
    pub gains: Vec<f32>,
    /// Device-engine grid-policy state (see [`GridCheckpoint`]).
    pub grid: Option<GridCheckpoint>,
}

/// The gpgpu engine's adaptive-grid hysteresis state, serialised with
/// the checkpoint (ROADMAP item (f)): everything `GridPolicy` + the
/// session's diameter tracking need to continue exactly where the
/// checkpointed session stopped.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GridCheckpoint {
    /// Embedding diameter as the *device* reported it after the last
    /// step (recomputing it host-side from `y` can differ in the last
    /// ulp, which is enough to flip a grid decision).
    pub diameter: f32,
    /// The grid the hysteresis policy is currently latched on.
    pub current: Option<usize>,
    /// Grid used by the last executed step (switch accounting).
    pub last_grid: usize,
    /// Switches since begin/warm-start (observability counter).
    pub grid_switches: usize,
}

/// v1: engine/iter/elapsed + the three state tensors.
const CHECKPOINT_MAGIC_V1: &[u8; 8] = b"GSNECKP1";
/// v2 appends a length-prefixed extension block (grid-policy state).
const CHECKPOINT_MAGIC_V2: &[u8; 8] = b"GSNECKP2";

/// Extension-block tag for [`GridCheckpoint`].
const EXT_GRID: u8 = 1;

impl Checkpoint {
    /// Compact binary encoding (little-endian; see `from_bytes`): magic,
    /// engine name, iter, elapsed, the three f32 tensors, then a
    /// length-prefixed extension block (empty for CPU engines).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(96 + 12 * self.y.len());
        out.extend_from_slice(CHECKPOINT_MAGIC_V2);
        let name = self.engine.as_bytes();
        out.extend_from_slice(&(name.len() as u64).to_le_bytes());
        out.extend_from_slice(name);
        out.extend_from_slice(&(self.iter as u64).to_le_bytes());
        out.extend_from_slice(&self.elapsed_s.to_le_bytes());
        out.extend_from_slice(&(self.y.len() as u64).to_le_bytes());
        for v in self.y.iter().chain(&self.vel).chain(&self.gains) {
            out.extend_from_slice(&v.to_le_bytes());
        }
        let mut ext = Vec::new();
        if let Some(g) = &self.grid {
            ext.push(EXT_GRID);
            ext.extend_from_slice(&g.diameter.to_le_bytes());
            ext.extend_from_slice(&(g.current.map_or(0, |c| c as u64)).to_le_bytes());
            ext.extend_from_slice(&(g.last_grid as u64).to_le_bytes());
            ext.extend_from_slice(&(g.grid_switches as u64).to_le_bytes());
        }
        out.extend_from_slice(&(ext.len() as u64).to_le_bytes());
        out.extend_from_slice(&ext);
        out
    }

    /// Inverse of [`Self::to_bytes`]; validates magic and lengths.
    /// Accepts both the current (v2) and the legacy v1 framing (v1 blobs
    /// simply carry no extension block, so `grid` restores as `None`).
    pub fn from_bytes(bytes: &[u8]) -> anyhow::Result<Self> {
        struct Cur<'a>(&'a [u8]);
        impl<'a> Cur<'a> {
            fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
                anyhow::ensure!(self.0.len() >= n, "checkpoint truncated");
                let (head, tail) = self.0.split_at(n);
                self.0 = tail;
                Ok(head)
            }
            fn u64(&mut self) -> anyhow::Result<u64> {
                Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
            }
            fn f32(&mut self) -> anyhow::Result<f32> {
                Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
            }
        }
        let mut c = Cur(bytes);
        let magic = c.take(8)?;
        let v2 = magic == CHECKPOINT_MAGIC_V2;
        anyhow::ensure!(v2 || magic == CHECKPOINT_MAGIC_V1, "not a gpgpu-sne checkpoint");
        let name_len = c.u64()? as usize;
        anyhow::ensure!(name_len <= 256, "implausible engine-name length {name_len}");
        let engine = String::from_utf8(c.take(name_len)?.to_vec())?;
        let iter = c.u64()? as usize;
        let elapsed_s = f64::from_le_bytes(c.take(8)?.try_into().unwrap());
        let len = c.u64()? as usize;
        // Bound before multiplying so a corrupt header cannot overflow
        // the size arithmetic or drive a huge allocation.
        anyhow::ensure!(len <= bytes.len() / 4, "implausible state length {len}");
        anyhow::ensure!(
            bytes.len() >= 8 + 8 + name_len + 24 + 12 * len,
            "checkpoint truncated: state length {len}"
        );
        let mut f32s = |out: &mut Vec<f32>| -> anyhow::Result<()> {
            out.reserve(len);
            for _ in 0..len {
                out.push(f32::from_le_bytes(c.take(4)?.try_into().unwrap()));
            }
            Ok(())
        };
        let (mut y, mut vel, mut gains) = (Vec::new(), Vec::new(), Vec::new());
        f32s(&mut y)?;
        f32s(&mut vel)?;
        f32s(&mut gains)?;
        let grid = if v2 {
            let ext_len = c.u64()? as usize;
            let mut ext = Cur(c.take(ext_len)?);
            if ext_len == 0 {
                None
            } else {
                anyhow::ensure!(ext.take(1)?[0] == EXT_GRID, "unknown checkpoint extension");
                let diameter = ext.f32()?;
                let current = match ext.u64()? as usize {
                    0 => None,
                    g => Some(g),
                };
                let last_grid = ext.u64()? as usize;
                let grid_switches = ext.u64()? as usize;
                Some(GridCheckpoint { diameter, current, last_grid, grid_switches })
            }
        } else {
            None
        };
        Ok(Self { engine, iter, elapsed_s, y, vel, gains, grid })
    }
}

/// A live, stepwise embedding optimisation: owns the optimiser state and
/// every piece of engine scratch, and advances one gradient-descent
/// iteration per `step()`. Pausing is simply not calling `step`; the
/// session stays valid indefinitely and resumes exactly where it stopped.
pub trait EmbeddingSession: Send {
    /// Name of the engine driving this session.
    fn engine_name(&self) -> &'static str;

    /// Next iteration index (number of steps applied so far).
    fn iter(&self) -> usize;

    /// True once `iter() >= params().iters` — `step` would error.
    fn is_done(&self) -> bool {
        self.iter() >= self.params().iters
    }

    /// Advance one iteration; returns its statistics. Errors once the
    /// session is done (extend with `set_params` to keep going).
    fn step(&mut self) -> anyhow::Result<IterStats>;

    /// Current `(n, 2)` row-major embedding (real points only).
    fn positions(&self) -> &[f32];

    /// Current optimisation hyperparameters.
    fn params(&self) -> &OptParams;

    /// Replace the hyperparameters mid-run: eta / exaggeration /
    /// momentum changes apply from the next step; raising `iters`
    /// extends a finished session. `seed`/`init_std` have no effect
    /// after initialisation.
    fn set_params(&mut self, params: OptParams);

    /// Re-embed from an existing `(n, 2)` layout: positions are
    /// replaced, velocity and gains reset, and the iteration counter
    /// rewinds to 0 (set `exaggeration_iters: 0` via [`Self::set_params`]
    /// first to resume without a second exaggeration phase).
    fn warm_start(&mut self, y0: &[f32]) -> anyhow::Result<()>;

    /// Snapshot the full optimiser state.
    fn checkpoint(&self) -> Checkpoint;

    /// Restore a previously captured state (lengths must match this
    /// session's problem size). The stored hyperparameters are NOT part
    /// of the checkpoint — the session keeps its own.
    fn restore(&mut self, ck: &Checkpoint) -> anyhow::Result<()>;

    /// Stats of the most recent step, if any ran.
    fn last_stats(&self) -> Option<IterStats>;
}

/// An embedding optimiser.
pub trait Engine: Send {
    fn name(&self) -> &'static str;

    /// Start a stepwise optimisation session over `p`. The session owns
    /// its state and scratch; the engine can begin further independent
    /// sessions.
    ///
    /// # Quickstart
    ///
    /// Dataset → kNN → P → session; step it, checkpoint it, restore the
    /// checkpoint into a fresh session and get the same positions back:
    ///
    /// ```
    /// use std::sync::Arc;
    /// use gpgpu_sne::embed::{self, Checkpoint, OptParams};
    /// use gpgpu_sne::hd::{backend, perplexity};
    ///
    /// # fn main() -> anyhow::Result<()> {
    /// let data = gpgpu_sne::data::by_name("gaussians", 80, 1)?;
    /// let knn = backend::by_name("brute")?.knn(&data, 15, 1);
    /// let p = Arc::new(perplexity::joint_p(&knn, 5.0));
    ///
    /// let params = OptParams { iters: 20, exaggeration_iters: 5, ..Default::default() };
    /// let mut engine = embed::by_name("bh-0.5", None)?;
    /// let mut session = engine.begin(p.clone(), &params)?;
    /// while session.iter() < 10 {
    ///     session.step()?;
    /// }
    ///
    /// // Serialise the optimiser state, restore it elsewhere, resume.
    /// let blob = session.checkpoint().to_bytes();
    /// let mut resumed = engine.begin(p, &params)?;
    /// resumed.restore(&Checkpoint::from_bytes(&blob)?)?;
    /// assert_eq!(resumed.iter(), 10);
    /// assert_eq!(resumed.positions(), session.positions());
    /// # Ok(())
    /// # }
    /// ```
    fn begin(
        &mut self,
        p: Arc<SparseP>,
        params: &OptParams,
    ) -> anyhow::Result<Box<dyn EmbeddingSession>>;

    /// Minimise KL(P||Q); returns the final `(n, 2)` embedding.
    /// The observer (if any) sees every iteration and can stop the run.
    ///
    /// This is a convenience loop over [`Engine::begin`] — stepping a
    /// session to completion is bit-identical (pinned by the
    /// `session_conformance` suite). It clones `p` once into an `Arc`
    /// (an O(N·k) copy, orders of magnitude under the optimisation it
    /// fronts); callers that already hold an `Arc<SparseP>` or run many
    /// sessions over one P should use [`Engine::begin`] +
    /// [`run_session`] directly, as the coordinator does.
    fn run(
        &mut self,
        p: &SparseP,
        params: &OptParams,
        observer: Option<&mut dyn FnMut(&IterStats, &[f32]) -> Control>,
    ) -> anyhow::Result<Vec<f32>> {
        let mut session = self.begin(Arc::new(p.clone()), params)?;
        run_session(session.as_mut(), observer)
    }
}

/// Drive a session to completion (or until the observer stops it) and
/// return the final embedding — the classic one-shot `Engine::run`
/// contract, expressed over the stepwise API.
pub fn run_session(
    session: &mut dyn EmbeddingSession,
    mut observer: Option<&mut dyn FnMut(&IterStats, &[f32]) -> Control>,
) -> anyhow::Result<Vec<f32>> {
    while !session.is_done() {
        let stats = session.step()?;
        if let Some(obs) = observer.as_deref_mut() {
            if obs(&stats, session.positions()) == Control::Stop {
                break;
            }
        }
    }
    Ok(session.positions().to_vec())
}

/// Gradient-descent state for the CPU engines.
#[derive(Debug, Clone)]
pub struct GdState {
    pub n: usize,
    pub y: Vec<f32>,
    pub vel: Vec<f32>,
    pub gains: Vec<f32>,
}

// The van der Maaten gain constants live beside the SIMD gradient
// kernel that consumes them; re-exported here for the historical paths.
pub use crate::util::simd::{GAIN_ADD, GAIN_MIN, GAIN_MUL};

/// Points per task of the fused step pass. Partials are indexed by
/// chunk, not by thread, so the reduction is deterministic regardless
/// of scheduling.
const STEP_CHUNK: usize = 2048;

impl GdState {
    /// Random Gaussian initialisation (deterministic in seed).
    pub fn init(n: usize, seed: u64, std: f32) -> Self {
        let mut rng = Rng::new(seed);
        let y = (0..2 * n).map(|_| rng.gauss_f32(0.0, std)).collect();
        Self { n, y, vel: vec![0.0; 2 * n], gains: vec![1.0; 2 * n] }
    }

    /// The fused per-iteration hot path: gradient combine
    /// (`g = 4·(ex·attr − rep/Z)`, Eq. 8), the van der Maaten
    /// gains/momentum update, the recentre mean, and (optionally) the
    /// bounding box — one threaded pass over the points plus an
    /// O(chunks) combine and a threaded mean-subtract, replacing four
    /// serial O(N) sweeps. Arithmetic per element is identical to
    /// [`Self::apply_gradient`] + [`Self::recenter`]; the per-chunk pair
    /// update runs through the dispatched `gd_update` SIMD kernel, which
    /// is bitwise-identical across tiers (see [`crate::util::simd`]).
    ///
    /// Returns the post-recentre bbox when `track_bbox` (observers need
    /// the diameter); headless runs pass `false` and skip the min/max
    /// work entirely.
    pub fn fused_step(
        &mut self,
        attr: &[f32],
        rep: &[f32],
        exaggeration: f32,
        inv_z: f32,
        eta: f32,
        momentum: f32,
        track_bbox: bool,
    ) -> Option<[f32; 4]> {
        let n = self.n;
        debug_assert!(attr.len() >= 2 * n && rep.len() >= 2 * n);
        let nchunks = n.div_ceil(STEP_CHUNK).max(1);
        let kern = simd::kernels().gd_update;
        // n/STEP_CHUNK slots of 24 B — a per-call allocation three orders
        // of magnitude under the pass it fronts, not worth carrying state.
        let mut partials = vec![GdPartial::identity(); nchunks];
        {
            let parts = SyncSlice::new(&mut partials);
            let ys = SyncSlice::new(&mut self.y);
            let vels = SyncSlice::new(&mut self.vel);
            let gains = SyncSlice::new(&mut self.gains);
            parallel::par_chunks(n, STEP_CHUNK, |range| {
                let ci = range.start / STEP_CHUNK;
                let lo = 2 * range.start;
                let len = 2 * (range.end - range.start);
                // SAFETY: chunk ranges are disjoint, so each worker owns
                // its slice of the three state tensors and its partial.
                let part = unsafe {
                    kern(GdArgs {
                        y: ys.slice_mut(lo, len),
                        vel: vels.slice_mut(lo, len),
                        gains: gains.slice_mut(lo, len),
                        attr: &attr[lo..lo + len],
                        rep: &rep[lo..lo + len],
                        exaggeration,
                        inv_z,
                        eta,
                        momentum,
                        track_bbox,
                    })
                };
                unsafe {
                    *parts.get_mut(ci) = part;
                }
            });
        }
        let mut total = GdPartial::identity();
        for p in &partials {
            total.sx += p.sx;
            total.sy += p.sy;
            total.bbox[0] = total.bbox[0].min(p.bbox[0]);
            total.bbox[1] = total.bbox[1].min(p.bbox[1]);
            total.bbox[2] = total.bbox[2].max(p.bbox[2]);
            total.bbox[3] = total.bbox[3].max(p.bbox[3]);
        }
        let cx = (total.sx / n as f64) as f32;
        let cy = (total.sy / n as f64) as f32;
        {
            let ys = SyncSlice::new(&mut self.y);
            parallel::par_chunks(n, STEP_CHUNK, |range| {
                for i in range {
                    unsafe {
                        *ys.get_mut(2 * i) -= cx;
                        *ys.get_mut(2 * i + 1) -= cy;
                    }
                }
            });
        }
        // The bbox was gathered pre-recentre; shifting it by the mean
        // gives the post-recentre box without a second min/max sweep.
        track_bbox.then(|| {
            let b = total.bbox;
            [b[0] - cx, b[1] - cy, b[2] - cx, b[3] - cy]
        })
    }

    /// One van der Maaten update from a gradient; recentres afterwards.
    pub fn apply_gradient(&mut self, grad: &[f32], eta: f32, momentum: f32) {
        debug_assert_eq!(grad.len(), 2 * self.n);
        for i in 0..2 * self.n {
            let g = grad[i];
            let same = g * self.vel[i] > 0.0;
            let gain = if same { self.gains[i] * GAIN_MUL } else { self.gains[i] + GAIN_ADD };
            let gain = gain.max(GAIN_MIN);
            self.gains[i] = gain;
            self.vel[i] = momentum * self.vel[i] - eta * gain * g;
            self.y[i] += self.vel[i];
        }
        self.recenter();
    }

    /// Subtract the mean.
    pub fn recenter(&mut self) {
        let (mut cx, mut cy) = (0.0f64, 0.0f64);
        for i in 0..self.n {
            cx += self.y[2 * i] as f64;
            cy += self.y[2 * i + 1] as f64;
        }
        cx /= self.n as f64;
        cy /= self.n as f64;
        for i in 0..self.n {
            self.y[2 * i] -= cx as f32;
            self.y[2 * i + 1] -= cy as f32;
        }
    }

    /// Bounding box `[min_x, min_y, max_x, max_y]`.
    pub fn bbox(&self) -> [f32; 4] {
        let mut b = [f32::INFINITY, f32::INFINITY, f32::NEG_INFINITY, f32::NEG_INFINITY];
        for i in 0..self.n {
            b[0] = b[0].min(self.y[2 * i]);
            b[1] = b[1].min(self.y[2 * i + 1]);
            b[2] = b[2].max(self.y[2 * i]);
            b[3] = b[3].max(self.y[2 * i + 1]);
        }
        b
    }
}

/// A repulsion approximation: fills `num` with the *numerator*
/// Σ_j t²_ij (y_i − y_j) and returns the normalisation Z = Σ_{k≠l} t_kl
/// estimate. `F_rep = num / Z` (Eq. 8 right term / Eq. 14).
pub trait Repulsion {
    fn compute(&mut self, y: &[f32], num: &mut [f32]) -> f64;
}

/// The stepwise session shared by every CPU engine (exact, BH, simulated
/// t-SNE-CUDA, both field engines). Owns the gradient-descent state and
/// the per-iteration scratch (force buffers plus whatever the repulsion
/// carries: quadtree storage, FFT plans, cached kernel spectra), so a
/// paused session resumes with warm caches and zero re-allocation.
///
/// The per-iteration O(N) tail (gradient combine, gains/momentum update,
/// recentre, bbox) runs through [`GdState::fused_step`] — one threaded
/// pass instead of four serial sweeps.
pub struct GdSession {
    engine_name: &'static str,
    p: Arc<SparseP>,
    params: OptParams,
    state: GdState,
    repulsion: Box<dyn Repulsion + Send>,
    attr: Vec<f32>,
    rep: Vec<f32>,
    iter: usize,
    /// Active optimisation seconds (pauses between steps do not count).
    elapsed_s: f64,
    last_stats: Option<IterStats>,
}

impl GdSession {
    pub fn new(
        engine_name: &'static str,
        p: Arc<SparseP>,
        params: &OptParams,
        repulsion: Box<dyn Repulsion + Send>,
    ) -> Self {
        let n = p.n();
        Self {
            engine_name,
            p,
            params: params.clone(),
            state: GdState::init(n, params.seed, params.init_std),
            repulsion,
            attr: vec![0.0f32; 2 * n],
            rep: vec![0.0f32; 2 * n],
            iter: 0,
            elapsed_s: 0.0,
            last_stats: None,
        }
    }

    /// Boxed constructor (what `Engine::begin` implementations return).
    pub fn boxed(
        engine_name: &'static str,
        p: Arc<SparseP>,
        params: &OptParams,
        repulsion: Box<dyn Repulsion + Send>,
    ) -> Box<dyn EmbeddingSession> {
        Box::new(Self::new(engine_name, p, params, repulsion))
    }
}

impl EmbeddingSession for GdSession {
    fn engine_name(&self) -> &'static str {
        self.engine_name
    }

    fn iter(&self) -> usize {
        self.iter
    }

    fn step(&mut self) -> anyhow::Result<IterStats> {
        anyhow::ensure!(
            self.iter < self.params.iters,
            "session complete at iter {} (extend via set_params)",
            self.iter
        );
        // Per-phase splits are read at most twice more per step than the
        // uninstrumented path (two extra `Instant::now()` calls) and only
        // when observability is on — the `obs` section of micro_hotpath
        // holds the whole delta under 1% of a step.
        let obs_on = crate::obs::enabled();
        let t = std::time::Instant::now();
        let iter = self.iter;
        let ex = self.params.exaggeration_at(iter);
        let (kl_pairs, p_sum) = super::attractive_forces(&self.p, &self.state.y, &mut self.attr);
        let t_attr = if obs_on { t.elapsed().as_secs_f64() } else { 0.0 };
        let z = self.repulsion.compute(&self.state.y, &mut self.rep).max(1e-12);
        let t_rep = if obs_on { t.elapsed().as_secs_f64() } else { 0.0 };
        let inv_z = (1.0 / z) as f32;
        let bbox = self
            .state
            .fused_step(
                &self.attr,
                &self.rep,
                ex,
                inv_z,
                self.params.eta,
                self.params.momentum_at(iter),
                true,
            )
            .expect("bbox tracked");
        let step_s = t.elapsed().as_secs_f64();
        self.elapsed_s += step_s;
        let stats = IterStats {
            iter,
            kl_est: kl_pairs + p_sum * z.ln(),
            z,
            diameter: (bbox[2] - bbox[0]).max(bbox[3] - bbox[1]),
            elapsed_s: self.elapsed_s,
            attr_s: t_attr,
            rep_s: if obs_on { t_rep - t_attr } else { 0.0 },
            grad_s: if obs_on { step_s - t_rep } else { 0.0 },
        };
        self.iter += 1;
        self.last_stats = Some(stats);
        Ok(stats)
    }

    fn positions(&self) -> &[f32] {
        &self.state.y
    }

    fn params(&self) -> &OptParams {
        &self.params
    }

    fn set_params(&mut self, params: OptParams) {
        self.params = params;
    }

    fn warm_start(&mut self, y0: &[f32]) -> anyhow::Result<()> {
        anyhow::ensure!(
            y0.len() == 2 * self.state.n,
            "warm_start layout has {} values, session needs {}",
            y0.len(),
            2 * self.state.n
        );
        self.state.y.copy_from_slice(y0);
        self.state.vel.fill(0.0);
        self.state.gains.fill(1.0);
        self.iter = 0;
        self.elapsed_s = 0.0;
        self.last_stats = None;
        Ok(())
    }

    fn checkpoint(&self) -> Checkpoint {
        Checkpoint {
            engine: self.engine_name.to_string(),
            iter: self.iter,
            elapsed_s: self.elapsed_s,
            y: self.state.y.clone(),
            vel: self.state.vel.clone(),
            gains: self.state.gains.clone(),
            grid: None,
        }
    }

    fn restore(&mut self, ck: &Checkpoint) -> anyhow::Result<()> {
        let want = 2 * self.state.n;
        anyhow::ensure!(
            ck.y.len() == want && ck.vel.len() == want && ck.gains.len() == want,
            "checkpoint state length {} does not fit session n={}",
            ck.y.len(),
            self.state.n
        );
        self.state.y.copy_from_slice(&ck.y);
        self.state.vel.copy_from_slice(&ck.vel);
        self.state.gains.copy_from_slice(&ck.gains);
        self.iter = ck.iter;
        self.elapsed_s = ck.elapsed_s;
        self.last_stats = None;
        Ok(())
    }

    fn last_stats(&self) -> Option<IterStats> {
        self.last_stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedules() {
        let p = OptParams::default();
        assert_eq!(p.momentum_at(0), 0.5);
        assert_eq!(p.momentum_at(250), 0.8);
        assert_eq!(p.exaggeration_at(0), 12.0);
        assert_eq!(p.exaggeration_at(249), 12.0);
        assert_eq!(p.exaggeration_at(250), 1.0);
    }

    #[test]
    fn init_is_deterministic() {
        let a = GdState::init(50, 1, 0.1);
        let b = GdState::init(50, 1, 0.1);
        assert_eq!(a.y, b.y);
        assert_ne!(a.y, GdState::init(50, 2, 0.1).y);
    }

    #[test]
    fn gains_stay_above_floor_and_update_rule() {
        let mut s = GdState::init(1, 0, 0.0);
        s.vel = vec![1.0, -1.0];
        s.gains = vec![1.0, 1.0];
        // grad same sign as vel halves-ish the gain; opposite sign adds.
        let y0 = s.y.clone();
        s.apply_gradient(&[0.5, 0.5], 1.0, 0.0);
        assert!((s.gains[0] - 0.8).abs() < 1e-6);
        assert!((s.gains[1] - 1.2).abs() < 1e-6);
        let _ = y0;
        for _ in 0..100 {
            s.apply_gradient(&[1.0, 1.0], 1.0, 0.0);
        }
        assert!(s.gains.iter().all(|&g| g >= GAIN_MIN));
    }

    #[test]
    fn fused_step_matches_serial_reference() {
        // The fused pass must reproduce grad-combine + apply_gradient +
        // recenter + bbox exactly (per-element arithmetic is identical;
        // only the mean/bbox reduction grouping differs).
        let n = 500;
        let mut fused = GdState::init(n, 9, 1.0);
        let mut serial = fused.clone();
        let mut rng = Rng::new(17);
        let attr: Vec<f32> = (0..2 * n).map(|_| rng.gauss_f32(0.0, 0.1)).collect();
        let rep: Vec<f32> = (0..2 * n).map(|_| rng.gauss_f32(0.0, 5.0)).collect();
        let (ex, inv_z, eta, mom) = (4.0f32, 0.25f32, 150.0f32, 0.6f32);
        let mut grad = vec![0.0f32; 2 * n];
        for i in 0..2 * n {
            grad[i] = 4.0 * (ex * attr[i] - rep[i] * inv_z);
        }
        serial.apply_gradient(&grad, eta, mom);
        let bb_ref = serial.bbox();
        let bb = fused.fused_step(&attr, &rep, ex, inv_z, eta, mom, true).unwrap();
        for i in 0..2 * n {
            assert!(
                (fused.y[i] - serial.y[i]).abs() < 1e-4,
                "y[{i}]: {} vs {}",
                fused.y[i],
                serial.y[i]
            );
            assert_eq!(fused.gains[i], serial.gains[i], "gains[{i}]");
            assert_eq!(fused.vel[i], serial.vel[i], "vel[{i}]");
        }
        for d in 0..4 {
            assert!((bb[d] - bb_ref[d]).abs() < 1e-4, "bbox[{d}]: {} vs {}", bb[d], bb_ref[d]);
        }
        // Headless runs skip bbox work entirely.
        assert!(fused.fused_step(&attr, &rep, ex, inv_z, eta, mom, false).is_none());
    }

    #[test]
    fn checkpoint_bytes_roundtrip_bitwise() {
        let mut rng = Rng::new(21);
        let n = 37;
        let ck = Checkpoint {
            engine: "bh-0.5".into(),
            iter: 123,
            elapsed_s: 4.5,
            y: (0..2 * n).map(|_| rng.gauss_f32(0.0, 3.0)).collect(),
            vel: (0..2 * n).map(|_| rng.gauss_f32(0.0, 0.3)).collect(),
            gains: (0..2 * n).map(|_| rng.gauss_f32(1.0, 0.1)).collect(),
            grid: None,
        };
        let back = Checkpoint::from_bytes(&ck.to_bytes()).unwrap();
        assert_eq!(back, ck);
        // Corruption is an error, not garbage.
        assert!(Checkpoint::from_bytes(b"junk").is_err());
        let mut bytes = ck.to_bytes();
        bytes.truncate(bytes.len() - 3);
        assert!(Checkpoint::from_bytes(&bytes).is_err());
    }

    #[test]
    fn checkpoint_grid_extension_roundtrips() {
        // Device checkpoints carry the grid-policy hysteresis state
        // (ROADMAP (f)); the extension must round-trip bit-exactly,
        // including the "no grid chosen yet" case.
        for current in [None, Some(128usize)] {
            let ck = Checkpoint {
                engine: "gpgpu".into(),
                iter: 7,
                elapsed_s: 0.25,
                y: vec![1.0, -2.0],
                vel: vec![0.5, 0.5],
                gains: vec![1.0, 1.0],
                grid: Some(GridCheckpoint {
                    diameter: 17.25,
                    current,
                    last_grid: 128,
                    grid_switches: 3,
                }),
            };
            let back = Checkpoint::from_bytes(&ck.to_bytes()).unwrap();
            assert_eq!(back, ck);
        }
    }

    #[test]
    fn legacy_v1_checkpoints_still_decode() {
        // A v1 blob (pre grid-extension framing) restores with
        // `grid: None` — durable journals written by older builds must
        // not become unreadable.
        let ck = Checkpoint {
            engine: "exact".into(),
            iter: 9,
            elapsed_s: 1.5,
            y: vec![0.25, -0.5, 1.0, 2.0],
            vel: vec![0.0; 4],
            gains: vec![1.0; 4],
            grid: None,
        };
        // Hand-assemble the v1 framing: v2 minus the extension block,
        // with the old magic.
        let v2 = ck.to_bytes();
        let mut v1 = v2[..v2.len() - 8].to_vec(); // drop the empty ext block
        v1[..8].copy_from_slice(b"GSNECKP1");
        let back = Checkpoint::from_bytes(&v1).unwrap();
        assert_eq!(back, ck);
    }

    #[test]
    fn recentre_zeroes_mean() {
        let mut s = GdState::init(10, 3, 1.0);
        for v in s.y.iter_mut() {
            *v += 5.0;
        }
        s.recenter();
        let mean: f32 = s.y.iter().sum::<f32>() / s.y.len() as f32;
        assert!(mean.abs() < 1e-4);
    }

    #[test]
    fn bbox_contains_all() {
        let s = GdState::init(30, 4, 1.0);
        let b = s.bbox();
        for i in 0..30 {
            assert!(s.y[2 * i] >= b[0] && s.y[2 * i] <= b[2]);
            assert!(s.y[2 * i + 1] >= b[1] && s.y[2 * i + 1] <= b[3]);
        }
    }
}
