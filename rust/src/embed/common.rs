//! Shared gradient-descent machinery (DESIGN.md S16): the van der Maaten
//! update rule (gains, momentum), the early-exaggeration and momentum
//! schedules the paper's evaluation uses, the engine trait, and the
//! generic optimisation loop every CPU engine runs through.

use crate::hd::SparseP;
use crate::util::parallel::{self, SyncSlice};
use crate::util::rng::Rng;

/// Optimisation hyperparameters (HDI defaults, §6 of the paper).
#[derive(Debug, Clone)]
pub struct OptParams {
    pub iters: usize,
    pub eta: f32,
    pub momentum0: f32,
    pub momentum1: f32,
    /// Iteration at which momentum switches 0.5 → 0.8.
    pub momentum_switch: usize,
    /// Early-exaggeration multiplier on P.
    pub exaggeration: f32,
    /// Iterations during which exaggeration applies.
    pub exaggeration_iters: usize,
    pub seed: u64,
    /// Initial embedding std-dev.
    pub init_std: f32,
}

impl Default for OptParams {
    fn default() -> Self {
        Self {
            iters: 1000,
            eta: 200.0,
            momentum0: 0.5,
            momentum1: 0.8,
            momentum_switch: 250,
            exaggeration: 12.0,
            exaggeration_iters: 250,
            seed: 42,
            init_std: 0.1,
        }
    }
}

impl OptParams {
    pub fn momentum_at(&self, iter: usize) -> f32 {
        if iter < self.momentum_switch {
            self.momentum0
        } else {
            self.momentum1
        }
    }

    pub fn exaggeration_at(&self, iter: usize) -> f32 {
        if iter < self.exaggeration_iters {
            self.exaggeration
        } else {
            1.0
        }
    }
}

/// Per-iteration statistics delivered to observers.
#[derive(Debug, Clone, Copy)]
pub struct IterStats {
    pub iter: usize,
    /// Neighbour-restricted KL estimate (comparable across engines).
    pub kl_est: f64,
    /// Normalisation term (exact or field-estimated Z).
    pub z: f64,
    /// Embedding diameter (bbox max side).
    pub diameter: f32,
    pub elapsed_s: f64,
}

/// Observer verdict: keep optimising or stop early (the A-tSNE
/// user-driven early termination the coordinator exposes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Control {
    Continue,
    Stop,
}

/// An embedding optimiser.
pub trait Engine: Send {
    fn name(&self) -> &'static str;

    /// Minimise KL(P||Q); returns the final `(n, 2)` embedding.
    /// The observer (if any) sees every iteration and can stop the run.
    fn run(
        &mut self,
        p: &SparseP,
        params: &OptParams,
        observer: Option<&mut dyn FnMut(&IterStats, &[f32]) -> Control>,
    ) -> anyhow::Result<Vec<f32>>;
}

/// Gradient-descent state for the CPU engines.
#[derive(Debug, Clone)]
pub struct GdState {
    pub n: usize,
    pub y: Vec<f32>,
    pub vel: Vec<f32>,
    pub gains: Vec<f32>,
}

pub const GAIN_ADD: f32 = 0.2;
pub const GAIN_MUL: f32 = 0.8;
pub const GAIN_MIN: f32 = 0.01;

/// Points per task of the fused step pass. Partials are indexed by
/// chunk, not by thread, so the reduction is deterministic regardless
/// of scheduling.
const STEP_CHUNK: usize = 2048;

/// Per-chunk partial of the fused step: coordinate sums (f64, for the
/// recentre mean) and a bounding box.
#[derive(Clone)]
struct StepPartial {
    sx: f64,
    sy: f64,
    bbox: [f32; 4],
}

impl StepPartial {
    fn identity() -> Self {
        Self {
            sx: 0.0,
            sy: 0.0,
            bbox: [f32::INFINITY, f32::INFINITY, f32::NEG_INFINITY, f32::NEG_INFINITY],
        }
    }
}

impl GdState {
    /// Random Gaussian initialisation (deterministic in seed).
    pub fn init(n: usize, seed: u64, std: f32) -> Self {
        let mut rng = Rng::new(seed);
        let y = (0..2 * n).map(|_| rng.gauss_f32(0.0, std)).collect();
        Self { n, y, vel: vec![0.0; 2 * n], gains: vec![1.0; 2 * n] }
    }

    /// The fused per-iteration hot path: gradient combine
    /// (`g = 4·(ex·attr − rep/Z)`, Eq. 8), the van der Maaten
    /// gains/momentum update, the recentre mean, and (optionally) the
    /// bounding box — one threaded pass over the points plus an
    /// O(chunks) combine and a threaded mean-subtract, replacing four
    /// serial O(N) sweeps. Arithmetic per element is identical to
    /// [`Self::apply_gradient`] + [`Self::recenter`].
    ///
    /// Returns the post-recentre bbox when `track_bbox` (observers need
    /// the diameter); headless runs pass `false` and skip the min/max
    /// work entirely.
    pub fn fused_step(
        &mut self,
        attr: &[f32],
        rep: &[f32],
        exaggeration: f32,
        inv_z: f32,
        eta: f32,
        momentum: f32,
        track_bbox: bool,
    ) -> Option<[f32; 4]> {
        let n = self.n;
        debug_assert!(attr.len() >= 2 * n && rep.len() >= 2 * n);
        let nchunks = n.div_ceil(STEP_CHUNK).max(1);
        // n/STEP_CHUNK slots of 24 B — a per-call allocation three orders
        // of magnitude under the pass it fronts, not worth carrying state.
        let mut partials = vec![StepPartial::identity(); nchunks];
        {
            let parts = SyncSlice::new(&mut partials);
            let ys = SyncSlice::new(&mut self.y);
            let vels = SyncSlice::new(&mut self.vel);
            let gains = SyncSlice::new(&mut self.gains);
            parallel::par_chunks(n, STEP_CHUNK, |range| {
                let ci = range.start / STEP_CHUNK;
                let mut acc = StepPartial::identity();
                for i in range {
                    for d in 0..2 {
                        let idx = 2 * i + d;
                        let g = 4.0 * (exaggeration * attr[idx] - rep[idx] * inv_z);
                        unsafe {
                            let vel = vels.get_mut(idx);
                            let gain = gains.get_mut(idx);
                            let same = g * *vel > 0.0;
                            let raw = if same { *gain * GAIN_MUL } else { *gain + GAIN_ADD };
                            let ng = raw.max(GAIN_MIN);
                            *gain = ng;
                            *vel = momentum * *vel - eta * ng * g;
                            *ys.get_mut(idx) += *vel;
                        }
                    }
                    let (x, yv) = unsafe { (*ys.get_mut(2 * i), *ys.get_mut(2 * i + 1)) };
                    acc.sx += x as f64;
                    acc.sy += yv as f64;
                    if track_bbox {
                        acc.bbox[0] = acc.bbox[0].min(x);
                        acc.bbox[1] = acc.bbox[1].min(yv);
                        acc.bbox[2] = acc.bbox[2].max(x);
                        acc.bbox[3] = acc.bbox[3].max(yv);
                    }
                }
                unsafe {
                    *parts.get_mut(ci) = acc;
                }
            });
        }
        let mut total = StepPartial::identity();
        for p in &partials {
            total.sx += p.sx;
            total.sy += p.sy;
            total.bbox[0] = total.bbox[0].min(p.bbox[0]);
            total.bbox[1] = total.bbox[1].min(p.bbox[1]);
            total.bbox[2] = total.bbox[2].max(p.bbox[2]);
            total.bbox[3] = total.bbox[3].max(p.bbox[3]);
        }
        let cx = (total.sx / n as f64) as f32;
        let cy = (total.sy / n as f64) as f32;
        {
            let ys = SyncSlice::new(&mut self.y);
            parallel::par_chunks(n, STEP_CHUNK, |range| {
                for i in range {
                    unsafe {
                        *ys.get_mut(2 * i) -= cx;
                        *ys.get_mut(2 * i + 1) -= cy;
                    }
                }
            });
        }
        // The bbox was gathered pre-recentre; shifting it by the mean
        // gives the post-recentre box without a second min/max sweep.
        track_bbox.then(|| {
            let b = total.bbox;
            [b[0] - cx, b[1] - cy, b[2] - cx, b[3] - cy]
        })
    }

    /// One van der Maaten update from a gradient; recentres afterwards.
    pub fn apply_gradient(&mut self, grad: &[f32], eta: f32, momentum: f32) {
        debug_assert_eq!(grad.len(), 2 * self.n);
        for i in 0..2 * self.n {
            let g = grad[i];
            let same = g * self.vel[i] > 0.0;
            let gain = if same { self.gains[i] * GAIN_MUL } else { self.gains[i] + GAIN_ADD };
            let gain = gain.max(GAIN_MIN);
            self.gains[i] = gain;
            self.vel[i] = momentum * self.vel[i] - eta * gain * g;
            self.y[i] += self.vel[i];
        }
        self.recenter();
    }

    /// Subtract the mean.
    pub fn recenter(&mut self) {
        let (mut cx, mut cy) = (0.0f64, 0.0f64);
        for i in 0..self.n {
            cx += self.y[2 * i] as f64;
            cy += self.y[2 * i + 1] as f64;
        }
        cx /= self.n as f64;
        cy /= self.n as f64;
        for i in 0..self.n {
            self.y[2 * i] -= cx as f32;
            self.y[2 * i + 1] -= cy as f32;
        }
    }

    /// Bounding box `[min_x, min_y, max_x, max_y]`.
    pub fn bbox(&self) -> [f32; 4] {
        let mut b = [f32::INFINITY, f32::INFINITY, f32::NEG_INFINITY, f32::NEG_INFINITY];
        for i in 0..self.n {
            b[0] = b[0].min(self.y[2 * i]);
            b[1] = b[1].min(self.y[2 * i + 1]);
            b[2] = b[2].max(self.y[2 * i]);
            b[3] = b[3].max(self.y[2 * i + 1]);
        }
        b
    }
}

/// A repulsion approximation: fills `num` with the *numerator*
/// Σ_j t²_ij (y_i − y_j) and returns the normalisation Z = Σ_{k≠l} t_kl
/// estimate. `F_rep = num / Z` (Eq. 8 right term / Eq. 14).
pub trait Repulsion {
    fn compute(&mut self, y: &[f32], num: &mut [f32]) -> f64;
}

/// The generic CPU optimisation loop shared by exact/BH/field engines.
///
/// The per-iteration O(N) tail (gradient combine, gains/momentum update,
/// recentre, bbox) runs through [`GdState::fused_step`] — one threaded
/// pass instead of four serial sweeps — and the bbox/stats work is done
/// only when an observer is actually attached.
pub fn run_gd_loop(
    repulsion: &mut dyn Repulsion,
    p: &SparseP,
    params: &OptParams,
    mut observer: Option<&mut dyn FnMut(&IterStats, &[f32]) -> Control>,
) -> anyhow::Result<Vec<f32>> {
    let n = p.n();
    let mut state = GdState::init(n, params.seed, params.init_std);
    let mut attr = vec![0.0f32; 2 * n];
    let mut rep = vec![0.0f32; 2 * n];
    let t0 = std::time::Instant::now();
    for iter in 0..params.iters {
        let ex = params.exaggeration_at(iter);
        let (kl_pairs, p_sum) = super::attractive_forces(p, &state.y, &mut attr);
        let z = repulsion.compute(&state.y, &mut rep).max(1e-12);
        let inv_z = (1.0 / z) as f32;
        let track = observer.is_some();
        let bbox = state.fused_step(
            &attr,
            &rep,
            ex,
            inv_z,
            params.eta,
            params.momentum_at(iter),
            track,
        );
        if let Some(obs) = observer.as_deref_mut() {
            let b = bbox.expect("bbox is tracked whenever an observer is attached");
            let stats = IterStats {
                iter,
                kl_est: kl_pairs + p_sum * z.ln(),
                z,
                diameter: (b[2] - b[0]).max(b[3] - b[1]),
                elapsed_s: t0.elapsed().as_secs_f64(),
            };
            if obs(&stats, &state.y) == Control::Stop {
                break;
            }
        }
    }
    Ok(state.y)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedules() {
        let p = OptParams::default();
        assert_eq!(p.momentum_at(0), 0.5);
        assert_eq!(p.momentum_at(250), 0.8);
        assert_eq!(p.exaggeration_at(0), 12.0);
        assert_eq!(p.exaggeration_at(249), 12.0);
        assert_eq!(p.exaggeration_at(250), 1.0);
    }

    #[test]
    fn init_is_deterministic() {
        let a = GdState::init(50, 1, 0.1);
        let b = GdState::init(50, 1, 0.1);
        assert_eq!(a.y, b.y);
        assert_ne!(a.y, GdState::init(50, 2, 0.1).y);
    }

    #[test]
    fn gains_stay_above_floor_and_update_rule() {
        let mut s = GdState::init(1, 0, 0.0);
        s.vel = vec![1.0, -1.0];
        s.gains = vec![1.0, 1.0];
        // grad same sign as vel halves-ish the gain; opposite sign adds.
        let y0 = s.y.clone();
        s.apply_gradient(&[0.5, 0.5], 1.0, 0.0);
        assert!((s.gains[0] - 0.8).abs() < 1e-6);
        assert!((s.gains[1] - 1.2).abs() < 1e-6);
        let _ = y0;
        for _ in 0..100 {
            s.apply_gradient(&[1.0, 1.0], 1.0, 0.0);
        }
        assert!(s.gains.iter().all(|&g| g >= GAIN_MIN));
    }

    #[test]
    fn fused_step_matches_serial_reference() {
        // The fused pass must reproduce grad-combine + apply_gradient +
        // recenter + bbox exactly (per-element arithmetic is identical;
        // only the mean/bbox reduction grouping differs).
        let n = 500;
        let mut fused = GdState::init(n, 9, 1.0);
        let mut serial = fused.clone();
        let mut rng = Rng::new(17);
        let attr: Vec<f32> = (0..2 * n).map(|_| rng.gauss_f32(0.0, 0.1)).collect();
        let rep: Vec<f32> = (0..2 * n).map(|_| rng.gauss_f32(0.0, 5.0)).collect();
        let (ex, inv_z, eta, mom) = (4.0f32, 0.25f32, 150.0f32, 0.6f32);
        let mut grad = vec![0.0f32; 2 * n];
        for i in 0..2 * n {
            grad[i] = 4.0 * (ex * attr[i] - rep[i] * inv_z);
        }
        serial.apply_gradient(&grad, eta, mom);
        let bb_ref = serial.bbox();
        let bb = fused.fused_step(&attr, &rep, ex, inv_z, eta, mom, true).unwrap();
        for i in 0..2 * n {
            assert!(
                (fused.y[i] - serial.y[i]).abs() < 1e-4,
                "y[{i}]: {} vs {}",
                fused.y[i],
                serial.y[i]
            );
            assert_eq!(fused.gains[i], serial.gains[i], "gains[{i}]");
            assert_eq!(fused.vel[i], serial.vel[i], "vel[{i}]");
        }
        for d in 0..4 {
            assert!((bb[d] - bb_ref[d]).abs() < 1e-4, "bbox[{d}]: {} vs {}", bb[d], bb_ref[d]);
        }
        // Headless runs skip bbox work entirely.
        assert!(fused.fused_step(&attr, &rep, ex, inv_z, eta, mom, false).is_none());
    }

    #[test]
    fn recentre_zeroes_mean() {
        let mut s = GdState::init(10, 3, 1.0);
        for v in s.y.iter_mut() {
            *v += 5.0;
        }
        s.recenter();
        let mean: f32 = s.y.iter().sum::<f32>() / s.y.len() as f32;
        assert!(mean.abs() < 1e-4);
    }

    #[test]
    fn bbox_contains_all() {
        let s = GdState::init(30, 4, 1.0);
        let b = s.bbox();
        for i in 0..30 {
            assert!(s.y[2 * i] >= b[0] && s.y[2 * i] <= b[2]);
            assert!(s.y[2 * i + 1] >= b[1] && s.y[2 * i + 1] <= b[3]);
        }
    }
}
