//! Barnes-Hut quadtree over the 2-D embedding (Barnes & Hut [3], as used
//! by BH-SNE [41]; DESIGN.md S12).
//!
//! Nodes store centre of mass and point count; the force traversal treats
//! a cell as a single super-point when `cell_size² / d² < θ²`, yielding
//! the O(N log N) repulsion approximation the paper compares against.

/// A flat-array quadtree (children allocated on demand).
pub struct QuadTree {
    nodes: Vec<Node>,
}

#[derive(Debug, Clone)]
struct Node {
    /// Square cell: centre + half side.
    cx: f32,
    cy: f32,
    half: f32,
    /// Centre of mass and cumulative count of the subtree.
    mass_x: f64,
    mass_y: f64,
    count: u32,
    /// If a single point resides here and no children: its position.
    point: Option<(f32, f32)>,
    /// Child indices (NW, NE, SW, SE) or NONE.
    children: [u32; 4],
}

const NONE: u32 = u32::MAX;

impl QuadTree {
    /// Empty tree (no storage) — pair with [`Self::rebuild`].
    pub fn empty() -> Self {
        Self { nodes: Vec::new() }
    }

    /// Build from a `(n, 2)` row-major embedding.
    pub fn build(y: &[f32]) -> Self {
        let mut tree = Self::empty();
        tree.rebuild(y);
        tree
    }

    /// Rebuild in place from a new layout, reusing the node storage —
    /// a stepwise session rebuilds the tree every iteration, and this
    /// keeps the hot path free of the O(N) node re-allocation. Insertion
    /// order (hence the finished tree) is identical to [`Self::build`].
    pub fn rebuild(&mut self, y: &[f32]) {
        let n = y.len() / 2;
        let mut b = [f32::INFINITY, f32::INFINITY, f32::NEG_INFINITY, f32::NEG_INFINITY];
        for i in 0..n {
            b[0] = b[0].min(y[2 * i]);
            b[1] = b[1].min(y[2 * i + 1]);
            b[2] = b[2].max(y[2 * i]);
            b[3] = b[3].max(y[2 * i + 1]);
        }
        let half = (0.5 * (b[2] - b[0]).max(b[3] - b[1])).max(1e-6) * 1.0001;
        let root = Node {
            cx: 0.5 * (b[0] + b[2]),
            cy: 0.5 * (b[1] + b[3]),
            half,
            mass_x: 0.0,
            mass_y: 0.0,
            count: 0,
            point: None,
            children: [NONE; 4],
        };
        self.nodes.clear();
        self.nodes.push(root);
        for i in 0..n {
            self.insert(0, y[2 * i], y[2 * i + 1], 0);
        }
    }

    fn insert(&mut self, node: u32, x: f32, y: f32, depth: usize) {
        let ni = node as usize;
        self.nodes[ni].mass_x += x as f64;
        self.nodes[ni].mass_y += y as f64;
        self.nodes[ni].count += 1;

        // Depth cap: degenerate coincident points accumulate as mass only.
        if depth > 48 {
            return;
        }
        if self.nodes[ni].count == 1 {
            self.nodes[ni].point = Some((x, y));
            return;
        }
        // Subdivide: push the resident point down first (if any).
        if let Some((px, py)) = self.nodes[ni].point.take() {
            let q = self.child_for(ni, px, py);
            self.insert(q, px, py, depth + 1);
        }
        let q = self.child_for(ni, x, y);
        self.insert(q, x, y, depth + 1);
    }

    /// Child quadrant node id for a position, allocating if needed.
    fn child_for(&mut self, ni: usize, x: f32, y: f32) -> u32 {
        let (cx, cy, half) = (self.nodes[ni].cx, self.nodes[ni].cy, self.nodes[ni].half);
        let (east, north) = (x >= cx, y >= cy);
        let qi = match (north, east) {
            (true, false) => 0,
            (true, true) => 1,
            (false, false) => 2,
            (false, true) => 3,
        };
        if self.nodes[ni].children[qi] == NONE {
            let h = half * 0.5;
            let child = Node {
                cx: cx + if east { h } else { -h },
                cy: cy + if north { h } else { -h },
                half: h,
                mass_x: 0.0,
                mass_y: 0.0,
                count: 0,
                point: None,
                children: [NONE; 4],
            };
            self.nodes.push(child);
            self.nodes[ni].children[qi] = (self.nodes.len() - 1) as u32;
        }
        self.nodes[ni].children[qi]
    }

    /// Accumulate the repulsion numerator and Z estimate for a query
    /// point: returns `(Σ t² dx, Σ t² dy, Σ t)` over all other points,
    /// with Barnes-Hut cell approximation at opening angle θ.
    ///
    /// The query point itself contributes t(0)=1 to the Z sum through its
    /// own cell; the caller subtracts 1 (exactly like Eq. 13's `S−1`).
    pub fn accumulate(&self, x: f32, y: f32, theta: f32) -> (f64, f64, f64) {
        let mut stack = Vec::with_capacity(64);
        self.accumulate_with(x, y, theta, &mut stack)
    }

    /// [`Self::accumulate`] with a caller-provided traversal stack, so a
    /// batched force pass reuses one allocation across all its queries
    /// instead of allocating per point.
    pub fn accumulate_with(
        &self,
        x: f32,
        y: f32,
        theta: f32,
        stack: &mut Vec<u32>,
    ) -> (f64, f64, f64) {
        let mut fx = 0.0f64;
        let mut fy = 0.0f64;
        let mut z = 0.0f64;
        let theta2 = (theta * theta).max(1e-12);
        stack.clear();
        stack.push(0);
        while let Some(ni) = stack.pop() {
            let node = &self.nodes[ni as usize];
            if node.count == 0 {
                continue;
            }
            let comx = node.mass_x / node.count as f64;
            let comy = node.mass_y / node.count as f64;
            let dx = x as f64 - comx;
            let dy = y as f64 - comy;
            let d2 = dx * dx + dy * dy;
            let cell = (2.0 * node.half) as f64;
            let is_leaf_point = node.point.is_some() && node.children.iter().all(|&c| c == NONE);
            if is_leaf_point || (cell * cell) < theta2 as f64 * d2 {
                // Treat as a single super-point of mass `count`.
                let t = 1.0 / (1.0 + d2);
                let m = node.count as f64;
                z += m * t;
                let t2m = t * t * m;
                fx += t2m * dx;
                fy += t2m * dy;
            } else {
                for &c in &node.children {
                    if c != NONE {
                        stack.push(c);
                    }
                }
                // Interior nodes may also hold no direct point; resident
                // single points were pushed to children on subdivision.
                if let Some((px, py)) = node.point {
                    let dx = (x - px) as f64;
                    let dy = (y - py) as f64;
                    let t = 1.0 / (1.0 + dx * dx + dy * dy);
                    z += t;
                    fx += t * t * dx;
                    fy += t * t * dy;
                }
            }
        }
        (fx, fy, z)
    }

    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    /// Total mass (point count) at the root — conservation invariant.
    pub fn total_count(&self) -> u32 {
        self.nodes[0].count
    }

    /// Root centre of mass.
    pub fn root_com(&self) -> (f64, f64) {
        let r = &self.nodes[0];
        (r.mass_x / r.count.max(1) as f64, r.mass_y / r.count.max(1) as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_points(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..2 * n).map(|_| rng.gauss_f32(0.0, 3.0)).collect()
    }

    #[test]
    fn mass_conservation() {
        let y = random_points(500, 1);
        let t = QuadTree::build(&y);
        assert_eq!(t.total_count(), 500);
        // Root COM == mean of points.
        let (mx, my) = t.root_com();
        let (mut ex, mut ey) = (0.0f64, 0.0f64);
        for i in 0..500 {
            ex += y[2 * i] as f64;
            ey += y[2 * i + 1] as f64;
        }
        assert!((mx - ex / 500.0).abs() < 1e-4);
        assert!((my - ey / 500.0).abs() < 1e-4);
    }

    #[test]
    fn theta_zero_is_exact() {
        // θ=0 never approximates: must equal the brute-force sums.
        let n = 120;
        let y = random_points(n, 2);
        let t = QuadTree::build(&y);
        for i in (0..n).step_by(13) {
            let (fx, fy, z) = t.accumulate(y[2 * i], y[2 * i + 1], 0.0);
            let (mut efx, mut efy, mut ez) = (0.0f64, 0.0f64, 0.0f64);
            for j in 0..n {
                let dx = y[2 * i] - y[2 * j];
                let dy = y[2 * i + 1] - y[2 * j + 1];
                let tt = 1.0f64 / (1.0 + (dx * dx + dy * dy) as f64);
                ez += tt;
                efx += tt * tt * dx as f64;
                efy += tt * tt * dy as f64;
            }
            assert!((z - ez).abs() < 1e-6 * ez.abs().max(1.0), "z {z} vs {ez}");
            assert!((fx - efx).abs() < 1e-6 * efx.abs().max(1e-3));
            assert!((fy - efy).abs() < 1e-6 * efy.abs().max(1e-3));
        }
    }

    #[test]
    fn theta_half_approximates_well() {
        let n = 400;
        let y = random_points(n, 3);
        let t = QuadTree::build(&y);
        let mut rel_err = 0.0f64;
        for i in (0..n).step_by(7) {
            let (fx, fy, _z) = t.accumulate(y[2 * i], y[2 * i + 1], 0.5);
            let (ex, ey, _) = t.accumulate(y[2 * i], y[2 * i + 1], 0.0);
            let err = ((fx - ex).powi(2) + (fy - ey).powi(2)).sqrt();
            let mag = (ex * ex + ey * ey).sqrt().max(1e-9);
            rel_err = rel_err.max(err / mag);
        }
        assert!(rel_err < 0.15, "BH θ=0.5 error too large: {rel_err}");
    }

    #[test]
    fn coincident_points_do_not_hang() {
        let y = vec![1.0f32, 1.0, 1.0, 1.0, 1.0, 1.0, 2.0, 2.0];
        let t = QuadTree::build(&y);
        assert_eq!(t.total_count(), 4);
        let (_, _, z) = t.accumulate(1.0, 1.0, 0.5);
        assert!(z.is_finite() && z > 0.0);
    }
}
