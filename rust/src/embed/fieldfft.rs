//! `fieldfft` — the paper's field-based optimiser with the repulsive
//! fields computed by FFT convolution (`field::conv::FftBackend`),
//! O(N + G² log G) per iteration instead of the gather mirror's O(N·G²).
//!
//! This is the interpolation-FFT formulation of Linderman et al.
//! ("Efficient Algorithms for t-distributed Stochastic Neighborhood
//! Embedding"; the same mathematics t-SNE-CUDA runs on device), so this
//! engine doubles as the honest CPU basis for the simulated GPU
//! baselines. Everything outside the field stage — gradient-descent loop,
//! attractive pass, adaptive-ρ grid policy — is shared with `fieldcpu`,
//! which is exactly the paper's axis of comparison.

use std::sync::Arc;

use super::common::{EmbeddingSession, Engine, GdSession, OptParams};
use super::fieldcpu::FieldRepulsion;
use crate::field::conv::FftBackend;
use crate::hd::SparseP;

/// The FFT-accelerated field engine.
pub struct FieldFft {
    pub rep: FieldRepulsion,
}

impl Default for FieldFft {
    fn default() -> Self {
        Self { rep: FieldRepulsion::with_backend(Box::new(FftBackend::new())) }
    }
}

impl Engine for FieldFft {
    fn name(&self) -> &'static str {
        "fieldfft"
    }

    fn begin(
        &mut self,
        p: Arc<SparseP>,
        params: &OptParams,
    ) -> anyhow::Result<Box<dyn EmbeddingSession>> {
        Ok(GdSession::boxed("fieldfft", p, params, Box::new(self.rep.fresh())))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embed::common::Repulsion;
    use crate::util::rng::Rng;

    #[test]
    fn engine_reports_name_and_runs() {
        let mut e = FieldFft::default();
        assert_eq!(e.name(), "fieldfft");
        // A tiny smoke run: 3 points, uniform P.
        let p = SparseP {
            csr: crate::hd::sparse::Csr::from_rows(
                3,
                3,
                2,
                vec![1, 2, 0, 2, 0, 1],
                vec![1.0 / 6.0; 6],
            ),
            perplexity: 2.0,
        };
        let params = OptParams { iters: 5, exaggeration_iters: 2, ..Default::default() };
        let y = e.run(&p, &params, None).unwrap();
        assert_eq!(y.len(), 6);
        assert!(y.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn repulsion_z_is_positive_for_spread_layouts() {
        let mut rng = Rng::new(3);
        let n = 120;
        let y: Vec<f32> = (0..2 * n).map(|_| rng.gauss_f32(0.0, 3.0)).collect();
        let mut num = vec![0.0f32; 2 * n];
        let mut rep = FieldFft::default().rep;
        let z = rep.compute(&y, &mut num);
        assert!(z > 0.0, "Ẑ must be positive, got {z}");
        assert!(num.iter().all(|v| v.is_finite()));
    }
}
