//! Exact t-SNE (van der Maaten & Hinton 2008) — the O(N²) baseline the
//! paper labels "t-SNE" (DESIGN.md S11). Repulsion is the full pairwise
//! sum; attractive forces share the sparse pass with every other engine.

use std::sync::Arc;

use super::common::{EmbeddingSession, Engine, GdSession, OptParams, Repulsion};
use crate::hd::SparseP;
use crate::util::parallel;

const CHUNK: usize = 32;

/// Exact O(N²) repulsion: `num_i = Σ_{j≠i} t²_ij (y_i − y_j)`,
/// `Z = Σ_{k≠l} t_kl` (threaded over rows; the Z partials land in
/// chunk-indexed slots and combine in chunk order, so the f64 sum is
/// deterministic regardless of thread scheduling — a checkpointed
/// session must replay identically on any worker).
pub struct ExactRepulsion;

impl Repulsion for ExactRepulsion {
    fn compute(&mut self, y: &[f32], num: &mut [f32]) -> f64 {
        let n = y.len() / 2;
        let nchunks = n.div_ceil(CHUNK).max(1);
        let mut z_parts = vec![0.0f64; nchunks];
        {
            let parts = parallel::SyncSlice::new(&mut z_parts);
            let slots = parallel::SyncSlice::new(num);
            parallel::par_chunks(n, CHUNK, |range| {
                let ci = range.start / CHUNK;
                let mut local_z = 0.0f64;
                for i in range {
                    let (xi, yi) = (y[2 * i], y[2 * i + 1]);
                    let (mut fx, mut fy) = (0.0f32, 0.0f32);
                    for j in 0..n {
                        if j == i {
                            continue;
                        }
                        let dx = xi - y[2 * j];
                        let dy = yi - y[2 * j + 1];
                        let t = 1.0 / (1.0 + dx * dx + dy * dy);
                        local_z += t as f64;
                        let t2 = t * t;
                        fx += t2 * dx;
                        fy += t2 * dy;
                    }
                    unsafe {
                        *slots.get_mut(2 * i) = fx;
                        *slots.get_mut(2 * i + 1) = fy;
                    }
                }
                unsafe {
                    *parts.get_mut(ci) = local_z;
                }
            });
        }
        z_parts.iter().sum()
    }
}

/// The exact-t-SNE engine.
pub struct ExactTsne;

impl Engine for ExactTsne {
    fn name(&self) -> &'static str {
        "exact"
    }

    fn begin(
        &mut self,
        p: Arc<SparseP>,
        params: &OptParams,
    ) -> anyhow::Result<Box<dyn EmbeddingSession>> {
        Ok(GdSession::boxed("exact", p, params, Box::new(ExactRepulsion)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embed::common::{Control, IterStats};
    use crate::hd::sparse::Csr;
    use crate::metrics::kl;

    fn ring_p(n: usize, k: usize) -> SparseP {
        let mut col = Vec::new();
        let mut val = Vec::new();
        for i in 0..n {
            for j in 1..=k {
                col.push(((i + j) % n) as u32);
                val.push(1.0 / (n * k) as f32);
            }
        }
        SparseP { csr: Csr::from_rows(n, n, k, col, val), perplexity: k as f32 }
    }

    #[test]
    fn repulsion_z_matches_metric_exact_z() {
        let mut rng = crate::util::rng::Rng::new(1);
        let n = 80;
        let y: Vec<f32> = (0..2 * n).map(|_| rng.gauss_f32(0.0, 2.0)).collect();
        let mut num = vec![0.0f32; 2 * n];
        let z = ExactRepulsion.compute(&y, &mut num);
        assert!((z - kl::exact_z(&y)).abs() / z < 1e-9);
    }

    #[test]
    fn repulsion_z_is_bitwise_deterministic() {
        // Chunk-indexed partials: the f64 Z must not depend on thread
        // scheduling (checkpointed sessions replay on any worker).
        let mut rng = crate::util::rng::Rng::new(4);
        let n = 300; // well past one chunk
        let y: Vec<f32> = (0..2 * n).map(|_| rng.gauss_f32(0.0, 2.0)).collect();
        let mut num = vec![0.0f32; 2 * n];
        let z0 = ExactRepulsion.compute(&y, &mut num);
        for _ in 0..5 {
            assert_eq!(ExactRepulsion.compute(&y, &mut num), z0);
        }
    }

    #[test]
    fn two_point_repulsion_analytic() {
        let y = vec![0.0f32, 0.0, 1.0, 0.0];
        let mut num = vec![0.0f32; 4];
        let z = ExactRepulsion.compute(&y, &mut num);
        // t = 1/2; numerator for point0 = t^2 * (0-1, 0-0) = (-0.25, 0).
        assert!((num[0] + 0.25).abs() < 1e-6);
        assert!((z - 1.0).abs() < 1e-9); // two ordered pairs * 1/2
    }

    #[test]
    fn optimisation_reduces_kl() {
        let n = 60;
        let p = ring_p(n, 3);
        let params = OptParams { iters: 150, exaggeration_iters: 40, seed: 7, ..Default::default() };
        let mut kl_first = f64::NAN;
        let mut kl_last = f64::NAN;
        let mut obs = |s: &IterStats, _y: &[f32]| {
            if s.iter == 0 {
                kl_first = s.kl_est;
            }
            kl_last = s.kl_est;
            Control::Continue
        };
        let y = ExactTsne.run(&p, &params, Some(&mut obs)).unwrap();
        assert!(kl_last < kl_first, "KL must drop: {kl_first} -> {kl_last}");
        assert!(y.iter().all(|v| v.is_finite()));
        // Exact final KL should be decent for a ring.
        let final_kl = kl::kl_divergence_exact(&p, &y);
        assert!(final_kl < kl_first, "exact final KL {final_kl} vs initial est {kl_first}");
    }

    #[test]
    fn observer_can_stop_early() {
        let p = ring_p(40, 2);
        let params = OptParams { iters: 500, ..Default::default() };
        let mut count = 0usize;
        let mut obs = |s: &IterStats, _y: &[f32]| {
            count += 1;
            if s.iter >= 9 {
                Control::Stop
            } else {
                Control::Continue
            }
        };
        ExactTsne.run(&p, &params, Some(&mut obs)).unwrap();
        assert_eq!(count, 10);
    }
}
