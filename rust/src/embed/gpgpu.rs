//! GPGPU-SNE — the paper's system (DESIGN.md S14): the full optimisation
//! step runs as the AOT-compiled XLA executable (L1 Pallas fields + L2
//! step graph); this engine owns the host-side policy around it:
//!
//! * pad the job into the smallest artifact N-bucket,
//! * upload the static tensors once (device-resident),
//! * per iteration, pick the grid variant by the paper's ρ policy from
//!   the bounding box the previous step returned (10% hysteresis), and
//! * run the step, feeding the evolving state back.

use std::sync::Arc;

use anyhow::Context;

use super::common::{Control, Engine, GdState, IterStats, OptParams};
use crate::hd::SparseP;
use crate::runtime::{Runtime, StaticArgs, StepState};

/// The discrete adaptive-resolution policy over the artifact grid set.
#[derive(Debug, Clone)]
pub struct GridPolicy {
    /// Embedding-units per pixel (paper: ρ = 0.5).
    pub rho: f32,
    /// Hysteresis band: only switch grids when the ideal size drifts this
    /// far (relative) from the current grid — avoids thrashing the
    /// executable cache between adjacent variants.
    pub hysteresis: f32,
    /// Available grid sizes, ascending.
    pub grids: Vec<usize>,
    current: Option<usize>,
}

impl GridPolicy {
    pub fn new(rho: f32, grids: Vec<usize>) -> Self {
        assert!(!grids.is_empty());
        let mut grids = grids;
        grids.sort_unstable();
        Self { rho, hysteresis: 0.10, grids, current: None }
    }

    /// Smallest available grid ≥ the ideal diameter/ρ (largest otherwise).
    fn ideal(&self, diameter: f32) -> usize {
        let want = (diameter / self.rho).ceil() as usize;
        *self.grids.iter().find(|&&g| g >= want).unwrap_or(self.grids.last().unwrap())
    }

    /// Grid for this iteration given the current embedding diameter.
    pub fn choose(&mut self, diameter: f32) -> usize {
        let ideal = self.ideal(diameter);
        match self.current {
            None => {
                self.current = Some(ideal);
                ideal
            }
            Some(cur) if ideal == cur => cur,
            Some(cur) => {
                // Only move when outside the hysteresis band.
                let want = diameter / self.rho;
                let boundary = cur as f32;
                let drift = if ideal > cur {
                    (want - boundary) / boundary
                } else {
                    (boundary - want) / boundary
                };
                if drift > self.hysteresis {
                    self.current = Some(ideal);
                    ideal
                } else {
                    cur
                }
            }
        }
    }

    pub fn current(&self) -> Option<usize> {
        self.current
    }
}

/// The device-backed engine.
pub struct GpgpuSne {
    rt: Arc<Runtime>,
    /// Per-run grid switch count (observability for tests/benches).
    pub grid_switches: usize,
    /// ρ override (None = 0.5).
    pub rho: f32,
}

impl GpgpuSne {
    pub fn new(rt: Arc<Runtime>) -> Self {
        Self { rt, grid_switches: 0, rho: 0.5 }
    }

    /// Pad a job into bucket form: (n_pad, mask, state, statics).
    fn prepare(
        &self,
        p: &SparseP,
        params: &OptParams,
    ) -> anyhow::Result<(usize, usize, StepState, StaticArgs)> {
        let n = p.n();
        let n_pad = self
            .rt
            .manifest
            .bucket_for(n)
            .with_context(|| format!("no artifact bucket fits n={n}"))?;
        anyhow::ensure!(
            n <= n_pad,
            "dataset n={n} exceeds the largest artifact bucket {n_pad}; rebuild artifacts with --full-matrix"
        );
        let k = self
            .rt
            .manifest
            .steps()
            .find(|a| a.n == n_pad)
            .map(|a| a.k)
            .context("no step artifact in bucket")?;
        let (idx, val) = p.to_padded(n_pad, k);
        let mut mask = vec![0.0f32; n_pad];
        mask[..n].fill(1.0);
        let statics = self.rt.upload_static(&mask, &idx, &val, k)?;
        // Initial embedding: same distribution as the CPU engines.
        let init = GdState::init(n, params.seed, params.init_std);
        let mut y = vec![0.0f32; 2 * n_pad];
        y[..2 * n].copy_from_slice(&init.y);
        let state = StepState::new(y, &mask);
        Ok((n_pad, k, state, statics))
    }
}

impl Engine for GpgpuSne {
    fn name(&self) -> &'static str {
        "gpgpu"
    }

    fn run(
        &mut self,
        p: &SparseP,
        params: &OptParams,
        mut observer: Option<&mut dyn FnMut(&IterStats, &[f32]) -> Control>,
    ) -> anyhow::Result<Vec<f32>> {
        let n = p.n();
        let (n_pad, _k, mut state, statics) = self.prepare(p, params)?;
        let grids = self.rt.manifest.grids_for(n_pad);
        anyhow::ensure!(!grids.is_empty(), "no grid variants for bucket {n_pad}");
        let mut policy = GridPolicy::new(self.rho, grids);
        self.grid_switches = 0;

        // Initial diameter from the random init.
        let mut diameter = {
            let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
            for i in 0..n {
                lo = lo.min(state.y[2 * i].min(state.y[2 * i + 1]));
                hi = hi.max(state.y[2 * i].max(state.y[2 * i + 1]));
            }
            (hi - lo).max(1e-3)
        };
        let t0 = std::time::Instant::now();
        let mut last_grid = 0usize;
        for iter in 0..params.iters {
            let grid = policy.choose(diameter);
            if grid != last_grid && last_grid != 0 {
                self.grid_switches += 1;
            }
            last_grid = grid;
            let exe = self.rt.step_executable(n_pad, grid)?;
            let out = self.rt.run_step(
                &exe,
                &mut state,
                &statics,
                params.eta,
                params.momentum_at(iter),
                params.exaggeration_at(iter),
            )?;
            diameter = out.diameter().max(1e-3);
            if let Some(obs) = observer.as_deref_mut() {
                let stats = IterStats {
                    iter,
                    kl_est: out.kl as f64,
                    z: out.zhat as f64,
                    diameter,
                    elapsed_s: t0.elapsed().as_secs_f64(),
                };
                if obs(&stats, &state.y[..2 * n]) == Control::Stop {
                    break;
                }
            }
        }
        Ok(state.y[..2 * n].to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_picks_smallest_covering_grid() {
        let mut p = GridPolicy::new(0.5, vec![32, 64, 128, 256]);
        assert_eq!(p.choose(10.0), 32); // 10/0.5 = 20 -> 32
        assert_eq!(p.choose(25.0), 64); // 50 -> 64 (drift large)
        assert_eq!(p.choose(200.0), 256); // 400 -> clamped to 256
    }

    #[test]
    fn policy_hysteresis_prevents_thrash() {
        let mut p = GridPolicy::new(0.5, vec![32, 64, 128]);
        assert_eq!(p.choose(30.0), 64); // 60 -> 64
        // Ideal drops to 32 (diameter 15.9 -> want 31.8) but drift from 64
        // is (64-31.8)/64 = 0.50 > hysteresis: switches.
        assert_eq!(p.choose(15.9), 32);
        // Wobble just above the 32 boundary must NOT bounce back to 64:
        assert_eq!(p.choose(16.2), 32); // want 32.4, drift (32.4-32)/32 ≈ 1% < 10%
        assert_eq!(p.choose(17.5), 32); // want 35, drift ~9.4% < 10%
        assert_eq!(p.choose(18.0), 64); // want 36, drift 12.5% -> switch
    }

    #[test]
    fn policy_is_stable_at_fixed_diameter() {
        let mut p = GridPolicy::new(0.5, vec![32, 64]);
        let g0 = p.choose(20.0);
        for _ in 0..100 {
            assert_eq!(p.choose(20.0), g0);
        }
    }
}
