//! GPGPU-SNE — the paper's system (DESIGN.md S14): the full optimisation
//! step runs as the AOT-compiled XLA executable (L1 Pallas fields + L2
//! step graph); this engine owns the host-side policy around it:
//!
//! * pad the job into the smallest artifact N-bucket,
//! * upload the static tensors once (device-resident),
//! * per iteration, pick the grid variant by the paper's ρ policy from
//!   the bounding box the previous step returned (10% hysteresis), and
//! * run the step, feeding the evolving state back.

use std::sync::Arc;

use anyhow::Context;

use super::common::{
    Checkpoint, EmbeddingSession, Engine, GdState, GridCheckpoint, IterStats, OptParams,
};
use crate::hd::SparseP;
use crate::runtime::{Runtime, StaticArgs, StepState};

/// The discrete adaptive-resolution policy over the artifact grid set.
#[derive(Debug, Clone)]
pub struct GridPolicy {
    /// Embedding-units per pixel (paper: ρ = 0.5).
    pub rho: f32,
    /// Hysteresis band: only switch grids when the ideal size drifts this
    /// far (relative) from the current grid — avoids thrashing the
    /// executable cache between adjacent variants.
    pub hysteresis: f32,
    /// Available grid sizes, ascending.
    pub grids: Vec<usize>,
    current: Option<usize>,
}

impl GridPolicy {
    pub fn new(rho: f32, grids: Vec<usize>) -> Self {
        assert!(!grids.is_empty());
        let mut grids = grids;
        grids.sort_unstable();
        Self { rho, hysteresis: 0.10, grids, current: None }
    }

    /// Smallest available grid ≥ the ideal diameter/ρ (largest otherwise).
    fn ideal(&self, diameter: f32) -> usize {
        let want = (diameter / self.rho).ceil() as usize;
        *self.grids.iter().find(|&&g| g >= want).unwrap_or(self.grids.last().unwrap())
    }

    /// Grid for this iteration given the current embedding diameter.
    pub fn choose(&mut self, diameter: f32) -> usize {
        let ideal = self.ideal(diameter);
        match self.current {
            None => {
                self.current = Some(ideal);
                ideal
            }
            Some(cur) if ideal == cur => cur,
            Some(cur) => {
                // Only move when outside the hysteresis band.
                let want = diameter / self.rho;
                let boundary = cur as f32;
                let drift = if ideal > cur {
                    (want - boundary) / boundary
                } else {
                    (boundary - want) / boundary
                };
                if drift > self.hysteresis {
                    self.current = Some(ideal);
                    ideal
                } else {
                    cur
                }
            }
        }
    }

    pub fn current(&self) -> Option<usize> {
        self.current
    }

    /// Restore the hysteresis latch from a checkpoint. A grid that is
    /// not in this policy's variant set (checkpoint taken against a
    /// different artifact build) is dropped — the policy then re-chooses
    /// freshly, which is the legacy (pre-serialisation) behaviour.
    pub fn set_current(&mut self, grid: Option<usize>) {
        self.current = grid.filter(|g| self.grids.contains(g));
    }
}

/// The device-backed engine.
pub struct GpgpuSne {
    rt: Arc<Runtime>,
    /// ρ override (None = 0.5).
    pub rho: f32,
}

impl GpgpuSne {
    pub fn new(rt: Arc<Runtime>) -> Self {
        Self { rt, rho: 0.5 }
    }

    /// Pad a job into bucket form: (n_pad, mask, state, statics).
    fn prepare(
        &self,
        p: &SparseP,
        params: &OptParams,
    ) -> anyhow::Result<(usize, usize, StepState, StaticArgs)> {
        let n = p.n();
        let n_pad = self
            .rt
            .manifest
            .bucket_for(n)
            .with_context(|| format!("no artifact bucket fits n={n}"))?;
        anyhow::ensure!(
            n <= n_pad,
            "dataset n={n} exceeds the largest artifact bucket {n_pad}; rebuild artifacts with --full-matrix"
        );
        let k = self
            .rt
            .manifest
            .steps()
            .find(|a| a.n == n_pad)
            .map(|a| a.k)
            .context("no step artifact in bucket")?;
        let (idx, val) = p.to_padded(n_pad, k);
        let mut mask = vec![0.0f32; n_pad];
        mask[..n].fill(1.0);
        let statics = self.rt.upload_static(&mask, &idx, &val, k)?;
        // Initial embedding: same distribution as the CPU engines.
        let init = GdState::init(n, params.seed, params.init_std);
        let mut y = vec![0.0f32; 2 * n_pad];
        y[..2 * n].copy_from_slice(&init.y);
        let state = StepState::new(y, &mask);
        Ok((n_pad, k, state, statics))
    }
}

impl Engine for GpgpuSne {
    fn name(&self) -> &'static str {
        "gpgpu"
    }

    fn begin(
        &mut self,
        p: Arc<SparseP>,
        params: &OptParams,
    ) -> anyhow::Result<Box<dyn EmbeddingSession>> {
        let n = p.n();
        let (n_pad, _k, state, statics) = self.prepare(&p, params)?;
        let grids = self.rt.manifest.grids_for(n_pad);
        anyhow::ensure!(!grids.is_empty(), "no grid variants for bucket {n_pad}");
        let policy = GridPolicy::new(self.rho, grids);
        let diameter = diameter_of(&state.y, n);
        Ok(Box::new(GpgpuSession {
            rt: self.rt.clone(),
            n,
            n_pad,
            params: params.clone(),
            state,
            statics,
            policy,
            iter: 0,
            elapsed_s: 0.0,
            diameter,
            last_grid: 0,
            grid_switches: 0,
            last_stats: None,
        }))
    }
}

/// Max-axis spread over the first `n` (real) points — drives the
/// adaptive-ρ grid policy.
fn diameter_of(y: &[f32], n: usize) -> f32 {
    let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
    for i in 0..n {
        lo = lo.min(y[2 * i].min(y[2 * i + 1]));
        hi = hi.max(y[2 * i].max(y[2 * i + 1]));
    }
    (hi - lo).max(1e-3)
}

/// A stepwise optimisation on the device path: owns the evolving state
/// tensors, the uploaded per-job statics (neighbour lists, P values,
/// mask — device-resident, uploaded once at `begin`), and the adaptive
/// grid policy. Pausing a session keeps the statics on device, so
/// resuming costs nothing but the next step.
pub struct GpgpuSession {
    rt: Arc<Runtime>,
    /// Real (unpadded) point count.
    n: usize,
    /// Artifact bucket size.
    n_pad: usize,
    params: OptParams,
    state: StepState,
    statics: StaticArgs,
    policy: GridPolicy,
    iter: usize,
    elapsed_s: f64,
    diameter: f32,
    last_grid: usize,
    /// Grid switch count since begin/warm-start (observability).
    pub grid_switches: usize,
    last_stats: Option<IterStats>,
}

impl EmbeddingSession for GpgpuSession {
    fn engine_name(&self) -> &'static str {
        "gpgpu"
    }

    fn iter(&self) -> usize {
        self.iter
    }

    fn step(&mut self) -> anyhow::Result<IterStats> {
        anyhow::ensure!(
            self.iter < self.params.iters,
            "session complete at iter {} (extend via set_params)",
            self.iter
        );
        let t = std::time::Instant::now();
        let grid = self.policy.choose(self.diameter);
        if grid != self.last_grid && self.last_grid != 0 {
            self.grid_switches += 1;
        }
        self.last_grid = grid;
        let exe = self.rt.step_executable(self.n_pad, grid)?;
        let out = self.rt.run_step(
            &exe,
            &mut self.state,
            &self.statics,
            self.params.eta,
            self.params.momentum_at(self.iter),
            self.params.exaggeration_at(self.iter),
        )?;
        self.diameter = out.diameter().max(1e-3);
        self.elapsed_s += t.elapsed().as_secs_f64();
        let stats = IterStats {
            iter: self.iter,
            kl_est: out.kl as f64,
            z: out.zhat as f64,
            diameter: self.diameter,
            elapsed_s: self.elapsed_s,
            // The device step is one fused executable — the per-phase
            // split is not observable from the host.
            attr_s: 0.0,
            rep_s: 0.0,
            grad_s: 0.0,
        };
        self.iter += 1;
        self.last_stats = Some(stats);
        Ok(stats)
    }

    fn positions(&self) -> &[f32] {
        &self.state.y[..2 * self.n]
    }

    fn params(&self) -> &OptParams {
        &self.params
    }

    fn set_params(&mut self, params: OptParams) {
        self.params = params;
    }

    fn warm_start(&mut self, y0: &[f32]) -> anyhow::Result<()> {
        anyhow::ensure!(
            y0.len() == 2 * self.n,
            "warm_start layout has {} values, session needs {}",
            y0.len(),
            2 * self.n
        );
        self.state.y.fill(0.0);
        self.state.y[..2 * self.n].copy_from_slice(y0);
        self.state.vel.fill(0.0);
        for (i, &m) in self.statics.mask_host.iter().enumerate() {
            let g = if m > 0.0 { 1.0 } else { 0.0 };
            self.state.gains[2 * i] = g;
            self.state.gains[2 * i + 1] = g;
        }
        self.policy = GridPolicy::new(self.policy.rho, self.policy.grids.clone());
        self.diameter = diameter_of(&self.state.y, self.n);
        self.last_grid = 0;
        self.grid_switches = 0;
        self.iter = 0;
        self.elapsed_s = 0.0;
        self.last_stats = None;
        Ok(())
    }

    /// Checkpoints carry the *padded* bucket tensors plus the grid
    /// policy's hysteresis state ([`GridCheckpoint`]), so a restored
    /// device session replays bit-identically: it latches onto the same
    /// grid (and the same device-reported diameter) the checkpointed
    /// session would have used for its next step.
    fn checkpoint(&self) -> Checkpoint {
        Checkpoint {
            engine: "gpgpu".to_string(),
            iter: self.iter,
            elapsed_s: self.elapsed_s,
            y: self.state.y.clone(),
            vel: self.state.vel.clone(),
            gains: self.state.gains.clone(),
            grid: Some(GridCheckpoint {
                diameter: self.diameter,
                current: self.policy.current(),
                last_grid: self.last_grid,
                grid_switches: self.grid_switches,
            }),
        }
    }

    fn restore(&mut self, ck: &Checkpoint) -> anyhow::Result<()> {
        let padded = 2 * self.n_pad;
        let real = 2 * self.n;
        anyhow::ensure!(
            ck.y.len() == ck.vel.len() && ck.y.len() == ck.gains.len(),
            "checkpoint tensors have mismatched lengths"
        );
        if ck.y.len() == padded {
            self.state.y.copy_from_slice(&ck.y);
            self.state.vel.copy_from_slice(&ck.vel);
            self.state.gains.copy_from_slice(&ck.gains);
        } else if ck.y.len() == real {
            // A CPU-engine checkpoint: pad into the bucket (padding slots
            // are inert — zero mask, zero gains).
            self.state.y.fill(0.0);
            self.state.vel.fill(0.0);
            self.state.gains.fill(0.0);
            self.state.y[..real].copy_from_slice(&ck.y);
            self.state.vel[..real].copy_from_slice(&ck.vel);
            self.state.gains[..real].copy_from_slice(&ck.gains);
        } else {
            anyhow::bail!(
                "checkpoint state length {} fits neither padded ({padded}) nor real ({real})",
                ck.y.len()
            );
        }
        match &ck.grid {
            Some(g) => {
                // Bit-identical resume: re-latch the hysteresis state and
                // keep the device-reported diameter (host recomputation
                // can differ in the last ulp — enough to flip a grid
                // decision near a band boundary).
                self.diameter = g.diameter.max(1e-3);
                self.policy.set_current(g.current);
                self.last_grid = g.last_grid;
                self.grid_switches = g.grid_switches;
            }
            None => {
                // CPU-engine or legacy checkpoint: derive the diameter
                // from the positions and let the policy re-choose.
                self.diameter = diameter_of(&self.state.y, self.n);
                self.policy.set_current(None);
                self.last_grid = 0;
                self.grid_switches = 0;
            }
        }
        self.iter = ck.iter;
        self.elapsed_s = ck.elapsed_s;
        self.last_stats = None;
        Ok(())
    }

    fn last_stats(&self) -> Option<IterStats> {
        self.last_stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_picks_smallest_covering_grid() {
        let mut p = GridPolicy::new(0.5, vec![32, 64, 128, 256]);
        assert_eq!(p.choose(10.0), 32); // 10/0.5 = 20 -> 32
        assert_eq!(p.choose(25.0), 64); // 50 -> 64 (drift large)
        assert_eq!(p.choose(200.0), 256); // 400 -> clamped to 256
    }

    #[test]
    fn policy_hysteresis_prevents_thrash() {
        let mut p = GridPolicy::new(0.5, vec![32, 64, 128]);
        assert_eq!(p.choose(30.0), 64); // 60 -> 64
        // Ideal drops to 32 (diameter 15.9 -> want 31.8) but drift from 64
        // is (64-31.8)/64 = 0.50 > hysteresis: switches.
        assert_eq!(p.choose(15.9), 32);
        // Wobble just above the 32 boundary must NOT bounce back to 64:
        assert_eq!(p.choose(16.2), 32); // want 32.4, drift (32.4-32)/32 ≈ 1% < 10%
        assert_eq!(p.choose(17.5), 32); // want 35, drift ~9.4% < 10%
        assert_eq!(p.choose(18.0), 64); // want 36, drift 12.5% -> switch
    }

    #[test]
    fn policy_is_stable_at_fixed_diameter() {
        let mut p = GridPolicy::new(0.5, vec![32, 64]);
        let g0 = p.choose(20.0);
        for _ in 0..100 {
            assert_eq!(p.choose(20.0), g0);
        }
    }

    #[test]
    fn restored_hysteresis_state_reproduces_the_policy_trajectory() {
        // The scenario that made ROADMAP (f) necessary: mid-run the
        // policy is latched on a grid inside a hysteresis band. A fresh
        // policy fed the same diameter chooses differently — only the
        // serialised latch reproduces the original trajectory.
        let mut live = GridPolicy::new(0.5, vec![32, 64, 128]);
        assert_eq!(live.choose(30.0), 64);
        assert_eq!(live.choose(15.9), 32, "want 31.8, drift 50%: switches down");
        assert_eq!(live.choose(16.2), 32, "want 32.4, drift 1.25% < 10%: stays latched");

        // checkpoint() would capture current = Some(32) here.
        let mut restored = GridPolicy::new(0.5, vec![32, 64, 128]);
        restored.set_current(live.current());
        assert_eq!(restored.choose(16.2), 32, "restored latch holds the band");

        let mut fresh = GridPolicy::new(0.5, vec![32, 64, 128]);
        assert_eq!(fresh.choose(16.2), 64, "without the latch the choice flips");

        // A latch from a foreign artifact set is dropped, not trusted.
        let mut skewed = GridPolicy::new(0.5, vec![32, 64, 128]);
        skewed.set_current(Some(96));
        assert_eq!(skewed.current(), None);
    }
}
