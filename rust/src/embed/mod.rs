//! Embedding optimisers: the paper's field-based GPGPU-SNE (device via
//! `runtime/`, CPU mirror in `fieldcpu`, FFT-accelerated CPU path in
//! `fieldfft` over `crate::field`) and every baseline its evaluation
//! compares against — exact t-SNE [42], Barnes-Hut-SNE [41] and a
//! simulated t-SNE-CUDA [7] (DESIGN.md S11–S16).
//!
//! All engines share the van der Maaten gradient-descent update
//! (gains + momentum + early exaggeration, `common.rs`) and the sparse
//! attractive-force pass; they differ only in how the repulsive forces
//! are approximated — which is exactly the paper's axis of comparison.
//!
//! Every engine exposes the *stepwise session* API (`Engine::begin` →
//! [`EmbeddingSession`]): sessions advance one iteration per `step()`,
//! can be paused/resumed/re-parameterised mid-run, warm-started from an
//! existing layout, and checkpointed to bytes. `Engine::run` is a
//! convenience loop over a session (`common::run_session`).

pub mod bh;
pub mod common;
pub mod exact;
pub mod fieldcpu;
pub mod fieldfft;
pub mod gpgpu;
pub mod quadtree;
pub mod tsnecuda;

pub use common::{
    run_session, Checkpoint, Control, EmbeddingSession, Engine, GdSession, GridCheckpoint,
    IterStats, OptParams,
};

use crate::hd::SparseP;

/// Construct an engine by its bench/CLI name.
///
/// `gpgpu` requires compiled artifacts (see `runtime::locate_artifacts`);
/// every other engine is self-contained CPU code.
pub fn by_name(
    name: &str,
    runtime: Option<std::sync::Arc<crate::runtime::Runtime>>,
) -> anyhow::Result<Box<dyn Engine>> {
    Ok(match name {
        "exact" => Box::new(exact::ExactTsne),
        "bh-0.5" => Box::new(bh::BarnesHut::new(0.5)),
        "bh-0.1" => Box::new(bh::BarnesHut::new(0.1)),
        "tsne-cuda-0.5" => Box::new(tsnecuda::TsneCudaSim::new(0.5)),
        "tsne-cuda-0.0" => Box::new(tsnecuda::TsneCudaSim::new(0.0)),
        "fieldcpu" => Box::new(fieldcpu::FieldCpu::default()),
        "fieldfft" => Box::new(fieldfft::FieldFft::default()),
        "gpgpu" => {
            let rt = runtime
                .ok_or_else(|| anyhow::anyhow!("gpgpu engine needs artifacts (run `make artifacts`)"))?;
            Box::new(gpgpu::GpgpuSne::new(rt))
        }
        other => anyhow::bail!("unknown engine '{other}'"),
    })
}

/// All engine names in the order the paper's figures list them.
pub const ENGINES: &[&str] = &[
    "exact",
    "bh-0.1",
    "bh-0.5",
    "tsne-cuda-0.0",
    "tsne-cuda-0.5",
    "fieldcpu",
    "fieldfft",
    "gpgpu",
];

/// Shared CPU attractive-force pass over the sparse P (Eq. 12).
///
/// Fills `attr` with Σ_j p_ij t_ij (y_i − y_j) and returns
/// (Σ_ij p_ij (ln p_ij − ln t_ij), Σ_ij p_ij) — the pieces of the
/// neighbour-restricted KL estimate (add `p_sum * ln Z`).
pub fn attractive_forces(p: &SparseP, y: &[f32], attr: &mut [f32]) -> (f64, f64) {
    let n = p.n();
    assert!(attr.len() >= 2 * n && y.len() >= 2 * n);
    // KL partials land in chunk-indexed slots instead of a shared Mutex:
    // no lock contention on the hot path, and the final sum is combined
    // in chunk order — deterministic regardless of thread scheduling.
    // 256 rows per chunk keeps dynamic balancing fine-grained while the
    // per-call partials Vec stays at n/16 bytes — noise next to the
    // O(n·k) force pass it rides on.
    const CHUNK: usize = 256;
    let nchunks = n.div_ceil(CHUNK).max(1);
    let mut partials = vec![(0.0f64, 0.0f64); nchunks];
    {
        let parts = crate::util::parallel::SyncSlice::new(&mut partials);
        let slots = crate::util::parallel::SyncSlice::new(attr);
        crate::util::parallel::par_chunks(n, CHUNK, |range| {
            let ci = range.start / CHUNK;
            let mut local_kl = 0.0f64;
            let mut local_ps = 0.0f64;
            for i in range {
                let (cols, vals) = p.csr.row(i);
                let (xi, yi) = (y[2 * i], y[2 * i + 1]);
                let (mut fx, mut fy) = (0.0f32, 0.0f32);
                for (c, &pij) in cols.iter().zip(vals) {
                    if pij <= 0.0 {
                        continue;
                    }
                    let j = *c as usize;
                    let dx = xi - y[2 * j];
                    let dy = yi - y[2 * j + 1];
                    let t = 1.0 / (1.0 + dx * dx + dy * dy);
                    let w = pij * t;
                    fx += w * dx;
                    fy += w * dy;
                    local_kl += pij as f64 * ((pij as f64).ln() - (t as f64).ln());
                    local_ps += pij as f64;
                }
                unsafe {
                    *slots.get_mut(2 * i) = fx;
                    *slots.get_mut(2 * i + 1) = fy;
                }
            }
            unsafe {
                *parts.get_mut(ci) = (local_kl, local_ps);
            }
        });
    }
    partials.iter().fold((0.0, 0.0), |acc, p| (acc.0 + p.0, acc.1 + p.1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hd::sparse::Csr;

    #[test]
    fn attractive_matches_two_point_analytic() {
        // Same case as the python kernel test.
        let p = SparseP {
            csr: Csr::from_rows(2, 2, 1, vec![1, 0], vec![0.5, 0.5]),
            perplexity: 1.0,
        };
        let y = vec![0.0, 0.0, 2.0, 0.0];
        let mut attr = vec![0.0f32; 4];
        let (_klp, psum) = attractive_forces(&p, &y, &mut attr);
        let t = 1.0 / 5.0;
        assert!((attr[0] - 0.5 * t * (-2.0)).abs() < 1e-6);
        assert!((attr[2] - 0.5 * t * 2.0).abs() < 1e-6);
        assert!((psum - 1.0).abs() < 1e-9);
    }

    #[test]
    fn by_name_knows_all_cpu_engines() {
        // Derive the CPU list from ENGINES so a new engine cannot be
        // forgotten here (gpgpu is the only runtime-gated entry).
        for &name in ENGINES.iter().filter(|&&n| n != "gpgpu") {
            assert!(by_name(name, None).is_ok(), "{name}");
        }
        assert!(by_name("gpgpu", None).is_err(), "gpgpu without runtime must error");
        assert!(by_name("bogus", None).is_err());
    }
}
