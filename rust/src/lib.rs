//! # gpgpu-sne
//!
//! Production-grade reproduction of **"GPGPU Linear Complexity t-SNE
//! Optimization"** (Pezzotti, Thijssen, Mordvintsev, Höllt, van Lew,
//! Lelieveldt, Eisemann, Vilanova — 2018): linear-complexity minimisation
//! of the t-SNE objective by replacing the O(N²) repulsive-force sum with
//! two fields over the 2-D embedding domain (a scalar density field `S`
//! and a vector force field `V`), evaluated on a pixel grid and queried by
//! bilinear interpolation.
//!
//! Architecture (see `DESIGN.md`): a three-layer stack in which
//! * **L1** (Pallas, build-time Python) evaluates the fields and the
//!   restricted-neighbourhood attractive forces,
//! * **L2** (JAX, build-time Python) fuses a full gradient-descent
//!   iteration and is AOT-lowered to HLO-text artifacts,
//! * **L3** (this crate) is the runtime system: dataset substrates, the
//!   similarity pipeline (pluggable `KnnBackend`s over blocked distance
//!   kernels, fused perplexity/P build, coordinator-level similarity
//!   caching — `hd/`), the PJRT runtime that executes the AOT
//!   artifacts, the host field subsystem (`field/`: exact gather oracle
//!   plus the O(N + G² log G) FFT-convolution backend behind a pluggable
//!   `FieldBackend` trait), the optimisers (exact t-SNE, Barnes-Hut,
//!   simulated t-SNE-CUDA, field engines — all exposed as stepwise
//!   `embed::EmbeddingSession`s: pause/resume/warm-start/checkpoint),
//!   metrics, the observability substrate (`obs/`: lock-free span
//!   tracing + a metrics registry, surfaced over the protocol's
//!   `metrics`/`trace` commands), and the progressive embedding
//!   *service*: a cooperative scheduler time-slicing sessions across
//!   workers, with the paper's adaptive field-resolution policy.
//!
//! Python never runs on the request path: after `make artifacts`, the
//! binary is self-contained.

pub mod cluster;
pub mod coordinator;
pub mod data;
pub mod embed;
pub mod field;
pub mod hd;
pub mod metrics;
pub mod obs;
pub mod runtime;
pub mod tools;
pub mod util;

/// Crate-wide result alias (anyhow is in the offline dependency closure).
pub type Result<T> = anyhow::Result<T>;
