//! Tiny CLI argument parser (the `clap` crate is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional
//! arguments, with typed accessors, defaults, and an auto-generated
//! usage string ([`Args::finish_help`] prints every accessor called so
//! far when `--help` was passed). Used by the main binary, every example
//! and every bench.
//!
//! ```
//! use gpgpu_sne::util::cli::Args;
//!
//! let argv = ["serve", "--addr", "0.0.0.0:7878", "--journal-every=25", "--verbose"];
//! let args = Args::parse("gpgpu-sne".into(), argv.iter().map(|s| s.to_string()).collect());
//! assert_eq!(args.positional, vec!["serve"]);
//! assert_eq!(args.str("addr", "127.0.0.1:7878", "bind address"), "0.0.0.0:7878");
//! assert_eq!(args.get("journal-every", 50usize, "journal cadence"), 25);
//! assert!(args.flag("verbose", "chatty output"));
//! assert_eq!(args.opt_str("state-dir", "durable state"), None);
//! ```

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub program: String,
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
    /// (name, help) for usage output, registered by accessors.
    seen: std::cell::RefCell<Vec<(String, String)>>,
}

impl Args {
    /// Parse `std::env::args()`.
    pub fn from_env() -> Self {
        let mut it = std::env::args();
        let program = it.next().unwrap_or_default();
        Self::parse(program, it.collect())
    }

    /// Parse an explicit vector (used by tests).
    pub fn parse(program: String, argv: Vec<String>) -> Self {
        let mut positional = Vec::new();
        let mut options = BTreeMap::new();
        let mut flags = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    options.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    options.insert(stripped.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    flags.push(stripped.to_string());
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Self { program, positional, options, flags, seen: Default::default() }
    }

    fn note(&self, name: &str, help: String) {
        self.seen.borrow_mut().push((name.to_string(), help));
    }

    /// Boolean flag (present / absent).
    pub fn flag(&self, name: &str, help: &str) -> bool {
        self.note(name, format!("(flag) {help}"));
        self.flags.iter().any(|f| f == name) || self.options.get(name).map(|v| v == "true").unwrap_or(false)
    }

    /// String option with default.
    pub fn str(&self, name: &str, default: &str, help: &str) -> String {
        self.note(name, format!("(default {default}) {help}"));
        self.options.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Optional string (no default).
    pub fn opt_str(&self, name: &str, help: &str) -> Option<String> {
        self.note(name, help.to_string());
        self.options.get(name).cloned()
    }

    /// Typed option with default; exits with a message on parse failure.
    pub fn get<T: std::str::FromStr>(&self, name: &str, default: T, help: &str) -> T
    where
        T: std::fmt::Display,
    {
        self.note(name, format!("(default {default}) {help}"));
        match self.options.get(name) {
            None => default,
            Some(v) => v.parse().unwrap_or_else(|_| {
                eprintln!("error: --{name} expects a {}, got '{v}'", std::any::type_name::<T>());
                std::process::exit(2);
            }),
        }
    }

    /// Typed option without a default (`None` when absent); exits with a
    /// message on parse failure — for options whose mere presence changes
    /// behaviour (e.g. `--auto-stop-window` enabling auto-stop).
    pub fn opt_get<T: std::str::FromStr>(&self, name: &str, help: &str) -> Option<T> {
        self.note(name, format!("(optional) {help}"));
        self.options.get(name).map(|v| {
            v.parse().unwrap_or_else(|_| {
                eprintln!("error: --{name} expects a {}, got '{v}'", std::any::type_name::<T>());
                std::process::exit(2);
            })
        })
    }

    /// Comma-separated typed list.
    pub fn list<T: std::str::FromStr>(&self, name: &str, default: &[T], help: &str) -> Vec<T>
    where
        T: Clone + std::fmt::Debug,
    {
        self.note(name, format!("(default {default:?}) {help}"));
        match self.options.get(name) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.trim().parse().unwrap_or_else(|_| {
                        eprintln!("error: --{name} has an unparsable element '{s}'");
                        std::process::exit(2);
                    })
                })
                .collect(),
        }
    }

    /// Print usage (from every accessor called so far) and exit if
    /// `--help` was passed. Call after all accessors.
    pub fn finish_help(&self, about: &str) {
        if self.flags.iter().any(|f| f == "help") {
            println!("{about}\n\nusage: {} [options]\n", self.program);
            for (name, help) in self.seen.borrow().iter() {
                println!("  --{name:<24} {help}");
            }
            std::process::exit(0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Args {
        Args::parse("prog".into(), v.iter().map(|s| s.to_string()).collect())
    }

    #[test]
    fn parses_forms() {
        // Note: a bare `--flag` greedily consumes a following non-`--`
        // token as its value, so flags that precede positionals must use
        // `--flag=true`. Positionals therefore come first by convention.
        let a = args(&["input.bin", "--n", "100", "--grid=64", "--verbose"]);
        assert_eq!(a.get("n", 0usize, ""), 100);
        assert_eq!(a.get("grid", 0usize, ""), 64);
        assert!(a.flag("verbose", ""));
        assert!(!a.flag("quiet", ""));
        assert_eq!(a.positional, vec!["input.bin"]);
        let b = args(&["--verbose=true", "run.bin"]);
        assert!(b.flag("verbose", ""));
        assert_eq!(b.positional, vec!["run.bin"]);
    }

    #[test]
    fn defaults_apply() {
        let a = args(&[]);
        assert_eq!(a.get("eta", 200.0f32, ""), 200.0);
        assert_eq!(a.str("name", "mnist", ""), "mnist");
        assert_eq!(a.opt_str("missing", ""), None);
    }

    #[test]
    fn opt_get_distinguishes_absent_from_set() {
        let a = args(&["--window", "25"]);
        assert_eq!(a.opt_get::<usize>("window", ""), Some(25));
        assert_eq!(a.opt_get::<usize>("missing", ""), None);
    }

    #[test]
    fn lists_parse() {
        let a = args(&["--ns", "1000,5000,10000"]);
        assert_eq!(a.list("ns", &[1usize], ""), vec![1000, 5000, 10000]);
        assert_eq!(a.list("grids", &[32usize, 64], ""), vec![32, 64]);
    }

    #[test]
    fn negative_numbers_as_values() {
        let a = args(&["--lo", "-3.5"]);
        assert_eq!(a.get("lo", 0.0f64, ""), -3.5);
    }
}
