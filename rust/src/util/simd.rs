//! Runtime-dispatched SIMD kernels for the hottest loops in the
//! pipeline (ISSUE 8): the blocked-kNN panel kernels (`dot` / `dot4` /
//! rank-1 update), the radix-2 FFT butterflies and the 4×4 transpose
//! tile, the cubic-Lagrange 4×4 deposit, the Cauchy field-row
//! accumulator, the fused gradient-descent update, and (ISSUE 9) the
//! fused three-channel spectral multiply of the FFT field backend.
//!
//! # Dispatch
//!
//! A kernel [`Tier`] is resolved once per process: CPU features are
//! probed with `is_x86_feature_detected!` on x86-64 (AVX2 → SSE4.1 →
//! scalar); aarch64 reports the `neon` tier (NEON is baseline there, so
//! its kernels are the lane-shaped portable bodies LLVM auto-vectorises
//! with NEON); every other target runs the scalar reference. The
//! resolution is overridable:
//!
//! * `PALLAS_SIMD=scalar|sse|avx2|neon|auto` — environment, read once.
//!   Naming a tier the CPU cannot run falls back to the detected tier
//!   (recorded in [`status_json`] as `source: "env-unsupported"`).
//! * [`set_tier`] — in-process override for tests and benches, so one
//!   binary can compare tiers directly.
//!
//! Call sites fetch the active function table with [`kernels`] (or a
//! specific one with [`Kernels::for_tier`]) and call through plain `fn`
//! pointers; the vector bodies are `#[target_feature]` functions behind
//! safe shims, reachable only through tables whose tier was verified
//! against the CPU, so the feature precondition always holds.
//!
//! # Determinism contract
//!
//! Every tier of every kernel produces **bit-identical** results (for
//! non-NaN inputs — see below): the vector bodies use no FMA, keep
//! per-lane arithmetic in the scalar evaluation order, and reduce
//! through the same canonical tree as the scalar reference (`dot`
//! accumulates eight independent chains combined as
//! `((s0+s1)+(s2+s3)) + ((s4+s5)+(s6+s7))`, with a sequential scalar
//! tail). This is what keeps checkpoint replay exact across machines
//! with different vector units, lets the conformance suite assert
//! equality instead of tolerances, and makes `PALLAS_SIMD=scalar` a
//! pure performance switch rather than a numerics switch. The one
//! carve-out: lane-wise `min`/`max` on NaN inputs follow the x86
//! `minps`/`maxps` operand convention, which differs from `f32::min` —
//! positions are never NaN in a live session, and the gain floor
//! (`max(raw, GAIN_MIN)`) agrees with `f32::max` on NaN anyway.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

use crate::util::json::Json;

/// Gain increment when gradient and velocity disagree (van der Maaten).
pub const GAIN_ADD: f32 = 0.2;
/// Gain multiplier when gradient and velocity agree.
pub const GAIN_MUL: f32 = 0.8;
/// Gain floor.
pub const GAIN_MIN: f32 = 0.01;

/// A kernel tier, ordered by capability.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Tier {
    /// Portable reference kernels (every target).
    Scalar = 0,
    /// 128-bit x86-64 path (`sse4.1`, for `blendv`).
    Sse41 = 1,
    /// 256-bit x86-64 path.
    Avx2 = 2,
    /// aarch64: the lane-shaped portable bodies, auto-vectorised (NEON
    /// is baseline on aarch64; explicit intrinsics are a follow-up).
    Neon = 3,
}

impl Tier {
    /// All tiers, for iteration in tests and benches.
    pub const ALL: [Tier; 4] = [Tier::Scalar, Tier::Sse41, Tier::Avx2, Tier::Neon];

    /// The `PALLAS_SIMD` spelling of this tier.
    pub fn name(self) -> &'static str {
        match self {
            Tier::Scalar => "scalar",
            Tier::Sse41 => "sse",
            Tier::Avx2 => "avx2",
            Tier::Neon => "neon",
        }
    }

    /// Inverse of [`Tier::name`].
    pub fn parse(s: &str) -> Option<Tier> {
        match s {
            "scalar" => Some(Tier::Scalar),
            "sse" | "sse4.1" | "sse41" => Some(Tier::Sse41),
            "avx2" => Some(Tier::Avx2),
            "neon" => Some(Tier::Neon),
            _ => None,
        }
    }

    fn from_u8(v: u8) -> Tier {
        match v {
            1 => Tier::Sse41,
            2 => Tier::Avx2,
            3 => Tier::Neon,
            _ => Tier::Scalar,
        }
    }
}

/// Whether this CPU can run `t`'s kernels.
pub fn supported(t: Tier) -> bool {
    match t {
        Tier::Scalar => true,
        #[cfg(target_arch = "x86_64")]
        Tier::Sse41 => is_x86_feature_detected!("sse4.1"),
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2 => is_x86_feature_detected!("avx2"),
        #[cfg(target_arch = "aarch64")]
        Tier::Neon => true,
        #[allow(unreachable_patterns)]
        _ => false,
    }
}

/// Best tier this CPU supports (ignoring overrides).
pub fn detected_tier() -> Tier {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            return Tier::Avx2;
        }
        if is_x86_feature_detected!("sse4.1") {
            return Tier::Sse41;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        return Tier::Neon;
    }
    #[allow(unreachable_code)]
    Tier::Scalar
}

/// How the process-wide tier was chosen.
struct Resolved {
    tier: Tier,
    source: &'static str,
}

static RESOLVED: OnceLock<Resolved> = OnceLock::new();

fn resolved() -> &'static Resolved {
    RESOLVED.get_or_init(|| match std::env::var("PALLAS_SIMD") {
        Err(_) => Resolved { tier: detected_tier(), source: "auto" },
        Ok(v) => {
            let v = v.to_ascii_lowercase();
            if v == "auto" || v.is_empty() {
                return Resolved { tier: detected_tier(), source: "auto" };
            }
            match Tier::parse(&v) {
                Some(t) if supported(t) => Resolved { tier: t, source: "env" },
                // Unknown or unrunnable request: run what the CPU has
                // rather than aborting a serve process over a typo, and
                // say so in `metrics`.
                _ => Resolved { tier: detected_tier(), source: "env-unsupported" },
            }
        }
    })
}

/// In-process override slot (`u8::MAX` = none), so tests and benches can
/// flip tiers without respawning; see [`set_tier`].
static FORCED: AtomicU8 = AtomicU8::new(u8::MAX);

/// Force the active tier (tests/benches), or `None` to restore the
/// env/auto resolution. Panics if the CPU cannot run `t`. Process-global:
/// concurrent tests that flip tiers must serialise around it.
pub fn set_tier(t: Option<Tier>) {
    match t {
        Some(t) => {
            assert!(supported(t), "simd tier '{}' not supported on this CPU", t.name());
            FORCED.store(t as u8, Ordering::Release);
        }
        None => FORCED.store(u8::MAX, Ordering::Release),
    }
}

/// The tier the next [`kernels`] call will hand out.
pub fn active_tier() -> Tier {
    match FORCED.load(Ordering::Acquire) {
        u8::MAX => resolved().tier,
        v => Tier::from_u8(v),
    }
}

/// The active kernel table.
#[inline]
pub fn kernels() -> &'static Kernels {
    Kernels::for_tier(active_tier())
}

/// Tier status for the obs plumbing (`metrics` → `"simd"` section).
pub fn status_json() -> Json {
    Json::obj(vec![
        ("tier", Json::Str(active_tier().name().into())),
        ("detected", Json::Str(detected_tier().name().into())),
        ("source", Json::Str(resolved().source.into())),
        ("forced", Json::Bool(FORCED.load(Ordering::Acquire) != u8::MAX)),
    ])
}

/// Arguments of the fused gradient-descent chunk kernel: one interleaved
/// `[x0, y0, x1, y1, ...]` state chunk (all slices the same even length)
/// plus the step scalars of [`crate::embed::common::GdState::fused_step`].
pub struct GdArgs<'a> {
    pub y: &'a mut [f32],
    pub vel: &'a mut [f32],
    pub gains: &'a mut [f32],
    pub attr: &'a [f32],
    pub rep: &'a [f32],
    pub exaggeration: f32,
    pub inv_z: f32,
    pub eta: f32,
    pub momentum: f32,
    pub track_bbox: bool,
}

/// Per-chunk partial of the fused GD kernel: coordinate sums (f64, for
/// the recentre mean, accumulated in point order) and a bounding box
/// `[min_x, min_y, max_x, max_y]`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GdPartial {
    pub sx: f64,
    pub sy: f64,
    pub bbox: [f32; 4],
}

impl GdPartial {
    pub fn identity() -> Self {
        Self {
            sx: 0.0,
            sy: 0.0,
            bbox: [f32::INFINITY, f32::INFINITY, f32::NEG_INFINITY, f32::NEG_INFINITY],
        }
    }
}

/// Arguments of the fused spectral-multiply chunk kernel
/// ([`crate::field::conv`]): one chunk of the charge half-spectrum
/// (split re/im; overwritten in place by the S-channel product) plus the
/// Vx/Vy product chunks and the matching chunks of the three cached
/// kernel spectra. All twelve slices have the same length.
pub struct SpectralArgs<'a> {
    pub sre: &'a mut [f32],
    pub sim: &'a mut [f32],
    pub xre: &'a mut [f32],
    pub xim: &'a mut [f32],
    pub yre: &'a mut [f32],
    pub yim: &'a mut [f32],
    pub ks_re: &'a [f32],
    pub ks_im: &'a [f32],
    pub kx_re: &'a [f32],
    pub kx_im: &'a [f32],
    pub ky_re: &'a [f32],
    pub ky_im: &'a [f32],
}

/// One tier's kernel set. All entries are plain safe `fn` pointers; the
/// unsafe feature preconditions live behind the shims that built the
/// table.
pub struct Kernels {
    pub tier: Tier,
    /// `⟨a, b⟩` — canonical eight-chain reduction + sequential tail.
    pub dot: fn(&[f32], &[f32]) -> f32,
    /// `[⟨q, b0⟩, ⟨q, b1⟩, ⟨q, b2⟩, ⟨q, b3⟩]`, each bit-identical to
    /// `dot` (the tail routes through the same reduction — ISSUE 8
    /// satellite: quad-scored and tail-scored candidates cannot drift).
    pub dot4: fn(&[f32], &[f32], &[f32], &[f32], &[f32]) -> [f32; 4],
    /// `acc[j] += qv · row[j]` — the blocked-kNN panel rank-1 update.
    pub rank1_update: fn(&mut [f32], &[f32], f32),
    /// One radix-2 stage group: `(a, b)` butterfly over four split-
    /// complex slices with per-stage contiguous twiddles (negated
    /// imaginary part when `inverse`).
    pub butterflies: fn(&mut [f32], &mut [f32], &mut [f32], &mut [f32], &[f32], &[f32], bool),
    /// `dst[c·ds + r] = src[r·ss + c]` for a 4×4 tile (pure movement).
    pub transpose4x4: fn(&[f32], usize, &mut [f32], usize),
    /// `out[base + a·stride + b] += wv[a] · wu[b]` — cubic splat tile.
    pub deposit4x4: fn(&mut [f32], usize, usize, &[f32; 4], &[f32; 4]),
    /// Accumulate one point's Cauchy contribution across a pixel row:
    /// `t = 1/(1 + dx² + dy²)`, `s += t`, `vx += t²·dx`, `vy += t²·dy`.
    pub cauchy_row: fn(&[f32], f32, f32, f32, &mut [f32], &mut [f32], &mut [f32]),
    /// Fused gradient combine + gains/momentum + position update over
    /// one chunk; returns the chunk's mean/bbox partial.
    pub gd_update: fn(GdArgs) -> GdPartial,
    /// Fused three-channel complex spectral multiply over one chunk of
    /// the charge half-spectrum (S product in place, Vx/Vy into their
    /// own planes) — the FFT field backend's per-iteration hot pass.
    pub spectral_mul: fn(SpectralArgs),
}

static SCALAR: Kernels = Kernels {
    tier: Tier::Scalar,
    dot: dot_scalar,
    dot4: dot4_scalar,
    rank1_update: rank1_update_scalar,
    butterflies: butterflies_scalar,
    transpose4x4: transpose4x4_scalar,
    deposit4x4: deposit4x4_scalar,
    cauchy_row: cauchy_row_scalar,
    gd_update: gd_update_scalar,
    spectral_mul: spectral_mul_scalar,
};

#[cfg(target_arch = "x86_64")]
static SSE41: Kernels = Kernels {
    tier: Tier::Sse41,
    dot: x86::dot_sse,
    dot4: x86::dot4_sse,
    rank1_update: x86::rank1_update_sse,
    butterflies: x86::butterflies_sse,
    transpose4x4: x86::transpose4x4_sse,
    deposit4x4: x86::deposit4x4_sse,
    cauchy_row: x86::cauchy_row_sse,
    gd_update: x86::gd_update_sse,
    spectral_mul: x86::spectral_mul_sse,
};

#[cfg(target_arch = "x86_64")]
static AVX2: Kernels = Kernels {
    tier: Tier::Avx2,
    dot: x86::dot_avx2,
    dot4: x86::dot4_avx2,
    rank1_update: x86::rank1_update_avx2,
    butterflies: x86::butterflies_avx2,
    // 4×4 in-register shuffles are 128-bit by nature; the SSE tile is
    // the right kernel on the AVX2 tier too.
    transpose4x4: x86::transpose4x4_sse,
    deposit4x4: x86::deposit4x4_sse,
    cauchy_row: x86::cauchy_row_avx2,
    gd_update: x86::gd_update_avx2,
    spectral_mul: x86::spectral_mul_avx2,
};

#[cfg(target_arch = "aarch64")]
static NEON: Kernels = Kernels {
    tier: Tier::Neon,
    dot: dot_scalar,
    dot4: dot4_scalar,
    rank1_update: rank1_update_scalar,
    butterflies: butterflies_scalar,
    transpose4x4: transpose4x4_scalar,
    deposit4x4: deposit4x4_scalar,
    cauchy_row: cauchy_row_scalar,
    gd_update: gd_update_scalar,
    spectral_mul: spectral_mul_scalar,
};

impl Kernels {
    /// The table for one specific tier (property tests and the bench's
    /// scalar-vs-vector comparisons). Panics if the CPU cannot run it.
    pub fn for_tier(t: Tier) -> &'static Kernels {
        assert!(supported(t), "simd tier '{}' not supported on this CPU", t.name());
        match t {
            Tier::Scalar => &SCALAR,
            #[cfg(target_arch = "x86_64")]
            Tier::Sse41 => &SSE41,
            #[cfg(target_arch = "x86_64")]
            Tier::Avx2 => &AVX2,
            #[cfg(target_arch = "aarch64")]
            Tier::Neon => &NEON,
            #[allow(unreachable_patterns)]
            _ => &SCALAR,
        }
    }
}

// ---------------------------------------------------------------------
// Scalar reference kernels. These are the semantics; every vector body
// below must match them bit-for-bit (see the module docs). The shapes
// are deliberately lane-friendly so even this tier auto-vectorises.
// ---------------------------------------------------------------------

/// Canonical dot product: eight independent chains over 8-wide blocks,
/// combined as `((s0+s1)+(s2+s3)) + ((s4+s5)+(s6+s7))`, then a
/// sequential scalar tail.
pub fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let blocks = n / 8;
    let mut s = [0.0f32; 8];
    for c in 0..blocks {
        let i = 8 * c;
        for (l, sl) in s.iter_mut().enumerate() {
            *sl += a[i + l] * b[i + l];
        }
    }
    let mut acc = ((s[0] + s[1]) + (s[2] + s[3])) + ((s[4] + s[5]) + (s[6] + s[7]));
    for i in 8 * blocks..n {
        acc += a[i] * b[i];
    }
    acc
}

fn dot4_scalar(q: &[f32], b0: &[f32], b1: &[f32], b2: &[f32], b3: &[f32]) -> [f32; 4] {
    [dot_scalar(q, b0), dot_scalar(q, b1), dot_scalar(q, b2), dot_scalar(q, b3)]
}

fn rank1_update_scalar(acc: &mut [f32], row: &[f32], qv: f32) {
    debug_assert_eq!(acc.len(), row.len());
    for (a, &b) in acc.iter_mut().zip(row.iter()) {
        *a += qv * b;
    }
}

/// One butterfly group over `[lo, hi)` — shared by the scalar kernel and
/// every vector kernel's tail, and called directly (not through the
/// table) by the FFT's short stages, where a dispatch per 2-element
/// group would cost more than the butterflies.
#[inline]
pub(crate) fn butterflies_scalar_range(
    ra: &mut [f32],
    ia: &mut [f32],
    rb: &mut [f32],
    ib: &mut [f32],
    wr: &[f32],
    wi: &[f32],
    inverse: bool,
    lo: usize,
    hi: usize,
) {
    for k in lo..hi {
        let wik = if inverse { -wi[k] } else { wi[k] };
        let wrk = wr[k];
        let vr = rb[k] * wrk - ib[k] * wik;
        let vi = rb[k] * wik + ib[k] * wrk;
        rb[k] = ra[k] - vr;
        ib[k] = ia[k] - vi;
        ra[k] += vr;
        ia[k] += vi;
    }
}

pub(crate) fn butterflies_scalar(
    ra: &mut [f32],
    ia: &mut [f32],
    rb: &mut [f32],
    ib: &mut [f32],
    wr: &[f32],
    wi: &[f32],
    inverse: bool,
) {
    let half = wr.len();
    debug_assert!(ra.len() == half && ia.len() == half && rb.len() == half && ib.len() == half);
    butterflies_scalar_range(ra, ia, rb, ib, wr, wi, inverse, 0, half);
}

fn transpose4x4_scalar(src: &[f32], ss: usize, dst: &mut [f32], ds: usize) {
    debug_assert!(src.len() >= 3 * ss + 4 && dst.len() >= 3 * ds + 4);
    for r in 0..4 {
        for c in 0..4 {
            dst[c * ds + r] = src[r * ss + c];
        }
    }
}

fn deposit4x4_scalar(out: &mut [f32], base: usize, stride: usize, wu: &[f32; 4], wv: &[f32; 4]) {
    debug_assert!(stride >= 4 && out.len() >= base + 3 * stride + 4);
    for (a, &wva) in wv.iter().enumerate() {
        let row = base + a * stride;
        for (b, &wub) in wu.iter().enumerate() {
            out[row + b] += wva * wub;
        }
    }
}

fn cauchy_row_scalar(
    px: &[f32],
    py: f32,
    yx: f32,
    yy: f32,
    s: &mut [f32],
    vx: &mut [f32],
    vy: &mut [f32],
) {
    let g = px.len();
    debug_assert!(s.len() == g && vx.len() == g && vy.len() == g);
    let dy = yy - py;
    let dy2 = dy * dy;
    for c in 0..g {
        let dx = yx - px[c];
        let t = 1.0 / (1.0 + dx * dx + dy2);
        s[c] += t;
        let t2 = t * t;
        vx[c] += t2 * dx;
        vy[c] += t2 * dy;
    }
}

/// Scalar GD update over points `[lo, hi)` of an interleaved chunk —
/// shared by the scalar kernel and the vector kernels' tails so the
/// sums continue in exact point order.
#[allow(clippy::too_many_arguments)]
fn gd_pairs_scalar(a: &mut GdArgs, lo: usize, hi: usize, out: &mut GdPartial) {
    for i in lo..hi {
        for d in 0..2 {
            let idx = 2 * i + d;
            let g = 4.0 * (a.exaggeration * a.attr[idx] - a.rep[idx] * a.inv_z);
            let same = g * a.vel[idx] > 0.0;
            let raw = if same { a.gains[idx] * GAIN_MUL } else { a.gains[idx] + GAIN_ADD };
            let ng = raw.max(GAIN_MIN);
            a.gains[idx] = ng;
            a.vel[idx] = a.momentum * a.vel[idx] - a.eta * ng * g;
            a.y[idx] += a.vel[idx];
        }
        let (x, yv) = (a.y[2 * i], a.y[2 * i + 1]);
        out.sx += x as f64;
        out.sy += yv as f64;
        if a.track_bbox {
            out.bbox[0] = out.bbox[0].min(x);
            out.bbox[1] = out.bbox[1].min(yv);
            out.bbox[2] = out.bbox[2].max(x);
            out.bbox[3] = out.bbox[3].max(yv);
        }
    }
}

fn gd_update_scalar(mut a: GdArgs) -> GdPartial {
    let m = a.y.len();
    debug_assert!(m % 2 == 0 && a.vel.len() == m && a.gains.len() == m);
    debug_assert!(a.attr.len() >= m && a.rep.len() >= m);
    let mut out = GdPartial::identity();
    gd_pairs_scalar(&mut a, 0, m / 2, &mut out);
    out
}

/// Scalar spectral multiply over entries `[lo, hi)` — shared by the
/// scalar kernel and the vector kernels' tails. Each complex product is
/// `out = c · k` evaluated as `(cr·kr − ci·ki, cr·ki + ci·kr)`; the S
/// channel reads each charge entry before overwriting it.
fn spectral_mul_scalar_range(a: &mut SpectralArgs, lo: usize, hi: usize) {
    for i in lo..hi {
        let cr = a.sre[i];
        let ci = a.sim[i];
        a.sre[i] = cr * a.ks_re[i] - ci * a.ks_im[i];
        a.sim[i] = cr * a.ks_im[i] + ci * a.ks_re[i];
        a.xre[i] = cr * a.kx_re[i] - ci * a.kx_im[i];
        a.xim[i] = cr * a.kx_im[i] + ci * a.kx_re[i];
        a.yre[i] = cr * a.ky_re[i] - ci * a.ky_im[i];
        a.yim[i] = cr * a.ky_im[i] + ci * a.ky_re[i];
    }
}

fn spectral_mul_scalar(mut a: SpectralArgs) {
    let n = a.sre.len();
    debug_assert!(a.sim.len() == n && a.xre.len() == n && a.xim.len() == n);
    debug_assert!(a.yre.len() == n && a.yim.len() == n);
    debug_assert!(a.ks_re.len() == n && a.ks_im.len() == n);
    debug_assert!(a.kx_re.len() == n && a.kx_im.len() == n);
    debug_assert!(a.ky_re.len() == n && a.ky_im.len() == n);
    spectral_mul_scalar_range(&mut a, 0, n);
}

// ---------------------------------------------------------------------
// x86-64 vector kernels. Each `_impl` is a `#[target_feature]` unsafe fn
// wrapped by a safe shim; the shims are only reachable through tables
// gated on `supported()`, so the feature precondition holds at every
// call. No FMA anywhere — see the module-level determinism contract.
// ---------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::{
        butterflies_scalar_range, gd_pairs_scalar, spectral_mul_scalar_range, GdArgs, GdPartial,
        SpectralArgs, GAIN_ADD, GAIN_MIN, GAIN_MUL,
    };
    use std::arch::x86_64::*;

    /// Canonical pairwise horizontal sum: `(l0+l1) + (l2+l3)`.
    #[inline]
    #[target_feature(enable = "sse4.1")]
    unsafe fn hsum4(v: __m128) -> f32 {
        let sw = _mm_shuffle_ps::<0b10_11_00_01>(v, v); // [l1, l0, l3, l2]
        let p = _mm_add_ps(v, sw); // [l0+l1, ., l2+l3, .]
        let hi = _mm_movehl_ps(p, p);
        _mm_cvtss_f32(_mm_add_ss(p, hi))
    }

    // ----- dot / dot4 / rank-1 -----

    pub fn dot_sse(a: &[f32], b: &[f32]) -> f32 {
        unsafe { dot_sse_impl(a, b) }
    }

    #[target_feature(enable = "sse4.1")]
    unsafe fn dot_sse_impl(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let blocks = n / 8;
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        // Chains s0..s3 in acc0, s4..s7 in acc1 — the scalar kernel's
        // eight chains, four per register.
        let mut acc0 = _mm_setzero_ps();
        let mut acc1 = _mm_setzero_ps();
        for c in 0..blocks {
            let i = 8 * c;
            acc0 = _mm_add_ps(acc0, _mm_mul_ps(_mm_loadu_ps(pa.add(i)), _mm_loadu_ps(pb.add(i))));
            acc1 = _mm_add_ps(
                acc1,
                _mm_mul_ps(_mm_loadu_ps(pa.add(i + 4)), _mm_loadu_ps(pb.add(i + 4))),
            );
        }
        let mut acc = hsum4(acc0) + hsum4(acc1);
        for i in 8 * blocks..n {
            acc += a[i] * b[i];
        }
        acc
    }

    pub fn dot_avx2(a: &[f32], b: &[f32]) -> f32 {
        unsafe { dot_avx2_impl(a, b) }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn dot_avx2_impl(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let blocks = n / 8;
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut acc8 = _mm256_setzero_ps();
        for c in 0..blocks {
            let i = 8 * c;
            acc8 = _mm256_add_ps(
                acc8,
                _mm256_mul_ps(_mm256_loadu_ps(pa.add(i)), _mm256_loadu_ps(pb.add(i))),
            );
        }
        let lo = _mm256_castps256_ps128(acc8);
        let hi = _mm256_extractf128_ps::<1>(acc8);
        let mut acc = hsum4(lo) + hsum4(hi);
        for i in 8 * blocks..n {
            acc += a[i] * b[i];
        }
        acc
    }

    pub fn dot4_sse(q: &[f32], b0: &[f32], b1: &[f32], b2: &[f32], b3: &[f32]) -> [f32; 4] {
        unsafe { dot4_sse_impl(q, b0, b1, b2, b3) }
    }

    #[target_feature(enable = "sse4.1")]
    unsafe fn dot4_sse_impl(q: &[f32], b0: &[f32], b1: &[f32], b2: &[f32], b3: &[f32]) -> [f32; 4] {
        let d = q.len();
        debug_assert!(b0.len() == d && b1.len() == d && b2.len() == d && b3.len() == d);
        let blocks = d / 8;
        let pq = q.as_ptr();
        let pbs = [b0.as_ptr(), b1.as_ptr(), b2.as_ptr(), b3.as_ptr()];
        let mut acc = [[_mm_setzero_ps(); 2]; 4];
        for c in 0..blocks {
            let i = 8 * c;
            let q0 = _mm_loadu_ps(pq.add(i));
            let q1 = _mm_loadu_ps(pq.add(i + 4));
            for (aj, &pb) in acc.iter_mut().zip(pbs.iter()) {
                aj[0] = _mm_add_ps(aj[0], _mm_mul_ps(q0, _mm_loadu_ps(pb.add(i))));
                aj[1] = _mm_add_ps(aj[1], _mm_mul_ps(q1, _mm_loadu_ps(pb.add(i + 4))));
            }
        }
        let bs = [b0, b1, b2, b3];
        let mut out = [0.0f32; 4];
        for j in 0..4 {
            let mut s = hsum4(acc[j][0]) + hsum4(acc[j][1]);
            for i in 8 * blocks..d {
                s += q[i] * bs[j][i];
            }
            out[j] = s;
        }
        out
    }

    pub fn dot4_avx2(q: &[f32], b0: &[f32], b1: &[f32], b2: &[f32], b3: &[f32]) -> [f32; 4] {
        unsafe { dot4_avx2_impl(q, b0, b1, b2, b3) }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn dot4_avx2_impl(
        q: &[f32],
        b0: &[f32],
        b1: &[f32],
        b2: &[f32],
        b3: &[f32],
    ) -> [f32; 4] {
        let d = q.len();
        debug_assert!(b0.len() == d && b1.len() == d && b2.len() == d && b3.len() == d);
        let blocks = d / 8;
        let pq = q.as_ptr();
        let pbs = [b0.as_ptr(), b1.as_ptr(), b2.as_ptr(), b3.as_ptr()];
        let mut acc = [_mm256_setzero_ps(); 4];
        for c in 0..blocks {
            let i = 8 * c;
            let qv = _mm256_loadu_ps(pq.add(i));
            for (aj, &pb) in acc.iter_mut().zip(pbs.iter()) {
                *aj = _mm256_add_ps(*aj, _mm256_mul_ps(qv, _mm256_loadu_ps(pb.add(i))));
            }
        }
        let bs = [b0, b1, b2, b3];
        let mut out = [0.0f32; 4];
        for j in 0..4 {
            let lo = _mm256_castps256_ps128(acc[j]);
            let hi = _mm256_extractf128_ps::<1>(acc[j]);
            let mut s = hsum4(lo) + hsum4(hi);
            for i in 8 * blocks..d {
                s += q[i] * bs[j][i];
            }
            out[j] = s;
        }
        out
    }

    pub fn rank1_update_sse(acc: &mut [f32], row: &[f32], qv: f32) {
        unsafe { rank1_update_sse_impl(acc, row, qv) }
    }

    #[target_feature(enable = "sse4.1")]
    unsafe fn rank1_update_sse_impl(acc: &mut [f32], row: &[f32], qv: f32) {
        debug_assert_eq!(acc.len(), row.len());
        let n = acc.len();
        let blocks = n / 4;
        let qs = _mm_set1_ps(qv);
        let (pa, pr) = (acc.as_mut_ptr(), row.as_ptr());
        for c in 0..blocks {
            let i = 4 * c;
            let v = _mm_add_ps(_mm_loadu_ps(pa.add(i)), _mm_mul_ps(qs, _mm_loadu_ps(pr.add(i))));
            _mm_storeu_ps(pa.add(i), v);
        }
        for i in 4 * blocks..n {
            acc[i] += qv * row[i];
        }
    }

    pub fn rank1_update_avx2(acc: &mut [f32], row: &[f32], qv: f32) {
        unsafe { rank1_update_avx2_impl(acc, row, qv) }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn rank1_update_avx2_impl(acc: &mut [f32], row: &[f32], qv: f32) {
        debug_assert_eq!(acc.len(), row.len());
        let n = acc.len();
        let blocks = n / 8;
        let qs = _mm256_set1_ps(qv);
        let (pa, pr) = (acc.as_mut_ptr(), row.as_ptr());
        for c in 0..blocks {
            let i = 8 * c;
            let v = _mm256_add_ps(
                _mm256_loadu_ps(pa.add(i)),
                _mm256_mul_ps(qs, _mm256_loadu_ps(pr.add(i))),
            );
            _mm256_storeu_ps(pa.add(i), v);
        }
        for i in 8 * blocks..n {
            acc[i] += qv * row[i];
        }
    }

    // ----- FFT butterflies + transpose tile -----

    pub fn butterflies_sse(
        ra: &mut [f32],
        ia: &mut [f32],
        rb: &mut [f32],
        ib: &mut [f32],
        wr: &[f32],
        wi: &[f32],
        inverse: bool,
    ) {
        unsafe { butterflies_sse_impl(ra, ia, rb, ib, wr, wi, inverse) }
    }

    #[target_feature(enable = "sse4.1")]
    unsafe fn butterflies_sse_impl(
        ra: &mut [f32],
        ia: &mut [f32],
        rb: &mut [f32],
        ib: &mut [f32],
        wr: &[f32],
        wi: &[f32],
        inverse: bool,
    ) {
        let half = wr.len();
        debug_assert!(ra.len() == half && ia.len() == half && rb.len() == half && ib.len() == half);
        let blocks = half / 4;
        let sign = _mm_set1_ps(-0.0);
        for c in 0..blocks {
            let k = 4 * c;
            let wrv = _mm_loadu_ps(wr.as_ptr().add(k));
            let mut wiv = _mm_loadu_ps(wi.as_ptr().add(k));
            if inverse {
                wiv = _mm_xor_ps(wiv, sign);
            }
            let rbv = _mm_loadu_ps(rb.as_ptr().add(k));
            let ibv = _mm_loadu_ps(ib.as_ptr().add(k));
            let vr = _mm_sub_ps(_mm_mul_ps(rbv, wrv), _mm_mul_ps(ibv, wiv));
            let vi = _mm_add_ps(_mm_mul_ps(rbv, wiv), _mm_mul_ps(ibv, wrv));
            let rav = _mm_loadu_ps(ra.as_ptr().add(k));
            let iav = _mm_loadu_ps(ia.as_ptr().add(k));
            _mm_storeu_ps(rb.as_mut_ptr().add(k), _mm_sub_ps(rav, vr));
            _mm_storeu_ps(ib.as_mut_ptr().add(k), _mm_sub_ps(iav, vi));
            _mm_storeu_ps(ra.as_mut_ptr().add(k), _mm_add_ps(rav, vr));
            _mm_storeu_ps(ia.as_mut_ptr().add(k), _mm_add_ps(iav, vi));
        }
        butterflies_scalar_range(ra, ia, rb, ib, wr, wi, inverse, 4 * blocks, half);
    }

    pub fn butterflies_avx2(
        ra: &mut [f32],
        ia: &mut [f32],
        rb: &mut [f32],
        ib: &mut [f32],
        wr: &[f32],
        wi: &[f32],
        inverse: bool,
    ) {
        unsafe { butterflies_avx2_impl(ra, ia, rb, ib, wr, wi, inverse) }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn butterflies_avx2_impl(
        ra: &mut [f32],
        ia: &mut [f32],
        rb: &mut [f32],
        ib: &mut [f32],
        wr: &[f32],
        wi: &[f32],
        inverse: bool,
    ) {
        let half = wr.len();
        debug_assert!(ra.len() == half && ia.len() == half && rb.len() == half && ib.len() == half);
        let blocks = half / 8;
        let sign = _mm256_set1_ps(-0.0);
        for c in 0..blocks {
            let k = 8 * c;
            let wrv = _mm256_loadu_ps(wr.as_ptr().add(k));
            let mut wiv = _mm256_loadu_ps(wi.as_ptr().add(k));
            if inverse {
                wiv = _mm256_xor_ps(wiv, sign);
            }
            let rbv = _mm256_loadu_ps(rb.as_ptr().add(k));
            let ibv = _mm256_loadu_ps(ib.as_ptr().add(k));
            let vr = _mm256_sub_ps(_mm256_mul_ps(rbv, wrv), _mm256_mul_ps(ibv, wiv));
            let vi = _mm256_add_ps(_mm256_mul_ps(rbv, wiv), _mm256_mul_ps(ibv, wrv));
            let rav = _mm256_loadu_ps(ra.as_ptr().add(k));
            let iav = _mm256_loadu_ps(ia.as_ptr().add(k));
            _mm256_storeu_ps(rb.as_mut_ptr().add(k), _mm256_sub_ps(rav, vr));
            _mm256_storeu_ps(ib.as_mut_ptr().add(k), _mm256_sub_ps(iav, vi));
            _mm256_storeu_ps(ra.as_mut_ptr().add(k), _mm256_add_ps(rav, vr));
            _mm256_storeu_ps(ia.as_mut_ptr().add(k), _mm256_add_ps(iav, vi));
        }
        butterflies_scalar_range(ra, ia, rb, ib, wr, wi, inverse, 8 * blocks, half);
    }

    pub fn transpose4x4_sse(src: &[f32], ss: usize, dst: &mut [f32], ds: usize) {
        unsafe { transpose4x4_sse_impl(src, ss, dst, ds) }
    }

    #[target_feature(enable = "sse4.1")]
    unsafe fn transpose4x4_sse_impl(src: &[f32], ss: usize, dst: &mut [f32], ds: usize) {
        assert!(src.len() >= 3 * ss + 4 && dst.len() >= 3 * ds + 4);
        let p = src.as_ptr();
        let mut r0 = _mm_loadu_ps(p);
        let mut r1 = _mm_loadu_ps(p.add(ss));
        let mut r2 = _mm_loadu_ps(p.add(2 * ss));
        let mut r3 = _mm_loadu_ps(p.add(3 * ss));
        _MM_TRANSPOSE4_PS(&mut r0, &mut r1, &mut r2, &mut r3);
        let q = dst.as_mut_ptr();
        _mm_storeu_ps(q, r0);
        _mm_storeu_ps(q.add(ds), r1);
        _mm_storeu_ps(q.add(2 * ds), r2);
        _mm_storeu_ps(q.add(3 * ds), r3);
    }

    // ----- field deposit / gather row -----

    pub fn deposit4x4_sse(
        out: &mut [f32],
        base: usize,
        stride: usize,
        wu: &[f32; 4],
        wv: &[f32; 4],
    ) {
        unsafe { deposit4x4_sse_impl(out, base, stride, wu, wv) }
    }

    #[target_feature(enable = "sse4.1")]
    unsafe fn deposit4x4_sse_impl(
        out: &mut [f32],
        base: usize,
        stride: usize,
        wu: &[f32; 4],
        wv: &[f32; 4],
    ) {
        assert!(stride >= 4 && out.len() >= base + 3 * stride + 4);
        let wuv = _mm_loadu_ps(wu.as_ptr());
        for (a, &wva) in wv.iter().enumerate() {
            let p = out.as_mut_ptr().add(base + a * stride);
            let v = _mm_add_ps(_mm_loadu_ps(p), _mm_mul_ps(_mm_set1_ps(wva), wuv));
            _mm_storeu_ps(p, v);
        }
    }

    pub fn cauchy_row_sse(
        px: &[f32],
        py: f32,
        yx: f32,
        yy: f32,
        s: &mut [f32],
        vx: &mut [f32],
        vy: &mut [f32],
    ) {
        unsafe { cauchy_row_sse_impl(px, py, yx, yy, s, vx, vy) }
    }

    #[target_feature(enable = "sse4.1")]
    unsafe fn cauchy_row_sse_impl(
        px: &[f32],
        py: f32,
        yx: f32,
        yy: f32,
        s: &mut [f32],
        vx: &mut [f32],
        vy: &mut [f32],
    ) {
        let g = px.len();
        debug_assert!(s.len() == g && vx.len() == g && vy.len() == g);
        let dy = yy - py;
        let dy2 = dy * dy;
        let blocks = g / 4;
        let yxv = _mm_set1_ps(yx);
        let dyv = _mm_set1_ps(dy);
        let dy2v = _mm_set1_ps(dy2);
        let one = _mm_set1_ps(1.0);
        for c in 0..blocks {
            let i = 4 * c;
            let dx = _mm_sub_ps(yxv, _mm_loadu_ps(px.as_ptr().add(i)));
            let den = _mm_add_ps(_mm_add_ps(one, _mm_mul_ps(dx, dx)), dy2v);
            let t = _mm_div_ps(one, den);
            let ps = s.as_mut_ptr().add(i);
            _mm_storeu_ps(ps, _mm_add_ps(_mm_loadu_ps(ps), t));
            let t2 = _mm_mul_ps(t, t);
            let pvx = vx.as_mut_ptr().add(i);
            _mm_storeu_ps(pvx, _mm_add_ps(_mm_loadu_ps(pvx), _mm_mul_ps(t2, dx)));
            let pvy = vy.as_mut_ptr().add(i);
            _mm_storeu_ps(pvy, _mm_add_ps(_mm_loadu_ps(pvy), _mm_mul_ps(t2, dyv)));
        }
        for c in 4 * blocks..g {
            let dx = yx - px[c];
            let t = 1.0 / (1.0 + dx * dx + dy2);
            s[c] += t;
            let t2 = t * t;
            vx[c] += t2 * dx;
            vy[c] += t2 * dy;
        }
    }

    pub fn cauchy_row_avx2(
        px: &[f32],
        py: f32,
        yx: f32,
        yy: f32,
        s: &mut [f32],
        vx: &mut [f32],
        vy: &mut [f32],
    ) {
        unsafe { cauchy_row_avx2_impl(px, py, yx, yy, s, vx, vy) }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn cauchy_row_avx2_impl(
        px: &[f32],
        py: f32,
        yx: f32,
        yy: f32,
        s: &mut [f32],
        vx: &mut [f32],
        vy: &mut [f32],
    ) {
        let g = px.len();
        debug_assert!(s.len() == g && vx.len() == g && vy.len() == g);
        let dy = yy - py;
        let dy2 = dy * dy;
        let blocks = g / 8;
        let yxv = _mm256_set1_ps(yx);
        let dyv = _mm256_set1_ps(dy);
        let dy2v = _mm256_set1_ps(dy2);
        let one = _mm256_set1_ps(1.0);
        for c in 0..blocks {
            let i = 8 * c;
            let dx = _mm256_sub_ps(yxv, _mm256_loadu_ps(px.as_ptr().add(i)));
            let den = _mm256_add_ps(_mm256_add_ps(one, _mm256_mul_ps(dx, dx)), dy2v);
            let t = _mm256_div_ps(one, den);
            let ps = s.as_mut_ptr().add(i);
            _mm256_storeu_ps(ps, _mm256_add_ps(_mm256_loadu_ps(ps), t));
            let t2 = _mm256_mul_ps(t, t);
            let pvx = vx.as_mut_ptr().add(i);
            _mm256_storeu_ps(pvx, _mm256_add_ps(_mm256_loadu_ps(pvx), _mm256_mul_ps(t2, dx)));
            let pvy = vy.as_mut_ptr().add(i);
            _mm256_storeu_ps(pvy, _mm256_add_ps(_mm256_loadu_ps(pvy), _mm256_mul_ps(t2, dyv)));
        }
        for c in 8 * blocks..g {
            let dx = yx - px[c];
            let t = 1.0 / (1.0 + dx * dx + dy2);
            s[c] += t;
            let t2 = t * t;
            vx[c] += t2 * dx;
            vy[c] += t2 * dy;
        }
    }

    // ----- fused GD update -----

    pub fn gd_update_sse(a: GdArgs) -> GdPartial {
        unsafe { gd_update_sse_impl(a) }
    }

    #[target_feature(enable = "sse4.1")]
    unsafe fn gd_update_sse_impl(mut a: GdArgs) -> GdPartial {
        let m = a.y.len();
        debug_assert!(m % 2 == 0 && a.vel.len() == m && a.gains.len() == m);
        debug_assert!(a.attr.len() >= m && a.rep.len() >= m);
        let mut out = GdPartial::identity();
        let four = _mm_set1_ps(4.0);
        let exv = _mm_set1_ps(a.exaggeration);
        let izv = _mm_set1_ps(a.inv_z);
        let etav = _mm_set1_ps(a.eta);
        let momv = _mm_set1_ps(a.momentum);
        let gmin = _mm_set1_ps(GAIN_MIN);
        let gmul = _mm_set1_ps(GAIN_MUL);
        let gadd = _mm_set1_ps(GAIN_ADD);
        let zero = _mm_setzero_ps();
        // Lanes alternate [x, y, x, y]; the f64 mean accumulates in
        // point order (two sequential pd adds per vector), matching the
        // scalar reference exactly.
        let mut acc = _mm_setzero_pd();
        let mut bmin = _mm_set1_ps(f32::INFINITY);
        let mut bmax = _mm_set1_ps(f32::NEG_INFINITY);
        let (py, pv, pg) = (a.y.as_mut_ptr(), a.vel.as_mut_ptr(), a.gains.as_mut_ptr());
        let (pa, pr) = (a.attr.as_ptr(), a.rep.as_ptr());
        let mut idx = 0usize;
        while idx + 4 <= m {
            let at = _mm_loadu_ps(pa.add(idx));
            let rp = _mm_loadu_ps(pr.add(idx));
            let g = _mm_mul_ps(four, _mm_sub_ps(_mm_mul_ps(exv, at), _mm_mul_ps(rp, izv)));
            let v = _mm_loadu_ps(pv.add(idx));
            let gn = _mm_loadu_ps(pg.add(idx));
            let same = _mm_cmpgt_ps(_mm_mul_ps(g, v), zero);
            let raw = _mm_blendv_ps(_mm_add_ps(gn, gadd), _mm_mul_ps(gn, gmul), same);
            let ng = _mm_max_ps(raw, gmin);
            _mm_storeu_ps(pg.add(idx), ng);
            let nv = _mm_sub_ps(_mm_mul_ps(momv, v), _mm_mul_ps(_mm_mul_ps(etav, ng), g));
            _mm_storeu_ps(pv.add(idx), nv);
            let ny = _mm_add_ps(_mm_loadu_ps(py.add(idx)), nv);
            _mm_storeu_ps(py.add(idx), ny);
            acc = _mm_add_pd(acc, _mm_cvtps_pd(ny));
            acc = _mm_add_pd(acc, _mm_cvtps_pd(_mm_movehl_ps(ny, ny)));
            if a.track_bbox {
                bmin = _mm_min_ps(bmin, ny);
                bmax = _mm_max_ps(bmax, ny);
            }
            idx += 4;
        }
        let mut sums = [0.0f64; 2];
        _mm_storeu_pd(sums.as_mut_ptr(), acc);
        out.sx = sums[0];
        out.sy = sums[1];
        if a.track_bbox {
            let (mut bn, mut bx) = ([0.0f32; 4], [0.0f32; 4]);
            _mm_storeu_ps(bn.as_mut_ptr(), bmin);
            _mm_storeu_ps(bx.as_mut_ptr(), bmax);
            out.bbox = [bn[0].min(bn[2]), bn[1].min(bn[3]), bx[0].max(bx[2]), bx[1].max(bx[3])];
        }
        gd_pairs_scalar(&mut a, idx / 2, m / 2, &mut out);
        out
    }

    pub fn gd_update_avx2(a: GdArgs) -> GdPartial {
        unsafe { gd_update_avx2_impl(a) }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn gd_update_avx2_impl(mut a: GdArgs) -> GdPartial {
        let m = a.y.len();
        debug_assert!(m % 2 == 0 && a.vel.len() == m && a.gains.len() == m);
        debug_assert!(a.attr.len() >= m && a.rep.len() >= m);
        let mut out = GdPartial::identity();
        let four = _mm256_set1_ps(4.0);
        let exv = _mm256_set1_ps(a.exaggeration);
        let izv = _mm256_set1_ps(a.inv_z);
        let etav = _mm256_set1_ps(a.eta);
        let momv = _mm256_set1_ps(a.momentum);
        let gmin = _mm256_set1_ps(GAIN_MIN);
        let gmul = _mm256_set1_ps(GAIN_MUL);
        let gadd = _mm256_set1_ps(GAIN_ADD);
        let zero = _mm256_setzero_ps();
        let mut acc = _mm_setzero_pd();
        let mut bmin = _mm256_set1_ps(f32::INFINITY);
        let mut bmax = _mm256_set1_ps(f32::NEG_INFINITY);
        let (py, pv, pg) = (a.y.as_mut_ptr(), a.vel.as_mut_ptr(), a.gains.as_mut_ptr());
        let (pa, pr) = (a.attr.as_ptr(), a.rep.as_ptr());
        let mut idx = 0usize;
        while idx + 8 <= m {
            let at = _mm256_loadu_ps(pa.add(idx));
            let rp = _mm256_loadu_ps(pr.add(idx));
            let g =
                _mm256_mul_ps(four, _mm256_sub_ps(_mm256_mul_ps(exv, at), _mm256_mul_ps(rp, izv)));
            let v = _mm256_loadu_ps(pv.add(idx));
            let gn = _mm256_loadu_ps(pg.add(idx));
            let same = _mm256_cmp_ps::<_CMP_GT_OQ>(_mm256_mul_ps(g, v), zero);
            let raw = _mm256_blendv_ps(_mm256_add_ps(gn, gadd), _mm256_mul_ps(gn, gmul), same);
            let ng = _mm256_max_ps(raw, gmin);
            _mm256_storeu_ps(pg.add(idx), ng);
            let nv = _mm256_sub_ps(
                _mm256_mul_ps(momv, v),
                _mm256_mul_ps(_mm256_mul_ps(etav, ng), g),
            );
            _mm256_storeu_ps(pv.add(idx), nv);
            let ny = _mm256_add_ps(_mm256_loadu_ps(py.add(idx)), nv);
            _mm256_storeu_ps(py.add(idx), ny);
            let lo = _mm256_castps256_ps128(ny);
            let hi = _mm256_extractf128_ps::<1>(ny);
            acc = _mm_add_pd(acc, _mm_cvtps_pd(lo));
            acc = _mm_add_pd(acc, _mm_cvtps_pd(_mm_movehl_ps(lo, lo)));
            acc = _mm_add_pd(acc, _mm_cvtps_pd(hi));
            acc = _mm_add_pd(acc, _mm_cvtps_pd(_mm_movehl_ps(hi, hi)));
            if a.track_bbox {
                bmin = _mm256_min_ps(bmin, ny);
                bmax = _mm256_max_ps(bmax, ny);
            }
            idx += 8;
        }
        let mut sums = [0.0f64; 2];
        _mm_storeu_pd(sums.as_mut_ptr(), acc);
        out.sx = sums[0];
        out.sy = sums[1];
        if a.track_bbox {
            let (mut bn, mut bx) = ([0.0f32; 8], [0.0f32; 8]);
            _mm256_storeu_ps(bn.as_mut_ptr(), bmin);
            _mm256_storeu_ps(bx.as_mut_ptr(), bmax);
            out.bbox = [
                bn[0].min(bn[2]).min(bn[4].min(bn[6])),
                bn[1].min(bn[3]).min(bn[5].min(bn[7])),
                bx[0].max(bx[2]).max(bx[4].max(bx[6])),
                bx[1].max(bx[3]).max(bx[5].max(bx[7])),
            ];
        }
        gd_pairs_scalar(&mut a, idx / 2, m / 2, &mut out);
        out
    }

    // ----- fused spectral multiply -----

    pub fn spectral_mul_sse(a: SpectralArgs) {
        unsafe { spectral_mul_sse_impl(a) }
    }

    #[target_feature(enable = "sse4.1")]
    unsafe fn spectral_mul_sse_impl(mut a: SpectralArgs) {
        let n = a.sre.len();
        debug_assert!(a.sim.len() == n && a.xre.len() == n && a.xim.len() == n);
        debug_assert!(a.yre.len() == n && a.yim.len() == n);
        debug_assert!(a.ks_re.len() == n && a.ks_im.len() == n);
        debug_assert!(a.kx_re.len() == n && a.kx_im.len() == n);
        debug_assert!(a.ky_re.len() == n && a.ky_im.len() == n);
        let blocks = n / 4;
        for c in 0..blocks {
            let i = 4 * c;
            // Charge entries load before the S-channel store overwrites
            // them — the in-place hazard the scalar reference carries.
            let cr = _mm_loadu_ps(a.sre.as_ptr().add(i));
            let ci = _mm_loadu_ps(a.sim.as_ptr().add(i));
            let kr = _mm_loadu_ps(a.ks_re.as_ptr().add(i));
            let ki = _mm_loadu_ps(a.ks_im.as_ptr().add(i));
            _mm_storeu_ps(
                a.sre.as_mut_ptr().add(i),
                _mm_sub_ps(_mm_mul_ps(cr, kr), _mm_mul_ps(ci, ki)),
            );
            _mm_storeu_ps(
                a.sim.as_mut_ptr().add(i),
                _mm_add_ps(_mm_mul_ps(cr, ki), _mm_mul_ps(ci, kr)),
            );
            let kr = _mm_loadu_ps(a.kx_re.as_ptr().add(i));
            let ki = _mm_loadu_ps(a.kx_im.as_ptr().add(i));
            _mm_storeu_ps(
                a.xre.as_mut_ptr().add(i),
                _mm_sub_ps(_mm_mul_ps(cr, kr), _mm_mul_ps(ci, ki)),
            );
            _mm_storeu_ps(
                a.xim.as_mut_ptr().add(i),
                _mm_add_ps(_mm_mul_ps(cr, ki), _mm_mul_ps(ci, kr)),
            );
            let kr = _mm_loadu_ps(a.ky_re.as_ptr().add(i));
            let ki = _mm_loadu_ps(a.ky_im.as_ptr().add(i));
            _mm_storeu_ps(
                a.yre.as_mut_ptr().add(i),
                _mm_sub_ps(_mm_mul_ps(cr, kr), _mm_mul_ps(ci, ki)),
            );
            _mm_storeu_ps(
                a.yim.as_mut_ptr().add(i),
                _mm_add_ps(_mm_mul_ps(cr, ki), _mm_mul_ps(ci, kr)),
            );
        }
        spectral_mul_scalar_range(&mut a, 4 * blocks, n);
    }

    pub fn spectral_mul_avx2(a: SpectralArgs) {
        unsafe { spectral_mul_avx2_impl(a) }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn spectral_mul_avx2_impl(mut a: SpectralArgs) {
        let n = a.sre.len();
        debug_assert!(a.sim.len() == n && a.xre.len() == n && a.xim.len() == n);
        debug_assert!(a.yre.len() == n && a.yim.len() == n);
        debug_assert!(a.ks_re.len() == n && a.ks_im.len() == n);
        debug_assert!(a.kx_re.len() == n && a.kx_im.len() == n);
        debug_assert!(a.ky_re.len() == n && a.ky_im.len() == n);
        let blocks = n / 8;
        for c in 0..blocks {
            let i = 8 * c;
            let cr = _mm256_loadu_ps(a.sre.as_ptr().add(i));
            let ci = _mm256_loadu_ps(a.sim.as_ptr().add(i));
            let kr = _mm256_loadu_ps(a.ks_re.as_ptr().add(i));
            let ki = _mm256_loadu_ps(a.ks_im.as_ptr().add(i));
            _mm256_storeu_ps(
                a.sre.as_mut_ptr().add(i),
                _mm256_sub_ps(_mm256_mul_ps(cr, kr), _mm256_mul_ps(ci, ki)),
            );
            _mm256_storeu_ps(
                a.sim.as_mut_ptr().add(i),
                _mm256_add_ps(_mm256_mul_ps(cr, ki), _mm256_mul_ps(ci, kr)),
            );
            let kr = _mm256_loadu_ps(a.kx_re.as_ptr().add(i));
            let ki = _mm256_loadu_ps(a.kx_im.as_ptr().add(i));
            _mm256_storeu_ps(
                a.xre.as_mut_ptr().add(i),
                _mm256_sub_ps(_mm256_mul_ps(cr, kr), _mm256_mul_ps(ci, ki)),
            );
            _mm256_storeu_ps(
                a.xim.as_mut_ptr().add(i),
                _mm256_add_ps(_mm256_mul_ps(cr, ki), _mm256_mul_ps(ci, kr)),
            );
            let kr = _mm256_loadu_ps(a.ky_re.as_ptr().add(i));
            let ki = _mm256_loadu_ps(a.ky_im.as_ptr().add(i));
            _mm256_storeu_ps(
                a.yre.as_mut_ptr().add(i),
                _mm256_sub_ps(_mm256_mul_ps(cr, kr), _mm256_mul_ps(ci, ki)),
            );
            _mm256_storeu_ps(
                a.yim.as_mut_ptr().add(i),
                _mm256_add_ps(_mm256_mul_ps(cr, ki), _mm256_mul_ps(ci, kr)),
            );
        }
        spectral_mul_scalar_range(&mut a, 8 * blocks, n);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tier_names_roundtrip() {
        for t in Tier::ALL {
            assert_eq!(Tier::parse(t.name()), Some(t));
        }
        assert_eq!(Tier::parse("bogus"), None);
    }

    #[test]
    fn detection_is_supported_and_active_defaults_to_it() {
        let det = detected_tier();
        assert!(supported(det));
        // Whatever the environment forced, the active tier must be
        // runnable here.
        assert!(supported(active_tier()));
    }

    #[test]
    fn scalar_dot_matches_naive_reduction() {
        let a: Vec<f32> = (0..37).map(|i| (i as f32).sin()).collect();
        let b: Vec<f32> = (0..37).map(|i| (i as f32).cos()).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot_scalar(&a, &b) - naive).abs() < 1e-4);
    }

    #[test]
    fn every_supported_tier_matches_scalar_bitwise_on_dot() {
        let a: Vec<f32> = (0..131).map(|i| ((i * 37) as f32).sin() * 3.0).collect();
        let b: Vec<f32> = (0..131).map(|i| ((i * 11) as f32).cos() * 0.5).collect();
        let want = dot_scalar(&a, &b);
        for t in Tier::ALL {
            if !supported(t) {
                continue;
            }
            let got = (Kernels::for_tier(t).dot)(&a, &b);
            assert_eq!(got.to_bits(), want.to_bits(), "tier {}", t.name());
        }
    }

    #[test]
    fn status_json_has_tier_fields() {
        let s = status_json().to_string();
        assert!(s.contains("\"tier\"") && s.contains("\"detected\"") && s.contains("\"source\""));
    }
}
