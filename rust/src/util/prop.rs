//! Property-based testing mini-framework (`proptest` is unavailable
//! offline).
//!
//! Deterministic-by-default randomized testing with typed generators and
//! greedy shrinking: on failure, the failing case is repeatedly simplified
//! (halving sizes / magnitudes) while it still fails, and the minimal
//! reproduction is reported together with its seed. Used by
//! `rust/tests/properties.rs` for the coordinator/substrate invariants.

use crate::util::rng::Rng;

/// Number of cases per property: `GPGPU_SNE_PROP_CASES` (default 64).
pub fn cases() -> usize {
    std::env::var("GPGPU_SNE_PROP_CASES").ok().and_then(|v| v.parse().ok()).unwrap_or(64)
}

/// A value generator with an optional shrinker.
pub struct Gen<T> {
    #[allow(clippy::type_complexity)]
    gen: Box<dyn Fn(&mut Rng) -> T>,
    #[allow(clippy::type_complexity)]
    shrink: Box<dyn Fn(&T) -> Vec<T>>,
}

impl<T: Clone + std::fmt::Debug + 'static> Gen<T> {
    pub fn new(gen: impl Fn(&mut Rng) -> T + 'static) -> Self {
        Self { gen: Box::new(gen), shrink: Box::new(|_| Vec::new()) }
    }

    pub fn with_shrink(mut self, shrink: impl Fn(&T) -> Vec<T> + 'static) -> Self {
        self.shrink = Box::new(shrink);
        self
    }

    pub fn sample(&self, rng: &mut Rng) -> T {
        (self.gen)(rng)
    }

    /// Map the generated value (shrinking is dropped across the map).
    pub fn map<U: Clone + std::fmt::Debug + 'static>(
        self,
        f: impl Fn(T) -> U + 'static,
    ) -> Gen<U> {
        Gen::new(move |r| f((self.gen)(r)))
    }
}

/// usize in [lo, hi], shrinking toward lo.
pub fn usize_in(lo: usize, hi: usize) -> Gen<usize> {
    assert!(lo <= hi);
    Gen::new(move |r| lo + r.below(hi - lo + 1)).with_shrink(move |&v| {
        let mut out = Vec::new();
        if v > lo {
            out.push(lo);
            out.push(lo + (v - lo) / 2);
            out.push(v - 1);
        }
        out.dedup();
        out
    })
}

/// f32 in [lo, hi], shrinking toward 0 (clamped into range).
pub fn f32_in(lo: f32, hi: f32) -> Gen<f32> {
    Gen::new(move |r| lo + (hi - lo) * r.f32()).with_shrink(move |&v| {
        let z = 0.0f32.clamp(lo, hi);
        if (v - z).abs() < 1e-6 {
            Vec::new()
        } else {
            vec![z, z + (v - z) / 2.0]
        }
    })
}

/// Vec of f32s with length in [min_len, max_len], values in [lo, hi];
/// shrinks by halving the length, then zeroing elements.
pub fn vec_f32(min_len: usize, max_len: usize, lo: f32, hi: f32) -> Gen<Vec<f32>> {
    assert!(min_len <= max_len);
    Gen::new(move |r| {
        let n = min_len + r.below(max_len - min_len + 1);
        (0..n).map(|_| lo + (hi - lo) * r.f32()).collect()
    })
    .with_shrink(move |v: &Vec<f32>| {
        let mut out = Vec::new();
        if v.len() > min_len {
            let half = min_len.max(v.len() / 2);
            out.push(v[..half].to_vec());
            out.push(v[..v.len() - 1].to_vec());
        }
        if v.iter().any(|&x| x != 0.0 && (0.0f32) >= lo && 0.0 <= hi) {
            let mut z = v.clone();
            for x in z.iter_mut() {
                *x /= 2.0;
            }
            out.push(z);
        }
        out
    })
}

/// 2-D point set (flattened row-major), n in [min_n, max_n].
pub fn points2d(min_n: usize, max_n: usize, extent: f32) -> Gen<Vec<f32>> {
    Gen::new(move |r| {
        let n = min_n + r.below(max_n - min_n + 1);
        (0..2 * n).map(|_| (r.f32() * 2.0 - 1.0) * extent).collect()
    })
    .with_shrink(move |v: &Vec<f32>| {
        let n = v.len() / 2;
        let mut out = Vec::new();
        if n > min_n {
            out.push(v[..2 * (min_n.max(n / 2))].to_vec());
            out.push(v[..2 * (n - 1)].to_vec());
        }
        out
    })
}

/// The outcome of `check`: panics on failure with the minimal case.
pub fn check<T: Clone + std::fmt::Debug + 'static>(
    name: &str,
    gen: &Gen<T>,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    let seed = std::env::var("GPGPU_SNE_PROP_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0xC0FFEE_u64);
    let mut rng = Rng::new(seed ^ hash_name(name));
    for case in 0..cases() {
        let value = gen.sample(&mut rng);
        if let Err(msg) = prop(&value) {
            // Greedy shrink: keep the first simplification that still fails.
            let mut cur = value;
            let mut cur_msg = msg;
            let mut rounds = 0;
            'outer: while rounds < 200 {
                rounds += 1;
                for cand in (gen.shrink)(&cur) {
                    if let Err(m) = prop(&cand) {
                        cur = cand;
                        cur_msg = m;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property '{name}' failed (case {case}, seed {seed}):\n  minimal input: {cur:?}\n  error: {cur_msg}"
            );
        }
    }
}

/// Check over pairs of independent generators.
pub fn check2<A: Clone + std::fmt::Debug + 'static, B: Clone + std::fmt::Debug + 'static>(
    name: &str,
    ga: &Gen<A>,
    gb: &Gen<B>,
    prop: impl Fn(&A, &B) -> Result<(), String>,
) {
    let seed = std::env::var("GPGPU_SNE_PROP_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0xC0FFEE_u64);
    let mut rng = Rng::new(seed ^ hash_name(name));
    for case in 0..cases() {
        let a = ga.sample(&mut rng);
        let b = gb.sample(&mut rng);
        if let Err(msg) = prop(&a, &b) {
            panic!("property '{name}' failed (case {case}, seed {seed}):\n  a: {a:?}\n  b: {b:?}\n  error: {msg}");
        }
    }
}

fn hash_name(name: &str) -> u64 {
    crate::util::hash::fnv1a(name.as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("sum nonneg", &vec_f32(0, 20, 0.0, 1.0), |v| {
            if v.iter().sum::<f32>() >= 0.0 {
                Ok(())
            } else {
                Err("negative".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_minimal_case() {
        check("always fails", &usize_in(0, 100), |_| Err("nope".into()));
    }

    #[test]
    fn shrinking_reaches_small_case() {
        // Property fails for v.len() >= 3; the shrinker should find len 3.
        let result = std::panic::catch_unwind(|| {
            check("len<3", &vec_f32(0, 64, 0.0, 1.0), |v| {
                if v.len() < 3 {
                    Ok(())
                } else {
                    Err(format!("len {}", v.len()))
                }
            });
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // Minimal reproduction should have been shrunk well below 64.
        assert!(msg.contains("len 3") || msg.contains("len 4"), "got: {msg}");
    }
}
