//! Deterministic PRNG substrate (the `rand` crate is unavailable offline).
//!
//! `SplitMix64` for seeding, `Xoshiro256++` (Blackman & Vigna) as the main
//! generator, plus the distributions the library needs: uniform ranges,
//! Gaussian (Box–Muller with caching), shuffling and sampling without
//! replacement. All algorithms are the reference public-domain versions.

/// SplitMix64 — used to expand a single u64 seed into generator state.
#[derive(Debug, Clone)]
pub struct SplitMix64(pub u64);

impl SplitMix64 {
    pub fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256++ — fast, high-quality, 256-bit state.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box–Muller variate.
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Seed deterministically from a u64.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()], gauss_spare: None }
    }

    /// Derive an independent stream (for per-thread generators).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0].wrapping_add(s[3]).rotate_left(23).wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) — Lemire's unbiased method.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn gauss(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.gauss_spare = Some(r * s);
            return r * c;
        }
    }

    /// Normal with given mean/std as f32.
    #[inline]
    pub fn gauss_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.gauss() as f32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Exponential with rate 1.
    pub fn exp(&mut self) -> f64 {
        -(1.0 - self.f64()).ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_moments() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gauss()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(5);
        let s = r.sample_indices(100, 30);
        let mut sorted = s.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 30);
        assert!(s.iter().all(|&i| i < 100));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
