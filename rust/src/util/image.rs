//! Figure emitters: PGM images (fields, embeddings) and CSV series.
//!
//! The paper's Figures 2 (field textures), 3 (kernel functions) and 5
//! (embeddings) are regenerated as portable graymaps + CSV, keeping the
//! repo free of image-library dependencies.

use std::io::Write;
use std::path::Path;

/// Write a grayscale PGM (P5) from row-major f32 data, min-max normalised.
/// Rows are flipped so increasing y in embedding space points up.
pub fn write_pgm(path: impl AsRef<Path>, data: &[f32], w: usize, h: usize) -> std::io::Result<()> {
    assert_eq!(data.len(), w * h);
    let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
    for &v in data {
        if v.is_finite() {
            lo = lo.min(v);
            hi = hi.max(v);
        }
    }
    let scale = if hi > lo { 255.0 / (hi - lo) } else { 0.0 };
    let mut f = std::fs::File::create(path)?;
    write!(f, "P5\n{w} {h}\n255\n")?;
    let mut bytes = Vec::with_capacity(w * h);
    for row in (0..h).rev() {
        for col in 0..w {
            let v = data[row * w + col];
            bytes.push(if v.is_finite() { ((v - lo) * scale) as u8 } else { 0 });
        }
    }
    f.write_all(&bytes)
}

/// Write a diverging-signed PGM: negative = dark, zero = mid, positive =
/// bright (for the V_x / V_y field channels of Fig. 2c-d).
pub fn write_pgm_signed(
    path: impl AsRef<Path>,
    data: &[f32],
    w: usize,
    h: usize,
) -> std::io::Result<()> {
    assert_eq!(data.len(), w * h);
    let amax = data.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-12);
    let mut f = std::fs::File::create(path)?;
    write!(f, "P5\n{w} {h}\n255\n")?;
    let mut bytes = Vec::with_capacity(w * h);
    for row in (0..h).rev() {
        for col in 0..w {
            let v = data[row * w + col] / amax; // [-1, 1]
            bytes.push((127.5 + 127.5 * v) as u8);
        }
    }
    f.write_all(&bytes)
}

/// Rasterise a labelled 2-D embedding into a PGM scatterplot.
/// Each point paints a small disc whose gray level encodes its label.
pub fn write_embedding_pgm(
    path: impl AsRef<Path>,
    points: &[f32], // (n,2) row-major
    labels: &[u8],
    size: usize,
) -> std::io::Result<()> {
    let n = points.len() / 2;
    assert!(labels.len() >= n);
    let (mut lo_x, mut hi_x, mut lo_y, mut hi_y) =
        (f32::INFINITY, f32::NEG_INFINITY, f32::INFINITY, f32::NEG_INFINITY);
    for i in 0..n {
        lo_x = lo_x.min(points[2 * i]);
        hi_x = hi_x.max(points[2 * i]);
        lo_y = lo_y.min(points[2 * i + 1]);
        hi_y = hi_y.max(points[2 * i + 1]);
    }
    let span = (hi_x - lo_x).max(hi_y - lo_y).max(1e-9);
    let max_label = labels[..n].iter().copied().max().unwrap_or(0).max(1) as f32;
    let mut img = vec![255u8; size * size];
    for i in 0..n {
        let px = ((points[2 * i] - lo_x) / span * (size - 3) as f32) as usize + 1;
        let py = ((points[2 * i + 1] - lo_y) / span * (size - 3) as f32) as usize + 1;
        let shade = 20 + (200.0 * labels[i] as f32 / max_label) as u8;
        for dy in 0..2usize {
            for dx in 0..2usize {
                let x = (px + dx).min(size - 1);
                let y = (py + dy).min(size - 1);
                img[(size - 1 - y) * size + x] = shade;
            }
        }
    }
    let mut f = std::fs::File::create(path)?;
    write!(f, "P5\n{size} {size}\n255\n")?;
    f.write_all(&img)
}

/// Write a CSV of named columns.
pub fn write_csv(
    path: impl AsRef<Path>,
    headers: &[&str],
    columns: &[Vec<f64>],
) -> std::io::Result<()> {
    assert_eq!(headers.len(), columns.len());
    let rows = columns.iter().map(|c| c.len()).max().unwrap_or(0);
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{}", headers.join(","))?;
    for r in 0..rows {
        let cells: Vec<String> = columns
            .iter()
            .map(|c| c.get(r).map(|v| format!("{v}")).unwrap_or_default())
            .collect();
        writeln!(f, "{}", cells.join(","))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pgm_roundtrip_header() {
        let dir = std::env::temp_dir().join(format!("gpgpu_sne_img_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.pgm");
        write_pgm(&p, &[0.0, 0.5, 1.0, 0.25], 2, 2).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        assert!(bytes.starts_with(b"P5\n2 2\n255\n"));
        assert_eq!(bytes.len(), b"P5\n2 2\n255\n".len() + 4);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn signed_pgm_midpoint() {
        let dir = std::env::temp_dir().join(format!("gpgpu_sne_img2_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("s.pgm");
        write_pgm_signed(&p, &[0.0], 1, 1).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        assert_eq!(*bytes.last().unwrap(), 127);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn csv_writes_columns() {
        let dir = std::env::temp_dir().join(format!("gpgpu_sne_csv_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.csv");
        write_csv(&p, &["a", "b"], &[vec![1.0, 2.0], vec![3.0, 4.0]]).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text, "a,b\n1,3\n2,4\n");
        std::fs::remove_dir_all(&dir).ok();
    }
}
