//! Small non-cryptographic hashes shared across the crate.

/// Byte-wise FNV-1a. Used for store-record checksums and filename
/// hashes (`coordinator::store`) and property-test name salting
/// (`util::prop`). `Dataset::fingerprint` deliberately uses a
/// *word*-wise FNV variant instead (one multiply per f32, not per
/// byte — it runs over every dataset value) and must not be unified
/// with this one: the two produce different hashes by design.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Standard FNV-1a 64-bit test vectors.
        assert_eq!(fnv1a(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x85944171f73967e8);
    }
}
