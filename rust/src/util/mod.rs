//! In-repo substrates for crates that are unavailable offline
//! (DESIGN.md S21–S26): PRNG, thread pool, CLI parsing, JSON, base64,
//! property-testing, bench statistics, and figure emitters.

pub mod b64;
pub mod bench;
pub mod cli;
pub mod hash;
pub mod image;
pub mod json;
pub mod parallel;
pub mod prop;
pub mod rng;
pub mod simd;
pub mod timer;
