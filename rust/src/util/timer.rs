//! Wall-clock timing helpers used across benches, examples and the
//! coordinator's progress reporting.

use std::time::{Duration, Instant};

/// A simple scoped timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_s() * 1e3
    }
}

/// A monotonic stopwatch with lap support — the one timing primitive
/// behind the scheduler's quantum accounting (`coordinator::service`)
/// and the observability instrumentation, replacing ad-hoc
/// `Instant` pairs.
///
/// * `elapsed*` reads time since the last [`Stopwatch::restart`] (or
///   construction) without disturbing the lap marker — budget checks
///   ("has this quantum used its 25 ms?") poll it freely.
/// * [`Stopwatch::lap`] returns the time since the previous lap (or
///   start) and advances the lap marker — per-segment splits.
/// * [`Stopwatch::expired`] is the deadline idiom: `sw.expired(budget)`
///   replaces `Instant::now() >= start + budget`.
#[derive(Debug, Clone, Copy)]
pub struct Stopwatch {
    start: Instant,
    last_lap: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        let now = Instant::now();
        Self { start: now, last_lap: now }
    }

    /// Time since start (or the last [`Self::restart`]).
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_s(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    /// Whole milliseconds since start — the scheduler's budget-check
    /// granularity.
    pub fn elapsed_ms(&self) -> u64 {
        self.elapsed().as_millis() as u64
    }

    /// Time since the previous lap (or start); advances the lap marker.
    pub fn lap(&mut self) -> Duration {
        let now = Instant::now();
        let d = now - self.last_lap;
        self.last_lap = now;
        d
    }

    /// Reset both the start and the lap marker to now.
    pub fn restart(&mut self) {
        let now = Instant::now();
        self.start = now;
        self.last_lap = now;
    }

    /// Has at least `budget` elapsed since start?
    pub fn expired(&self, budget: Duration) -> bool {
        self.elapsed() >= budget
    }
}

/// Format seconds for human output: `12.3ms`, `4.56s`, `2m03s`.
pub fn fmt_secs(s: f64) -> String {
    if s < 1.0 {
        format!("{:.1}ms", s * 1e3)
    } else if s < 120.0 {
        format!("{s:.2}s")
    } else {
        let m = (s / 60.0).floor();
        format!("{m:.0}m{:02.0}s", s - m * 60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn formats() {
        assert_eq!(fmt_secs(0.0123), "12.3ms");
        assert_eq!(fmt_secs(4.5), "4.50s");
        assert_eq!(fmt_secs(125.0), "2m05s");
    }

    #[test]
    fn timer_monotonic() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(t.elapsed_ms() >= 1.0);
    }

    #[test]
    fn stopwatch_laps_partition_elapsed() {
        let mut sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(2));
        let a = sw.lap();
        std::thread::sleep(Duration::from_millis(2));
        let b = sw.lap();
        assert!(a >= Duration::from_millis(1));
        assert!(b >= Duration::from_millis(1));
        // Laps split the total: their sum cannot exceed elapsed.
        assert!(a + b <= sw.elapsed() + Duration::from_millis(1));
    }

    #[test]
    fn stopwatch_restart_and_deadline() {
        let mut sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(sw.expired(Duration::from_millis(1)));
        assert!(!sw.expired(Duration::from_secs(3600)));
        sw.restart();
        assert!(sw.elapsed_ms() < 3600 * 1000);
        assert!(!sw.expired(Duration::from_secs(3600)));
    }
}
