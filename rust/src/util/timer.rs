//! Wall-clock timing helpers used across benches, examples and the
//! coordinator's progress reporting.

use std::time::Instant;

/// A simple scoped timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_s() * 1e3
    }
}

/// Format seconds for human output: `12.3ms`, `4.56s`, `2m03s`.
pub fn fmt_secs(s: f64) -> String {
    if s < 1.0 {
        format!("{:.1}ms", s * 1e3)
    } else if s < 120.0 {
        format!("{s:.2}s")
    } else {
        let m = (s / 60.0).floor();
        format!("{m:.0}m{:02.0}s", s - m * 60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn formats() {
        assert_eq!(fmt_secs(0.0123), "12.3ms");
        assert_eq!(fmt_secs(4.5), "4.50s");
        assert_eq!(fmt_secs(125.0), "2m05s");
    }

    #[test]
    fn timer_monotonic() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(t.elapsed_ms() >= 1.0);
    }
}
