//! Data-parallel substrate (the `rayon` crate is unavailable offline).
//!
//! Scoped fork-join parallelism over `std::thread::scope`: chunked
//! parallel-for, parallel map, and a reusable worker-count policy. Used by
//! the kNN stages, perplexity search, exact/BH force loops and metrics —
//! the paper's CPU baselines are multi-threaded C++, so ours are
//! multi-threaded Rust.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads: `GPGPU_SNE_THREADS` or available parallelism.
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("GPGPU_SNE_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Run `body(range)` over disjoint chunks of `0..n` on `threads` workers.
///
/// Work is distributed dynamically (atomic chunk counter) so irregular
/// per-item cost (e.g. perplexity bisection) balances well.
pub fn par_chunks(n: usize, chunk: usize, body: impl Fn(std::ops::Range<usize>) + Sync) {
    let threads = num_threads().min(n.div_ceil(chunk)).max(1);
    if threads <= 1 || n <= chunk {
        body(0..n);
        return;
    }
    let counter = AtomicUsize::new(0);
    let nchunks = n.div_ceil(chunk);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let c = counter.fetch_add(1, Ordering::Relaxed);
                if c >= nchunks {
                    break;
                }
                let lo = c * chunk;
                let hi = (lo + chunk).min(n);
                body(lo..hi);
            });
        }
    });
}

/// Parallel-for over indices with dynamic scheduling.
pub fn par_for(n: usize, body: impl Fn(usize) + Sync) {
    // Chunk to amortise the atomic; 64 is small enough for imbalance.
    par_chunks(n, 64, |r| {
        for i in r {
            body(i);
        }
    });
}

/// Parallel map: `out[i] = f(i)` for `i in 0..n`.
pub fn par_map<T: Send + Clone + Default>(n: usize, f: impl Fn(usize) -> T + Sync) -> Vec<T> {
    let mut out = vec![T::default(); n];
    {
        let slots = SyncSlice::new(&mut out);
        par_for(n, |i| unsafe {
            *slots.get_mut(i) = f(i);
        });
    }
    out
}

/// Write-disjoint shared mutable slice — the classic scoped-parallelism
/// escape hatch. Safe as long as every index is written by at most one
/// worker (true for all call sites: each `i` is claimed exactly once).
pub struct SyncSlice<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: std::marker::PhantomData<&'a mut [T]>,
}

unsafe impl<T: Send> Send for SyncSlice<'_, T> {}
unsafe impl<T: Send> Sync for SyncSlice<'_, T> {}

impl<'a, T> SyncSlice<'a, T> {
    pub fn new(slice: &'a mut [T]) -> Self {
        Self { ptr: slice.as_mut_ptr(), len: slice.len(), _marker: std::marker::PhantomData }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// # Safety
    /// Each index must be written from at most one thread at a time.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn get_mut(&self, i: usize) -> &mut T {
        debug_assert!(i < self.len);
        &mut *self.ptr.add(i)
    }

    /// Contiguous sub-slice `[start, start + len)` — the chunk-kernel
    /// variant of [`Self::get_mut`] (the SIMD kernels take whole chunks,
    /// not single elements).
    ///
    /// # Safety
    /// Ranges handed to concurrent workers must be disjoint.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice_mut(&self, start: usize, len: usize) -> &mut [T] {
        debug_assert!(start + len <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(start), len)
    }
}

/// Parallel reduce: fold chunks locally, combine the partials.
pub fn par_reduce<T: Send + Clone>(
    n: usize,
    identity: T,
    fold: impl Fn(T, usize) -> T + Sync,
    combine: impl Fn(T, T) -> T,
) -> T {
    let threads = num_threads();
    if threads <= 1 || n < 1024 {
        return (0..n).fold(identity, fold);
    }
    let chunk = n.div_ceil(threads);
    let mut partials = vec![identity.clone(); threads];
    {
        let slots = SyncSlice::new(&mut partials);
        std::thread::scope(|s| {
            for t in 0..threads {
                let fold = &fold;
                let identity = identity.clone();
                let slots = &slots;
                s.spawn(move || {
                    let lo = t * chunk;
                    let hi = ((t + 1) * chunk).min(n);
                    let acc = (lo..hi).fold(identity, fold);
                    unsafe {
                        *slots.get_mut(t) = acc;
                    }
                });
            }
        });
    }
    partials.into_iter().fold(identity, combine)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn par_for_covers_all_indices_once() {
        let n = 10_000;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        par_for(n, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn par_map_matches_serial() {
        let out = par_map(5000, |i| (i * i) as u64);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, (i * i) as u64);
        }
    }

    #[test]
    fn par_reduce_sums() {
        let n = 100_000usize;
        let s = par_reduce(n, 0u64, |acc, i| acc + i as u64, |a, b| a + b);
        assert_eq!(s, (n as u64 - 1) * n as u64 / 2);
    }

    #[test]
    fn empty_and_tiny_inputs() {
        par_for(0, |_| panic!("must not run"));
        let out = par_map(1, |i| i);
        assert_eq!(out, vec![0]);
    }
}
