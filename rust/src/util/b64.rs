//! Minimal standard base64 (RFC 4648, `+/` alphabet, `=` padding) — the
//! framing the TCP protocol uses to carry checkpoint blobs inside JSON
//! lines (`checkpoint` response, `submit.resume_from`). No crates.io
//! codec is available offline, and the protocol only needs encode /
//! strict decode of byte blobs, so this stays deliberately tiny.

const ALPHABET: &[u8; 64] = b"ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz0123456789+/";

/// Encode bytes as standard padded base64.
pub fn encode(bytes: &[u8]) -> String {
    let mut out = String::with_capacity(bytes.len().div_ceil(3) * 4);
    for chunk in bytes.chunks(3) {
        let b0 = chunk[0] as u32;
        let b1 = chunk.get(1).copied().unwrap_or(0) as u32;
        let b2 = chunk.get(2).copied().unwrap_or(0) as u32;
        let triple = (b0 << 16) | (b1 << 8) | b2;
        out.push(ALPHABET[(triple >> 18) as usize & 63] as char);
        out.push(ALPHABET[(triple >> 12) as usize & 63] as char);
        out.push(if chunk.len() > 1 {
            ALPHABET[(triple >> 6) as usize & 63] as char
        } else {
            '='
        });
        out.push(if chunk.len() > 2 { ALPHABET[triple as usize & 63] as char } else { '=' });
    }
    out
}

#[inline]
fn decode_char(c: u8) -> Option<u32> {
    match c {
        b'A'..=b'Z' => Some((c - b'A') as u32),
        b'a'..=b'z' => Some((c - b'a' + 26) as u32),
        b'0'..=b'9' => Some((c - b'0' + 52) as u32),
        b'+' => Some(62),
        b'/' => Some(63),
        _ => None,
    }
}

/// Strict decode of standard padded base64: rejects whitespace, bad
/// lengths, interior `=` and trailing garbage (a checkpoint blob either
/// decodes exactly or the request is an error).
pub fn decode(s: &str) -> anyhow::Result<Vec<u8>> {
    let b = s.as_bytes();
    anyhow::ensure!(b.len() % 4 == 0, "base64 length {} is not a multiple of 4", b.len());
    let mut out = Vec::with_capacity(b.len() / 4 * 3);
    for (ci, chunk) in b.chunks(4).enumerate() {
        let last = ci + 1 == b.len() / 4;
        let pad = chunk.iter().rev().take_while(|&&c| c == b'=').count();
        anyhow::ensure!(pad <= 2 && (pad == 0 || last), "bad base64 padding");
        let mut triple = 0u32;
        for (i, &c) in chunk.iter().enumerate() {
            let v = if i >= 4 - pad {
                0
            } else {
                decode_char(c)
                    .ok_or_else(|| anyhow::anyhow!("bad base64 character '{}'", c as char))?
            };
            triple = (triple << 6) | v;
        }
        out.push((triple >> 16) as u8);
        if pad < 2 {
            out.push((triple >> 8) as u8);
        }
        if pad < 1 {
            out.push(triple as u8);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // RFC 4648 §10 test vectors.
        for (plain, enc) in [
            ("", ""),
            ("f", "Zg=="),
            ("fo", "Zm8="),
            ("foo", "Zm9v"),
            ("foob", "Zm9vYg=="),
            ("fooba", "Zm9vYmE="),
            ("foobar", "Zm9vYmFy"),
        ] {
            assert_eq!(encode(plain.as_bytes()), enc);
            assert_eq!(decode(enc).unwrap(), plain.as_bytes());
        }
    }

    #[test]
    fn binary_roundtrip() {
        let bytes: Vec<u8> = (0..=255u8).cycle().take(1021).collect();
        assert_eq!(decode(&encode(&bytes)).unwrap(), bytes);
    }

    #[test]
    fn rejects_garbage() {
        assert!(decode("Zg=").is_err(), "bad length");
        assert!(decode("Zg!=").is_err(), "bad character");
        assert!(decode("Z===").is_err(), "over-padding");
        assert!(decode("Zg==Zg==").is_err(), "padding before the final chunk");
        assert!(decode("Zm9v\n").is_err(), "whitespace is not tolerated");
    }
}
