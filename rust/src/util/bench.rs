//! Benchmark harness substrate (`criterion` is unavailable offline).
//!
//! `cargo bench` targets (harness = false) use this: warmup, repeated
//! timed runs, robust statistics (median + MAD), and emitters that print
//! paper-style rows and write CSV series next to the bench for plotting.

use std::io::Write;
use std::time::Instant;

use crate::util::timer::fmt_secs;

/// Statistics over repeated timing samples (seconds).
#[derive(Debug, Clone)]
pub struct Stats {
    pub samples: Vec<f64>,
}

impl Stats {
    pub fn median(&self) -> f64 {
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        if s.is_empty() {
            return f64::NAN;
        }
        let m = s.len() / 2;
        if s.len() % 2 == 1 {
            s[m]
        } else {
            0.5 * (s[m - 1] + s[m])
        }
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn mean(&self) -> f64 {
        self.samples.iter().sum::<f64>() / self.samples.len().max(1) as f64
    }

    /// Nearest-rank percentile, `q` in [0, 1] (`pct(0.5)` ≈ median for
    /// odd sample counts). NaN on an empty sample set.
    pub fn pct(&self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((q.clamp(0.0, 1.0) * s.len() as f64).ceil() as usize).max(1);
        s[rank.min(s.len()) - 1]
    }

    /// Median absolute deviation (robust spread).
    pub fn mad(&self) -> f64 {
        let med = self.median();
        let devs = Stats { samples: self.samples.iter().map(|s| (s - med).abs()).collect() };
        devs.median()
    }
}

/// Measure `f` with `warmup` discarded runs and `iters` timed runs.
pub fn measure(warmup: usize, iters: usize, mut f: impl FnMut()) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    Stats { samples }
}

/// Measure a single run (for expensive end-to-end workloads where
/// repetition is the sweep itself).
pub fn measure_once(mut f: impl FnMut()) -> f64 {
    let t = Instant::now();
    f();
    t.elapsed().as_secs_f64()
}

/// A bench report: named rows of named columns, printed as a markdown
/// table and optionally dumped to CSV (for figure regeneration).
pub struct Report {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<(String, Vec<String>)>,
}

impl Report {
    pub fn new(title: &str, columns: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, name: &str, values: Vec<String>) {
        assert_eq!(values.len(), self.columns.len(), "row width mismatch");
        self.rows.push((name.to_string(), values));
    }

    /// Print as a markdown table (what the paper's tables look like).
    pub fn print(&self) {
        println!("\n## {}\n", self.title);
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        let mut name_w = 4;
        for (name, vals) in &self.rows {
            name_w = name_w.max(name.len());
            for (i, v) in vals.iter().enumerate() {
                widths[i] = widths[i].max(v.len());
            }
        }
        print!("| {:name_w$} |", "");
        for (c, w) in self.columns.iter().zip(&widths) {
            print!(" {c:>w$} |");
        }
        println!();
        print!("|{}|", "-".repeat(name_w + 2));
        for w in &widths {
            print!("{}|", "-".repeat(w + 2));
        }
        println!();
        for (name, vals) in &self.rows {
            print!("| {name:name_w$} |");
            for (v, w) in vals.iter().zip(&widths) {
                print!(" {v:>w$} |");
            }
            println!();
        }
        println!();
    }

    /// Write CSV to `bench_out/<file>` (created if needed).
    pub fn write_csv(&self, file: &str) -> std::io::Result<()> {
        std::fs::create_dir_all("bench_out")?;
        let path = std::path::Path::new("bench_out").join(file);
        let mut f = std::fs::File::create(&path)?;
        writeln!(f, "name,{}", self.columns.join(","))?;
        for (name, vals) in &self.rows {
            writeln!(f, "{name},{}", vals.join(","))?;
        }
        eprintln!("  [csv] wrote {}", path.display());
        Ok(())
    }
}

/// Format a time cell.
pub fn tcell(seconds: f64) -> String {
    fmt_secs(seconds)
}

/// Quick "did the bench binary get a --quick flag" helper: benches scale
/// their sweeps down under `--quick` / `GPGPU_SNE_QUICK=1` so `cargo
/// bench` finishes in CI-scale time.
pub fn quick_mode() -> bool {
    std::env::args().any(|a| a == "--quick")
        || std::env::var("GPGPU_SNE_QUICK").map(|v| v == "1").unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_median_and_mad() {
        let s = Stats { samples: vec![1.0, 2.0, 100.0] };
        assert_eq!(s.median(), 2.0);
        assert_eq!(s.mad(), 1.0);
        let e = Stats { samples: vec![1.0, 3.0] };
        assert_eq!(e.median(), 2.0);
    }

    #[test]
    fn percentiles_use_nearest_rank() {
        let s = Stats { samples: (1..=100).map(|i| i as f64).collect() };
        assert_eq!(s.pct(0.50), 50.0);
        assert_eq!(s.pct(0.95), 95.0);
        assert_eq!(s.pct(0.99), 99.0);
        assert_eq!(s.pct(0.0), 1.0);
        assert_eq!(s.pct(1.0), 100.0);
        let one = Stats { samples: vec![7.0] };
        assert_eq!(one.pct(0.5), 7.0);
        assert!(Stats { samples: vec![] }.pct(0.5).is_nan());
    }

    #[test]
    fn measure_runs_expected_times() {
        let mut count = 0;
        let st = measure(2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(st.samples.len(), 5);
    }

    #[test]
    fn report_shape_checked() {
        let mut r = Report::new("t", &["a", "b"]);
        r.row("x", vec!["1".into(), "2".into()]);
        assert_eq!(r.rows.len(), 1);
    }
}
