//! MNIST IDX loader with synthetic fallback.
//!
//! If `data/mnist/train-images-idx3-ubyte` (+ labels) exists — the
//! standard download, optionally with the `.gz` already decompressed —
//! the real dataset is used, exactly as the paper does. Otherwise the
//! MNIST-like manifold generator stands in (DESIGN.md §7) and the dataset
//! name records that substitution.

use std::io::Read;
use std::path::{Path, PathBuf};

use crate::hd::Dataset;

const IMAGES_MAGIC: u32 = 0x0000_0803;
const LABELS_MAGIC: u32 = 0x0000_0801;

/// Candidate locations for the raw IDX files.
fn candidates() -> Vec<PathBuf> {
    ["data/mnist", "../data/mnist", "/root/data/mnist"].iter().map(PathBuf::from).collect()
}

fn read_u32(r: &mut impl Read) -> std::io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_be_bytes(b))
}

/// Parse an IDX3 image file into (n, rows*cols, pixels as f32 in [0,1]).
pub fn parse_idx_images(bytes: &[u8]) -> anyhow::Result<(usize, usize, Vec<f32>)> {
    let mut r = bytes;
    let magic = read_u32(&mut r)?;
    anyhow::ensure!(magic == IMAGES_MAGIC, "bad images magic {magic:#x}");
    let n = read_u32(&mut r)? as usize;
    let rows = read_u32(&mut r)? as usize;
    let cols = read_u32(&mut r)? as usize;
    let d = rows * cols;
    anyhow::ensure!(r.len() >= n * d, "truncated image payload");
    let x = r[..n * d].iter().map(|&b| b as f32 / 255.0).collect();
    Ok((n, d, x))
}

/// Parse an IDX1 label file.
pub fn parse_idx_labels(bytes: &[u8]) -> anyhow::Result<Vec<u8>> {
    let mut r = bytes;
    let magic = read_u32(&mut r)?;
    anyhow::ensure!(magic == LABELS_MAGIC, "bad labels magic {magic:#x}");
    let n = read_u32(&mut r)? as usize;
    anyhow::ensure!(r.len() >= n, "truncated label payload");
    Ok(r[..n].to_vec())
}

/// Try to load real MNIST from disk.
pub fn load_real(dir: &Path) -> anyhow::Result<Dataset> {
    let images = std::fs::read(dir.join("train-images-idx3-ubyte"))?;
    let labels = std::fs::read(dir.join("train-labels-idx1-ubyte"))?;
    let (n, d, x) = parse_idx_images(&images)?;
    let labels = parse_idx_labels(&labels)?;
    anyhow::ensure!(labels.len() == n, "image/label count mismatch");
    Ok(Dataset::new("mnist", n, d, x, labels))
}

/// Real MNIST if present (subsampled to `n`), MNIST-like otherwise.
pub fn load_or_synthesize(n: usize, seed: u64) -> Dataset {
    for dir in candidates() {
        if dir.join("train-images-idx3-ubyte").exists() {
            match load_real(&dir) {
                Ok(ds) => return ds.subsample(n, seed),
                Err(e) => eprintln!("warning: MNIST at {} unreadable ({e}); using synthetic", dir.display()),
            }
        }
    }
    super::generators::mnist_like(n, seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_idx_images(n: usize) -> Vec<u8> {
        let mut b = Vec::new();
        b.extend_from_slice(&IMAGES_MAGIC.to_be_bytes());
        b.extend_from_slice(&(n as u32).to_be_bytes());
        b.extend_from_slice(&2u32.to_be_bytes());
        b.extend_from_slice(&2u32.to_be_bytes());
        for i in 0..n * 4 {
            b.push((i % 256) as u8);
        }
        b
    }

    #[test]
    fn parses_idx_images() {
        let (n, d, x) = parse_idx_images(&tiny_idx_images(3)).unwrap();
        assert_eq!((n, d), (3, 4));
        assert_eq!(x.len(), 12);
        assert!((x[1] - 1.0 / 255.0).abs() < 1e-6);
    }

    #[test]
    fn parses_idx_labels() {
        let mut b = Vec::new();
        b.extend_from_slice(&LABELS_MAGIC.to_be_bytes());
        b.extend_from_slice(&4u32.to_be_bytes());
        b.extend_from_slice(&[7, 0, 9, 3]);
        assert_eq!(parse_idx_labels(&b).unwrap(), vec![7, 0, 9, 3]);
    }

    #[test]
    fn rejects_bad_magic() {
        let mut b = tiny_idx_images(1);
        b[3] = 0x42;
        assert!(parse_idx_images(&b).is_err());
    }

    #[test]
    fn fallback_synthesizes() {
        let ds = load_or_synthesize(64, 0);
        assert_eq!(ds.n, 64);
        assert_eq!(ds.d, 784);
    }
}
