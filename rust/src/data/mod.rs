//! Dataset substrates (DESIGN.md S19, substitutions in DESIGN.md §7).
//!
//! The paper evaluates on MNIST, WikiWord, GoogleNews word2vec and two
//! ImageNet activation datasets — none shippable here. Each is replaced
//! by a generator that reproduces the *statistics the algorithms react
//! to* (manifold structure, cluster-size skew, sparsity/nonnegativity),
//! plus a real-MNIST IDX loader that kicks in when files are present.

pub mod generators;
pub mod mnist;

pub use generators::{gaussian_mixture, imagenet_like, mnist_like, wordvec_like};

use crate::hd::Dataset;

/// Construct one of the paper's five evaluation datasets by name
/// (`mnist`, `wikiword`, `word2vec`, `imagenet-mixed3a`, `imagenet-head0`),
/// subsampled/generated at `n` points. Names match Table 1.
pub fn by_name(name: &str, n: usize, seed: u64) -> anyhow::Result<Dataset> {
    Ok(match name {
        "mnist" => mnist::load_or_synthesize(n, seed),
        "wikiword" => wordvec_like("wikiword", n, 300, 400, seed),
        "word2vec" | "googlenews" => wordvec_like("word2vec", n, 300, 1200, seed),
        "imagenet-mixed3a" => imagenet_like("imagenet-mixed3a", n, 256, seed),
        "imagenet-head0" => imagenet_like("imagenet-head0", n, 128, seed),
        "gaussians" => gaussian_mixture("gaussians", n, 32, 10, seed),
        other => anyhow::bail!(
            "unknown dataset '{other}' (expected mnist|wikiword|word2vec|imagenet-mixed3a|imagenet-head0|gaussians)"
        ),
    })
}

/// The five paper datasets of Table 1, with their full-scale sizes.
pub const TABLE1: &[(&str, usize, usize)] = &[
    ("mnist", 60_000, 784),
    ("wikiword", 350_000, 300),
    ("word2vec", 3_000_000, 300),
    ("imagenet-mixed3a", 100_000, 256),
    ("imagenet-head0", 100_000, 128),
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn by_name_builds_each_table1_dataset() {
        for (name, _, d) in TABLE1 {
            let ds = by_name(name, 200, 1).unwrap();
            assert_eq!(ds.n, 200);
            assert_eq!(ds.d, *d, "{name} dimensionality");
            assert!(ds.x.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn unknown_name_errors() {
        assert!(by_name("nope", 10, 0).is_err());
    }
}
