//! Synthetic dataset generators mirroring the paper's evaluation data
//! (DESIGN.md §7 documents each substitution and why it preserves the
//! behaviour the experiments probe).

use crate::hd::Dataset;
use crate::util::rng::Rng;

/// Plain Gaussian mixture: `c` isotropic clusters in `d` dims.
pub fn gaussian_mixture(name: &str, n: usize, d: usize, c: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut centers = vec![0.0f32; c * d];
    for v in centers.iter_mut() {
        *v = rng.gauss_f32(0.0, 4.0);
    }
    let mut x = vec![0.0f32; n * d];
    let mut labels = vec![0u8; n];
    for i in 0..n {
        let cl = rng.below(c);
        labels[i] = cl as u8;
        for j in 0..d {
            x[i * d + j] = centers[cl * d + j] + rng.gauss_f32(0.0, 1.0);
        }
    }
    Dataset::new(name, n, d, x, labels)
}

/// MNIST-like: 10 nonlinearly-warped low-rank manifolds in 784-d pixel
/// space, with gray values in [0,1], MNIST's class imbalance profile and
/// per-class intrinsic dimension ~8 (what makes t-SNE's MNIST plots the
/// canonical 10-blob figure).
pub fn mnist_like(n: usize, seed: u64) -> Dataset {
    let d = 784;
    let intrinsic = 8;
    let classes = 10;
    let mut rng = Rng::new(seed ^ 0x6d6e6973745f6c6b);
    // Per-class random linear map intrinsic -> 784 plus a class prototype
    // ("average digit"): points are prototype + A z + bump nonlinearity.
    let mut protos = vec![0.0f32; classes * d];
    let mut maps = vec![0.0f32; classes * intrinsic * d];
    for cl in 0..classes {
        // Prototype: a smooth blobby image (sum of a few 2-D Gaussians on
        // the 28x28 grid) — gives pixel-space correlations like digits.
        for blob in 0..3 {
            let cx = rng.range_f64(6.0, 22.0);
            let cy = rng.range_f64(6.0, 22.0);
            let s2 = rng.range_f64(4.0, 18.0);
            let amp = rng.range_f64(0.4, 0.9);
            let _ = blob;
            for py in 0..28 {
                for px in 0..28 {
                    let dx = px as f64 - cx;
                    let dy = py as f64 - cy;
                    protos[cl * d + py * 28 + px] +=
                        (amp * (-(dx * dx + dy * dy) / (2.0 * s2)).exp()) as f32;
                }
            }
        }
        for v in maps[cl * intrinsic * d..(cl + 1) * intrinsic * d].iter_mut() {
            *v = rng.gauss_f32(0.0, 0.12);
        }
    }
    // MNIST class frequencies are near-uniform with mild imbalance.
    let weights: [f64; 10] = [0.099, 0.113, 0.099, 0.102, 0.097, 0.090, 0.099, 0.104, 0.098, 0.099];
    let cum: Vec<f64> = weights
        .iter()
        .scan(0.0, |acc, w| {
            *acc += w;
            Some(*acc)
        })
        .collect();
    let mut x = vec![0.0f32; n * d];
    let mut labels = vec![0u8; n];
    let mut z = vec![0.0f32; intrinsic];
    for i in 0..n {
        let u = rng.f64() * cum[9];
        let cl = cum.iter().position(|&c| u <= c).unwrap_or(9);
        labels[i] = cl as u8;
        for zj in z.iter_mut() {
            *zj = rng.gauss_f32(0.0, 1.0);
        }
        // Nonlinear warp: mix latent coords through tanh so the manifold
        // curves (pure linear maps would be PCA-recoverable, unlike MNIST).
        let w0 = (z[0] * 0.9).tanh();
        let w1 = (z[1] * 0.9).tanh();
        let row = &mut x[i * d..(i + 1) * d];
        let map = &maps[cl * intrinsic * d..(cl + 1) * intrinsic * d];
        for j in 0..d {
            let mut v = protos[cl * d + j];
            for (l, &zl) in z.iter().enumerate() {
                v += map[l * d + j] * zl;
            }
            // Latent-dependent brightness/slant warps.
            v *= 1.0 + 0.12 * w0;
            v += 0.05 * w1 * ((j % 28) as f32 / 28.0 - 0.5);
            row[j] = v.clamp(0.0, 1.0);
        }
    }
    Dataset::new("mnist-like", n, d, x, labels)
}

/// Word-embedding-like: clusters on the unit sphere with Zipfian
/// (power-law) sizes and heavy-tailed outliers — the density skew that
/// stresses Barnes-Hut cells and that the paper's Fig. 6 row 2 analysis
/// attributes its quality advantage to.
pub fn wordvec_like(name: &str, n: usize, d: usize, n_clusters: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ 0x776f7264766563);
    // Zipf weights w_c = 1/(c+2)^1.07 (word frequencies' classic exponent).
    let weights: Vec<f64> = (0..n_clusters).map(|c| 1.0 / (c as f64 + 2.0).powf(1.07)).collect();
    let total: f64 = weights.iter().sum();
    let cum: Vec<f64> = weights
        .iter()
        .scan(0.0, |acc, w| {
            *acc += w / total;
            Some(*acc)
        })
        .collect();
    let mut centers = vec![0.0f32; n_clusters * d];
    for c in 0..n_clusters {
        let mut norm = 0.0f32;
        for j in 0..d {
            let v = rng.gauss_f32(0.0, 1.0);
            centers[c * d + j] = v;
            norm += v * v;
        }
        let inv = 1.0 / norm.sqrt().max(1e-9);
        for j in 0..d {
            centers[c * d + j] *= inv;
        }
    }
    let mut x = vec![0.0f32; n * d];
    let mut labels = vec![0u8; n];
    for i in 0..n {
        let u = rng.f64();
        let cl = match cum.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
            Ok(p) | Err(p) => p.min(n_clusters - 1),
        };
        labels[i] = (cl % 256) as u8;
        // Spread grows for rarer clusters; 2% heavy-tail outliers.
        let spread = 0.12 + 0.1 * (cl as f32 / n_clusters as f32);
        let outlier = rng.f64() < 0.02;
        let s = if outlier { 0.8 } else { spread };
        let mut norm = 0.0f32;
        let row = &mut x[i * d..(i + 1) * d];
        for j in 0..d {
            let v = centers[cl * d + j] + rng.gauss_f32(0.0, s);
            row[j] = v;
            norm += v * v;
        }
        // Word vectors are commonly length-normalised for similarity use.
        let inv = 1.0 / norm.sqrt().max(1e-9);
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
    Dataset::new(name, n, d, x, labels)
}

/// DNN-activation-like: nonnegative, ~60% sparse (ReLU), log-normal
/// magnitudes, hierarchical class structure (superclasses containing
/// subclasses) — the statistics of the paper's ImageNet Mixed3a/Head0
/// layer activations.
pub fn imagenet_like(name: &str, n: usize, d: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ 0x696d6167656e6574);
    let supers = 8;
    let subs_per = 6;
    // Superclass direction + subclass offsets.
    let mut sup_dir = vec![0.0f32; supers * d];
    for v in sup_dir.iter_mut() {
        *v = rng.gauss_f32(0.0, 1.0).max(0.0); // nonnegative prototype
    }
    let mut sub_dir = vec![0.0f32; supers * subs_per * d];
    for v in sub_dir.iter_mut() {
        *v = rng.gauss_f32(0.0, 0.5);
    }
    let mut x = vec![0.0f32; n * d];
    let mut labels = vec![0u8; n];
    for i in 0..n {
        let sp = rng.below(supers);
        let sb = rng.below(subs_per);
        labels[i] = (sp * subs_per + sb) as u8;
        let row = &mut x[i * d..(i + 1) * d];
        // Log-normal per-point gain (activation magnitude variation).
        let gain = (rng.gauss() * 0.5).exp() as f32;
        for j in 0..d {
            let mean = sup_dir[sp * d + j] + sub_dir[(sp * subs_per + sb) * d + j];
            let v = (mean + rng.gauss_f32(0.0, 0.35)) * gain;
            // ReLU: negatives clip to exact zero -> ~50-65% sparsity.
            row[j] = v.max(0.0);
        }
    }
    Dataset::new(name, n, d, x, labels)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mnist_like_statistics() {
        let ds = mnist_like(2000, 3);
        assert_eq!(ds.d, 784);
        assert!(ds.x.iter().all(|&v| (0.0..=1.0).contains(&v)), "gray values in [0,1]");
        // All ten classes present with rough balance.
        let mut counts = [0usize; 10];
        for &l in &ds.labels {
            counts[l as usize] += 1;
        }
        for (c, &cnt) in counts.iter().enumerate() {
            assert!(cnt > 100, "class {c} undersampled: {cnt}");
        }
    }

    #[test]
    fn mnist_like_classes_are_separated() {
        // Mean within-class distance should be well below between-class.
        let ds = mnist_like(600, 5);
        let mut within = (0.0f64, 0usize);
        let mut between = (0.0f64, 0usize);
        for i in (0..ds.n).step_by(7) {
            for j in (i + 1..ds.n).step_by(11) {
                let d = crate::hd::dist2(ds.row(i), ds.row(j)) as f64;
                if ds.labels[i] == ds.labels[j] {
                    within.0 += d;
                    within.1 += 1;
                } else {
                    between.0 += d;
                    between.1 += 1;
                }
            }
        }
        // Real MNIST pixel-space ratio is ~1.2-1.4; require that regime.
        let w = within.0 / within.1 as f64;
        let b = between.0 / between.1 as f64;
        assert!(b > 1.25 * w, "classes not separated: within={w:.3} between={b:.3}");
    }

    #[test]
    fn wordvec_like_is_unit_norm_and_zipfian() {
        let ds = wordvec_like("w", 3000, 64, 50, 7);
        for i in (0..ds.n).step_by(97) {
            let norm: f32 = ds.row(i).iter().map(|v| v * v).sum();
            assert!((norm - 1.0).abs() < 1e-3, "row {i} not unit norm: {norm}");
        }
        // Cluster sizes skew: the biggest label should dominate smallest.
        let mut counts = std::collections::HashMap::new();
        for &l in &ds.labels {
            *counts.entry(l).or_insert(0usize) += 1;
        }
        let max = *counts.values().max().unwrap();
        let min = *counts.values().min().unwrap();
        assert!(max > 5 * min, "no Zipf skew: max={max} min={min}");
    }

    #[test]
    fn imagenet_like_is_sparse_nonnegative() {
        let ds = imagenet_like("i", 1000, 128, 2);
        assert!(ds.x.iter().all(|&v| v >= 0.0));
        let zeros = ds.x.iter().filter(|&&v| v == 0.0).count() as f64 / ds.x.len() as f64;
        assert!((0.3..0.8).contains(&zeros), "ReLU sparsity off: {zeros}");
    }

    #[test]
    fn generators_are_deterministic() {
        let a = wordvec_like("w", 100, 32, 10, 42);
        let b = wordvec_like("w", 100, 32, 10, 42);
        assert_eq!(a.x, b.x);
        let c = mnist_like(50, 42);
        let d = mnist_like(50, 42);
        assert_eq!(c.x, d.x);
    }
}
