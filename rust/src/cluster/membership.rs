//! Cluster membership and rendezvous (HRW) ownership.
//!
//! The router keeps one [`Membership`] table: every worker it has been
//! told about (`router --workers`) or that announced itself (`hello`),
//! with a liveness state driven by the heartbeat loop. Ownership of a
//! dataset fingerprint is decided by **highest-random-weight** (HRW /
//! rendezvous) hashing: each worker's score for a key is a 64-bit mix
//! of the key with a per-worker salt, and the alive worker with the
//! maximum score owns the key. The properties the router relies on:
//!
//! * **Stability** — adding or removing one worker only remaps the keys
//!   that worker owned (≈ 1/K of the keyspace), so every other shard's
//!   two-level similarity store stays hot.
//! * **Determinism** — the salt is a pure function of the worker's
//!   address, so any router instance (or a test) computes the same
//!   owner for the same membership set. No coordination state to lose.
//! * **No ring to rebalance** — unlike consistent-hash rings there are
//!   no virtual nodes or token ranges; the score is recomputed per
//!   decision (a few ns, pinned by the `cluster` section of
//!   `benches/micro_hotpath.rs`).

use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Stable worker identifier, assigned at registration (1-based).
pub type WorkerId = u64;

/// Liveness as seen by the router's heartbeat loop.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkerState {
    /// Responding to heartbeats; eligible to own keys.
    Up,
    /// Being drained (`shutdown` with a `worker` field): keeps serving
    /// its live jobs while they migrate off, but owns no new keys.
    Draining,
    /// Missed heartbeats past the timeout; its jobs fail over.
    Dead,
}

impl WorkerState {
    pub fn label(&self) -> &'static str {
        match self {
            WorkerState::Up => "up",
            WorkerState::Draining => "draining",
            WorkerState::Dead => "dead",
        }
    }
}

/// One registered worker.
#[derive(Clone, Debug)]
pub struct WorkerInfo {
    pub id: WorkerId,
    pub addr: String,
    pub state: WorkerState,
    /// Last successful heartbeat (or registration).
    pub last_seen: Instant,
    /// HRW salt — FNV-1a of the address, fixed at registration.
    salt: u64,
}

/// The membership table. All methods take `&self`; a single mutex
/// guards the vector (membership changes are rare and the table is
/// small — scans beat any fancier structure here).
#[derive(Default)]
pub struct Membership {
    workers: Mutex<Vec<WorkerInfo>>,
}

/// splitmix64 finalizer: a full-avalanche 64-bit mix.
#[inline]
fn mix(mut z: u64) -> u64 {
    z ^= z >> 33;
    z = z.wrapping_mul(0xff51_afd7_ed55_8ccd);
    z ^= z >> 33;
    z = z.wrapping_mul(0xc4ce_b9fe_1a85_ec53);
    z ^= z >> 33;
    z
}

/// FNV-1a over a byte string (the worker-address salt).
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The HRW score of `key` on a worker with `salt`. Public so the bench
/// can pin the per-decision cost.
#[inline]
pub fn hrw_score(key: u64, salt: u64) -> u64 {
    mix(key ^ mix(salt))
}

impl Membership {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a worker by address, or refresh an existing one (same
    /// address ⇒ same id; a dead worker that re-announces comes back
    /// `Up`). Returns the worker's id.
    pub fn register(&self, addr: &str) -> WorkerId {
        let mut g = self.workers.lock().unwrap();
        if let Some(w) = g.iter_mut().find(|w| w.addr == addr) {
            w.state = WorkerState::Up;
            w.last_seen = Instant::now();
            return w.id;
        }
        let id = g.len() as WorkerId + 1;
        g.push(WorkerInfo {
            id,
            addr: addr.to_string(),
            state: WorkerState::Up,
            last_seen: Instant::now(),
            salt: fnv1a(addr.as_bytes()),
        });
        id
    }

    /// Record a successful heartbeat.
    pub fn refresh(&self, id: WorkerId) {
        let mut g = self.workers.lock().unwrap();
        if let Some(w) = g.iter_mut().find(|w| w.id == id) {
            w.last_seen = Instant::now();
            if w.state == WorkerState::Dead {
                w.state = WorkerState::Up;
            }
        }
    }

    pub fn mark_dead(&self, id: WorkerId) {
        self.set_state(id, WorkerState::Dead);
    }

    pub fn mark_draining(&self, id: WorkerId) {
        self.set_state(id, WorkerState::Draining);
    }

    fn set_state(&self, id: WorkerId, state: WorkerState) {
        let mut g = self.workers.lock().unwrap();
        if let Some(w) = g.iter_mut().find(|w| w.id == id) {
            w.state = state;
        }
    }

    /// Expire workers whose last heartbeat is older than `timeout`.
    /// Returns the ids that *newly* transitioned to `Dead` (the
    /// router's failover trigger).
    pub fn expire(&self, timeout: Duration) -> Vec<WorkerId> {
        let mut g = self.workers.lock().unwrap();
        let mut newly_dead = Vec::new();
        for w in g.iter_mut() {
            if w.state != WorkerState::Dead && w.last_seen.elapsed() > timeout {
                w.state = WorkerState::Dead;
                newly_dead.push(w.id);
            }
        }
        newly_dead
    }

    /// The HRW owner of `key` among `Up` workers: `(id, addr)` of the
    /// maximum-score worker, ties broken by id (lowest wins) so the
    /// decision is total even for colliding scores.
    pub fn owner_of(&self, key: u64) -> Option<(WorkerId, String)> {
        let g = self.workers.lock().unwrap();
        g.iter()
            .filter(|w| w.state == WorkerState::Up)
            .max_by(|a, b| {
                hrw_score(key, a.salt).cmp(&hrw_score(key, b.salt)).then(b.id.cmp(&a.id))
            })
            .map(|w| (w.id, w.addr.clone()))
    }

    /// Like [`owner_of`](Self::owner_of) but excluding one worker — the
    /// migration target chooser ("anywhere but where it is now").
    pub fn owner_of_excluding(&self, key: u64, not: WorkerId) -> Option<(WorkerId, String)> {
        let g = self.workers.lock().unwrap();
        g.iter()
            .filter(|w| w.state == WorkerState::Up && w.id != not)
            .max_by(|a, b| {
                hrw_score(key, a.salt).cmp(&hrw_score(key, b.salt)).then(b.id.cmp(&a.id))
            })
            .map(|w| (w.id, w.addr.clone()))
    }

    pub fn addr_of(&self, id: WorkerId) -> Option<String> {
        let g = self.workers.lock().unwrap();
        g.iter().find(|w| w.id == id).map(|w| w.addr.clone())
    }

    pub fn state_of(&self, id: WorkerId) -> Option<WorkerState> {
        let g = self.workers.lock().unwrap();
        g.iter().find(|w| w.id == id).map(|w| w.state)
    }

    /// Snapshot of every registered worker.
    pub fn snapshot(&self) -> Vec<WorkerInfo> {
        self.workers.lock().unwrap().clone()
    }

    /// Ids + addresses of every non-`Dead` worker (heartbeat targets).
    pub fn probe_targets(&self) -> Vec<(WorkerId, String)> {
        let g = self.workers.lock().unwrap();
        g.iter()
            .filter(|w| w.state != WorkerState::Dead)
            .map(|w| (w.id, w.addr.clone()))
            .collect()
    }

    /// Number of `Up` workers.
    pub fn up_count(&self) -> usize {
        self.workers.lock().unwrap().iter().filter(|w| w.state == WorkerState::Up).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn members(addrs: &[&str]) -> Membership {
        let m = Membership::new();
        for a in addrs {
            m.register(a);
        }
        m
    }

    #[test]
    fn register_is_idempotent_by_addr() {
        let m = Membership::new();
        let a = m.register("127.0.0.1:7001");
        let b = m.register("127.0.0.1:7002");
        assert_ne!(a, b);
        assert_eq!(m.register("127.0.0.1:7001"), a);
        assert_eq!(m.snapshot().len(), 2);
    }

    #[test]
    fn dead_worker_reanimates_on_register() {
        let m = members(&["127.0.0.1:7001"]);
        m.mark_dead(1);
        assert_eq!(m.state_of(1), Some(WorkerState::Dead));
        assert_eq!(m.register("127.0.0.1:7001"), 1);
        assert_eq!(m.state_of(1), Some(WorkerState::Up));
    }

    #[test]
    fn hrw_is_deterministic_and_sticky() {
        let m = members(&["127.0.0.1:7001", "127.0.0.1:7002", "127.0.0.1:7003"]);
        for key in [0u64, 1, 42, 0xdead_beef, u64::MAX] {
            let a = m.owner_of(key);
            let b = m.owner_of(key);
            assert_eq!(a, b, "owner of {key:#x} must be stable");
        }
    }

    #[test]
    fn hrw_spreads_keys_across_workers() {
        let m = members(&["127.0.0.1:7001", "127.0.0.1:7002", "127.0.0.1:7003"]);
        let mut counts = [0usize; 3];
        for key in 0..3000u64 {
            let (id, _) = m.owner_of(mix(key)).unwrap();
            counts[(id - 1) as usize] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            assert!(c > 500, "worker {} owns only {c}/3000 keys — HRW is skewed", i + 1);
        }
    }

    #[test]
    fn removing_a_worker_only_remaps_its_keys() {
        let m = members(&["127.0.0.1:7001", "127.0.0.1:7002", "127.0.0.1:7003"]);
        let keys: Vec<u64> = (0..2000u64).map(mix).collect();
        let before: Vec<_> = keys.iter().map(|&k| m.owner_of(k).unwrap().0).collect();
        m.mark_dead(2);
        for (i, &k) in keys.iter().enumerate() {
            let after = m.owner_of(k).unwrap().0;
            if before[i] != 2 {
                assert_eq!(after, before[i], "key {k:#x} moved off a surviving worker");
            } else {
                assert_ne!(after, 2);
            }
        }
    }

    #[test]
    fn draining_workers_own_nothing_new() {
        let m = members(&["127.0.0.1:7001", "127.0.0.1:7002"]);
        m.mark_draining(1);
        for key in 0..100u64 {
            assert_eq!(m.owner_of(mix(key)).unwrap().0, 2);
        }
    }

    #[test]
    fn expire_reports_each_death_once() {
        let m = members(&["127.0.0.1:7001", "127.0.0.1:7002"]);
        assert!(m.expire(Duration::from_secs(60)).is_empty());
        let newly = m.expire(Duration::from_nanos(0));
        assert_eq!(newly.len(), 2);
        assert!(m.expire(Duration::from_nanos(0)).is_empty(), "already dead: not re-reported");
        assert_eq!(m.owner_of(7), None, "no alive workers, no owner");
    }
}
