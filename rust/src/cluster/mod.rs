//! Sharded multi-node coordinator (`pallas router`): fingerprint
//! routing, live session migration, journal-replicated failover.
//!
//! One router process fronts N independent `serve` workers over the
//! existing line-oriented TCP protocol (`docs/PROTOCOL.md`). The split:
//!
//! * [`membership`] — who the workers are, whether they are alive, and
//!   which one owns a dataset fingerprint (rendezvous/HRW hashing, so
//!   adding or losing a shard only remaps that shard's keys and every
//!   other shard's two-level similarity store stays hot).
//! * [`router`] — the serving process: routes `submit` by fingerprint,
//!   proxies job-scoped commands with id rewriting, replicates worker
//!   checkpoints into its own journal each heartbeat, migrates live
//!   sessions (`migrate`, drain-on-shutdown), and fails jobs over from
//!   dead workers bit-identically (checkpoint replay is deterministic,
//!   pinned by `tests/cluster.rs`).
//!
//! Workers need no cluster awareness at all: the router speaks plain
//! client commands at them, and `serve --router <addr>` merely makes a
//! worker announce itself (`hello`) so deployment stays one flag.
//! `docs/ARCHITECTURE.md` ("Cluster topology") has the full picture.

pub mod membership;
pub mod router;

pub use membership::{hrw_score, Membership, WorkerId, WorkerInfo, WorkerState};
pub use router::{rpc, Router, RouterConfig};
