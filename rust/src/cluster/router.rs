//! The `pallas router`: a sharded multi-node front end over N `serve`
//! workers, speaking the same one-JSON-object-per-line TCP protocol.
//!
//! **Routing.** A `submit` is parsed through the exact worker code path
//! ([`protocol::spec_from_json`]), the dataset's content fingerprint is
//! computed (and cached per `(dataset, n, seed)`), and rendezvous
//! hashing ([`super::membership`]) picks the owning worker — the same
//! fingerprint always lands on the same shard while membership is
//! stable, so each shard's two-level similarity store stays hot and
//! repeat submits of a dataset hit that shard's caches. Job-scoped
//! commands (`status`, `pause`, `checkpoint`, …) are proxied to the
//! owner with the job id rewritten both ways: clients hold one
//! router-assigned id for the job's whole life, across migrations and
//! failovers.
//!
//! **Replication.** Each heartbeat round, the router pulls a
//! `checkpoint` from every running job and journals it (spec + blob)
//! into its own state dir through the worker-side
//! [`JobJournal`] machinery — the router holds a warm copy of every
//! job's resumable state without workers knowing about each other.
//!
//! **Migration.** `migrate` moves a live job: checkpoint at the source,
//! stop it there, re-submit on the target with `resume_from`. The
//! checkpoint codec replays bit-identically (pinned since the
//! durability PRs), so a migrated job finishes with exactly the
//! positions an uninterrupted run produces. `shutdown` with a `worker`
//! field drains a shard by migrating every job off before the worker
//! itself is shut down.
//!
//! **Failover.** Workers that miss heartbeats past the timeout are
//! declared dead; their non-terminal jobs are re-submitted on the
//! surviving HRW owner from the last replicated checkpoint (or from
//! scratch — both replay bit-identically, a fresh run is just the
//! empty-checkpoint case). Routes that cannot be placed (no survivors)
//! retry every round. The heartbeat probe and the replication pull are
//! fault-injectable ([`faultinject::CLUSTER_HEARTBEAT_DROP`],
//! [`faultinject::CLUSTER_REPLICATE_FAIL`]) so the chaos suite can
//! drive split-brain-ish scenarios deterministically.

use std::collections::HashMap;
use std::io::{BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::faultinject;
use crate::coordinator::protocol::{
    self, err_code, err_msg, ok_fields, spec_from_json, spec_to_json, Cmd, LineRead,
};
use crate::coordinator::{JobJournal, JobSpec};
use crate::data;
use crate::obs::{self, Counter, Gauge, Histogram, Registry};
use crate::util::json::{self, Json};
use crate::util::b64;

use super::membership::{Membership, WorkerId, WorkerState};

/// Router tuning knobs.
pub struct RouterConfig {
    /// Heartbeat cadence. `None` disables the background loop — tests
    /// and benches drive [`Router::heartbeat_once`] by hand for
    /// deterministic failure schedules.
    pub heartbeat_interval: Option<Duration>,
    /// A worker whose last successful heartbeat is older than this is
    /// declared dead and its jobs fail over.
    pub heartbeat_timeout: Duration,
    /// Per-RPC connect/read/write timeout for proxied calls.
    pub rpc_timeout: Duration,
    /// Journal replicated checkpoints here (`<dir>/cluster-journal`).
    /// `None` keeps replicas in memory only.
    pub state_dir: Option<PathBuf>,
    /// Give up a `wait` proxy after this long (a wait must not hold a
    /// router connection thread forever when a job is unplaceable).
    pub wait_timeout: Duration,
}

impl Default for RouterConfig {
    fn default() -> Self {
        Self {
            heartbeat_interval: Some(Duration::from_millis(1000)),
            heartbeat_timeout: Duration::from_millis(3000),
            rpc_timeout: Duration::from_secs(10),
            state_dir: None,
            wait_timeout: Duration::from_secs(600),
        }
    }
}

/// One routed job: where it lives now plus everything needed to move
/// or revive it (spec, fingerprint, last replicated checkpoint).
struct RouteEntry {
    worker: WorkerId,
    /// The job's id *on the worker* (each worker numbers independently).
    worker_job: u64,
    spec: JobSpec,
    /// `spec_to_json` line — the journal payload, parsed back through
    /// the identical submit path on re-admission.
    spec_line: String,
    fingerprint: u64,
    /// Last replicated checkpoint blob (empty until one is pulled).
    last_ckpt: Vec<u8>,
    replicated_iter: u64,
    terminal: bool,
    /// Set while a `migrate` is in flight; `wait` polls and the
    /// replication pass skip the route until it settles.
    migrating: bool,
}

/// One JSON-per-line RPC to a worker: connect, send, read one bounded
/// response line. Public for `serve --router` announcements, the
/// cluster tests and the `cluster` bench section.
pub fn rpc(addr: &str, line: &str, timeout: Duration) -> anyhow::Result<Json> {
    let sa: SocketAddr = addr
        .to_socket_addrs()?
        .next()
        .ok_or_else(|| anyhow::anyhow!("unresolvable address '{addr}'"))?;
    let stream = TcpStream::connect_timeout(&sa, timeout)?;
    stream.set_read_timeout(Some(timeout))?;
    stream.set_write_timeout(Some(timeout))?;
    let mut w = stream.try_clone()?;
    w.write_all(line.as_bytes())?;
    w.write_all(b"\n")?;
    w.flush()?;
    let mut r = BufReader::new(stream);
    let mut buf = Vec::new();
    match protocol::read_bounded_line(&mut r, &mut buf, protocol::MAX_REQUEST_BYTES)? {
        LineRead::Line => {}
        LineRead::Eof => anyhow::bail!("worker {addr} closed the connection without replying"),
        LineRead::TooLarge => anyhow::bail!("worker {addr} response exceeded the frame bound"),
    }
    let text = std::str::from_utf8(&buf)?;
    json::parse(text).map_err(|e| anyhow::anyhow!("bad response from {addr}: {e}"))
}

fn is_ok(v: &Json) -> bool {
    v.get("ok") == Some(&Json::Bool(true))
}

/// Rebuild a forwardable `submit` line from a parsed spec, re-attaching
/// `resume_from` (which [`spec_to_json`] deliberately never emits — the
/// journal carries checkpoints out of band, but the wire must not).
fn submit_line(spec: &JobSpec, resume_b64: Option<&str>) -> String {
    let Json::Obj(mut fields) = spec_to_json(spec) else { unreachable!("spec_to_json is an obj") };
    fields.insert(0, ("cmd".to_string(), Json::Str("submit".into())));
    if let Some(b) = resume_b64 {
        fields.push(("resume_from".to_string(), Json::Str(b.into())));
    }
    Json::Obj(fields).to_string()
}

/// The router: membership + routing table + replication journal.
pub struct Router {
    cfg: RouterConfig,
    pub membership: Membership,
    routes: Mutex<HashMap<u64, RouteEntry>>,
    next_job: AtomicU64,
    journal: Option<JobJournal>,
    /// Fingerprint cache: computing one regenerates the dataset
    /// (O(N·D)), so amortise it per `(dataset, n, seed)`.
    fingerprints: Mutex<HashMap<(String, usize, u64), u64>>,
    draining: AtomicBool,
    metrics: Registry,
    migrations: Arc<Counter>,
    failovers: Arc<Counter>,
    heartbeats_missed: Arc<Counter>,
    replicated: Arc<Counter>,
    workers_up: Arc<Gauge>,
    route_ns: Arc<Histogram>,
}

impl Router {
    pub fn new(cfg: RouterConfig) -> Self {
        let journal = cfg.state_dir.as_ref().and_then(|dir| {
            let dir = dir.join("cluster-journal");
            match JobJournal::open(&dir) {
                Ok(j) => Some(j),
                Err(e) => {
                    eprintln!(
                        "warning: cluster journal at {} unusable ({e}); replicas stay in memory",
                        dir.display()
                    );
                    None
                }
            }
        });
        let metrics = Registry::new();
        let migrations = metrics.counter("cluster.migrations");
        let failovers = metrics.counter("cluster.failovers");
        let heartbeats_missed = metrics.counter("cluster.heartbeats_missed");
        let replicated = metrics.counter("cluster.checkpoints_replicated");
        let workers_up = metrics.gauge("cluster.workers_up");
        let route_ns = metrics.histogram("cluster.route_ns");
        Self {
            cfg,
            membership: Membership::new(),
            routes: Mutex::new(HashMap::new()),
            next_job: AtomicU64::new(1),
            journal,
            fingerprints: Mutex::new(HashMap::new()),
            draining: AtomicBool::new(false),
            metrics,
            migrations,
            failovers,
            heartbeats_missed,
            replicated,
            workers_up,
            route_ns,
        }
    }

    /// Register a worker (CLI `--workers` or a `hello`).
    pub fn register_worker(&self, addr: &str) -> WorkerId {
        let id = self.membership.register(addr);
        self.update_gauges();
        id
    }

    pub fn is_draining(&self) -> bool {
        self.draining.load(Ordering::SeqCst)
    }

    /// Dataset fingerprint for a spec, cached per `(dataset, n, seed)`.
    fn fingerprint_of(&self, spec: &JobSpec) -> anyhow::Result<u64> {
        let key = (spec.dataset.clone(), spec.n, spec.seed);
        if let Some(&fp) = self.fingerprints.lock().unwrap().get(&key) {
            return Ok(fp);
        }
        let fp = data::by_name(&spec.dataset, spec.n, spec.seed)?.fingerprint();
        self.fingerprints.lock().unwrap().insert(key, fp);
        Ok(fp)
    }

    fn journal_write(&self, id: u64, spec_line: &str, ckpt: &[u8]) {
        if let Some(j) = &self.journal {
            j.write(id, spec_line, ckpt);
        }
    }

    fn journal_remove(&self, id: u64) {
        if let Some(j) = &self.journal {
            j.remove(id);
        }
    }

    // ------------------------------------------------------------------
    // Command handlers
    // ------------------------------------------------------------------

    /// Handle one request line; returns (response line, keep_going).
    /// Mirrors [`protocol::handle_line`] for the router plane.
    pub fn handle_line(&self, line: &str) -> (String, bool) {
        let v = match json::parse(line.trim()) {
            Ok(v) => v,
            Err(e) => return (err_msg(&format!("bad json: {e}")), true),
        };
        let name = v.str_field("cmd").unwrap_or("");
        let Some(cmd) = Cmd::parse(name) else {
            return (err_msg(&format!("unknown cmd '{name}'")), true);
        };
        match cmd {
            Cmd::Submit => (self.handle_submit(&v), true),
            Cmd::Wait => (self.handle_wait(&v), true),
            Cmd::Status
            | Cmd::Snapshot
            | Cmd::Checkpoint
            | Cmd::Pause
            | Cmd::Resume
            | Cmd::Update
            | Cmd::Stop => (self.proxy_job_cmd(&v, cmd), true),
            Cmd::Trace if v.num_field("job").is_some() => (self.proxy_job_cmd(&v, cmd), true),
            Cmd::Trace => {
                let last = v.num_field("last").unwrap_or(128.0).max(1.0) as usize;
                let events = obs::trace::snapshot(None, last);
                (
                    ok_fields(vec![
                        ("count", Json::Num(events.len() as f64)),
                        ("events", Json::Arr(events.iter().map(|e| e.to_json()).collect())),
                    ]),
                    true,
                )
            }
            Cmd::Stats => (self.handle_stats(&v), true),
            Cmd::List => (self.handle_list(), true),
            Cmd::Metrics => (ok_fields(vec![("metrics", self.metrics_json())]), true),
            Cmd::Fault => (handle_fault(&v), true),
            Cmd::Migrate => (self.handle_migrate(&v), true),
            Cmd::ClusterStats => (self.handle_cluster_stats(), true),
            Cmd::Hello => (self.handle_hello(&v), true),
            Cmd::Shutdown => self.handle_shutdown(&v),
            Cmd::Quit => (ok_fields(vec![("bye", Json::Bool(true))]), false),
        }
    }

    fn handle_submit(&self, v: &Json) -> String {
        if self.is_draining() {
            return err_code("draining", true, "router is draining");
        }
        let spec = match spec_from_json(v) {
            Ok(s) => s,
            Err(e) => return err_msg(&format!("bad submit: {e:#}")),
        };
        let fp = match self.fingerprint_of(&spec) {
            Ok(f) => f,
            Err(e) => return err_msg(&format!("bad submit: {e:#}")),
        };
        let t0 = Instant::now();
        let Some((wid, addr)) = self.membership.owner_of(fp) else {
            return err_code("no_workers", true, "no alive workers to route to");
        };
        self.route_ns.record(t0.elapsed().as_nanos() as u64);
        let resume_b64 = v.str_field("resume_from").map(str::to_string);
        let line = submit_line(&spec, resume_b64.as_deref());
        let resp = match rpc(&addr, &line, self.cfg.rpc_timeout) {
            Ok(r) => r,
            Err(e) => {
                return err_code("worker_unavailable", true, &format!("worker {wid} ({addr}): {e:#}"))
            }
        };
        if !is_ok(&resp) {
            // Pass the worker's structured error (queue_full, …) through.
            return resp.to_string();
        }
        let Some(worker_job) = resp.num_field("job").map(|j| j as u64) else {
            return err_msg("worker accepted the submit but returned no job id");
        };
        let id = self.next_job.fetch_add(1, Ordering::SeqCst);
        let spec_line = spec_to_json(&spec).to_string();
        let ckpt = resume_b64.as_deref().and_then(|b| b64::decode(b).ok()).unwrap_or_default();
        self.journal_write(id, &spec_line, &ckpt);
        self.routes.lock().unwrap().insert(
            id,
            RouteEntry {
                worker: wid,
                worker_job,
                spec,
                spec_line,
                fingerprint: fp,
                last_ckpt: ckpt,
                replicated_iter: 0,
                terminal: false,
                migrating: false,
            },
        );
        self.update_gauges();
        ok_fields(vec![
            ("job", Json::Num(id as f64)),
            ("worker", Json::Num(wid as f64)),
            ("fingerprint", Json::Str(format!("{fp:016x}"))),
        ])
    }

    /// Current (worker, worker_job, terminal, migrating) for a routed job.
    fn route_of(&self, id: u64) -> Option<(WorkerId, u64, bool, bool)> {
        let g = self.routes.lock().unwrap();
        g.get(&id).map(|r| (r.worker, r.worker_job, r.terminal, r.migrating))
    }

    /// Proxy a job-scoped command to the owning worker, rewriting the
    /// job id in both directions.
    fn proxy_job_cmd(&self, v: &Json, cmd: Cmd) -> String {
        let Some(id) = v.num_field("job").map(|j| j as u64) else {
            return err_msg(&format!("'{}' requires a job id", cmd.name()));
        };
        let Some((wid, worker_job, _, _)) = self.route_of(id) else {
            return err_msg("unknown job");
        };
        let Some(addr) = self.membership.addr_of(wid) else {
            return err_msg("unknown job");
        };
        let Json::Obj(fields) = v else { return err_msg("request is not an object") };
        let mut fields = fields.clone();
        for (k, val) in fields.iter_mut() {
            if k == "job" {
                *val = Json::Num(worker_job as f64);
            }
        }
        let line = Json::Obj(fields).to_string();
        let mut resp = match rpc(&addr, &line, self.cfg.rpc_timeout) {
            Ok(r) => r,
            Err(e) => {
                return err_code("worker_unavailable", true, &format!("worker {wid} ({addr}): {e:#}"))
            }
        };
        if is_ok(&resp) {
            match cmd {
                // A client-driven checkpoint doubles as a replication
                // pull — stash the blob so a failover resumes from it.
                Cmd::Checkpoint => {
                    let iter = resp.num_field("iter").unwrap_or(0.0) as u64;
                    if let Some(b) = resp.str_field("checkpoint") {
                        if let Ok(bytes) = b64::decode(b) {
                            self.stash_replica(id, bytes, iter);
                        }
                    }
                }
                Cmd::Stop => self.mark_terminal(id),
                _ => {}
            }
        }
        if let Json::Obj(fields) = &mut resp {
            for (k, val) in fields.iter_mut() {
                if k == "job" {
                    *val = Json::Num(id as f64);
                }
            }
        }
        resp.to_string()
    }

    fn stash_replica(&self, id: u64, bytes: Vec<u8>, iter: u64) {
        let mut g = self.routes.lock().unwrap();
        if let Some(r) = g.get_mut(&id) {
            if iter >= r.replicated_iter {
                r.last_ckpt = bytes;
                r.replicated_iter = iter;
                let (spec_line, ckpt) = (r.spec_line.clone(), r.last_ckpt.clone());
                drop(g);
                self.replicated.inc();
                self.journal_write(id, &spec_line, &ckpt);
            }
        }
    }

    fn mark_terminal(&self, id: u64) {
        let mut g = self.routes.lock().unwrap();
        if let Some(r) = g.get_mut(&id) {
            r.terminal = true;
        }
        drop(g);
        self.journal_remove(id);
        self.update_gauges();
    }

    /// `wait` must not park a router thread in a blocking worker-side
    /// `wait` — the job can migrate or fail over mid-wait, and a
    /// blocked proxy would pin it to the old worker. Poll `status`
    /// (re-resolving the route each round, so failovers redirect us)
    /// until the job is terminal, then issue one instant `wait` for the
    /// result.
    fn handle_wait(&self, v: &Json) -> String {
        let Some(id) = v.num_field("job").map(|j| j as u64) else {
            return err_msg("'wait' requires a job id");
        };
        let deadline = Instant::now() + self.cfg.wait_timeout;
        loop {
            if Instant::now() > deadline {
                return err_code("wait_timeout", true, "job did not reach a terminal state in time");
            }
            let Some((wid, worker_job, _, migrating)) = self.route_of(id) else {
                return err_msg("unknown job");
            };
            if migrating {
                std::thread::sleep(Duration::from_millis(20));
                continue;
            }
            let Some(addr) = self.membership.addr_of(wid) else {
                return err_msg("unknown job");
            };
            let status = rpc(
                &addr,
                &format!(r#"{{"cmd":"status","job":{worker_job}}}"#),
                self.cfg.rpc_timeout,
            );
            match status {
                Ok(st) if is_ok(&st) => {
                    if st.get("terminal") == Some(&Json::Bool(true)) {
                        let wline = format!(r#"{{"cmd":"wait","job":{worker_job}}}"#);
                        if let Ok(mut resp) = rpc(&addr, &wline, self.cfg.rpc_timeout) {
                            if is_ok(&resp) {
                                self.mark_terminal(id);
                            }
                            if let Json::Obj(fields) = &mut resp {
                                for (k, val) in fields.iter_mut() {
                                    if k == "job" {
                                        *val = Json::Num(id as f64);
                                    }
                                }
                            }
                            return resp.to_string();
                        }
                        // Worker died between status and wait; the
                        // heartbeat loop will fail the job over — retry.
                    }
                }
                // `ok:false` (job unknown right after a failover
                // re-submit) or an unreachable worker: both settle once
                // the heartbeat loop has re-routed; keep polling.
                _ => {}
            }
            std::thread::sleep(Duration::from_millis(50));
        }
    }

    /// Sum a worker-plane `stats` response across every alive shard
    /// (every field is a monotonic count, so the sum is meaningful);
    /// pass `{"worker": id}` to read one shard.
    fn handle_stats(&self, v: &Json) -> String {
        let targets: Vec<(WorkerId, String)> = match v.num_field("worker") {
            Some(w) => {
                let wid = w as u64;
                match self.membership.addr_of(wid) {
                    Some(a) => vec![(wid, a)],
                    None => return err_msg("unknown worker"),
                }
            }
            None => self
                .membership
                .snapshot()
                .into_iter()
                .filter(|w| w.state == WorkerState::Up)
                .map(|w| (w.id, w.addr))
                .collect(),
        };
        let mut sums: Vec<(String, f64)> = Vec::new();
        let mut polled = 0usize;
        for (_, addr) in &targets {
            let Ok(resp) = rpc(addr, r#"{"cmd":"stats"}"#, self.cfg.rpc_timeout) else {
                continue;
            };
            if !is_ok(&resp) {
                continue;
            }
            polled += 1;
            if let Json::Obj(fields) = &resp {
                for (k, val) in fields {
                    let (Some(n), true) = (val.as_f64(), k != "ok") else { continue };
                    match sums.iter_mut().find(|(name, _)| name == k) {
                        Some((_, s)) => *s += n,
                        None => sums.push((k.clone(), n)),
                    }
                }
            }
        }
        let mut fields: Vec<(&str, Json)> =
            sums.iter().map(|(k, s)| (k.as_str(), Json::Num(*s))).collect();
        let polled_json = Json::Num(polled as f64);
        fields.push(("workers_polled", polled_json));
        ok_fields(fields)
    }

    /// The router's `list`: every routed job with its placement. Phase
    /// lives on the workers; `status` (proxied) reports it per job.
    fn handle_list(&self) -> String {
        let g = self.routes.lock().unwrap();
        let mut ids: Vec<u64> = g.keys().copied().collect();
        ids.sort_unstable();
        let jobs = Json::Arr(
            ids.iter()
                .map(|id| {
                    let r = &g[id];
                    Json::obj(vec![
                        ("job", Json::Num(*id as f64)),
                        ("worker", Json::Num(r.worker as f64)),
                        ("worker_job", Json::Num(r.worker_job as f64)),
                        ("terminal", Json::Bool(r.terminal)),
                    ])
                })
                .collect(),
        );
        ok_fields(vec![("jobs", jobs)])
    }

    fn handle_hello(&self, v: &Json) -> String {
        let Some(addr) = v.str_field("addr") else {
            return err_msg("'hello' requires the worker's addr");
        };
        let id = self.register_worker(addr);
        ok_fields(vec![("worker", Json::Num(id as f64))])
    }

    /// Live migration: checkpoint at the source, stop it there, resume
    /// on the target. Optional `"to": <worker id>` pins the target;
    /// otherwise the best alive worker *other than the source* takes it.
    fn handle_migrate(&self, v: &Json) -> String {
        let Some(id) = v.num_field("job").map(|j| j as u64) else {
            return err_msg("'migrate' requires a job id");
        };
        // Claim the route for migration under the lock.
        let (src, src_job, fp) = {
            let mut g = self.routes.lock().unwrap();
            let Some(r) = g.get_mut(&id) else { return err_msg("unknown job") };
            if r.terminal {
                return err_msg("job is terminal; nothing to migrate");
            }
            if r.migrating {
                return err_msg("job is already migrating");
            }
            r.migrating = true;
            (r.worker, r.worker_job, r.fingerprint)
        };
        let res = self.migrate_route(id, src, src_job, fp, v.num_field("to").map(|t| t as u64));
        {
            let mut g = self.routes.lock().unwrap();
            if let Some(r) = g.get_mut(&id) {
                r.migrating = false;
            }
        }
        match res {
            Ok((to, resumed_iter)) => {
                self.migrations.inc();
                self.update_gauges();
                ok_fields(vec![
                    ("job", Json::Num(id as f64)),
                    ("from", Json::Num(src as f64)),
                    ("to", Json::Num(to as f64)),
                    ("resumed_iter", Json::Num(resumed_iter as f64)),
                ])
            }
            Err(e) => err_msg(&format!("migrate failed: {e:#}")),
        }
    }

    fn migrate_route(
        &self,
        id: u64,
        src: WorkerId,
        src_job: u64,
        fp: u64,
        to: Option<WorkerId>,
    ) -> anyhow::Result<(WorkerId, u64)> {
        let (dst, dst_addr) = match to {
            Some(wid) => {
                anyhow::ensure!(wid != src, "job is already on worker {wid}");
                let addr = self
                    .membership
                    .addr_of(wid)
                    .ok_or_else(|| anyhow::anyhow!("unknown target worker {wid}"))?;
                anyhow::ensure!(
                    self.membership.state_of(wid) == Some(WorkerState::Up),
                    "target worker {wid} is not up"
                );
                (wid, addr)
            }
            None => self
                .membership
                .owner_of_excluding(fp, src)
                .ok_or_else(|| anyhow::anyhow!("no alternative alive worker"))?,
        };
        // Fresh checkpoint from the source; fall back to the last
        // replicated one (or a from-scratch resubmit — bit-identical
        // either way, the checkpoint only skips already-replayed work).
        let src_addr = self.membership.addr_of(src);
        let fresh = src_addr.as_ref().and_then(|a| {
            let line = format!(r#"{{"cmd":"checkpoint","job":{src_job}}}"#);
            let r = rpc(a, &line, self.cfg.rpc_timeout).ok()?;
            if !is_ok(&r) {
                return None;
            }
            let bytes = b64::decode(r.str_field("checkpoint")?).ok()?;
            Some((bytes, r.num_field("iter").unwrap_or(0.0) as u64))
        });
        if let Some(a) = &src_addr {
            // Stop the source copy; best effort — a dead source is
            // exactly the failover case and needs no stopping.
            let _ = rpc(a, &format!(r#"{{"cmd":"stop","job":{src_job}}}"#), self.cfg.rpc_timeout);
        }
        let (ckpt, iter) = match fresh {
            Some(f) => f,
            None => {
                let g = self.routes.lock().unwrap();
                let r = g.get(&id).ok_or_else(|| anyhow::anyhow!("route vanished"))?;
                (r.last_ckpt.clone(), r.replicated_iter)
            }
        };
        let resume = (!ckpt.is_empty()).then(|| b64::encode(&ckpt));
        let (spec, spec_line) = {
            let g = self.routes.lock().unwrap();
            let r = g.get(&id).ok_or_else(|| anyhow::anyhow!("route vanished"))?;
            (r.spec.clone(), r.spec_line.clone())
        };
        let line = submit_line(&spec, resume.as_deref());
        let resp = rpc(&dst_addr, &line, self.cfg.rpc_timeout)?;
        anyhow::ensure!(is_ok(&resp), "target worker {dst} rejected the resume: {resp}");
        let new_job = resp
            .num_field("job")
            .map(|j| j as u64)
            .ok_or_else(|| anyhow::anyhow!("target returned no job id"))?;
        {
            let mut g = self.routes.lock().unwrap();
            if let Some(r) = g.get_mut(&id) {
                r.worker = dst;
                r.worker_job = new_job;
                r.last_ckpt = ckpt.clone();
                r.replicated_iter = iter;
            }
        }
        self.journal_write(id, &spec_line, &ckpt);
        Ok((dst, iter))
    }

    fn handle_cluster_stats(&self) -> String {
        let routes = self.routes.lock().unwrap();
        let mut owned: HashMap<WorkerId, usize> = HashMap::new();
        for r in routes.values() {
            if !r.terminal {
                *owned.entry(r.worker).or_default() += 1;
            }
        }
        let workers = Json::Arr(
            self.membership
                .snapshot()
                .into_iter()
                .map(|w| {
                    Json::obj(vec![
                        ("id", Json::Num(w.id as f64)),
                        ("addr", Json::Str(w.addr.clone())),
                        ("state", Json::Str(w.state.label().into())),
                        ("jobs_owned", Json::Num(*owned.get(&w.id).unwrap_or(&0) as f64)),
                        ("age_ms", Json::Num(w.last_seen.elapsed().as_millis() as f64)),
                    ])
                })
                .collect(),
        );
        let mut ids: Vec<u64> = routes.keys().copied().collect();
        ids.sort_unstable();
        let jobs = Json::Arr(
            ids.iter()
                .map(|id| {
                    let r = &routes[id];
                    Json::obj(vec![
                        ("job", Json::Num(*id as f64)),
                        ("worker", Json::Num(r.worker as f64)),
                        ("worker_job", Json::Num(r.worker_job as f64)),
                        ("fingerprint", Json::Str(format!("{:016x}", r.fingerprint))),
                        ("terminal", Json::Bool(r.terminal)),
                        ("replicated_iter", Json::Num(r.replicated_iter as f64)),
                    ])
                })
                .collect(),
        );
        drop(routes);
        ok_fields(vec![
            ("workers", workers),
            ("jobs", jobs),
            ("workers_up", Json::Num(self.membership.up_count() as f64)),
            ("migrations", Json::Num(self.migrations.get() as f64)),
            ("failovers", Json::Num(self.failovers.get() as f64)),
            ("heartbeats_missed", Json::Num(self.heartbeats_missed.get() as f64)),
        ])
    }

    /// `shutdown` with a `"worker"` field drains that shard: mark it
    /// draining (it owns no new keys), migrate its live jobs off, then
    /// shut the worker itself down. Bare `shutdown` stops the router —
    /// workers are independent processes and keep serving.
    fn handle_shutdown(&self, v: &Json) -> (String, bool) {
        if let Some(w) = v.num_field("worker") {
            let wid = w as u64;
            let Some(addr) = self.membership.addr_of(wid) else {
                return (err_msg("unknown worker"), true);
            };
            self.membership.mark_draining(wid);
            let victims: Vec<(u64, u64, u64)> = {
                let g = self.routes.lock().unwrap();
                g.iter()
                    .filter(|(_, r)| r.worker == wid && !r.terminal && !r.migrating)
                    .map(|(&id, r)| (id, r.worker_job, r.fingerprint))
                    .collect()
            };
            let mut moved = 0usize;
            for (id, wjob, fp) in victims {
                {
                    let mut g = self.routes.lock().unwrap();
                    match g.get_mut(&id) {
                        Some(r) if !r.migrating && !r.terminal => r.migrating = true,
                        _ => continue,
                    }
                }
                let res = self.migrate_route(id, wid, wjob, fp, None);
                if let Some(r) = self.routes.lock().unwrap().get_mut(&id) {
                    r.migrating = false;
                }
                if res.is_ok() {
                    self.migrations.inc();
                    moved += 1;
                }
            }
            let _ = rpc(&addr, r#"{"cmd":"shutdown"}"#, self.cfg.rpc_timeout);
            self.membership.mark_dead(wid);
            self.update_gauges();
            (
                ok_fields(vec![
                    ("worker", Json::Num(wid as f64)),
                    ("draining", Json::Bool(true)),
                    ("migrated_jobs", Json::Num(moved as f64)),
                ]),
                true,
            )
        } else {
            self.draining.store(true, Ordering::SeqCst);
            (ok_fields(vec![("draining", Json::Bool(true))]), false)
        }
    }

    // ------------------------------------------------------------------
    // Heartbeat / replication / failover
    // ------------------------------------------------------------------

    /// One heartbeat round: probe every non-dead worker, replicate
    /// checkpoints from responsive ones, expire the silent, fail over
    /// every route stranded on a dead worker. Public so tests and the
    /// bench drive deterministic schedules; the background loop
    /// ([`spawn_heartbeat`](Self::spawn_heartbeat)) just calls it.
    pub fn heartbeat_once(&self) {
        for (wid, addr) in self.membership.probe_targets() {
            let dropped = faultinject::fire(faultinject::CLUSTER_HEARTBEAT_DROP);
            let alive = !dropped
                && rpc(&addr, r#"{"cmd":"list"}"#, self.cfg.rpc_timeout)
                    .map(|r| is_ok(&r))
                    .unwrap_or(false);
            if alive {
                self.membership.refresh(wid);
                self.replicate_worker(wid, &addr);
            } else {
                self.heartbeats_missed.inc();
            }
        }
        let _ = self.membership.expire(self.cfg.heartbeat_timeout);
        self.failover_dead_routes();
        self.update_gauges();
    }

    /// Pull a checkpoint from every non-terminal job on a responsive
    /// worker and journal it — the failover replica.
    fn replicate_worker(&self, wid: WorkerId, addr: &str) {
        let owned: Vec<(u64, u64)> = {
            let g = self.routes.lock().unwrap();
            g.iter()
                .filter(|(_, r)| r.worker == wid && !r.terminal && !r.migrating)
                .map(|(&id, r)| (id, r.worker_job))
                .collect()
        };
        for (id, wjob) in owned {
            let sline = format!(r#"{{"cmd":"status","job":{wjob}}}"#);
            let Ok(st) = rpc(addr, &sline, self.cfg.rpc_timeout) else { continue };
            if !is_ok(&st) {
                continue;
            }
            if st.get("terminal") == Some(&Json::Bool(true)) {
                self.mark_terminal(id);
                continue;
            }
            if faultinject::fire(faultinject::CLUSTER_REPLICATE_FAIL) {
                continue;
            }
            let cline = format!(r#"{{"cmd":"checkpoint","job":{wjob}}}"#);
            let Ok(ck) = rpc(addr, &cline, self.cfg.rpc_timeout) else { continue };
            if !is_ok(&ck) {
                continue; // still in the similarity stage — nothing to replicate yet
            }
            let iter = ck.num_field("iter").unwrap_or(0.0) as u64;
            if let Some(b) = ck.str_field("checkpoint") {
                if let Ok(bytes) = b64::decode(b) {
                    self.stash_replica(id, bytes, iter);
                }
            }
        }
    }

    /// Re-admit every non-terminal route stranded on a dead worker onto
    /// the surviving HRW owner, resuming from the replicated checkpoint
    /// (or from scratch — bit-identical, just slower). Routes with no
    /// surviving candidate stay put and retry next round.
    fn failover_dead_routes(&self) {
        let stranded: Vec<u64> = {
            let g = self.routes.lock().unwrap();
            g.iter()
                .filter(|(_, r)| {
                    !r.terminal
                        && !r.migrating
                        && self.membership.state_of(r.worker) == Some(WorkerState::Dead)
                })
                .map(|(&id, _)| id)
                .collect()
        };
        for id in stranded {
            let (spec, spec_line, fp, ckpt, iter) = {
                let g = self.routes.lock().unwrap();
                let Some(r) = g.get(&id) else { continue };
                (
                    r.spec.clone(),
                    r.spec_line.clone(),
                    r.fingerprint,
                    r.last_ckpt.clone(),
                    r.replicated_iter,
                )
            };
            let Some((wid, addr)) = self.membership.owner_of(fp) else { continue };
            let resume = (!ckpt.is_empty()).then(|| b64::encode(&ckpt));
            let line = submit_line(&spec, resume.as_deref());
            let Ok(resp) = rpc(&addr, &line, self.cfg.rpc_timeout) else { continue };
            if !is_ok(&resp) {
                continue;
            }
            let Some(new_job) = resp.num_field("job").map(|j| j as u64) else { continue };
            {
                let mut g = self.routes.lock().unwrap();
                if let Some(r) = g.get_mut(&id) {
                    r.worker = wid;
                    r.worker_job = new_job;
                }
            }
            self.failovers.inc();
            self.journal_write(id, &spec_line, &ckpt);
            eprintln!(
                "cluster: job {id} failed over to worker {wid} ({addr}), resumed at iter {iter}"
            );
        }
    }

    /// Re-admit journalled jobs after a router restart. Call once the
    /// initial worker set is registered.
    pub fn recover(&self) -> usize {
        let Some(j) = &self.journal else { return 0 };
        let mut readmitted = 0usize;
        for entry in j.read_all() {
            let Ok(v) = json::parse(&entry.spec_json) else { continue };
            let Ok(spec) = spec_from_json(&v) else { continue };
            let Ok(fp) = self.fingerprint_of(&spec) else { continue };
            let Some((wid, addr)) = self.membership.owner_of(fp) else { continue };
            let resume = (!entry.checkpoint.is_empty()).then(|| b64::encode(&entry.checkpoint));
            let line = submit_line(&spec, resume.as_deref());
            let Ok(resp) = rpc(&addr, &line, self.cfg.rpc_timeout) else { continue };
            if !is_ok(&resp) {
                continue;
            }
            let Some(worker_job) = resp.num_field("job").map(|j| j as u64) else { continue };
            // Preserve the journalled id; keep the allocator ahead of it.
            let id = entry.id;
            self.next_job.fetch_max(id + 1, Ordering::SeqCst);
            self.routes.lock().unwrap().insert(
                id,
                RouteEntry {
                    worker: wid,
                    worker_job,
                    spec_line: spec_to_json(&spec).to_string(),
                    spec,
                    fingerprint: fp,
                    last_ckpt: entry.checkpoint,
                    replicated_iter: 0,
                    terminal: false,
                    migrating: false,
                },
            );
            readmitted += 1;
        }
        self.update_gauges();
        readmitted
    }

    fn update_gauges(&self) {
        self.workers_up.set(self.membership.up_count() as i64);
        let mut owned: HashMap<WorkerId, i64> = HashMap::new();
        {
            let g = self.routes.lock().unwrap();
            for r in g.values() {
                if !r.terminal {
                    *owned.entry(r.worker).or_default() += 1;
                }
            }
        }
        for w in self.membership.snapshot() {
            self.metrics
                .gauge(&format!("cluster.shard.{}.jobs_owned", w.id))
                .set(*owned.get(&w.id).unwrap_or(&0));
        }
    }

    /// Router metrics (the `metrics` command): the cluster registry
    /// (per-shard gauges, migration/failover counters, route latency).
    pub fn metrics_json(&self) -> Json {
        Json::obj(vec![("cluster", self.metrics.snapshot())])
    }

    /// Start the background heartbeat loop (no-op when the config says
    /// manual). The thread exits when the router drains.
    pub fn spawn_heartbeat(self: &Arc<Self>) {
        let Some(interval) = self.cfg.heartbeat_interval else { return };
        let router = Arc::clone(self);
        std::thread::spawn(move || {
            while !router.is_draining() {
                router.heartbeat_once();
                std::thread::sleep(interval);
            }
        });
    }

    /// Accept loop, mirroring the worker-plane server: one thread per
    /// connection, bounded request frames, exits once draining.
    pub fn serve(
        self: &Arc<Self>,
        addr: &str,
        on_bound: impl FnOnce(SocketAddr),
    ) -> anyhow::Result<()> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        on_bound(local);
        for stream in listener.incoming() {
            if self.is_draining() {
                break;
            }
            let Ok(stream) = stream else { continue };
            let router = Arc::clone(self);
            std::thread::spawn(move || {
                let _ = handle_client(&router, stream);
            });
        }
        Ok(())
    }
}

/// The worker-plane `fault` handler, verbatim semantics: the registry
/// is process-global, so arming `cluster.*` points over the router's
/// own socket drives its heartbeat/replication paths.
fn handle_fault(v: &Json) -> String {
    if v.get("clear") == Some(&Json::Bool(true)) {
        faultinject::disarm_all();
    }
    if let Some(spec) = v.str_field("spec") {
        if let Err(e) = faultinject::arm_spec(spec) {
            return err_msg(&format!("bad fault spec: {e}"));
        }
    }
    let points = Json::Arr(
        faultinject::status()
            .into_iter()
            .map(|p| {
                Json::obj(vec![
                    ("point", Json::Str(p.point.into())),
                    ("trigger", Json::Str(p.trigger)),
                    ("checks", Json::Num(p.checks as f64)),
                    ("fired", Json::Num(p.fired as f64)),
                ])
            })
            .collect(),
    );
    ok_fields(vec![("enabled", Json::Bool(faultinject::enabled())), ("points", points)])
}

fn handle_client(router: &Router, stream: TcpStream) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(600)))?;
    stream.set_write_timeout(Some(Duration::from_secs(30)))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut buf = Vec::new();
    loop {
        match protocol::read_bounded_line(&mut reader, &mut buf, protocol::MAX_REQUEST_BYTES)? {
            LineRead::Eof => return Ok(()),
            LineRead::TooLarge => {
                let resp = err_code("request_too_large", false, "request exceeds the frame bound");
                writer.write_all(resp.as_bytes())?;
                writer.write_all(b"\n")?;
                return Ok(());
            }
            LineRead::Line => {}
        }
        let line = String::from_utf8_lossy(&buf).into_owned();
        if line.trim().is_empty() {
            continue;
        }
        let (resp, keep) = router.handle_line(&line);
        writer.write_all(resp.as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
        if !keep {
            // Poke the accept loop so a bare `shutdown` unblocks it.
            let _ = TcpStream::connect(writer.local_addr()?);
            return Ok(());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn submit_line_reattaches_resume_from() {
        let spec = JobSpec::default();
        let line = submit_line(&spec, Some("AAAA"));
        let v = json::parse(&line).unwrap();
        assert_eq!(v.str_field("cmd"), Some("submit"));
        assert_eq!(v.str_field("resume_from"), Some("AAAA"));
        let bare = json::parse(&submit_line(&spec, None)).unwrap();
        assert!(bare.get("resume_from").is_none());
    }

    #[test]
    fn router_with_no_workers_rejects_submits_retriably() {
        let r = Router::new(RouterConfig { heartbeat_interval: None, ..Default::default() });
        let (resp, keep) =
            r.handle_line(r#"{"cmd":"submit","dataset":"mnist","n":64,"iters":5}"#);
        assert!(keep);
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(v.str_field("code"), Some("no_workers"));
        assert_eq!(v.get("retriable"), Some(&Json::Bool(true)));
    }

    #[test]
    fn worker_plane_job_cmds_need_known_jobs() {
        let r = Router::new(RouterConfig { heartbeat_interval: None, ..Default::default() });
        for cmd in ["status", "pause", "resume", "stop", "checkpoint", "migrate"] {
            let (resp, _) = r.handle_line(&format!(r#"{{"cmd":"{cmd}","job":7}}"#));
            let v = json::parse(&resp).unwrap();
            assert_eq!(v.get("ok"), Some(&Json::Bool(false)), "{cmd} on unknown job must fail");
        }
    }

    #[test]
    fn hello_registers_and_cluster_stats_reports() {
        let r = Router::new(RouterConfig { heartbeat_interval: None, ..Default::default() });
        let (resp, _) = r.handle_line(r#"{"cmd":"hello","addr":"127.0.0.1:7001"}"#);
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.num_field("worker"), Some(1.0));
        // Same addr re-announces as the same worker.
        let (resp, _) = r.handle_line(r#"{"cmd":"hello","addr":"127.0.0.1:7001"}"#);
        assert_eq!(json::parse(&resp).unwrap().num_field("worker"), Some(1.0));
        let (stats, _) = r.handle_line(r#"{"cmd":"cluster_stats"}"#);
        let v = json::parse(&stats).unwrap();
        assert_eq!(v.num_field("workers_up"), Some(1.0));
        assert_eq!(v.get("workers").and_then(Json::as_arr).map(<[Json]>::len), Some(1));
    }

    #[test]
    fn bare_shutdown_drains_the_router() {
        let r = Router::new(RouterConfig { heartbeat_interval: None, ..Default::default() });
        let (resp, keep) = r.handle_line(r#"{"cmd":"shutdown"}"#);
        assert!(!keep);
        assert!(r.is_draining());
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("draining"), Some(&Json::Bool(true)));
        let (resp, _) = r.handle_line(r#"{"cmd":"submit","dataset":"mnist","n":64}"#);
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.str_field("code"), Some("draining"));
    }
}
