//! Nearest-Neighbour Preservation (Venna et al. [44]) — the paper's §6
//! metric #3 and the rows 3 of Figures 6/7.
//!
//! For every point: take its `K_HIGH = 30` nearest neighbours in the
//! high-dimensional space and, for each k = 1..30, its k nearest in the
//! embedding. With T(k) = |high ∩ low_k|: precision(k) = T/k, recall(k) =
//! T/30. Curves are averaged over all points (or a subsample for big N,
//! as the paper does for Word2Vec).

use crate::hd::{bruteforce, Dataset, KnnGraph};
use crate::util::parallel;

pub const K_HIGH: usize = 30;

/// An averaged precision/recall curve, index = k-1 for k = 1..=30.
#[derive(Debug, Clone)]
pub struct NnpCurve {
    pub precision: Vec<f64>,
    pub recall: Vec<f64>,
}

impl NnpCurve {
    /// Area-ish single-number summary (mean precision over the curve) —
    /// handy for tables and regression tests.
    pub fn mean_precision(&self) -> f64 {
        self.precision.iter().sum::<f64>() / self.precision.len() as f64
    }

    pub fn mean_recall(&self) -> f64 {
        self.recall.iter().sum::<f64>() / self.recall.len() as f64
    }
}

/// NNP curve of `embedding` (`(n,2)` row-major) against `data`.
///
/// `sample`: evaluate on at most this many query points (0 = all); the
/// paper subsamples NNP for its 3M dataset for exactly this reason.
pub fn nnp_curve(data: &Dataset, embedding: &[f32], sample: usize, seed: u64) -> NnpCurve {
    let n = data.n;
    assert!(embedding.len() >= 2 * n);
    let queries: Vec<usize> = if sample == 0 || sample >= n {
        (0..n).collect()
    } else {
        crate::util::rng::Rng::new(seed).sample_indices(n, sample)
    };
    // High-d exact kNN for the query subset against the full dataset.
    let high = knn_subset_high(data, &queries, K_HIGH);
    // Low-d exact kNN in the embedding for the same queries.
    let low = knn_subset_low(embedding, n, &queries, K_HIGH);

    let m = queries.len();
    let mut tp_sum = vec![0.0f64; K_HIGH]; // Σ_points T(k)
    for q in 0..m {
        let hset: std::collections::HashSet<u32> = high.row_idx(q).iter().copied().collect();
        let mut t = 0usize;
        for k in 0..K_HIGH {
            if hset.contains(&low.row_idx(q)[k]) {
                t += 1;
            }
            tp_sum[k] += t as f64;
        }
    }
    let precision = (0..K_HIGH).map(|k| tp_sum[k] / ((k + 1) as f64 * m as f64)).collect();
    let recall = (0..K_HIGH).map(|k| tp_sum[k] / (K_HIGH as f64 * m as f64)).collect();
    NnpCurve { precision, recall }
}

fn knn_subset_high(data: &Dataset, queries: &[usize], k: usize) -> KnnGraph {
    let m = queries.len();
    let mut g = KnnGraph::new(m, k);
    {
        let idx = parallel::SyncSlice::new(&mut g.idx);
        let d2s = parallel::SyncSlice::new(&mut g.d2);
        parallel::par_chunks(m, 8, |range| {
            for q in range {
                let i = queries[q];
                let qi = data.row(i);
                let mut kb = crate::hd::knn::KBest::new(k);
                for j in 0..data.n {
                    if j == i {
                        continue;
                    }
                    let d = crate::hd::dist2(qi, data.row(j));
                    if d < kb.bound() {
                        kb.push(d, j as u32);
                    }
                }
                for (slot, (d, id)) in kb.into_sorted().into_iter().enumerate() {
                    unsafe {
                        *idx.get_mut(q * k + slot) = id;
                        *d2s.get_mut(q * k + slot) = d;
                    }
                }
            }
        });
    }
    g
}

fn knn_subset_low(embedding: &[f32], n: usize, queries: &[usize], k: usize) -> KnnGraph {
    let m = queries.len();
    let q_pts: Vec<f32> = queries.iter().flat_map(|&i| [embedding[2 * i], embedding[2 * i + 1]]).collect();
    // knn_cross can't self-exclude across index spaces; exclude by id.
    let mut g = bruteforce::knn_cross(embedding, n, 2, &q_pts, k + 1, false);
    // Drop each query's own id from its row.
    let mut out = KnnGraph::new(m, k);
    for q in 0..m {
        let own = queries[q] as u32;
        let mut slot = 0;
        for j in 0..k + 1 {
            let id = g.row_idx(q)[j];
            if id == own || slot == k {
                continue;
            }
            out.idx[q * k + slot] = id;
            out.d2[q * k + slot] = g.row_d2(q)[j];
            slot += 1;
        }
        // If own id was not in the k+1 (distance ties), drop the farthest.
        while slot < k {
            out.idx[q * k + slot] = g.row_idx(q)[slot];
            out.d2[q * k + slot] = g.row_d2(q)[slot];
            slot += 1;
        }
    }
    g = out;
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn perfect_preservation_when_embedding_is_the_data() {
        // 2-D data embedded as itself: precision = recall = 1 at k = 30.
        let mut rng = Rng::new(2);
        let n = 120;
        let x: Vec<f32> = (0..2 * n).map(|_| rng.gauss_f32(0.0, 1.0)).collect();
        let data = Dataset::new("d", n, 2, x.clone(), vec![]);
        let c = nnp_curve(&data, &x, 0, 0);
        assert!(c.precision[K_HIGH - 1] > 0.999, "p30={}", c.precision[K_HIGH - 1]);
        assert!(c.recall[K_HIGH - 1] > 0.999);
        // And precision(k) = 1 for every k (prefix property holds when
        // orderings are identical).
        assert!(c.precision.iter().all(|&p| p > 0.999));
    }

    #[test]
    fn random_embedding_scores_low() {
        let mut rng = Rng::new(3);
        let n = 200;
        let x: Vec<f32> = (0..n * 16).map(|_| rng.gauss_f32(0.0, 1.0)).collect();
        let data = Dataset::new("d", n, 16, x, vec![]);
        let y: Vec<f32> = (0..2 * n).map(|_| rng.gauss_f32(0.0, 1.0)).collect();
        let c = nnp_curve(&data, &y, 0, 0);
        // Random chance is ~ K/N = 0.15; allow slack.
        assert!(c.mean_precision() < 0.35, "random embedding too good: {}", c.mean_precision());
    }

    #[test]
    fn subsampled_curve_is_close_to_full() {
        let mut rng = Rng::new(5);
        let n = 300;
        let x: Vec<f32> = (0..n * 4).map(|_| rng.gauss_f32(0.0, 1.0)).collect();
        let data = Dataset::new("d", n, 4, x, vec![]);
        let y: Vec<f32> = (0..n).flat_map(|i| {
            let r = data.row(i);
            [r[0] + 0.1 * r[2], r[1] - 0.1 * r[3]]
        }).collect();
        let full = nnp_curve(&data, &y, 0, 0);
        let sub = nnp_curve(&data, &y, 150, 7);
        assert!((full.mean_precision() - sub.mean_precision()).abs() < 0.08);
    }

    #[test]
    fn recall_monotone_in_k() {
        let mut rng = Rng::new(8);
        let n = 100;
        let x: Vec<f32> = (0..n * 8).map(|_| rng.gauss_f32(0.0, 1.0)).collect();
        let data = Dataset::new("d", n, 8, x, vec![]);
        let y: Vec<f32> = (0..2 * n).map(|_| rng.gauss_f32(0.0, 1.0)).collect();
        let c = nnp_curve(&data, &y, 0, 0);
        for w in c.recall.windows(2) {
            assert!(w[1] >= w[0] - 1e-12, "recall must be monotone");
        }
    }
}
