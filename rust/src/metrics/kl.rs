//! Kullback–Leibler divergence of an embedding (Eq. 1).
//!
//! `KL(P||Q) = Σ_ij p_ij ln(p_ij / q_ij)` with
//! `q_ij = t_ij / Z`, `t = (1+d²)^{-1}`, `Z = Σ_{k≠l} t_kl`.
//!
//! The sum over P is sparse (P is supported on the kNN graph), but Z is a
//! full O(N²) pairwise sum — computed threaded and exactly here, which is
//! feasible for every N the quality figures use. `kl_divergence_sparse_z`
//! accepts an externally-estimated Z (e.g. the field-based Ẑ) so the
//! estimator itself can be validated against the exact value.

use crate::hd::SparseP;
use crate::util::parallel;

/// Exact Z: Σ_{k≠l} (1 + ||y_k - y_l||²)^{-1} over all ordered pairs.
pub fn exact_z(y: &[f32]) -> f64 {
    let n = y.len() / 2;
    // Sum over unordered pairs, then double (t is symmetric).
    let half = parallel::par_reduce(
        n,
        0.0f64,
        |acc, i| {
            let (xi, yi) = (y[2 * i], y[2 * i + 1]);
            let mut s = acc;
            for j in i + 1..n {
                let dx = xi - y[2 * j];
                let dy = yi - y[2 * j + 1];
                s += 1.0 / (1.0 + (dx * dx + dy * dy) as f64);
            }
            s
        },
        |a, b| a + b,
    );
    2.0 * half
}

/// KL divergence given an explicit normalisation Z.
pub fn kl_divergence_sparse_z(p: &SparseP, y: &[f32], z: f64) -> f64 {
    let n = p.n();
    assert!(y.len() >= 2 * n);
    let ln_z = z.ln();
    parallel::par_reduce(
        n,
        0.0f64,
        |acc, i| {
            let (cols, vals) = p.csr.row(i);
            let (xi, yi) = (y[2 * i], y[2 * i + 1]);
            let mut s = acc;
            for (c, &pij) in cols.iter().zip(vals) {
                if pij <= 0.0 {
                    continue;
                }
                let j = *c as usize;
                let dx = xi - y[2 * j];
                let dy = yi - y[2 * j + 1];
                let t = 1.0 / (1.0 + (dx * dx + dy * dy) as f64);
                // ln q = ln t - ln Z
                s += pij as f64 * ((pij as f64).ln() - t.ln() + ln_z);
            }
            s
        },
        |a, b| a + b,
    )
}

/// Exact KL divergence (exact Z), the paper's quality metric #2.
pub fn kl_divergence_exact(p: &SparseP, y: &[f32]) -> f64 {
    kl_divergence_sparse_z(p, y, exact_z(y))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hd::sparse::Csr;

    fn uniform_p(n: usize, k: usize) -> SparseP {
        // Ring neighbours, uniform probabilities summing to 1.
        let mut col = Vec::new();
        let mut val = Vec::new();
        for i in 0..n {
            for j in 1..=k {
                col.push(((i + j) % n) as u32);
                val.push(1.0 / (n * k) as f32);
            }
        }
        SparseP { csr: Csr::from_rows(n, n, k, col, val), perplexity: k as f32 }
    }

    #[test]
    fn exact_z_small_case() {
        // Three points: pairwise d² = 1 (0-1), 1 (1-2), 4 (0-2).
        let y = vec![0.0, 0.0, 1.0, 0.0, 2.0, 0.0];
        let expect = 2.0 * (0.5 + 0.5 + 0.2);
        assert!((exact_z(&y) - expect).abs() < 1e-9);
    }

    #[test]
    fn kl_nonnegative_and_zero_when_q_matches() {
        // If Q == P exactly, KL = 0. Construct 2 points with p = q.
        // With n=2: q_01 = q_10 = 0.5 regardless of distance. p = 0.5 each.
        let p = uniform_p(2, 1);
        let y = vec![0.0, 0.0, 3.0, 0.0];
        let kl = kl_divergence_exact(&p, &y);
        assert!(kl.abs() < 1e-9, "kl={kl}");
    }

    #[test]
    fn kl_decreases_when_structure_matches() {
        // P favours ring neighbours; an embedding placing ring neighbours
        // close must have lower KL than a random one.
        let n = 60;
        let p = uniform_p(n, 2);
        let mut rng = crate::util::rng::Rng::new(3);
        let good: Vec<f32> = (0..n)
            .flat_map(|i| {
                let a = i as f32 / n as f32 * std::f32::consts::TAU;
                [a.cos() * 5.0, a.sin() * 5.0]
            })
            .collect();
        let random: Vec<f32> = (0..2 * n).map(|_| rng.gauss_f32(0.0, 5.0)).collect();
        assert!(kl_divergence_exact(&p, &good) < kl_divergence_exact(&p, &random));
    }

    #[test]
    fn sparse_z_matches_exact_when_given_exact_z() {
        let n = 40;
        let p = uniform_p(n, 3);
        let mut rng = crate::util::rng::Rng::new(1);
        let y: Vec<f32> = (0..2 * n).map(|_| rng.gauss_f32(0.0, 2.0)).collect();
        let z = exact_z(&y);
        let a = kl_divergence_exact(&p, &y);
        let b = kl_divergence_sparse_z(&p, &y, z);
        assert!((a - b).abs() < 1e-12);
    }
}
