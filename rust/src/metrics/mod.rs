//! Embedding quality metrics used by the paper's evaluation (§6):
//! Kullback–Leibler divergence of the final embedding (the objective
//! itself) and Nearest-Neighbour Preservation precision/recall
//! (Venna et al. [44], as implemented by Ingram & Munzner [15]).

pub mod kl;
pub mod nnp;

pub use kl::{kl_divergence_exact, kl_divergence_sparse_z};
pub use nnp::{nnp_curve, NnpCurve};
