//! Dependency-free radix-2 FFT (power-of-two sizes only).
//!
//! Iterative Cooley–Tukey with a bit-reversal permutation and a twiddle
//! table computed once per plan in f64 (then rounded to f32), which keeps
//! the worst-case relative error of a 2048² 2-D transform comfortably
//! below 1e-5 — two orders of magnitude under the subsystem's 1% force
//! accuracy budget.
//!
//! Data layout is split re/im `&mut [f32]` (structure-of-arrays): the
//! butterflies vectorise, and real-input planes (charge grids, kernels)
//! reuse the same buffers without an interleave pass. 2-D transforms are
//! row FFTs → in-place transpose → row FFTs → transpose, with the row
//! passes threaded over `util::parallel`.

use crate::util::parallel;

/// An FFT plan for one power-of-two size: the twiddle half-table
/// `tw[k] = e^{-2πik/n}`, `k < n/2`, plus the bit-reversal index table
/// (both computed once — `run` is called 2·m times per 2-D transform).
pub struct Fft {
    n: usize,
    tw_re: Vec<f32>,
    tw_im: Vec<f32>,
    rev: Vec<u32>,
}

impl Fft {
    pub fn new(n: usize) -> Self {
        assert!(n.is_power_of_two() && n >= 2, "radix-2 FFT needs a power-of-two size, got {n}");
        let mut tw_re = Vec::with_capacity(n / 2);
        let mut tw_im = Vec::with_capacity(n / 2);
        for k in 0..n / 2 {
            let ang = -2.0 * std::f64::consts::PI * k as f64 / n as f64;
            tw_re.push(ang.cos() as f32);
            tw_im.push(ang.sin() as f32);
        }
        // rev[i] = bit-reverse of i over log2(n) bits.
        let mut rev = vec![0u32; n];
        for i in 1..n {
            rev[i] = (rev[i >> 1] >> 1) | if i & 1 == 1 { (n >> 1) as u32 } else { 0 };
        }
        Self { n, tw_re, tw_im, rev }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        false
    }

    /// In-place forward DFT of one length-`n` signal.
    pub fn forward(&self, re: &mut [f32], im: &mut [f32]) {
        self.run(re, im, false);
    }

    /// In-place inverse DFT (including the 1/n scale).
    pub fn inverse(&self, re: &mut [f32], im: &mut [f32]) {
        self.run(re, im, true);
        let s = 1.0 / self.n as f32;
        for v in re.iter_mut() {
            *v *= s;
        }
        for v in im.iter_mut() {
            *v *= s;
        }
    }

    fn run(&self, re: &mut [f32], im: &mut [f32], inverse: bool) {
        let n = self.n;
        debug_assert_eq!(re.len(), n);
        debug_assert_eq!(im.len(), n);
        // Bit-reversal permutation (precomputed table).
        for i in 1..n {
            let j = self.rev[i] as usize;
            if i < j {
                re.swap(i, j);
                im.swap(i, j);
            }
        }
        // Butterfly stages.
        let mut len = 2usize;
        while len <= n {
            let half = len / 2;
            let stride = n / len;
            for start in (0..n).step_by(len) {
                for k in 0..half {
                    let wi_raw = self.tw_im[k * stride];
                    let (wr, wi) = (self.tw_re[k * stride], if inverse { -wi_raw } else { wi_raw });
                    let a = start + k;
                    let b = a + half;
                    let vr = re[b] * wr - im[b] * wi;
                    let vi = re[b] * wi + im[b] * wr;
                    re[b] = re[a] - vr;
                    im[b] = im[a] - vi;
                    re[a] += vr;
                    im[a] += vi;
                }
            }
            len <<= 1;
        }
    }
}

/// In-place transpose of a square row-major `m×m` matrix.
pub fn transpose(a: &mut [f32], m: usize) {
    debug_assert_eq!(a.len(), m * m);
    for r in 0..m {
        for c in r + 1..m {
            a.swap(r * m + c, c * m + r);
        }
    }
}

/// Shared-buffer handle for threading row transforms (rows are disjoint).
struct Rows {
    ptr: *mut f32,
    m: usize,
}

unsafe impl Send for Rows {}
unsafe impl Sync for Rows {}

impl Rows {
    /// # Safety
    /// Each row index must be used by at most one thread at a time.
    unsafe fn row(&self, r: usize) -> &mut [f32] {
        std::slice::from_raw_parts_mut(self.ptr.add(r * self.m), self.m)
    }
}

fn fft_rows(plan: &Fft, re: &mut [f32], im: &mut [f32], inverse: bool) {
    let m = plan.len();
    let re_rows = Rows { ptr: re.as_mut_ptr(), m };
    let im_rows = Rows { ptr: im.as_mut_ptr(), m };
    parallel::par_chunks(m, 8, |rows| {
        for r in rows {
            let (rr, ri) = unsafe { (re_rows.row(r), im_rows.row(r)) };
            plan.run(rr, ri, inverse);
        }
    });
    if inverse {
        let s = 1.0 / m as f32;
        for v in re.iter_mut() {
            *v *= s;
        }
        for v in im.iter_mut() {
            *v *= s;
        }
    }
}

/// In-place 2-D DFT of a row-major `m×m` plane (`m = plan.len()`).
/// The inverse includes the full 1/m² scale.
pub fn fft2d(plan: &Fft, re: &mut [f32], im: &mut [f32], inverse: bool) {
    let m = plan.len();
    assert_eq!(re.len(), m * m);
    assert_eq!(im.len(), m * m);
    fft_rows(plan, re, im, inverse);
    transpose(re, m);
    transpose(im, m);
    fft_rows(plan, re, im, inverse);
    transpose(re, m);
    transpose(im, m);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Naive O(n²) DFT in f64, the correctness reference.
    fn dft_naive(x: &[f32]) -> (Vec<f64>, Vec<f64>) {
        let n = x.len();
        let mut re = vec![0.0f64; n];
        let mut im = vec![0.0f64; n];
        for (k, (rk, ik)) in re.iter_mut().zip(im.iter_mut()).enumerate() {
            for (t, &v) in x.iter().enumerate() {
                let ang = -2.0 * std::f64::consts::PI * (k * t) as f64 / n as f64;
                *rk += v as f64 * ang.cos();
                *ik += v as f64 * ang.sin();
            }
        }
        (re, im)
    }

    #[test]
    fn matches_naive_dft() {
        let mut rng = Rng::new(1);
        for n in [2usize, 8, 32, 128] {
            let x: Vec<f32> = (0..n).map(|_| rng.gauss_f32(0.0, 1.0)).collect();
            let (er, ei) = dft_naive(&x);
            let mut re = x.clone();
            let mut im = vec![0.0f32; n];
            Fft::new(n).forward(&mut re, &mut im);
            for k in 0..n {
                assert!(
                    (re[k] as f64 - er[k]).abs() < 1e-3 && (im[k] as f64 - ei[k]).abs() < 1e-3,
                    "n={n} k={k}: ({},{}) vs ({},{})",
                    re[k],
                    im[k],
                    er[k],
                    ei[k]
                );
            }
        }
    }

    #[test]
    fn forward_inverse_roundtrip() {
        let mut rng = Rng::new(2);
        let n = 256;
        let plan = Fft::new(n);
        let x: Vec<f32> = (0..n).map(|_| rng.gauss_f32(0.0, 2.0)).collect();
        let mut re = x.clone();
        let mut im = vec![0.0f32; n];
        plan.forward(&mut re, &mut im);
        plan.inverse(&mut re, &mut im);
        for i in 0..n {
            assert!((re[i] - x[i]).abs() < 1e-4, "{} vs {}", re[i], x[i]);
            assert!(im[i].abs() < 1e-4);
        }
    }

    #[test]
    fn impulse_has_flat_spectrum() {
        let n = 64;
        let mut re = vec![0.0f32; n];
        let mut im = vec![0.0f32; n];
        re[0] = 1.0;
        Fft::new(n).forward(&mut re, &mut im);
        for k in 0..n {
            assert!((re[k] - 1.0).abs() < 1e-5 && im[k].abs() < 1e-5);
        }
    }

    #[test]
    fn fft2d_roundtrip_and_dc() {
        let mut rng = Rng::new(3);
        let m = 32;
        let plan = Fft::new(m);
        let x: Vec<f32> = (0..m * m).map(|_| rng.gauss_f32(0.0, 1.0)).collect();
        let mut re = x.clone();
        let mut im = vec![0.0f32; m * m];
        fft2d(&plan, &mut re, &mut im, false);
        // DC bin = sum of the plane.
        let sum: f64 = x.iter().map(|&v| v as f64).sum();
        assert!((re[0] as f64 - sum).abs() < 1e-3 * sum.abs().max(1.0));
        fft2d(&plan, &mut re, &mut im, true);
        for i in 0..m * m {
            assert!((re[i] - x[i]).abs() < 1e-4);
            assert!(im[i].abs() < 1e-4);
        }
    }

    #[test]
    fn transpose_involution() {
        let m = 5;
        let a: Vec<f32> = (0..25).map(|i| i as f32).collect();
        let mut b = a.clone();
        transpose(&mut b, m);
        assert_eq!(b[1], a[5]); // (0,1) <- (1,0)
        transpose(&mut b, m);
        assert_eq!(a, b);
    }
}
