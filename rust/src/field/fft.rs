//! Dependency-free radix-2 FFT (power-of-two sizes only) plus the
//! real-input (r2c/c2r) 2-D spectral pipeline the field convolution runs
//! on.
//!
//! Iterative Cooley–Tukey with a bit-reversal permutation and a twiddle
//! table computed once per plan in f64 (then rounded to f32), which keeps
//! the worst-case relative error of a 2048² 2-D transform comfortably
//! below 1e-5 — two orders of magnitude under the subsystem's 1% force
//! accuracy budget.
//!
//! Data layout is split re/im `&mut [f32]` (structure-of-arrays): the
//! butterflies vectorise, and real-input planes (charge grids, kernels)
//! reuse the same buffers without an interleave pass.
//!
//! Two 2-D pipelines are exposed:
//!
//! * [`fft2d`] — full complex `M×M` transform (rows → transpose → rows →
//!   transpose). Kept as the correctness reference and for callers with
//!   genuinely complex planes.
//! * [`rfft2d`] / [`irfft2d`] — the production *real* pipeline. The
//!   charge grid and the Cauchy kernels are purely real, so their spectra
//!   are Hermitian (`F[-u,-v] = conj F[u,v]`) and only the half-spectrum
//!   of `hw = M/2 + 1` column frequencies needs computing or storing.
//!   Row transforms use the two-for-one trick — adjacent real rows `a`,
//!   `b` are packed as `a + i·b` (for split storage that is literally
//!   "use row `a` as re and row `b` as im"), one complex FFT runs, and
//!   the two row spectra are separated by Hermitian symmetry — so the
//!   row pass does `M/2` FFTs instead of `M`, and the column pass runs
//!   over `hw ≈ M/2` rows instead of `M`. A full real 2-D transform is
//!   therefore ~half a complex one; the conv pipeline's per-iteration
//!   transform work drops from 4 complex-equivalents to ~2.
//!
//! Half-spectrum layout is **column-frequency-major**: `spec[k·M + j]`
//! holds bin `(row-frequency j, column-frequency k)` for `k < hw` — i.e.
//! the transpose of the top `hw` columns of the full spectrum. That is
//! exactly the state the pipeline is in after its single mid-transform
//! transpose, so no extra data movement is spent restoring row-major
//! order; the elementwise spectral multiply is layout-agnostic.
//!
//! Transposes are tiled and threaded ([`transpose`], [`transpose_into`]):
//! at M = 2048 a plane is 16 MB, far beyond L2, and the naive
//! element-swap walk is the pipeline's memory-bandwidth bottleneck —
//! `TILE×TILE` blocks keep both the read and write streams inside L1.
//!
//! `inverse`/`fft2d` own their 1/n normalisation; [`irfft2d`] instead
//! takes an explicit `scale` fused into its final write, so callers that
//! fold the normalisation elsewhere (conv.rs bakes 1/M² into the cached
//! kernel spectra) pay nothing for it.

use crate::util::parallel::{self, SyncSlice};
use crate::util::simd;

/// An FFT plan for one power-of-two size: per-stage twiddle tables plus
/// the bit-reversal index table (both computed once — `run` is called
/// O(m) times per 2-D transform).
///
/// Twiddles are stored *per stage, contiguously*: the stage with
/// half-length `h` keeps its `h` factors `e^{-πik/h}`, `k < h`, at flat
/// offset `h − 1` (total `n − 1` entries). The classic shared half-table
/// would be walked at stride `n/len`, which defeats vector loads; the
/// per-stage layout makes every butterfly group a unit-stride stream for
/// the dispatched SIMD kernel (`util::simd`), and costs the same n
/// floats overall. The f64 angle evaluation is unchanged, so the stored
/// factors are bit-identical to the seed's.
pub struct Fft {
    n: usize,
    stw_re: Vec<f32>,
    stw_im: Vec<f32>,
    rev: Vec<u32>,
}

impl Fft {
    pub fn new(n: usize) -> Self {
        assert!(n.is_power_of_two() && n >= 2, "radix-2 FFT needs a power-of-two size, got {n}");
        let mut stw_re = Vec::with_capacity(n - 1);
        let mut stw_im = Vec::with_capacity(n - 1);
        let mut h = 1usize;
        while h <= n / 2 {
            for k in 0..h {
                let ang = -2.0 * std::f64::consts::PI * k as f64 / (2 * h) as f64;
                stw_re.push(ang.cos() as f32);
                stw_im.push(ang.sin() as f32);
            }
            h <<= 1;
        }
        // rev[i] = bit-reverse of i over log2(n) bits.
        let mut rev = vec![0u32; n];
        for i in 1..n {
            rev[i] = (rev[i >> 1] >> 1) | if i & 1 == 1 { (n >> 1) as u32 } else { 0 };
        }
        Self { n, stw_re, stw_im, rev }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    /// True only for a zero-length plan — which `new` rejects, so a
    /// constructed plan is never empty. Present for the `len`/`is_empty`
    /// pair convention; it must answer honestly, not stub `false`.
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// In-place forward DFT of one length-`n` signal.
    pub fn forward(&self, re: &mut [f32], im: &mut [f32]) {
        self.run(re, im, false);
    }

    /// In-place inverse DFT (including the 1/n scale).
    pub fn inverse(&self, re: &mut [f32], im: &mut [f32]) {
        self.run(re, im, true);
        let s = 1.0 / self.n as f32;
        for v in re.iter_mut() {
            *v *= s;
        }
        for v in im.iter_mut() {
            *v *= s;
        }
    }

    /// In-place raw DFT (no normalisation in either direction).
    fn run(&self, re: &mut [f32], im: &mut [f32], inverse: bool) {
        let n = self.n;
        debug_assert_eq!(re.len(), n);
        debug_assert_eq!(im.len(), n);
        // Bit-reversal permutation (precomputed table).
        for i in 1..n {
            let j = self.rev[i] as usize;
            if i < j {
                re.swap(i, j);
                im.swap(i, j);
            }
        }
        // Butterfly stages: stage with half-length `half` reads its
        // contiguous twiddle run at offset `half − 1`. Long stages go
        // through the dispatched kernel; short ones (half < 8, where one
        // indirect call per 2–8 elements would dominate) inline the
        // scalar reference directly — same arithmetic, no dispatch.
        let bf = simd::kernels().butterflies;
        let mut len = 2usize;
        while len <= n {
            let half = len / 2;
            let off = half - 1;
            let wr = &self.stw_re[off..off + half];
            let wi = &self.stw_im[off..off + half];
            for start in (0..n).step_by(len) {
                let (ra, rb) = re[start..start + len].split_at_mut(half);
                let (ia, ib) = im[start..start + len].split_at_mut(half);
                if half < 8 {
                    simd::butterflies_scalar(ra, ia, rb, ib, wr, wi, inverse);
                } else {
                    bf(ra, ia, rb, ib, wr, wi, inverse);
                }
            }
            len <<= 1;
        }
    }
}

/// Number of stored column frequencies of a real length-`m` transform:
/// the non-redundant Hermitian half, `m/2 + 1`.
pub const fn half_width(m: usize) -> usize {
    m / 2 + 1
}

/// Edge of the cache blocks used by the tiled transposes. Two f32 tiles
/// (the read stream and the write stream) are 2·32² · 4 B = 8 KB —
/// comfortably inside L1 on every target.
const TILE: usize = 32;

/// In-place transpose of a square row-major `m×m` matrix, cache-blocked
/// (`TILE×TILE` tile pairs) and threaded over tile-row bands. Bands own
/// disjoint tile pairs — band `bi` swaps blocks `(bi, bj)`/`(bj, bi)`
/// for `bj ≥ bi` only — so no two workers touch the same element.
pub fn transpose(a: &mut [f32], m: usize) {
    debug_assert_eq!(a.len(), m * m);
    let nb = m.div_ceil(TILE);
    let cells = SyncSlice::new(a);
    parallel::par_chunks(nb, 1, |band| {
        for bi in band {
            let r0 = bi * TILE;
            let r1 = (r0 + TILE).min(m);
            // Diagonal tile: swap its upper triangle.
            for r in r0..r1 {
                for c in (r + 1)..r1 {
                    unsafe {
                        std::mem::swap(cells.get_mut(r * m + c), cells.get_mut(c * m + r));
                    }
                }
            }
            // Off-diagonal tiles (bi, bj>bi): swap the two mirror blocks.
            for c0 in ((bi + 1) * TILE..m).step_by(TILE) {
                let c1 = (c0 + TILE).min(m);
                for r in r0..r1 {
                    for c in c0..c1 {
                        unsafe {
                            std::mem::swap(cells.get_mut(r * m + c), cells.get_mut(c * m + r));
                        }
                    }
                }
            }
        }
    });
}

/// Out-of-place transpose of a row-major `rows×cols` matrix into a
/// `cols×rows` one: `dst[c·rows + r] = src[r·cols + c]`. Tiled so the
/// strided stream stays within `TILE` cache lines per block, threaded
/// over column bands (each band writes a disjoint contiguous dst slab).
/// Inside a tile the bulk moves through the dispatched 4×4 in-register
/// transpose kernel (pure data movement — no numerics); ragged edges
/// fall back to the element walk.
pub fn transpose_into(src: &[f32], dst: &mut [f32], rows: usize, cols: usize) {
    debug_assert!(src.len() >= rows * cols);
    debug_assert!(dst.len() >= rows * cols);
    let t4 = simd::kernels().transpose4x4;
    let out = SyncSlice::new(dst);
    parallel::par_chunks(cols, TILE, |cband| {
        for r0 in (0..rows).step_by(TILE) {
            let r1 = (r0 + TILE).min(rows);
            let mut c = cband.start;
            while c + 4 <= cband.end {
                let mut r = r0;
                while r + 4 <= r1 {
                    // SAFETY: bands own disjoint dst column slabs and
                    // the 4×4 span stays inside this band's columns.
                    let d = unsafe { out.slice_mut(c * rows + r, 3 * rows + 4) };
                    t4(&src[r * cols + c..], cols, d, rows);
                    r += 4;
                }
                for rr in r..r1 {
                    for cc in c..c + 4 {
                        unsafe {
                            *out.get_mut(cc * rows + rr) = src[rr * cols + cc];
                        }
                    }
                }
                c += 4;
            }
            for cc in c..cband.end {
                for rr in r0..r1 {
                    unsafe {
                        *out.get_mut(cc * rows + rr) = src[rr * cols + cc];
                    }
                }
            }
        }
    });
}

/// Shared-buffer handle for threading row transforms (rows are disjoint).
struct Rows {
    ptr: *mut f32,
    stride: usize,
}

unsafe impl Send for Rows {}
unsafe impl Sync for Rows {}

impl Rows {
    /// # Safety
    /// Each row index must be used by at most one thread at a time.
    unsafe fn row(&self, r: usize) -> &mut [f32] {
        std::slice::from_raw_parts_mut(self.ptr.add(r * self.stride), self.stride)
    }
}

/// Raw (unnormalised) complex FFTs over `nrows` contiguous rows of
/// length `plan.len()`, threaded.
fn fft_rows(plan: &Fft, re: &mut [f32], im: &mut [f32], nrows: usize, inverse: bool) {
    let m = plan.len();
    debug_assert!(re.len() >= nrows * m && im.len() >= nrows * m);
    let re_rows = Rows { ptr: re.as_mut_ptr(), stride: m };
    let im_rows = Rows { ptr: im.as_mut_ptr(), stride: m };
    parallel::par_chunks(nrows, 8, |rows| {
        for r in rows {
            let (rr, ri) = unsafe { (re_rows.row(r), im_rows.row(r)) };
            plan.run(rr, ri, inverse);
        }
    });
}

/// Threaded in-place scale of a whole plane.
fn scale_plane(buf: &mut [f32], s: f32) {
    let n = buf.len();
    let slots = SyncSlice::new(buf);
    parallel::par_chunks(n, 1 << 15, |range| {
        for i in range {
            unsafe {
                *slots.get_mut(i) *= s;
            }
        }
    });
}

/// In-place 2-D DFT of a row-major `m×m` complex plane
/// (`m = plan.len()`). The inverse includes the full 1/m² scale.
pub fn fft2d(plan: &Fft, re: &mut [f32], im: &mut [f32], inverse: bool) {
    let m = plan.len();
    assert_eq!(re.len(), m * m);
    assert_eq!(im.len(), m * m);
    fft_rows(plan, re, im, m, inverse);
    transpose(re, m);
    transpose(im, m);
    fft_rows(plan, re, im, m, inverse);
    transpose(re, m);
    transpose(im, m);
    if inverse {
        let s = 1.0 / (m * m) as f32;
        scale_plane(re, s);
        scale_plane(im, s);
    }
}

/// Forward real 2-D transform: row-major real `m×m` `plane` (destroyed)
/// → half-spectrum `spec_re/spec_im` of `hw×m` entries, where
/// `spec[k·m + j]` is bin (row-frequency `j`, column-frequency `k`),
/// `k < hw = m/2 + 1` (see the module docs for why this transposed
/// layout is the natural resting state). `tmp_re/tmp_im` are `m·hw`
/// scratch planes; all output/scratch contents are fully overwritten.
pub fn rfft2d(
    plan: &Fft,
    plane: &mut [f32],
    spec_re: &mut [f32],
    spec_im: &mut [f32],
    tmp_re: &mut [f32],
    tmp_im: &mut [f32],
) {
    let m = plan.len();
    let hw = half_width(m);
    assert_eq!(plane.len(), m * m);
    assert!(spec_re.len() >= hw * m && spec_im.len() >= hw * m);
    assert!(tmp_re.len() >= m * hw && tmp_im.len() >= m * hw);
    // 1. Two-for-one row FFTs: row pair (a, b) = (2p, 2p+1) packed as
    //    a + i·b runs one in-place complex FFT inside the plane itself,
    //    then the Hermitian unpack separates the two row spectra into
    //    the m×hw half rows:
    //      A[k] = (Z[k] + conj Z[m−k]) / 2
    //      B[k] = (Z[k] − conj Z[m−k]) / 2i
    {
        let prows = Rows { ptr: plane.as_mut_ptr(), stride: m };
        let tre = Rows { ptr: tmp_re.as_mut_ptr(), stride: hw };
        let tim = Rows { ptr: tmp_im.as_mut_ptr(), stride: hw };
        parallel::par_chunks(m / 2, 4, |pairs| {
            for pair in pairs {
                let (a, b) = (2 * pair, 2 * pair + 1);
                let (zre, zim) = unsafe { (prows.row(a), prows.row(b)) };
                plan.run(zre, zim, false);
                let (are, aim) = unsafe { (tre.row(a), tim.row(a)) };
                let (bre, bim) = unsafe { (tre.row(b), tim.row(b)) };
                for k in 0..hw {
                    let mk = (m - k) & (m - 1); // (m − k) mod m
                    are[k] = 0.5 * (zre[k] + zre[mk]);
                    aim[k] = 0.5 * (zim[k] - zim[mk]);
                    bre[k] = 0.5 * (zim[k] + zim[mk]);
                    bim[k] = 0.5 * (zre[mk] - zre[k]);
                }
            }
        });
    }
    // 2. m×hw → hw×m: the half-spectrum's resting layout.
    transpose_into(tmp_re, spec_re, m, hw);
    transpose_into(tmp_im, spec_im, m, hw);
    // 3. Column FFTs: hw complex rows of length m.
    fft_rows(plan, spec_re, spec_im, hw, false);
}

/// Inverse of [`rfft2d`]: half-spectrum `spec_re/spec_im` (`hw×m`,
/// destroyed) → real `m×m` `plane`. The transforms are raw; `scale` is
/// fused into the final row writes — pass `1.0 / (m·m)` for a true
/// inverse, or `1.0` when the normalisation was folded upstream (the
/// conv pipeline bakes it into the cached kernel spectra).
pub fn irfft2d(
    plan: &Fft,
    spec_re: &mut [f32],
    spec_im: &mut [f32],
    plane: &mut [f32],
    tmp_re: &mut [f32],
    tmp_im: &mut [f32],
    scale: f32,
) {
    let m = plan.len();
    let hw = half_width(m);
    assert_eq!(plane.len(), m * m);
    assert!(spec_re.len() >= hw * m && spec_im.len() >= hw * m);
    assert!(tmp_re.len() >= m * hw && tmp_im.len() >= m * hw);
    // 1. Raw inverse column FFTs.
    fft_rows(plan, spec_re, spec_im, hw, true);
    // 2. hw×m → m×hw.
    transpose_into(spec_re, tmp_re, hw, m);
    transpose_into(spec_im, tmp_im, hw, m);
    // 3. Row pairs: rebuild the packed full-width row a + i·b from the
    //    two Hermitian half rows (the mirror of the forward unpack:
    //    Z[k] = A[k] + i·B[k] for k < hw, Z[k] = conj A[m−k] +
    //    i·conj B[m−k] above), one raw inverse FFT in place in the
    //    plane, scale fused into the final write.
    {
        let prows = Rows { ptr: plane.as_mut_ptr(), stride: m };
        let tre = Rows { ptr: tmp_re.as_mut_ptr(), stride: hw };
        let tim = Rows { ptr: tmp_im.as_mut_ptr(), stride: hw };
        parallel::par_chunks(m / 2, 4, |pairs| {
            for pair in pairs {
                let (a, b) = (2 * pair, 2 * pair + 1);
                let (are, aim) = unsafe { (tre.row(a), tim.row(a)) };
                let (bre, bim) = unsafe { (tre.row(b), tim.row(b)) };
                let (zre, zim) = unsafe { (prows.row(a), prows.row(b)) };
                for k in 0..hw {
                    zre[k] = are[k] - bim[k];
                    zim[k] = aim[k] + bre[k];
                }
                for k in hw..m {
                    let mk = m - k;
                    zre[k] = are[mk] + bim[mk];
                    zim[k] = bre[mk] - aim[mk];
                }
                plan.run(zre, zim, true);
                if scale != 1.0 {
                    for v in zre.iter_mut() {
                        *v *= scale;
                    }
                    for v in zim.iter_mut() {
                        *v *= scale;
                    }
                }
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Naive O(n²) DFT in f64, the correctness reference.
    fn dft_naive(x: &[f32]) -> (Vec<f64>, Vec<f64>) {
        let n = x.len();
        let mut re = vec![0.0f64; n];
        let mut im = vec![0.0f64; n];
        for (k, (rk, ik)) in re.iter_mut().zip(im.iter_mut()).enumerate() {
            for (t, &v) in x.iter().enumerate() {
                let ang = -2.0 * std::f64::consts::PI * (k * t) as f64 / n as f64;
                *rk += v as f64 * ang.cos();
                *ik += v as f64 * ang.sin();
            }
        }
        (re, im)
    }

    fn random_plane(m: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..m * m).map(|_| rng.gauss_f32(0.0, 1.0)).collect()
    }

    #[test]
    fn matches_naive_dft() {
        let mut rng = Rng::new(1);
        for n in [2usize, 8, 32, 128] {
            let x: Vec<f32> = (0..n).map(|_| rng.gauss_f32(0.0, 1.0)).collect();
            let (er, ei) = dft_naive(&x);
            let mut re = x.clone();
            let mut im = vec![0.0f32; n];
            Fft::new(n).forward(&mut re, &mut im);
            for k in 0..n {
                assert!(
                    (re[k] as f64 - er[k]).abs() < 1e-3 && (im[k] as f64 - ei[k]).abs() < 1e-3,
                    "n={n} k={k}: ({},{}) vs ({},{})",
                    re[k],
                    im[k],
                    er[k],
                    ei[k]
                );
            }
        }
    }

    #[test]
    fn forward_inverse_roundtrip() {
        let mut rng = Rng::new(2);
        let n = 256;
        let plan = Fft::new(n);
        let x: Vec<f32> = (0..n).map(|_| rng.gauss_f32(0.0, 2.0)).collect();
        let mut re = x.clone();
        let mut im = vec![0.0f32; n];
        plan.forward(&mut re, &mut im);
        plan.inverse(&mut re, &mut im);
        for i in 0..n {
            assert!((re[i] - x[i]).abs() < 1e-4, "{} vs {}", re[i], x[i]);
            assert!(im[i].abs() < 1e-4);
        }
    }

    #[test]
    fn impulse_has_flat_spectrum() {
        let n = 64;
        let mut re = vec![0.0f32; n];
        let mut im = vec![0.0f32; n];
        re[0] = 1.0;
        Fft::new(n).forward(&mut re, &mut im);
        for k in 0..n {
            assert!((re[k] - 1.0).abs() < 1e-5 && im[k].abs() < 1e-5);
        }
    }

    #[test]
    fn plan_is_never_empty() {
        // The convention pair must not lie: a constructed plan has
        // positive length, so is_empty is false (it used to stub
        // `false` unconditionally — same answer, honest derivation).
        let p = Fft::new(8);
        assert_eq!(p.len(), 8);
        assert!(!p.is_empty());
    }

    #[test]
    fn fft2d_roundtrip_and_dc() {
        let mut rng = Rng::new(3);
        let m = 32;
        let plan = Fft::new(m);
        let x: Vec<f32> = (0..m * m).map(|_| rng.gauss_f32(0.0, 1.0)).collect();
        let mut re = x.clone();
        let mut im = vec![0.0f32; m * m];
        fft2d(&plan, &mut re, &mut im, false);
        // DC bin = sum of the plane.
        let sum: f64 = x.iter().map(|&v| v as f64).sum();
        assert!((re[0] as f64 - sum).abs() < 1e-3 * sum.abs().max(1.0));
        fft2d(&plan, &mut re, &mut im, true);
        for i in 0..m * m {
            assert!((re[i] - x[i]).abs() < 1e-4);
            assert!(im[i].abs() < 1e-4);
        }
    }

    #[test]
    fn rfft2d_matches_full_complex_spectrum() {
        // Golden equivalence: the half-spectrum entry (k, j) must be the
        // full complex transform's bin (row-freq j, col-freq k).
        for (m, seed) in [(2usize, 4u64), (8, 5), (32, 6), (64, 7)] {
            let hw = half_width(m);
            let plan = Fft::new(m);
            let x = random_plane(m, seed);
            let mut fre = x.clone();
            let mut fim = vec![0.0f32; m * m];
            fft2d(&plan, &mut fre, &mut fim, false);
            let mut plane = x.clone();
            let mut sre = vec![0.0f32; hw * m];
            let mut sim = vec![0.0f32; hw * m];
            let mut tre = vec![0.0f32; m * hw];
            let mut tim = vec![0.0f32; m * hw];
            rfft2d(&plan, &mut plane, &mut sre, &mut sim, &mut tre, &mut tim);
            let scale = fre
                .iter()
                .chain(fim.iter())
                .fold(0.0f32, |a, v| a.max(v.abs()))
                .max(1.0);
            for k in 0..hw {
                for j in 0..m {
                    let dr = (sre[k * m + j] - fre[j * m + k]).abs();
                    let di = (sim[k * m + j] - fim[j * m + k]).abs();
                    assert!(
                        dr < 2e-4 * scale && di < 2e-4 * scale,
                        "m={m} bin(j={j},k={k}): ({},{}) vs ({},{})",
                        sre[k * m + j],
                        sim[k * m + j],
                        fre[j * m + k],
                        fim[j * m + k]
                    );
                }
            }
        }
    }

    #[test]
    fn rfft2d_roundtrip() {
        for (m, seed) in [(2usize, 8u64), (16, 9), (64, 10)] {
            let hw = half_width(m);
            let plan = Fft::new(m);
            let x = random_plane(m, seed);
            let mut plane = x.clone();
            let mut sre = vec![0.0f32; hw * m];
            let mut sim = vec![0.0f32; hw * m];
            let mut tre = vec![0.0f32; m * hw];
            let mut tim = vec![0.0f32; m * hw];
            rfft2d(&plan, &mut plane, &mut sre, &mut sim, &mut tre, &mut tim);
            let s = 1.0 / (m * m) as f32;
            irfft2d(&plan, &mut sre, &mut sim, &mut plane, &mut tre, &mut tim, s);
            for i in 0..m * m {
                assert!((plane[i] - x[i]).abs() < 1e-4, "m={m} i={i}: {} vs {}", plane[i], x[i]);
            }
        }
    }

    fn transpose_naive(a: &[f32], rows: usize, cols: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; rows * cols];
        for r in 0..rows {
            for c in 0..cols {
                out[c * rows + r] = a[r * cols + c];
            }
        }
        out
    }

    #[test]
    fn transpose_involution() {
        let m = 5;
        let a: Vec<f32> = (0..25).map(|i| i as f32).collect();
        let mut b = a.clone();
        transpose(&mut b, m);
        assert_eq!(b[1], a[5]); // (0,1) <- (1,0)
        transpose(&mut b, m);
        assert_eq!(a, b);
    }

    #[test]
    fn tiled_transpose_matches_naive_square() {
        // Sizes straddling the tile edge, including non-tile-aligned.
        for m in [1usize, 5, 31, 32, 33, 100] {
            let a: Vec<f32> = (0..m * m).map(|i| i as f32).collect();
            let mut b = a.clone();
            transpose(&mut b, m);
            assert_eq!(b, transpose_naive(&a, m, m), "m={m}");
        }
    }

    #[test]
    fn tiled_transpose_into_matches_naive_rect() {
        for (rows, cols) in [(1usize, 7usize), (5, 3), (32, 32), (33, 65), (100, 17), (17, 100)] {
            let a: Vec<f32> = (0..rows * cols).map(|i| i as f32).collect();
            let mut b = vec![0.0f32; rows * cols];
            transpose_into(&a, &mut b, rows, cols);
            assert_eq!(b, transpose_naive(&a, rows, cols), "{rows}x{cols}");
        }
    }
}
