//! Field-texture subsystem (DESIGN.md S13, paper §5): everything that
//! turns an embedding `y` into the 3-channel field texture `(S, Vx, Vy)`
//! the repulsive forces are read from.
//!
//! The paper draws this texture on the GPU; this module owns every host
//! implementation behind the [`FieldBackend`] trait:
//!
//! * [`gather::GatherBackend`] — exact per-pixel evaluation, O(N·G²).
//!   The reference/oracle implementation (the compute-shader formulation).
//! * [`conv::FftBackend`] — splat + FFT convolution, O(N + G² log G)
//!   (Linderman et al.'s interpolation-FFT formulation; the same
//!   mathematics t-SNE-CUDA uses on device). The production CPU path.
//!
//! The spectral machinery ([`fft`]) exploits that every plane here is
//! *real*: transforms run through an r2c/c2r pipeline that packs row
//! pairs two-for-one and keeps only the Hermitian half-spectrum
//! (`M/2 + 1` column frequencies, stored transposed). Per iteration the
//! convolution costs one real forward plus three real inverses ≈ 2
//! complex-transform equivalents (the full-complex formulation needs 4),
//! the three channel multiplies are fused into one pass over the charge
//! spectrum, and the mid-transform transposes are tiled and threaded.
//! Cached kernel spectra ([`conv::SpectralKernels`]) live in the same
//! half-spectrum layout, halving the cache footprint.
//!
//! Shared pieces live here: the texture type, the square-grid placement
//! policy (mirroring `python/compile/model.py::grid_placement`), and
//! bilinear sampling.

pub mod conv;
pub mod fft;
pub mod gather;
pub mod splat;

/// Margin in pixels around the bbox (matches `model.GRID_MARGIN_PX`).
pub const GRID_MARGIN_PX: f32 = 1.5;

/// Where a `G×G` texture sits in embedding space: pixel `(r, c)` has its
/// centre at `origin + (idx + 0.5) * pixel` per axis.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Placement {
    pub origin: [f32; 2],
    pub pixel: f32,
}

/// The field texture: S, V_x, V_y on a G×G grid plus its placement.
pub struct FieldTexture {
    pub grid: usize,
    pub origin: [f32; 2],
    pub pixel: f32,
    /// Channel-major `(3, G, G)`: S, Vx, Vy.
    pub tex: Vec<f32>,
}

impl FieldTexture {
    /// Bilinear sample at `(x, y)`: returns `(S, Vx, Vy)`.
    #[inline]
    pub fn sample(&self, x: f32, y: f32) -> [f32; 3] {
        bilinear(&self.tex, self.grid, self.origin, self.pixel, x, y)
    }
}

/// A field-texture implementation. `compute` evaluates (or approximates)
///
///   S(p)  = Σ_i 1 / (1 + |y_i − p|²)            (Eq. 10)
///   V(p)  = Σ_i (y_i − p) / (1 + |y_i − p|²)²   (Eq. 11)
///
/// at every pixel centre of the placed grid. Backends may carry mutable
/// state (plan/kernel caches), hence `&mut self`.
pub trait FieldBackend {
    fn name(&self) -> &'static str;

    fn compute(&mut self, y: &[f32], placement: Placement, grid: usize) -> FieldTexture;

    /// A new backend of the same kind and configuration but with cold
    /// caches/scratch — how an engine stamps out per-session backends
    /// (each [`crate::embed::EmbeddingSession`] owns its own plans and
    /// kernel caches). Cold caches recompute the same values, so a fresh
    /// backend is numerically identical to a warm one.
    fn fresh(&self) -> Box<dyn FieldBackend + Send>;
}

/// Square grid placement covering `bbox` with margin (mirrors
/// `python/compile/model.py::grid_placement`).
pub fn grid_placement(bbox: [f32; 4], grid: usize) -> ([f32; 2], f32) {
    let g = grid as f32;
    let span = (bbox[2] - bbox[0]).max(bbox[3] - bbox[1]).max(1e-3);
    let pixel = span / (g - 2.0 * GRID_MARGIN_PX);
    let cx = 0.5 * (bbox[0] + bbox[2]);
    let cy = 0.5 * (bbox[1] + bbox[3]);
    let half = 0.5 * g * pixel;
    ([cx - half, cy - half], pixel)
}

/// [`grid_placement`] as a [`Placement`].
pub fn place(bbox: [f32; 4], grid: usize) -> Placement {
    let (origin, pixel) = grid_placement(bbox, grid);
    Placement { origin, pixel }
}

/// Bilinear sample of a 3-channel channel-major texture at `(x, y)`
/// (mirrors `ref.bilinear_ref`): returns (S, Vx, Vy).
#[inline]
pub fn bilinear(tex: &[f32], grid: usize, origin: [f32; 2], pixel: f32, x: f32, y: f32) -> [f32; 3] {
    let plane = grid * grid;
    let u = ((x - origin[0]) / pixel - 0.5).clamp(0.0, grid as f32 - 1.000001);
    let v = ((y - origin[1]) / pixel - 0.5).clamp(0.0, grid as f32 - 1.000001);
    let j0 = (u.floor() as usize).min(grid - 2);
    let i0 = (v.floor() as usize).min(grid - 2);
    let fu = u - j0 as f32;
    let fv = v - i0 as f32;
    let mut out = [0.0f32; 3];
    for (ch, o) in out.iter_mut().enumerate() {
        let base = ch * plane;
        let f00 = tex[base + i0 * grid + j0];
        let f01 = tex[base + i0 * grid + j0 + 1];
        let f10 = tex[base + (i0 + 1) * grid + j0];
        let f11 = tex[base + (i0 + 1) * grid + j0 + 1];
        let top = f00 * (1.0 - fu) + f01 * fu;
        let bot = f10 * (1.0 - fu) + f11 * fu;
        *o = top * (1.0 - fv) + bot * fv;
    }
    out
}

/// Bounding box `[min_x, min_y, max_x, max_y]` of an `(n, 2)` layout.
pub fn bbox_of(y: &[f32]) -> [f32; 4] {
    let n = y.len() / 2;
    let mut b = [f32::INFINITY, f32::INFINITY, f32::NEG_INFINITY, f32::NEG_INFINITY];
    for i in 0..n {
        b[0] = b[0].min(y[2 * i]);
        b[1] = b[1].min(y[2 * i + 1]);
        b[2] = b[2].max(y[2 * i]);
        b[3] = b[3].max(y[2 * i + 1]);
    }
    b
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bilinear_matches_python_convention() {
        // Exact at pixel centres.
        let grid = 4;
        let mut tex = vec![0.0f32; 3 * 16];
        tex[16 + 2 * 4 + 1] = 7.0; // Vx at (row 2, col 1)
        let origin = [0.0f32, 0.0];
        let pixel = 1.0;
        let out = bilinear(&tex, grid, origin, pixel, 1.5, 2.5);
        assert!((out[1] - 7.0).abs() < 1e-6);
        // Halfway to the next column: linear halving.
        let out = bilinear(&tex, grid, origin, pixel, 2.0, 2.5);
        assert!((out[1] - 3.5).abs() < 1e-6);
    }

    #[test]
    fn placement_covers_bbox_with_margin() {
        let bbox = [-3.0f32, -1.0, 5.0, 7.0];
        let grid = 64;
        let (origin, pixel) = grid_placement(bbox, grid);
        // The span (8.0) maps onto grid − 2·margin pixels.
        assert!((pixel - 8.0 / (64.0 - 3.0)).abs() < 1e-6);
        // Every bbox corner lies strictly inside the placed grid.
        let hi = [origin[0] + 64.0 * pixel, origin[1] + 64.0 * pixel];
        assert!(bbox[0] > origin[0] && bbox[1] > origin[1]);
        assert!(bbox[2] < hi[0] && bbox[3] < hi[1]);
    }

    #[test]
    fn bbox_of_contains_points() {
        let y = [0.0f32, 1.0, -2.0, 3.0, 4.0, -1.0];
        assert_eq!(bbox_of(&y), [-2.0, -1.0, 4.0, 3.0]);
    }

    #[test]
    fn texture_sample_matches_free_fn() {
        let t = FieldTexture { grid: 4, origin: [0.0, 0.0], pixel: 1.0, tex: vec![1.5; 48] };
        assert_eq!(t.sample(1.7, 2.2), bilinear(&t.tex, 4, [0.0, 0.0], 1.0, 1.7, 2.2));
    }
}
