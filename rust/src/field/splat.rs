//! O(N) deposition of point charges onto the field grid.
//!
//! The FFT formulation (Linderman et al., t-SNE-CUDA) replaces each
//! embedding point by an equivalent charge distribution on the regular
//! grid, so the kernel sums become a discrete convolution. Deposition
//! order sets the accuracy of the whole pipeline:
//!
//! * [`splat_bilinear`] — 2×2 hat-function weights, O(h²) accuracy. Too
//!   coarse at the paper's ρ = 0.5 operating point (measured ~8–15%
//!   force error); kept for the ablation bench.
//! * [`splat_cubic`] — 4×4 cubic-Lagrange weights, O(h⁴) accuracy, the
//!   production path (the same polynomial-interpolation idea FIt-SNE
//!   uses, at p = 3).
//!
//! Grid nodes are *pixel centres*: node `(r, c)` sits at
//! `origin + (idx + 0.5) * pixel`, matching the gather oracle's
//! evaluation points so textures are comparable node-for-node.

/// Deposit unit charges with 2×2 bilinear (hat) weights.
///
/// `out` is a row-major buffer with `stride ≥ grid` columns per row; only
/// the top-left `grid × grid` block is touched. Total deposited mass is
/// exactly `n` (weights always sum to 1).
pub fn splat_bilinear(
    y: &[f32],
    origin: [f32; 2],
    pixel: f32,
    grid: usize,
    stride: usize,
    out: &mut [f32],
) {
    assert!(grid >= 2 && stride >= grid && out.len() >= stride * grid);
    let n = y.len() / 2;
    let lim = grid as f32 - 1.000001;
    for i in 0..n {
        let u = ((y[2 * i] - origin[0]) / pixel - 0.5).clamp(0.0, lim);
        let v = ((y[2 * i + 1] - origin[1]) / pixel - 0.5).clamp(0.0, lim);
        let j0 = (u.floor() as usize).min(grid - 2);
        let i0 = (v.floor() as usize).min(grid - 2);
        let fu = u - j0 as f32;
        let fv = v - i0 as f32;
        let base = i0 * stride + j0;
        out[base] += (1.0 - fu) * (1.0 - fv);
        out[base + 1] += fu * (1.0 - fv);
        out[base + stride] += (1.0 - fu) * fv;
        out[base + stride + 1] += fu * fv;
    }
}

/// Cubic-Lagrange weights for the 4 nodes at offsets −1, 0, 1, 2 around
/// the base node, with `f ∈ [0, 1)` the fractional position past it.
/// The weights sum to 1 for every `f` (Lagrange partition of unity).
#[inline]
pub fn lagrange4(f: f32) -> [f32; 4] {
    let f = f as f64;
    [
        (-f * (f - 1.0) * (f - 2.0) / 6.0) as f32,
        ((f + 1.0) * (f - 1.0) * (f - 2.0) / 2.0) as f32,
        (-(f + 1.0) * f * (f - 2.0) / 2.0) as f32,
        ((f + 1.0) * f * (f - 1.0) / 6.0) as f32,
    ]
}

/// Deposit unit charges with 4×4 cubic-Lagrange weights (O(h⁴)).
///
/// Same buffer contract as [`splat_bilinear`]. Coordinates are clamped
/// into the grid first (like the bilinear path and the texture readback),
/// so a point outside the placement deposits its full, bounded charge at
/// the border instead of blowing up the cubic extrapolation. Near the
/// border the stencil base shifts inward (weights then extrapolate over
/// at most one node, still summing to 1), so `grid` must be ≥ 4.
pub fn splat_cubic(
    y: &[f32],
    origin: [f32; 2],
    pixel: f32,
    grid: usize,
    stride: usize,
    out: &mut [f32],
) {
    assert!(grid >= 4 && stride >= grid && out.len() >= stride * grid);
    let deposit = crate::util::simd::kernels().deposit4x4;
    let n = y.len() / 2;
    let lim = grid as f32 - 1.000001;
    for i in 0..n {
        let u = ((y[2 * i] - origin[0]) / pixel - 0.5).clamp(0.0, lim);
        let v = ((y[2 * i + 1] - origin[1]) / pixel - 0.5).clamp(0.0, lim);
        let j0 = (u.floor() as isize).clamp(1, grid as isize - 3) as usize;
        let i0 = (v.floor() as isize).clamp(1, grid as isize - 3) as usize;
        let wu = lagrange4(u - j0 as f32);
        let wv = lagrange4(v - i0 as f32);
        // Stencil base is the top-left of the 4×4 footprint; the clamps
        // above guarantee it stays inside the `stride × grid` buffer.
        deposit(out, (i0 - 1) * stride + (j0 - 1), stride, &wu, &wv);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn mass(buf: &[f32], grid: usize, stride: usize) -> f64 {
        let mut s = 0.0f64;
        for r in 0..grid {
            for c in 0..grid {
                s += buf[r * stride + c] as f64;
            }
        }
        s
    }

    #[test]
    fn both_splats_conserve_mass() {
        let mut rng = Rng::new(7);
        let n = 200;
        let y: Vec<f32> = (0..2 * n).map(|_| rng.gauss_f32(0.0, 3.0)).collect();
        let (origin, pixel) = crate::field::grid_placement(crate::field::bbox_of(&y), 32);
        let mut a = vec![0.0f32; 32 * 32];
        let mut b = vec![0.0f32; 40 * 32]; // non-trivial stride
        splat_bilinear(&y, origin, pixel, 32, 32, &mut a);
        splat_cubic(&y, origin, pixel, 32, 40, &mut b);
        assert!((mass(&a, 32, 32) - n as f64).abs() < 1e-3);
        assert!((mass(&b, 32, 40) - n as f64).abs() < 1e-3);
    }

    #[test]
    fn point_on_node_deposits_delta() {
        // A point exactly at a pixel centre puts all its charge there.
        let origin = [0.0f32, 0.0];
        let pixel = 1.0;
        let y = [5.5f32, 9.5]; // centre of column 5, row 9
        let mut cub = vec![0.0f32; 16 * 16];
        splat_cubic(&y, origin, pixel, 16, 16, &mut cub);
        assert!((cub[9 * 16 + 5] - 1.0).abs() < 1e-6);
        let total: f32 = cub.iter().map(|v| v.abs()).sum();
        assert!((total - 1.0).abs() < 1e-5, "no charge elsewhere: {total}");
    }

    #[test]
    fn out_of_grid_points_deposit_bounded_border_charge() {
        // A point far outside the placement must not excite the cubic
        // extrapolation — it clamps to the border like the bilinear path.
        let origin = [0.0f32, 0.0];
        let pixel = 1.0;
        let y = [-40.0f32, 60.0]; // way outside a 16x16 grid
        let mut cub = vec![0.0f32; 16 * 16];
        splat_cubic(&y, origin, pixel, 16, 16, &mut cub);
        let total: f64 = cub.iter().map(|&v| v as f64).sum();
        assert!((total - 1.0).abs() < 1e-5, "mass must still be 1: {total}");
        let peak = cub.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        assert!(peak <= 4.0, "border weights must stay bounded: {peak}");
    }

    #[test]
    fn lagrange_weights_partition_unity() {
        for k in 0..=10 {
            let f = k as f32 / 10.0;
            let w = lagrange4(f);
            let s: f32 = w.iter().sum();
            assert!((s - 1.0).abs() < 1e-6, "f={f}: {w:?}");
        }
        // At f = 0 the base node takes everything.
        let w = lagrange4(0.0);
        assert!((w[1] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn splats_reproduce_first_moment() {
        // Both stencils are exact on linear functions, so the deposited
        // charge centroid must coincide with the point (pixel units).
        let origin = [0.0f32, 0.0];
        let pixel = 1.0;
        let y = [5.93f32, 8.21];
        let mut bil = vec![0.0f32; 16 * 16];
        let mut cub = vec![0.0f32; 16 * 16];
        splat_bilinear(&y, origin, pixel, 16, 16, &mut bil);
        splat_cubic(&y, origin, pixel, 16, 16, &mut cub);
        let centroid = |buf: &[f32]| -> (f64, f64) {
            let (mut sx, mut sy) = (0.0f64, 0.0f64);
            for r in 0..16 {
                for c in 0..16 {
                    sx += (buf[r * 16 + c] * (c as f32 + 0.5)) as f64;
                    sy += (buf[r * 16 + c] * (r as f32 + 0.5)) as f64;
                }
            }
            (sx, sy)
        };
        for buf in [&cub, &bil] {
            let (cx, cy) = centroid(buf);
            assert!((cx - y[0] as f64).abs() < 1e-4, "{cx} vs {}", y[0]);
            assert!((cy - y[1] as f64).abs() < 1e-4, "{cy} vs {}", y[1]);
        }
    }
}
