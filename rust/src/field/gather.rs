//! Exact per-pixel field evaluation — the compute-shader / gather
//! formulation (paper §5.2) with unbounded support, O(N·G²).
//!
//! This is the subsystem's *oracle*: every other backend is validated
//! against it (and it is itself validated against the exact O(N²)
//! repulsion in `embed::fieldcpu` tests). It also remains the fallback
//! engine's workhorse and the reference point for the ablation benches.

use super::{FieldBackend, FieldTexture, Placement};
use crate::util::{parallel, simd};

/// Evaluate the fields exactly at every pixel centre (Eq. 10/11).
/// Threaded over pixel rows; within a row the per-point Cauchy
/// accumulation runs through the dispatched SIMD row kernel. Each pixel
/// still sums points in ascending `i`, so the result is bitwise
/// identical to the historical per-pixel loop on every tier.
pub fn compute_fields(y: &[f32], origin: [f32; 2], pixel: f32, grid: usize) -> Vec<f32> {
    let n = y.len() / 2;
    let mut tex = vec![0.0f32; 3 * grid * grid];
    let plane = grid * grid;
    let px: Vec<f32> = (0..grid).map(|c| origin[0] + (c as f32 + 0.5) * pixel).collect();
    let row_kernel = simd::kernels().cauchy_row;
    {
        let slots = parallel::SyncSlice::new(&mut tex);
        parallel::par_chunks(grid, 4, |rows| {
            for r in rows {
                let py = origin[1] + (r as f32 + 0.5) * pixel;
                // SAFETY: each row `r` is claimed by exactly one worker;
                // the three planes' row slices are disjoint.
                let (s, vx, vy) = unsafe {
                    (
                        slots.slice_mut(r * grid, grid),
                        slots.slice_mut(plane + r * grid, grid),
                        slots.slice_mut(2 * plane + r * grid, grid),
                    )
                };
                for i in 0..n {
                    row_kernel(&px, py, y[2 * i], y[2 * i + 1], s, vx, vy);
                }
            }
        });
    }
    tex
}

/// Bounded-support splat-style field accumulation — the paper's §5.1.2
/// rasterisation variant: each point only touches pixels within `support`
/// embedding-units (the texture-quad footprint). Kept for the ablation
/// bench (accuracy/speed vs the unbounded gather above).
pub fn compute_fields_splat(
    y: &[f32],
    origin: [f32; 2],
    pixel: f32,
    grid: usize,
    support: f32,
) -> Vec<f32> {
    let n = y.len() / 2;
    let mut tex = vec![0.0f32; 3 * grid * grid];
    let plane = grid * grid;
    let rad_px = (support / pixel).ceil() as isize;
    for i in 0..n {
        let (yx, yy) = (y[2 * i], y[2 * i + 1]);
        let ci = (((yy - origin[1]) / pixel) - 0.5).round() as isize;
        let cj = (((yx - origin[0]) / pixel) - 0.5).round() as isize;
        for r in (ci - rad_px).max(0)..=(ci + rad_px).min(grid as isize - 1) {
            let py = origin[1] + (r as f32 + 0.5) * pixel;
            for c in (cj - rad_px).max(0)..=(cj + rad_px).min(grid as isize - 1) {
                let px = origin[0] + (c as f32 + 0.5) * pixel;
                let dx = yx - px;
                let dy = yy - py;
                let d2 = dx * dx + dy * dy;
                if d2 > support * support {
                    continue;
                }
                let t = 1.0 / (1.0 + d2);
                let idx = (r as usize) * grid + c as usize;
                tex[idx] += t;
                let t2 = t * t;
                tex[plane + idx] += t2 * dx;
                tex[2 * plane + idx] += t2 * dy;
            }
        }
    }
    tex
}

/// The exact-gather backend (test oracle / fallback).
#[derive(Debug, Clone, Copy, Default)]
pub struct GatherBackend;

impl FieldBackend for GatherBackend {
    fn name(&self) -> &'static str {
        "gather"
    }

    fn compute(&mut self, y: &[f32], placement: Placement, grid: usize) -> FieldTexture {
        FieldTexture {
            grid,
            origin: placement.origin,
            pixel: placement.pixel,
            tex: compute_fields(y, placement.origin, placement.pixel, grid),
        }
    }

    fn fresh(&self) -> Box<dyn FieldBackend + Send> {
        Box::new(GatherBackend)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::field::grid_placement;
    use crate::util::rng::Rng;

    fn random_y(n: usize, seed: u64, spread: f32) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..2 * n).map(|_| rng.gauss_f32(0.0, spread)).collect()
    }

    #[test]
    fn splat_with_wide_support_matches_gather() {
        let n = 60;
        let y = random_y(n, 2, 1.0);
        let bbox = crate::field::bbox_of(&y);
        let grid = 64;
        let (origin, pixel) = grid_placement(bbox, grid);
        let a = compute_fields(&y, origin, pixel, grid);
        let b = compute_fields_splat(&y, origin, pixel, grid, 1e6);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    }

    #[test]
    fn splat_with_narrow_support_underestimates_s() {
        let n = 40;
        let y = random_y(n, 3, 1.0);
        let grid = 32;
        let (origin, pixel) = grid_placement([-3.0, -3.0, 3.0, 3.0], grid);
        let full = compute_fields(&y, origin, pixel, grid);
        let cut = compute_fields_splat(&y, origin, pixel, grid, 0.5);
        let s_full: f32 = full[..grid * grid].iter().sum();
        let s_cut: f32 = cut[..grid * grid].iter().sum();
        assert!(s_cut < s_full, "bounded support must lose mass");
        assert!(s_cut > 0.0);
    }

    #[test]
    fn backend_wraps_free_fn() {
        let y = random_y(30, 5, 2.0);
        let p = crate::field::place(crate::field::bbox_of(&y), 32);
        let t = GatherBackend.compute(&y, p, 32);
        assert_eq!(t.tex, compute_fields(&y, p.origin, p.pixel, 32));
        assert_eq!(t.grid, 32);
    }
}
