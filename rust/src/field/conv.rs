//! FFT convolution of the splatted charge grid with sampled Cauchy
//! kernels — the O(N + G² log G) field backend.
//!
//! The kernel sums of Eq. 10/11 are translation-invariant, so with point
//! charges deposited on a regular grid (`splat`) the three field channels
//! are discrete convolutions with
//!
//!   k_S(δ)  =  1 / (1 + |δ|²)
//!   k_Vx(δ) = −δ_x / (1 + |δ|²)²      (sign: the field is Σ K(y_i − p),
//!   k_Vy(δ) = −δ_y / (1 + |δ|²)²       i.e. the kernel mirrored in δ)
//!
//! computed via zero-padded FFTs: the fine grid (G_f = s·G nodes) is
//! embedded in the top-left of an `M×M` plane, `M = next_pow2(2·G_f)`,
//! which makes the circular convolution exact for every in-grid
//! displacement (no wraparound).
//!
//! Accuracy comes from two knobs validated against the gather oracle:
//! cubic-Lagrange deposition (O(h⁴), `splat`) and internal oversampling —
//! the convolution runs at a fine pixel `h_f = pixel / s ≤ FINE_PIXEL`,
//! with the fine grid offset by `(pixel − h_f)/2` so every s-th fine node
//! coincides *exactly* with a coarse pixel centre; the coarse texture is
//! then a stride-s copy, not an interpolation. At the paper's ρ = 0.5
//! operating point this keeps max force error vs the oracle ≲ 0.3%
//! (bilinear deposition without oversampling measures 8–15%).
//!
//! Kernel spectra depend only on `(M, h_f)`, so they are cached. A live
//! optimisation drifts the placement pixel a little every iteration, so
//! exact-key caching would never hit there; the cache therefore reuses a
//! spectra set whenever the pixel is within `KERNEL_PIXEL_RTOL` (0.1%)
//! of the cached one — a ≤ ~0.2% field perturbation, well inside the 1%
//! accuracy budget — which skips the rebuild (half the transform work)
//! through steady phases and in benches alike.

use std::sync::Arc;

use super::fft::{fft2d, Fft};
use super::{splat, FieldBackend, FieldTexture, Placement};
use crate::util::parallel::{self, SyncSlice};

/// Internal pixel target (embedding units). The Cauchy kernels have an
/// intrinsic scale of 1 embedding unit, so an absolute target is the
/// right policy knob; 0.35 keeps cubic-deposition error under 1% with
/// margin while ρ = 0.5 placements oversample only 2×.
pub const FINE_PIXEL: f32 = 0.35;

/// Hard cap on the oversampling factor (memory guard: M grows with s).
pub const MAX_OVERSAMPLE: usize = 4;

/// Relative pixel tolerance within which cached kernel spectra are
/// reused instead of rebuilt. The Cauchy kernels' sensitivity to the
/// sampling pitch is O(1) relative, so this contributes ≤ ~2× the
/// tolerance in field error — negligible against the 1% budget, while
/// letting slowly-drifting placements (every real optimisation) hit.
pub const KERNEL_PIXEL_RTOL: f32 = 1e-3;

/// Cap on the padded transform side M. Oversampling is reduced (never
/// below 1) to respect it, bounding the scratch planes at 4·M² and each
/// cached kernel set at 6·M² f32 (64 MB + 96 MB/set at the default).
/// At the ρ-policy operating point the cap never binds (G ≤ 512, s = 2
/// → M = 2048); it only sheds oversampling once the grid is clamped at
/// `max_grid` AND the diameter has outgrown it — where field accuracy
/// is pixel-limited for every backend anyway.
pub const MAX_TRANSFORM: usize = 2048;

/// Frequency-domain Cauchy kernels for one `(M, fine-pixel)` pair.
pub struct SpectralKernels {
    pub m: usize,
    pub pixel: f32,
    /// Per channel (S, Vx, Vy): split re/im spectra of length M².
    chan: [(Vec<f32>, Vec<f32>); 3],
}

impl SpectralKernels {
    /// Sample the three kernels over signed displacements and transform.
    pub fn build(plan: &Fft, pixel: f32) -> Self {
        let m = plan.len();
        let mut chan: [(Vec<f32>, Vec<f32>); 3] = [
            (vec![0.0; m * m], vec![0.0; m * m]),
            (vec![0.0; m * m], vec![0.0; m * m]),
            (vec![0.0; m * m], vec![0.0; m * m]),
        ];
        let signed = |i: usize| -> f64 {
            if i < m / 2 {
                i as f64
            } else {
                i as f64 - m as f64
            }
        };
        {
            let [c_s, c_vx, c_vy] = &mut chan;
            let s = SyncSlice::new(&mut c_s.0);
            let vx = SyncSlice::new(&mut c_vx.0);
            let vy = SyncSlice::new(&mut c_vy.0);
            parallel::par_chunks(m, 16, |rows| {
                for r in rows {
                    let dy = signed(r) * pixel as f64;
                    for c in 0..m {
                        let dx = signed(c) * pixel as f64;
                        let ks = 1.0 / (1.0 + dx * dx + dy * dy);
                        let kv = ks * ks;
                        unsafe {
                            *s.get_mut(r * m + c) = ks as f32;
                            *vx.get_mut(r * m + c) = (-dx * kv) as f32;
                            *vy.get_mut(r * m + c) = (-dy * kv) as f32;
                        }
                    }
                }
            });
        }
        for (re, im) in chan.iter_mut() {
            fft2d(plan, re, im, false);
        }
        Self { m, pixel, chan }
    }
}

/// Tiny LRU over kernel spectra, matched by `(M, pixel ≈ within rtol)`.
pub struct KernelCache {
    entries: Vec<Arc<SpectralKernels>>,
    capacity: usize,
    /// Relative pixel tolerance for a hit (see [`KERNEL_PIXEL_RTOL`]).
    pub pixel_rtol: f32,
}

impl KernelCache {
    pub fn new(capacity: usize) -> Self {
        Self { entries: Vec::new(), capacity: capacity.max(1), pixel_rtol: KERNEL_PIXEL_RTOL }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Fetch (moving to front) or build (evicting the oldest). A cached
    /// set matches when its transform size is identical and its pixel is
    /// within `pixel_rtol` (relative) of the requested one.
    pub fn get(&mut self, plan: &Fft, pixel: f32) -> Arc<SpectralKernels> {
        let m = plan.len();
        let rtol = self.pixel_rtol.max(0.0);
        if let Some(pos) = self
            .entries
            .iter()
            .position(|k| k.m == m && (k.pixel - pixel).abs() <= rtol * pixel.abs())
        {
            let hit = self.entries.remove(pos);
            self.entries.insert(0, hit);
            return self.entries[0].clone();
        }
        let built = Arc::new(SpectralKernels::build(plan, pixel));
        self.entries.insert(0, built.clone());
        self.entries.truncate(self.capacity);
        built
    }
}

/// The FFT field backend: splat → FFT → spectral multiply → inverse FFT.
pub struct FftBackend {
    /// Internal pixel target; lower = more accurate, bigger transforms.
    pub fine_pixel: f32,
    /// Oversampling cap.
    pub max_oversample: usize,
    /// Padded-transform cap (memory bound; see [`MAX_TRANSFORM`]).
    pub max_transform: usize,
    kernels: KernelCache,
    /// FFT plans keyed by size (at most a few sizes alive per run).
    plans: Vec<Arc<Fft>>,
    /// Reusable M² scratch planes (charge re/im, product re/im) — the
    /// backend is called every iteration, so the hot path must not
    /// re-allocate ~4×M² floats each time.
    cre: Vec<f32>,
    cim: Vec<f32>,
    pre: Vec<f32>,
    pim: Vec<f32>,
    /// Oversample factor used by the last `compute` (observability).
    pub last_oversample: usize,
    /// Padded transform size used by the last `compute` (observability).
    pub last_m: usize,
}

impl Default for FftBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl FftBackend {
    pub fn new() -> Self {
        Self {
            fine_pixel: FINE_PIXEL,
            max_oversample: MAX_OVERSAMPLE,
            max_transform: MAX_TRANSFORM,
            kernels: KernelCache::new(2),
            plans: Vec::new(),
            cre: Vec::new(),
            cim: Vec::new(),
            pre: Vec::new(),
            pim: Vec::new(),
            last_oversample: 0,
            last_m: 0,
        }
    }

    /// The oversampling factor the accuracy policy picks for a pixel size.
    pub fn oversample_for(&self, pixel: f32) -> usize {
        ((pixel / self.fine_pixel).ceil() as usize).clamp(1, self.max_oversample)
    }

    /// Cached kernel-spectra count (test observability).
    pub fn cached_kernel_sets(&self) -> usize {
        self.kernels.len()
    }

    fn plan(&mut self, m: usize) -> Arc<Fft> {
        if let Some(p) = self.plans.iter().find(|p| p.len() == m) {
            return p.clone();
        }
        let p = Arc::new(Fft::new(m));
        self.plans.push(p.clone());
        if self.plans.len() > 4 {
            self.plans.remove(0);
        }
        p
    }
}

impl FieldBackend for FftBackend {
    fn name(&self) -> &'static str {
        "fft"
    }

    fn compute(&mut self, y: &[f32], placement: Placement, grid: usize) -> FieldTexture {
        let pixel = placement.pixel;
        let mut s = self.oversample_for(pixel);
        // Shed oversampling (never below 1) to respect the memory cap.
        while s > 1 && (2 * s * grid).next_power_of_two() > self.max_transform {
            s -= 1;
        }
        let gf = s * grid;
        let pf = pixel / s as f32;
        // Offset so fine node s·c lands exactly on coarse pixel centre c.
        let shift = 0.5 * (pixel - pf);
        let of = [placement.origin[0] + shift, placement.origin[1] + shift];
        let m = (2 * gf).next_power_of_two();
        self.last_oversample = s;
        self.last_m = m;
        let plan = self.plan(m);
        let kernels = self.kernels.get(&plan, pf);

        // Charge plane (real input, imaginary part starts zero). The
        // scratch buffers are reused across calls; clear+resize zeroes
        // them without reallocating once capacity is established.
        let (cre, cim, pre, pim) = (&mut self.cre, &mut self.cim, &mut self.pre, &mut self.pim);
        cre.clear();
        cre.resize(m * m, 0.0);
        cim.clear();
        cim.resize(m * m, 0.0);
        // pre/pim are fully overwritten by the spectral multiply.
        pre.resize(m * m, 0.0);
        pim.resize(m * m, 0.0);
        splat::splat_cubic(y, of, pf, gf, m, cre);
        fft2d(&plan, cre, cim, false);

        let mut tex = vec![0.0f32; 3 * grid * grid];
        let plane = grid * grid;
        for ch in 0..3 {
            let (kre, kim) = &kernels.chan[ch];
            {
                let pre_s = SyncSlice::new(pre);
                let pim_s = SyncSlice::new(pim);
                let (cre, cim) = (&*cre, &*cim);
                parallel::par_chunks(m * m, 1 << 15, |range| {
                    for i in range {
                        unsafe {
                            *pre_s.get_mut(i) = cre[i] * kre[i] - cim[i] * kim[i];
                            *pim_s.get_mut(i) = cre[i] * kim[i] + cim[i] * kre[i];
                        }
                    }
                });
            }
            fft2d(&plan, pre, pim, true);
            // Stride-s copy of the fine plane back onto coarse centres.
            for r in 0..grid {
                let src = r * s * m;
                let dst = ch * plane + r * grid;
                for c in 0..grid {
                    tex[dst + c] = pre[src + c * s];
                }
            }
        }
        FieldTexture { grid, origin: placement.origin, pixel, tex }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::field::gather::GatherBackend;
    use crate::field::{bbox_of, place};
    use crate::util::rng::Rng;

    fn random_y(n: usize, seed: u64, spread: f32) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..2 * n).map(|_| rng.gauss_f32(0.0, spread)).collect()
    }

    fn max_rel_err(a: &[f32], b: &[f32]) -> f32 {
        let scale = a.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-9);
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max) / scale
    }

    #[test]
    fn matches_gather_oracle_per_channel() {
        let y = random_y(400, 2, 5.0);
        let grid = 64;
        let p = place(bbox_of(&y), grid);
        let oracle = GatherBackend.compute(&y, p, grid);
        let mut backend = FftBackend::new();
        let t = backend.compute(&y, p, grid);
        let plane = grid * grid;
        for ch in 0..3 {
            let err = max_rel_err(
                &oracle.tex[ch * plane..(ch + 1) * plane],
                &t.tex[ch * plane..(ch + 1) * plane],
            );
            assert!(err < 0.01, "channel {ch}: max rel err {err}");
        }
    }

    #[test]
    fn non_power_of_two_grids_work() {
        let y = random_y(150, 4, 4.0);
        let grid = 48; // pads internally to a power of two
        let p = place(bbox_of(&y), grid);
        let oracle = GatherBackend.compute(&y, p, grid);
        let t = FftBackend::new().compute(&y, p, grid);
        assert_eq!(t.tex.len(), 3 * grid * grid);
        assert!(max_rel_err(&oracle.tex, &t.tex) < 0.01);
    }

    #[test]
    fn oversample_policy_tracks_pixel_size() {
        let b = FftBackend::new();
        assert_eq!(b.oversample_for(0.1), 1);
        assert_eq!(b.oversample_for(0.5), 2);
        assert_eq!(b.oversample_for(0.99), 3);
        assert_eq!(b.oversample_for(10.0), MAX_OVERSAMPLE);
    }

    #[test]
    fn transform_cap_sheds_oversampling() {
        let mut b = FftBackend::new();
        b.max_transform = 256;
        let y = random_y(50, 11, 30.0); // big spread -> large pixel -> wants s=4
        let p = place(bbox_of(&y), 64);
        assert!(b.oversample_for(p.pixel) > 2, "case must want heavy oversampling");
        let _ = b.compute(&y, p, 64);
        assert!(b.last_m <= 256, "cap must bound the transform, got M={}", b.last_m);
        assert!(b.last_oversample >= 1);
    }

    #[test]
    fn kernel_cache_hits_on_repeat_placement() {
        let y = random_y(100, 6, 3.0);
        let p = place(bbox_of(&y), 32);
        let mut b = FftBackend::new();
        let t1 = b.compute(&y, p, 32);
        assert_eq!(b.cached_kernel_sets(), 1);
        let t2 = b.compute(&y, p, 32);
        assert_eq!(b.cached_kernel_sets(), 1, "same placement must hit the cache");
        assert_eq!(t1.tex, t2.tex, "cached kernels must be deterministic");
        // A different pixel size builds a second entry.
        let p2 = Placement { origin: p.origin, pixel: p.pixel * 1.5 };
        let _ = b.compute(&y, p2, 32);
        assert_eq!(b.cached_kernel_sets(), 2);
    }

    #[test]
    fn cache_tolerates_small_pixel_drift() {
        // A live optimisation drifts the pixel a fraction of a percent per
        // iteration; that must reuse the cached spectra, while a real
        // resolution change must rebuild.
        let plan = Fft::new(8);
        let mut cache = KernelCache::new(4);
        let a = cache.get(&plan, 0.5);
        let b = cache.get(&plan, 0.5 * 1.0005); // within 0.1% -> hit
        assert!(Arc::ptr_eq(&a, &b), "0.05% drift must hit the cache");
        let c = cache.get(&plan, 0.55); // 10% away -> rebuild
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn cache_lru_evicts_oldest() {
        let plan = Fft::new(8);
        let mut cache = KernelCache::new(2);
        let a = cache.get(&plan, 0.1);
        let _b = cache.get(&plan, 0.2);
        let _a2 = cache.get(&plan, 0.1); // refresh a
        let _c = cache.get(&plan, 0.3); // evicts 0.2
        assert_eq!(cache.len(), 2);
        let a3 = cache.get(&plan, 0.1);
        assert!(Arc::ptr_eq(&a, &a3), "0.1 must have survived");
    }
}
