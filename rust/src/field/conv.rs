//! FFT convolution of the splatted charge grid with sampled Cauchy
//! kernels — the O(N + G² log G) field backend.
//!
//! The kernel sums of Eq. 10/11 are translation-invariant, so with point
//! charges deposited on a regular grid (`splat`) the three field channels
//! are discrete convolutions with
//!
//!   k_S(δ)  =  1 / (1 + |δ|²)
//!   k_Vx(δ) = −δ_x / (1 + |δ|²)²      (sign: the field is Σ K(y_i − p),
//!   k_Vy(δ) = −δ_y / (1 + |δ|²)²       i.e. the kernel mirrored in δ)
//!
//! computed via zero-padded FFTs: the fine grid (G_f = s·G nodes) is
//! embedded in the top-left of an `M×M` plane, `M = next_pow2(2·G_f)`,
//! which makes the circular convolution exact for every in-grid
//! displacement (no wraparound).
//!
//! Every spatial plane here is purely *real*, so the spectral pipeline is
//! the r2c/c2r one ([`crate::field::fft::rfft2d`]): only the Hermitian
//! half-spectrum (`hw = M/2 + 1` column frequencies, stored `hw×M`) is
//! ever computed, stored or multiplied. Per iteration that is one real
//! forward (the charge) plus three real inverses (S, Vx, Vy) — about
//! **2 complex-transform equivalents instead of the 4** the full-complex
//! formulation costs — and the three spectral multiplies are fused into
//! a single pass that reads the charge spectrum and each kernel spectrum
//! exactly once (3× less plane traffic than channel-at-a-time). The
//! inverse-transform 1/M² normalisation is folded into the cached kernel
//! spectra at build time, so the per-iteration inverses run raw.
//!
//! Accuracy comes from two knobs validated against the gather oracle:
//! cubic-Lagrange deposition (O(h⁴), `splat`) and internal oversampling —
//! the convolution runs at a fine pixel `h_f = pixel / s ≤ FINE_PIXEL`,
//! with the fine grid offset by `(pixel − h_f)/2` so every s-th fine node
//! coincides *exactly* with a coarse pixel centre; the coarse texture is
//! then a stride-s copy, not an interpolation. At the paper's ρ = 0.5
//! operating point this keeps max force error vs the oracle ≲ 0.3%
//! (bilinear deposition without oversampling measures 8–15%).
//!
//! Kernel spectra depend only on `(M, h_f)`, so they are cached. A live
//! optimisation drifts the placement pixel a little every iteration, so
//! exact-key caching would never hit there; the cache therefore reuses a
//! spectra set whenever the pixel is within `KERNEL_PIXEL_RTOL` (0.1%)
//! of the cached one — a ≤ ~0.2% field perturbation, well inside the 1%
//! accuracy budget — which skips the rebuild (half the transform work)
//! through steady phases and in benches alike.

use std::sync::Arc;

use super::fft::{half_width, irfft2d, rfft2d, Fft};
use super::{splat, FieldBackend, FieldTexture, Placement};
use crate::util::parallel::{self, SyncSlice};
use crate::util::simd::{self, SpectralArgs};

/// Internal pixel target (embedding units). The Cauchy kernels have an
/// intrinsic scale of 1 embedding unit, so an absolute target is the
/// right policy knob; 0.35 keeps cubic-deposition error under 1% with
/// margin while ρ = 0.5 placements oversample only 2×.
pub const FINE_PIXEL: f32 = 0.35;

/// Hard cap on the oversampling factor (memory guard: M grows with s).
pub const MAX_OVERSAMPLE: usize = 4;

/// Relative pixel tolerance within which cached kernel spectra are
/// reused instead of rebuilt. The Cauchy kernels' sensitivity to the
/// sampling pitch is O(1) relative, so this contributes ≤ ~2× the
/// tolerance in field error — negligible against the 1% budget, while
/// letting slowly-drifting placements (every real optimisation) hit.
pub const KERNEL_PIXEL_RTOL: f32 = 1e-3;

/// Cap on the padded transform side M. Oversampling is reduced (never
/// below 1) to respect it, bounding the backend scratch at ~5·M² f32
/// (80 MB at the default) and each cached kernel set at its 6 half-
/// spectra ≈ 3·M² f32 (48 MB/set — the half-spectrum layout halved
/// this). At the ρ-policy operating point the cap never binds (G ≤ 512,
/// s = 2 → M = 2048); it only sheds oversampling once the grid is
/// clamped at `max_grid` AND the diameter has outgrown it — where field
/// accuracy is pixel-limited for every backend anyway.
pub const MAX_TRANSFORM: usize = 2048;

/// Frequency-domain Cauchy kernels for one `(M, fine-pixel)` pair, in
/// the `hw×M` half-spectrum layout, pre-scaled by 1/M² (the inverse
/// normalisation) so the hot path's inverse transforms run raw.
pub struct SpectralKernels {
    pub m: usize,
    pub pixel: f32,
    /// Per channel (S, Vx, Vy): split re/im half-spectra of hw·M entries.
    chan: [(Vec<f32>, Vec<f32>); 3],
}

/// Sample one spatial Cauchy kernel channel over signed displacements
/// onto an `m×m` plane (row-major, wrap-ordered: index i ≥ m/2 means
/// displacement i − m).
fn sample_kernel(ch: usize, pixel: f32, m: usize, plane: &mut [f32]) {
    debug_assert_eq!(plane.len(), m * m);
    let cells = SyncSlice::new(plane);
    parallel::par_chunks(m, 16, |rows| {
        let signed = |i: usize| -> f64 {
            if i < m / 2 {
                i as f64
            } else {
                i as f64 - m as f64
            }
        };
        for r in rows {
            let dy = signed(r) * pixel as f64;
            for c in 0..m {
                let dx = signed(c) * pixel as f64;
                let ks = 1.0 / (1.0 + dx * dx + dy * dy);
                let v = match ch {
                    0 => ks,
                    1 => -dx * ks * ks,
                    _ => -dy * ks * ks,
                };
                unsafe {
                    *cells.get_mut(r * m + c) = v as f32;
                }
            }
        }
    });
}

impl SpectralKernels {
    /// Sample the three kernels over signed displacements, transform each
    /// through the real pipeline, and fold in the 1/M² inverse scale.
    pub fn build(plan: &Fft, pixel: f32) -> Self {
        let m = plan.len();
        let hw = half_width(m);
        let mut chan: [(Vec<f32>, Vec<f32>); 3] = [
            (vec![0.0; hw * m], vec![0.0; hw * m]),
            (vec![0.0; hw * m], vec![0.0; hw * m]),
            (vec![0.0; hw * m], vec![0.0; hw * m]),
        ];
        let mut plane = vec![0.0f32; m * m];
        let mut tmp_re = vec![0.0f32; m * hw];
        let mut tmp_im = vec![0.0f32; m * hw];
        let inv_m2 = 1.0 / (m * m) as f32;
        for (ch, (kre, kim)) in chan.iter_mut().enumerate() {
            sample_kernel(ch, pixel, m, &mut plane);
            rfft2d(plan, &mut plane, kre, kim, &mut tmp_re, &mut tmp_im);
            for v in kre.iter_mut() {
                *v *= inv_m2;
            }
            for v in kim.iter_mut() {
                *v *= inv_m2;
            }
        }
        Self { m, pixel, chan }
    }
}

/// Tiny LRU over kernel spectra, matched by `(M, pixel ≈ within rtol)`.
pub struct KernelCache {
    entries: Vec<Arc<SpectralKernels>>,
    capacity: usize,
    /// Relative pixel tolerance for a hit (see [`KERNEL_PIXEL_RTOL`]).
    pub pixel_rtol: f32,
}

impl KernelCache {
    pub fn new(capacity: usize) -> Self {
        Self { entries: Vec::new(), capacity: capacity.max(1), pixel_rtol: KERNEL_PIXEL_RTOL }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Fetch (moving to front) or build (evicting the oldest). A cached
    /// set matches when its transform size is identical and its pixel is
    /// within `pixel_rtol` (relative) of the requested one.
    pub fn get(&mut self, plan: &Fft, pixel: f32) -> Arc<SpectralKernels> {
        let m = plan.len();
        let rtol = self.pixel_rtol.max(0.0);
        if let Some(pos) = self
            .entries
            .iter()
            .position(|k| k.m == m && (k.pixel - pixel).abs() <= rtol * pixel.abs())
        {
            let hit = self.entries.remove(pos);
            self.entries.insert(0, hit);
            return self.entries[0].clone();
        }
        let built = Arc::new(SpectralKernels::build(plan, pixel));
        self.entries.insert(0, built.clone());
        self.entries.truncate(self.capacity);
        built
    }
}

/// The FFT field backend: splat → r2c FFT → fused spectral multiply →
/// three c2r inverse FFTs.
pub struct FftBackend {
    /// Internal pixel target; lower = more accurate, bigger transforms.
    pub fine_pixel: f32,
    /// Oversampling cap.
    pub max_oversample: usize,
    /// Padded-transform cap (memory bound; see [`MAX_TRANSFORM`]).
    pub max_transform: usize,
    kernels: KernelCache,
    /// FFT plans keyed by size (at most a few sizes alive per run).
    plans: Vec<Arc<Fft>>,
    /// Reusable scratch — the backend runs every iteration, so the hot
    /// path must not re-allocate ~5·M² floats each time. `plane` is the
    /// real M² plane (charge in, per-channel field out); `spec_*` holds
    /// the charge half-spectrum and is overwritten in place by the S
    /// product during the fused multiply; `vxp_*`/`vyp_*` receive the
    /// Vx/Vy products; `tmp_*` is the transform transpose scratch.
    plane: Vec<f32>,
    spec_re: Vec<f32>,
    spec_im: Vec<f32>,
    vxp_re: Vec<f32>,
    vxp_im: Vec<f32>,
    vyp_re: Vec<f32>,
    vyp_im: Vec<f32>,
    tmp_re: Vec<f32>,
    tmp_im: Vec<f32>,
    /// Oversample factor used by the last `compute` (observability).
    pub last_oversample: usize,
    /// Padded transform size used by the last `compute` (observability).
    pub last_m: usize,
}

impl Default for FftBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl FftBackend {
    pub fn new() -> Self {
        Self {
            fine_pixel: FINE_PIXEL,
            max_oversample: MAX_OVERSAMPLE,
            max_transform: MAX_TRANSFORM,
            kernels: KernelCache::new(2),
            plans: Vec::new(),
            plane: Vec::new(),
            spec_re: Vec::new(),
            spec_im: Vec::new(),
            vxp_re: Vec::new(),
            vxp_im: Vec::new(),
            vyp_re: Vec::new(),
            vyp_im: Vec::new(),
            tmp_re: Vec::new(),
            tmp_im: Vec::new(),
            last_oversample: 0,
            last_m: 0,
        }
    }

    /// The oversampling factor the accuracy policy picks for a pixel size.
    pub fn oversample_for(&self, pixel: f32) -> usize {
        ((pixel / self.fine_pixel).ceil() as usize).clamp(1, self.max_oversample)
    }

    /// Cached kernel-spectra count (test observability).
    pub fn cached_kernel_sets(&self) -> usize {
        self.kernels.len()
    }

    fn plan(&mut self, m: usize) -> Arc<Fft> {
        if let Some(p) = self.plans.iter().find(|p| p.len() == m) {
            return p.clone();
        }
        let p = Arc::new(Fft::new(m));
        self.plans.push(p.clone());
        if self.plans.len() > 4 {
            self.plans.remove(0);
        }
        p
    }
}

impl FieldBackend for FftBackend {
    fn name(&self) -> &'static str {
        "fft"
    }

    fn fresh(&self) -> Box<dyn FieldBackend + Send> {
        let mut b = FftBackend::new();
        b.fine_pixel = self.fine_pixel;
        b.max_oversample = self.max_oversample;
        b.max_transform = self.max_transform;
        Box::new(b)
    }

    fn compute(&mut self, y: &[f32], placement: Placement, grid: usize) -> FieldTexture {
        let pixel = placement.pixel;
        let mut s = self.oversample_for(pixel);
        // Shed oversampling (never below 1) to respect the memory cap.
        while s > 1 && (2 * s * grid).next_power_of_two() > self.max_transform {
            s -= 1;
        }
        let gf = s * grid;
        let pf = pixel / s as f32;
        // Offset so fine node s·c lands exactly on coarse pixel centre c.
        let shift = 0.5 * (pixel - pf);
        let of = [placement.origin[0] + shift, placement.origin[1] + shift];
        let m = (2 * gf).next_power_of_two();
        let hw = half_width(m);
        let ns = hw * m;
        self.last_oversample = s;
        self.last_m = m;
        let plan = self.plan(m);
        let kernels = self.kernels.get(&plan, pf);

        // The charge plane must start zeroed (splat accumulates); every
        // other scratch plane is fully overwritten, so a bare resize
        // (no clearing pass) suffices once capacity is established.
        let (plane, spec_re, spec_im, vxp_re, vxp_im, vyp_re, vyp_im, tmp_re, tmp_im) = (
            &mut self.plane,
            &mut self.spec_re,
            &mut self.spec_im,
            &mut self.vxp_re,
            &mut self.vxp_im,
            &mut self.vyp_re,
            &mut self.vyp_im,
            &mut self.tmp_re,
            &mut self.tmp_im,
        );
        plane.clear();
        plane.resize(m * m, 0.0);
        spec_re.resize(ns, 0.0);
        spec_im.resize(ns, 0.0);
        vxp_re.resize(ns, 0.0);
        vxp_im.resize(ns, 0.0);
        vyp_re.resize(ns, 0.0);
        vyp_im.resize(ns, 0.0);
        tmp_re.resize(ns, 0.0);
        tmp_im.resize(ns, 0.0);
        splat::splat_cubic(y, of, pf, gf, m, plane);
        rfft2d(&plan, plane, spec_re, spec_im, tmp_re, tmp_im);

        // Fused spectral multiply: ONE pass over the charge half-spectrum
        // produces all three channel products — charge and kernel spectra
        // are each read exactly once, the S product lands back in spec_*
        // (each entry is read before it is overwritten), Vx/Vy land in
        // their own planes. The per-chunk body dispatches to the active
        // SIMD tier; every tier is bit-identical to the scalar reference
        // (pinned in `tests/simd_conformance.rs`).
        {
            let (ks, kx, ky) = (&kernels.chan[0], &kernels.chan[1], &kernels.chan[2]);
            let kern = simd::kernels();
            let sre = SyncSlice::new(spec_re);
            let sim = SyncSlice::new(spec_im);
            let xre = SyncSlice::new(vxp_re);
            let xim = SyncSlice::new(vxp_im);
            let yre = SyncSlice::new(vyp_re);
            let yim = SyncSlice::new(vyp_im);
            parallel::par_chunks(ns, 1 << 15, |range| {
                let (lo, len) = (range.start, range.len());
                // SAFETY: par_chunks hands out disjoint ranges.
                unsafe {
                    (kern.spectral_mul)(SpectralArgs {
                        sre: sre.slice_mut(lo, len),
                        sim: sim.slice_mut(lo, len),
                        xre: xre.slice_mut(lo, len),
                        xim: xim.slice_mut(lo, len),
                        yre: yre.slice_mut(lo, len),
                        yim: yim.slice_mut(lo, len),
                        ks_re: &ks.0[lo..lo + len],
                        ks_im: &ks.1[lo..lo + len],
                        kx_re: &kx.0[lo..lo + len],
                        kx_im: &kx.1[lo..lo + len],
                        ky_re: &ky.0[lo..lo + len],
                        ky_im: &ky.1[lo..lo + len],
                    });
                }
            });
        }

        // Inverse-transform each product (raw: the 1/M² normalisation
        // lives in the cached kernel spectra) and stride-copy the fine
        // plane back onto coarse pixel centres.
        let mut tex = vec![0.0f32; 3 * grid * grid];
        let coarse = grid * grid;
        let prods: [(&mut Vec<f32>, &mut Vec<f32>); 3] =
            [(spec_re, spec_im), (vxp_re, vxp_im), (vyp_re, vyp_im)];
        for (ch, (pre, pim)) in prods.into_iter().enumerate() {
            irfft2d(&plan, pre, pim, plane, tmp_re, tmp_im, 1.0);
            for r in 0..grid {
                let src = r * s * m;
                let dst = ch * coarse + r * grid;
                for c in 0..grid {
                    tex[dst + c] = plane[src + c * s];
                }
            }
        }
        FieldTexture { grid, origin: placement.origin, pixel, tex }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::field::gather::GatherBackend;
    use crate::field::{bbox_of, place};
    use crate::util::rng::Rng;

    fn random_y(n: usize, seed: u64, spread: f32) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..2 * n).map(|_| rng.gauss_f32(0.0, spread)).collect()
    }

    fn max_rel_err(a: &[f32], b: &[f32]) -> f32 {
        let scale = a.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-9);
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max) / scale
    }

    #[test]
    fn matches_gather_oracle_per_channel() {
        let y = random_y(400, 2, 5.0);
        let grid = 64;
        let p = place(bbox_of(&y), grid);
        let oracle = GatherBackend.compute(&y, p, grid);
        let mut backend = FftBackend::new();
        let t = backend.compute(&y, p, grid);
        let plane = grid * grid;
        for ch in 0..3 {
            let err = max_rel_err(
                &oracle.tex[ch * plane..(ch + 1) * plane],
                &t.tex[ch * plane..(ch + 1) * plane],
            );
            assert!(err < 0.01, "channel {ch}: max rel err {err}");
        }
    }

    #[test]
    fn non_power_of_two_grids_work() {
        let y = random_y(150, 4, 4.0);
        let grid = 48; // pads internally to a power of two
        let p = place(bbox_of(&y), grid);
        let oracle = GatherBackend.compute(&y, p, grid);
        let t = FftBackend::new().compute(&y, p, grid);
        assert_eq!(t.tex.len(), 3 * grid * grid);
        assert!(max_rel_err(&oracle.tex, &t.tex) < 0.01);
    }

    #[test]
    fn oversample_policy_tracks_pixel_size() {
        let b = FftBackend::new();
        assert_eq!(b.oversample_for(0.1), 1);
        assert_eq!(b.oversample_for(0.5), 2);
        assert_eq!(b.oversample_for(0.99), 3);
        assert_eq!(b.oversample_for(10.0), MAX_OVERSAMPLE);
    }

    #[test]
    fn transform_cap_sheds_oversampling() {
        let mut b = FftBackend::new();
        b.max_transform = 256;
        let y = random_y(50, 11, 30.0); // big spread -> large pixel -> wants s=4
        let p = place(bbox_of(&y), 64);
        assert!(b.oversample_for(p.pixel) > 2, "case must want heavy oversampling");
        let _ = b.compute(&y, p, 64);
        assert!(b.last_m <= 256, "cap must bound the transform, got M={}", b.last_m);
        assert!(b.last_oversample >= 1);
    }

    #[test]
    fn kernel_cache_hits_on_repeat_placement() {
        let y = random_y(100, 6, 3.0);
        let p = place(bbox_of(&y), 32);
        let mut b = FftBackend::new();
        let t1 = b.compute(&y, p, 32);
        assert_eq!(b.cached_kernel_sets(), 1);
        let t2 = b.compute(&y, p, 32);
        assert_eq!(b.cached_kernel_sets(), 1, "same placement must hit the cache");
        assert_eq!(t1.tex, t2.tex, "cached kernels must be deterministic");
        // A different pixel size builds a second entry.
        let p2 = Placement { origin: p.origin, pixel: p.pixel * 1.5 };
        let _ = b.compute(&y, p2, 32);
        assert_eq!(b.cached_kernel_sets(), 2);
    }

    #[test]
    fn cache_tolerates_small_pixel_drift() {
        // A live optimisation drifts the pixel a fraction of a percent per
        // iteration; that must reuse the cached spectra, while a real
        // resolution change must rebuild.
        let plan = Fft::new(8);
        let mut cache = KernelCache::new(4);
        let a = cache.get(&plan, 0.5);
        let b = cache.get(&plan, 0.5 * 1.0005); // within 0.1% -> hit
        assert!(Arc::ptr_eq(&a, &b), "0.05% drift must hit the cache");
        let c = cache.get(&plan, 0.55); // 10% away -> rebuild
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn cache_lru_evicts_oldest() {
        let plan = Fft::new(8);
        let mut cache = KernelCache::new(2);
        let a = cache.get(&plan, 0.1);
        let _b = cache.get(&plan, 0.2);
        let _a2 = cache.get(&plan, 0.1); // refresh a
        let _c = cache.get(&plan, 0.3); // evicts 0.2
        assert_eq!(cache.len(), 2);
        let a3 = cache.get(&plan, 0.1);
        assert!(Arc::ptr_eq(&a, &a3), "0.1 must have survived");
    }

    #[test]
    fn half_spectrum_kernels_match_full_complex_build() {
        // The cached half-spectrum kernels (scale folded in) must carry
        // exactly the information of the old full-complex build: convolve
        // a random charge through the backend pipeline and through a
        // straight full-complex reference, compare the S channel.
        use crate::field::fft::fft2d;
        let m = 32usize;
        let plan = Fft::new(m);
        let mut rng = Rng::new(13);
        let charge: Vec<f32> = (0..m * m).map(|_| rng.gauss_f32(0.0, 1.0)).collect();
        let pixel = 0.4f32;

        // Reference: full complex transforms, explicit normalisation.
        let mut kre = vec![0.0f32; m * m];
        sample_kernel(0, pixel, m, &mut kre);
        let mut kim = vec![0.0f32; m * m];
        fft2d(&plan, &mut kre, &mut kim, false);
        let mut cre = charge.clone();
        let mut cim = vec![0.0f32; m * m];
        fft2d(&plan, &mut cre, &mut cim, false);
        let mut pre = vec![0.0f32; m * m];
        let mut pim = vec![0.0f32; m * m];
        for i in 0..m * m {
            pre[i] = cre[i] * kre[i] - cim[i] * kim[i];
            pim[i] = cre[i] * kim[i] + cim[i] * kre[i];
        }
        fft2d(&plan, &mut pre, &mut pim, true);

        // Half-spectrum path, as the backend runs it.
        let hw = half_width(m);
        let kernels = SpectralKernels::build(&plan, pixel);
        let mut plane = charge.clone();
        let mut sre = vec![0.0f32; hw * m];
        let mut sim = vec![0.0f32; hw * m];
        let mut tre = vec![0.0f32; m * hw];
        let mut tim = vec![0.0f32; m * hw];
        rfft2d(&plan, &mut plane, &mut sre, &mut sim, &mut tre, &mut tim);
        for i in 0..hw * m {
            let (cr, ci) = (sre[i], sim[i]);
            sre[i] = cr * kernels.chan[0].0[i] - ci * kernels.chan[0].1[i];
            sim[i] = cr * kernels.chan[0].1[i] + ci * kernels.chan[0].0[i];
        }
        irfft2d(&plan, &mut sre, &mut sim, &mut plane, &mut tre, &mut tim, 1.0);

        let scale = pre.iter().fold(0.0f32, |a, v| a.max(v.abs())).max(1e-9);
        for i in 0..m * m {
            assert!(
                (plane[i] - pre[i]).abs() < 1e-3 * scale,
                "i={i}: {} vs {}",
                plane[i],
                pre[i]
            );
        }
    }
}
