//! `pallas-loadgen`: a deterministic, seeded load/chaos generator for a
//! live `serve` (or `router`) endpoint.
//!
//! Spawns N concurrent clients, each with its own TCP connection and a
//! **plan derived purely from the seed**: per job a priority class
//! (interactive/batch), a behaviour profile, and a dataset seed. The
//! profiles cover the protocol surface the scheduler actually contends
//! over:
//!
//! * `run`   — submit, wait for completion;
//! * `watch` — submit, poll `snapshot` mid-run, wait;
//! * `churn` — submit an effectively-endless job, `pause`/`resume`/
//!   `checkpoint` it mid-run, then `stop`;
//! * `kill`  — submit an effectively-endless job, `stop` it mid-run.
//!
//! Endless-job profiles always end `stopped`, bounded ones always end
//! `completed` — so with no shedding, **job-outcome accounting is a
//! pure function of the seed** (the reproducibility contract the CI
//! `tools` job pins by running the same seed twice). Wall-clock
//! latencies and server-side metrics ride along in the summary but are
//! deliberately outside that contract; so is any run with `--fault`,
//! which arms fault points mid-run and trades determinism for chaos.
//!
//! The run fails (non-zero exit from the bin) when a **hard invariant**
//! breaks: every submitted job must reach a terminal account entry
//! (no hangs — every wait is socket-timeout bounded, the whole run
//! wall-clock bounded), nothing may fail outright, and when both
//! priority classes ran long enough to contend, the scheduler's
//! `quanta_interactive`/`quanta_batch` split must sit within tolerance
//! of the nominal 3:1 interleave with neither class starved.

use std::collections::BTreeMap;
use std::io::{BufReader, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use crate::coordinator::protocol::{read_bounded_line, LineRead};
use crate::util::bench::Stats;
use crate::util::json::{self, Json};
use crate::util::rng::Rng;

/// Iteration count for `churn`/`kill` jobs: far beyond what any test
/// window can complete, so their outcome is always `stopped`.
const ENDLESS_ITERS: usize = 1_000_000;

/// Nominal interactive:batch quantum ratio under contention — mirrors
/// the scheduler's `BATCH_POP_PERIOD` = 4 (3 interactive pops per batch
/// pop).
pub const NOMINAL_SKEW: f64 = 3.0;

/// Quanta both classes must have accumulated before the skew band is
/// enforced (below this the ratio is startup noise, not scheduling).
const SKEW_MIN_QUANTA: u64 = 200;

#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// `host:port` of a live `serve` (or `router`) endpoint.
    pub addr: String,
    pub seed: u64,
    pub clients: usize,
    pub jobs_per_client: usize,
    /// Points per dataset (`gaussians`).
    pub n: usize,
    /// Iterations for bounded (`run`/`watch`) jobs.
    pub iters: usize,
    /// Fault spec armed over the wire once the clients are running
    /// (chaos mode; forfeits accounting determinism by design).
    pub fault_spec: Option<String>,
    /// Hard wall clock for the whole run — exceeding it IS the failure.
    pub timeout: Duration,
    /// Multiplicative fairness band around [`NOMINAL_SKEW`].
    pub skew_tolerance: f64,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7001".into(),
            seed: 1,
            clients: 8,
            jobs_per_client: 2,
            n: 64,
            iters: 120,
            fault_spec: None,
            timeout: Duration::from_secs(300),
            skew_tolerance: 4.0,
        }
    }
}

/// One client's record of one planned job.
struct JobRecord {
    class: &'static str,
    profile: &'static str,
    outcome: String,
    /// Wall time of the final `wait` call (terminal outcomes only).
    wait_s: Option<f64>,
    ops_ok: u64,
}

/// The machine-readable run summary.
pub struct Summary {
    pub outcomes: BTreeMap<String, u64>,
    pub per_class: BTreeMap<String, u64>,
    pub per_profile: BTreeMap<String, u64>,
    pub submitted: u64,
    pub ops_ok: u64,
    pub wait_s: Vec<f64>,
    pub elapsed_s: f64,
    /// (`quanta_interactive`, `quanta_batch`) from the server, if the
    /// endpoint exposed the scheduler counters.
    pub quanta: Option<(u64, u64)>,
    pub deliver_lag: Option<Json>,
    pub violations: Vec<String>,
}

impl Summary {
    pub fn ok(&self) -> bool {
        self.violations.is_empty()
    }

    /// The deterministic slice of the summary: what two runs with the
    /// same seed against fresh servers must reproduce byte-for-byte.
    /// `ops_ok` is deliberately absent — `watch` snapshot polls race the
    /// job's progress ("no snapshot yet" early, terminal errors late),
    /// so the op tally is timing-dependent and lives with the other
    /// non-deterministic fields in [`Summary::to_json`].
    pub fn accounting_json(&self) -> Json {
        let map = |m: &BTreeMap<String, u64>| {
            Json::Obj(m.iter().map(|(k, v)| (k.clone(), Json::Num(*v as f64))).collect())
        };
        Json::obj(vec![
            ("submitted", Json::Num(self.submitted as f64)),
            ("outcomes", map(&self.outcomes)),
            ("per_class", map(&self.per_class)),
            ("per_profile", map(&self.per_profile)),
        ])
    }

    pub fn to_json(&self, cfg: &LoadgenConfig) -> Json {
        let stats = Stats { samples: self.wait_s.clone() };
        let pct = |q: f64| {
            if self.wait_s.is_empty() {
                Json::Null
            } else {
                Json::Num(stats.pct(q) * 1e3)
            }
        };
        let fairness = match self.quanta {
            Some((i, b)) => Json::obj(vec![
                ("quanta_interactive", Json::Num(i as f64)),
                ("quanta_batch", Json::Num(b as f64)),
                (
                    "skew",
                    if b > 0 { Json::Num(i as f64 / b as f64) } else { Json::Null },
                ),
                ("nominal", Json::Num(NOMINAL_SKEW)),
            ]),
            None => Json::Null,
        };
        Json::obj(vec![
            ("seed", Json::Num(cfg.seed as f64)),
            ("clients", Json::Num(cfg.clients as f64)),
            ("jobs_per_client", Json::Num(cfg.jobs_per_client as f64)),
            ("accounting", self.accounting_json()),
            ("ops_ok", Json::Num(self.ops_ok as f64)),
            (
                "wait_ms",
                Json::obj(vec![("p50", pct(0.50)), ("p95", pct(0.95)), ("p99", pct(0.99))]),
            ),
            (
                "snapshot_deliver_lag_ns",
                self.deliver_lag.clone().unwrap_or(Json::Null),
            ),
            ("fairness", fairness),
            ("elapsed_s", Json::Num(self.elapsed_s)),
            (
                "violations",
                Json::Arr(self.violations.iter().map(|v| Json::Str(v.clone())).collect()),
            ),
            ("ok", Json::Bool(self.ok())),
        ])
    }
}

/// One line-protocol connection (the chaos-harness client idiom).
struct Conn {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Conn {
    fn connect(addr: &str) -> Result<Self, String> {
        let stream = TcpStream::connect(addr).map_err(|e| format!("connect {addr}: {e}"))?;
        stream
            .set_read_timeout(Some(Duration::from_secs(60)))
            .map_err(|e| format!("set timeout: {e}"))?;
        let reader = BufReader::new(stream.try_clone().map_err(|e| format!("clone: {e}"))?);
        Ok(Conn { reader, writer: stream })
    }

    fn call(&mut self, line: &str) -> Result<Json, String> {
        self.writer
            .write_all(line.as_bytes())
            .and_then(|_| self.writer.write_all(b"\n"))
            .map_err(|e| format!("write: {e}"))?;
        let mut buf = Vec::new();
        match read_bounded_line(&mut self.reader, &mut buf, 64 << 20)
            .map_err(|e| format!("read: {e}"))?
        {
            LineRead::Line => {}
            other => return Err(format!("connection closed mid-call: {other:?}")),
        }
        let text = String::from_utf8_lossy(&buf);
        json::parse(&text).map_err(|e| format!("bad response '{text}': {e}"))
    }
}

fn is_ok(v: &Json) -> bool {
    v.get("ok") == Some(&Json::Bool(true))
}

/// Retriable shed codes the protocol layer emits under admission
/// control; anything else non-ok is a hard failure.
fn is_shed(v: &Json) -> bool {
    matches!(v.str_field("code"), Some("queue_full" | "server_busy" | "draining" | "no_workers"))
}

const PROFILES: [&str; 4] = ["run", "watch", "churn", "kill"];

/// One client thread: execute its seeded plan, one connection, jobs in
/// sequence (concurrency comes from the client count).
fn client_run(cfg: &LoadgenConfig, client: usize, deadline: Instant) -> Vec<JobRecord> {
    // Independent deterministic stream per client (golden-ratio stride
    // keeps neighbouring client seeds decorrelated).
    let mut rng = Rng::new(cfg.seed.wrapping_add(0x9E37_79B9_7F4A_7C15u64.wrapping_mul(client as u64 + 1)));
    let mut records = Vec::with_capacity(cfg.jobs_per_client);
    let mut conn = match Conn::connect(&cfg.addr) {
        Ok(c) => c,
        Err(e) => {
            // Account every planned job so "all accounted" can still be
            // checked (and still fail the run via no_failures).
            for _ in 0..cfg.jobs_per_client {
                records.push(JobRecord {
                    class: "interactive",
                    profile: "none",
                    outcome: format!("failed: {e}"),
                    wait_s: None,
                    ops_ok: 0,
                });
            }
            return records;
        }
    };
    for _ in 0..cfg.jobs_per_client {
        // The plan draws are unconditional and ordered, so the plan is
        // identical across runs regardless of how the server behaves.
        let class = if rng.below(2) == 0 { "interactive" } else { "batch" };
        let profile = PROFILES[rng.below(PROFILES.len())];
        let data_seed = rng.below(8) as u64;
        records.push(run_one_job(cfg, &mut conn, class, profile, data_seed, deadline));
    }
    records
}

fn run_one_job(
    cfg: &LoadgenConfig,
    conn: &mut Conn,
    class: &'static str,
    profile: &'static str,
    data_seed: u64,
    deadline: Instant,
) -> JobRecord {
    let endless = matches!(profile, "churn" | "kill");
    let iters = if endless { ENDLESS_ITERS } else { cfg.iters };
    let mut rec =
        JobRecord { class, profile, outcome: String::new(), wait_s: None, ops_ok: 0 };
    let submit = format!(
        r#"{{"cmd":"submit","dataset":"gaussians","n":{},"engine":"bh-0.5","iters":{iters},"perplexity":8,"knn":"brute","seed":{data_seed},"snapshot_every":1,"priority":"{class}"}}"#,
        cfg.n
    );
    let v = match conn.call(&submit) {
        Ok(v) => v,
        Err(e) => {
            rec.outcome = format!("failed: submit: {e}");
            return rec;
        }
    };
    if !is_ok(&v) {
        rec.outcome = if is_shed(&v) {
            "shed".into()
        } else {
            format!("failed: submit rejected: {v}")
        };
        return rec;
    }
    let Some(job) = v.num_field("job").map(|j| j as u64) else {
        rec.outcome = "failed: submit returned no job id".into();
        return rec;
    };
    // Mid-run phase. Endless jobs first spin until the job demonstrably
    // runs (status shows an optimisation step) so stop always lands
    // mid-flight — that pins the outcome to `stopped` deterministically.
    if endless {
        loop {
            if Instant::now() >= deadline {
                rec.outcome = format!("hung: job {job} never reached iter 1");
                return rec;
            }
            match conn.call(&format!(r#"{{"cmd":"status","job":{job}}}"#)) {
                Ok(s) if is_ok(&s) && s.num_field("iter").unwrap_or(0.0) >= 1.0 => break,
                Ok(_) => std::thread::sleep(Duration::from_millis(5)),
                Err(e) => {
                    rec.outcome = format!("failed: status: {e}");
                    return rec;
                }
            }
        }
    }
    let ops: &[&str] = match profile {
        "watch" => &["snapshot", "snapshot", "snapshot"],
        "churn" => &["pause", "resume", "checkpoint", "stop"],
        "kill" => &["stop"],
        _ => &[],
    };
    for op in ops {
        let line = format!(r#"{{"cmd":"{op}","job":{job}}}"#);
        match conn.call(&line) {
            // `watch` polls race completion ("no snapshot yet" on a job
            // that barely started, terminal errors late) — only the
            // endless profiles' ops are deterministic successes.
            Ok(r) if is_ok(&r) => rec.ops_ok += 1,
            Ok(r) if endless => {
                rec.outcome = format!("failed: {op} rejected: {r}");
                return rec;
            }
            Ok(_) => {}
            Err(e) => {
                rec.outcome = format!("failed: {op}: {e}");
                return rec;
            }
        }
    }
    let t = Instant::now();
    match conn.call(&format!(r#"{{"cmd":"wait","job":{job}}}"#)) {
        Ok(r) if is_ok(&r) => {
            rec.wait_s = Some(t.elapsed().as_secs_f64());
            let stopped = r.get("stopped_early") == Some(&Json::Bool(true));
            rec.outcome = match (endless, stopped) {
                (true, true) => "stopped".into(),
                (false, false) => "completed".into(),
                // An endless job that "completed" or a bounded job that
                // stopped itself would break the accounting contract.
                _ => format!("failed: unexpected terminal state: {r}"),
            };
        }
        Ok(r) => rec.outcome = format!("failed: wait: {r}"),
        Err(e) => rec.outcome = format!("hung: wait: {e}"),
    }
    rec
}

/// Pull the fairness counters and deliver-lag histogram off the server.
fn server_metrics(conn: &mut Conn) -> (Option<(u64, u64)>, Option<Json>) {
    let Ok(v) = conn.call(r#"{"cmd":"metrics"}"#) else {
        return (None, None);
    };
    let m = v.get("metrics");
    let counters = m.and_then(|m| m.get("service")).and_then(|s| s.get("counters"));
    let quanta = counters.and_then(|c| {
        Some((
            c.num_field("scheduler.quanta_interactive")? as u64,
            c.num_field("scheduler.quanta_batch")? as u64,
        ))
    });
    let lag = m
        .and_then(|m| m.get("global"))
        .and_then(|g| g.get("histograms"))
        .and_then(|h| h.get("snapshot.deliver_lag_ns"))
        .cloned();
    (quanta, lag)
}

/// Drive the full run against `cfg.addr`.
pub fn run(cfg: &LoadgenConfig) -> Result<Summary, String> {
    let start = Instant::now();
    let deadline = start + cfg.timeout;
    let mut control = Conn::connect(&cfg.addr)?;
    let handles: Vec<std::thread::JoinHandle<Vec<JobRecord>>> = (0..cfg.clients)
        .map(|c| {
            let cfg = cfg.clone();
            std::thread::spawn(move || client_run(&cfg, c, deadline))
        })
        .collect();
    if let Some(spec) = &cfg.fault_spec {
        let v = control.call(&format!(r#"{{"cmd":"fault","spec":"{spec}"}}"#))?;
        if !is_ok(&v) {
            return Err(format!("fault arm rejected: {v}"));
        }
    }
    let mut records = Vec::new();
    let mut violations = Vec::new();
    for (i, h) in handles.into_iter().enumerate() {
        match h.join() {
            Ok(r) => records.extend(r),
            Err(_) => violations.push(format!("client {i} panicked")),
        }
    }
    if cfg.fault_spec.is_some() {
        let _ = control.call(r#"{"cmd":"fault","clear":true}"#);
    }
    let (quanta, deliver_lag) = server_metrics(&mut control);
    let elapsed = start.elapsed();

    let mut outcomes: BTreeMap<String, u64> = BTreeMap::new();
    let mut per_class = BTreeMap::new();
    let mut per_profile = BTreeMap::new();
    let mut ops_ok = 0;
    let mut wait_s = Vec::new();
    for r in &records {
        // Failure details stay in the violation list; the accounting
        // buckets are the coarse deterministic classes.
        let bucket = r.outcome.split(':').next().unwrap_or("?").to_string();
        *outcomes.entry(bucket).or_default() += 1;
        *per_class.entry(r.class.to_string()).or_default() += 1;
        *per_profile.entry(r.profile.to_string()).or_default() += 1;
        ops_ok += r.ops_ok;
        wait_s.extend(r.wait_s);
        if r.outcome.starts_with("failed") || r.outcome.starts_with("hung") {
            violations.push(format!("{}/{}: {}", r.class, r.profile, r.outcome));
        }
    }

    // Hard invariants.
    let planned = (cfg.clients * cfg.jobs_per_client) as u64;
    let accounted: u64 = outcomes.values().sum();
    if accounted != planned {
        violations.push(format!("accounting hole: {accounted} of {planned} jobs accounted"));
    }
    if elapsed > cfg.timeout {
        violations.push(format!(
            "wall clock exceeded: {:.1}s > {:.1}s",
            elapsed.as_secs_f64(),
            cfg.timeout.as_secs_f64()
        ));
    }
    let both_classes =
        per_class.get("interactive").copied().unwrap_or(0) > 0
            && per_class.get("batch").copied().unwrap_or(0) > 0;
    if let (true, Some((qi, qb))) = (both_classes, quanta) {
        if qi == 0 || qb == 0 {
            violations.push(format!(
                "starvation: quanta_interactive={qi}, quanta_batch={qb} with both classes submitted"
            ));
        } else if qi.min(qb) >= SKEW_MIN_QUANTA {
            let skew = qi as f64 / qb as f64;
            let (lo, hi) =
                (NOMINAL_SKEW / cfg.skew_tolerance, NOMINAL_SKEW * cfg.skew_tolerance);
            if skew < lo || skew > hi {
                violations.push(format!(
                    "fairness skew {skew:.2} outside [{lo:.2}, {hi:.2}] (nominal {NOMINAL_SKEW}:1)"
                ));
            }
        }
    }

    Ok(Summary {
        outcomes,
        per_class,
        per_profile,
        submitted: planned,
        ops_ok,
        wait_s,
        elapsed_s: elapsed.as_secs_f64(),
        quanta,
        deliver_lag,
        violations,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{protocol, EmbeddingService, ServiceConfig};
    use std::sync::Arc;

    fn start_server() -> (Arc<EmbeddingService>, std::net::SocketAddr) {
        let svc = Arc::new(EmbeddingService::with_config(
            None,
            ServiceConfig { max_concurrent: 2, ..Default::default() },
        ));
        let (tx, rx) = std::sync::mpsc::channel();
        let svc2 = svc.clone();
        std::thread::spawn(move || {
            let _ = protocol::serve_with(svc2, "127.0.0.1:0", 256, move |a| {
                let _ = tx.send(a);
            });
        });
        let addr = rx.recv_timeout(Duration::from_secs(10)).expect("bind");
        (svc, addr)
    }

    fn small_cfg(addr: &str) -> LoadgenConfig {
        LoadgenConfig {
            addr: addr.to_string(),
            seed: 7,
            clients: 4,
            jobs_per_client: 2,
            n: 64,
            iters: 60,
            timeout: Duration::from_secs(120),
            ..Default::default()
        }
    }

    #[test]
    fn seeded_run_is_deterministic_and_accounts_every_job() {
        // Two runs, same seed, each against its own fresh server: the
        // accounting slice of the summary must be byte-identical — the
        // CI tools job pins the same contract over a real `serve`.
        let (_svc1, addr1) = start_server();
        let s1 = run(&small_cfg(&addr1.to_string())).expect("first run");
        assert!(s1.ok(), "violations: {:?}", s1.violations);
        assert_eq!(s1.submitted, 8);
        assert_eq!(s1.outcomes.values().sum::<u64>(), 8, "every job accounted");
        assert!(s1.outcomes.get("completed").copied().unwrap_or(0) > 0);
        let stopped = s1.outcomes.get("stopped").copied().unwrap_or(0);
        let expect_stopped: u64 = s1
            .per_profile
            .iter()
            .filter(|(p, _)| *p == "churn" || *p == "kill")
            .map(|(_, c)| c)
            .sum();
        assert_eq!(stopped, expect_stopped, "endless profiles end stopped, exactly");

        let (_svc2, addr2) = start_server();
        let s2 = run(&small_cfg(&addr2.to_string())).expect("second run");
        assert!(s2.ok(), "violations: {:?}", s2.violations);
        assert_eq!(
            s1.accounting_json().to_string(),
            s2.accounting_json().to_string(),
            "same seed against a fresh server must reproduce the accounting"
        );

        // A different seed draws a different plan (profiles/classes),
        // which is the point of seeding.
        let (_svc3, addr3) = start_server();
        let mut other = small_cfg(&addr3.to_string());
        other.seed = 8;
        let s3 = run(&other).expect("third run");
        assert!(s3.ok(), "violations: {:?}", s3.violations);
        assert_eq!(s3.outcomes.values().sum::<u64>(), 8);

        // The summary JSON carries the invariant verdict and fairness
        // counters scraped from the live server.
        let j = s1.to_json(&small_cfg(&addr1.to_string()));
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)));
        assert!(j.get("accounting").is_some());
        assert!(s1.quanta.is_some(), "serve exposes the scheduler counters");
    }

    #[test]
    fn unreachable_endpoint_fails_each_job_not_the_process() {
        // A dead endpoint: `run` itself errors on the control
        // connection — loudly, not a hang.
        let cfg = small_cfg("127.0.0.1:1");
        assert!(run(&cfg).is_err());
    }
}
