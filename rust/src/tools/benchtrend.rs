//! `pallas-bench-trend`: the cross-run perf gate over
//! `BENCH_history.jsonl`.
//!
//! CI appends one `{"commit","run","date","bench":<BENCH_micro.json>}`
//! line per run (see `.github/workflows/ci.yml`); this module parses the
//! series, flattens every numeric leaf of each `bench` snapshot into a
//! dotted path (arrays keyed by their elements' `name`/`workers` field),
//! diffs the newest entry against a baseline, and gates the diff with
//! per-section [`Rule`]s. The default rules reproduce the two inline
//! gates the workflow used to carry:
//!
//! * `simd.kernels.*.speedup` — higher is better, fail on a >10% drop.
//!   Ratios, not raw ns, so runner-speed drift cancels out; skipped
//!   entirely when `simd.tier` changed between the two entries (a
//!   different runner CPU is not a regression).
//! * `cluster.placements.*.owner_of_ns` — lower is better, fail only on
//!   a >2× blow-up (the bench itself pins the absolute budget; the
//!   trend gate only catches gross cross-run regressions).
//!
//! Everything else in the snapshot is rendered in the trend table but
//! not gated. Fewer than two comparable entries ⇒ nothing to diff, the
//! gate passes (first run after a section lands, or a cold CI cache).

use crate::util::json::{self, Json};

/// One parsed line of `BENCH_history.jsonl`.
pub struct Entry {
    pub commit: String,
    pub date: String,
    pub bench: Json,
}

/// Parse the history file's contents. Unparseable lines are an error —
/// a gate that silently skips garbage would pass on a corrupt artifact.
pub fn parse_history(text: &str) -> Result<Vec<Entry>, String> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let v = json::parse(line).map_err(|e| format!("history line {}: {e}", i + 1))?;
        let bench =
            v.get("bench").cloned().ok_or_else(|| format!("history line {}: no bench", i + 1))?;
        out.push(Entry {
            commit: v.str_field("commit").unwrap_or("?").to_string(),
            date: v.str_field("date").unwrap_or("?").to_string(),
            bench,
        });
    }
    Ok(out)
}

/// Flatten every numeric leaf into `(dotted.path, value)`. Array
/// elements are keyed by a `name` (string) or `workers` (number) field
/// when they carry one — so `simd.kernels[{name:"gd_fused",speedup:2}]`
/// becomes `simd.kernels.gd_fused.speedup` and stays comparable across
/// runs even if the array order changes — falling back to the index.
pub fn flatten(bench: &Json) -> Vec<(String, f64)> {
    let mut out = Vec::new();
    walk("", bench, &mut out);
    out
}

fn walk(prefix: &str, v: &Json, out: &mut Vec<(String, f64)>) {
    match v {
        Json::Num(n) => out.push((prefix.to_string(), *n)),
        Json::Obj(fields) => {
            for (k, val) in fields {
                let path = if prefix.is_empty() { k.clone() } else { format!("{prefix}.{k}") };
                walk(&path, val, out);
            }
        }
        Json::Arr(items) => {
            for (i, item) in items.iter().enumerate() {
                let key = item
                    .str_field("name")
                    .map(str::to_string)
                    .or_else(|| item.num_field("workers").map(|w| format!("{}", w as i64)))
                    .unwrap_or_else(|| i.to_string());
                walk(&format!("{prefix}.{key}"), item, out);
            }
        }
        _ => {}
    }
}

/// Which way a metric improves.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    /// e.g. a speedup ratio: a drop is the regression.
    HigherIsBetter,
    /// e.g. a latency: a rise is the regression.
    LowerIsBetter,
}

/// A gating rule: paths matching `pattern` (dot-separated, `*` matches
/// one segment) regress when they move against `direction` by more than
/// `tolerance` (fractional: 0.10 ⇒ 10% worse, 1.0 ⇒ 2× worse).
pub struct Rule {
    pub pattern: &'static str,
    pub direction: Direction,
    pub tolerance: f64,
    /// Skip this rule entirely when the value at this path differs
    /// between baseline and current (e.g. the SIMD dispatch tier — a
    /// different runner CPU is not a regression).
    pub guard_path: Option<&'static str>,
}

/// The rules CI gates on — the formalisation of the workflow's old
/// inline checks.
pub fn default_rules() -> Vec<Rule> {
    vec![
        Rule {
            pattern: "simd.kernels.*.speedup",
            direction: Direction::HigherIsBetter,
            tolerance: 0.10,
            guard_path: Some("simd.tier"),
        },
        Rule {
            pattern: "cluster.placements.*.owner_of_ns",
            direction: Direction::LowerIsBetter,
            tolerance: 1.0,
            guard_path: None,
        },
    ]
}

fn path_matches(pattern: &str, path: &str) -> bool {
    let ps: Vec<&str> = pattern.split('.').collect();
    let xs: Vec<&str> = path.split('.').collect();
    ps.len() == xs.len() && ps.iter().zip(&xs).all(|(p, x)| *p == "*" || p == x)
}

/// One flattened metric's movement between baseline and current.
pub struct Delta {
    pub path: String,
    pub old: f64,
    pub new: f64,
    /// `new / old` (NaN when `old` is 0 or not finite).
    pub ratio: f64,
    /// Whether a rule gates this path.
    pub gated: bool,
    /// Gated and moved against its direction past tolerance.
    pub regressed: bool,
}

/// The full trend analysis between two history entries.
pub struct Analysis {
    pub baseline_commit: String,
    pub current_commit: String,
    pub deltas: Vec<Delta>,
    /// Human-readable notes on anything the gate chose not to judge
    /// (guard-path skips, missing baselines) — a gate that silently
    /// narrows its own coverage reads as "everything passed".
    pub skipped: Vec<String>,
}

impl Analysis {
    pub fn regressions(&self) -> Vec<&Delta> {
        self.deltas.iter().filter(|d| d.regressed).collect()
    }
}

/// Diff `cur` against `prev` under `rules`.
pub fn compare(prev: &Entry, cur: &Entry, rules: &[Rule]) -> Analysis {
    let old: Vec<(String, f64)> = flatten(&prev.bench);
    let lookup = |path: &str| old.iter().find(|(p, _)| p == path).map(|&(_, v)| v);
    let mut skipped = Vec::new();
    // Resolve guard paths once: a rule whose guard value changed (or is
    // string-valued — compare via the raw Json) is disabled for this diff.
    let guard_changed = |guard: &str| -> bool {
        let a = json_at(&prev.bench, guard);
        let b = json_at(&cur.bench, guard);
        match (a, b) {
            (Some(x), Some(y)) => x.to_string() != y.to_string(),
            _ => false,
        }
    };
    let active: Vec<(&Rule, bool)> = rules
        .iter()
        .map(|r| {
            let disabled = r.guard_path.map(guard_changed).unwrap_or(false);
            if disabled {
                skipped.push(format!(
                    "rule '{}' skipped: guard {} changed between {} and {}",
                    r.pattern,
                    r.guard_path.unwrap(),
                    prev.commit,
                    cur.commit
                ));
            }
            (r, disabled)
        })
        .collect();
    let mut deltas = Vec::new();
    for (path, new) in flatten(&cur.bench) {
        let Some(old_v) = lookup(&path) else {
            continue; // new metric: nothing to diff against yet
        };
        let ratio = if old_v.is_finite() && old_v != 0.0 { new / old_v } else { f64::NAN };
        let rule = active
            .iter()
            .find(|(r, disabled)| !disabled && path_matches(r.pattern, &path))
            .map(|(r, _)| *r);
        let regressed = match rule {
            Some(r) if ratio.is_finite() => match r.direction {
                Direction::HigherIsBetter => ratio < 1.0 - r.tolerance,
                Direction::LowerIsBetter => ratio > 1.0 + r.tolerance,
            },
            _ => false,
        };
        deltas.push(Delta { path, old: old_v, new, ratio, gated: rule.is_some(), regressed });
    }
    Analysis {
        baseline_commit: prev.commit.clone(),
        current_commit: cur.commit.clone(),
        deltas,
        skipped,
    }
}

fn json_at<'a>(v: &'a Json, path: &str) -> Option<&'a Json> {
    let mut cur = v;
    for seg in path.split('.') {
        cur = cur.get(seg)?;
    }
    Some(cur)
}

/// Analyze the history: newest entry vs `baseline` (a commit prefix) or
/// the second-newest. `Ok(None)` when there is nothing to diff.
pub fn analyze(
    entries: &[Entry],
    baseline: Option<&str>,
    rules: &[Rule],
) -> Result<Option<Analysis>, String> {
    let Some(cur) = entries.last() else {
        return Ok(None);
    };
    let prev = match baseline {
        Some(c) => Some(
            entries[..entries.len() - 1]
                .iter()
                .rev()
                .find(|e| e.commit.starts_with(c))
                .ok_or_else(|| format!("baseline commit '{c}' not in history"))?,
        ),
        None => entries[..entries.len() - 1].last(),
    };
    Ok(prev.map(|p| compare(p, cur, rules)))
}

/// Render the trend table as markdown. `all` includes ungated metrics;
/// otherwise only gated paths (plus any regression) are shown.
pub fn render_markdown(a: &Analysis, all: bool) -> String {
    use std::fmt::Write;
    let mut out = String::new();
    let _ = writeln!(
        out,
        "## Bench trend: {} → {}\n",
        &a.baseline_commit[..a.baseline_commit.len().min(12)],
        &a.current_commit[..a.current_commit.len().min(12)]
    );
    let _ = writeln!(out, "| metric | baseline | current | ratio | verdict |");
    let _ = writeln!(out, "|---|---|---|---|---|");
    for d in &a.deltas {
        if !all && !d.gated && !d.regressed {
            continue;
        }
        let verdict = if d.regressed {
            "REGRESSED"
        } else if d.gated {
            "ok"
        } else {
            "-"
        };
        let _ = writeln!(
            out,
            "| {} | {:.4} | {:.4} | {:.3} | {} |",
            d.path, d.old, d.new, d.ratio, verdict
        );
    }
    for s in &a.skipped {
        let _ = writeln!(out, "\n> skipped: {s}");
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(commit: &str, bench: &str) -> Entry {
        Entry {
            commit: commit.into(),
            date: "2026-01-01".into(),
            bench: json::parse(bench).unwrap(),
        }
    }

    fn simd_bench(tier: &str, speedup: f64) -> String {
        format!(
            r#"{{"simd":{{"tier":"{tier}","kernels":[{{"name":"gd_fused","speedup":{speedup}}},{{"name":"splat","speedup":3.0}}]}},"cluster":{{"placements":[{{"workers":4,"owner_of_ns":100.0}},{{"workers":16,"owner_of_ns":220.0}}]}},"sched":{{"quantum_ns":5.0}}}}"#
        )
    }

    #[test]
    fn history_parses_and_flattens_keyed_arrays() {
        let l1 =
            format!(r#"{{"commit":"aaa1","run":"1","date":"d","bench":{}}}"#, simd_bench("avx2", 2.0));
        let l2 =
            format!(r#"{{"commit":"bbb2","run":"2","date":"d","bench":{}}}"#, simd_bench("avx2", 2.1));
        let text = format!("{l1}\n\n{l2}\n");
        let entries = parse_history(&text).unwrap();
        assert_eq!(entries.len(), 2);
        let flat = flatten(&entries[0].bench);
        let get = |p: &str| flat.iter().find(|(x, _)| x == p).map(|&(_, v)| v);
        assert_eq!(get("simd.kernels.gd_fused.speedup"), Some(2.0));
        assert_eq!(get("cluster.placements.16.owner_of_ns"), Some(220.0));
        assert_eq!(get("sched.quantum_ns"), Some(5.0));
        assert!(parse_history("not json\n").is_err());
        assert!(parse_history(r#"{"commit":"x"}"#).is_err(), "bench-less lines are loud");
    }

    #[test]
    fn injected_20_percent_speedup_regression_fails_the_gate() {
        // The acceptance scenario: a kernel's speedup drops 20% between
        // two runs — that must come out as a gated regression.
        let prev = entry("aaa", &simd_bench("avx2", 2.5));
        let cur = entry("bbb", &simd_bench("avx2", 2.0));
        let a = compare(&prev, &cur, &default_rules());
        let regs = a.regressions();
        assert_eq!(regs.len(), 1, "exactly the dropped kernel regresses");
        assert_eq!(regs[0].path, "simd.kernels.gd_fused.speedup");
        assert!((regs[0].ratio - 0.8).abs() < 1e-9);
        // A 5% wobble on the same rule stays green.
        let cur_ok = entry("ccc", &simd_bench("avx2", 2.4));
        assert!(compare(&prev, &cur_ok, &default_rules()).regressions().is_empty());
    }

    #[test]
    fn latency_blowup_gates_only_past_2x() {
        let prev = entry("aaa", &simd_bench("avx2", 2.0));
        // 1.9× on owner_of_ns: within the deliberately lenient bound.
        let mut near = simd_bench("avx2", 2.0);
        near = near.replace("100.0", "190.0");
        assert!(compare(&prev, &entry("bbb", &near), &default_rules())
            .regressions()
            .is_empty());
        // 2.5× blows the gate.
        let far = simd_bench("avx2", 2.0).replace("100.0", "250.0");
        let a = compare(&prev, &entry("ccc", &far), &default_rules());
        let regs = a.regressions();
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].path, "cluster.placements.4.owner_of_ns");
    }

    #[test]
    fn tier_change_disarms_the_simd_rule_but_not_the_cluster_rule() {
        let prev = entry("aaa", &simd_bench("avx2", 2.5));
        // Speedup halves AND the tier changed (different runner CPU):
        // the simd rule is skipped, loudly.
        let cur = entry("bbb", &simd_bench("scalar", 1.0));
        let a = compare(&prev, &cur, &default_rules());
        assert!(a.regressions().is_empty());
        assert_eq!(a.skipped.len(), 1);
        assert!(a.skipped[0].contains("simd.tier"), "{}", a.skipped[0]);
        // The cluster rule still gates on the same diff.
        let far = simd_bench("scalar", 1.0).replace("100.0", "300.0");
        let a = compare(&prev, &entry("ccc", &far), &default_rules());
        assert_eq!(a.regressions().len(), 1);
        assert_eq!(a.regressions()[0].path, "cluster.placements.4.owner_of_ns");
    }

    #[test]
    fn short_history_and_baseline_selection() {
        let one = vec![entry("aaa", &simd_bench("avx2", 2.0))];
        assert!(analyze(&one, None, &default_rules()).unwrap().is_none(), "nothing to diff");
        assert!(analyze(&[], None, &default_rules()).unwrap().is_none());
        let three = vec![
            entry("aaa111", &simd_bench("avx2", 3.0)),
            entry("bbb222", &simd_bench("avx2", 2.5)),
            entry("ccc333", &simd_bench("avx2", 2.4)),
        ];
        // Default baseline: the adjacent previous entry — 4% drop, green.
        let a = analyze(&three, None, &default_rules()).unwrap().unwrap();
        assert_eq!(a.baseline_commit, "bbb222");
        assert!(a.regressions().is_empty());
        // Pinned baseline by commit prefix: 20% drop vs aaa111, red.
        let a = analyze(&three, Some("aaa"), &default_rules()).unwrap().unwrap();
        assert_eq!(a.baseline_commit, "aaa111");
        assert_eq!(a.regressions().len(), 1);
        assert!(analyze(&three, Some("zzz"), &default_rules()).is_err());
    }

    #[test]
    fn markdown_table_shows_gated_rows_and_verdicts() {
        let prev = entry("aaa111222333", &simd_bench("avx2", 2.5));
        let cur = entry("bbb444555666", &simd_bench("avx2", 2.0));
        let a = compare(&prev, &cur, &default_rules());
        let md = render_markdown(&a, false);
        assert!(md.contains("aaa111222333 → bbb444555666"));
        assert!(md.contains("simd.kernels.gd_fused.speedup"));
        assert!(md.contains("REGRESSED"));
        assert!(!md.contains("sched.quantum_ns"), "ungated rows hidden by default");
        let md_all = render_markdown(&a, true);
        assert!(md_all.contains("sched.quantum_ns"));
    }
}
