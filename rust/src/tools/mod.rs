//! Operational tool suite backing the `pallas-*` binaries.
//!
//! Small, sharp tools over the library's own substrates (the fpm-tools
//! pattern: thin `src/bin/` entry points, all logic here where it is
//! unit-testable):
//!
//! * [`loadgen`] — deterministic seeded load/chaos generator driving a
//!   live `serve`/`router` endpoint over the line protocol
//!   (`pallas-loadgen`);
//! * [`benchtrend`] — `BENCH_history.jsonl` trend analysis and the CI
//!   regression gate (`pallas-bench-trend`);
//! * [`fsck`] — offline integrity checker for a `--state-dir`
//!   (`pallas-fsck`), dry-run by default.
//!
//! Each binary prints a machine-readable JSON summary on stdout and
//! reserves its exit code: 0 = clean, 1 = the tool's own verdict failed
//! (invariant violation, regression, defective store), 2 = usage error.

pub mod benchtrend;
pub mod fsck;
pub mod loadgen;
