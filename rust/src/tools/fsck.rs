//! `pallas-fsck`: offline integrity check (and optional repair) for a
//! coordinator state dir.
//!
//! Walks the three record populations a `serve --state-dir` (or a
//! router's `--state-dir`) accumulates —
//!
//! ```text
//! <state-dir>/simstore/g-*.rec      kNN-graph records   (KIND_GRAPH)
//! <state-dir>/simstore/p-*.rec      joint-P records     (KIND_P)
//! <state-dir>/jobs/job-*.job        worker job journal  (KIND_JOB)
//! <state-dir>/cluster-journal/*.job router job journal  (KIND_JOB)
//! ```
//!
//! — and verifies each file's record framing (magic/kind/version/length/
//! checksum via [`store::verify_record_bytes`]), its deep structure and
//! key echo (via [`store::fsck_payload_check`]), and that the echoed key
//! names exactly the file it sits under. Orphaned `*.tmp.*` files left
//! by a writer killed between its tmp write and rename are reported too.
//!
//! **Dry-run by default**: with neither `repair` nor `compact` set the
//! pass does only `std::fs::read` — it never deletes, rewrites, renames
//! or creates anything, so the state dir is byte-for-byte untouched (the
//! serving stack's own `read_record` deletes defective files as it goes;
//! fsck deliberately does not share that self-healing behaviour).
//! `repair` deletes corrupt records and tmp orphans, and **renames**
//! misplaced records to the name their key echo dictates — their framing
//! and payload are fully healthy, so the data is recoverable, not trash
//! (deleting only when the proper name is already taken by another
//! record). `compact` additionally rewrites every healthy record
//! atomically (fresh framing, one file per record, implies the `repair`
//! actions).

use std::path::{Path, PathBuf};

use crate::coordinator::store::{self, KIND_GRAPH, KIND_JOB, KIND_P};
use crate::util::json::Json;

/// What a pass may do to the dir. `Default` is the read-only dry run.
#[derive(Debug, Clone, Copy, Default)]
pub struct FsckOptions {
    /// Delete corrupt records and orphaned tmp files; rename misplaced
    /// records to the name their key echo dictates (delete only when
    /// that name is already taken).
    pub repair: bool,
    /// Rewrite healthy records atomically (implies the repair actions).
    pub compact: bool,
}

impl FsckOptions {
    fn mutating(&self) -> bool {
        self.repair || self.compact
    }
}

/// One defective file and why.
pub struct Defect {
    pub path: PathBuf,
    pub reason: String,
}

/// A healthy record sitting under a name its key echo disagrees with,
/// and the filename the echo says it should have.
pub struct Misplaced {
    pub path: PathBuf,
    pub expected: String,
}

/// The outcome of one pass.
#[derive(Default)]
pub struct FsckReport {
    /// Record files examined (tmp orphans not included).
    pub scanned: usize,
    /// Framing + deep structure + key echo all verified.
    pub healthy: usize,
    /// Total bytes of healthy records.
    pub healthy_bytes: u64,
    /// Bad framing or bad structure.
    pub corrupt: Vec<Defect>,
    /// Healthy record sitting under a name its key echo disagrees with
    /// (it can never be found by its key until it is renamed).
    pub misplaced: Vec<Misplaced>,
    /// `*.tmp.*` leftovers from a crashed writer.
    pub orphaned_tmp: Vec<PathBuf>,
    /// Files deleted (repair/compact only).
    pub removed: usize,
    /// Misplaced records moved to their key-echo name (repair/compact only).
    pub renamed: usize,
    /// Healthy records rewritten (compact only).
    pub rewritten: usize,
}

impl FsckReport {
    /// Clean ⇔ nothing is corrupt, misplaced, or orphaned.
    pub fn clean(&self) -> bool {
        self.corrupt.is_empty() && self.misplaced.is_empty() && self.orphaned_tmp.is_empty()
    }

    /// Machine-readable summary (what the bin prints).
    pub fn to_json(&self) -> Json {
        let defects = |v: &[Defect]| {
            Json::Arr(
                v.iter()
                    .map(|d| {
                        Json::obj(vec![
                            ("path", Json::Str(d.path.display().to_string())),
                            ("reason", Json::Str(d.reason.clone())),
                        ])
                    })
                    .collect(),
            )
        };
        Json::obj(vec![
            ("scanned", Json::Num(self.scanned as f64)),
            ("healthy", Json::Num(self.healthy as f64)),
            ("healthy_bytes", Json::Num(self.healthy_bytes as f64)),
            ("corrupt", defects(&self.corrupt)),
            (
                "misplaced",
                Json::Arr(
                    self.misplaced
                        .iter()
                        .map(|m| {
                            Json::obj(vec![
                                ("path", Json::Str(m.path.display().to_string())),
                                ("expected", Json::Str(m.expected.clone())),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "orphaned_tmp",
                Json::Arr(
                    self.orphaned_tmp
                        .iter()
                        .map(|p| Json::Str(p.display().to_string()))
                        .collect(),
                ),
            ),
            ("removed", Json::Num(self.removed as f64)),
            ("renamed", Json::Num(self.renamed as f64)),
            ("rewritten", Json::Num(self.rewritten as f64)),
            ("clean", Json::Bool(self.clean())),
        ])
    }
}

/// The record populations under a state dir: (subdir, filename suffix,
/// expected kind keyed by filename prefix).
fn kind_for(name: &str) -> Option<u8> {
    if name.starts_with("g-") && name.ends_with(".rec") {
        Some(KIND_GRAPH)
    } else if name.starts_with("p-") && name.ends_with(".rec") {
        Some(KIND_P)
    } else if name.starts_with("job-") && name.ends_with(".job") {
        Some(KIND_JOB)
    } else {
        None
    }
}

/// Run one pass over `state_dir`. Missing subdirectories are fine (a
/// worker dir has no `cluster-journal`, a router dir no `simstore`).
pub fn run_fsck(state_dir: &Path, opts: &FsckOptions) -> std::io::Result<FsckReport> {
    let mut report = FsckReport::default();
    for sub in ["simstore", "jobs", "cluster-journal"] {
        let dir = state_dir.join(sub);
        if !dir.is_dir() {
            continue;
        }
        let mut names: Vec<PathBuf> =
            std::fs::read_dir(&dir)?.flatten().map(|e| e.path()).collect();
        names.sort();
        for path in names {
            let Some(name) = path.file_name().and_then(|n| n.to_str()).map(String::from) else {
                continue;
            };
            if name.contains(".tmp.") {
                report.orphaned_tmp.push(path);
                continue;
            }
            let Some(kind) = kind_for(&name) else {
                continue; // not ours: never judge (or delete) foreign files
            };
            report.scanned += 1;
            let bytes = std::fs::read(&path)?;
            let verdict = match store::verify_record_bytes(&bytes, kind) {
                Err(d) => Err(d.to_string()),
                Ok(payload) => {
                    store::fsck_payload_check(kind, payload).map(|expected| (expected, payload))
                }
            };
            match verdict {
                Err(reason) => report.corrupt.push(Defect { path, reason }),
                Ok((expected, _)) if expected != name => {
                    report.misplaced.push(Misplaced { path, expected })
                }
                Ok((_, payload)) => {
                    report.healthy += 1;
                    report.healthy_bytes += bytes.len() as u64;
                    if opts.compact {
                        // Atomic rewrite: same payload, fresh framing.
                        store::write_record(&path, kind, payload)?;
                        report.rewritten += 1;
                    }
                }
            }
        }
    }
    if opts.mutating() {
        for d in &report.corrupt {
            if std::fs::remove_file(&d.path).is_ok() {
                report.removed += 1;
            }
        }
        // Misplaced records are healthy data under the wrong name:
        // restore them to the name the key echo dictates so lookups find
        // them again. Delete only when that name is already occupied
        // (the occupant was verified this same pass, so the duplicate
        // really is dead weight).
        for m in &report.misplaced {
            let target = m.path.with_file_name(&m.expected);
            if target.exists() {
                if std::fs::remove_file(&m.path).is_ok() {
                    report.removed += 1;
                }
            } else if std::fs::rename(&m.path, &target).is_ok() {
                report.renamed += 1;
            }
        }
        for p in &report.orphaned_tmp {
            if std::fs::remove_file(p).is_ok() {
                report.removed += 1;
            }
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::KnnMethod;
    use crate::coordinator::simcache::{GraphKey, SimKey};
    use crate::coordinator::{JobJournal, SimStore};
    use crate::hd::sparse::Csr;
    use crate::hd::{KnnGraph, SparseP};
    use std::collections::BTreeMap;

    fn tmp_state_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("gsne-fsck-{}-{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn graph_key() -> GraphKey {
        GraphKey { fingerprint: 0xbeef, method: KnnMethod::Brute, k: 3, seed: 9 }
    }

    /// A state dir with two healthy sim records, one healthy journal
    /// entry, one corrupt record, one misplaced record, one tmp orphan.
    fn seeded_dir(name: &str) -> PathBuf {
        let dir = tmp_state_dir(name);
        let store = SimStore::open(&dir.join("simstore")).unwrap();
        let g = KnnGraph {
            n: 4,
            k: 3,
            idx: vec![1, 2, 3, 0, 2, 3, 0, 1, 3, 0, 1, 2],
            d2: (0..12).map(|i| i as f32).collect(),
        };
        store.store_graph(&graph_key(), &g);
        let p = SparseP {
            csr: Csr::from_rows(2, 2, 2, vec![0, 1, 1, 0], vec![0.1, 0.4, 0.3, 0.2]),
            perplexity: 12.0,
        };
        store.store_p(&SimKey { graph: graph_key(), perplexity_bits: 12.0f32.to_bits() }, &p);
        let j = JobJournal::open(&dir.join("jobs")).unwrap();
        j.write(7, r#"{"dataset":"gaussians","n":64}"#, b"checkpoint-bytes");
        // Corrupt: a scribbled-over record under a record name.
        std::fs::write(dir.join("simstore").join("g-0000000000000000.rec"), b"scribble")
            .unwrap();
        // Misplaced: a healthy journal record copied under the wrong id.
        std::fs::copy(dir.join("jobs").join("job-7.job"), dir.join("jobs").join("job-9.job"))
            .unwrap();
        // Orphan: a crashed writer's tmp leftover.
        std::fs::write(dir.join("simstore").join("p-aaaa.rec.tmp.4242"), b"half").unwrap();
        dir
    }

    fn dir_bytes(dir: &Path) -> BTreeMap<PathBuf, Vec<u8>> {
        let mut out = BTreeMap::new();
        let mut stack = vec![dir.to_path_buf()];
        while let Some(d) = stack.pop() {
            for e in std::fs::read_dir(&d).unwrap().flatten() {
                let p = e.path();
                if p.is_dir() {
                    stack.push(p);
                } else {
                    let bytes = std::fs::read(&p).unwrap();
                    out.insert(p, bytes);
                }
            }
        }
        out
    }

    #[test]
    fn dry_run_reports_everything_and_mutates_nothing() {
        let dir = seeded_dir("dry");
        let before = dir_bytes(&dir);
        let report = run_fsck(&dir, &FsckOptions::default()).unwrap();
        // The defect census: 3 healthy, 1 corrupt, 1 misplaced, 1 orphan.
        assert_eq!(report.scanned, 5);
        assert_eq!(report.healthy, 3);
        assert_eq!(report.corrupt.len(), 1);
        assert!(report.corrupt[0].path.ends_with("g-0000000000000000.rec"));
        assert_eq!(report.misplaced.len(), 1);
        assert!(report.misplaced[0].path.ends_with("job-9.job"));
        assert_eq!(report.misplaced[0].expected, "job-7.job");
        assert_eq!(report.orphaned_tmp.len(), 1);
        assert!(!report.clean());
        assert_eq!((report.removed, report.rewritten), (0, 0));
        // The satellite's contract: a read-only pass leaves every byte
        // of the state dir identical — nothing deleted, written, moved.
        assert_eq!(dir_bytes(&dir), before, "dry run must not mutate the state dir");
        // And it is idempotent.
        let again = run_fsck(&dir, &FsckOptions::default()).unwrap();
        assert_eq!(again.scanned, 5);
        assert_eq!(dir_bytes(&dir), before);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn repair_removes_defects_and_keeps_healthy_records_loadable() {
        let dir = seeded_dir("repair");
        let report =
            run_fsck(&dir, &FsckOptions { repair: true, compact: false }).unwrap();
        // The misplaced record's proper name (job-7.job) is occupied by
        // the verified original, so the duplicate is deleted, not renamed.
        assert_eq!(report.removed, 3, "corrupt + misplaced duplicate + orphan");
        assert_eq!(report.renamed, 0);
        let after = run_fsck(&dir, &FsckOptions::default()).unwrap();
        assert!(after.clean());
        assert_eq!(after.healthy, 3);
        // The healthy population still round-trips through the real readers.
        let store = SimStore::open(&dir.join("simstore")).unwrap();
        assert!(store.load_graph(&graph_key()).is_some(), "repair must not touch healthy data");
        let j = JobJournal::open(&dir.join("jobs")).unwrap();
        let all = j.read_all();
        assert_eq!(all.len(), 1);
        assert_eq!(all[0].id, 7);
        assert_eq!(all[0].checkpoint, b"checkpoint-bytes");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn repair_renames_misplaced_record_back_to_its_key_echo_name() {
        // A healthy journal record stranded under the wrong id, with the
        // proper name free: repair must move it home, not destroy it.
        let dir = tmp_state_dir("rename");
        let j = JobJournal::open(&dir.join("jobs")).unwrap();
        j.write(7, r#"{"dataset":"gaussians","n":64}"#, b"checkpoint-bytes");
        std::fs::rename(dir.join("jobs").join("job-7.job"), dir.join("jobs").join("job-9.job"))
            .unwrap();

        let report = run_fsck(&dir, &FsckOptions { repair: true, compact: false }).unwrap();
        assert_eq!(report.misplaced.len(), 1);
        assert_eq!(report.renamed, 1, "healthy data is recovered, not deleted");
        assert_eq!(report.removed, 0);
        assert!(dir.join("jobs").join("job-7.job").exists());
        assert!(!dir.join("jobs").join("job-9.job").exists());

        // The restored record is clean and loadable by the real reader.
        let after = run_fsck(&dir, &FsckOptions::default()).unwrap();
        assert!(after.clean());
        let all = JobJournal::open(&dir.join("jobs")).unwrap().read_all();
        assert_eq!(all.len(), 1);
        assert_eq!(all[0].id, 7);
        assert_eq!(all[0].checkpoint, b"checkpoint-bytes");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn compact_rewrites_healthy_records_bit_identically() {
        let dir = seeded_dir("compact");
        let report = run_fsck(&dir, &FsckOptions { repair: false, compact: true }).unwrap();
        assert_eq!(report.rewritten, 3);
        assert_eq!(report.removed, 3, "compact implies the repair deletions");
        // Same payload + same framing ⇒ the rewritten files verify and
        // the store still serves them.
        let after = run_fsck(&dir, &FsckOptions::default()).unwrap();
        assert!(after.clean());
        assert_eq!(after.healthy, 3);
        let store = SimStore::open(&dir.join("simstore")).unwrap();
        assert!(store.load_graph(&graph_key()).is_some());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_subdirs_and_foreign_files_are_ignored() {
        let dir = tmp_state_dir("sparse");
        // No simstore/jobs/cluster-journal at all.
        let r = run_fsck(&dir, &FsckOptions::default()).unwrap();
        assert_eq!(r.scanned, 0);
        assert!(r.clean());
        // A foreign file in a known subdir is not scanned (or deleted).
        std::fs::create_dir_all(dir.join("simstore")).unwrap();
        std::fs::write(dir.join("simstore").join("README.txt"), b"hands off").unwrap();
        let r = run_fsck(&dir, &FsckOptions { repair: true, compact: false }).unwrap();
        assert_eq!(r.scanned, 0);
        assert!(dir.join("simstore").join("README.txt").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
