//! Blocked squared-Euclidean distance kernels — the shared inner loop of
//! every kNN structure in `hd/`.
//!
//! The seed computed every pairwise distance with a per-pair scalar scan
//! (`dist2`): load two rows, subtract, square, accumulate. That keeps one
//! short dependency chain in flight and re-streams both rows from cache
//! for every pair. This module replaces it with the classic factorisation
//!
//!   ‖x − y‖² = ‖x‖² + ‖y‖² − 2⟨x, y⟩
//!
//! over *packed panels*, GEMM-style: row norms are precomputed once, the
//! base matrix is packed into `B_BLOCK`-row panels stored feature-major
//! ([`PackedBase`]), and the inner loop is a rank-1 update — broadcast
//! one query feature, multiply-accumulate it against a unit-stride panel
//! row into a `B_BLOCK`-wide accumulator. The accumulator and the
//! current panel stay L1-resident across a whole query block, the panel
//! row access is contiguous (so LLVM vectorises the `bj` loop), and each
//! loaded panel element is reused by every live query. The C mirror of
//! this kernel measures 3.3× over the scalar scan at N=10k, D=128
//! single-threaded (see BENCH_micro.json `similarities`).
//!
//! Tree structures score their *gathered* candidate lists (leaf buckets,
//! kNN-descent candidates) through [`scan_candidates`]: the same
//! factorisation with a 4-candidate micro-kernel (four independent
//! accumulator chains over one streamed read of the query).
//!
//! Exactness: the factorised form differs from the scalar scan only by
//! f32 rounding (≲1e-6 relative), far below neighbour-distance gaps on
//! real data; `bruteforce::knn_scalar_reference` is kept as the
//! equivalence oracle for tests and benches.
//!
//! The arithmetic itself lives in [`crate::util::simd`]: `dot`, the
//! four-candidate `dot4` and the panel rank-1 update are dispatched
//! kernels (scalar / SSE4.1 / AVX2, selected at runtime), and every tier
//! is bit-identical to the scalar reference — including the tails, so a
//! candidate scored by the quad micro-kernel and the same candidate
//! scored by the remainder path can no longer drift apart.

use super::knn::{KBest, KnnGraph};
use crate::util::parallel;
use crate::util::simd;

/// Query rows per worker chunk (one KBest per live query row).
pub const Q_BLOCK: usize = 32;
/// Base rows per packed panel; the `B_BLOCK`-wide accumulator (512 B)
/// and one panel row (512 B) stay L1-resident.
pub const B_BLOCK: usize = 128;

/// Plain dot product through the active SIMD tier (bit-identical across
/// tiers; see `util::simd`).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    (simd::kernels().dot)(a, b)
}

/// Squared norm of every row of a row-major `(n, d)` matrix (parallel).
pub fn row_sq_norms(x: &[f32], n: usize, d: usize) -> Vec<f32> {
    debug_assert_eq!(x.len(), n * d);
    let kern = simd::kernels();
    let mut out = vec![0.0f32; n];
    {
        let slots = parallel::SyncSlice::new(&mut out);
        parallel::par_chunks(n, 256, |range| {
            for i in range {
                let row = &x[i * d..(i + 1) * d];
                unsafe {
                    *slots.get_mut(i) = (kern.dot)(row, row);
                }
            }
        });
    }
    out
}

/// Score a candidate id list against one query through the factorised
/// micro-kernel: `d²(q, x_c) = ‖q‖² + ‖x_c‖² − 2⟨q, x_c⟩`, pushed into
/// `kb`. This is the leaf-scan primitive of the VP-tree and KD-forest.
pub fn scan_candidates(
    q: &[f32],
    q_norm: f32,
    x: &[f32],
    d: usize,
    norms: &[f32],
    cand: &[u32],
    kb: &mut KBest,
) {
    let kern = simd::kernels();
    let quads = cand.len() / 4;
    for c in 0..quads {
        let ids = &cand[4 * c..4 * c + 4];
        let (i0, i1, i2, i3) =
            (ids[0] as usize, ids[1] as usize, ids[2] as usize, ids[3] as usize);
        let s = (kern.dot4)(
            q,
            &x[i0 * d..(i0 + 1) * d],
            &x[i1 * d..(i1 + 1) * d],
            &x[i2 * d..(i2 + 1) * d],
            &x[i3 * d..(i3 + 1) * d],
        );
        for (t, &id) in ids.iter().enumerate() {
            let d2 = (q_norm + norms[id as usize] - 2.0 * s[t]).max(0.0);
            if d2 < kb.bound() {
                kb.push(d2, id);
            }
        }
    }
    for &id in &cand[4 * quads..] {
        let i = id as usize;
        let d2 = (q_norm + norms[i] - 2.0 * (kern.dot)(q, &x[i * d..(i + 1) * d])).max(0.0);
        if d2 < kb.bound() {
            kb.push(d2, id);
        }
    }
}

/// A row-major `(n, d)` matrix repacked into `B_BLOCK`-row panels stored
/// *feature-major*: panel `p`, feature `t` holds the `t`-th coordinate of
/// base rows `[p·B_BLOCK, (p+1)·B_BLOCK)` contiguously (zero-padded past
/// `n`). The GEMM-style layout the panel kernel streams at unit stride.
pub struct PackedBase {
    pub n: usize,
    pub d: usize,
    data: Vec<f32>,
}

impl PackedBase {
    /// Number of panels covering `n` base rows.
    #[inline]
    pub fn panels(n: usize) -> usize {
        n.div_ceil(B_BLOCK)
    }

    /// Pack `x` (parallel over panels).
    pub fn pack(x: &[f32], n: usize, d: usize) -> Self {
        debug_assert_eq!(x.len(), n * d);
        let npan = Self::panels(n);
        let mut data = vec![0.0f32; npan * d * B_BLOCK];
        {
            let slots = parallel::SyncSlice::new(&mut data);
            parallel::par_chunks(npan, 1, |range| {
                for p in range {
                    let b0 = p * B_BLOCK;
                    let blen = B_BLOCK.min(n - b0);
                    let base = p * d * B_BLOCK;
                    for bj in 0..blen {
                        let row = &x[(b0 + bj) * d..(b0 + bj + 1) * d];
                        for (t, &v) in row.iter().enumerate() {
                            unsafe {
                                *slots.get_mut(base + t * B_BLOCK + bj) = v;
                            }
                        }
                    }
                }
            });
        }
        Self { n, d, data }
    }

    /// Panel `p` as a `(d, B_BLOCK)` feature-major slice.
    #[inline]
    pub fn panel(&self, p: usize) -> &[f32] {
        &self.data[p * self.d * B_BLOCK..(p + 1) * self.d * B_BLOCK]
    }
}

/// Exact kNN of `queries` against a packed base. Parallel over query
/// blocks; each worker streams every panel through the rank-1-update
/// kernel, amortising each panel load across its live queries.
pub fn knn_blocked(
    base: &PackedBase,
    b_norms: &[f32],
    queries: &[f32],
    q_n: usize,
    q_norms: &[f32],
    k: usize,
    exclude_self_index: bool,
) -> KnnGraph {
    let (base_n, d) = (base.n, base.d);
    let npan = PackedBase::panels(base_n);
    let kern = simd::kernels();
    let mut g = KnnGraph::new(q_n, k);
    {
        let rows = parallel::SyncSlice::new(&mut g.idx);
        let dists = parallel::SyncSlice::new(&mut g.d2);
        parallel::par_chunks(q_n, Q_BLOCK, |range| {
            let mut best: Vec<KBest> = range.clone().map(|_| KBest::new(k)).collect();
            let mut acc = [0.0f32; B_BLOCK];
            for p in 0..npan {
                let b0 = p * B_BLOCK;
                let blen = B_BLOCK.min(base_n - b0);
                let panel = base.panel(p);
                for (qi, kb) in best.iter_mut().enumerate() {
                    let i = range.start + qi;
                    let q = &queries[i * d..(i + 1) * d];
                    // Rank-1 update: acc[bj] = ⟨q, base_row(b0+bj)⟩.
                    acc.fill(0.0);
                    for (t, &qv) in q.iter().enumerate() {
                        let row = &panel[t * B_BLOCK..(t + 1) * B_BLOCK];
                        (kern.rank1_update)(&mut acc, row, qv);
                    }
                    let qn = q_norms[i];
                    for (bj, &s) in acc.iter().enumerate().take(blen) {
                        let j = b0 + bj;
                        if exclude_self_index && j == i {
                            continue;
                        }
                        let d2 = (qn + b_norms[j] - 2.0 * s).max(0.0);
                        if d2 < kb.bound() {
                            kb.push(d2, j as u32);
                        }
                    }
                }
            }
            for (qi, kb) in best.into_iter().enumerate() {
                let i = range.start + qi;
                for (slot, (dv, id)) in kb.into_sorted().into_iter().enumerate() {
                    unsafe {
                        *rows.get_mut(i * k + slot) = id;
                        *dists.get_mut(i * k + slot) = dv;
                    }
                }
            }
        });
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random(n: usize, d: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n * d).map(|_| rng.gauss_f32(0.0, 1.0)).collect()
    }

    #[test]
    fn dot_matches_naive() {
        let a = random(1, 13, 1);
        let b = random(1, 13, 2);
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-4);
    }

    #[test]
    fn norms_match_dist_to_origin() {
        let x = random(7, 5, 3);
        let norms = row_sq_norms(&x, 7, 5);
        for i in 0..7 {
            let row = &x[i * 5..(i + 1) * 5];
            let naive: f32 = row.iter().map(|v| v * v).sum();
            assert!((norms[i] - naive).abs() < 1e-4);
        }
    }

    #[test]
    fn packing_roundtrips_every_row() {
        let (n, d) = (300, 19); // crosses a panel boundary, odd d
        let x = random(n, d, 4);
        let packed = PackedBase::pack(&x, n, d);
        for i in 0..n {
            let (p, bj) = (i / B_BLOCK, i % B_BLOCK);
            let panel = packed.panel(p);
            for t in 0..d {
                assert_eq!(panel[t * B_BLOCK + bj], x[i * d + t], "({i},{t})");
            }
        }
        // Padding rows are zero.
        let last = packed.panel(PackedBase::panels(n) - 1);
        for t in 0..d {
            for bj in (n % B_BLOCK)..B_BLOCK {
                assert_eq!(last[t * B_BLOCK + bj], 0.0);
            }
        }
    }

    #[test]
    fn blocked_knn_matches_scalar_dist2() {
        let (n, d) = (333, 21); // not multiples of the block sizes
        let x = random(n, d, 5);
        let norms = row_sq_norms(&x, n, d);
        let packed = PackedBase::pack(&x, n, d);
        let k = 7;
        let g = knn_blocked(&packed, &norms, &x, n, &norms, k, true);
        for i in (0..n).step_by(13) {
            // Oracle: scalar dist2 full sort.
            let q = &x[i * d..(i + 1) * d];
            let mut want: Vec<(f32, u32)> = (0..n)
                .filter(|&j| j != i)
                .map(|j| (super::super::dist2(q, &x[j * d..(j + 1) * d]), j as u32))
                .collect();
            want.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
            for slot in 0..k {
                assert_eq!(g.row_idx(i)[slot], want[slot].1, "row {i} slot {slot}");
                assert!((g.row_d2(i)[slot] - want[slot].0).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn scan_candidates_agrees_with_factorised_oracle() {
        let (n, d) = (23, 9);
        let x = random(n, d, 8);
        let norms = row_sq_norms(&x, n, d);
        let q = &x[0..d];
        let cand: Vec<u32> = (1..n as u32).collect();
        let mut kb = KBest::new(5);
        scan_candidates(q, norms[0], &x, d, &norms, &cand, &mut kb);
        let sorted = kb.into_sorted();
        let mut want: Vec<(f32, u32)> = cand
            .iter()
            .map(|&j| {
                let ji = j as usize;
                let s = dot(q, &x[ji * d..(ji + 1) * d]);
                ((norms[0] + norms[ji] - 2.0 * s).max(0.0), j)
            })
            .collect();
        want.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
        for (g, w) in sorted.iter().zip(&want) {
            assert_eq!(g.1, w.1);
            assert!((g.0 - w.0).abs() < 1e-6);
        }
    }
}
