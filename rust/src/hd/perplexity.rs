//! Perplexity calibration (Eq. 3–4) and the joint probability matrix P
//! (Eq. 2) — the similarity stage of every t-SNE variant (DESIGN.md S9).
//!
//! For each point a binary search finds the Gaussian bandwidth β_i =
//! 1/(2σ_i²) whose conditional distribution over the k nearest neighbours
//! has the requested perplexity; the conditional matrix is then
//! symmetrised and normalised into a joint P with Σ p_ij = 1.
//!
//! [`joint_p`] fuses the three steps — calibration, symmetrisation,
//! global normalisation — into one chunk-parallel pipeline with
//! deterministic chunk-indexed partials (the discipline of
//! `embed::common::GdState::fused_step`): no intermediate transpose CSR,
//! no per-row linear-search merging, one output allocation sized exactly.
//! The seed's transpose-and-merge construction survives as
//! [`joint_p_reference`], the oracle the property tests compare against.

use super::knn::KnnGraph;
use super::sparse::Csr;
use crate::util::parallel;

/// Binary-search tolerance on log2(perplexity).
const LOG_PERP_TOL: f64 = 1e-5;
const MAX_BISECT: usize = 200;

/// The symmetric joint probability matrix P, normalised to Σ = 1.
#[derive(Debug, Clone)]
pub struct SparseP {
    pub csr: Csr,
    pub perplexity: f32,
}

/// Calibrate β for one row of squared distances so the conditional
/// distribution's perplexity matches. Returns (β, conditional probs).
pub fn calibrate_row(d2: &[f32], perplexity: f64) -> (f64, Vec<f32>) {
    let target_entropy = perplexity.ln(); // nats
    let mut beta = 1.0f64;
    let (mut beta_min, mut beta_max) = (f64::NEG_INFINITY, f64::INFINITY);
    let mut probs = vec![0.0f32; d2.len()];
    if d2.is_empty() {
        return (beta, probs);
    }
    // Shift distances for numerical stability: exp(-β (d² - d²_min)).
    let dmin = d2.iter().cloned().fold(f32::INFINITY, f32::min) as f64;
    let dmax = d2.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    // Degenerate row: all distances (numerically) equal. The entropy is
    // the constant ln(k) for every β, so the bisection below would run
    // all MAX_BISECT iterations doubling β toward overflow without ever
    // moving the entropy. The uniform distribution is the exact answer.
    if dmax - dmin <= 1e-12 * dmax.abs().max(1.0) {
        probs.fill(1.0 / d2.len() as f32);
        return (beta, probs);
    }
    for _ in 0..MAX_BISECT {
        let mut sum = 0.0f64;
        let mut sum_dp = 0.0f64;
        for (j, &d) in d2.iter().enumerate() {
            let e = (-(beta) * (d as f64 - dmin)).exp();
            probs[j] = e as f32;
            sum += e;
            sum_dp += e * (d as f64 - dmin);
        }
        // Entropy H = ln(sum) + β * E[d²].
        let entropy = if sum > 0.0 { sum.ln() + beta * sum_dp / sum } else { 0.0 };
        let diff = entropy - target_entropy;
        if diff.abs() < LOG_PERP_TOL {
            break;
        }
        if diff > 0.0 {
            beta_min = beta;
            beta = if beta_max.is_infinite() { beta * 2.0 } else { 0.5 * (beta + beta_max) };
        } else {
            beta_max = beta;
            beta = if beta_min.is_infinite() { beta * 0.5 } else { 0.5 * (beta + beta_min) };
        }
    }
    let sum: f64 = probs.iter().map(|&p| p as f64).sum();
    let inv = if sum > 0.0 { (1.0 / sum) as f32 } else { 0.0 };
    for p in probs.iter_mut() {
        *p *= inv;
    }
    (beta, probs)
}

/// Conditional probabilities p_{j|i} over each point's kNN (Eq. 3–4).
pub fn conditional_p(knn: &KnnGraph, perplexity: f32) -> Csr {
    let (n, k) = (knn.n, knn.k);
    assert!(
        k as f32 >= perplexity,
        "need k >= perplexity (k={k}, mu={perplexity}); BH-SNE uses k = 3*mu"
    );
    let mut val = vec![0.0f32; n * k];
    {
        let slots = parallel::SyncSlice::new(&mut val);
        parallel::par_chunks(n, 32, |range| {
            for i in range {
                let (_beta, probs) = calibrate_row(knn.row_d2(i), perplexity as f64);
                for (j, p) in probs.into_iter().enumerate() {
                    unsafe {
                        *slots.get_mut(i * k + j) = p;
                    }
                }
            }
        });
    }
    Csr::from_rows(n, n, k, knn.idx.iter().copied().collect(), val)
}

/// Sum of the column-sorted conditional row `j`'s entries at column `c`
/// (0.0 when absent; padded duplicate edges sum, as the reference path
/// merges them).
#[inline]
fn cond_at(fcol: &[u32], fval: &[f32], j: usize, k: usize, c: u32) -> f32 {
    let row = &fcol[j * k..(j + 1) * k];
    let vals = &fval[j * k..(j + 1) * k];
    let mut t = row.partition_point(|&x| x < c);
    let mut s = 0.0f32;
    while t < k && row[t] == c {
        s += vals[t];
        t += 1;
    }
    s
}

/// Joint P (Eq. 2), fused: calibration, symmetrisation and global
/// normalisation in one chunk-parallel pipeline.
///
/// 1. **Calibrate** (parallel): each row's bisection, then the row's
///    `(column, p_{j|i})` pairs sorted by column into two flat `(n, k)`
///    arrays — the column-sorted conditional matrix.
/// 2. **Reverse offsets** (one O(N·k) counting pass): for every point,
///    which rows point at it. Only *sources* are recorded (stable
///    counting order keeps them sorted); values are read back from the
///    sorted forward rows by binary search — no transposed value array.
/// 3. **Merge** (parallel): output row i is the sorted two-pointer union
///    of the forward row and its reverse sources with
///    `p_ij = (p_{j|i} + p_{i|j})/2`, written straight into one exactly
///    sized output allocation; per-chunk f64 partial sums combined in
///    chunk order give the deterministic global total for the final
///    parallel Σ p_ij = 1 scaling.
pub fn joint_p(knn: &KnnGraph, perplexity: f32) -> SparseP {
    let (n, k) = (knn.n, knn.k);
    assert!(
        k as f32 >= perplexity,
        "need k >= perplexity (k={k}, mu={perplexity}); BH-SNE uses k = 3*mu"
    );
    // --- Pass 1: calibrate + column-sort each conditional row.
    let mut fcol = vec![0u32; n * k];
    let mut fval = vec![0.0f32; n * k];
    {
        let cs = parallel::SyncSlice::new(&mut fcol);
        let vs = parallel::SyncSlice::new(&mut fval);
        parallel::par_chunks(n, 32, |range| {
            let mut pairs: Vec<(u32, f32)> = Vec::with_capacity(k);
            for i in range {
                let (_beta, probs) = calibrate_row(knn.row_d2(i), perplexity as f64);
                pairs.clear();
                pairs.extend(knn.row_idx(i).iter().copied().zip(probs));
                pairs.sort_unstable_by_key(|e| e.0);
                for (slot, (c, v)) in pairs.iter().enumerate() {
                    unsafe {
                        *cs.get_mut(i * k + slot) = *c;
                        *vs.get_mut(i * k + slot) = *v;
                    }
                }
            }
        });
    }
    // --- Pass 2: reverse-edge offsets (counting sort over columns;
    // iterating sources in ascending order keeps each reverse row sorted).
    let mut rptr = vec![0usize; n + 1];
    for &c in &fcol {
        rptr[c as usize + 1] += 1;
    }
    for i in 0..n {
        rptr[i + 1] += rptr[i];
    }
    let mut rsrc = vec![0u32; n * k];
    {
        let mut cursor = rptr.clone();
        for i in 0..n {
            for &c in &fcol[i * k..(i + 1) * k] {
                rsrc[cursor[c as usize]] = i as u32;
                cursor[c as usize] += 1;
            }
        }
    }
    // --- Pass 3a: output row lengths (distinct columns in the union).
    let mut row_ptr = vec![0usize; n + 1];
    {
        let lens = parallel::SyncSlice::new(&mut row_ptr);
        parallel::par_chunks(n, 64, |range| {
            for i in range {
                let fwd = &fcol[i * k..(i + 1) * k];
                let rev = &rsrc[rptr[i]..rptr[i + 1]];
                let (mut a, mut b, mut len) = (0usize, 0usize, 0usize);
                while a < fwd.len() || b < rev.len() {
                    let ca = if a < fwd.len() { fwd[a] } else { u32::MAX };
                    let cb = if b < rev.len() { rev[b] } else { u32::MAX };
                    let c = ca.min(cb);
                    while a < fwd.len() && fwd[a] == c {
                        a += 1;
                    }
                    while b < rev.len() && rev[b] == c {
                        b += 1;
                    }
                    len += 1;
                }
                unsafe {
                    *lens.get_mut(i + 1) = len;
                }
            }
        });
    }
    for i in 0..n {
        row_ptr[i + 1] += row_ptr[i];
    }
    let nnz = row_ptr[n];
    // --- Pass 3b: merge-fill the single output allocation; chunk-indexed
    // f64 partials give a deterministic global sum.
    const CHUNK: usize = 64;
    let nchunks = n.div_ceil(CHUNK).max(1);
    let mut col = vec![0u32; nnz];
    let mut val = vec![0.0f32; nnz];
    let mut partials = vec![0.0f64; nchunks];
    {
        let ocs = parallel::SyncSlice::new(&mut col);
        let ovs = parallel::SyncSlice::new(&mut val);
        let parts = parallel::SyncSlice::new(&mut partials);
        parallel::par_chunks(n, CHUNK, |range| {
            let ci = range.start / CHUNK;
            let mut local_sum = 0.0f64;
            for i in range {
                let fwd_cols = &fcol[i * k..(i + 1) * k];
                let fwd_vals = &fval[i * k..(i + 1) * k];
                let rev = &rsrc[rptr[i]..rptr[i + 1]];
                let mut out = row_ptr[i];
                let (mut a, mut b) = (0usize, 0usize);
                while a < fwd_cols.len() || b < rev.len() {
                    let ca = if a < fwd_cols.len() { fwd_cols[a] } else { u32::MAX };
                    let cb = if b < rev.len() { rev[b] } else { u32::MAX };
                    let c = ca.min(cb);
                    let mut v = 0.0f32;
                    // Forward contribution: Σ p_{c|i} over duplicate slots.
                    while a < fwd_cols.len() && fwd_cols[a] == c {
                        v += 0.5 * fwd_vals[a];
                        a += 1;
                    }
                    // Reverse contribution: p_{i|c} looked up in row c
                    // (the lookup already sums duplicate edges, so the
                    // run of equal sources advances without re-adding).
                    if b < rev.len() && rev[b] == c {
                        v += 0.5 * cond_at(&fcol, &fval, c as usize, k, i as u32);
                        while b < rev.len() && rev[b] == c {
                            b += 1;
                        }
                    }
                    unsafe {
                        *ocs.get_mut(out) = c;
                        *ovs.get_mut(out) = v;
                    }
                    local_sum += v as f64;
                    out += 1;
                }
                debug_assert_eq!(out, row_ptr[i + 1]);
            }
            unsafe {
                *parts.get_mut(ci) = local_sum;
            }
        });
    }
    let total: f64 = partials.iter().sum();
    if total > 0.0 {
        let s = (1.0 / total) as f32;
        let vs = parallel::SyncSlice::new(&mut val);
        parallel::par_chunks(nnz, 4096, |range| {
            for i in range {
                unsafe {
                    *vs.get_mut(i) *= s;
                }
            }
        });
    }
    let csr = Csr { n_rows: n, n_cols: n, row_ptr, col, val };
    SparseP { csr, perplexity }
}

/// The seed construction — conditional CSR, explicit transpose,
/// per-row merge, then a global scale — kept as the equivalence oracle
/// for [`joint_p`] (property tests and the `similarities` bench).
pub fn joint_p_reference(knn: &KnnGraph, perplexity: f32) -> SparseP {
    let cond = conditional_p(knn, perplexity);
    let mut sym = cond.symmetrize_mean();
    let total = sym.sum();
    if total > 0.0 {
        sym.scale((1.0 / total) as f32);
    }
    SparseP { csr: sym, perplexity }
}

impl SparseP {
    pub fn n(&self) -> usize {
        self.csr.n_rows
    }

    /// Pad into the fixed-width `(n_pad, k_pad)` neighbour-list layout the
    /// AOT artifacts consume. Rows longer than `k_pad` keep their `k_pad`
    /// largest-probability entries (renormalised globally afterwards);
    /// padded slots have index 0 and probability exactly 0.
    pub fn to_padded(&self, n_pad: usize, k_pad: usize) -> (Vec<i32>, Vec<f32>) {
        assert!(n_pad >= self.n());
        let mut idx = vec![0i32; n_pad * k_pad];
        let mut val = vec![0.0f32; n_pad * k_pad];
        let mut dropped = 0.0f64;
        for i in 0..self.n() {
            let (cs, vs) = self.csr.row(i);
            if cs.len() <= k_pad {
                for (slot, (c, v)) in cs.iter().zip(vs).enumerate() {
                    idx[i * k_pad + slot] = *c as i32;
                    val[i * k_pad + slot] = *v;
                }
            } else {
                let mut order: Vec<usize> = (0..cs.len()).collect();
                order.sort_by(|&a, &b| vs[b].partial_cmp(&vs[a]).unwrap());
                for (slot, &o) in order[..k_pad].iter().enumerate() {
                    idx[i * k_pad + slot] = cs[o] as i32;
                    val[i * k_pad + slot] = vs[o];
                }
                dropped += order[k_pad..].iter().map(|&o| vs[o] as f64).sum::<f64>();
            }
        }
        if dropped > 0.0 {
            // Renormalise so the kept mass still sums to 1.
            let keep = 1.0 - dropped;
            if keep > 0.0 {
                let s = (1.0 / keep) as f32;
                for v in val.iter_mut() {
                    *v *= s;
                }
            }
        }
        (idx, val)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hd::{bruteforce, dataset::Dataset};
    use crate::util::rng::Rng;

    fn toy_graph() -> KnnGraph {
        let mut rng = Rng::new(4);
        let n = 120;
        let x: Vec<f32> = (0..n * 6).map(|_| rng.gauss_f32(0.0, 1.0)).collect();
        let data = Dataset::new("t", n, 6, x, vec![]);
        bruteforce::knn(&data, 24)
    }

    #[test]
    fn calibration_hits_target_perplexity() {
        let g = toy_graph();
        for i in [0usize, 7, 63] {
            let (_beta, probs) = calibrate_row(g.row_d2(i), 8.0);
            let sum: f64 = probs.iter().map(|&p| p as f64).sum();
            assert!((sum - 1.0).abs() < 1e-5, "row must normalise, got {sum}");
            let entropy: f64 = probs
                .iter()
                .filter(|&&p| p > 0.0)
                .map(|&p| -(p as f64) * (p as f64).ln())
                .sum();
            let perp = entropy.exp();
            assert!((perp - 8.0).abs() < 0.05, "perplexity {perp} != 8");
        }
    }

    #[test]
    fn closer_neighbours_get_larger_p() {
        let g = toy_graph();
        let (_b, probs) = calibrate_row(g.row_d2(3), 8.0);
        // d2 rows are sorted ascending => probs must be non-increasing.
        for w in probs.windows(2) {
            assert!(w[0] >= w[1] - 1e-7);
        }
    }

    #[test]
    fn degenerate_equal_distance_row_is_uniform() {
        // All distances identical: entropy is ln(k) for every β, so the
        // bisection can never converge — the fix must return the uniform
        // distribution immediately (and not after 200 doubling steps).
        let d2 = vec![2.5f32; 12];
        let (beta, probs) = calibrate_row(&d2, 5.0);
        assert_eq!(beta, 1.0, "β must be left at its initial value");
        for &p in &probs {
            assert!((p - 1.0 / 12.0).abs() < 1e-7, "uniform probs, got {p}");
        }
        // Zero-distance degenerate rows (duplicated points) too.
        let d2 = vec![0.0f32; 7];
        let (_beta, probs) = calibrate_row(&d2, 3.0);
        for &p in &probs {
            assert!((p - 1.0 / 7.0).abs() < 1e-7);
        }
    }

    #[test]
    fn joint_p_is_normalised_and_symmetric() {
        let g = toy_graph();
        let p = joint_p(&g, 8.0);
        assert!((p.csr.sum() - 1.0).abs() < 1e-5);
        let get = |i: usize, j: usize| -> f32 {
            let (cs, vs) = p.csr.row(i);
            cs.iter().zip(vs).find(|(c, _)| **c == j as u32).map(|(_, v)| *v).unwrap_or(0.0)
        };
        for i in (0..p.n()).step_by(17) {
            let (cs, _) = p.csr.row(i);
            for &j in cs.iter().take(5) {
                assert!(
                    (get(i, j as usize) - get(j as usize, i)).abs() < 1e-7,
                    "P must be symmetric at ({i},{j})"
                );
            }
        }
    }

    #[test]
    fn fused_matches_reference_exactly() {
        let g = toy_graph();
        let fused = joint_p(&g, 8.0);
        let refp = joint_p_reference(&g, 8.0);
        assert_eq!(fused.csr.row_ptr, refp.csr.row_ptr, "identical sparsity structure");
        assert_eq!(fused.csr.col, refp.csr.col, "identical column order");
        for (a, b) in fused.csr.val.iter().zip(&refp.csr.val) {
            assert!((a - b).abs() < 1e-6, "fused {a} vs reference {b}");
        }
    }

    #[test]
    fn fused_matches_reference_with_padded_duplicate_rows() {
        // Under-full padded rows (duplicate neighbour entries) are the
        // nasty case: duplicates must merge identically on both paths.
        let mut g = KnnGraph::new(4, 3);
        g.idx = vec![
            1, 2, 2, // row 0: duplicate neighbour 2
            0, 3, 3, // row 1: duplicate neighbour 3
            0, 1, 3, //
            2, 0, 0, // row 3: duplicate neighbour 0
        ];
        g.d2 = vec![
            1.0, 2.0, 2.0, //
            1.0, 3.0, 3.0, //
            2.0, 4.0, 5.0, //
            5.0, 6.0, 6.0, //
        ];
        let fused = joint_p(&g, 2.0);
        let refp = joint_p_reference(&g, 2.0);
        assert_eq!(fused.csr.row_ptr, refp.csr.row_ptr);
        assert_eq!(fused.csr.col, refp.csr.col);
        for (a, b) in fused.csr.val.iter().zip(&refp.csr.val) {
            assert!((a - b).abs() < 1e-6, "fused {a} vs reference {b}");
        }
        assert!((fused.csr.sum() - 1.0).abs() < 1e-5);
    }

    #[test]
    fn padded_layout_roundtrip() {
        let g = toy_graph();
        let p = joint_p(&g, 8.0);
        let kmax = p.csr.max_row_len();
        let (idx, val) = p.to_padded(256, kmax + 4);
        assert_eq!(idx.len(), 256 * (kmax + 4));
        let total: f64 = val.iter().map(|&v| v as f64).sum();
        assert!((total - 1.0).abs() < 1e-4, "padded mass {total}");
        // Rows beyond n are all-zero.
        for i in p.n()..256 {
            assert!(val[i * (kmax + 4)..(i + 1) * (kmax + 4)].iter().all(|&v| v == 0.0));
        }
    }

    #[test]
    fn padded_truncation_keeps_biggest_and_renormalises() {
        let g = toy_graph();
        let p = joint_p(&g, 8.0);
        let (_, val) = p.to_padded(128, 8); // force truncation
        let total: f64 = val.iter().map(|&v| v as f64).sum();
        assert!((total - 1.0).abs() < 1e-3, "renormalised mass {total}");
    }
}
