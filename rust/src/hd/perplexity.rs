//! Perplexity calibration (Eq. 3–4) and the joint probability matrix P
//! (Eq. 2) — the similarity stage of every t-SNE variant (DESIGN.md S9).
//!
//! For each point a binary search finds the Gaussian bandwidth β_i =
//! 1/(2σ_i²) whose conditional distribution over the k nearest neighbours
//! has the requested perplexity; the conditional matrix is then
//! symmetrised and normalised into a joint P with Σ p_ij = 1.

use super::knn::KnnGraph;
use super::sparse::Csr;
use crate::util::parallel;

/// Binary-search tolerance on log2(perplexity).
const LOG_PERP_TOL: f64 = 1e-5;
const MAX_BISECT: usize = 200;

/// The symmetric joint probability matrix P, normalised to Σ = 1.
#[derive(Debug, Clone)]
pub struct SparseP {
    pub csr: Csr,
    pub perplexity: f32,
}

/// Calibrate β for one row of squared distances so the conditional
/// distribution's perplexity matches. Returns (β, conditional probs).
pub fn calibrate_row(d2: &[f32], perplexity: f64) -> (f64, Vec<f32>) {
    let target_entropy = perplexity.ln(); // nats
    let mut beta = 1.0f64;
    let (mut beta_min, mut beta_max) = (f64::NEG_INFINITY, f64::INFINITY);
    let mut probs = vec![0.0f32; d2.len()];
    // Shift distances for numerical stability: exp(-β (d² - d²_min)).
    let dmin = d2.iter().cloned().fold(f32::INFINITY, f32::min) as f64;
    for _ in 0..MAX_BISECT {
        let mut sum = 0.0f64;
        let mut sum_dp = 0.0f64;
        for (j, &d) in d2.iter().enumerate() {
            let e = (-(beta) * (d as f64 - dmin)).exp();
            probs[j] = e as f32;
            sum += e;
            sum_dp += e * (d as f64 - dmin);
        }
        // Entropy H = ln(sum) + β * E[d²].
        let entropy = if sum > 0.0 { sum.ln() + beta * sum_dp / sum } else { 0.0 };
        let diff = entropy - target_entropy;
        if diff.abs() < LOG_PERP_TOL {
            break;
        }
        if diff > 0.0 {
            beta_min = beta;
            beta = if beta_max.is_infinite() { beta * 2.0 } else { 0.5 * (beta + beta_max) };
        } else {
            beta_max = beta;
            beta = if beta_min.is_infinite() { beta * 0.5 } else { 0.5 * (beta + beta_min) };
        }
    }
    let sum: f64 = probs.iter().map(|&p| p as f64).sum();
    let inv = if sum > 0.0 { (1.0 / sum) as f32 } else { 0.0 };
    for p in probs.iter_mut() {
        *p *= inv;
    }
    (beta, probs)
}

/// Conditional probabilities p_{j|i} over each point's kNN (Eq. 3–4).
pub fn conditional_p(knn: &KnnGraph, perplexity: f32) -> Csr {
    let (n, k) = (knn.n, knn.k);
    assert!(
        k as f32 >= perplexity,
        "need k >= perplexity (k={k}, mu={perplexity}); BH-SNE uses k = 3*mu"
    );
    let mut val = vec![0.0f32; n * k];
    {
        let slots = parallel::SyncSlice::new(&mut val);
        parallel::par_chunks(n, 32, |range| {
            for i in range {
                let (_beta, probs) = calibrate_row(knn.row_d2(i), perplexity as f64);
                for (j, p) in probs.into_iter().enumerate() {
                    unsafe {
                        *slots.get_mut(i * k + j) = p;
                    }
                }
            }
        });
    }
    Csr::from_rows(n, n, k, knn.idx.iter().copied().collect(), val)
}

/// Joint P (Eq. 2): symmetrise the conditional matrix and normalise the
/// whole matrix to Σ p_ij = 1 (the 1/N of Eq. 2 followed by the implicit
/// global normalisation t-SNE implementations apply).
pub fn joint_p(knn: &KnnGraph, perplexity: f32) -> SparseP {
    let cond = conditional_p(knn, perplexity);
    let mut sym = cond.symmetrize_mean();
    let total = sym.sum();
    if total > 0.0 {
        sym.scale((1.0 / total) as f32);
    }
    SparseP { csr: sym, perplexity }
}

impl SparseP {
    pub fn n(&self) -> usize {
        self.csr.n_rows
    }

    /// Pad into the fixed-width `(n_pad, k_pad)` neighbour-list layout the
    /// AOT artifacts consume. Rows longer than `k_pad` keep their `k_pad`
    /// largest-probability entries (renormalised globally afterwards);
    /// padded slots have index 0 and probability exactly 0.
    pub fn to_padded(&self, n_pad: usize, k_pad: usize) -> (Vec<i32>, Vec<f32>) {
        assert!(n_pad >= self.n());
        let mut idx = vec![0i32; n_pad * k_pad];
        let mut val = vec![0.0f32; n_pad * k_pad];
        let mut dropped = 0.0f64;
        for i in 0..self.n() {
            let (cs, vs) = self.csr.row(i);
            if cs.len() <= k_pad {
                for (slot, (c, v)) in cs.iter().zip(vs).enumerate() {
                    idx[i * k_pad + slot] = *c as i32;
                    val[i * k_pad + slot] = *v;
                }
            } else {
                let mut order: Vec<usize> = (0..cs.len()).collect();
                order.sort_by(|&a, &b| vs[b].partial_cmp(&vs[a]).unwrap());
                for (slot, &o) in order[..k_pad].iter().enumerate() {
                    idx[i * k_pad + slot] = cs[o] as i32;
                    val[i * k_pad + slot] = vs[o];
                }
                dropped += order[k_pad..].iter().map(|&o| vs[o] as f64).sum::<f64>();
            }
        }
        if dropped > 0.0 {
            // Renormalise so the kept mass still sums to 1.
            let keep = 1.0 - dropped;
            if keep > 0.0 {
                let s = (1.0 / keep) as f32;
                for v in val.iter_mut() {
                    *v *= s;
                }
            }
        }
        (idx, val)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hd::{bruteforce, dataset::Dataset};
    use crate::util::rng::Rng;

    fn toy_graph() -> KnnGraph {
        let mut rng = Rng::new(4);
        let n = 120;
        let x: Vec<f32> = (0..n * 6).map(|_| rng.gauss_f32(0.0, 1.0)).collect();
        let data = Dataset::new("t", n, 6, x, vec![]);
        bruteforce::knn(&data, 24)
    }

    #[test]
    fn calibration_hits_target_perplexity() {
        let g = toy_graph();
        for i in [0usize, 7, 63] {
            let (_beta, probs) = calibrate_row(g.row_d2(i), 8.0);
            let sum: f64 = probs.iter().map(|&p| p as f64).sum();
            assert!((sum - 1.0).abs() < 1e-5, "row must normalise, got {sum}");
            let entropy: f64 = probs
                .iter()
                .filter(|&&p| p > 0.0)
                .map(|&p| -(p as f64) * (p as f64).ln())
                .sum();
            let perp = entropy.exp();
            assert!((perp - 8.0).abs() < 0.05, "perplexity {perp} != 8");
        }
    }

    #[test]
    fn closer_neighbours_get_larger_p() {
        let g = toy_graph();
        let (_b, probs) = calibrate_row(g.row_d2(3), 8.0);
        // d2 rows are sorted ascending => probs must be non-increasing.
        for w in probs.windows(2) {
            assert!(w[0] >= w[1] - 1e-7);
        }
    }

    #[test]
    fn joint_p_is_normalised_and_symmetric() {
        let g = toy_graph();
        let p = joint_p(&g, 8.0);
        assert!((p.csr.sum() - 1.0).abs() < 1e-5);
        let get = |i: usize, j: usize| -> f32 {
            let (cs, vs) = p.csr.row(i);
            cs.iter().zip(vs).find(|(c, _)| **c == j as u32).map(|(_, v)| *v).unwrap_or(0.0)
        };
        for i in (0..p.n()).step_by(17) {
            let (cs, _) = p.csr.row(i);
            for &j in cs.iter().take(5) {
                assert!(
                    (get(i, j as usize) - get(j as usize, i)).abs() < 1e-7,
                    "P must be symmetric at ({i},{j})"
                );
            }
        }
    }

    #[test]
    fn padded_layout_roundtrip() {
        let g = toy_graph();
        let p = joint_p(&g, 8.0);
        let kmax = p.csr.max_row_len();
        let (idx, val) = p.to_padded(256, kmax + 4);
        assert_eq!(idx.len(), 256 * (kmax + 4));
        let total: f64 = val.iter().map(|&v| v as f64).sum();
        assert!((total - 1.0).abs() < 1e-4, "padded mass {total}");
        // Rows beyond n are all-zero.
        for i in p.n()..256 {
            assert!(val[i * (kmax + 4)..(i + 1) * (kmax + 4)].iter().all(|&v| v == 0.0));
        }
    }

    #[test]
    fn padded_truncation_keeps_biggest_and_renormalises() {
        let g = toy_graph();
        let p = joint_p(&g, 8.0);
        let (_, val) = p.to_padded(128, 8); // force truncation
        let total: f64 = val.iter().map(|&v| v as f64).sum();
        assert!((total - 1.0).abs() < 1e-3, "renormalised mass {total}");
    }
}
