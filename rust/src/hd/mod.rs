//! High-dimensional substrate: datasets, distances, kNN (exact and
//! approximate), perplexity calibration and the sparse joint-probability
//! matrix P — everything upstream of the embedding optimisers.
//!
//! The paper treats similarity computation as prior work (§5.1.1: "We use
//! existing techniques here"); those existing techniques are nonetheless
//! substrates this repo must provide (DESIGN.md S6–S10): exact brute-force
//! kNN, the VP-tree used by BH-SNE [45], and the randomised KD-forest used
//! by A-tSNE / as a FAISS stand-in [29].
//!
//! # The similarity pipeline
//!
//! Every kNN structure lives behind the pluggable [`backend::KnnBackend`]
//! trait (the similarity-stage mirror of `field::FieldBackend`, with the
//! same `by_name` + registry discipline as `embed::ENGINES`), and all of
//! them score candidates through the *blocked distance kernels* of
//! [`blocked`]: precomputed row norms plus tiled `‖x‖²+‖y‖²−2x·y` panels,
//! so the innermost loop is a dense dot-product micro-kernel instead of a
//! per-pair scalar scan ([`dist2`] remains the scalar oracle). Downstream,
//! [`perplexity::joint_p`] fuses calibration, symmetrisation and global
//! normalisation into one chunk-parallel pass with deterministic
//! chunk-indexed partials (the seed's transpose-and-merge path survives
//! as [`perplexity::joint_p_reference`], the equivalence oracle). The
//! coordinator caches the finished `SparseP` per dataset fingerprint —
//! see `coordinator::simcache`.

pub mod backend;
pub mod blocked;
pub mod bruteforce;
pub mod dataset;
pub mod kdforest;
pub mod knn;
pub mod perplexity;
pub mod sparse;
pub mod vptree;

pub use backend::KnnBackend;
pub use dataset::Dataset;
pub use knn::KnnGraph;
pub use perplexity::SparseP;

/// Squared Euclidean distance between two vectors.
///
/// Manually unrolled 4-wide so LLVM vectorises it. Once the innermost
/// loop of every kNN structure, now the *scalar reference* the blocked
/// panel kernels ([`blocked`]) are validated against; still used where a
/// single pair is genuinely needed.
#[inline]
pub fn dist2(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for c in 0..chunks {
        let i = 4 * c;
        let d0 = a[i] - b[i];
        let d1 = a[i + 1] - b[i + 1];
        let d2 = a[i + 2] - b[i + 2];
        let d3 = a[i + 3] - b[i + 3];
        s0 += d0 * d0;
        s1 += d1 * d1;
        s2 += d2 * d2;
        s3 += d3 * d3;
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for i in 4 * chunks..n {
        let d = a[i] - b[i];
        s += d * d;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dist2_matches_naive() {
        let a: Vec<f32> = (0..13).map(|i| i as f32 * 0.5).collect();
        let b: Vec<f32> = (0..13).map(|i| 6.0 - i as f32).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| (x - y) * (x - y)).sum();
        assert!((dist2(&a, &b) - naive).abs() < 1e-4);
    }

    #[test]
    fn dist2_zero_on_identical() {
        let a = vec![1.5f32; 97];
        assert_eq!(dist2(&a, &a), 0.0);
    }
}
