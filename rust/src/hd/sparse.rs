//! CSR sparse matrix — storage for the conditional and joint probability
//! matrices (DESIGN.md S10).

/// Compressed sparse row matrix of f32.
#[derive(Debug, Clone, PartialEq)]
pub struct Csr {
    pub n_rows: usize,
    pub n_cols: usize,
    /// Length n_rows + 1.
    pub row_ptr: Vec<usize>,
    pub col: Vec<u32>,
    pub val: Vec<f32>,
}

impl Csr {
    /// Build from uniform-width rows (`k` entries each).
    pub fn from_rows(n_rows: usize, n_cols: usize, k: usize, col: Vec<u32>, val: Vec<f32>) -> Self {
        assert_eq!(col.len(), n_rows * k);
        assert_eq!(val.len(), n_rows * k);
        let row_ptr = (0..=n_rows).map(|i| i * k).collect();
        Self { n_rows, n_cols, row_ptr, col, val }
    }

    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f32]) {
        let (a, b) = (self.row_ptr[i], self.row_ptr[i + 1]);
        (&self.col[a..b], &self.val[a..b])
    }

    pub fn nnz(&self) -> usize {
        self.val.len()
    }

    pub fn sum(&self) -> f64 {
        self.val.iter().map(|&v| v as f64).sum()
    }

    /// Scale all values in place.
    pub fn scale(&mut self, s: f32) {
        for v in self.val.iter_mut() {
            *v *= s;
        }
    }

    /// Transpose (O(nnz)).
    pub fn transpose(&self) -> Csr {
        let mut counts = vec![0usize; self.n_cols + 1];
        for &c in &self.col {
            counts[c as usize + 1] += 1;
        }
        for i in 0..self.n_cols {
            counts[i + 1] += counts[i];
        }
        let row_ptr = counts.clone();
        let mut col = vec![0u32; self.nnz()];
        let mut val = vec![0.0f32; self.nnz()];
        let mut cursor = counts;
        for r in 0..self.n_rows {
            let (cs, vs) = self.row(r);
            for (c, v) in cs.iter().zip(vs) {
                let slot = cursor[*c as usize];
                col[slot] = r as u32;
                val[slot] = *v;
                cursor[*c as usize] += 1;
            }
        }
        Csr { n_rows: self.n_cols, n_cols: self.n_rows, row_ptr, col, val }
    }

    /// Symmetric average: `(A + Aᵀ) / 2`, merging duplicate coordinates.
    /// This is exactly the t-SNE symmetrisation of Eq. 2 before the global
    /// 1/N normalisation.
    pub fn symmetrize_mean(&self) -> Csr {
        assert_eq!(self.n_rows, self.n_cols);
        let t = self.transpose();
        let mut row_ptr = vec![0usize; self.n_rows + 1];
        let mut col = Vec::with_capacity(self.nnz() * 2);
        let mut val = Vec::with_capacity(self.nnz() * 2);
        for r in 0..self.n_rows {
            // Merge the two sorted-by-col rows? Rows are not sorted; use a
            // small map per row (rows are k-sized, k ~ 100).
            let mut entries: Vec<(u32, f32)> = Vec::new();
            let push = |entries: &mut Vec<(u32, f32)>, c: u32, v: f32| {
                if let Some(e) = entries.iter_mut().find(|e| e.0 == c) {
                    e.1 += v;
                } else {
                    entries.push((c, v));
                }
            };
            let (cs, vs) = self.row(r);
            for (c, v) in cs.iter().zip(vs) {
                push(&mut entries, *c, 0.5 * *v);
            }
            let (cs, vs) = t.row(r);
            for (c, v) in cs.iter().zip(vs) {
                push(&mut entries, *c, 0.5 * *v);
            }
            entries.sort_unstable_by_key(|e| e.0);
            for (c, v) in entries {
                col.push(c);
                val.push(v);
            }
            row_ptr[r + 1] = col.len();
        }
        Csr { n_rows: self.n_rows, n_cols: self.n_cols, row_ptr, col, val }
    }

    /// Maximum row length.
    pub fn max_row_len(&self) -> usize {
        (0..self.n_rows).map(|i| self.row_ptr[i + 1] - self.row_ptr[i]).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Csr {
        // [[0, 1, 0], [2, 0, 3], [0, 0, 4]]
        Csr {
            n_rows: 3,
            n_cols: 3,
            row_ptr: vec![0, 1, 3, 4],
            col: vec![1, 0, 2, 2],
            val: vec![1.0, 2.0, 3.0, 4.0],
        }
    }

    #[test]
    fn transpose_roundtrip() {
        let a = small();
        let tt = a.transpose().transpose();
        // Same matrix content (rows come out sorted by construction).
        for r in 0..3 {
            let (c1, v1) = a.row(r);
            let mut z1: Vec<_> = c1.iter().zip(v1).collect();
            z1.sort_by_key(|(c, _)| **c);
            let (c2, v2) = tt.row(r);
            let z2: Vec<_> = c2.iter().zip(v2).collect();
            assert_eq!(z1, z2);
        }
    }

    #[test]
    fn symmetrize_is_symmetric_and_preserves_sum() {
        let a = small();
        let s = a.symmetrize_mean();
        assert!((s.sum() - a.sum()).abs() < 1e-6);
        // Check s[i][j] == s[j][i].
        let get = |m: &Csr, i: usize, j: usize| -> f32 {
            let (cs, vs) = m.row(i);
            cs.iter().zip(vs).find(|(c, _)| **c == j as u32).map(|(_, v)| *v).unwrap_or(0.0)
        };
        for i in 0..3 {
            for j in 0..3 {
                assert!((get(&s, i, j) - get(&s, j, i)).abs() < 1e-6);
            }
        }
        assert!((get(&s, 0, 1) - 1.5).abs() < 1e-6); // (1 + 2)/2
    }

    #[test]
    fn from_rows_uniform() {
        let c = Csr::from_rows(2, 4, 2, vec![0, 1, 2, 3], vec![1., 2., 3., 4.]);
        assert_eq!(c.row(1), (&[2u32, 3u32][..], &[3.0f32, 4.0f32][..]));
        assert_eq!(c.max_row_len(), 2);
    }
}
