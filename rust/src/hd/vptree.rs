//! Vantage-Point tree (Yianilos 1993) — the exact metric-tree kNN used by
//! the original BH-SNE pipeline [41, 45] (DESIGN.md S7).
//!
//! Exact nearest-neighbour search with triangle-inequality pruning. As the
//! paper's own prior work observes (A-tSNE [34]), pruning degrades in high
//! dimensions — which is precisely the motivation for the KD-forest
//! (`kdforest.rs`); the benches quantify that crossover.

use super::dataset::Dataset;
use super::knn::{KBest, KnnGraph};
use crate::util::parallel;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
struct Node {
    /// Index of the vantage point (into the dataset).
    vp: u32,
    /// Median distance (not squared) splitting inside/outside.
    radius: f32,
    /// Child node indices (usize::MAX = none).
    inside: u32,
    outside: u32,
}

const NONE: u32 = u32::MAX;

/// An exact VP-tree over a dataset.
pub struct VpTree<'a> {
    data: &'a Dataset,
    nodes: Vec<Node>,
    root: u32,
}

impl<'a> VpTree<'a> {
    /// Build with deterministic vantage-point selection (seeded).
    pub fn build(data: &'a Dataset, seed: u64) -> Self {
        let mut items: Vec<(u32, f32)> = (0..data.n as u32).map(|i| (i, 0.0)).collect();
        let mut nodes = Vec::with_capacity(data.n);
        let mut rng = Rng::new(seed);
        let root = Self::build_rec(data, &mut items[..], &mut nodes, &mut rng);
        Self { data, nodes, root }
    }

    fn build_rec(
        data: &Dataset,
        items: &mut [(u32, f32)],
        nodes: &mut Vec<Node>,
        rng: &mut Rng,
    ) -> u32 {
        if items.is_empty() {
            return NONE;
        }
        // Pick a random vantage point, move it to the front.
        let pick = rng.below(items.len());
        items.swap(0, pick);
        let vp = items[0].0;
        let rest = &mut items[1..];
        if rest.is_empty() {
            let id = nodes.len() as u32;
            nodes.push(Node { vp, radius: 0.0, inside: NONE, outside: NONE });
            return id;
        }
        let vprow = data.row(vp as usize);
        for it in rest.iter_mut() {
            it.1 = super::dist2(vprow, data.row(it.0 as usize)).sqrt();
        }
        // Median split.
        let mid = rest.len() / 2;
        rest.select_nth_unstable_by(mid, |a, b| a.1.partial_cmp(&b.1).unwrap());
        let radius = rest[mid].1;
        let id = nodes.len() as u32;
        nodes.push(Node { vp, radius, inside: NONE, outside: NONE });
        let (ins, outs) = rest.split_at_mut(mid);
        let inside = Self::build_rec(data, ins, nodes, rng);
        let outside = Self::build_rec(data, outs, nodes, rng);
        nodes[id as usize].inside = inside;
        nodes[id as usize].outside = outside;
        id
    }

    /// Exact k nearest neighbours of `query` (optionally excluding one id).
    pub fn knn_query(&self, query: &[f32], k: usize, exclude: Option<u32>) -> Vec<(f32, u32)> {
        let mut kb = KBest::new(k);
        self.search(self.root, query, exclude, &mut kb);
        kb.into_sorted()
    }

    fn search(&self, node: u32, query: &[f32], exclude: Option<u32>, kb: &mut KBest) {
        if node == NONE {
            return;
        }
        let n = &self.nodes[node as usize];
        let d = super::dist2(query, self.data.row(n.vp as usize)).sqrt();
        if Some(n.vp) != exclude {
            let d2 = d * d;
            if d2 < kb.bound() {
                kb.push(d2, n.vp);
            }
        }
        // Search the nearer side first; prune with the triangle inequality.
        let tau = kb.bound().sqrt();
        if d < n.radius {
            self.search(n.inside, query, exclude, kb);
            let tau = kb.bound().sqrt();
            if d + tau >= n.radius {
                self.search(n.outside, query, exclude, kb);
            }
        } else {
            self.search(n.outside, query, exclude, kb);
            let tau = kb.bound().sqrt();
            if d - tau <= n.radius {
                self.search(n.inside, query, exclude, kb);
            }
        }
        let _ = tau;
    }

    /// Full kNN graph (parallel over queries).
    pub fn knn(&self, k: usize) -> KnnGraph {
        let mut g = KnnGraph::new(self.data.n, k);
        {
            let idx = parallel::SyncSlice::new(&mut g.idx);
            let d2 = parallel::SyncSlice::new(&mut g.d2);
            parallel::par_chunks(self.data.n, 16, |range| {
                for i in range {
                    let res = self.knn_query(self.data.row(i), k, Some(i as u32));
                    for (slot, (d, id)) in res.into_iter().enumerate() {
                        unsafe {
                            *idx.get_mut(i * k + slot) = id;
                            *d2.get_mut(i * k + slot) = d;
                        }
                    }
                }
            });
        }
        g
    }

    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hd::bruteforce;

    fn random_dataset(n: usize, d: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let x: Vec<f32> = (0..n * d).map(|_| rng.gauss_f32(0.0, 1.0)).collect();
        Dataset::new("r", n, d, x, vec![])
    }

    #[test]
    fn tree_contains_every_point_once() {
        let data = random_dataset(257, 4, 3);
        let t = VpTree::build(&data, 7);
        assert_eq!(t.node_count(), 257);
        let mut seen = vec![false; 257];
        for n in &t.nodes {
            assert!(!seen[n.vp as usize], "duplicate vantage point");
            seen[n.vp as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn matches_brute_force_exactly() {
        // Exactness invariant: same neighbour sets as brute force (modulo
        // distance ties at f32 precision).
        let data = random_dataset(300, 8, 11);
        let t = VpTree::build(&data, 5);
        let approx = t.knn(5);
        let exact = bruteforce::knn(&data, 5);
        let recall = approx.recall_against(&exact);
        assert!(recall > 0.999, "vp-tree must be exact, recall={recall}");
    }

    #[test]
    fn distances_match_brute_force() {
        let data = random_dataset(150, 6, 2);
        let t = VpTree::build(&data, 1);
        let g = t.knn(3);
        let e = bruteforce::knn(&data, 3);
        for i in 0..data.n {
            for j in 0..3 {
                assert!((g.row_d2(i)[j] - e.row_d2(i)[j]).abs() < 1e-4);
            }
        }
    }
}
