//! Vantage-Point tree (Yianilos 1993) — the exact metric-tree kNN used by
//! the original BH-SNE pipeline [41, 45] (DESIGN.md S7).
//!
//! Exact nearest-neighbour search with triangle-inequality pruning. As the
//! paper's own prior work observes (A-tSNE [34]), pruning degrades in high
//! dimensions — which is precisely the motivation for the KD-forest
//! (`kdforest.rs`); the benches quantify that crossover.
//!
//! Small subtrees collapse into *bucket leaves* scanned with the blocked
//! dot-product kernel (`hd::blocked::scan_candidates` over precomputed row
//! norms): the bottom of the tree — where most of the work is — becomes a
//! dense micro-kernel sweep instead of per-node pointer chasing, and every
//! ball-node distance reuses the same `‖x‖²+‖y‖²−2x·y` factorisation.

use super::blocked;
use super::dataset::Dataset;
use super::knn::{KBest, KnnGraph};
use crate::util::parallel;
use crate::util::rng::Rng;

const NONE: u32 = u32::MAX;
/// Subtrees at or below this size become bucket leaves.
const LEAF_SIZE: usize = 16;

#[derive(Debug, Clone)]
enum Node {
    /// A vantage point with its median ball.
    Ball {
        vp: u32,
        /// Median distance (not squared) splitting inside/outside.
        radius: f32,
        /// Child node indices (NONE = absent).
        inside: u32,
        outside: u32,
    },
    /// A bucket of point ids (`order[start..end]`), scanned densely.
    Leaf { start: u32, end: u32 },
}

/// An exact VP-tree over a dataset.
pub struct VpTree<'a> {
    data: &'a Dataset,
    nodes: Vec<Node>,
    /// Point ids; leaf ranges index into this.
    order: Vec<u32>,
    /// Per-row squared norms (shared by build and every query).
    norms: Vec<f32>,
    root: u32,
}

impl<'a> VpTree<'a> {
    /// Build with deterministic vantage-point selection (seeded).
    pub fn build(data: &'a Dataset, seed: u64) -> Self {
        let norms = blocked::row_sq_norms(&data.x, data.n, data.d);
        let mut items: Vec<(u32, f32)> = (0..data.n as u32).map(|i| (i, 0.0)).collect();
        let mut nodes = Vec::with_capacity(2 * data.n / LEAF_SIZE.max(1) + 1);
        let mut order = Vec::with_capacity(data.n);
        let mut rng = Rng::new(seed);
        let root =
            Self::build_rec(data, &norms, &mut items[..], &mut nodes, &mut order, &mut rng);
        Self { data, nodes, order, norms, root }
    }

    #[inline]
    fn d2(data: &Dataset, norms: &[f32], a: u32, b: u32) -> f32 {
        let (ai, bi) = (a as usize, b as usize);
        (norms[ai] + norms[bi] - 2.0 * blocked::dot(data.row(ai), data.row(bi))).max(0.0)
    }

    fn build_rec(
        data: &Dataset,
        norms: &[f32],
        items: &mut [(u32, f32)],
        nodes: &mut Vec<Node>,
        order: &mut Vec<u32>,
        rng: &mut Rng,
    ) -> u32 {
        if items.is_empty() {
            return NONE;
        }
        if items.len() <= LEAF_SIZE {
            let start = order.len() as u32;
            order.extend(items.iter().map(|it| it.0));
            let id = nodes.len() as u32;
            nodes.push(Node::Leaf { start, end: order.len() as u32 });
            return id;
        }
        // Pick a random vantage point, move it to the front.
        let pick = rng.below(items.len());
        items.swap(0, pick);
        let vp = items[0].0;
        let rest = &mut items[1..];
        for it in rest.iter_mut() {
            it.1 = Self::d2(data, norms, vp, it.0).sqrt();
        }
        // Median split.
        let mid = rest.len() / 2;
        rest.select_nth_unstable_by(mid, |a, b| a.1.partial_cmp(&b.1).unwrap());
        let radius = rest[mid].1;
        let id = nodes.len() as u32;
        nodes.push(Node::Ball { vp, radius, inside: NONE, outside: NONE });
        let (ins, outs) = rest.split_at_mut(mid);
        let inside = Self::build_rec(data, norms, ins, nodes, order, rng);
        let outside = Self::build_rec(data, norms, outs, nodes, order, rng);
        if let Node::Ball { inside: i, outside: o, .. } = &mut nodes[id as usize] {
            *i = inside;
            *o = outside;
        }
        id
    }

    /// Exact k nearest neighbours of `query` (optionally excluding one id).
    pub fn knn_query(&self, query: &[f32], k: usize, exclude: Option<u32>) -> Vec<(f32, u32)> {
        let q_norm = blocked::dot(query, query);
        let mut kb = KBest::new(k);
        let mut scratch: Vec<u32> = Vec::with_capacity(LEAF_SIZE);
        self.search(self.root, query, q_norm, exclude, &mut kb, &mut scratch);
        kb.into_sorted()
    }

    fn search(
        &self,
        node: u32,
        query: &[f32],
        q_norm: f32,
        exclude: Option<u32>,
        kb: &mut KBest,
        scratch: &mut Vec<u32>,
    ) {
        if node == NONE {
            return;
        }
        match &self.nodes[node as usize] {
            Node::Leaf { start, end } => {
                let ids = &self.order[*start as usize..*end as usize];
                if let Some(ex) = exclude {
                    scratch.clear();
                    scratch.extend(ids.iter().copied().filter(|&i| i != ex));
                    blocked::scan_candidates(
                        query, q_norm, &self.data.x, self.data.d, &self.norms, scratch, kb,
                    );
                } else {
                    blocked::scan_candidates(
                        query, q_norm, &self.data.x, self.data.d, &self.norms, ids, kb,
                    );
                }
            }
            Node::Ball { vp, radius, inside, outside } => {
                let vpi = *vp as usize;
                let d2 = (q_norm + self.norms[vpi]
                    - 2.0 * blocked::dot(query, self.data.row(vpi)))
                .max(0.0);
                if Some(*vp) != exclude && d2 < kb.bound() {
                    kb.push(d2, *vp);
                }
                let d = d2.sqrt();
                // Search the nearer side first; prune the other with the
                // triangle inequality.
                if d < *radius {
                    self.search(*inside, query, q_norm, exclude, kb, scratch);
                    if d + kb.bound().sqrt() >= *radius {
                        self.search(*outside, query, q_norm, exclude, kb, scratch);
                    }
                } else {
                    self.search(*outside, query, q_norm, exclude, kb, scratch);
                    if d - kb.bound().sqrt() <= *radius {
                        self.search(*inside, query, q_norm, exclude, kb, scratch);
                    }
                }
            }
        }
    }

    /// Full kNN graph (parallel over queries).
    pub fn knn(&self, k: usize) -> KnnGraph {
        let mut g = KnnGraph::new(self.data.n, k);
        {
            let idx = parallel::SyncSlice::new(&mut g.idx);
            let d2 = parallel::SyncSlice::new(&mut g.d2);
            parallel::par_chunks(self.data.n, 16, |range| {
                for i in range {
                    let res = self.knn_query(self.data.row(i), k, Some(i as u32));
                    for (slot, (d, id)) in res.into_iter().enumerate() {
                        unsafe {
                            *idx.get_mut(i * k + slot) = id;
                            *d2.get_mut(i * k + slot) = d;
                        }
                    }
                }
            });
        }
        g
    }

    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    /// Every point id, exactly once: vantage points plus leaf buckets.
    #[cfg(test)]
    fn all_point_ids(&self) -> Vec<u32> {
        let mut ids: Vec<u32> = self.order.clone();
        for n in &self.nodes {
            if let Node::Ball { vp, .. } = n {
                ids.push(*vp);
            }
        }
        ids
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hd::bruteforce;

    fn random_dataset(n: usize, d: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let x: Vec<f32> = (0..n * d).map(|_| rng.gauss_f32(0.0, 1.0)).collect();
        Dataset::new("r", n, d, x, vec![])
    }

    #[test]
    fn tree_partitions_every_point_once() {
        let data = random_dataset(257, 4, 3);
        let t = VpTree::build(&data, 7);
        let mut ids = t.all_point_ids();
        assert_eq!(ids.len(), 257, "every point exactly once (vp or leaf)");
        ids.sort_unstable();
        for (want, got) in ids.iter().enumerate() {
            assert_eq!(*got, want as u32, "duplicate or missing point");
        }
        // Bucket leaves actually formed (far fewer nodes than points).
        assert!(t.node_count() < 257, "expected bucket leaves, got {} nodes", t.node_count());
    }

    #[test]
    fn matches_brute_force_exactly() {
        // Exactness invariant: same neighbour sets as brute force (modulo
        // distance ties at f32 precision).
        let data = random_dataset(300, 8, 11);
        let t = VpTree::build(&data, 5);
        let approx = t.knn(5);
        let exact = bruteforce::knn(&data, 5);
        let recall = approx.recall_against(&exact);
        assert!(recall > 0.999, "vp-tree must be exact, recall={recall}");
    }

    #[test]
    fn distances_match_brute_force() {
        let data = random_dataset(150, 6, 2);
        let t = VpTree::build(&data, 1);
        let g = t.knn(3);
        let e = bruteforce::knn(&data, 3);
        for i in 0..data.n {
            for j in 0..3 {
                assert!((g.row_d2(i)[j] - e.row_d2(i)[j]).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn tiny_dataset_is_all_leaf() {
        let data = random_dataset(9, 3, 1);
        let t = VpTree::build(&data, 2);
        assert_eq!(t.node_count(), 1);
        let g = t.knn(4);
        let e = bruteforce::knn(&data, 4);
        assert!(g.recall_against(&e) > 0.999);
    }
}
