//! Exact kNN by threaded brute force — the ground truth for recall
//! measurements and for the NNP metric (DESIGN.md S6), and the honest
//! baseline for small N.
//!
//! The pair loop runs through the blocked panel kernel (`hd::blocked`):
//! row norms are precomputed and distances come from `‖x‖²+‖y‖²−2x·y`
//! panels over cached base blocks. The seed's per-pair scalar scan is
//! kept as [`knn_scalar_reference`] — the equivalence oracle the property
//! tests and the `similarities` bench section compare against.

use super::blocked;
use super::dataset::Dataset;
use super::knn::{KBest, KnnGraph};
use crate::util::parallel;

/// Exact k-nearest neighbours of every point (self excluded), O(N² D),
/// via packed blocked distance panels.
pub fn knn(data: &Dataset, k: usize) -> KnnGraph {
    assert!(k < data.n, "k={k} must be < n={}", data.n);
    let norms = blocked::row_sq_norms(&data.x, data.n, data.d);
    let packed = blocked::PackedBase::pack(&data.x, data.n, data.d);
    blocked::knn_blocked(&packed, &norms, &data.x, data.n, &norms, k, true)
}

/// Exact kNN of `queries` rows against `base` rows (used by the NNP metric
/// to search the 2-D embedding). Points are *not* assumed shared, so no
/// self-exclusion unless `exclude_self_index` is set.
pub fn knn_cross(
    base: &[f32],
    base_n: usize,
    dim: usize,
    queries: &[f32],
    k: usize,
    exclude_self_index: bool,
) -> KnnGraph {
    let qn = queries.len() / dim;
    let b_norms = blocked::row_sq_norms(base, base_n, dim);
    let q_norms = blocked::row_sq_norms(queries, qn, dim);
    let packed = blocked::PackedBase::pack(base, base_n, dim);
    blocked::knn_blocked(&packed, &b_norms, queries, qn, &q_norms, k, exclude_self_index)
}

/// The seed's per-pair scalar scan, kept verbatim as the oracle the
/// blocked kernel is validated (and benchmarked) against.
pub fn knn_scalar_reference(data: &Dataset, k: usize) -> KnnGraph {
    assert!(k < data.n, "k={k} must be < n={}", data.n);
    let mut g = KnnGraph::new(data.n, k);
    {
        let rows = parallel::SyncSlice::new(&mut g.idx);
        let dists = parallel::SyncSlice::new(&mut g.d2);
        parallel::par_chunks(data.n, 16, |range| {
            for i in range {
                let qi = data.row(i);
                let mut kb = KBest::new(k);
                for j in 0..data.n {
                    if j == i {
                        continue;
                    }
                    let d = super::dist2(qi, data.row(j));
                    if d < kb.bound() {
                        kb.push(d, j as u32);
                    }
                }
                for (slot, (d, id)) in kb.into_sorted().into_iter().enumerate() {
                    unsafe {
                        *rows.get_mut(i * k + slot) = id;
                        *dists.get_mut(i * k + slot) = d;
                    }
                }
            }
        });
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn grid_dataset() -> Dataset {
        // 1-D line: nearest neighbours are trivially adjacent indices.
        let x: Vec<f32> = (0..10).map(|i| i as f32).collect();
        Dataset::new("line", 10, 1, x, vec![])
    }

    #[test]
    fn line_neighbours_are_adjacent() {
        let g = knn(&grid_dataset(), 2);
        assert_eq!(g.row_idx(0), &[1, 2]);
        let r5: Vec<u32> = g.row_idx(5).to_vec();
        assert!(r5.contains(&4) && r5.contains(&6));
        assert_eq!(g.row_d2(0), &[1.0, 4.0]);
    }

    #[test]
    fn excludes_self() {
        let g = knn(&grid_dataset(), 3);
        for i in 0..10 {
            assert!(!g.row_idx(i).contains(&(i as u32)));
        }
    }

    #[test]
    fn distances_sorted_ascending() {
        let mut rng = Rng::new(1);
        let n = 200;
        let x: Vec<f32> = (0..n * 8).map(|_| rng.gauss_f32(0.0, 1.0)).collect();
        let d = Dataset::new("r", n, 8, x, vec![]);
        let g = knn(&d, 10);
        for i in 0..n {
            let row = g.row_d2(i);
            for w in row.windows(2) {
                assert!(w[0] <= w[1]);
            }
        }
    }

    #[test]
    fn cross_knn_on_embedding() {
        // base == queries in 2-D with self-exclusion: same as knn().
        let pts: Vec<f32> = vec![0., 0., 1., 0., 0., 1., 5., 5.];
        let g = knn_cross(&pts, 4, 2, &pts, 2, true);
        let r0: Vec<u32> = g.row_idx(0).to_vec();
        assert!(r0.contains(&1) && r0.contains(&2));
    }

    #[test]
    fn blocked_matches_scalar_reference() {
        let mut rng = Rng::new(9);
        let n = 300;
        let x: Vec<f32> = (0..n * 17).map(|_| rng.gauss_f32(0.0, 1.0)).collect();
        let d = Dataset::new("r", n, 17, x, vec![]);
        let blocked = knn(&d, 12);
        let scalar = knn_scalar_reference(&d, 12);
        // Tie-insensitive exactness: identical sorted neighbour distances
        // (f32 rounding can swap equal-distance neighbour *identities*).
        for i in 0..n {
            for j in 0..12 {
                let (a, b) = (blocked.row_d2(i)[j], scalar.row_d2(i)[j]);
                assert!((a - b).abs() < 1e-4 * b.max(1.0), "d2[{i}][{j}]: {a} vs {b}");
            }
        }
        assert!(blocked.recall_against(&scalar) > 0.999, "blocked kernel must be exact");
    }
}
