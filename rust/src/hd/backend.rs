//! Pluggable kNN backends — the similarity-stage mirror of
//! `field::FieldBackend` / `embed::ENGINES`.
//!
//! Every way of building the high-dimensional kNN graph lives behind
//! [`KnnBackend`], constructed by [`by_name`] from the same strings the
//! CLI / protocol accept ([`BACKENDS`] is the registry benches and the
//! drift test iterate). Backends may carry tuning state (hence
//! `&mut self`), and all of them score candidates through the blocked
//! distance kernels in [`super::blocked`].
//!
//! ```
//! use gpgpu_sne::hd::backend;
//!
//! # fn main() -> anyhow::Result<()> {
//! let data = gpgpu_sne::data::by_name("gaussians", 60, 1)?;
//! let exact = backend::by_name("brute")?.knn(&data, 5, 0);
//! let approx = backend::by_name("kdforest")?.knn(&data, 5, 0);
//! assert_eq!(exact.k, 5);
//! assert!(approx.recall_against(&exact) > 0.5);
//! # Ok(())
//! # }
//! ```

use super::bruteforce;
use super::dataset::Dataset;
use super::kdforest::{ForestParams, KdForest};
use super::knn::KnnGraph;
use super::vptree::VpTree;

/// A kNN-graph implementation: for each point of `data`, its `k` nearest
/// neighbours (self excluded), rows sorted by ascending distance.
pub trait KnnBackend {
    fn name(&self) -> &'static str;

    /// `seed` feeds any randomised construction (vantage-point choice,
    /// tree splits); exact backends ignore it.
    fn knn(&mut self, data: &Dataset, k: usize, seed: u64) -> KnnGraph;
}

/// Exact O(N²D) brute force over blocked distance panels.
pub struct BruteBackend;

impl KnnBackend for BruteBackend {
    fn name(&self) -> &'static str {
        "brute"
    }

    fn knn(&mut self, data: &Dataset, k: usize, _seed: u64) -> KnnGraph {
        bruteforce::knn(data, k)
    }
}

/// Exact VP-tree (BH-SNE's metric tree) with bucket leaves.
pub struct VpTreeBackend;

impl KnnBackend for VpTreeBackend {
    fn name(&self) -> &'static str {
        "vptree"
    }

    fn knn(&mut self, data: &Dataset, k: usize, seed: u64) -> KnnGraph {
        VpTree::build(data, seed).knn(k)
    }
}

/// Approximate randomised KD-forest (A-tSNE / FAISS stand-in).
pub struct KdForestBackend {
    pub params: ForestParams,
}

impl Default for KdForestBackend {
    fn default() -> Self {
        Self { params: ForestParams::default() }
    }
}

impl KnnBackend for KdForestBackend {
    fn name(&self) -> &'static str {
        "kdforest"
    }

    fn knn(&mut self, data: &Dataset, k: usize, seed: u64) -> KnnGraph {
        KdForest::build(data, self.params, seed).knn(k)
    }
}

/// Canonical backend names, in the order benches sweep them.
pub const BACKENDS: &[&str] = &["brute", "vptree", "kdforest"];

/// Construct a backend by its CLI / protocol name.
pub fn by_name(name: &str) -> anyhow::Result<Box<dyn KnnBackend>> {
    Ok(match name {
        "brute" | "exact" => Box::new(BruteBackend),
        "vptree" => Box::new(VpTreeBackend),
        "kdforest" | "approx" => Box::new(KdForestBackend::default()),
        other => anyhow::bail!("unknown knn backend '{other}' (expected brute|vptree|kdforest)"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_dataset(n: usize, d: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let x: Vec<f32> = (0..n * d).map(|_| rng.gauss_f32(0.0, 1.0)).collect();
        Dataset::new("r", n, d, x, vec![])
    }

    #[test]
    fn registry_resolves_every_backend() {
        for &name in BACKENDS {
            let b = by_name(name).unwrap();
            assert_eq!(b.name(), name, "registry drift for '{name}'");
        }
        assert!(by_name("bogus").is_err());
    }

    #[test]
    fn aliases_resolve() {
        assert_eq!(by_name("exact").unwrap().name(), "brute");
        assert_eq!(by_name("approx").unwrap().name(), "kdforest");
    }

    #[test]
    fn all_backends_produce_valid_graphs() {
        let data = random_dataset(120, 8, 7);
        let exact = by_name("brute").unwrap().knn(&data, 6, 0);
        for &name in BACKENDS {
            let g = by_name(name).unwrap().knn(&data, 6, 0);
            assert_eq!(g.n, 120);
            assert_eq!(g.k, 6);
            for i in 0..g.n {
                assert!(!g.row_idx(i).contains(&(i as u32)), "{name}: self in row {i}");
                for w in g.row_d2(i).windows(2) {
                    assert!(w[0] <= w[1], "{name}: row {i} not sorted");
                }
            }
            assert!(g.recall_against(&exact) > 0.85, "{name}: recall too low");
        }
    }
}
