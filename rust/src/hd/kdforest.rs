//! Randomised KD-tree forest — approximate kNN in high dimensions
//! (Muja & Lowe [29]; the similarity stage of A-tSNE [34] and our stand-in
//! for FAISS in the simulated t-SNE-CUDA comparator; DESIGN.md S8).
//!
//! Each tree splits on a random choice among the top-variance dimensions
//! with a perturbed median threshold; queries descend all trees, then do a
//! bounded best-bin-first exploration with a shared priority queue. A
//! final neighbour-of-neighbour refinement pass (one kNN-descent sweep,
//! Dong et al. [10]) lifts recall to the ~0.9+ regime the paper's
//! pipelines operate at.
//!
//! Leaf scans and the kNN-descent sweep batch their candidates through
//! the blocked dot-product kernel (`hd::blocked::scan_candidates` over
//! precomputed row norms) instead of per-pair scalar `dist2` scans.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::blocked;
use super::dataset::Dataset;
use super::knn::{KBest, KnnGraph};
use crate::util::parallel;
use crate::util::rng::Rng;

const NONE: u32 = u32::MAX;
/// Split dimension is drawn among this many top-variance dims (FLANN's 5).
const TOP_DIMS: usize = 5;

#[derive(Debug, Clone)]
enum Node {
    Split { dim: u32, thresh: f32, left: u32, right: u32 },
    Leaf { start: u32, end: u32 },
}

struct Tree {
    nodes: Vec<Node>,
    /// Point ids, leaf ranges index into this.
    order: Vec<u32>,
    root: u32,
}

/// Forest parameters.
#[derive(Debug, Clone, Copy)]
pub struct ForestParams {
    pub trees: usize,
    pub leaf_size: usize,
    /// Max extra leaves visited per query (best-bin-first budget).
    pub checks: usize,
    /// Run one kNN-descent refinement sweep after the tree search.
    pub refine: bool,
}

impl Default for ForestParams {
    fn default() -> Self {
        Self { trees: 4, leaf_size: 32, checks: 64, refine: true }
    }
}

/// A forest of randomised KD-trees over a dataset.
pub struct KdForest<'a> {
    data: &'a Dataset,
    trees: Vec<Tree>,
    /// Per-row squared norms shared by every leaf scan.
    norms: Vec<f32>,
    params: ForestParams,
}

impl<'a> KdForest<'a> {
    pub fn build(data: &'a Dataset, params: ForestParams, seed: u64) -> Self {
        let norms = blocked::row_sq_norms(&data.x, data.n, data.d);
        let mut master = Rng::new(seed);
        let seeds: Vec<u64> = (0..params.trees).map(|_| master.next_u64()).collect();
        let mut trees: Vec<Option<Tree>> = (0..params.trees).map(|_| None).collect();
        {
            let slots = parallel::SyncSlice::new(&mut trees);
            parallel::par_for(params.trees, |t| {
                let tree = Self::build_tree(data, params.leaf_size, seeds[t]);
                unsafe {
                    *slots.get_mut(t) = Some(tree);
                }
            });
        }
        Self { data, trees: trees.into_iter().map(Option::unwrap).collect(), norms, params }
    }

    fn build_tree(data: &Dataset, leaf_size: usize, seed: u64) -> Tree {
        let mut order: Vec<u32> = (0..data.n as u32).collect();
        let mut nodes = Vec::new();
        let mut rng = Rng::new(seed);
        let n = order.len();
        let root = Self::build_rec(data, &mut order, 0, n, leaf_size, &mut nodes, &mut rng);
        Tree { nodes, order, root }
    }

    #[allow(clippy::too_many_arguments)]
    fn build_rec(
        data: &Dataset,
        order: &mut [u32],
        start: usize,
        end: usize,
        leaf_size: usize,
        nodes: &mut Vec<Node>,
        rng: &mut Rng,
    ) -> u32 {
        let len = end - start;
        if len <= leaf_size {
            let id = nodes.len() as u32;
            nodes.push(Node::Leaf { start: start as u32, end: end as u32 });
            return id;
        }
        let slice = &order[start..end];
        // Estimate per-dimension variance on a sample, pick among the top.
        let sample: Vec<u32> = if slice.len() > 64 {
            (0..64).map(|_| slice[rng.below(slice.len())]).collect()
        } else {
            slice.to_vec()
        };
        let d = data.d;
        let mut var = vec![0.0f32; d];
        let mut mean = vec![0.0f32; d];
        for &i in &sample {
            let row = data.row(i as usize);
            for j in 0..d {
                mean[j] += row[j];
            }
        }
        let inv = 1.0 / sample.len() as f32;
        for m in mean.iter_mut() {
            *m *= inv;
        }
        for &i in &sample {
            let row = data.row(i as usize);
            for j in 0..d {
                let v = row[j] - mean[j];
                var[j] += v * v;
            }
        }
        let mut dims: Vec<usize> = (0..d).collect();
        dims.sort_by(|&a, &b| var[b].partial_cmp(&var[a]).unwrap());
        let dim = dims[rng.below(TOP_DIMS.min(d))];
        // Perturbed mean threshold.
        let thresh = mean[dim] + (rng.f32() - 0.5) * 0.2 * (var[dim] * inv).sqrt();

        // Partition in place.
        let slice = &mut order[start..end];
        let mut lo = 0usize;
        let mut hi = slice.len();
        while lo < hi {
            if data.row(slice[lo] as usize)[dim] < thresh {
                lo += 1;
            } else {
                hi -= 1;
                slice.swap(lo, hi);
            }
        }
        // Degenerate split (all on one side): fall back to median split.
        if lo == 0 || lo == slice.len() {
            let mid = slice.len() / 2;
            slice.select_nth_unstable_by(mid, |&a, &b| {
                data.row(a as usize)[dim].partial_cmp(&data.row(b as usize)[dim]).unwrap()
            });
            lo = mid;
        }
        let id = nodes.len() as u32;
        nodes.push(Node::Split { dim: dim as u32, thresh, left: NONE, right: NONE });
        let left = Self::build_rec(data, order, start, start + lo, leaf_size, nodes, rng);
        let right = Self::build_rec(data, order, start + lo, end, leaf_size, nodes, rng);
        if let Node::Split { left: l, right: r, .. } = &mut nodes[id as usize] {
            *l = left;
            *r = right;
        }
        id
    }

    /// Approximate kNN of `query` (best-bin-first across all trees).
    pub fn knn_query(&self, query: &[f32], k: usize, exclude: Option<u32>) -> Vec<(f32, u32)> {
        let q_norm = blocked::dot(query, query);
        let mut kb = KBest::new(k);
        let mut visited = vec![false; self.data.n];
        let mut cand: Vec<u32> = Vec::with_capacity(self.params.leaf_size);
        // Priority queue of (margin distance, tree, node) — min-heap.
        let mut pq: BinaryHeap<Reverse<(OrdF32, u32, u32)>> = BinaryHeap::new();
        for (t, tree) in self.trees.iter().enumerate() {
            self.descend(
                tree, tree.root, query, q_norm, exclude, &mut kb, &mut visited, &mut cand,
                &mut pq, t as u32,
            );
        }
        let mut checks = 0usize;
        while let Some(Reverse((margin, t, node))) = pq.pop() {
            if checks >= self.params.checks {
                break;
            }
            if margin.0 * margin.0 >= kb.bound() {
                continue;
            }
            checks += 1;
            let tree = &self.trees[t as usize];
            self.descend(
                tree, node, query, q_norm, exclude, &mut kb, &mut visited, &mut cand, &mut pq, t,
            );
        }
        kb.into_sorted()
    }

    #[allow(clippy::too_many_arguments)]
    fn descend(
        &self,
        tree: &Tree,
        mut node: u32,
        query: &[f32],
        q_norm: f32,
        exclude: Option<u32>,
        kb: &mut KBest,
        visited: &mut [bool],
        cand: &mut Vec<u32>,
        pq: &mut BinaryHeap<Reverse<(OrdF32, u32, u32)>>,
        t: u32,
    ) {
        loop {
            match &tree.nodes[node as usize] {
                Node::Leaf { start, end } => {
                    cand.clear();
                    for &i in &tree.order[*start as usize..*end as usize] {
                        if Some(i) == exclude || visited[i as usize] {
                            continue;
                        }
                        visited[i as usize] = true;
                        cand.push(i);
                    }
                    blocked::scan_candidates(
                        query, q_norm, &self.data.x, self.data.d, &self.norms, cand, kb,
                    );
                    return;
                }
                Node::Split { dim, thresh, left, right } => {
                    let diff = query[*dim as usize] - thresh;
                    let (near, far) = if diff < 0.0 { (*left, *right) } else { (*right, *left) };
                    pq.push(Reverse((OrdF32(diff.abs()), t, far)));
                    node = near;
                }
            }
        }
    }

    /// Approximate kNN graph: tree search + optional kNN-descent sweep.
    pub fn knn(&self, k: usize) -> KnnGraph {
        let n = self.data.n;
        let mut g = KnnGraph::new(n, k);
        {
            let idx = parallel::SyncSlice::new(&mut g.idx);
            let d2 = parallel::SyncSlice::new(&mut g.d2);
            parallel::par_chunks(n, 16, |range| {
                for i in range {
                    let res = self.knn_query(self.data.row(i), k, Some(i as u32));
                    for (slot, (d, id)) in res.iter().enumerate() {
                        unsafe {
                            *idx.get_mut(i * k + slot) = *id;
                            *d2.get_mut(i * k + slot) = *d;
                        }
                    }
                    // Under-full rows (tiny datasets): pad with last found.
                    if let Some(&(d, id)) = res.last() {
                        for slot in res.len()..k {
                            unsafe {
                                *idx.get_mut(i * k + slot) = id;
                                *d2.get_mut(i * k + slot) = d;
                            }
                        }
                    }
                }
            });
        }
        if self.params.refine {
            self.knn_descent_sweep(&mut g);
        }
        g
    }

    /// One kNN-descent sweep: consider neighbours-of-neighbours as
    /// candidates (Dong et al. [10]); improves recall substantially for
    /// one extra O(N k²) pass. Candidates are deduplicated, then scored
    /// in one blocked batch per query.
    fn knn_descent_sweep(&self, g: &mut KnnGraph) {
        let n = g.n;
        let k = g.k;
        let snapshot_idx = g.idx.clone();
        let idx = parallel::SyncSlice::new(&mut g.idx);
        let d2 = parallel::SyncSlice::new(&mut g.d2);
        parallel::par_chunks(n, 16, |range| {
            let mut cand: Vec<u32> = Vec::with_capacity(k * k + k);
            for i in range {
                let qi = self.data.row(i);
                let mut kb = KBest::new(k);
                let mut seen = std::collections::HashSet::with_capacity(k * k + k);
                cand.clear();
                for slot in 0..k {
                    let j = snapshot_idx[i * k + slot];
                    if j as usize != i && seen.insert(j) {
                        cand.push(j);
                    }
                    for slot2 in 0..k {
                        let j2 = snapshot_idx[j as usize * k + slot2];
                        if j2 as usize != i && seen.insert(j2) {
                            cand.push(j2);
                        }
                    }
                }
                blocked::scan_candidates(
                    qi,
                    self.norms[i],
                    &self.data.x,
                    self.data.d,
                    &self.norms,
                    &cand,
                    &mut kb,
                );
                for (slot, (d, id)) in kb.into_sorted().into_iter().enumerate() {
                    unsafe {
                        *idx.get_mut(i * k + slot) = id;
                        *d2.get_mut(i * k + slot) = d;
                    }
                }
            }
        });
    }
}

/// Total-ordered f32 for the priority queue.
#[derive(PartialEq, PartialOrd)]
struct OrdF32(f32);
impl Eq for OrdF32 {}
#[allow(clippy::derive_ord_xor_partial_ord)]
impl Ord for OrdF32 {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.partial_cmp(other).unwrap_or(std::cmp::Ordering::Equal)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hd::bruteforce;

    fn clustered_dataset(n: usize, d: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let mut x = Vec::with_capacity(n * d);
        for i in 0..n {
            let c = (i % 5) as f32 * 4.0;
            for _ in 0..d {
                x.push(c + rng.gauss_f32(0.0, 1.0));
            }
        }
        Dataset::new("c", n, d, x, vec![])
    }

    #[test]
    fn recall_above_090_on_clustered_data() {
        let data = clustered_dataset(600, 16, 4);
        let f = KdForest::build(&data, ForestParams::default(), 9);
        let g = f.knn(10);
        let e = bruteforce::knn(&data, 10);
        let recall = g.recall_against(&e);
        assert!(recall > 0.9, "kd-forest recall too low: {recall}");
    }

    #[test]
    fn refinement_improves_recall() {
        let data = clustered_dataset(500, 32, 6);
        let p_no = ForestParams { refine: false, checks: 8, trees: 2, ..Default::default() };
        let p_yes = ForestParams { refine: true, checks: 8, trees: 2, ..Default::default() };
        let e = bruteforce::knn(&data, 8);
        let r_no = KdForest::build(&data, p_no, 1).knn(8).recall_against(&e);
        let r_yes = KdForest::build(&data, p_yes, 1).knn(8).recall_against(&e);
        assert!(r_yes >= r_no, "refine must not hurt: {r_yes} vs {r_no}");
    }

    #[test]
    fn rows_have_no_self_and_sorted() {
        let data = clustered_dataset(300, 8, 2);
        let g = KdForest::build(&data, ForestParams::default(), 3).knn(6);
        for i in 0..data.n {
            assert!(!g.row_idx(i).contains(&(i as u32)));
            for w in g.row_d2(i).windows(2) {
                assert!(w[0] <= w[1]);
            }
        }
    }
}
