//! k-nearest-neighbour graph representation + shared helpers.

/// A kNN graph: for each of `n` points, its `k` nearest neighbours
/// (excluding itself), sorted by ascending distance.
#[derive(Debug, Clone)]
pub struct KnnGraph {
    pub n: usize,
    pub k: usize,
    /// Row-major `(n, k)` neighbour indices.
    pub idx: Vec<u32>,
    /// Row-major `(n, k)` squared distances.
    pub d2: Vec<f32>,
}

impl KnnGraph {
    pub fn new(n: usize, k: usize) -> Self {
        Self { n, k, idx: vec![0; n * k], d2: vec![f32::INFINITY; n * k] }
    }

    #[inline]
    pub fn row_idx(&self, i: usize) -> &[u32] {
        &self.idx[i * self.k..(i + 1) * self.k]
    }

    #[inline]
    pub fn row_d2(&self, i: usize) -> &[f32] {
        &self.d2[i * self.k..(i + 1) * self.k]
    }

    /// Fraction of (point, true-neighbour) pairs the approximate graph
    /// recovered — the recall measure quoted for FAISS/A-tSNE settings.
    pub fn recall_against(&self, exact: &KnnGraph) -> f64 {
        assert_eq!(self.n, exact.n);
        let k = self.k.min(exact.k);
        let mut hits = 0usize;
        for i in 0..self.n {
            let truth: std::collections::HashSet<u32> =
                exact.row_idx(i)[..k].iter().copied().collect();
            hits += self.row_idx(i)[..k].iter().filter(|j| truth.contains(j)).count();
        }
        hits as f64 / (self.n * k) as f64
    }
}

/// Bounded max-heap tracking the k smallest (distance, index) pairs seen.
/// The backbone of every kNN search in this crate.
#[derive(Debug, Clone)]
pub struct KBest {
    k: usize,
    /// Binary max-heap by distance (root = current worst of the best).
    heap: Vec<(f32, u32)>,
}

impl KBest {
    pub fn new(k: usize) -> Self {
        Self { k, heap: Vec::with_capacity(k + 1) }
    }

    /// Current worst distance among the best k (INFINITY until full).
    #[inline]
    pub fn bound(&self) -> f32 {
        if self.heap.len() < self.k {
            f32::INFINITY
        } else {
            self.heap[0].0
        }
    }

    #[inline]
    pub fn push(&mut self, d: f32, i: u32) {
        if self.heap.len() < self.k {
            self.heap.push((d, i));
            self.sift_up(self.heap.len() - 1);
        } else if d < self.heap[0].0 {
            self.heap[0] = (d, i);
            self.sift_down(0);
        }
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let p = (i - 1) / 2;
            if self.heap[i].0 > self.heap[p].0 {
                self.heap.swap(i, p);
                i = p;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        loop {
            let (l, r) = (2 * i + 1, 2 * i + 2);
            let mut m = i;
            if l < self.heap.len() && self.heap[l].0 > self.heap[m].0 {
                m = l;
            }
            if r < self.heap.len() && self.heap[r].0 > self.heap[m].0 {
                m = r;
            }
            if m == i {
                break;
            }
            self.heap.swap(i, m);
            i = m;
        }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Drain into (distance, index) pairs sorted ascending by distance.
    pub fn into_sorted(mut self) -> Vec<(f32, u32)> {
        self.heap.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
        self.heap
    }

    /// Write the sorted result into graph row `i` (padding with the last
    /// neighbour if fewer than k were found — only happens for tiny n).
    pub fn write_row(self, g: &mut KnnGraph, i: usize) {
        let k = g.k;
        let sorted = self.into_sorted();
        for j in 0..k {
            let (d, id) = if sorted.is_empty() {
                (f32::INFINITY, i as u32)
            } else {
                sorted[j.min(sorted.len() - 1)]
            };
            g.idx[i * k + j] = id;
            g.d2[i * k + j] = d;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kbest_keeps_smallest() {
        let mut kb = KBest::new(3);
        for (d, i) in [(5.0, 0), (1.0, 1), (4.0, 2), (2.0, 3), (3.0, 4)] {
            kb.push(d, i);
        }
        let s = kb.into_sorted();
        assert_eq!(s.iter().map(|x| x.1).collect::<Vec<_>>(), vec![1, 3, 4]);
        assert_eq!(s[0].0, 1.0);
    }

    #[test]
    fn kbest_bound_tightens() {
        let mut kb = KBest::new(2);
        assert_eq!(kb.bound(), f32::INFINITY);
        kb.push(3.0, 0);
        assert_eq!(kb.bound(), f32::INFINITY);
        kb.push(1.0, 1);
        assert_eq!(kb.bound(), 3.0);
        kb.push(0.5, 2);
        assert_eq!(kb.bound(), 1.0);
    }

    #[test]
    fn recall_of_identical_graph_is_one() {
        let mut g = KnnGraph::new(4, 2);
        for i in 0..4 {
            g.idx[i * 2] = ((i + 1) % 4) as u32;
            g.idx[i * 2 + 1] = ((i + 2) % 4) as u32;
        }
        assert_eq!(g.recall_against(&g), 1.0);
    }
}
