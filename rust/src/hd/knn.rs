//! k-nearest-neighbour graph representation + shared helpers.

/// A kNN graph: for each of `n` points, its `k` nearest neighbours
/// (excluding itself), sorted by ascending distance.
#[derive(Debug, Clone)]
pub struct KnnGraph {
    pub n: usize,
    pub k: usize,
    /// Row-major `(n, k)` neighbour indices.
    pub idx: Vec<u32>,
    /// Row-major `(n, k)` squared distances.
    pub d2: Vec<f32>,
}

impl KnnGraph {
    pub fn new(n: usize, k: usize) -> Self {
        Self { n, k, idx: vec![0; n * k], d2: vec![f32::INFINITY; n * k] }
    }

    #[inline]
    pub fn row_idx(&self, i: usize) -> &[u32] {
        &self.idx[i * self.k..(i + 1) * self.k]
    }

    #[inline]
    pub fn row_d2(&self, i: usize) -> &[f32] {
        &self.d2[i * self.k..(i + 1) * self.k]
    }

    /// Fraction of (point, true-neighbour) pairs the approximate graph
    /// recovered — the recall measure quoted for FAISS/A-tSNE settings.
    ///
    /// Rows are sorted by *distance*, so both sides are index-sorted into
    /// scratch buffers and intersected with a two-pointer walk — no
    /// per-row `HashSet` allocation (this runs inside recall sweeps over
    /// large N). Duplicated entries in `self` rows (padded under-full
    /// rows) count once per occurrence, exactly as the set-lookup did.
    pub fn recall_against(&self, exact: &KnnGraph) -> f64 {
        assert_eq!(self.n, exact.n);
        let k = self.k.min(exact.k);
        let mut hits = 0usize;
        let mut mine: Vec<u32> = Vec::with_capacity(k);
        let mut truth: Vec<u32> = Vec::with_capacity(k);
        for i in 0..self.n {
            mine.clear();
            mine.extend_from_slice(&self.row_idx(i)[..k]);
            mine.sort_unstable();
            truth.clear();
            truth.extend_from_slice(&exact.row_idx(i)[..k]);
            truth.sort_unstable();
            let (mut a, mut b) = (0usize, 0usize);
            while a < k && b < k {
                match mine[a].cmp(&truth[b]) {
                    std::cmp::Ordering::Less => a += 1,
                    std::cmp::Ordering::Greater => b += 1,
                    std::cmp::Ordering::Equal => {
                        let c = mine[a];
                        while a < k && mine[a] == c {
                            hits += 1;
                            a += 1;
                        }
                        while b < k && truth[b] == c {
                            b += 1;
                        }
                    }
                }
            }
        }
        hits as f64 / (self.n * k) as f64
    }
}

/// Bounded max-heap tracking the k smallest (distance, index) pairs seen.
/// The backbone of every kNN search in this crate.
#[derive(Debug, Clone)]
pub struct KBest {
    k: usize,
    /// Binary max-heap by distance (root = current worst of the best).
    heap: Vec<(f32, u32)>,
}

impl KBest {
    pub fn new(k: usize) -> Self {
        Self { k, heap: Vec::with_capacity(k + 1) }
    }

    /// Current worst distance among the best k (INFINITY until full).
    #[inline]
    pub fn bound(&self) -> f32 {
        if self.heap.len() < self.k {
            f32::INFINITY
        } else {
            self.heap[0].0
        }
    }

    #[inline]
    pub fn push(&mut self, d: f32, i: u32) {
        if self.heap.len() < self.k {
            self.heap.push((d, i));
            self.sift_up(self.heap.len() - 1);
        } else if d < self.heap[0].0 {
            self.heap[0] = (d, i);
            self.sift_down(0);
        }
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let p = (i - 1) / 2;
            if self.heap[i].0 > self.heap[p].0 {
                self.heap.swap(i, p);
                i = p;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        loop {
            let (l, r) = (2 * i + 1, 2 * i + 2);
            let mut m = i;
            if l < self.heap.len() && self.heap[l].0 > self.heap[m].0 {
                m = l;
            }
            if r < self.heap.len() && self.heap[r].0 > self.heap[m].0 {
                m = r;
            }
            if m == i {
                break;
            }
            self.heap.swap(i, m);
            i = m;
        }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Drain into (distance, index) pairs sorted ascending by distance.
    pub fn into_sorted(mut self) -> Vec<(f32, u32)> {
        self.heap.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
        self.heap
    }

    /// Write the sorted result into graph row `i` (padding with the last
    /// neighbour if fewer than k were found — only happens for tiny n).
    pub fn write_row(self, g: &mut KnnGraph, i: usize) {
        let k = g.k;
        let sorted = self.into_sorted();
        for j in 0..k {
            let (d, id) = if sorted.is_empty() {
                (f32::INFINITY, i as u32)
            } else {
                sorted[j.min(sorted.len() - 1)]
            };
            g.idx[i * k + j] = id;
            g.d2[i * k + j] = d;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kbest_keeps_smallest() {
        let mut kb = KBest::new(3);
        for (d, i) in [(5.0, 0), (1.0, 1), (4.0, 2), (2.0, 3), (3.0, 4)] {
            kb.push(d, i);
        }
        let s = kb.into_sorted();
        assert_eq!(s.iter().map(|x| x.1).collect::<Vec<_>>(), vec![1, 3, 4]);
        assert_eq!(s[0].0, 1.0);
    }

    #[test]
    fn kbest_bound_tightens() {
        let mut kb = KBest::new(2);
        assert_eq!(kb.bound(), f32::INFINITY);
        kb.push(3.0, 0);
        assert_eq!(kb.bound(), f32::INFINITY);
        kb.push(1.0, 1);
        assert_eq!(kb.bound(), 3.0);
        kb.push(0.5, 2);
        assert_eq!(kb.bound(), 1.0);
    }

    #[test]
    fn recall_counts_duplicates_like_the_set_lookup_did() {
        // `mine` row 0 has a padded duplicate neighbour (1,1): both
        // occurrences hit, exactly as per-occurrence set lookups counted.
        let mut mine = KnnGraph::new(2, 2);
        mine.idx = vec![1, 1, 0, 1];
        let mut exact = KnnGraph::new(2, 2);
        exact.idx = vec![1, 0, 0, 1];
        // Row 0: both entries (1,1) ∈ {1,0} → 2 hits. Row 1: both hit.
        assert_eq!(mine.recall_against(&exact), 1.0);
        // And a genuine miss still counts as a miss.
        let mut miss = KnnGraph::new(2, 2);
        miss.idx = vec![1, 1, 0, 0];
        // Row 1 of `miss` is {0,0}; truth row 1 is {0,1} → duplicate 0
        // counts twice (old semantics), so 4/4... check against HashSet
        // oracle instead:
        let oracle = |s: &KnnGraph, e: &KnnGraph| -> f64 {
            let k = 2;
            let mut hits = 0;
            for i in 0..2 {
                let t: std::collections::HashSet<u32> =
                    e.row_idx(i)[..k].iter().copied().collect();
                hits += s.row_idx(i)[..k].iter().filter(|j| t.contains(j)).count();
            }
            hits as f64 / 4.0
        };
        assert_eq!(miss.recall_against(&exact), oracle(&miss, &exact));
        assert_eq!(mine.recall_against(&exact), oracle(&mine, &exact));
    }

    #[test]
    fn recall_of_identical_graph_is_one() {
        let mut g = KnnGraph::new(4, 2);
        for i in 0..4 {
            g.idx[i * 2] = ((i + 1) % 4) as u32;
            g.idx[i * 2 + 1] = ((i + 2) % 4) as u32;
        }
        assert_eq!(g.recall_against(&g), 1.0);
    }
}
