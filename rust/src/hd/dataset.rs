//! In-memory high-dimensional dataset: row-major `(n, d)` f32 matrix plus
//! optional integer labels (used only for colouring figures and for the
//! class-structure sanity checks — never by the algorithms).

/// A dense high-dimensional dataset.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub name: String,
    pub n: usize,
    pub d: usize,
    /// Row-major `(n, d)`.
    pub x: Vec<f32>,
    /// One label per point (0 when unknown).
    pub labels: Vec<u8>,
}

impl Dataset {
    pub fn new(name: impl Into<String>, n: usize, d: usize, x: Vec<f32>, labels: Vec<u8>) -> Self {
        assert_eq!(x.len(), n * d, "data shape mismatch");
        let labels = if labels.is_empty() { vec![0; n] } else { labels };
        assert_eq!(labels.len(), n);
        Self { name: name.into(), n, d, x, labels }
    }

    /// The `i`-th row.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.d..(i + 1) * self.d]
    }

    /// Random subset of `m` points (deterministic in `seed`), preserving
    /// labels — used by the paper's growing-N sweeps (Fig. 6/7).
    pub fn subsample(&self, m: usize, seed: u64) -> Dataset {
        if m >= self.n {
            return self.clone();
        }
        let mut rng = crate::util::rng::Rng::new(seed);
        let keep = rng.sample_indices(self.n, m);
        let mut x = Vec::with_capacity(m * self.d);
        let mut labels = Vec::with_capacity(m);
        for &i in &keep {
            x.extend_from_slice(self.row(i));
            labels.push(self.labels[i]);
        }
        Dataset::new(format!("{}[{m}]", self.name), m, self.d, x, labels)
    }

    /// Per-feature standardisation (zero mean, unit variance); features
    /// with zero variance are left centred. Standard preprocessing before
    /// the perplexity search.
    pub fn standardize(&mut self) {
        for j in 0..self.d {
            let mut mean = 0.0f64;
            for i in 0..self.n {
                mean += self.x[i * self.d + j] as f64;
            }
            mean /= self.n as f64;
            let mut var = 0.0f64;
            for i in 0..self.n {
                let v = self.x[i * self.d + j] as f64 - mean;
                var += v * v;
            }
            var /= self.n as f64;
            let inv = if var > 1e-12 { 1.0 / var.sqrt() } else { 0.0 };
            for i in 0..self.n {
                let v = &mut self.x[i * self.d + j];
                *v = ((*v as f64 - mean) * inv) as f32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_and_shape() {
        let d = Dataset::new("t", 3, 2, vec![1., 2., 3., 4., 5., 6.], vec![0, 1, 2]);
        assert_eq!(d.row(1), &[3., 4.]);
    }

    #[test]
    fn subsample_is_deterministic_and_labelled() {
        let d = Dataset::new("t", 100, 1, (0..100).map(|i| i as f32).collect(), (0..100).map(|i| i as u8).collect());
        let a = d.subsample(10, 42);
        let b = d.subsample(10, 42);
        assert_eq!(a.x, b.x);
        assert_eq!(a.n, 10);
        for i in 0..10 {
            assert_eq!(a.x[i] as u8, a.labels[i], "labels must follow their rows");
        }
    }

    #[test]
    fn standardize_zero_mean_unit_var() {
        let mut d = Dataset::new("t", 4, 1, vec![1., 2., 3., 4.], vec![]);
        d.standardize();
        let mean: f32 = d.x.iter().sum::<f32>() / 4.0;
        let var: f32 = d.x.iter().map(|v| v * v).sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-6);
        assert!((var - 1.0).abs() < 1e-5);
    }
}
