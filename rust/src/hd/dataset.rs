//! In-memory high-dimensional dataset: row-major `(n, d)` f32 matrix plus
//! optional integer labels (used only for colouring figures and for the
//! class-structure sanity checks — never by the algorithms).

/// A dense high-dimensional dataset.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub name: String,
    pub n: usize,
    pub d: usize,
    /// Row-major `(n, d)`.
    pub x: Vec<f32>,
    /// One label per point (0 when unknown).
    pub labels: Vec<u8>,
}

impl Dataset {
    pub fn new(name: impl Into<String>, n: usize, d: usize, x: Vec<f32>, labels: Vec<u8>) -> Self {
        assert_eq!(x.len(), n * d, "data shape mismatch");
        let labels = if labels.is_empty() { vec![0; n] } else { labels };
        assert_eq!(labels.len(), n);
        Self { name: name.into(), n, d, x, labels }
    }

    /// The `i`-th row.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.d..(i + 1) * self.d]
    }

    /// Random subset of `m` points (deterministic in `seed`), preserving
    /// labels — used by the paper's growing-N sweeps (Fig. 6/7).
    pub fn subsample(&self, m: usize, seed: u64) -> Dataset {
        if m >= self.n {
            return self.clone();
        }
        let mut rng = crate::util::rng::Rng::new(seed);
        let keep = rng.sample_indices(self.n, m);
        let mut x = Vec::with_capacity(m * self.d);
        let mut labels = Vec::with_capacity(m);
        for &i in &keep {
            x.extend_from_slice(self.row(i));
            labels.push(self.labels[i]);
        }
        Dataset::new(format!("{}[{m}]", self.name), m, self.d, x, labels)
    }

    /// Per-feature standardisation (zero mean, unit variance); features
    /// with zero variance are left centred. Standard preprocessing before
    /// the perplexity search.
    ///
    /// One Welford pass over *row chunks* in parallel (cache-friendly
    /// row-major access instead of the seed's two strided column passes),
    /// per-chunk `(count, mean, M2)` partials merged in chunk order
    /// (Chan et al. — deterministic regardless of thread scheduling),
    /// then a parallel row-major apply pass.
    pub fn standardize(&mut self) {
        let (n, d) = (self.n, self.d);
        if n == 0 || d == 0 {
            return;
        }
        const CHUNK: usize = 512;
        let nchunks = n.div_ceil(CHUNK);
        let mut partials: Vec<Option<(usize, Vec<f64>, Vec<f64>)>> = vec![None; nchunks];
        {
            let slots = crate::util::parallel::SyncSlice::new(&mut partials);
            let x = &self.x;
            crate::util::parallel::par_chunks(n, CHUNK, |range| {
                let ci = range.start / CHUNK;
                let mut count = 0usize;
                let mut mean = vec![0.0f64; d];
                let mut m2 = vec![0.0f64; d];
                for i in range {
                    count += 1;
                    let inv = 1.0 / count as f64;
                    let row = &x[i * d..(i + 1) * d];
                    for j in 0..d {
                        let v = row[j] as f64;
                        let delta = v - mean[j];
                        mean[j] += delta * inv;
                        m2[j] += delta * (v - mean[j]);
                    }
                }
                unsafe {
                    *slots.get_mut(ci) = Some((count, mean, m2));
                }
            });
        }
        let mut count = 0usize;
        let mut mean = vec![0.0f64; d];
        let mut m2 = vec![0.0f64; d];
        for (cb, mb, m2b) in partials.into_iter().flatten() {
            if cb == 0 {
                continue;
            }
            let tot = (count + cb) as f64;
            for j in 0..d {
                let delta = mb[j] - mean[j];
                mean[j] += delta * (cb as f64 / tot);
                m2[j] += m2b[j] + delta * delta * (count as f64 * cb as f64 / tot);
            }
            count += cb;
        }
        let inv_std: Vec<f64> = (0..d)
            .map(|j| {
                let var = m2[j] / n as f64;
                if var > 1e-12 {
                    1.0 / var.sqrt()
                } else {
                    0.0
                }
            })
            .collect();
        {
            let xs = crate::util::parallel::SyncSlice::new(&mut self.x);
            let (mean, inv_std) = (&mean, &inv_std);
            crate::util::parallel::par_chunks(n, CHUNK, |range| {
                for i in range {
                    for j in 0..d {
                        unsafe {
                            let v = xs.get_mut(i * d + j);
                            *v = ((*v as f64 - mean[j]) * inv_std[j]) as f32;
                        }
                    }
                }
            });
        }
    }

    /// Content fingerprint (FNV-1a over the shape and every value's bit
    /// pattern) — the dataset component of the coordinator's similarity
    /// cache key. One O(N·D) pass, negligible next to any kNN build.
    pub fn fingerprint(&self) -> u64 {
        const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h = OFFSET;
        let mut mix = |w: u64| {
            h ^= w;
            h = h.wrapping_mul(PRIME);
        };
        mix(self.n as u64);
        mix(self.d as u64);
        for &v in &self.x {
            h ^= v.to_bits() as u64;
            h = h.wrapping_mul(PRIME);
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_and_shape() {
        let d = Dataset::new("t", 3, 2, vec![1., 2., 3., 4., 5., 6.], vec![0, 1, 2]);
        assert_eq!(d.row(1), &[3., 4.]);
    }

    #[test]
    fn subsample_is_deterministic_and_labelled() {
        let d = Dataset::new("t", 100, 1, (0..100).map(|i| i as f32).collect(), (0..100).map(|i| i as u8).collect());
        let a = d.subsample(10, 42);
        let b = d.subsample(10, 42);
        assert_eq!(a.x, b.x);
        assert_eq!(a.n, 10);
        for i in 0..10 {
            assert_eq!(a.x[i] as u8, a.labels[i], "labels must follow their rows");
        }
    }

    #[test]
    fn standardize_zero_mean_unit_var() {
        let mut d = Dataset::new("t", 4, 1, vec![1., 2., 3., 4.], vec![]);
        d.standardize();
        let mean: f32 = d.x.iter().sum::<f32>() / 4.0;
        let var: f32 = d.x.iter().map(|v| v * v).sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-6);
        assert!((var - 1.0).abs() < 1e-5);
    }

    #[test]
    fn standardize_matches_two_pass_reference() {
        // Welford + chunk merge vs the seed's two-pass column loop, on a
        // dataset spanning several parallel chunks (n > 512).
        let n = 1100usize;
        let d = 3usize;
        let mut rng = crate::util::rng::Rng::new(11);
        let x: Vec<f32> = (0..n * d).map(|_| rng.gauss_f32(5.0, 3.0)).collect();
        let mut ds = Dataset::new("t", n, d, x.clone(), vec![]);
        ds.standardize();
        for j in 0..d {
            let mut mean = 0.0f64;
            for i in 0..n {
                mean += x[i * d + j] as f64;
            }
            mean /= n as f64;
            let mut var = 0.0f64;
            for i in 0..n {
                let v = x[i * d + j] as f64 - mean;
                var += v * v;
            }
            var /= n as f64;
            let inv = if var > 1e-12 { 1.0 / var.sqrt() } else { 0.0 };
            for i in (0..n).step_by(97) {
                let want = ((x[i * d + j] as f64 - mean) * inv) as f32;
                let got = ds.x[i * d + j];
                assert!((got - want).abs() < 1e-5, "({i},{j}): {got} vs {want}");
            }
        }
    }

    #[test]
    fn standardize_leaves_constant_features_at_zero() {
        let mut d = Dataset::new("t", 3, 2, vec![7., 1., 7., 2., 7., 3.], vec![]);
        d.standardize();
        for i in 0..3 {
            assert_eq!(d.x[i * 2], 0.0, "constant feature must map to 0");
        }
    }

    #[test]
    fn fingerprint_distinguishes_content_and_is_stable() {
        let a = Dataset::new("a", 3, 2, vec![1., 2., 3., 4., 5., 6.], vec![]);
        let b = Dataset::new("b", 3, 2, vec![1., 2., 3., 4., 5., 6.], vec![]);
        assert_eq!(a.fingerprint(), b.fingerprint(), "name must not matter");
        let c = Dataset::new("c", 3, 2, vec![1., 2., 3., 4., 5., 6.5], vec![]);
        assert_ne!(a.fingerprint(), c.fingerprint());
        let shape = Dataset::new("s", 2, 3, vec![1., 2., 3., 4., 5., 6.], vec![]);
        assert_ne!(a.fingerprint(), shape.fingerprint(), "shape must matter");
    }
}
