//! Metrics registry: named counters, gauges and log-bucketed
//! histograms, registered once and updated via relaxed atomics.
//!
//! Registration (`Registry::counter` etc.) takes a lock and may
//! allocate; it happens once per call site (cache the returned `Arc`,
//! or park it in a `OnceLock` from free functions). Updates are single
//! `fetch_add`s. Snapshots ([`Registry::snapshot`]) serialise every
//! registered metric to [`Json`] — counters and gauges as numbers,
//! histograms as `{count, sum, p50, p95, p99}` — which is exactly what
//! the protocol's `metrics` command and `serve --metrics-dump` emit.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Duration;

use crate::util::json::Json;

/// Monotonically increasing event count.
#[derive(Debug, Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Instantaneous signed level (queue depths, active workers).
#[derive(Debug, Default)]
pub struct Gauge {
    v: AtomicI64,
}

impl Gauge {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn set(&self, v: i64) {
        self.v.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, d: i64) {
        self.v.fetch_add(d, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Number of power-of-two buckets — covers the full `u64` range.
const BUCKETS: usize = 64;

/// Log-bucketed histogram over `u64` samples (by convention durations
/// in nanoseconds, names suffixed `_ns`).
///
/// Bucket 0 holds the value 0; bucket `b ≥ 1` holds `[2^(b-1), 2^b)`.
/// Recording is two relaxed `fetch_add`s plus a `leading_zeros`;
/// quantile estimates return the geometric midpoint of the covering
/// bucket, so they are accurate to within a factor of 2 — plenty for
/// "did the quantum blow its 25 ms budget" questions.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    fn bucket_of(v: u64) -> usize {
        (64 - v.leading_zeros() as usize).min(BUCKETS - 1)
    }

    /// Representative value for a bucket: the geometric midpoint of its
    /// `[2^(b-1), 2^b)` range.
    fn bucket_mid(b: usize) -> u64 {
        match b {
            0 => 0,
            1 => 1,
            b => 3u64 << (b - 2),
        }
    }

    pub fn record(&self, v: u64) {
        self.buckets[Self::bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Record a duration in nanoseconds.
    pub fn record_duration(&self, d: Duration) {
        self.record(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Estimated `q`-quantile (`0.0 ..= 1.0`) of everything recorded so
    /// far; 0 when empty. Accurate to within 2× (bucket resolution).
    pub fn quantile(&self, q: f64) -> u64 {
        let counts: Vec<u64> = self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (b, &c) in counts.iter().enumerate() {
            cum += c;
            if cum >= target {
                return Self::bucket_mid(b);
            }
        }
        Self::bucket_mid(BUCKETS - 1)
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::Num(self.count() as f64)),
            ("sum", Json::Num(self.sum() as f64)),
            ("p50", Json::Num(self.quantile(0.50) as f64)),
            ("p95", Json::Num(self.quantile(0.95) as f64)),
            ("p99", Json::Num(self.quantile(0.99) as f64)),
        ])
    }
}

#[derive(Default)]
struct Inner {
    counters: BTreeMap<String, Arc<Counter>>,
    gauges: BTreeMap<String, Arc<Gauge>>,
    histograms: BTreeMap<String, Arc<Histogram>>,
}

/// A named set of metrics. One process-wide instance ([`registry`])
/// serves free-function call sites; subsystems that want isolation
/// (the scheduler, tests) own their own.
#[derive(Default)]
pub struct Registry {
    inner: Mutex<Inner>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Get-or-register: the same name always returns the same handle.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut g = self.inner.lock().unwrap();
        g.counters.entry(name.to_string()).or_default().clone()
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut g = self.inner.lock().unwrap();
        g.gauges.entry(name.to_string()).or_default().clone()
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut g = self.inner.lock().unwrap();
        g.histograms.entry(name.to_string()).or_default().clone()
    }

    /// Serialise every registered metric, sorted by name.
    pub fn snapshot(&self) -> Json {
        let g = self.inner.lock().unwrap();
        let counters =
            g.counters.iter().map(|(k, c)| (k.clone(), Json::Num(c.get() as f64))).collect();
        let gauges = g.gauges.iter().map(|(k, v)| (k.clone(), Json::Num(v.get() as f64))).collect();
        let hists = g.histograms.iter().map(|(k, h)| (k.clone(), h.to_json())).collect();
        Json::Obj(vec![
            ("counters".to_string(), Json::Obj(counters)),
            ("gauges".to_string(), Json::Obj(gauges)),
            ("histograms".to_string(), Json::Obj(hists)),
        ])
    }
}

/// The process-wide registry: store I/O and snapshot-fanout metrics
/// live here (their call sites are free functions with no service
/// handle in scope).
pub fn registry() -> &'static Registry {
    static R: OnceLock<Registry> = OnceLock::new();
    R.get_or_init(Registry::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_roundtrip() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let g = Gauge::new();
        g.set(7);
        g.add(-3);
        assert_eq!(g.get(), 4);
    }

    #[test]
    fn histogram_buckets_by_log2() {
        assert_eq!(Histogram::bucket_of(0), 0);
        assert_eq!(Histogram::bucket_of(1), 1);
        assert_eq!(Histogram::bucket_of(2), 2);
        assert_eq!(Histogram::bucket_of(3), 2);
        assert_eq!(Histogram::bucket_of(4), 3);
        assert_eq!(Histogram::bucket_of(u64::MAX), 63);
    }

    #[test]
    fn histogram_quantiles_within_bucket_resolution() {
        let h = Histogram::new();
        assert_eq!(h.quantile(0.5), 0, "empty histogram reads 0");
        for _ in 0..90 {
            h.record(1_000); // bucket [512, 1024)
        }
        for _ in 0..10 {
            h.record(1_000_000); // bucket [2^19, 2^20)
        }
        assert_eq!(h.count(), 100);
        let p50 = h.quantile(0.50);
        assert!((512..1024).contains(&p50), "p50={p50}");
        let p99 = h.quantile(0.99);
        assert!((524_288..2_097_152).contains(&p99), "p99={p99}");
        assert!(h.quantile(1.0) >= p99);
    }

    #[test]
    fn registry_returns_stable_handles_and_snapshots() {
        let r = Registry::new();
        let a = r.counter("x.events");
        let b = r.counter("x.events");
        a.inc();
        assert_eq!(b.get(), 1, "same name, same counter");
        r.gauge("x.depth").set(3);
        r.histogram("x.lat_ns").record(100);
        let snap = r.snapshot();
        assert_eq!(snap.get("counters").unwrap().num_field("x.events"), Some(1.0));
        assert_eq!(snap.get("gauges").unwrap().num_field("x.depth"), Some(3.0));
        let h = snap.get("histograms").unwrap().get("x.lat_ns").unwrap();
        assert_eq!(h.num_field("count"), Some(1.0));
        assert_eq!(h.num_field("sum"), Some(100.0));
    }
}
