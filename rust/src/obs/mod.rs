//! Observability substrate: low-overhead tracing + a metrics registry.
//!
//! The coordinator serves checkpointed, time-sliced, cached jobs
//! (`coordinator/`); this module makes that machinery visible without
//! perturbing it. Two primitives:
//!
//! * [`trace`] — per-thread lock-free ring buffers of span events
//!   (`span_begin`/`span_end` with a span kind, job id and quantum
//!   sequence number). Emitting an event is a handful of atomic stores
//!   into a pre-allocated ring; draining ([`trace::snapshot`]) walks
//!   every thread's ring seqlock-style and merges. No allocation on the
//!   hot path — rings are allocated once per thread, on first use.
//! * [`metrics`] — named counters, gauges and log-bucketed histograms
//!   (p50/p95/p99), registered once in a [`metrics::Registry`] and
//!   updated via relaxed atomics thereafter.
//!
//! Metric naming scheme: `<subsystem>.<quantity>[_<unit>]`, e.g.
//! `scheduler.quantum_ns`, `store.write_bytes`, `snapshot.publish_skipped`.
//! Duration histograms always record **nanoseconds** and carry the
//! `_ns` suffix; byte counters carry `_bytes`. The process-wide
//! registry ([`metrics::registry`]) holds metrics owned by free
//! functions (store I/O, snapshot fanout); the scheduler keeps its own
//! per-service `Registry` so tests observe an isolated instance — both
//! are merged by the `metrics` protocol command.
//!
//! The whole subsystem sits behind one global switch
//! ([`set_enabled`]): when off, span emission and the engines' per-phase
//! step timing short-circuit to nothing. The overhead budget with
//! everything on is <1% of a `session_step`, enforced by the `obs`
//! section of `benches/micro_hotpath.rs`.

pub mod metrics;
pub mod trace;

pub use metrics::{registry, Counter, Gauge, Histogram, Registry};
pub use trace::{now_ns, span, span_begin, span_end, Span, SpanEvent, SpanGuard, SpanKind};

use std::sync::atomic::{AtomicBool, Ordering};

static ENABLED: AtomicBool = AtomicBool::new(true);

/// Master switch for hot-path instrumentation (tracing + per-phase
/// engine timings). Metrics that live on cold paths (store I/O, cache
/// registration) stay on regardless — they cost nothing measurable.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Is hot-path instrumentation on? One relaxed load.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}
