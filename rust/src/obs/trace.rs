//! Structured tracing: per-thread lock-free ring buffers of span
//! events.
//!
//! Every instrumented thread owns a fixed-size ring (allocated once, on
//! the thread's first event — never on the steady-state hot path).
//! Emitting an event packs it into four `u64` words and stores them
//! into the next slot under a per-slot seqlock stamp; no locks, no
//! allocation, a handful of atomic stores. Draining
//! ([`snapshot`]) walks every ring, skips slots that are mid-overwrite
//! (odd or changed stamp), merges and time-orders what remains — a
//! *best-effort* consistent view, which is the right trade for a trace
//! buffer: the writer never waits for the reader.
//!
//! Span identity is the closed [`Span`] enum (adding an instrumentation
//! point = adding a variant), so events carry a byte, not a string.
//! Timestamps are nanoseconds on a process-wide monotonic epoch
//! ([`now_ns`]); `job` is the numeric job id (0 = no job context, e.g.
//! store I/O on the admission path) and `seq` is the scheduler's
//! quantum sequence number (or the iteration for step spans).

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::Json;

/// Default per-thread ring capacity, in events (`serve --trace-ring`
/// overrides via [`set_ring_capacity`]).
pub const DEFAULT_RING_CAPACITY: usize = 4096;

/// What a span event describes. Closed set: one byte on the wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Span {
    /// One scheduler quantum of a job (`coordinator::service`).
    Quantum,
    /// A job parked by `pause` — begin at park, end at first
    /// post-resume slice, so the span length *is* the park→resume
    /// latency.
    Park,
    /// One engine iteration driven by the scheduler.
    EngineStep,
    /// Snapshot fanout to subscribers.
    SnapshotPublish,
    /// Similarity-stage lookup (cache + compute) for a job.
    SimLookup,
    /// Durable-store record read.
    StoreRead,
    /// Durable-store record write.
    StoreWrite,
}

impl Span {
    pub fn name(self) -> &'static str {
        match self {
            Span::Quantum => "scheduler.quantum",
            Span::Park => "scheduler.park",
            Span::EngineStep => "engine.step",
            Span::SnapshotPublish => "snapshot.publish",
            Span::SimLookup => "simcache.lookup",
            Span::StoreRead => "store.read",
            Span::StoreWrite => "store.write",
        }
    }

    fn as_u8(self) -> u8 {
        match self {
            Span::Quantum => 0,
            Span::Park => 1,
            Span::EngineStep => 2,
            Span::SnapshotPublish => 3,
            Span::SimLookup => 4,
            Span::StoreRead => 5,
            Span::StoreWrite => 6,
        }
    }

    fn from_u8(v: u8) -> Option<Span> {
        Some(match v {
            0 => Span::Quantum,
            1 => Span::Park,
            2 => Span::EngineStep,
            3 => Span::SnapshotPublish,
            4 => Span::SimLookup,
            5 => Span::StoreRead,
            6 => Span::StoreWrite,
            _ => return None,
        })
    }
}

/// Begin/end marker.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    Begin,
    End,
}

/// One trace event.
#[derive(Debug, Clone, Copy)]
pub struct SpanEvent {
    pub kind: SpanKind,
    pub span: Span,
    /// Numeric job id; 0 when there is no job context.
    pub job: u64,
    /// Quantum sequence number (step spans: the iteration).
    pub seq: u64,
    /// Nanoseconds on the process trace epoch ([`now_ns`]).
    pub t_ns: u64,
}

impl SpanEvent {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("span", Json::Str(self.span.name().to_string())),
            (
                "kind",
                Json::Str(match self.kind {
                    SpanKind::Begin => "begin".to_string(),
                    SpanKind::End => "end".to_string(),
                }),
            ),
            ("job", Json::Num(self.job as f64)),
            ("seq", Json::Num(self.seq as f64)),
            ("t_ns", Json::Num(self.t_ns as f64)),
        ])
    }
}

fn epoch() -> &'static Instant {
    static E: OnceLock<Instant> = OnceLock::new();
    E.get_or_init(Instant::now)
}

/// Nanoseconds since the process trace epoch (first observability use).
/// Monotonic across threads — safe to subtract for lags.
pub fn now_ns() -> u64 {
    epoch().elapsed().as_nanos() as u64
}

/// One ring slot: a seqlock stamp plus the packed event. The stamp is
/// `2k+1` while the k-th write is in flight and `2k+2` once complete
/// (0 = never written); readers discard odd or changed stamps.
struct Slot {
    stamp: AtomicU64,
    w0: AtomicU64,
    w1: AtomicU64,
    w2: AtomicU64,
    w3: AtomicU64,
}

/// A single-writer ring of span events. The writer is the owning
/// thread; readers are whoever drains ([`snapshot`]).
struct Ring {
    slots: Box<[Slot]>,
    /// Number of events ever pushed (writer-owned).
    head: AtomicU64,
}

impl Ring {
    fn with_capacity(n: usize) -> Ring {
        let n = n.max(16);
        let slots = (0..n)
            .map(|_| Slot {
                stamp: AtomicU64::new(0),
                w0: AtomicU64::new(0),
                w1: AtomicU64::new(0),
                w2: AtomicU64::new(0),
                w3: AtomicU64::new(0),
            })
            .collect::<Vec<_>>()
            .into_boxed_slice();
        Ring { slots, head: AtomicU64::new(0) }
    }

    fn push(&self, e: SpanEvent) {
        let k = self.head.load(Ordering::Relaxed);
        let slot = &self.slots[(k % self.slots.len() as u64) as usize];
        slot.stamp.store(2 * k + 1, Ordering::SeqCst);
        let kind = match e.kind {
            SpanKind::Begin => 0u64,
            SpanKind::End => 1u64,
        };
        slot.w0.store(kind | (e.span.as_u8() as u64) << 8, Ordering::Relaxed);
        slot.w1.store(e.job, Ordering::Relaxed);
        slot.w2.store(e.seq, Ordering::Relaxed);
        slot.w3.store(e.t_ns, Ordering::Relaxed);
        slot.stamp.store(2 * k + 2, Ordering::SeqCst);
        self.head.store(k + 1, Ordering::Release);
    }

    /// Every consistently-readable event in the ring, unordered.
    fn read_all(&self) -> Vec<SpanEvent> {
        let mut out = Vec::with_capacity(self.slots.len());
        for slot in self.slots.iter() {
            let a = slot.stamp.load(Ordering::SeqCst);
            if a == 0 || a % 2 == 1 {
                continue; // empty or mid-write
            }
            let w0 = slot.w0.load(Ordering::Relaxed);
            let w1 = slot.w1.load(Ordering::Relaxed);
            let w2 = slot.w2.load(Ordering::Relaxed);
            let w3 = slot.w3.load(Ordering::Relaxed);
            if slot.stamp.load(Ordering::SeqCst) != a {
                continue; // overwritten while reading
            }
            let Some(span) = Span::from_u8((w0 >> 8) as u8) else { continue };
            let kind = if w0 & 0xff == 0 { SpanKind::Begin } else { SpanKind::End };
            out.push(SpanEvent { kind, span, job: w1, seq: w2, t_ns: w3 });
        }
        out
    }
}

struct Shared {
    rings: Mutex<Vec<Arc<Ring>>>,
    capacity: AtomicUsize,
}

fn shared() -> &'static Shared {
    static S: OnceLock<Shared> = OnceLock::new();
    S.get_or_init(|| Shared {
        rings: Mutex::new(Vec::new()),
        capacity: AtomicUsize::new(DEFAULT_RING_CAPACITY),
    })
}

thread_local! {
    static RING: Arc<Ring> = {
        let s = shared();
        let ring = Arc::new(Ring::with_capacity(s.capacity.load(Ordering::Relaxed)));
        s.rings.lock().unwrap().push(ring.clone());
        ring
    };
}

/// Size rings created *after* this call (existing rings keep their
/// capacity — threads allocate on first event). `serve --trace-ring`
/// calls this before spawning workers.
pub fn set_ring_capacity(n: usize) {
    shared().capacity.store(n.max(16), Ordering::Relaxed);
}

fn emit(kind: SpanKind, span: Span, job: u64, seq: u64) {
    if !super::enabled() {
        return;
    }
    let e = SpanEvent { kind, span, job, seq, t_ns: now_ns() };
    let _ = RING.try_with(|r| r.push(e));
}

pub fn span_begin(span: Span, job: u64, seq: u64) {
    emit(SpanKind::Begin, span, job, seq);
}

pub fn span_end(span: Span, job: u64, seq: u64) {
    emit(SpanKind::End, span, job, seq);
}

/// RAII span: begin now, end on drop.
pub struct SpanGuard {
    span: Span,
    job: u64,
    seq: u64,
}

pub fn span(span: Span, job: u64, seq: u64) -> SpanGuard {
    span_begin(span, job, seq);
    SpanGuard { span, job, seq }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        span_end(self.span, self.job, self.seq);
    }
}

/// Merge every thread's ring: events for `job` (or all jobs when
/// `None`), time-ordered, truncated to the newest `last_n`.
pub fn snapshot(job: Option<u64>, last_n: usize) -> Vec<SpanEvent> {
    let rings: Vec<Arc<Ring>> = shared().rings.lock().unwrap().clone();
    let mut evs: Vec<SpanEvent> = rings
        .iter()
        .flat_map(|r| r.read_all())
        .filter(|e| job.map_or(true, |j| e.job == j))
        .collect();
    evs.sort_by_key(|e| e.t_ns);
    if evs.len() > last_n {
        evs.drain(..evs.len() - last_n);
    }
    evs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_roundtrips_events() {
        let r = Ring::with_capacity(32);
        for i in 0..5u64 {
            r.push(SpanEvent {
                kind: SpanKind::Begin,
                span: Span::Quantum,
                job: 7,
                seq: i,
                t_ns: 100 + i,
            });
        }
        let mut evs = r.read_all();
        evs.sort_by_key(|e| e.seq);
        assert_eq!(evs.len(), 5);
        assert_eq!(evs[4].seq, 4);
        assert_eq!(evs[0].job, 7);
        assert_eq!(evs[0].span, Span::Quantum);
        assert_eq!(evs[0].kind, SpanKind::Begin);
    }

    #[test]
    fn ring_wraps_keeping_newest() {
        let r = Ring::with_capacity(16);
        for i in 0..50u64 {
            r.push(SpanEvent { kind: SpanKind::End, span: Span::Park, job: 1, seq: i, t_ns: i });
        }
        let evs = r.read_all();
        assert_eq!(evs.len(), 16);
        assert!(evs.iter().all(|e| e.seq >= 34), "only the newest survive");
    }

    #[test]
    fn spans_reach_the_global_snapshot() {
        // A job id no other test uses, so parallel tests can't interfere.
        let job = 0xdead_beef_0001;
        {
            let _g = span(Span::EngineStep, job, 3);
        }
        let evs = snapshot(Some(job), 100);
        assert_eq!(evs.len(), 2, "begin + end");
        assert_eq!(evs[0].kind, SpanKind::Begin);
        assert_eq!(evs[1].kind, SpanKind::End);
        assert!(evs[0].t_ns <= evs[1].t_ns, "time-ordered");
        assert_eq!(evs[1].seq, 3);

        // last_n truncation keeps the tail.
        let one = snapshot(Some(job), 1);
        assert_eq!(one.len(), 1);
        assert_eq!(one[0].kind, SpanKind::End);
    }

    #[test]
    fn event_json_shape() {
        let e =
            SpanEvent { kind: SpanKind::Begin, span: Span::StoreWrite, job: 2, seq: 9, t_ns: 11 };
        let j = e.to_json();
        assert_eq!(j.str_field("span"), Some("store.write"));
        assert_eq!(j.str_field("kind"), Some("begin"));
        assert_eq!(j.num_field("job"), Some(2.0));
        assert_eq!(j.num_field("seq"), Some(9.0));
    }
}
