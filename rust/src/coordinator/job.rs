//! Job specification and lifecycle types.

use crate::embed::OptParams;

/// How the high-dimensional kNN graph is computed. Each variant names a
/// `hd::backend` registry entry; `Hash` because the method is part of the
/// similarity-cache key.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KnnMethod {
    /// Exact O(N²D) brute force.
    Brute,
    /// Exact VP-tree (BH-SNE's structure).
    VpTree,
    /// Approximate randomised KD-forest (A-tSNE / FAISS stand-in).
    KdForest,
}

impl KnnMethod {
    /// The `hd::backend::by_name` registry name.
    pub fn backend_name(&self) -> &'static str {
        match self {
            KnnMethod::Brute => "brute",
            KnnMethod::VpTree => "vptree",
            KnnMethod::KdForest => "kdforest",
        }
    }

    /// Whether the backend's *output* depends on the seed. Brute force
    /// ignores it entirely, so the similarity cache can key seed-blind
    /// and serve seed sweeps over identical data from one entry.
    /// (VP-tree stays seed-sensitive: vantage selection can reorder
    /// equal-distance ties, and cached results must be bit-reproducible.)
    pub fn seed_sensitive(&self) -> bool {
        !matches!(self, KnnMethod::Brute)
    }

    /// Stable one-byte tag for the on-disk similarity store
    /// (`coordinator::store`). Append-only: tags are part of the record
    /// format and must never be reused for a different method.
    pub fn tag(&self) -> u8 {
        match self {
            KnnMethod::Brute => 0,
            KnnMethod::VpTree => 1,
            KnnMethod::KdForest => 2,
        }
    }

    /// Inverse of [`Self::tag`]; unknown tags (a record written by a
    /// newer build) read as `None`, i.e. a store miss.
    pub fn from_tag(tag: u8) -> Option<Self> {
        Some(match tag {
            0 => KnnMethod::Brute,
            1 => KnnMethod::VpTree,
            2 => KnnMethod::KdForest,
            _ => return None,
        })
    }
}

impl std::str::FromStr for KnnMethod {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Ok(match s {
            "brute" | "exact" => Self::Brute,
            "vptree" => Self::VpTree,
            "kdforest" | "approx" => Self::KdForest,
            other => anyhow::bail!("unknown knn method '{other}'"),
        })
    }
}

/// Scheduling class for the step-quantum scheduler. `Interactive` jobs
/// take quanta ahead of `Batch` work under contention (weighted
/// round-robin in `service.rs`), so a wall of batch submissions cannot
/// starve a user watching an embedding evolve; batch still gets a
/// guaranteed share so it cannot starve either.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Priority {
    /// Latency-sensitive: a user is watching. The default.
    #[default]
    Interactive,
    /// Throughput work: yields to interactive under contention.
    Batch,
}

impl Priority {
    /// Protocol wire name (the submit `priority` field).
    pub fn label(&self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Batch => "batch",
        }
    }
}

impl std::str::FromStr for Priority {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Ok(match s {
            "interactive" => Self::Interactive,
            "batch" => Self::Batch,
            other => anyhow::bail!("unknown priority '{other}' (interactive|batch)"),
        })
    }
}

/// Automatic early termination: stop when the KL estimate improved less
/// than `rel_eps` (relatively) over the last `window` iterations.
#[derive(Debug, Clone, Copy)]
pub struct AutoStop {
    pub window: usize,
    pub rel_eps: f64,
}

/// A mid-run hyperparameter update (the protocol's `update` command and
/// [`crate::embed::EmbeddingSession::set_params`] payload): every field
/// is optional, set fields overwrite the session's current
/// [`OptParams`]. Raising `iters` extends a finished job; lowering it
/// below the current iteration ends the job at the next scheduler slice.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ParamUpdate {
    pub iters: Option<usize>,
    pub eta: Option<f32>,
    pub exaggeration: Option<f32>,
    pub exaggeration_iters: Option<usize>,
    pub momentum0: Option<f32>,
    pub momentum1: Option<f32>,
    pub momentum_switch: Option<usize>,
}

impl ParamUpdate {
    pub fn is_empty(&self) -> bool {
        *self == Self::default()
    }

    /// Overwrite `params`' fields with the set ones.
    pub fn apply(&self, params: &mut OptParams) {
        if let Some(v) = self.iters {
            params.iters = v;
        }
        if let Some(v) = self.eta {
            params.eta = v;
        }
        if let Some(v) = self.exaggeration {
            params.exaggeration = v;
        }
        if let Some(v) = self.exaggeration_iters {
            params.exaggeration_iters = v;
        }
        if let Some(v) = self.momentum0 {
            params.momentum0 = v;
        }
        if let Some(v) = self.momentum1 {
            params.momentum1 = v;
        }
        if let Some(v) = self.momentum_switch {
            params.momentum_switch = v;
        }
    }

    /// Layer `later` on top of this update (later's set fields win) —
    /// how the job control slot merges updates that arrive faster than
    /// the scheduler drains them.
    pub fn merged_with(&self, later: &ParamUpdate) -> ParamUpdate {
        ParamUpdate {
            iters: later.iters.or(self.iters),
            eta: later.eta.or(self.eta),
            exaggeration: later.exaggeration.or(self.exaggeration),
            exaggeration_iters: later.exaggeration_iters.or(self.exaggeration_iters),
            momentum0: later.momentum0.or(self.momentum0),
            momentum1: later.momentum1.or(self.momentum1),
            momentum_switch: later.momentum_switch.or(self.momentum_switch),
        }
    }
}

/// Everything needed to run one embedding job.
#[derive(Debug, Clone)]
pub struct JobSpec {
    /// Dataset name (see `data::by_name`).
    pub dataset: String,
    /// Number of points to generate/subsample.
    pub n: usize,
    /// Engine name (see `embed::by_name`).
    pub engine: String,
    pub perplexity: f32,
    pub knn: KnnMethod,
    pub params: OptParams,
    /// Emit a snapshot every this many iterations (0 = only the final).
    pub snapshot_every: usize,
    pub auto_stop: Option<AutoStop>,
    /// Scheduling class (protocol `priority`, default interactive).
    pub priority: Priority,
    /// Dataset/seed salt.
    pub seed: u64,
    /// Client-supplied initial `(n, 2)` layout: the session is
    /// warm-started from it before the first step (protocol `y0`).
    pub y0: Option<Vec<f32>>,
    /// Serialised [`crate::embed::Checkpoint`] to resume from (protocol
    /// `resume_from`, journal re-admission). Applied after `y0`, so when
    /// both are present the checkpoint wins.
    pub resume_from: Option<Vec<u8>>,
}

impl Default for JobSpec {
    fn default() -> Self {
        Self {
            dataset: "mnist".into(),
            n: 2000,
            engine: "fieldcpu".into(),
            perplexity: 30.0,
            knn: KnnMethod::KdForest,
            params: OptParams::default(),
            snapshot_every: 50,
            auto_stop: None,
            priority: Priority::Interactive,
            seed: 42,
            y0: None,
            resume_from: None,
        }
    }
}

impl JobSpec {
    /// Neighbour count for the P computation: the BH-SNE 3µ restriction.
    pub fn knn_k(&self) -> usize {
        ((3.0 * self.perplexity).floor() as usize).max(3)
    }
}

/// Where a job currently is.
#[derive(Debug, Clone, PartialEq)]
pub enum JobPhase {
    Queued,
    Knn,
    Perplexity,
    Optimizing { iter: usize, total: usize },
    /// Parked by a `pause` command; `resume` re-enters the scheduler.
    Paused { iter: usize, total: usize },
    Done,
    Stopped,
    Failed(String),
}

impl JobPhase {
    pub fn is_terminal(&self) -> bool {
        matches!(self, JobPhase::Done | JobPhase::Stopped | JobPhase::Failed(_))
    }

    pub fn label(&self) -> String {
        match self {
            JobPhase::Queued => "queued".into(),
            JobPhase::Knn => "knn".into(),
            JobPhase::Perplexity => "perplexity".into(),
            JobPhase::Optimizing { iter, total } => format!("optimizing {iter}/{total}"),
            JobPhase::Paused { iter, total } => format!("paused {iter}/{total}"),
            JobPhase::Done => "done".into(),
            JobPhase::Stopped => "stopped".into(),
            JobPhase::Failed(e) => format!("failed: {e}"),
        }
    }
}

/// A progressive embedding snapshot.
#[derive(Debug, Clone)]
pub struct Snapshot {
    pub iter: usize,
    pub kl_est: f64,
    pub elapsed_s: f64,
    /// `(n, 2)` row-major positions (shared, cheap to clone).
    pub positions: std::sync::Arc<Vec<f32>>,
    /// Publish timestamp on the [`crate::obs::now_ns`] monotonic epoch;
    /// subscribers subtract it from `now_ns()` to measure delivery lag
    /// (the `snapshot.deliver_lag_ns` histogram).
    pub published_ns: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn knn_k_is_3mu() {
        let spec = JobSpec { perplexity: 30.0, ..Default::default() };
        assert_eq!(spec.knn_k(), 90);
        let tiny = JobSpec { perplexity: 0.5, ..Default::default() };
        assert_eq!(tiny.knn_k(), 3);
    }

    #[test]
    fn priority_parses_and_labels() {
        assert_eq!("interactive".parse::<Priority>().unwrap(), Priority::Interactive);
        assert_eq!("batch".parse::<Priority>().unwrap(), Priority::Batch);
        assert!("urgent".parse::<Priority>().is_err());
        for p in [Priority::Interactive, Priority::Batch] {
            assert_eq!(p.label().parse::<Priority>().unwrap(), p, "label roundtrips");
        }
        assert_eq!(Priority::default(), Priority::Interactive);
    }

    #[test]
    fn knn_method_parses() {
        assert_eq!("brute".parse::<KnnMethod>().unwrap(), KnnMethod::Brute);
        assert_eq!("vptree".parse::<KnnMethod>().unwrap(), KnnMethod::VpTree);
        assert_eq!("approx".parse::<KnnMethod>().unwrap(), KnnMethod::KdForest);
        assert!("x".parse::<KnnMethod>().is_err());
    }

    #[test]
    fn every_method_roundtrips_through_the_backend_registry() {
        for m in [KnnMethod::Brute, KnnMethod::VpTree, KnnMethod::KdForest] {
            // The registry must know every method, and the name must
            // parse back to the same method (no drift in either
            // direction).
            let b = crate::hd::backend::by_name(m.backend_name()).unwrap();
            assert_eq!(b.name(), m.backend_name());
            assert_eq!(m.backend_name().parse::<KnnMethod>().unwrap(), m);
        }
    }

    #[test]
    fn knn_method_tags_roundtrip() {
        for m in [KnnMethod::Brute, KnnMethod::VpTree, KnnMethod::KdForest] {
            assert_eq!(KnnMethod::from_tag(m.tag()), Some(m));
        }
        assert_eq!(KnnMethod::from_tag(250), None, "unknown tags are store misses");
    }

    #[test]
    fn phase_terminality() {
        assert!(JobPhase::Done.is_terminal());
        assert!(JobPhase::Failed("x".into()).is_terminal());
        assert!(!JobPhase::Optimizing { iter: 1, total: 2 }.is_terminal());
        assert_eq!(JobPhase::Optimizing { iter: 1, total: 2 }.label(), "optimizing 1/2");
        assert!(!JobPhase::Paused { iter: 3, total: 9 }.is_terminal());
        assert_eq!(JobPhase::Paused { iter: 3, total: 9 }.label(), "paused 3/9");
    }

    #[test]
    fn param_update_applies_and_merges() {
        let mut p = OptParams::default();
        let u = ParamUpdate { eta: Some(50.0), iters: Some(10), ..Default::default() };
        assert!(!u.is_empty());
        assert!(ParamUpdate::default().is_empty());
        u.apply(&mut p);
        assert_eq!(p.eta, 50.0);
        assert_eq!(p.iters, 10);
        assert_eq!(p.momentum1, OptParams::default().momentum1, "unset fields untouched");
        let later = ParamUpdate { eta: Some(75.0), momentum1: Some(0.9), ..Default::default() };
        let m = u.merged_with(&later);
        assert_eq!(m.eta, Some(75.0), "later wins");
        assert_eq!(m.iters, Some(10), "earlier survives");
        assert_eq!(m.momentum1, Some(0.9));
    }
}
