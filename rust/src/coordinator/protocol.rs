//! Line-oriented TCP protocol for the serve mode (DESIGN.md S20).
//!
//! One JSON object per line, both directions:
//!
//! ```text
//! -> {"cmd":"submit","dataset":"mnist","n":2000,"engine":"fieldcpu","iters":500}
//! <- {"ok":true,"job":1}
//! -> {"cmd":"status","job":1}
//! <- {"ok":true,"job":1,"phase":"optimizing 120/500","kl":2.31,"iter":119}
//! -> {"cmd":"snapshot","job":1}  // live positions, straight from the session
//! <- {"ok":true,"job":1,"iter":119,"kl":2.31,"positions":[x0,y0,x1,y1,...]}
//! -> {"cmd":"pause","job":1}     // park at the next step boundary
//! <- {"ok":true,"job":1}         //   (status then reads "paused 130/500")
//! -> {"cmd":"update","job":1,"eta":120,"iters":800}
//! <- {"ok":true,"job":1}         // live re-parameterisation mid-run
//! -> {"cmd":"resume","job":1}    // re-enter the scheduler
//! -> {"cmd":"stop","job":1}      // user-driven early termination
//! -> {"cmd":"wait","job":1}      // blocks until terminal
//! <- {"ok":true,"job":1,...,"knn_s":1.2,"perplexity_s":0.3,"sim_cache_hit":false}
//! -> {"cmd":"list"}
//! -> {"cmd":"stats"}             // similarity-cache hit/miss/compute counters
//! -> {"cmd":"quit"}
//! ```
//!
//! The service behind these commands is a cooperative scheduler: jobs
//! are embedding *sessions* time-sliced across `max_concurrent` workers
//! in step quanta (fair round-robin — a large job cannot starve small
//! ones), each quantum publishing a snapshot straight from the session
//! state, so `snapshot` is always live without configuring
//! `snapshot_every`. `pause` parks a session (its optimiser state and
//! caches stay warm), `resume` re-enters it, and `update` overwrites
//! eta / exaggeration(+iters) / momentum(0/1/switch) / iters on the live
//! session — raising `iters` extends a run, lowering it ends the run at
//! the next boundary.
//!
//! `submit` also accepts `auto_stop_window` (+ optional
//! `auto_stop_eps`, default 1e-5): automatic termination once the KL
//! estimate improves less than `eps` (relative) over the last `window`
//! iterations after exaggeration lifts.
//!
//! `wait` reports the per-stage similarity timings and whether the job's
//! kNN + P matrix came from the coordinator similarity cache (a repeat
//! job over the same data: `knn_s + perplexity_s ≈ 0`; concurrent
//! identical submissions coalesce onto one computation).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use crate::embed::OptParams;
use crate::util::json::{self, Json};

use super::job::{AutoStop, JobSpec, ParamUpdate};
use super::service::EmbeddingService;

/// Parse a submit command into a JobSpec (missing fields -> defaults).
pub fn spec_from_json(v: &Json) -> anyhow::Result<JobSpec> {
    let mut spec = JobSpec::default();
    if let Some(d) = v.str_field("dataset") {
        spec.dataset = d.to_string();
    }
    if let Some(n) = v.num_field("n") {
        spec.n = n as usize;
    }
    if let Some(e) = v.str_field("engine") {
        spec.engine = e.to_string();
    }
    if let Some(p) = v.num_field("perplexity") {
        spec.perplexity = p as f32;
    }
    if let Some(k) = v.str_field("knn") {
        spec.knn = k.parse()?;
    }
    let mut params = OptParams::default();
    if let Some(i) = v.num_field("iters") {
        params.iters = i as usize;
    }
    if let Some(e) = v.num_field("eta") {
        params.eta = e as f32;
    }
    if let Some(x) = v.num_field("exaggeration_iters") {
        params.exaggeration_iters = x as usize;
    }
    if let Some(s) = v.num_field("seed") {
        params.seed = s as u64;
        spec.seed = s as u64;
    }
    spec.params = params;
    if let Some(s) = v.num_field("snapshot_every") {
        spec.snapshot_every = s as usize;
    }
    if let Some(w) = v.num_field("auto_stop_window") {
        spec.auto_stop = Some(AutoStop {
            window: (w as usize).max(1),
            rel_eps: v.num_field("auto_stop_eps").unwrap_or(1e-5),
        });
    }
    Ok(spec)
}

/// Parse the optional fields of an `update` command.
pub fn update_from_json(v: &Json) -> ParamUpdate {
    ParamUpdate {
        iters: v.num_field("iters").map(|x| x as usize),
        eta: v.num_field("eta").map(|x| x as f32),
        exaggeration: v.num_field("exaggeration").map(|x| x as f32),
        exaggeration_iters: v.num_field("exaggeration_iters").map(|x| x as usize),
        momentum0: v.num_field("momentum0").map(|x| x as f32),
        momentum1: v.num_field("momentum1").map(|x| x as f32),
        momentum_switch: v.num_field("momentum_switch").map(|x| x as usize),
    }
}

fn ok_fields(fields: Vec<(&str, Json)>) -> String {
    let mut all = vec![("ok", Json::Bool(true))];
    all.extend(fields);
    Json::obj(all).to_string()
}

fn err_msg(msg: &str) -> String {
    Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::Str(msg.into()))]).to_string()
}

/// Handle one request line; returns (response line, keep_going).
pub fn handle_line(svc: &EmbeddingService, line: &str) -> (String, bool) {
    let v = match json::parse(line.trim()) {
        Ok(v) => v,
        Err(e) => return (err_msg(&format!("bad json: {e}")), true),
    };
    let cmd = v.str_field("cmd").unwrap_or("");
    match cmd {
        "submit" => match spec_from_json(&v) {
            Ok(spec) => {
                let id = svc.submit(spec);
                (ok_fields(vec![("job", Json::Num(id as f64))]), true)
            }
            Err(e) => (err_msg(&format!("{e:#}")), true),
        },
        "status" => {
            let id = v.num_field("job").unwrap_or(0.0) as u64;
            match svc.phase(id) {
                None => (err_msg("unknown job"), true),
                Some(phase) => {
                    let mut fields = vec![
                        ("job", Json::Num(id as f64)),
                        ("phase", Json::Str(phase.label())),
                        ("terminal", Json::Bool(phase.is_terminal())),
                    ];
                    if let Some(s) = svc.latest_snapshot(id) {
                        fields.push(("iter", Json::Num(s.iter as f64)));
                        fields.push(("kl", Json::Num(s.kl_est)));
                        fields.push(("elapsed_s", Json::Num(s.elapsed_s)));
                    }
                    (ok_fields(fields), true)
                }
            }
        }
        "snapshot" => {
            let id = v.num_field("job").unwrap_or(0.0) as u64;
            match svc.latest_snapshot(id) {
                None => (err_msg("no snapshot yet"), true),
                Some(s) => {
                    let pos = Json::Arr(s.positions.iter().map(|&p| Json::Num(p as f64)).collect());
                    (
                        ok_fields(vec![
                            ("job", Json::Num(id as f64)),
                            ("iter", Json::Num(s.iter as f64)),
                            ("kl", Json::Num(s.kl_est)),
                            ("positions", pos),
                        ]),
                        true,
                    )
                }
            }
        }
        "stop" => {
            let id = v.num_field("job").unwrap_or(0.0) as u64;
            if svc.stop(id) {
                (ok_fields(vec![("job", Json::Num(id as f64))]), true)
            } else {
                (err_msg("unknown job"), true)
            }
        }
        "pause" => {
            let id = v.num_field("job").unwrap_or(0.0) as u64;
            if svc.pause(id) {
                (ok_fields(vec![("job", Json::Num(id as f64))]), true)
            } else {
                (err_msg("unknown or finished job"), true)
            }
        }
        "resume" => {
            let id = v.num_field("job").unwrap_or(0.0) as u64;
            if svc.resume(id) {
                (ok_fields(vec![("job", Json::Num(id as f64))]), true)
            } else {
                (err_msg("unknown or finished job"), true)
            }
        }
        "update" => {
            let id = v.num_field("job").unwrap_or(0.0) as u64;
            let update = update_from_json(&v);
            if update.is_empty() {
                (err_msg("update carries no fields (iters/eta/exaggeration/exaggeration_iters/momentum0/momentum1/momentum_switch)"), true)
            } else if svc.update(id, update) {
                (ok_fields(vec![("job", Json::Num(id as f64))]), true)
            } else {
                (err_msg("unknown or finished job"), true)
            }
        }
        "wait" => {
            let id = v.num_field("job").unwrap_or(0.0) as u64;
            match svc.wait(id) {
                Ok(res) => (
                    ok_fields(vec![
                        ("job", Json::Num(id as f64)),
                        ("iters", Json::Num(res.iters_run as f64)),
                        ("kl", Json::Num(res.kl_est)),
                        ("stopped_early", Json::Bool(res.stopped_early)),
                        ("knn_s", Json::Num(res.timings.knn_s)),
                        ("perplexity_s", Json::Num(res.timings.perplexity_s)),
                        ("sim_cache_hit", Json::Bool(res.timings.sim_cache_hit)),
                        ("optimize_s", Json::Num(res.timings.optimize_s)),
                        ("total_s", Json::Num(res.timings.total())),
                    ]),
                    true,
                ),
                Err(e) => (err_msg(&format!("{e:#}")), true),
            }
        }
        "stats" => {
            let (hits, misses) = svc.sim_cache().stats();
            (
                ok_fields(vec![
                    ("sim_cache_hits", Json::Num(hits as f64)),
                    ("sim_cache_misses", Json::Num(misses as f64)),
                    ("sim_cache_computes", Json::Num(svc.sim_cache().computes() as f64)),
                    ("sim_cache_entries", Json::Num(svc.sim_cache().len() as f64)),
                ]),
                true,
            )
        }
        "list" => {
            let jobs = Json::Arr(
                svc.list()
                    .into_iter()
                    .map(|(id, ph)| {
                        Json::obj(vec![
                            ("job", Json::Num(id as f64)),
                            ("phase", Json::Str(ph.label())),
                        ])
                    })
                    .collect(),
            );
            (ok_fields(vec![("jobs", jobs)]), true)
        }
        "quit" => (ok_fields(vec![("bye", Json::Bool(true))]), false),
        other => (err_msg(&format!("unknown cmd '{other}'")), true),
    }
}

fn handle_client(svc: Arc<EmbeddingService>, stream: TcpStream) {
    let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_default();
    let reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut writer = stream;
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let (resp, keep) = handle_line(&svc, &line);
        if writer.write_all(resp.as_bytes()).is_err() || writer.write_all(b"\n").is_err() {
            break;
        }
        if !keep {
            break;
        }
    }
    let _ = peer;
}

/// Serve forever on `addr` (e.g. `127.0.0.1:7878`). Returns the bound
/// address via callback (so callers/tests can bind port 0).
pub fn serve(
    svc: Arc<EmbeddingService>,
    addr: &str,
    on_bound: impl FnOnce(std::net::SocketAddr),
) -> anyhow::Result<()> {
    let listener = TcpListener::bind(addr)?;
    on_bound(listener.local_addr()?);
    for stream in listener.incoming() {
        let Ok(stream) = stream else { continue };
        let svc = svc.clone();
        std::thread::spawn(move || handle_client(svc, stream));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn svc() -> EmbeddingService {
        EmbeddingService::new(None, 2)
    }

    #[test]
    fn submit_status_wait_roundtrip() {
        let s = svc();
        let (resp, _) = handle_line(
            &s,
            r#"{"cmd":"submit","dataset":"gaussians","n":80,"engine":"bh-0.5","iters":20,"perplexity":8,"knn":"brute"}"#,
        );
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{resp}");
        let id = v.num_field("job").unwrap() as u64;

        let (resp, _) = handle_line(&s, &format!(r#"{{"cmd":"wait","job":{id}}}"#));
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{resp}");
        assert_eq!(v.num_field("iters").unwrap() as usize, 20);

        let (resp, _) = handle_line(&s, &format!(r#"{{"cmd":"status","job":{id}}}"#));
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.str_field("phase"), Some("done"));
        assert_eq!(v.get("terminal"), Some(&Json::Bool(true)));
    }

    #[test]
    fn snapshot_has_positions() {
        let s = svc();
        let (resp, _) = handle_line(
            &s,
            r#"{"cmd":"submit","dataset":"gaussians","n":60,"engine":"bh-0.5","iters":15,"perplexity":6,"knn":"brute","snapshot_every":1}"#,
        );
        let id = json::parse(&resp).unwrap().num_field("job").unwrap() as u64;
        handle_line(&s, &format!(r#"{{"cmd":"wait","job":{id}}}"#));
        let (resp, _) = handle_line(&s, &format!(r#"{{"cmd":"snapshot","job":{id}}}"#));
        let v = json::parse(&resp).unwrap();
        let pos = v.get("positions").unwrap().as_arr().unwrap();
        assert_eq!(pos.len(), 120);
    }

    #[test]
    fn repeat_submit_reports_cache_hit_and_stats() {
        let s = svc();
        let submit =
            r#"{"cmd":"submit","dataset":"gaussians","n":90,"engine":"bh-0.5","iters":15,"perplexity":8,"knn":"brute"}"#;
        let wait = |s: &EmbeddingService, id: u64| {
            json::parse(&handle_line(s, &format!(r#"{{"cmd":"wait","job":{id}}}"#)).0).unwrap()
        };
        let id1 = json::parse(&handle_line(&s, submit).0).unwrap().num_field("job").unwrap();
        let v = wait(&s, id1 as u64);
        assert_eq!(v.get("sim_cache_hit"), Some(&Json::Bool(false)), "{v}");
        assert!(v.num_field("knn_s").unwrap() >= 0.0);
        assert!(v.num_field("perplexity_s").unwrap() >= 0.0);

        let id2 = json::parse(&handle_line(&s, submit).0).unwrap().num_field("job").unwrap();
        let v = wait(&s, id2 as u64);
        assert_eq!(v.get("sim_cache_hit"), Some(&Json::Bool(true)), "{v}");
        assert_eq!(v.num_field("perplexity_s").unwrap(), 0.0);

        let v = json::parse(&handle_line(&s, r#"{"cmd":"stats"}"#).0).unwrap();
        assert_eq!(v.num_field("sim_cache_hits").unwrap() as u64, 1, "{v}");
        assert_eq!(v.num_field("sim_cache_misses").unwrap() as u64, 1);
        assert_eq!(v.num_field("sim_cache_entries").unwrap() as u64, 1);
    }

    #[test]
    fn submit_parses_auto_stop() {
        let v = json::parse(r#"{"cmd":"submit","auto_stop_window":25,"auto_stop_eps":0.001}"#)
            .unwrap();
        let auto = spec_from_json(&v).unwrap().auto_stop.expect("auto stop set");
        assert_eq!(auto.window, 25);
        assert!((auto.rel_eps - 0.001).abs() < 1e-12);
        // Window alone gets the default epsilon.
        let v = json::parse(r#"{"cmd":"submit","auto_stop_window":10}"#).unwrap();
        assert_eq!(spec_from_json(&v).unwrap().auto_stop.unwrap().rel_eps, 1e-5);
        // Absent -> none (the pre-existing default).
        let v = json::parse(r#"{"cmd":"submit"}"#).unwrap();
        assert!(spec_from_json(&v).unwrap().auto_stop.is_none());
    }

    #[test]
    fn pause_update_resume_cycle() {
        let s = svc();
        let (resp, _) = handle_line(
            &s,
            r#"{"cmd":"submit","dataset":"gaussians","n":120,"engine":"bh-0.5","iters":100000,"perplexity":8,"knn":"brute"}"#,
        );
        let id = json::parse(&resp).unwrap().num_field("job").unwrap() as u64;
        let status = |s: &EmbeddingService| {
            json::parse(&handle_line(s, &format!(r#"{{"cmd":"status","job":{id}}}"#)).0).unwrap()
        };
        // Wait until it is optimising, then pause.
        while !status(&s).str_field("phase").unwrap_or("").starts_with("optimizing") {
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        let v = json::parse(&handle_line(&s, &format!(r#"{{"cmd":"pause","job":{id}}}"#)).0)
            .unwrap();
        assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{v}");
        // The scheduler parks it at the next step boundary.
        let paused_iter = loop {
            let v = status(&s);
            let phase = v.str_field("phase").unwrap_or("").to_string();
            if phase.starts_with("paused") {
                break v.num_field("iter").unwrap_or(0.0) as usize;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        };
        // Re-parameterise while parked: cut the run short.
        let cut = paused_iter.max(1) + 1;
        let v = json::parse(
            &handle_line(&s, &format!(r#"{{"cmd":"update","job":{id},"iters":{cut},"eta":50}}"#))
                .0,
        )
        .unwrap();
        assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{v}");
        let v = json::parse(&handle_line(&s, &format!(r#"{{"cmd":"resume","job":{id}}}"#)).0)
            .unwrap();
        assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{v}");
        let v = json::parse(&handle_line(&s, &format!(r#"{{"cmd":"wait","job":{id}}}"#)).0)
            .unwrap();
        assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{v}");
        assert_eq!(v.get("stopped_early"), Some(&Json::Bool(false)), "shortened, not stopped");
        assert!(v.num_field("iters").unwrap() < 100000.0, "update must cap the run: {v}");
        // Control commands on a finished job are errors.
        let v = json::parse(&handle_line(&s, &format!(r#"{{"cmd":"pause","job":{id}}}"#)).0)
            .unwrap();
        assert_eq!(v.get("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    fn zero_iteration_job_yields_parseable_wait() {
        // A job can now legitimately finalise before any step runs
        // (iters:0, or stop before the first quantum); its KL is NaN,
        // which must serialise as null — not break the JSON line.
        let s = svc();
        let (resp, _) = handle_line(
            &s,
            r#"{"cmd":"submit","dataset":"gaussians","n":50,"engine":"bh-0.5","iters":0,"perplexity":5,"knn":"brute"}"#,
        );
        let id = json::parse(&resp).unwrap().num_field("job").unwrap() as u64;
        let (resp, _) = handle_line(&s, &format!(r#"{{"cmd":"wait","job":{id}}}"#));
        let v = json::parse(&resp)
            .expect("wait response must stay valid JSON with no iterations run");
        assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{resp}");
        assert_eq!(v.num_field("iters").unwrap() as usize, 0);
        assert_eq!(v.get("kl"), Some(&Json::Null), "NaN KL serialises as null: {resp}");
    }

    #[test]
    fn update_with_no_fields_is_an_error() {
        let s = svc();
        let (resp, _) = handle_line(
            &s,
            r#"{"cmd":"submit","dataset":"gaussians","n":80,"engine":"bh-0.5","iters":30,"perplexity":8,"knn":"brute"}"#,
        );
        let id = json::parse(&resp).unwrap().num_field("job").unwrap() as u64;
        let (resp, _) = handle_line(&s, &format!(r#"{{"cmd":"update","job":{id}}}"#));
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok"), Some(&Json::Bool(false)), "{v}");
        handle_line(&s, &format!(r#"{{"cmd":"wait","job":{id}}}"#));
    }

    #[test]
    fn bad_requests_get_errors_not_panics() {
        let s = svc();
        for line in [
            "not json",
            r#"{"cmd":"status","job":42}"#,
            r#"{"cmd":"frobnicate"}"#,
            r#"{"cmd":"submit","dataset":"bogus"}"#,
        ] {
            let (resp, keep) = handle_line(&s, line);
            let v = json::parse(&resp).unwrap();
            // submit of bogus dataset succeeds at submit time and fails in
            // the worker; everything else errors immediately.
            assert!(v.get("ok").is_some());
            assert!(keep);
        }
    }

    #[test]
    fn quit_closes() {
        let s = svc();
        let (resp, keep) = handle_line(&s, r#"{"cmd":"quit"}"#);
        assert!(!keep);
        assert!(resp.contains("bye"));
    }
}
