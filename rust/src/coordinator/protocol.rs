//! Line-oriented TCP protocol for the serve mode (DESIGN.md S20).
//!
//! One JSON object per line, both directions:
//!
//! ```text
//! -> {"cmd":"submit","dataset":"mnist","n":2000,"engine":"fieldcpu","iters":500}
//! <- {"ok":true,"job":1}
//! -> {"cmd":"status","job":1}
//! <- {"ok":true,"job":1,"phase":"optimizing 120/500","kl":2.31,"iter":119}
//! -> {"cmd":"snapshot","job":1}
//! <- {"ok":true,"job":1,"iter":119,"kl":2.31,"positions":[x0,y0,x1,y1,...]}
//! -> {"cmd":"stop","job":1}      // user-driven early termination
//! -> {"cmd":"wait","job":1}      // blocks until terminal
//! <- {"ok":true,"job":1,...,"knn_s":1.2,"perplexity_s":0.3,"sim_cache_hit":false}
//! -> {"cmd":"list"}
//! -> {"cmd":"stats"}             // similarity-cache hit/miss counters
//! -> {"cmd":"quit"}
//! ```
//!
//! `wait` reports the per-stage similarity timings and whether the job's
//! kNN + P matrix came from the coordinator similarity cache (a repeat
//! job over the same data: `knn_s + perplexity_s ≈ 0`).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use crate::embed::OptParams;
use crate::util::json::{self, Json};

use super::job::JobSpec;
use super::service::EmbeddingService;

/// Parse a submit command into a JobSpec (missing fields -> defaults).
pub fn spec_from_json(v: &Json) -> anyhow::Result<JobSpec> {
    let mut spec = JobSpec::default();
    if let Some(d) = v.str_field("dataset") {
        spec.dataset = d.to_string();
    }
    if let Some(n) = v.num_field("n") {
        spec.n = n as usize;
    }
    if let Some(e) = v.str_field("engine") {
        spec.engine = e.to_string();
    }
    if let Some(p) = v.num_field("perplexity") {
        spec.perplexity = p as f32;
    }
    if let Some(k) = v.str_field("knn") {
        spec.knn = k.parse()?;
    }
    let mut params = OptParams::default();
    if let Some(i) = v.num_field("iters") {
        params.iters = i as usize;
    }
    if let Some(e) = v.num_field("eta") {
        params.eta = e as f32;
    }
    if let Some(x) = v.num_field("exaggeration_iters") {
        params.exaggeration_iters = x as usize;
    }
    if let Some(s) = v.num_field("seed") {
        params.seed = s as u64;
        spec.seed = s as u64;
    }
    spec.params = params;
    if let Some(s) = v.num_field("snapshot_every") {
        spec.snapshot_every = s as usize;
    }
    Ok(spec)
}

fn ok_fields(fields: Vec<(&str, Json)>) -> String {
    let mut all = vec![("ok", Json::Bool(true))];
    all.extend(fields);
    Json::obj(all).to_string()
}

fn err_msg(msg: &str) -> String {
    Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::Str(msg.into()))]).to_string()
}

/// Handle one request line; returns (response line, keep_going).
pub fn handle_line(svc: &EmbeddingService, line: &str) -> (String, bool) {
    let v = match json::parse(line.trim()) {
        Ok(v) => v,
        Err(e) => return (err_msg(&format!("bad json: {e}")), true),
    };
    let cmd = v.str_field("cmd").unwrap_or("");
    match cmd {
        "submit" => match spec_from_json(&v) {
            Ok(spec) => {
                let id = svc.submit(spec);
                (ok_fields(vec![("job", Json::Num(id as f64))]), true)
            }
            Err(e) => (err_msg(&format!("{e:#}")), true),
        },
        "status" => {
            let id = v.num_field("job").unwrap_or(0.0) as u64;
            match svc.phase(id) {
                None => (err_msg("unknown job"), true),
                Some(phase) => {
                    let mut fields = vec![
                        ("job", Json::Num(id as f64)),
                        ("phase", Json::Str(phase.label())),
                        ("terminal", Json::Bool(phase.is_terminal())),
                    ];
                    if let Some(s) = svc.latest_snapshot(id) {
                        fields.push(("iter", Json::Num(s.iter as f64)));
                        fields.push(("kl", Json::Num(s.kl_est)));
                        fields.push(("elapsed_s", Json::Num(s.elapsed_s)));
                    }
                    (ok_fields(fields), true)
                }
            }
        }
        "snapshot" => {
            let id = v.num_field("job").unwrap_or(0.0) as u64;
            match svc.latest_snapshot(id) {
                None => (err_msg("no snapshot yet"), true),
                Some(s) => {
                    let pos = Json::Arr(s.positions.iter().map(|&p| Json::Num(p as f64)).collect());
                    (
                        ok_fields(vec![
                            ("job", Json::Num(id as f64)),
                            ("iter", Json::Num(s.iter as f64)),
                            ("kl", Json::Num(s.kl_est)),
                            ("positions", pos),
                        ]),
                        true,
                    )
                }
            }
        }
        "stop" => {
            let id = v.num_field("job").unwrap_or(0.0) as u64;
            if svc.stop(id) {
                (ok_fields(vec![("job", Json::Num(id as f64))]), true)
            } else {
                (err_msg("unknown job"), true)
            }
        }
        "wait" => {
            let id = v.num_field("job").unwrap_or(0.0) as u64;
            match svc.wait(id) {
                Ok(res) => (
                    ok_fields(vec![
                        ("job", Json::Num(id as f64)),
                        ("iters", Json::Num(res.iters_run as f64)),
                        ("kl", Json::Num(res.kl_est)),
                        ("stopped_early", Json::Bool(res.stopped_early)),
                        ("knn_s", Json::Num(res.timings.knn_s)),
                        ("perplexity_s", Json::Num(res.timings.perplexity_s)),
                        ("sim_cache_hit", Json::Bool(res.timings.sim_cache_hit)),
                        ("optimize_s", Json::Num(res.timings.optimize_s)),
                        ("total_s", Json::Num(res.timings.total())),
                    ]),
                    true,
                ),
                Err(e) => (err_msg(&format!("{e:#}")), true),
            }
        }
        "stats" => {
            let (hits, misses) = svc.sim_cache().stats();
            (
                ok_fields(vec![
                    ("sim_cache_hits", Json::Num(hits as f64)),
                    ("sim_cache_misses", Json::Num(misses as f64)),
                    ("sim_cache_entries", Json::Num(svc.sim_cache().len() as f64)),
                ]),
                true,
            )
        }
        "list" => {
            let jobs = Json::Arr(
                svc.list()
                    .into_iter()
                    .map(|(id, ph)| {
                        Json::obj(vec![
                            ("job", Json::Num(id as f64)),
                            ("phase", Json::Str(ph.label())),
                        ])
                    })
                    .collect(),
            );
            (ok_fields(vec![("jobs", jobs)]), true)
        }
        "quit" => (ok_fields(vec![("bye", Json::Bool(true))]), false),
        other => (err_msg(&format!("unknown cmd '{other}'")), true),
    }
}

fn handle_client(svc: Arc<EmbeddingService>, stream: TcpStream) {
    let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_default();
    let reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut writer = stream;
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let (resp, keep) = handle_line(&svc, &line);
        if writer.write_all(resp.as_bytes()).is_err() || writer.write_all(b"\n").is_err() {
            break;
        }
        if !keep {
            break;
        }
    }
    let _ = peer;
}

/// Serve forever on `addr` (e.g. `127.0.0.1:7878`). Returns the bound
/// address via callback (so callers/tests can bind port 0).
pub fn serve(
    svc: Arc<EmbeddingService>,
    addr: &str,
    on_bound: impl FnOnce(std::net::SocketAddr),
) -> anyhow::Result<()> {
    let listener = TcpListener::bind(addr)?;
    on_bound(listener.local_addr()?);
    for stream in listener.incoming() {
        let Ok(stream) = stream else { continue };
        let svc = svc.clone();
        std::thread::spawn(move || handle_client(svc, stream));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn svc() -> EmbeddingService {
        EmbeddingService::new(None, 2)
    }

    #[test]
    fn submit_status_wait_roundtrip() {
        let s = svc();
        let (resp, _) = handle_line(
            &s,
            r#"{"cmd":"submit","dataset":"gaussians","n":80,"engine":"bh-0.5","iters":20,"perplexity":8,"knn":"brute"}"#,
        );
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{resp}");
        let id = v.num_field("job").unwrap() as u64;

        let (resp, _) = handle_line(&s, &format!(r#"{{"cmd":"wait","job":{id}}}"#));
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{resp}");
        assert_eq!(v.num_field("iters").unwrap() as usize, 20);

        let (resp, _) = handle_line(&s, &format!(r#"{{"cmd":"status","job":{id}}}"#));
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.str_field("phase"), Some("done"));
        assert_eq!(v.get("terminal"), Some(&Json::Bool(true)));
    }

    #[test]
    fn snapshot_has_positions() {
        let s = svc();
        let (resp, _) = handle_line(
            &s,
            r#"{"cmd":"submit","dataset":"gaussians","n":60,"engine":"bh-0.5","iters":15,"perplexity":6,"knn":"brute","snapshot_every":1}"#,
        );
        let id = json::parse(&resp).unwrap().num_field("job").unwrap() as u64;
        handle_line(&s, &format!(r#"{{"cmd":"wait","job":{id}}}"#));
        let (resp, _) = handle_line(&s, &format!(r#"{{"cmd":"snapshot","job":{id}}}"#));
        let v = json::parse(&resp).unwrap();
        let pos = v.get("positions").unwrap().as_arr().unwrap();
        assert_eq!(pos.len(), 120);
    }

    #[test]
    fn repeat_submit_reports_cache_hit_and_stats() {
        let s = svc();
        let submit =
            r#"{"cmd":"submit","dataset":"gaussians","n":90,"engine":"bh-0.5","iters":15,"perplexity":8,"knn":"brute"}"#;
        let wait = |s: &EmbeddingService, id: u64| {
            json::parse(&handle_line(s, &format!(r#"{{"cmd":"wait","job":{id}}}"#)).0).unwrap()
        };
        let id1 = json::parse(&handle_line(&s, submit).0).unwrap().num_field("job").unwrap();
        let v = wait(&s, id1 as u64);
        assert_eq!(v.get("sim_cache_hit"), Some(&Json::Bool(false)), "{v}");
        assert!(v.num_field("knn_s").unwrap() >= 0.0);
        assert!(v.num_field("perplexity_s").unwrap() >= 0.0);

        let id2 = json::parse(&handle_line(&s, submit).0).unwrap().num_field("job").unwrap();
        let v = wait(&s, id2 as u64);
        assert_eq!(v.get("sim_cache_hit"), Some(&Json::Bool(true)), "{v}");
        assert_eq!(v.num_field("perplexity_s").unwrap(), 0.0);

        let v = json::parse(&handle_line(&s, r#"{"cmd":"stats"}"#).0).unwrap();
        assert_eq!(v.num_field("sim_cache_hits").unwrap() as u64, 1, "{v}");
        assert_eq!(v.num_field("sim_cache_misses").unwrap() as u64, 1);
        assert_eq!(v.num_field("sim_cache_entries").unwrap() as u64, 1);
    }

    #[test]
    fn bad_requests_get_errors_not_panics() {
        let s = svc();
        for line in [
            "not json",
            r#"{"cmd":"status","job":42}"#,
            r#"{"cmd":"frobnicate"}"#,
            r#"{"cmd":"submit","dataset":"bogus"}"#,
        ] {
            let (resp, keep) = handle_line(&s, line);
            let v = json::parse(&resp).unwrap();
            // submit of bogus dataset succeeds at submit time and fails in
            // the worker; everything else errors immediately.
            assert!(v.get("ok").is_some());
            assert!(keep);
        }
    }

    #[test]
    fn quit_closes() {
        let s = svc();
        let (resp, keep) = handle_line(&s, r#"{"cmd":"quit"}"#);
        assert!(!keep);
        assert!(resp.contains("bye"));
    }
}
