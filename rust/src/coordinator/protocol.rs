//! Line-oriented TCP protocol for the serve mode (DESIGN.md S20): one
//! JSON object per line in both directions.
//!
//! **The complete reference lives in `docs/PROTOCOL.md`** (repo root) —
//! every command, request/response schemas, error cases, and an
//! annotated session transcript. A test below asserts every [`Cmd`]
//! name appears there, so the doc cannot drift from this dispatcher.
//! The short version:
//!
//! ```text
//! submit status snapshot checkpoint pause resume update stop wait list
//! stats metrics trace fault shutdown quit migrate cluster_stats hello
//! ```
//!
//! The service behind these commands is the cooperative scheduler of
//! `service.rs` (sessions time-sliced in step quanta, live snapshots,
//! pause/resume parking, live re-parameterisation). `checkpoint`
//! returns a job's full optimiser state as a base64 blob; `submit`
//! accepts `resume_from` (such a blob) and/or `y0` (a client-supplied
//! layout), which together with `serve --state-dir` journaling makes
//! jobs durable across service restarts.
//!
//! The front end is **hardened** (docs/PROTOCOL.md "Failure
//! semantics"): request lines are read through a bounded framed reader
//! (over [`MAX_REQUEST_BYTES`] ⇒ a structured `request_too_large`
//! error and the connection closes, never unbounded buffering),
//! connections carry read/write timeouts, `serve` sheds accepts over a
//! connection cap with a retriable `server_busy` error, `submit` sheds
//! through the service's admission control (`queue_full` / `draining`),
//! `fault` arms the [`super::faultinject`] registry over the wire, and
//! `shutdown` drains the scheduler — park + journal every live session
//! — before the accept loop exits.
//!
//! The last three commands (`migrate`, `cluster_stats`, `hello`) belong
//! to the **router plane** ([`crate::cluster`]): a `pallas router`
//! process answers them, while a plain worker returns a structured
//! `router_only` error pointing clients at the router. They live in
//! [`Cmd`] anyway so the dispatcher, the usage error and the doc-drift
//! test stay a single source of truth across both planes.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::Duration;

use crate::embed::{Checkpoint, OptParams};
use crate::obs;
use crate::util::b64;
use crate::util::json::{self, Json};

use super::faultinject;
use super::job::{AutoStop, JobSpec, ParamUpdate};
use super::service::{EmbeddingService, SubmitError};

/// Hard cap on one request line. A line-oriented protocol must bound
/// what it buffers before parsing — without this, a client (or a fuzzer
/// stuck without newlines) grows the server's memory without limit.
/// 64 MiB comfortably fits the largest legitimate request (a `submit`
/// carrying a 100k-point `y0` plus a checkpoint blob).
pub const MAX_REQUEST_BYTES: usize = 64 << 20;

/// Per-connection socket timeouts. The read timeout bounds how long an
/// idle or wedged client may pin a connection slot (the server is not
/// reading while it executes a command, so slow *commands* are
/// unaffected); the write timeout bounds a client that stops draining
/// responses.
pub const READ_TIMEOUT: Duration = Duration::from_secs(120);
pub const WRITE_TIMEOUT: Duration = Duration::from_secs(30);

/// Accept-time connection cap for [`serve`]. Connections past the cap
/// get one `server_busy` error line and are closed.
pub const MAX_CONNECTIONS: usize = 256;

/// How long one `net.stall` fault holds a connection before the request
/// is handled — long enough for the chaos harness to overlap stalled
/// and healthy clients, short enough to stay well inside the timeouts.
const STALL_MS: u64 = 250;

/// The protocol's command set. `ALL` and `name()` are the single source
/// of truth the dispatcher, the usage error and the `docs/PROTOCOL.md`
/// sync test all share.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Cmd {
    Submit,
    Status,
    Snapshot,
    Checkpoint,
    Pause,
    Resume,
    Update,
    Stop,
    Wait,
    List,
    Stats,
    Metrics,
    Trace,
    Fault,
    Shutdown,
    Quit,
    /// Router plane: move a live job to another worker
    /// (checkpoint → stop → resume elsewhere). Workers reject it.
    Migrate,
    /// Router plane: membership, per-shard ownership and
    /// failover/migration counters. Workers reject it.
    ClusterStats,
    /// Router plane: a worker announcing itself (`serve --router`);
    /// doubles as the heartbeat refresh. Workers reject it.
    Hello,
}

impl Cmd {
    pub const ALL: &'static [Cmd] = &[
        Cmd::Submit,
        Cmd::Status,
        Cmd::Snapshot,
        Cmd::Checkpoint,
        Cmd::Pause,
        Cmd::Resume,
        Cmd::Update,
        Cmd::Stop,
        Cmd::Wait,
        Cmd::List,
        Cmd::Stats,
        Cmd::Metrics,
        Cmd::Trace,
        Cmd::Fault,
        Cmd::Shutdown,
        Cmd::Quit,
        Cmd::Migrate,
        Cmd::ClusterStats,
        Cmd::Hello,
    ];

    /// Wire name (the `cmd` field).
    pub fn name(&self) -> &'static str {
        match self {
            Cmd::Submit => "submit",
            Cmd::Status => "status",
            Cmd::Snapshot => "snapshot",
            Cmd::Checkpoint => "checkpoint",
            Cmd::Pause => "pause",
            Cmd::Resume => "resume",
            Cmd::Update => "update",
            Cmd::Stop => "stop",
            Cmd::Wait => "wait",
            Cmd::List => "list",
            Cmd::Stats => "stats",
            Cmd::Metrics => "metrics",
            Cmd::Trace => "trace",
            Cmd::Fault => "fault",
            Cmd::Shutdown => "shutdown",
            Cmd::Quit => "quit",
            Cmd::Migrate => "migrate",
            Cmd::ClusterStats => "cluster_stats",
            Cmd::Hello => "hello",
        }
    }

    pub fn parse(s: &str) -> Option<Cmd> {
        Cmd::ALL.iter().copied().find(|c| c.name() == s)
    }
}

/// Parse a submit command into a JobSpec (missing fields -> defaults).
pub fn spec_from_json(v: &Json) -> anyhow::Result<JobSpec> {
    let mut spec = JobSpec::default();
    if let Some(d) = v.str_field("dataset") {
        spec.dataset = d.to_string();
    }
    if let Some(n) = v.num_field("n") {
        // Bound the allocation-driving fields up front: a huge or
        // non-finite `n` must be a structured submit error, not an
        // admitted job that OOMs a worker.
        anyhow::ensure!(n.is_finite() && (0.0..=1e8).contains(&n), "n out of range: {n}");
        spec.n = n as usize;
    }
    if let Some(e) = v.str_field("engine") {
        spec.engine = e.to_string();
    }
    if let Some(p) = v.num_field("perplexity") {
        spec.perplexity = p as f32;
    }
    if let Some(k) = v.str_field("knn") {
        spec.knn = k.parse()?;
    }
    if let Some(p) = v.str_field("priority") {
        spec.priority = p.parse()?;
    }
    let mut params = OptParams::default();
    if let Some(i) = v.num_field("iters") {
        anyhow::ensure!(i.is_finite() && (0.0..=1e9).contains(&i), "iters out of range: {i}");
        params.iters = i as usize;
    }
    if let Some(e) = v.num_field("eta") {
        params.eta = e as f32;
    }
    if let Some(x) = v.num_field("exaggeration") {
        params.exaggeration = x as f32;
    }
    if let Some(x) = v.num_field("exaggeration_iters") {
        params.exaggeration_iters = x as usize;
    }
    if let Some(m) = v.num_field("momentum0") {
        params.momentum0 = m as f32;
    }
    if let Some(m) = v.num_field("momentum1") {
        params.momentum1 = m as f32;
    }
    if let Some(m) = v.num_field("momentum_switch") {
        params.momentum_switch = m as usize;
    }
    if let Some(s) = v.num_field("init_std") {
        params.init_std = s as f32;
    }
    if let Some(s) = v.num_field("seed") {
        params.seed = s as u64;
        spec.seed = s as u64;
    }
    spec.params = params;
    if let Some(s) = v.num_field("snapshot_every") {
        spec.snapshot_every = s as usize;
    }
    if let Some(w) = v.num_field("auto_stop_window") {
        spec.auto_stop = Some(AutoStop {
            window: (w as usize).max(1),
            rel_eps: v.num_field("auto_stop_eps").unwrap_or(1e-5),
        });
    }
    if let Some(y0) = v.get("y0") {
        let arr = y0
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("y0 must be a flat [x0,y0,x1,y1,...] array"))?;
        let vals = arr
            .iter()
            .map(|e| e.as_f64().map(|f| f as f32))
            .collect::<Option<Vec<f32>>>()
            .ok_or_else(|| anyhow::anyhow!("y0 must contain only numbers"))?;
        anyhow::ensure!(vals.len() % 2 == 0, "y0 length {} is not 2·n", vals.len());
        spec.y0 = Some(vals);
    }
    if let Some(blob) = v.str_field("resume_from") {
        let bytes = b64::decode(blob)
            .map_err(|e| anyhow::anyhow!("resume_from is not valid base64: {e}"))?;
        // Validate eagerly so a bad blob fails the submit, not the job.
        Checkpoint::from_bytes(&bytes)
            .map_err(|e| anyhow::anyhow!("resume_from is not a valid checkpoint: {e:#}"))?;
        spec.resume_from = Some(bytes);
    }
    Ok(spec)
}

/// Inverse of [`spec_from_json`] over the same wire field names — what
/// the checkpoint journal persists, so a re-admitted job parses through
/// the identical code path as a TCP submit. `y0` is emitted when
/// present (an admit-time journal record written before any checkpoint
/// must preserve the warm start); `resume_from` never is — the journal
/// carries the checkpoint out of band.
pub fn spec_to_json(spec: &JobSpec) -> Json {
    let mut fields = vec![
        ("dataset", Json::Str(spec.dataset.clone())),
        ("n", Json::Num(spec.n as f64)),
        ("engine", Json::Str(spec.engine.clone())),
        ("perplexity", Json::Num(spec.perplexity as f64)),
        ("knn", Json::Str(spec.knn.backend_name().into())),
        ("iters", Json::Num(spec.params.iters as f64)),
        ("eta", Json::Num(spec.params.eta as f64)),
        ("exaggeration", Json::Num(spec.params.exaggeration as f64)),
        ("exaggeration_iters", Json::Num(spec.params.exaggeration_iters as f64)),
        ("momentum0", Json::Num(spec.params.momentum0 as f64)),
        ("momentum1", Json::Num(spec.params.momentum1 as f64)),
        ("momentum_switch", Json::Num(spec.params.momentum_switch as f64)),
        ("init_std", Json::Num(spec.params.init_std as f64)),
        ("seed", Json::Num(spec.seed as f64)),
        ("snapshot_every", Json::Num(spec.snapshot_every as f64)),
        ("priority", Json::Str(spec.priority.label().into())),
    ];
    if let Some(auto) = &spec.auto_stop {
        fields.push(("auto_stop_window", Json::Num(auto.window as f64)));
        fields.push(("auto_stop_eps", Json::Num(auto.rel_eps)));
    }
    if let Some(y0) = &spec.y0 {
        fields.push(("y0", Json::Arr(y0.iter().map(|&v| Json::Num(v as f64)).collect())));
    }
    Json::obj(fields)
}

/// Parse the optional fields of an `update` command.
pub fn update_from_json(v: &Json) -> ParamUpdate {
    ParamUpdate {
        iters: v.num_field("iters").map(|x| x as usize),
        eta: v.num_field("eta").map(|x| x as f32),
        exaggeration: v.num_field("exaggeration").map(|x| x as f32),
        exaggeration_iters: v.num_field("exaggeration_iters").map(|x| x as usize),
        momentum0: v.num_field("momentum0").map(|x| x as f32),
        momentum1: v.num_field("momentum1").map(|x| x as f32),
        momentum_switch: v.num_field("momentum_switch").map(|x| x as usize),
    }
}

/// `snapshot.deliver_lag_ns` — age of a snapshot when a client fetched
/// it (publish timestamp vs. read time). The CLI's streaming printer
/// records into the same global histogram.
fn deliver_lag_ns() -> &'static Arc<obs::Histogram> {
    static H: OnceLock<Arc<obs::Histogram>> = OnceLock::new();
    H.get_or_init(|| obs::registry().histogram("snapshot.deliver_lag_ns"))
}

pub(crate) fn ok_fields(fields: Vec<(&str, Json)>) -> String {
    let mut all = vec![("ok", Json::Bool(true))];
    all.extend(fields);
    Json::obj(all).to_string()
}

pub(crate) fn err_msg(msg: &str) -> String {
    Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::Str(msg.into()))]).to_string()
}

/// Structured error with a machine-readable `code` and a `retriable`
/// hint — the shedding/overload responses (`queue_full`, `draining`,
/// `server_busy`, `request_too_large`) where a client must distinguish
/// "back off and retry" from "your request is broken".
pub(crate) fn err_code(code: &str, retriable: bool, msg: &str) -> String {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("error", Json::Str(msg.into())),
        ("code", Json::Str(code.into())),
        ("retriable", Json::Bool(retriable)),
    ])
    .to_string()
}

/// `net.connections_open` — live connections (gauge).
fn conns_open() -> &'static Arc<obs::Gauge> {
    static G: OnceLock<Arc<obs::Gauge>> = OnceLock::new();
    G.get_or_init(|| obs::registry().gauge("net.connections_open"))
}

/// `net.connections_shed` — accepts refused at the connection cap.
fn conns_shed() -> &'static Arc<obs::Counter> {
    static C: OnceLock<Arc<obs::Counter>> = OnceLock::new();
    C.get_or_init(|| obs::registry().counter("net.connections_shed"))
}

/// `net.requests_too_large` — request lines that blew [`MAX_REQUEST_BYTES`].
fn requests_too_large() -> &'static Arc<obs::Counter> {
    static C: OnceLock<Arc<obs::Counter>> = OnceLock::new();
    C.get_or_init(|| obs::registry().counter("net.requests_too_large"))
}

/// Handle one request line; returns (response line, keep_going).
pub fn handle_line(svc: &EmbeddingService, line: &str) -> (String, bool) {
    let v = match json::parse(line.trim()) {
        Ok(v) => v,
        Err(e) => return (err_msg(&format!("bad json: {e}")), true),
    };
    let name = v.str_field("cmd").unwrap_or("");
    let Some(cmd) = Cmd::parse(name) else {
        return (err_msg(&format!("unknown cmd '{name}'")), true);
    };
    match cmd {
        Cmd::Submit => match spec_from_json(&v) {
            // TCP submits go through admission control; in-process
            // callers (CLI, journal re-admission) use the infallible
            // `submit` directly.
            Ok(spec) => match svc.try_submit(spec) {
                Ok(id) => (ok_fields(vec![("job", Json::Num(id as f64))]), true),
                Err(e @ SubmitError::QueueFull { .. }) => {
                    (err_code("queue_full", true, &e.to_string()), true)
                }
                Err(e @ SubmitError::Draining) => {
                    (err_code("draining", true, &e.to_string()), true)
                }
            },
            Err(e) => (err_msg(&format!("{e:#}")), true),
        },
        Cmd::Status => {
            let id = v.num_field("job").unwrap_or(0.0) as u64;
            match svc.phase(id) {
                None => (err_msg("unknown job"), true),
                Some(phase) => {
                    let mut fields = vec![
                        ("job", Json::Num(id as f64)),
                        ("phase", Json::Str(phase.label())),
                        ("terminal", Json::Bool(phase.is_terminal())),
                    ];
                    if let Some(s) = svc.latest_snapshot(id) {
                        fields.push(("iter", Json::Num(s.iter as f64)));
                        fields.push(("kl", Json::Num(s.kl_est)));
                        fields.push(("elapsed_s", Json::Num(s.elapsed_s)));
                    }
                    (ok_fields(fields), true)
                }
            }
        }
        Cmd::Snapshot => {
            let id = v.num_field("job").unwrap_or(0.0) as u64;
            match svc.latest_snapshot(id) {
                None => (err_msg("no snapshot yet"), true),
                Some(s) => {
                    deliver_lag_ns().record(obs::now_ns().saturating_sub(s.published_ns));
                    let pos = Json::Arr(s.positions.iter().map(|&p| Json::Num(p as f64)).collect());
                    (
                        ok_fields(vec![
                            ("job", Json::Num(id as f64)),
                            ("iter", Json::Num(s.iter as f64)),
                            ("kl", Json::Num(s.kl_est)),
                            ("positions", pos),
                        ]),
                        true,
                    )
                }
            }
        }
        Cmd::Checkpoint => {
            let id = v.num_field("job").unwrap_or(0.0) as u64;
            match svc.checkpoint(id) {
                Err(e) => (err_msg(&format!("{e:#}")), true),
                Ok(ck) => (
                    ok_fields(vec![
                        ("job", Json::Num(id as f64)),
                        ("engine", Json::Str(ck.engine.clone())),
                        ("iter", Json::Num(ck.iter as f64)),
                        ("elapsed_s", Json::Num(ck.elapsed_s)),
                        ("checkpoint", Json::Str(b64::encode(&ck.to_bytes()))),
                    ]),
                    true,
                ),
            }
        }
        Cmd::Stop => {
            let id = v.num_field("job").unwrap_or(0.0) as u64;
            if svc.stop(id) {
                (ok_fields(vec![("job", Json::Num(id as f64))]), true)
            } else {
                (err_msg("unknown job"), true)
            }
        }
        Cmd::Pause => {
            let id = v.num_field("job").unwrap_or(0.0) as u64;
            if svc.pause(id) {
                (ok_fields(vec![("job", Json::Num(id as f64))]), true)
            } else {
                (err_msg("unknown or finished job"), true)
            }
        }
        Cmd::Resume => {
            let id = v.num_field("job").unwrap_or(0.0) as u64;
            if svc.resume(id) {
                (ok_fields(vec![("job", Json::Num(id as f64))]), true)
            } else {
                (err_msg("unknown or finished job"), true)
            }
        }
        Cmd::Update => {
            let id = v.num_field("job").unwrap_or(0.0) as u64;
            let update = update_from_json(&v);
            if update.is_empty() {
                (err_msg("update carries no fields (iters/eta/exaggeration/exaggeration_iters/momentum0/momentum1/momentum_switch)"), true)
            } else if svc.update(id, update) {
                (ok_fields(vec![("job", Json::Num(id as f64))]), true)
            } else {
                (err_msg("unknown or finished job"), true)
            }
        }
        Cmd::Wait => {
            let id = v.num_field("job").unwrap_or(0.0) as u64;
            match svc.wait(id) {
                Ok(res) => {
                    let mut fields = vec![
                        ("job", Json::Num(id as f64)),
                        ("iters", Json::Num(res.iters_run as f64)),
                        ("kl", Json::Num(res.kl_est)),
                        ("stopped_early", Json::Bool(res.stopped_early)),
                    ];
                    fields.extend(res.timings.to_json_fields());
                    (ok_fields(fields), true)
                }
                Err(e) => (err_msg(&format!("{e:#}")), true),
            }
        }
        Cmd::Stats => {
            let cache = svc.sim_cache();
            let (hits, misses) = cache.stats();
            let g = cache.graph_stats();
            (
                ok_fields(vec![
                    ("sim_cache_hits", Json::Num(hits as f64)),
                    ("sim_cache_misses", Json::Num(misses as f64)),
                    ("sim_cache_computes", Json::Num(cache.computes() as f64)),
                    ("sim_cache_entries", Json::Num(cache.len() as f64)),
                    ("sim_cache_disk_hits", Json::Num(cache.p_stats().disk_hits as f64)),
                    ("knn_cache_hits", Json::Num(g.hits as f64)),
                    ("knn_cache_computes", Json::Num(g.computes as f64)),
                    ("knn_cache_entries", Json::Num(cache.graph_len() as f64)),
                    ("knn_cache_disk_hits", Json::Num(g.disk_hits as f64)),
                ]),
                true,
            )
        }
        Cmd::List => {
            let jobs = Json::Arr(
                svc.list()
                    .into_iter()
                    .map(|(id, ph)| {
                        Json::obj(vec![
                            ("job", Json::Num(id as f64)),
                            ("phase", Json::Str(ph.label())),
                        ])
                    })
                    .collect(),
            );
            (ok_fields(vec![("jobs", jobs)]), true)
        }
        Cmd::Metrics => (ok_fields(vec![("metrics", svc.metrics_json())]), true),
        Cmd::Trace => {
            let job = v.num_field("job").map(|j| j as u64);
            let last = v.num_field("last").unwrap_or(128.0).max(1.0) as usize;
            let events = obs::trace::snapshot(job, last);
            (
                ok_fields(vec![
                    ("count", Json::Num(events.len() as f64)),
                    ("events", Json::Arr(events.iter().map(|e| e.to_json()).collect())),
                ]),
                true,
            )
        }
        Cmd::Fault => {
            // `clear` first, then `spec`: `{"clear":true,"spec":...}` is
            // replace-all. Either way the response reports live status.
            if v.get("clear") == Some(&Json::Bool(true)) {
                faultinject::disarm_all();
            }
            if let Some(spec) = v.str_field("spec") {
                if let Err(e) = faultinject::arm_spec(spec) {
                    return (err_msg(&format!("bad fault spec: {e}")), true);
                }
            }
            let points = Json::Arr(
                faultinject::status()
                    .into_iter()
                    .map(|p| {
                        Json::obj(vec![
                            ("point", Json::Str(p.point.into())),
                            ("trigger", Json::Str(p.trigger)),
                            ("checks", Json::Num(p.checks as f64)),
                            ("fired", Json::Num(p.fired as f64)),
                        ])
                    })
                    .collect(),
            );
            (
                ok_fields(vec![
                    ("enabled", Json::Bool(faultinject::enabled())),
                    ("points", points),
                ]),
                true,
            )
        }
        Cmd::Shutdown => {
            // Drain runs inline on this connection's thread: the
            // response is the handshake's completion — once the client
            // reads it, every live job is parked + journalled (or the
            // timeout expired) and admission is off for good.
            let t = v.num_field("timeout_s").unwrap_or(30.0);
            let t = if t.is_finite() { t.clamp(0.0, 600.0) } else { 30.0 };
            let parked = svc.drain(Duration::from_secs_f64(t));
            (
                ok_fields(vec![
                    ("draining", Json::Bool(true)),
                    ("parked_jobs", Json::Num(parked as f64)),
                ]),
                false,
            )
        }
        Cmd::Quit => (ok_fields(vec![("bye", Json::Bool(true))]), false),
        // Router-plane commands answered by `pallas router`
        // (`crate::cluster`), not by a worker. The structured code lets
        // a client that connected to the wrong plane correct itself.
        Cmd::Migrate | Cmd::ClusterStats | Cmd::Hello => (
            err_code(
                "router_only",
                false,
                &format!("'{}' is a router command; this endpoint is a worker", cmd.name()),
            ),
            true,
        ),
    }
}

/// Outcome of one bounded framed read.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum LineRead {
    /// One complete line is in the buffer (newline stripped).
    Line,
    /// Clean end of stream with nothing buffered.
    Eof,
    /// The line exceeded the cap; whatever arrived was discarded, not
    /// buffered.
    TooLarge,
}

/// Read one `\n`-terminated line into `out`, never holding more than
/// `max` bytes. The replacement for `BufRead::lines()` on the request
/// path: `lines()` buffers an entire line before returning it, so a
/// newline-free stream grows the allocation without bound.
pub(crate) fn read_bounded_line<R: BufRead>(
    r: &mut R,
    out: &mut Vec<u8>,
    max: usize,
) -> std::io::Result<LineRead> {
    out.clear();
    loop {
        let avail = match r.fill_buf() {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        };
        if avail.is_empty() {
            // EOF: a final unterminated line still counts.
            return Ok(if out.is_empty() { LineRead::Eof } else { LineRead::Line });
        }
        if let Some(pos) = avail.iter().position(|&b| b == b'\n') {
            if out.len() + pos > max {
                r.consume(pos + 1);
                return Ok(LineRead::TooLarge);
            }
            out.extend_from_slice(&avail[..pos]);
            r.consume(pos + 1);
            return Ok(LineRead::Line);
        }
        let take = avail.len();
        if out.len() + take > max {
            r.consume(take);
            return Ok(LineRead::TooLarge);
        }
        out.extend_from_slice(avail);
        r.consume(take);
    }
}

fn handle_client(svc: Arc<EmbeddingService>, stream: TcpStream, local: std::net::SocketAddr) {
    let _ = stream.set_read_timeout(Some(READ_TIMEOUT));
    let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
    let mut reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut writer = stream;
    let mut buf = Vec::new();
    loop {
        match read_bounded_line(&mut reader, &mut buf, MAX_REQUEST_BYTES) {
            // Timeouts surface here as WouldBlock/TimedOut: close.
            Err(_) | Ok(LineRead::Eof) => break,
            Ok(LineRead::TooLarge) => {
                requests_too_large().inc();
                let resp = err_code(
                    "request_too_large",
                    false,
                    &format!("request exceeds {MAX_REQUEST_BYTES} bytes; closing connection"),
                );
                let _ = writer.write_all(resp.as_bytes());
                let _ = writer.write_all(b"\n");
                break;
            }
            Ok(LineRead::Line) => {
                let line = String::from_utf8_lossy(&buf);
                if line.trim().is_empty() {
                    continue;
                }
                // `net.stall`: hold the connection mid-request the way a
                // wedged client or network would, so the chaos harness
                // overlaps stalled and healthy traffic.
                if faultinject::fire(faultinject::NET_STALL) {
                    std::thread::sleep(Duration::from_millis(STALL_MS));
                }
                let (resp, keep) = handle_line(&svc, &line);
                if writer.write_all(resp.as_bytes()).is_err() || writer.write_all(b"\n").is_err()
                {
                    break;
                }
                if !keep {
                    break;
                }
            }
        }
    }
    // A `shutdown` handled on this connection leaves the accept loop
    // blocked in `accept`; poke it so `serve` observes the drain and
    // exits. (Harmless no-op once the listener is gone.)
    if svc.is_draining() {
        let _ = TcpStream::connect(local);
    }
}

/// Serve on `addr` (e.g. `127.0.0.1:7878`) until drained. Returns the
/// bound address via callback (so callers/tests can bind port 0).
pub fn serve(
    svc: Arc<EmbeddingService>,
    addr: &str,
    on_bound: impl FnOnce(std::net::SocketAddr),
) -> anyhow::Result<()> {
    serve_with(svc, addr, MAX_CONNECTIONS, on_bound)
}

/// [`serve`] with an explicit connection cap. Accepts past the cap are
/// shed at accept time with one retriable `server_busy` error line —
/// bounded thread count, no silently growing backlog. The loop exits
/// once the service is draining (the `shutdown` command, or SIGTERM via
/// `EmbeddingService::drain` plus a wake-up connection).
pub fn serve_with(
    svc: Arc<EmbeddingService>,
    addr: &str,
    max_connections: usize,
    on_bound: impl FnOnce(std::net::SocketAddr),
) -> anyhow::Result<()> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    on_bound(local);
    let live = Arc::new(AtomicUsize::new(0));
    for stream in listener.incoming() {
        if svc.is_draining() {
            break;
        }
        let Ok(stream) = stream else { continue };
        if live.load(Ordering::SeqCst) >= max_connections.max(1) {
            conns_shed().inc();
            let mut s = stream;
            let _ = s.set_write_timeout(Some(WRITE_TIMEOUT));
            let resp = err_code("server_busy", true, "connection cap reached; retry later");
            let _ = s.write_all(resp.as_bytes());
            let _ = s.write_all(b"\n");
            continue;
        }
        live.fetch_add(1, Ordering::SeqCst);
        conns_open().add(1);
        let svc = svc.clone();
        let live = live.clone();
        std::thread::spawn(move || {
            handle_client(svc, stream, local);
            live.fetch_sub(1, Ordering::SeqCst);
            conns_open().add(-1);
        });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn svc() -> EmbeddingService {
        EmbeddingService::new(None, 2)
    }

    #[test]
    fn submit_status_wait_roundtrip() {
        let s = svc();
        let (resp, _) = handle_line(
            &s,
            r#"{"cmd":"submit","dataset":"gaussians","n":80,"engine":"bh-0.5","iters":20,"perplexity":8,"knn":"brute"}"#,
        );
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{resp}");
        let id = v.num_field("job").unwrap() as u64;

        let (resp, _) = handle_line(&s, &format!(r#"{{"cmd":"wait","job":{id}}}"#));
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{resp}");
        assert_eq!(v.num_field("iters").unwrap() as usize, 20);

        let (resp, _) = handle_line(&s, &format!(r#"{{"cmd":"status","job":{id}}}"#));
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.str_field("phase"), Some("done"));
        assert_eq!(v.get("terminal"), Some(&Json::Bool(true)));
    }

    #[test]
    fn snapshot_has_positions() {
        let s = svc();
        let (resp, _) = handle_line(
            &s,
            r#"{"cmd":"submit","dataset":"gaussians","n":60,"engine":"bh-0.5","iters":15,"perplexity":6,"knn":"brute","snapshot_every":1}"#,
        );
        let id = json::parse(&resp).unwrap().num_field("job").unwrap() as u64;
        handle_line(&s, &format!(r#"{{"cmd":"wait","job":{id}}}"#));
        let (resp, _) = handle_line(&s, &format!(r#"{{"cmd":"snapshot","job":{id}}}"#));
        let v = json::parse(&resp).unwrap();
        let pos = v.get("positions").unwrap().as_arr().unwrap();
        assert_eq!(pos.len(), 120);
    }

    #[test]
    fn repeat_submit_reports_cache_hit_and_stats() {
        let s = svc();
        let submit =
            r#"{"cmd":"submit","dataset":"gaussians","n":90,"engine":"bh-0.5","iters":15,"perplexity":8,"knn":"brute"}"#;
        let wait = |s: &EmbeddingService, id: u64| {
            json::parse(&handle_line(s, &format!(r#"{{"cmd":"wait","job":{id}}}"#)).0).unwrap()
        };
        let id1 = json::parse(&handle_line(&s, submit).0).unwrap().num_field("job").unwrap();
        let v = wait(&s, id1 as u64);
        assert_eq!(v.get("sim_cache_hit"), Some(&Json::Bool(false)), "{v}");
        assert!(v.num_field("knn_s").unwrap() >= 0.0);
        assert!(v.num_field("perplexity_s").unwrap() >= 0.0);

        let id2 = json::parse(&handle_line(&s, submit).0).unwrap().num_field("job").unwrap();
        let v = wait(&s, id2 as u64);
        assert_eq!(v.get("sim_cache_hit"), Some(&Json::Bool(true)), "{v}");
        assert_eq!(v.num_field("perplexity_s").unwrap(), 0.0);

        let v = json::parse(&handle_line(&s, r#"{"cmd":"stats"}"#).0).unwrap();
        assert_eq!(v.num_field("sim_cache_hits").unwrap() as u64, 1, "{v}");
        assert_eq!(v.num_field("sim_cache_misses").unwrap() as u64, 1);
        assert_eq!(v.num_field("sim_cache_entries").unwrap() as u64, 1);
    }

    #[test]
    fn submit_parses_auto_stop() {
        let v = json::parse(r#"{"cmd":"submit","auto_stop_window":25,"auto_stop_eps":0.001}"#)
            .unwrap();
        let auto = spec_from_json(&v).unwrap().auto_stop.expect("auto stop set");
        assert_eq!(auto.window, 25);
        assert!((auto.rel_eps - 0.001).abs() < 1e-12);
        // Window alone gets the default epsilon.
        let v = json::parse(r#"{"cmd":"submit","auto_stop_window":10}"#).unwrap();
        assert_eq!(spec_from_json(&v).unwrap().auto_stop.unwrap().rel_eps, 1e-5);
        // Absent -> none (the pre-existing default).
        let v = json::parse(r#"{"cmd":"submit"}"#).unwrap();
        assert!(spec_from_json(&v).unwrap().auto_stop.is_none());
    }

    #[test]
    fn pause_update_resume_cycle() {
        let s = svc();
        let (resp, _) = handle_line(
            &s,
            r#"{"cmd":"submit","dataset":"gaussians","n":120,"engine":"bh-0.5","iters":100000,"perplexity":8,"knn":"brute"}"#,
        );
        let id = json::parse(&resp).unwrap().num_field("job").unwrap() as u64;
        let status = |s: &EmbeddingService| {
            json::parse(&handle_line(s, &format!(r#"{{"cmd":"status","job":{id}}}"#)).0).unwrap()
        };
        // Wait until it is optimising, then pause.
        while !status(&s).str_field("phase").unwrap_or("").starts_with("optimizing") {
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        let v = json::parse(&handle_line(&s, &format!(r#"{{"cmd":"pause","job":{id}}}"#)).0)
            .unwrap();
        assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{v}");
        // The scheduler parks it at the next step boundary.
        let paused_iter = loop {
            let v = status(&s);
            let phase = v.str_field("phase").unwrap_or("").to_string();
            if phase.starts_with("paused") {
                break v.num_field("iter").unwrap_or(0.0) as usize;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        };
        // Re-parameterise while parked: cut the run short.
        let cut = paused_iter.max(1) + 1;
        let v = json::parse(
            &handle_line(&s, &format!(r#"{{"cmd":"update","job":{id},"iters":{cut},"eta":50}}"#))
                .0,
        )
        .unwrap();
        assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{v}");
        let v = json::parse(&handle_line(&s, &format!(r#"{{"cmd":"resume","job":{id}}}"#)).0)
            .unwrap();
        assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{v}");
        let v = json::parse(&handle_line(&s, &format!(r#"{{"cmd":"wait","job":{id}}}"#)).0)
            .unwrap();
        assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{v}");
        assert_eq!(v.get("stopped_early"), Some(&Json::Bool(false)), "shortened, not stopped");
        assert!(v.num_field("iters").unwrap() < 100000.0, "update must cap the run: {v}");
        // Control commands on a finished job are errors.
        let v = json::parse(&handle_line(&s, &format!(r#"{{"cmd":"pause","job":{id}}}"#)).0)
            .unwrap();
        assert_eq!(v.get("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    fn zero_iteration_job_yields_parseable_wait() {
        // A job can now legitimately finalise before any step runs
        // (iters:0, or stop before the first quantum); its KL is NaN,
        // which must serialise as null — not break the JSON line.
        let s = svc();
        let (resp, _) = handle_line(
            &s,
            r#"{"cmd":"submit","dataset":"gaussians","n":50,"engine":"bh-0.5","iters":0,"perplexity":5,"knn":"brute"}"#,
        );
        let id = json::parse(&resp).unwrap().num_field("job").unwrap() as u64;
        let (resp, _) = handle_line(&s, &format!(r#"{{"cmd":"wait","job":{id}}}"#));
        let v = json::parse(&resp)
            .expect("wait response must stay valid JSON with no iterations run");
        assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{resp}");
        assert_eq!(v.num_field("iters").unwrap() as usize, 0);
        assert_eq!(v.get("kl"), Some(&Json::Null), "NaN KL serialises as null: {resp}");
    }

    #[test]
    fn update_with_no_fields_is_an_error() {
        let s = svc();
        let (resp, _) = handle_line(
            &s,
            r#"{"cmd":"submit","dataset":"gaussians","n":80,"engine":"bh-0.5","iters":30,"perplexity":8,"knn":"brute"}"#,
        );
        let id = json::parse(&resp).unwrap().num_field("job").unwrap() as u64;
        let (resp, _) = handle_line(&s, &format!(r#"{{"cmd":"update","job":{id}}}"#));
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok"), Some(&Json::Bool(false)), "{v}");
        handle_line(&s, &format!(r#"{{"cmd":"wait","job":{id}}}"#));
    }

    #[test]
    fn bad_requests_get_errors_not_panics() {
        let s = svc();
        for line in [
            "not json",
            r#"{"cmd":"status","job":42}"#,
            r#"{"cmd":"frobnicate"}"#,
            r#"{"cmd":"submit","dataset":"bogus"}"#,
        ] {
            let (resp, keep) = handle_line(&s, line);
            let v = json::parse(&resp).unwrap();
            // submit of bogus dataset succeeds at submit time and fails in
            // the worker; everything else errors immediately.
            assert!(v.get("ok").is_some());
            assert!(keep);
        }
    }

    #[test]
    fn quit_closes() {
        let s = svc();
        let (resp, keep) = handle_line(&s, r#"{"cmd":"quit"}"#);
        assert!(!keep);
        assert!(resp.contains("bye"));
    }

    #[test]
    fn checkpoint_then_resume_from_roundtrips() {
        let s = svc();
        let (resp, _) = handle_line(
            &s,
            r#"{"cmd":"submit","dataset":"gaussians","n":80,"engine":"bh-0.5","iters":100000,"perplexity":8,"knn":"brute"}"#,
        );
        let id = json::parse(&resp).unwrap().num_field("job").unwrap() as u64;
        // Wait until stepping, then grab a live checkpoint.
        while !json::parse(&handle_line(&s, &format!(r#"{{"cmd":"status","job":{id}}}"#)).0)
            .unwrap()
            .str_field("phase")
            .unwrap_or("")
            .starts_with("optimizing")
        {
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        let (resp, _) = handle_line(&s, &format!(r#"{{"cmd":"checkpoint","job":{id}}}"#));
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{resp}");
        assert_eq!(v.str_field("engine"), Some("bh-0.5"));
        let iter = v.num_field("iter").unwrap() as usize;
        assert!(iter > 0);
        let blob = v.str_field("checkpoint").unwrap().to_string();
        // The blob is framed base64 of the byte codec.
        let ck = crate::embed::Checkpoint::from_bytes(
            &crate::util::b64::decode(&blob).expect("valid base64"),
        )
        .expect("valid checkpoint");
        assert_eq!(ck.iter, iter);
        handle_line(&s, &format!(r#"{{"cmd":"stop","job":{id}}}"#));
        handle_line(&s, &format!(r#"{{"cmd":"wait","job":{id}}}"#));

        // Submit a resumed job from the blob: it continues past `iter`.
        let horizon = iter + 5;
        let (resp, _) = handle_line(
            &s,
            &format!(
                r#"{{"cmd":"submit","dataset":"gaussians","n":80,"engine":"bh-0.5","iters":{horizon},"perplexity":8,"knn":"brute","resume_from":"{blob}"}}"#
            ),
        );
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{resp}");
        let rid = v.num_field("job").unwrap() as u64;
        let (resp, _) = handle_line(&s, &format!(r#"{{"cmd":"wait","job":{rid}}}"#));
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{resp}");
        assert_eq!(v.num_field("iters").unwrap() as usize, horizon, "resumed, not restarted");
    }

    #[test]
    fn submit_rejects_bad_resume_and_y0() {
        let s = svc();
        for line in [
            r#"{"cmd":"submit","resume_from":"not base64!!"}"#,
            r#"{"cmd":"submit","resume_from":"YWJj"}"#, // base64 of "abc": not a checkpoint
            r#"{"cmd":"submit","y0":"nope"}"#,
            r#"{"cmd":"submit","y0":[1,2,3]}"#, // odd length
            r#"{"cmd":"submit","y0":[1,"x"]}"#,
        ] {
            let (resp, keep) = handle_line(&s, line);
            let v = json::parse(&resp).unwrap();
            assert_eq!(v.get("ok"), Some(&Json::Bool(false)), "{line} -> {resp}");
            assert!(keep);
        }
    }

    #[test]
    fn submit_parses_y0() {
        let v = json::parse(r#"{"cmd":"submit","y0":[0.5,-1.25,3,4]}"#).unwrap();
        let spec = spec_from_json(&v).unwrap();
        assert_eq!(spec.y0, Some(vec![0.5, -1.25, 3.0, 4.0]));
        // Absent -> none; the end-to-end warm-start effect is pinned by
        // `pipeline::tests::spec_resume_from_and_y0_feed_the_session`.
        let v = json::parse(r#"{"cmd":"submit"}"#).unwrap();
        assert!(spec_from_json(&v).unwrap().y0.is_none());
    }

    #[test]
    fn spec_json_roundtrip_preserves_every_field() {
        // The journal persists specs through spec_to_json and re-parses
        // them with spec_from_json — a field either roundtrips or a
        // restarted job silently changes behaviour.
        let mut spec = JobSpec {
            dataset: "wikiword".into(),
            n: 4321,
            engine: "fieldfft".into(),
            perplexity: 17.5,
            knn: "vptree".parse().unwrap(),
            snapshot_every: 7,
            auto_stop: Some(AutoStop { window: 33, rel_eps: 2.5e-4 }),
            priority: "batch".parse().unwrap(),
            seed: 99,
            ..Default::default()
        };
        spec.params = OptParams {
            iters: 1234,
            eta: 150.0,
            momentum0: 0.4,
            momentum1: 0.85,
            momentum_switch: 200,
            exaggeration: 9.0,
            exaggeration_iters: 111,
            seed: 99,
            init_std: 0.05,
        };
        let json_line = spec_to_json(&spec).to_string();
        let back = spec_from_json(&json::parse(&json_line).unwrap()).unwrap();
        assert_eq!(back.dataset, spec.dataset);
        assert_eq!(back.n, spec.n);
        assert_eq!(back.engine, spec.engine);
        assert_eq!(back.perplexity, spec.perplexity);
        assert_eq!(back.knn, spec.knn);
        assert_eq!(back.snapshot_every, spec.snapshot_every);
        assert_eq!(back.priority, spec.priority);
        assert_eq!(back.seed, spec.seed);
        let auto = back.auto_stop.unwrap();
        assert_eq!(auto.window, 33);
        assert!((auto.rel_eps - 2.5e-4).abs() < 1e-12);
        assert_eq!(back.params.iters, spec.params.iters);
        assert_eq!(back.params.eta, spec.params.eta);
        assert_eq!(back.params.momentum0, spec.params.momentum0);
        assert_eq!(back.params.momentum1, spec.params.momentum1);
        assert_eq!(back.params.momentum_switch, spec.params.momentum_switch);
        assert_eq!(back.params.exaggeration, spec.params.exaggeration);
        assert_eq!(back.params.exaggeration_iters, spec.params.exaggeration_iters);
        assert_eq!(back.params.init_std, spec.params.init_std);
        assert_eq!(back.params.seed, spec.params.seed);
    }

    #[test]
    fn metrics_and_trace_commands_report_live_jobs() {
        let s = svc();
        let (resp, _) = handle_line(
            &s,
            r#"{"cmd":"submit","dataset":"gaussians","n":80,"engine":"bh-0.5","iters":25,"perplexity":8,"knn":"brute"}"#,
        );
        let id = json::parse(&resp).unwrap().num_field("job").unwrap() as u64;
        handle_line(&s, &format!(r#"{{"cmd":"wait","job":{id}}}"#));

        let (resp, _) = handle_line(&s, r#"{"cmd":"metrics"}"#);
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{resp}");
        let m = v.get("metrics").unwrap();
        let hist = m.get("service").unwrap().get("histograms").unwrap();
        assert!(
            hist.get("scheduler.quantum_ns").unwrap().num_field("count").unwrap() >= 1.0,
            "{resp}"
        );
        assert!(m.get("sim_cache").unwrap().num_field("p_computes").unwrap() >= 1.0, "{resp}");
        let jobs = m.get("jobs").unwrap().as_arr().unwrap();
        assert_eq!(jobs.len(), 1, "{resp}");
        assert_eq!(jobs[0].num_field("job"), Some(id as f64));
        assert!(jobs[0].num_field("steps").unwrap() >= 25.0, "{resp}");

        let (resp, _) = handle_line(&s, &format!(r#"{{"cmd":"trace","job":{id},"last":64}}"#));
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{resp}");
        let events = v.get("events").unwrap().as_arr().unwrap();
        assert!(!events.is_empty(), "trace must carry this job's spans");
        assert!(events.len() <= 64);
        assert!(events.iter().all(|e| e.num_field("job") == Some(id as f64)));
        assert!(
            events.iter().any(|e| e.str_field("span") == Some("scheduler.quantum")),
            "{resp}"
        );
    }

    #[test]
    fn bounded_reader_frames_and_caps_lines() {
        use std::io::Cursor;
        let mut buf = Vec::new();
        // Plain framing: lines come through intact, newline stripped,
        // final unterminated line included, then EOF.
        let mut r = BufReader::new(Cursor::new(b"hello\nworld\ntail".to_vec()));
        assert_eq!(read_bounded_line(&mut r, &mut buf, 16).unwrap(), LineRead::Line);
        assert_eq!(buf, b"hello");
        assert_eq!(read_bounded_line(&mut r, &mut buf, 16).unwrap(), LineRead::Line);
        assert_eq!(buf, b"world");
        assert_eq!(read_bounded_line(&mut r, &mut buf, 16).unwrap(), LineRead::Line);
        assert_eq!(buf, b"tail");
        assert_eq!(read_bounded_line(&mut r, &mut buf, 16).unwrap(), LineRead::Eof);
        // A newline-free flood never accumulates past the cap.
        let mut r = BufReader::new(Cursor::new(vec![b'x'; 1 << 16]));
        assert_eq!(read_bounded_line(&mut r, &mut buf, 16).unwrap(), LineRead::TooLarge);
        // An oversized but newline-terminated line resyncs: the next
        // line still parses (handle_client closes anyway, but the
        // reader itself must not corrupt the frame boundary).
        let mut big = vec![b'y'; 64];
        big.extend_from_slice(b"\nok\n");
        let mut r = BufReader::new(Cursor::new(big));
        assert_eq!(read_bounded_line(&mut r, &mut buf, 16).unwrap(), LineRead::TooLarge);
        assert_eq!(read_bounded_line(&mut r, &mut buf, 16).unwrap(), LineRead::Line);
        assert_eq!(buf, b"ok");
    }

    #[test]
    fn every_command_survives_malformed_input() {
        let s = svc();
        // Garbage with no usable cmd: always a structured error line.
        for line in [
            "not json",
            "{",
            "[1,2,3]",
            "\"submit\"",
            "null",
            r#"{"cmd":42}"#,
            r#"{"cmd":null}"#,
            r#"{"cmd":""}"#,
            r#"{"cmd":["submit"]}"#,
            r#"{"cmd":"submit" "cmd":"oops"}"#,
        ] {
            let (resp, keep) = handle_line(&s, line);
            let v = json::parse(&resp)
                .unwrap_or_else(|e| panic!("{line} -> unparseable response {resp}: {e}"));
            assert_eq!(v.get("ok"), Some(&Json::Bool(false)), "{line} -> {resp}");
            assert!(keep, "{line}");
        }
        // Every command with missing, wrong-typed, negative and huge
        // fields: a parseable response, never a panic, never a hang.
        // (`submit` and `shutdown` mutate service state — separately
        // below.)
        for cmd in Cmd::ALL {
            if matches!(cmd, Cmd::Submit | Cmd::Shutdown) {
                continue;
            }
            for args in [
                "",
                r#","job":"twelve""#,
                r#","job":-1"#,
                r#","job":1e308"#,
                r#","job":{"nested":true},"last":"many","spec":42,"clear":"yes""#,
            ] {
                let line = format!(r#"{{"cmd":"{}"{args}}}"#, cmd.name());
                let (resp, keep) = handle_line(&s, &line);
                let v = json::parse(&resp)
                    .unwrap_or_else(|e| panic!("{line} -> unparseable response {resp}: {e}"));
                assert!(v.get("ok").is_some(), "{line} -> {resp}");
                assert_eq!(keep, *cmd != Cmd::Quit, "{line}");
            }
        }
        // Submit with hostile payloads: structured errors at submit
        // time — nothing is admitted that could wreck a worker.
        for line in [
            r#"{"cmd":"submit","n":1e300}"#,
            r#"{"cmd":"submit","n":-7}"#,
            r#"{"cmd":"submit","iters":-3}"#,
            r#"{"cmd":"submit","iters":1e307}"#,
            r#"{"cmd":"submit","knn":"quantum"}"#,
            r#"{"cmd":"submit","priority":"urgent"}"#,
            r#"{"cmd":"submit","y0":{"x":1}}"#,
            r#"{"cmd":"submit","resume_from":"!!!"}"#,
        ] {
            let (resp, keep) = handle_line(&s, line);
            let v = json::parse(&resp).unwrap();
            assert_eq!(v.get("ok"), Some(&Json::Bool(false)), "{line} -> {resp}");
            assert!(keep, "{line}");
        }
        assert!(s.list().is_empty(), "malformed input must not admit jobs");
        // Shutdown clamps absurd timeouts and drains an idle service
        // cleanly (fresh instance: draining is sticky).
        let s2 = svc();
        for line in
            [r#"{"cmd":"shutdown","timeout_s":-5}"#, r#"{"cmd":"shutdown","timeout_s":"soon"}"#]
        {
            let (resp, keep) = handle_line(&s2, line);
            let v = json::parse(&resp).unwrap();
            assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{line} -> {resp}");
            assert_eq!(v.num_field("parked_jobs"), Some(0.0), "{resp}");
            assert!(!keep, "{line}");
        }
    }

    #[test]
    fn fault_command_arms_reports_and_clears() {
        // Touches only the reserved test point, serialised with the
        // faultinject unit tests, so parallel tests in this process
        // never see an armed real fault.
        let _l = faultinject::test_registry_lock();
        faultinject::disarm_all();
        let (resp, keep) = handle_line(&svc(), r#"{"cmd":"fault","spec":"test.point=every:2"}"#);
        assert!(keep);
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{resp}");
        assert_eq!(v.get("enabled"), Some(&Json::Bool(true)), "{resp}");
        let points = v.get("points").unwrap().as_arr().unwrap();
        assert!(
            points.iter().any(|p| p.str_field("point") == Some("test.point")
                && p.str_field("trigger") == Some("every:2")),
            "{resp}"
        );
        // Unknown point / bad trigger: loud error, nothing armed extra.
        let (resp, _) = handle_line(&svc(), r#"{"cmd":"fault","spec":"store.wrte=once"}"#);
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok"), Some(&Json::Bool(false)), "{resp}");
        // Clear: registry empties, switch drops.
        let (resp, _) = handle_line(&svc(), r#"{"cmd":"fault","clear":true}"#);
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{resp}");
        assert!(v.get("points").unwrap().as_arr().unwrap().is_empty(), "{resp}");
    }

    #[test]
    fn submit_sheds_while_draining() {
        let s = svc();
        let (resp, _) = handle_line(&s, r#"{"cmd":"shutdown","timeout_s":1}"#);
        assert_eq!(json::parse(&resp).unwrap().get("ok"), Some(&Json::Bool(true)), "{resp}");
        let (resp, keep) = handle_line(
            &s,
            r#"{"cmd":"submit","dataset":"gaussians","n":60,"engine":"bh-0.5","iters":5,"perplexity":6,"knn":"brute"}"#,
        );
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok"), Some(&Json::Bool(false)), "{resp}");
        assert_eq!(v.str_field("code"), Some("draining"), "{resp}");
        assert_eq!(v.get("retriable"), Some(&Json::Bool(true)), "{resp}");
        assert!(keep);
    }

    #[test]
    fn protocol_doc_covers_every_command() {
        // docs/PROTOCOL.md is the reference the doc-header points at;
        // every wire command must appear there (as `"cmd":"<name>"`), and
        // conversely every documented cmd string must dispatch.
        let doc = include_str!("../../../docs/PROTOCOL.md");
        for cmd in Cmd::ALL {
            let needle = format!("\"cmd\":\"{}\"", cmd.name());
            assert!(
                doc.contains(&needle),
                "docs/PROTOCOL.md does not document the `{}` command ({needle})",
                cmd.name()
            );
        }
        // Response-field coverage: the durable-path and scheduling-class
        // fields are documented.
        for field in
            ["resume_from", "checkpoint", "y0", "sim_cache_hit", "knn_cache_hit", "priority"]
        {
            assert!(doc.contains(field), "docs/PROTOCOL.md lost the `{field}` field");
        }
    }
}
