//! Durable coordinator state (ROADMAP (c)/(d)): the on-disk similarity
//! store behind [`super::simcache::SimilarityCache`] and the checkpoint
//! journal behind `serve --state-dir`.
//!
//! Both persist through one **record** framing: magic + kind + version +
//! length + FNV-1a checksum + payload, written atomically (temp file +
//! rename) so a crash mid-write never leaves a half-record under the
//! final name. Reads are paranoid by construction — a record that is
//! truncated, version-skewed, checksum-mismatched, from a different
//! kind, or whose *echoed key* does not match the requested one (the
//! filename is only a hash) is treated as **absent**, never trusted:
//! the cache falls back to recomputing and the journal skips the job.
//! Corrupt files are best-effort deleted so they cannot shadow a later
//! healthy write.
//!
//! Writes are **advisory and self-healing**: a failed write is retried
//! a bounded number of times with exponential backoff (transient
//! hiccups), and a write that still fails flips the store or journal
//! into **memory-only degraded mode** — further writes are skipped, the
//! `store.degraded` / `journal.degraded` gauge goes to 1, and serving
//! continues; a sick disk never takes down the job path. Opening a
//! store/journal reaps orphaned `*.tmp.*` files left by a process
//! killed between the tmp write and the rename. The failure paths are
//! testable on demand through [`super::faultinject`]'s `store.write`,
//! `store.write_crash`, `store.read_corrupt` and `journal.append`
//! points.
//!
//! Layout under a service state dir:
//!
//! ```text
//! <state-dir>/
//!   simstore/g-<hash16>.rec   level-1: kNN graph per (fingerprint, method, k, seed)
//!   simstore/p-<hash16>.rec   level-2: joint P per (graph key, perplexity)
//!   jobs/job-<id>.job         journalled spec + checkpoint of a live job
//! ```

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::Duration;

use crate::hd::sparse::Csr;
use crate::hd::{KnnGraph, SparseP};
use crate::obs;
use crate::util::hash::fnv1a;
use crate::util::timer::Stopwatch;

use super::faultinject;
use super::job::KnnMethod;
use super::simcache::{GraphKey, SimKey};

/// Attempts per advisory write before the owner degrades to
/// memory-only: first try + two retries, backing off 2 ms then 8 ms.
const WRITE_ATTEMPTS: u32 = 3;
const RETRY_BACKOFF: Duration = Duration::from_millis(2);

/// Record-I/O metrics, in the process-wide registry (the record
/// functions are free functions — there is no service handle in scope):
/// `store.{read,write}_bytes` counters plus `store.{read,write}_ns`
/// latency histograms. Reads that come back absent/corrupt still count
/// their latency (the probe cost is real) but add no bytes.
struct IoMetrics {
    read_bytes: Arc<obs::Counter>,
    write_bytes: Arc<obs::Counter>,
    read_ns: Arc<obs::Histogram>,
    write_ns: Arc<obs::Histogram>,
    write_retries: Arc<obs::Counter>,
    store_degraded: Arc<obs::Gauge>,
    journal_degraded: Arc<obs::Gauge>,
}

fn io_metrics() -> &'static IoMetrics {
    static M: OnceLock<IoMetrics> = OnceLock::new();
    M.get_or_init(|| {
        let r = obs::registry();
        IoMetrics {
            read_bytes: r.counter("store.read_bytes"),
            write_bytes: r.counter("store.write_bytes"),
            read_ns: r.histogram("store.read_ns"),
            write_ns: r.histogram("store.write_ns"),
            write_retries: r.counter("store.write_retries"),
            store_degraded: r.gauge("store.degraded"),
            journal_degraded: r.gauge("journal.degraded"),
        }
    })
}

const RECORD_MAGIC: &[u8; 8] = b"GSNESTR1";
const RECORD_VERSION: u16 = 1;
const HEADER_LEN: usize = 8 + 1 + 2 + 8 + 8;

/// Record kinds (part of the header, so a graph record renamed over a P
/// record path is rejected rather than misparsed).
pub const KIND_GRAPH: u8 = b'G';
pub const KIND_P: u8 = b'P';
pub const KIND_JOB: u8 = b'J';

/// Frame and atomically write one record. The temp file carries the
/// process id so concurrent writers (two services misconfigured onto
/// one dir) cannot interleave; the final rename is atomic on POSIX.
pub fn write_record(path: &Path, kind: u8, payload: &[u8]) -> std::io::Result<()> {
    let _span = obs::span(obs::Span::StoreWrite, 0, 0);
    let point =
        if kind == KIND_JOB { faultinject::JOURNAL_APPEND } else { faultinject::STORE_WRITE };
    if faultinject::fire(point) {
        return Err(std::io::Error::new(std::io::ErrorKind::Other, "injected store write fault"));
    }
    let sw = Stopwatch::start();
    let mut buf = Vec::with_capacity(HEADER_LEN + payload.len());
    buf.extend_from_slice(RECORD_MAGIC);
    buf.push(kind);
    buf.extend_from_slice(&RECORD_VERSION.to_le_bytes());
    buf.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    buf.extend_from_slice(&fnv1a(payload).to_le_bytes());
    buf.extend_from_slice(payload);
    let tmp = path.with_extension(format!("tmp.{}", std::process::id()));
    std::fs::write(&tmp, &buf)?;
    if faultinject::fire(faultinject::STORE_WRITE_CRASH) {
        // Simulated kill between the tmp write and the rename: the tmp
        // file stays behind, the destination never appears, and — like a
        // real crash — the caller never learns anything went wrong.
        return Ok(());
    }
    let out = std::fs::rename(&tmp, path);
    let m = io_metrics();
    m.write_ns.record_duration(sw.elapsed());
    if out.is_ok() {
        m.write_bytes.add(buf.len() as u64);
    }
    out
}

/// Read and verify one record; any defect (missing, truncated, trailing
/// bytes, bad magic/kind/version/checksum) reads as `None`, and the
/// offending file is best-effort removed so it cannot mask later writes.
pub fn read_record(path: &Path, kind: u8) -> Option<Vec<u8>> {
    let _span = obs::span(obs::Span::StoreRead, 0, 0);
    let sw = Stopwatch::start();
    let mut bytes = match std::fs::read(path) {
        Ok(b) => b,
        Err(_) => {
            io_metrics().read_ns.record_duration(sw.elapsed());
            return None;
        }
    };
    if faultinject::fire(faultinject::STORE_READ_CORRUPT) {
        // Injected bit rot: flip the last payload byte so the checksum
        // check fires and the defect path (miss + file removal) runs.
        if let Some(b) = bytes.last_mut() {
            *b ^= 0xff;
        }
    }
    let payload = (|| {
        if bytes.len() < HEADER_LEN || &bytes[..8] != RECORD_MAGIC || bytes[8] != kind {
            return None;
        }
        if u16::from_le_bytes(bytes[9..11].try_into().unwrap()) != RECORD_VERSION {
            return None;
        }
        let len = u64::from_le_bytes(bytes[11..19].try_into().unwrap()) as usize;
        if bytes.len() != HEADER_LEN + len {
            return None;
        }
        let sum = u64::from_le_bytes(bytes[19..27].try_into().unwrap());
        let payload = &bytes[HEADER_LEN..];
        (fnv1a(payload) == sum).then(|| payload.to_vec())
    })();
    if payload.is_none() {
        let _ = std::fs::remove_file(path);
    }
    let m = io_metrics();
    m.read_ns.record_duration(sw.elapsed());
    m.read_bytes.add(payload.as_ref().map_or(0, |p| p.len() as u64));
    payload
}

/// [`write_record`] with bounded retry: transient failures back off
/// exponentially ([`RETRY_BACKOFF`], ×4 per attempt) for up to
/// [`WRITE_ATTEMPTS`] tries. Retries are counted in
/// `store.write_retries`; the final error is returned for the caller's
/// degrade decision.
fn write_record_with_retry(path: &Path, kind: u8, payload: &[u8]) -> std::io::Result<()> {
    let mut delay = RETRY_BACKOFF;
    let mut attempt = 0;
    loop {
        match write_record(path, kind, payload) {
            Ok(()) => return Ok(()),
            Err(e) => {
                attempt += 1;
                if attempt >= WRITE_ATTEMPTS {
                    return Err(e);
                }
                io_metrics().write_retries.inc();
                std::thread::sleep(delay);
                delay *= 4;
            }
        }
    }
}

/// Remove orphaned temp files (`<name>.tmp.<pid>`) left by a process
/// killed between [`write_record`]'s tmp write and its rename. Called
/// when a store or journal directory is opened; returns the reap count.
/// A concurrent writer's in-flight tmp could be reaped here in theory —
/// its rename then fails transiently, which the retry path absorbs.
fn reap_tmp_files(dir: &Path) -> usize {
    let Ok(entries) = std::fs::read_dir(dir) else {
        return 0;
    };
    let mut reaped = 0;
    for entry in entries.flatten() {
        let name = entry.file_name();
        let Some(name) = name.to_str() else {
            continue;
        };
        if name.contains(".tmp.") && std::fs::remove_file(entry.path()).is_ok() {
            reaped += 1;
        }
    }
    reaped
}

/// Little-endian payload reader: every accessor returns `None` past the
/// end, so decoders are total functions over arbitrary bytes.
struct Rd<'a>(&'a [u8]);

impl<'a> Rd<'a> {
    fn take(&mut self, n: usize) -> Option<&'a [u8]> {
        if self.0.len() < n {
            return None;
        }
        let (head, tail) = self.0.split_at(n);
        self.0 = tail;
        Some(head)
    }
    fn u8(&mut self) -> Option<u8> {
        Some(self.take(1)?[0])
    }
    fn u32(&mut self) -> Option<u32> {
        Some(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Option<u64> {
        Some(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f32(&mut self) -> Option<f32> {
        Some(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u32s(&mut self, n: usize) -> Option<Vec<u32>> {
        let raw = self.take(n.checked_mul(4)?)?;
        Some(raw.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect())
    }
    fn f32s(&mut self, n: usize) -> Option<Vec<f32>> {
        let raw = self.take(n.checked_mul(4)?)?;
        Some(raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }
    fn u64s(&mut self, n: usize) -> Option<Vec<u64>> {
        let raw = self.take(n.checked_mul(8)?)?;
        Some(raw.chunks_exact(8).map(|c| u64::from_le_bytes(c.try_into().unwrap())).collect())
    }
    fn done(&self) -> bool {
        self.0.is_empty()
    }
}

fn encode_graph_key(key: &GraphKey, out: &mut Vec<u8>) {
    out.extend_from_slice(&key.fingerprint.to_le_bytes());
    out.push(key.method.tag());
    out.extend_from_slice(&(key.k as u64).to_le_bytes());
    out.extend_from_slice(&key.seed.to_le_bytes());
}

fn decode_graph_key(rd: &mut Rd) -> Option<GraphKey> {
    let fingerprint = rd.u64()?;
    let method = KnnMethod::from_tag(rd.u8()?)?;
    let k = rd.u64()? as usize;
    let seed = rd.u64()?;
    Some(GraphKey { fingerprint, method, k, seed })
}

fn encode_sim_key(key: &SimKey, out: &mut Vec<u8>) {
    encode_graph_key(&key.graph, out);
    out.extend_from_slice(&key.perplexity_bits.to_le_bytes());
}

fn decode_sim_key(rd: &mut Rd) -> Option<SimKey> {
    let graph = decode_graph_key(rd)?;
    let perplexity_bits = rd.u32()?;
    Some(SimKey { graph, perplexity_bits })
}

fn key_file(dir: &Path, prefix: &str, key_bytes: &[u8]) -> PathBuf {
    dir.join(format!("{prefix}-{:016x}.rec", fnv1a(key_bytes)))
}

/// Why a record's raw bytes failed verification — the read-only twin of
/// the defect cases [`read_record`] folds into `None`. `pallas-fsck`
/// reports these instead of deleting (deletion is [`read_record`]'s
/// self-healing behaviour, never a dry-run's).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RecordDefect {
    /// Shorter than one record header.
    Truncated,
    /// The 8-byte magic is not `GSNESTR1`.
    BadMagic,
    /// Header kind byte differs from the expected kind.
    WrongKind { expected: u8, found: u8 },
    /// Format version this build does not understand.
    BadVersion { found: u16 },
    /// Payload length in the header disagrees with the file size.
    LengthMismatch { header: u64, actual: u64 },
    /// FNV-1a checksum over the payload does not match the header.
    ChecksumMismatch,
}

impl std::fmt::Display for RecordDefect {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RecordDefect::Truncated => write!(f, "truncated (shorter than a record header)"),
            RecordDefect::BadMagic => write!(f, "bad magic"),
            RecordDefect::WrongKind { expected, found } => {
                write!(f, "kind '{}' where '{}' expected", *found as char, *expected as char)
            }
            RecordDefect::BadVersion { found } => write!(f, "unknown record version {found}"),
            RecordDefect::LengthMismatch { header, actual } => {
                write!(f, "payload length {header} in header, {actual} on disk")
            }
            RecordDefect::ChecksumMismatch => write!(f, "checksum mismatch"),
        }
    }
}

/// Verify one record's raw bytes against the framing contract and return
/// the payload slice. Pure: unlike [`read_record`] this never touches
/// the filesystem, so fsck's dry-run can probe a store without mutating
/// it byte-for-byte.
pub fn verify_record_bytes(bytes: &[u8], kind: u8) -> Result<&[u8], RecordDefect> {
    if bytes.len() < HEADER_LEN {
        return Err(RecordDefect::Truncated);
    }
    if &bytes[..8] != RECORD_MAGIC {
        return Err(RecordDefect::BadMagic);
    }
    if bytes[8] != kind {
        return Err(RecordDefect::WrongKind { expected: kind, found: bytes[8] });
    }
    let version = u16::from_le_bytes(bytes[9..11].try_into().unwrap());
    if version != RECORD_VERSION {
        return Err(RecordDefect::BadVersion { found: version });
    }
    let len = u64::from_le_bytes(bytes[11..19].try_into().unwrap());
    let actual = (bytes.len() - HEADER_LEN) as u64;
    if len != actual {
        return Err(RecordDefect::LengthMismatch { header: len, actual });
    }
    let sum = u64::from_le_bytes(bytes[19..27].try_into().unwrap());
    let payload = &bytes[HEADER_LEN..];
    if fnv1a(payload) != sum {
        return Err(RecordDefect::ChecksumMismatch);
    }
    Ok(payload)
}

/// Deep structural check of a verified payload: decodes it the way the
/// store/journal readers would and returns the file name the record
/// *should* live under (its key echo hashed the way [`key_file`] names
/// files, or `job-<id>.job` for journal entries). A name that disagrees
/// with the actual file means the record can never be found by its key
/// — fsck reports it as misplaced. Pure and total over arbitrary bytes.
pub fn fsck_payload_check(kind: u8, payload: &[u8]) -> Result<String, String> {
    match kind {
        KIND_GRAPH => {
            let mut rd = Rd(payload);
            let key = decode_graph_key(&mut rd).ok_or("graph key echo truncated")?;
            let n = rd.u64().ok_or("missing n")? as usize;
            let k = rd.u64().ok_or("missing k")? as usize;
            let len = n.checked_mul(k).ok_or("n*k overflows")?;
            let idx = rd.u32s(len).ok_or("neighbour indices truncated")?;
            rd.f32s(len).ok_or("neighbour distances truncated")?;
            if !rd.done() {
                return Err("trailing bytes after graph payload".into());
            }
            if idx.iter().any(|&i| i as usize >= n) {
                return Err(format!("neighbour index out of range (n={n})"));
            }
            let mut kb = Vec::with_capacity(25);
            encode_graph_key(&key, &mut kb);
            Ok(format!("g-{:016x}.rec", fnv1a(&kb)))
        }
        KIND_P => {
            let mut rd = Rd(payload);
            let key = decode_sim_key(&mut rd).ok_or("P key echo truncated")?;
            rd.f32().ok_or("missing perplexity")?;
            let n_rows = rd.u64().ok_or("missing n_rows")? as usize;
            let n_cols = rd.u64().ok_or("missing n_cols")? as usize;
            let nnz = rd.u64().ok_or("missing nnz")? as usize;
            let row_ptr: Vec<usize> = rd
                .u64s(n_rows.checked_add(1).ok_or("n_rows overflows")?)
                .ok_or("row_ptr truncated")?
                .into_iter()
                .map(|v| v as usize)
                .collect();
            let col = rd.u32s(nnz).ok_or("columns truncated")?;
            rd.f32s(nnz).ok_or("values truncated")?;
            if !rd.done() {
                return Err("trailing bytes after P payload".into());
            }
            if !row_ptr.windows(2).all(|w| w[0] <= w[1])
                || row_ptr.first() != Some(&0)
                || row_ptr.last() != Some(&nnz)
            {
                return Err("row_ptr is not a monotone [0..=nnz] ramp".into());
            }
            if col.iter().any(|&c| c as usize >= n_cols) {
                return Err(format!("column index out of range (n_cols={n_cols})"));
            }
            let mut kb = Vec::with_capacity(29);
            encode_sim_key(&key, &mut kb);
            Ok(format!("p-{:016x}.rec", fnv1a(&kb)))
        }
        KIND_JOB => {
            let mut rd = Rd(payload);
            let id = rd.u64().ok_or("missing job id")?;
            let spec_len = rd.u64().ok_or("missing spec length")? as usize;
            let spec = rd.take(spec_len).ok_or("spec truncated")?;
            std::str::from_utf8(spec).map_err(|_| "spec is not utf-8")?;
            // The remainder is the checkpoint blob: opaque here (its own
            // codec validates on re-admission), any length allowed.
            Ok(format!("job-{id}.job"))
        }
        other => Err(format!("unknown record kind '{}'", other as char)),
    }
}

/// The on-disk half of the two-level similarity store: level-1 kNN-graph
/// records and level-2 joint-P records, keyed by a filename hash with the
/// full key echoed (and verified) inside the payload. Writes are
/// advisory — they retry with backoff on transient errors, and a write
/// that keeps failing flips the store into memory-only degraded mode
/// (`store.degraded` gauge = 1, further writes skipped) with a one-line
/// warning, never an error on the job path.
pub struct SimStore {
    dir: PathBuf,
    degraded: AtomicBool,
}

impl SimStore {
    /// Open (creating) the store directory, reaping any `*.tmp.*`
    /// orphans a crashed writer left behind.
    pub fn open(dir: &Path) -> std::io::Result<Self> {
        std::fs::create_dir_all(dir)?;
        let reaped = reap_tmp_files(dir);
        if reaped > 0 {
            eprintln!("sim store: reaped {reaped} orphaned tmp file(s) in {}", dir.display());
        }
        Ok(Self { dir: dir.to_path_buf(), degraded: AtomicBool::new(false) })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// True once a write has exhausted its retries and the store went
    /// memory-only (sticky until the process restarts).
    pub fn degraded(&self) -> bool {
        self.degraded.load(Ordering::Relaxed)
    }

    fn write_advisory(&self, path: &Path, kind: u8, payload: &[u8], what: &str) {
        if self.degraded() {
            return;
        }
        if let Err(e) = write_record_with_retry(path, kind, payload) {
            self.degraded.store(true, Ordering::Relaxed);
            io_metrics().store_degraded.set(1);
            eprintln!(
                "warning: sim store {what} write failed after retries ({e}); \
                 degrading to memory-only"
            );
        }
    }

    fn graph_path(&self, key: &GraphKey) -> PathBuf {
        let mut kb = Vec::with_capacity(25);
        encode_graph_key(key, &mut kb);
        key_file(&self.dir, "g", &kb)
    }

    fn p_path(&self, key: &SimKey) -> PathBuf {
        let mut kb = Vec::with_capacity(29);
        encode_sim_key(key, &mut kb);
        key_file(&self.dir, "p", &kb)
    }

    pub fn store_graph(&self, key: &GraphKey, g: &KnnGraph) {
        let mut payload = Vec::with_capacity(41 + 8 * g.idx.len());
        encode_graph_key(key, &mut payload);
        payload.extend_from_slice(&(g.n as u64).to_le_bytes());
        payload.extend_from_slice(&(g.k as u64).to_le_bytes());
        for &i in &g.idx {
            payload.extend_from_slice(&i.to_le_bytes());
        }
        for &d in &g.d2 {
            payload.extend_from_slice(&d.to_le_bytes());
        }
        self.write_advisory(&self.graph_path(key), KIND_GRAPH, &payload, "graph");
    }

    pub fn load_graph(&self, key: &GraphKey) -> Option<KnnGraph> {
        let payload = read_record(&self.graph_path(key), KIND_GRAPH)?;
        let mut rd = Rd(&payload);
        if decode_graph_key(&mut rd)? != *key {
            return None; // filename-hash collision with another key
        }
        let n = rd.u64()? as usize;
        let k = rd.u64()? as usize;
        let len = n.checked_mul(k)?;
        let idx = rd.u32s(len)?;
        let d2 = rd.f32s(len)?;
        if !rd.done() || idx.iter().any(|&i| i as usize >= n) {
            return None;
        }
        Some(KnnGraph { n, k, idx, d2 })
    }

    pub fn store_p(&self, key: &SimKey, p: &SparseP) {
        let csr = &p.csr;
        let mut payload =
            Vec::with_capacity(64 + 8 * csr.row_ptr.len() + 8 * csr.val.len());
        encode_sim_key(key, &mut payload);
        payload.extend_from_slice(&p.perplexity.to_le_bytes());
        payload.extend_from_slice(&(csr.n_rows as u64).to_le_bytes());
        payload.extend_from_slice(&(csr.n_cols as u64).to_le_bytes());
        payload.extend_from_slice(&(csr.nnz() as u64).to_le_bytes());
        for &r in &csr.row_ptr {
            payload.extend_from_slice(&(r as u64).to_le_bytes());
        }
        for &c in &csr.col {
            payload.extend_from_slice(&c.to_le_bytes());
        }
        for &v in &csr.val {
            payload.extend_from_slice(&v.to_le_bytes());
        }
        self.write_advisory(&self.p_path(key), KIND_P, &payload, "P");
    }

    pub fn load_p(&self, key: &SimKey) -> Option<SparseP> {
        let payload = read_record(&self.p_path(key), KIND_P)?;
        let mut rd = Rd(&payload);
        if decode_sim_key(&mut rd)? != *key {
            return None;
        }
        let perplexity = rd.f32()?;
        let n_rows = rd.u64()? as usize;
        let n_cols = rd.u64()? as usize;
        let nnz = rd.u64()? as usize;
        let row_ptr: Vec<usize> =
            rd.u64s(n_rows.checked_add(1)?)?.into_iter().map(|v| v as usize).collect();
        let col = rd.u32s(nnz)?;
        let val = rd.f32s(nnz)?;
        // Structural validation: monotone row_ptr bounded by nnz, and
        // column indices inside the matrix.
        let monotone = row_ptr.windows(2).all(|w| w[0] <= w[1]);
        if !rd.done()
            || !monotone
            || row_ptr.first() != Some(&0)
            || row_ptr.last() != Some(&nnz)
            || col.iter().any(|&c| c as usize >= n_cols)
        {
            return None;
        }
        Some(SparseP { csr: Csr { n_rows, n_cols, row_ptr, col, val }, perplexity })
    }
}

/// The checkpoint journal: one record per live job, rewritten in place
/// at the configured interval. Payload is `[id][spec-json][checkpoint
/// bytes]` — everything `serve --state-dir` needs to re-admit the job as
/// resumable after a restart.
pub struct JobJournal {
    dir: PathBuf,
    degraded: AtomicBool,
}

/// One re-admittable journal entry.
pub struct JournalEntry {
    pub id: u64,
    /// The job spec as protocol-shaped JSON (current session params at
    /// journal time, so TCP `update`s survive the restart too).
    pub spec_json: String,
    /// Serialised [`crate::embed::Checkpoint`].
    pub checkpoint: Vec<u8>,
}

impl JobJournal {
    /// Open (creating) the journal directory, reaping `*.tmp.*` orphans.
    pub fn open(dir: &Path) -> std::io::Result<Self> {
        std::fs::create_dir_all(dir)?;
        let reaped = reap_tmp_files(dir);
        if reaped > 0 {
            eprintln!("journal: reaped {reaped} orphaned tmp file(s) in {}", dir.display());
        }
        Ok(Self { dir: dir.to_path_buf(), degraded: AtomicBool::new(false) })
    }

    fn path(&self, id: u64) -> PathBuf {
        self.dir.join(format!("job-{id}.job"))
    }

    /// True once an append has exhausted its retries and journalling
    /// went memory-only (sticky until the process restarts).
    pub fn degraded(&self) -> bool {
        self.degraded.load(Ordering::Relaxed)
    }

    /// Journal (or re-journal) one job. Advisory like the sim store:
    /// retried with backoff, then degraded to memory-only (the
    /// `journal.degraded` gauge flips to 1 and jobs simply lose
    /// restart durability — they keep running).
    pub fn write(&self, id: u64, spec_json: &str, checkpoint: &[u8]) {
        if self.degraded() {
            return;
        }
        let spec = spec_json.as_bytes();
        let mut payload = Vec::with_capacity(24 + spec.len() + checkpoint.len());
        payload.extend_from_slice(&id.to_le_bytes());
        payload.extend_from_slice(&(spec.len() as u64).to_le_bytes());
        payload.extend_from_slice(spec);
        payload.extend_from_slice(checkpoint);
        if let Err(e) = write_record_with_retry(&self.path(id), KIND_JOB, &payload) {
            self.degraded.store(true, Ordering::Relaxed);
            io_metrics().journal_degraded.set(1);
            eprintln!(
                "warning: checkpoint journal write failed for job {id} after retries ({e}); \
                 degrading to memory-only"
            );
        }
    }

    /// Drop a finished (or failed) job's journal entry.
    pub fn remove(&self, id: u64) {
        let _ = std::fs::remove_file(self.path(id));
    }

    /// Every readable journal entry, sorted by id. Corrupt entries are
    /// skipped (and removed by [`read_record`]); an id that disagrees
    /// with its payload is skipped too.
    pub fn read_all(&self) -> Vec<JournalEntry> {
        let mut out = Vec::new();
        let Ok(entries) = std::fs::read_dir(&self.dir) else {
            return out;
        };
        for entry in entries.flatten() {
            let path = entry.path();
            if path.extension().and_then(|e| e.to_str()) != Some("job") {
                continue;
            }
            let Some(payload) = read_record(&path, KIND_JOB) else {
                continue;
            };
            let parsed = (|| {
                let mut rd = Rd(&payload);
                let id = rd.u64()?;
                let spec_len = rd.u64()? as usize;
                let spec_json = String::from_utf8(rd.take(spec_len)?.to_vec()).ok()?;
                let checkpoint = rd.0.to_vec();
                Some(JournalEntry { id, spec_json, checkpoint })
            })();
            if let Some(e) = parsed {
                out.push(e);
            }
        }
        out.sort_by_key(|e| e.id);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("gsne-store-{}-{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn graph_key() -> GraphKey {
        GraphKey { fingerprint: 0xfeed, method: KnnMethod::Brute, k: 3, seed: 7 }
    }

    fn sim_key() -> SimKey {
        SimKey { graph: graph_key(), perplexity_bits: 8.5f32.to_bits() }
    }

    fn graph() -> KnnGraph {
        KnnGraph {
            n: 4,
            k: 3,
            idx: vec![1, 2, 3, 0, 2, 3, 0, 1, 3, 0, 1, 2],
            d2: (0..12).map(|i| i as f32 * 0.5).collect(),
        }
    }

    fn sparse_p() -> SparseP {
        SparseP {
            csr: Csr::from_rows(2, 2, 2, vec![0, 1, 1, 0], vec![0.1, 0.4, 0.3, 0.2]),
            perplexity: 8.5,
        }
    }

    #[test]
    fn record_roundtrip_and_rejection() {
        let dir = tmp_dir("record");
        let path = dir.join("x.rec");
        write_record(&path, KIND_GRAPH, b"hello payload").unwrap();
        assert_eq!(read_record(&path, KIND_GRAPH).unwrap(), b"hello payload");

        // Wrong kind is rejected (and the file removed).
        write_record(&path, KIND_GRAPH, b"hello payload").unwrap();
        assert!(read_record(&path, KIND_P).is_none());
        assert!(!path.exists(), "defective reads clear the file");

        // Flipped payload byte → checksum mismatch.
        write_record(&path, KIND_GRAPH, b"hello payload").unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        *bytes.last_mut().unwrap() ^= 0xff;
        std::fs::write(&path, &bytes).unwrap();
        assert!(read_record(&path, KIND_GRAPH).is_none());

        // Truncation.
        write_record(&path, KIND_GRAPH, b"hello payload").unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 2]).unwrap();
        assert!(read_record(&path, KIND_GRAPH).is_none());

        // Version skew.
        write_record(&path, KIND_GRAPH, b"hello payload").unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[9] = 0xff;
        std::fs::write(&path, &bytes).unwrap();
        assert!(read_record(&path, KIND_GRAPH).is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn graph_and_p_records_roundtrip() {
        let dir = tmp_dir("roundtrip");
        let store = SimStore::open(&dir).unwrap();
        assert!(store.load_graph(&graph_key()).is_none(), "empty store misses");

        store.store_graph(&graph_key(), &graph());
        let g = store.load_graph(&graph_key()).expect("graph persisted");
        assert_eq!(g.idx, graph().idx);
        assert_eq!(g.d2, graph().d2);

        store.store_p(&sim_key(), &sparse_p());
        let p = store.load_p(&sim_key()).expect("P persisted");
        assert_eq!(p.csr, sparse_p().csr);
        assert_eq!(p.perplexity, 8.5);

        // A different key misses even though records exist.
        let mut other = graph_key();
        other.k = 4;
        assert!(store.load_graph(&other).is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_store_entries_read_as_misses() {
        let dir = tmp_dir("corrupt");
        let store = SimStore::open(&dir).unwrap();
        store.store_p(&sim_key(), &sparse_p());
        // Scribble over every record in the dir.
        for entry in std::fs::read_dir(&dir).unwrap().flatten() {
            std::fs::write(entry.path(), b"not a record at all").unwrap();
        }
        assert!(store.load_p(&sim_key()).is_none(), "corruption is a miss, not a panic");
        // And the next write/read cycle is healthy again.
        store.store_p(&sim_key(), &sparse_p());
        assert!(store.load_p(&sim_key()).is_some());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn structurally_invalid_payloads_are_rejected() {
        let dir = tmp_dir("structure");
        let store = SimStore::open(&dir).unwrap();
        // A graph whose neighbour indices exceed n: valid record framing,
        // invalid content — must not be served.
        let mut bad = graph();
        bad.idx[0] = 99;
        store.store_graph(&graph_key(), &bad);
        assert!(store.load_graph(&graph_key()).is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn unwritable_store_degrades_to_memory_only() {
        let dir = tmp_dir("degrade");
        let store = SimStore::open(&dir).unwrap();
        assert!(!store.degraded());
        // Yank the directory out from under the store: every write
        // attempt now fails, retries exhaust, and the store goes
        // memory-only instead of erroring the job path.
        std::fs::remove_dir_all(&dir).unwrap();
        store.store_graph(&graph_key(), &graph());
        assert!(store.degraded(), "exhausted retries must flip degraded mode");
        // Degraded writes are skipped outright — no panic, no error.
        store.store_p(&sim_key(), &sparse_p());
        assert!(store.load_p(&sim_key()).is_none());
    }

    #[test]
    fn unwritable_journal_degrades_to_memory_only() {
        let dir = tmp_dir("journal-degrade");
        let j = JobJournal::open(&dir).unwrap();
        std::fs::remove_dir_all(&dir).unwrap();
        j.write(1, r#"{"dataset":"gaussians"}"#, b"ckpt");
        assert!(j.degraded());
        j.write(2, r#"{"dataset":"gaussians"}"#, b"ckpt");
        assert!(j.read_all().is_empty());
    }

    #[test]
    fn open_reaps_orphaned_tmp_files() {
        let dir = tmp_dir("reap");
        {
            let store = SimStore::open(&dir).unwrap();
            store.store_graph(&graph_key(), &graph());
        }
        // Plant orphans shaped like a crashed writer's leftovers.
        std::fs::write(dir.join("g-0123456789abcdef.tmp.9999"), b"half a record").unwrap();
        std::fs::write(dir.join("p-fedcba9876543210.tmp.1"), b"").unwrap();
        let store = SimStore::open(&dir).unwrap();
        let leftover: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .flatten()
            .filter_map(|e| e.file_name().to_str().map(String::from))
            .filter(|n| n.contains(".tmp."))
            .collect();
        assert!(leftover.is_empty(), "orphaned tmp files must be reaped, got {leftover:?}");
        assert!(store.load_graph(&graph_key()).is_some(), "healthy records survive the reap");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn verify_record_bytes_is_pure_and_classifies_defects() {
        let dir = tmp_dir("verify");
        let path = dir.join("x.rec");
        write_record(&path, KIND_GRAPH, b"payload bytes").unwrap();
        let healthy = std::fs::read(&path).unwrap();
        assert_eq!(verify_record_bytes(&healthy, KIND_GRAPH).unwrap(), b"payload bytes");
        assert_eq!(
            verify_record_bytes(&healthy, KIND_P),
            Err(RecordDefect::WrongKind { expected: KIND_P, found: KIND_GRAPH })
        );
        assert_eq!(verify_record_bytes(&healthy[..10], KIND_GRAPH), Err(RecordDefect::Truncated));
        let mut bad = healthy.clone();
        bad[0] ^= 0xff;
        assert_eq!(verify_record_bytes(&bad, KIND_GRAPH), Err(RecordDefect::BadMagic));
        let mut bad = healthy.clone();
        bad[9] = 0xff;
        assert_eq!(
            verify_record_bytes(&bad, KIND_GRAPH),
            Err(RecordDefect::BadVersion { found: u16::from_le_bytes([0xff, bad[10]]) })
        );
        let mut bad = healthy.clone();
        bad.pop();
        assert!(matches!(
            verify_record_bytes(&bad, KIND_GRAPH),
            Err(RecordDefect::LengthMismatch { .. })
        ));
        let mut bad = healthy.clone();
        *bad.last_mut().unwrap() ^= 0xff;
        assert_eq!(verify_record_bytes(&bad, KIND_GRAPH), Err(RecordDefect::ChecksumMismatch));
        // Pure by contract: the defective file is still on disk, intact.
        assert_eq!(std::fs::read(&path).unwrap(), healthy);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fsck_payload_check_names_healthy_records_and_rejects_structure() {
        let dir = tmp_dir("fsck-payload");
        let store = SimStore::open(&dir).unwrap();
        store.store_graph(&graph_key(), &graph());
        store.store_p(&sim_key(), &sparse_p());
        // Every record's deep check returns exactly the name it sits
        // under — the key echo and the filename hash agree.
        for entry in std::fs::read_dir(&dir).unwrap().flatten() {
            let name = entry.file_name().to_str().unwrap().to_string();
            let kind = if name.starts_with("g-") { KIND_GRAPH } else { KIND_P };
            let bytes = std::fs::read(entry.path()).unwrap();
            let payload = verify_record_bytes(&bytes, kind).unwrap();
            assert_eq!(fsck_payload_check(kind, payload).unwrap(), name);
        }
        // Journal entries name themselves by their echoed id.
        let j = JobJournal::open(&dir.join("jobs")).unwrap();
        j.write(42, r#"{"dataset":"gaussians"}"#, b"ckpt");
        let bytes = std::fs::read(dir.join("jobs").join("job-42.job")).unwrap();
        let payload = verify_record_bytes(&bytes, KIND_JOB).unwrap();
        assert_eq!(fsck_payload_check(KIND_JOB, payload).unwrap(), "job-42.job");
        // Structurally invalid content fails the deep check even though
        // the record framing (checksum included) is pristine.
        let mut bad = graph();
        bad.idx[0] = 99;
        store.store_graph(&graph_key(), &bad);
        let gname = format!(
            "g-{:016x}.rec",
            fnv1a(&{
                let mut kb = Vec::new();
                encode_graph_key(&graph_key(), &mut kb);
                kb
            })
        );
        let bytes = std::fs::read(dir.join(&gname)).unwrap();
        let payload = verify_record_bytes(&bytes, KIND_GRAPH).unwrap();
        assert!(fsck_payload_check(KIND_GRAPH, payload).is_err());
        // Arbitrary garbage is an error, never a panic.
        assert!(fsck_payload_check(KIND_P, b"\x01\x02\x03").is_err());
        assert!(fsck_payload_check(KIND_JOB, b"").is_err());
        assert!(fsck_payload_check(b'Z', b"").is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn journal_roundtrip_skips_corruption() {
        let dir = tmp_dir("journal");
        let j = JobJournal::open(&dir).unwrap();
        j.write(3, r#"{"dataset":"gaussians"}"#, b"ckpt-bytes-3");
        j.write(1, r#"{"dataset":"mnist"}"#, b"ckpt-bytes-1");
        j.write(2, r#"{"dataset":"mnist"}"#, b"ckpt-bytes-2");
        j.remove(2);
        // Corrupt job 3's record on disk.
        std::fs::write(dir.join("job-3.job"), b"garbage").unwrap();
        let all = j.read_all();
        assert_eq!(all.len(), 1, "one live, one removed, one corrupt");
        assert_eq!(all[0].id, 1);
        assert_eq!(all[0].spec_json, r#"{"dataset":"mnist"}"#);
        assert_eq!(all[0].checkpoint, b"ckpt-bytes-1");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
