//! The L3 coordinator (DESIGN.md S20): a *progressive embedding service*
//! in the Progressive Visual Analytics mould the paper positions itself
//! in (Fig. 1, the A-tSNE lineage, the in-browser demo).
//!
//! A job flows through **kNN → perplexity/P → optimise**; the optimise
//! stage is a stepwise [`crate::embed::EmbeddingSession`] driven by the
//! service's *cooperative scheduler* (`service.rs`): `max_concurrent`
//! workers time-slice every active session in step quanta (fair
//! round-robin — a 100k-point job cannot starve small interactive ones),
//! publishing live snapshots straight from session state, honouring
//! user-driven stop, `pause`/`resume` parking, and live `update`
//! re-parameterisation (`job.rs::ParamUpdate`). `protocol.rs` exposes
//! the whole thing over a line-oriented TCP protocol (reference:
//! `docs/PROTOCOL.md`); the service also holds the **two-level
//! similarity store** (`simcache.rs`): level 1 caches the kNN graph per
//! `(dataset fingerprint, knn method, k, seed)`, level 2 the finished P
//! per `(graph, perplexity)` — repeated jobs skip the entire similarity
//! stage, perplexity sweeps recompute only the cheap fused P build, and
//! *concurrent* identical submissions coalesce onto a single in-flight
//! computation, reported through `StageTimings::sim_cache_hit` /
//! `knn_cache_hit` and the protocol's `wait`/`stats` responses.
//!
//! The coordinator is **durable** when given a state directory
//! (`serve --state-dir`, `ServiceConfig::state_dir`): `store.rs`
//! persists both similarity-store levels as checksummed record files
//! and journals every running session's checkpoint at a configurable
//! iteration interval, so a restarted service re-admits interrupted
//! jobs as resumable (same ids, bit-identical continuation) and serves
//! repeat submits from disk instead of recomputing kNN graphs.
//! `checkpoint`/`resume_from`/`y0` expose the same machinery to TCP
//! clients. See `docs/ARCHITECTURE.md` for the full lifecycle.
//!
//! Every serving layer is instrumented through [`crate::obs`]: the
//! scheduler records quantum-duration/step histograms, queue depth,
//! budget overruns and park→resume latency into a service-local
//! registry; the similarity cache counts per-level
//! hits/misses/coalesces/evictions; the store counts I/O bytes and
//! latency; snapshot publishing tracks fanout time, skipped publishes
//! and delivery lag. The `metrics` protocol command (and
//! `serve --metrics-dump`) merges all of it into one JSON snapshot, and
//! `trace` exposes the span-event ring buffers per job.
//!
//! The stack is **hardened** and testable under provoked failure:
//! [`faultinject`] compiles named fault points (store errors, simulated
//! crash-in-rename, engine-step panics, connection stalls, slow
//! subscribers) into the serving paths at <1 ns disarmed cost, armed
//! over the wire (`fault`) or at startup (`serve --fault`). The layers
//! degrade instead of dying: the protocol front end bounds request
//! size, applies per-connection timeouts and sheds connections over a
//! cap; admission sheds `submit` with a retriable error over a queue
//! cap; the store retries transient I/O with backoff and then falls
//! back to memory-only operation (an `obs` gauge flips); snapshot
//! fanout bounds per-subscriber queues with drop-oldest backpressure
//! and evicts subscribers that stay slow; and `shutdown` (or SIGTERM)
//! drains gracefully — stop admitting, checkpoint + journal every live
//! session at a step boundary, exit — so a restart resumes
//! bit-identically. `tests/chaos.rs` drives all of it concurrently
//! over the real protocol.

pub mod faultinject;
pub mod job;
pub mod pipeline;
pub mod progress;
pub mod protocol;
pub mod service;
pub mod simcache;
pub mod store;

pub use job::{AutoStop, JobPhase, JobSpec, KnnMethod, ParamUpdate, Priority, Snapshot};
pub use pipeline::{
    begin_session, prepare_similarities, run_pipeline, run_pipeline_cached, AutoStopTracker,
    JobResult, PreparedJob, StageTimings,
};
pub use service::{EmbeddingService, JobId, ServiceConfig, SubmitError};
pub use simcache::{GraphKey, LevelStats, SimKey, SimilarityCache, Source};
pub use store::{JobJournal, SimStore};
