//! The L3 coordinator (DESIGN.md S20): a *progressive embedding service*
//! in the Progressive Visual Analytics mould the paper positions itself
//! in (Fig. 1, the A-tSNE lineage, the in-browser demo).
//!
//! A job flows through **kNN → perplexity/P → optimise**; the optimise
//! stage streams progressive snapshots (iteration, KL estimate, point
//! positions) to subscribers, honours user-driven early termination, and
//! — for the `gpgpu` engine — applies the adaptive field-resolution
//! policy over the AOT artifact set. `serve.rs` exposes the whole thing
//! over a line-oriented TCP protocol; `service.rs` multiplexes concurrent
//! jobs over one shared PJRT runtime and holds the *similarity cache*
//! (`simcache.rs`): repeated jobs whose `(dataset fingerprint, knn
//! method, k, perplexity, seed)` match a previous job skip the entire
//! similarity stage and go straight to optimisation, reported through
//! `StageTimings::sim_cache_hit` and the protocol's `wait`/`status`
//! responses.

pub mod job;
pub mod pipeline;
pub mod progress;
pub mod protocol;
pub mod service;
pub mod simcache;

pub use job::{JobPhase, JobSpec, KnnMethod, Snapshot};
pub use pipeline::{run_pipeline, run_pipeline_cached, JobResult, StageTimings};
pub use service::{EmbeddingService, JobId};
pub use simcache::{SimKey, SimilarityCache};
