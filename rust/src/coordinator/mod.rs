//! The L3 coordinator (DESIGN.md S20): a *progressive embedding service*
//! in the Progressive Visual Analytics mould the paper positions itself
//! in (Fig. 1, the A-tSNE lineage, the in-browser demo).
//!
//! A job flows through **kNN → perplexity/P → optimise**; the optimise
//! stage is a stepwise [`crate::embed::EmbeddingSession`] driven by the
//! service's *cooperative scheduler* (`service.rs`): `max_concurrent`
//! workers time-slice every active session in step quanta (fair
//! round-robin — a 100k-point job cannot starve small interactive ones),
//! publishing live snapshots straight from session state, honouring
//! user-driven stop, `pause`/`resume` parking, and live `update`
//! re-parameterisation (`job.rs::ParamUpdate`). `protocol.rs` exposes
//! the whole thing over a line-oriented TCP protocol; the service also
//! holds the *similarity cache* (`simcache.rs`): repeated jobs whose
//! `(dataset fingerprint, knn method, k, perplexity, seed)` match a
//! previous job skip the entire similarity stage, and *concurrent*
//! identical submissions coalesce onto a single in-flight computation,
//! reported through `StageTimings::sim_cache_hit` and the protocol's
//! `wait`/`stats` responses.

pub mod job;
pub mod pipeline;
pub mod progress;
pub mod protocol;
pub mod service;
pub mod simcache;

pub use job::{AutoStop, JobPhase, JobSpec, KnnMethod, ParamUpdate, Snapshot};
pub use pipeline::{
    begin_session, prepare_similarities, run_pipeline, run_pipeline_cached, AutoStopTracker,
    JobResult, PreparedJob, StageTimings,
};
pub use service::{EmbeddingService, JobId};
pub use simcache::{SimKey, SimilarityCache};
