//! The L3 coordinator (DESIGN.md S20): a *progressive embedding service*
//! in the Progressive Visual Analytics mould the paper positions itself
//! in (Fig. 1, the A-tSNE lineage, the in-browser demo).
//!
//! A job flows through **kNN → perplexity/P → optimise**; the optimise
//! stage streams progressive snapshots (iteration, KL estimate, point
//! positions) to subscribers, honours user-driven early termination, and
//! — for the `gpgpu` engine — applies the adaptive field-resolution
//! policy over the AOT artifact set. `serve.rs` exposes the whole thing
//! over a line-oriented TCP protocol; `service.rs` multiplexes concurrent
//! jobs over one shared PJRT runtime.

pub mod job;
pub mod pipeline;
pub mod progress;
pub mod protocol;
pub mod service;

pub use job::{JobPhase, JobSpec, KnnMethod, Snapshot};
pub use pipeline::{run_pipeline, JobResult, StageTimings};
pub use service::{EmbeddingService, JobId};
