//! Coordinator-level similarity store (DESIGN.md S20, ROADMAP (b)/(c)):
//! the kNN graph + perplexity calibration + P-matrix build is a pure
//! function of `(dataset content, knn method, k, perplexity, seed)`, and
//! under heavy repeated traffic the same dataset is embedded over and
//! over (engine sweeps, optimiser tweaks, progressive re-runs).
//!
//! The store is **two-level**, mirroring the two halves of the
//! similarity stage:
//!
//! * **Level 1** — the kNN *graph*, keyed by [`GraphKey`]
//!   `(fingerprint, method, k, seed)`. The expensive half: O(N²D) /
//!   tree construction.
//! * **Level 2** — the finished joint [`SparseP`], keyed by [`SimKey`]
//!   `(GraphKey, perplexity)`. The cheap half: a fused calibration pass
//!   over the level-1 graph.
//!
//! A perplexity sweep over one dataset therefore computes the graph
//! **once** and re-runs only the fused P build per perplexity, instead
//! of one full kNN per sweep point.
//!
//! Both levels are bounded LRUs of `Arc`s with **in-flight coalescing**
//! ([`CoalescingLru`]): the first caller of a missing key publishes a
//! *pending* entry and computes; concurrent identical callers block on
//! it and share the result — exactly one computation per key no matter
//! how many jobs race (the `computes` counters are the proof the tests
//! pin). Pending entries are never evicted; if a leader fails, waiters
//! wake and one takes over.
//!
//! With [`SimilarityCache::with_disk`] both levels additionally persist
//! through a [`SimStore`] (`coordinator::store`): a memory miss probes
//! disk before computing, and every computed value is written back —
//! versioned, checksummed records, so a restarted service keeps its hot
//! set and corrupt or version-skewed entries degrade to recomputation,
//! never to trusted garbage.
//!
//! One per [`super::EmbeddingService`]; pipelines run outside a service
//! pass `None` and behave exactly as before.

use std::collections::HashMap;
use std::hash::Hash;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::hd::{KnnGraph, SparseP};

use super::job::KnnMethod;
use super::store::SimStore;

/// Everything the kNN graph depends on (store level 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GraphKey {
    /// `Dataset::fingerprint()` — content hash, not the dataset name.
    pub fingerprint: u64,
    pub method: KnnMethod,
    /// Effective neighbour count (after the `min(n-1)` clamp).
    pub k: usize,
    /// Seed feeding randomised kNN construction (0 for backends whose
    /// output ignores the seed — see `KnnMethod::seed_sensitive`).
    pub seed: u64,
}

/// Everything the finished P matrix depends on (store level 2): the
/// graph plus the perplexity the fused build calibrated against.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SimKey {
    pub graph: GraphKey,
    /// Bit pattern of the *effective* perplexity (after the `min(k)`
    /// clamp); f32 carries no NaN here so bit equality is value equality.
    pub perplexity_bits: u32,
}

/// Where a served value came from — the cache-hit taxonomy `wait`
/// reports and the restart tests pin.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Source {
    /// Ready in memory, or coalesced onto a concurrent leader.
    Memory,
    /// Loaded from the on-disk store (restart warm-up path).
    Disk,
    /// Actually computed by this caller.
    Computed,
}

impl Source {
    /// Did the caller skip the computation?
    pub fn is_hit(&self) -> bool {
        !matches!(self, Source::Computed)
    }
}

/// Rendezvous for one in-flight computation.
struct Pending<V> {
    state: Mutex<PendingState<V>>,
    cv: Condvar,
}

enum PendingState<V> {
    Computing,
    Ready(Arc<V>),
    Failed,
}

enum Slot<V> {
    Ready { v: Arc<V>, last_used: u64 },
    Pending(Arc<Pending<V>>),
}

/// Counter snapshot of one level.
/// `hits` counts memory hits, coalesced waits *and* disk hits (the
/// caller skipped the computation); `misses` and `computes` count
/// actual computations started; `coalesced` and `evictions` break out
/// the waiter and LRU-pressure paths for the observability surface.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct LevelStats {
    pub hits: u64,
    pub misses: u64,
    pub computes: u64,
    pub disk_hits: u64,
    /// Subset of `hits` that were waits coalesced onto a concurrent
    /// identical computation.
    pub coalesced: u64,
    /// Ready entries dropped under LRU capacity pressure.
    pub evictions: u64,
}

impl LevelStats {
    /// JSON object for the `metrics` protocol command, fields prefixed
    /// (e.g. `p_hits`, `graph_evictions`).
    pub fn to_json_fields(&self, prefix: &str) -> Vec<(String, crate::util::json::Json)> {
        use crate::util::json::Json;
        [
            ("hits", self.hits),
            ("misses", self.misses),
            ("computes", self.computes),
            ("disk_hits", self.disk_hits),
            ("coalesced", self.coalesced),
            ("evictions", self.evictions),
        ]
        .into_iter()
        .map(|(k, v)| (format!("{prefix}_{k}"), Json::Num(v as f64)))
        .collect()
    }
}

/// Bounded LRU map with in-flight coalescing — the machinery shared by
/// both store levels. Value-generic so the kNN-graph and P levels are
/// one implementation.
struct CoalescingLru<K, V> {
    map: Mutex<HashMap<K, Slot<V>>>,
    capacity: usize,
    tick: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    /// Computations actually run through `get_or_compute` (coalesced
    /// waiters and disk loads do not count — that is the point).
    computes: AtomicU64,
    disk_hits: AtomicU64,
    coalesced: AtomicU64,
    evictions: AtomicU64,
}

impl<K: Eq + Hash + Copy, V> CoalescingLru<K, V> {
    fn new(capacity: usize) -> Self {
        Self {
            map: Mutex::new(HashMap::new()),
            capacity: capacity.max(1),
            tick: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            computes: AtomicU64::new(0),
            disk_hits: AtomicU64::new(0),
            coalesced: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    fn next_tick(&self) -> u64 {
        self.tick.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Evict least-recently-used *ready* entries down to capacity
    /// (pending entries are in flight and never evicted). Counted in
    /// `LevelStats::evictions`.
    fn evict_over_capacity(&self, map: &mut HashMap<K, Slot<V>>) {
        loop {
            let ready = map
                .iter()
                .filter_map(|(k, s)| match s {
                    Slot::Ready { last_used, .. } => Some((*k, *last_used)),
                    Slot::Pending(_) => None,
                })
                .collect::<Vec<_>>();
            if ready.len() <= self.capacity {
                return;
            }
            let oldest = ready.iter().min_by_key(|(_, t)| *t).map(|(k, _)| *k).unwrap();
            map.remove(&oldest);
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Look up a value; counts a hit or miss and refreshes recency.
    /// A pending (in-flight) entry counts as a miss and returns `None`
    /// without waiting — use [`Self::get_or_compute`] to coalesce.
    fn get(&self, key: &K) -> Option<Arc<V>> {
        let tick = self.next_tick();
        let mut map = self.map.lock().unwrap();
        match map.get_mut(key) {
            Some(Slot::Ready { v, last_used }) => {
                *last_used = tick;
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(v.clone())
            }
            _ => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Insert (or refresh) a ready entry, evicting the least-recently-
    /// used one when over capacity.
    fn insert(&self, key: K, v: Arc<V>) {
        let tick = self.next_tick();
        let mut map = self.map.lock().unwrap();
        map.insert(key, Slot::Ready { v, last_used: tick });
        self.evict_over_capacity(&mut map);
    }

    /// The coalescing entry point: returns the value and its [`Source`].
    ///
    /// * Ready entry → `Memory`, immediately.
    /// * Nothing → this caller is the *leader*: a pending entry is
    ///   published, `load` (the disk probe) runs first; only if it
    ///   misses does `compute` run (outside the map lock either way).
    ///   The result is installed and every waiter woken.
    /// * Pending entry → the caller blocks until the leader finishes and
    ///   shares its result (`Memory`: no computation ran for it). If the
    ///   leader failed, one waiter takes over as the new leader.
    fn get_or_compute(
        &self,
        key: &K,
        load: impl FnOnce() -> Option<Arc<V>>,
        compute: impl FnOnce() -> anyhow::Result<Arc<V>>,
    ) -> anyhow::Result<(Arc<V>, Source)> {
        let mut load = Some(load);
        let mut compute = Some(compute);
        loop {
            enum Action<V> {
                Hit(Arc<V>),
                Lead(Arc<Pending<V>>),
                Wait(Arc<Pending<V>>),
            }
            let action = {
                let tick = self.next_tick();
                let mut map = self.map.lock().unwrap();
                match map.get_mut(key) {
                    Some(Slot::Ready { v, last_used }) => {
                        *last_used = tick;
                        self.hits.fetch_add(1, Ordering::Relaxed);
                        Action::Hit(v.clone())
                    }
                    Some(Slot::Pending(pending)) => Action::Wait(pending.clone()),
                    None => {
                        let pending = Arc::new(Pending {
                            state: Mutex::new(PendingState::Computing),
                            cv: Condvar::new(),
                        });
                        map.insert(*key, Slot::Pending(pending.clone()));
                        Action::Lead(pending)
                    }
                }
            };
            match action {
                Action::Hit(v) => return Ok((v, Source::Memory)),
                Action::Lead(pending) => {
                    // Run the disk probe / computation with no cache lock
                    // held; on success promote the entry, on failure (or
                    // panic — the guard below) remove it so waiters can
                    // retry.
                    struct Cleanup<'a, K: Eq + Hash + Copy, V> {
                        cache: &'a CoalescingLru<K, V>,
                        key: K,
                        pending: Arc<Pending<V>>,
                        armed: bool,
                    }
                    impl<K: Eq + Hash + Copy, V> Drop for Cleanup<'_, K, V> {
                        fn drop(&mut self) {
                            if !self.armed {
                                return;
                            }
                            let mut map = self.cache.map.lock().unwrap();
                            if let Some(Slot::Pending(cur)) = map.get(&self.key) {
                                if Arc::ptr_eq(cur, &self.pending) {
                                    map.remove(&self.key);
                                }
                            }
                            drop(map);
                            *self.pending.state.lock().unwrap() = PendingState::Failed;
                            self.pending.cv.notify_all();
                        }
                    }
                    let mut guard =
                        Cleanup { cache: self, key: *key, pending: pending.clone(), armed: true };
                    let loader = load.take().expect("a caller leads at most once");
                    let (result, source) = match loader() {
                        Some(v) => {
                            self.hits.fetch_add(1, Ordering::Relaxed);
                            self.disk_hits.fetch_add(1, Ordering::Relaxed);
                            (Ok(v), Source::Disk)
                        }
                        None => {
                            self.misses.fetch_add(1, Ordering::Relaxed);
                            self.computes.fetch_add(1, Ordering::Relaxed);
                            let f = compute.take().expect("a caller leads at most once");
                            (f(), Source::Computed)
                        }
                    };
                    match result {
                        Ok(v) => {
                            guard.armed = false;
                            let tick = self.next_tick();
                            {
                                let mut map = self.map.lock().unwrap();
                                map.insert(*key, Slot::Ready { v: v.clone(), last_used: tick });
                                self.evict_over_capacity(&mut map);
                            }
                            *pending.state.lock().unwrap() = PendingState::Ready(v.clone());
                            pending.cv.notify_all();
                            return Ok((v, source));
                        }
                        Err(e) => {
                            // Cleanup runs via the guard.
                            drop(guard);
                            return Err(e);
                        }
                    }
                }
                Action::Wait(pending) => {
                    let mut state = pending.state.lock().unwrap();
                    let outcome = loop {
                        let resolved = match &*state {
                            PendingState::Computing => None,
                            PendingState::Ready(v) => Some(Some(v.clone())),
                            PendingState::Failed => Some(None),
                        };
                        match resolved {
                            None => state = pending.cv.wait(state).unwrap(),
                            Some(out) => break out,
                        }
                    };
                    drop(state);
                    if let Some(v) = outcome {
                        // Coalesced: the leader's work served us.
                        self.hits.fetch_add(1, Ordering::Relaxed);
                        self.coalesced.fetch_add(1, Ordering::Relaxed);
                        return Ok((v, Source::Memory));
                    }
                    // Leader failed — loop: retry as a potential leader.
                    // (A retrying waiter may still hold its own load/
                    // compute closures; re-arm them if consumed is
                    // impossible — they were consumed only if *we* led.)
                }
            }
        }
    }

    fn stats(&self) -> LevelStats {
        LevelStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            computes: self.computes.load(Ordering::Relaxed),
            disk_hits: self.disk_hits.load(Ordering::Relaxed),
            coalesced: self.coalesced.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
        }
    }

    fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }
}

/// What [`SimilarityCache::get_or_compute`] hands back: the P matrix,
/// where it came from, and (when the P had to be built) where its kNN
/// graph came from plus the split stage timings.
pub struct SimLookup {
    pub p: Arc<SparseP>,
    pub p_source: Source,
    /// `None` when the P itself was served (the graph was never needed).
    pub graph_source: Option<Source>,
    /// Seconds spent inside the kNN computation (0 when not computed).
    pub knn_s: f64,
    /// Seconds spent inside the fused P build (0 when not computed).
    pub perplexity_s: f64,
}

/// The two-level similarity store: a P-level and a graph-level
/// [`CoalescingLru`] over one optional on-disk [`SimStore`].
pub struct SimilarityCache {
    p_level: CoalescingLru<SimKey, SparseP>,
    graph_level: CoalescingLru<GraphKey, KnnGraph>,
    disk: Option<SimStore>,
}

impl SimilarityCache {
    /// In-memory store: `capacity` ready entries per level.
    pub fn new(capacity: usize) -> Self {
        Self {
            p_level: CoalescingLru::new(capacity),
            graph_level: CoalescingLru::new(capacity),
            disk: None,
        }
    }

    /// Store with disk persistence under `dir` (see
    /// [`crate::coordinator::store::SimStore`]). An unusable directory
    /// degrades to the in-memory store with a warning — persistence is
    /// an optimisation, never a failure mode of the job path.
    pub fn with_disk(capacity: usize, dir: &Path) -> Self {
        let disk = match SimStore::open(dir) {
            Ok(store) => Some(store),
            Err(e) => {
                eprintln!(
                    "warning: similarity store dir {} unusable ({e}); running in-memory",
                    dir.display()
                );
                None
            }
        };
        Self {
            p_level: CoalescingLru::new(capacity),
            graph_level: CoalescingLru::new(capacity),
            disk,
        }
    }

    /// Whether a disk store is attached (diagnostics).
    pub fn has_disk(&self) -> bool {
        self.disk.is_some()
    }

    /// The full two-level lookup. `knn` computes the level-1 graph;
    /// `build_p` turns a graph into the joint P (and may flag phase
    /// transitions on the caller's side). Either closure runs at most
    /// once, and only on the path that actually needed it:
    ///
    /// * P in memory/on disk → neither runs.
    /// * P missing, graph in memory/on disk → only `build_p` runs.
    /// * Both missing → `knn` then `build_p`.
    ///
    /// Computed values are written through to disk when attached.
    pub fn get_or_compute(
        &self,
        key: &SimKey,
        knn: impl FnOnce() -> anyhow::Result<Arc<KnnGraph>>,
        build_p: impl FnOnce(&KnnGraph) -> anyhow::Result<Arc<SparseP>>,
    ) -> anyhow::Result<SimLookup> {
        // Shuttle the inner-level outcome out of the P-compute closure
        // (it only runs when the P level misses everywhere).
        let mut graph_source = None;
        let mut knn_s = 0.0f64;
        let mut perplexity_s = 0.0f64;
        let (p, p_source) = self.p_level.get_or_compute(
            key,
            || self.disk.as_ref().and_then(|d| d.load_p(key)).map(Arc::new),
            || {
                let (graph, gsrc) = self.graph_level.get_or_compute(
                    &key.graph,
                    || self.disk.as_ref().and_then(|d| d.load_graph(&key.graph)).map(Arc::new),
                    || {
                        let t = std::time::Instant::now();
                        let g = knn()?;
                        knn_s = t.elapsed().as_secs_f64();
                        if let Some(d) = &self.disk {
                            d.store_graph(&key.graph, &g);
                        }
                        Ok(g)
                    },
                )?;
                graph_source = Some(gsrc);
                let t = std::time::Instant::now();
                let p = build_p(&graph)?;
                perplexity_s = t.elapsed().as_secs_f64();
                if let Some(d) = &self.disk {
                    d.store_p(key, &p);
                }
                Ok(p)
            },
        )?;
        Ok(SimLookup { p, p_source, graph_source, knn_s, perplexity_s })
    }

    /// P-level lookup without computing (tests/tools).
    pub fn get(&self, key: &SimKey) -> Option<Arc<SparseP>> {
        self.p_level.get(key)
    }

    /// Insert a ready P entry (tests/tools).
    pub fn insert(&self, key: SimKey, p: Arc<SparseP>) {
        self.p_level.insert(key, p);
    }

    /// `(hits, misses)` of the P level since construction — the
    /// service-facing numbers (`stats` command, `sim_cache_hit`).
    pub fn stats(&self) -> (u64, u64) {
        let s = self.p_level.stats();
        (s.hits, s.misses)
    }

    /// P-matrix computations actually executed.
    pub fn computes(&self) -> u64 {
        self.p_level.stats().computes
    }

    /// Full counter snapshot of the P level.
    pub fn p_stats(&self) -> LevelStats {
        self.p_level.stats()
    }

    /// Full counter snapshot of the graph level. `computes` here is the
    /// number of kNN graphs actually built — the number the restart
    /// acceptance test pins at zero.
    pub fn graph_stats(&self) -> LevelStats {
        self.graph_level.stats()
    }

    /// Ready + pending entries in the P level.
    pub fn len(&self) -> usize {
        self.p_level.len()
    }

    /// Ready + pending entries in the graph level.
    pub fn graph_len(&self) -> usize {
        self.graph_level.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hd::sparse::Csr;

    fn p(tag: f32) -> Arc<SparseP> {
        Arc::new(SparseP {
            csr: Csr::from_rows(1, 1, 1, vec![0], vec![tag]),
            perplexity: tag,
        })
    }

    fn graph(n: usize, k: usize) -> Arc<KnnGraph> {
        let idx = (0..n * k).map(|i| ((i + 1) % n) as u32).collect();
        let d2 = (0..n * k).map(|i| i as f32).collect();
        Arc::new(KnnGraph { n, k, idx, d2 })
    }

    fn gkey(fp: u64) -> GraphKey {
        GraphKey { fingerprint: fp, method: KnnMethod::Brute, k: 10, seed: 1 }
    }

    fn key(fp: u64) -> SimKey {
        SimKey { graph: gkey(fp), perplexity_bits: 8.0f32.to_bits() }
    }

    fn tmp_dir(name: &str) -> std::path::PathBuf {
        let dir =
            std::env::temp_dir().join(format!("gsne-simcache-{}-{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn hit_and_miss_accounting() {
        let c = SimilarityCache::new(4);
        assert!(c.get(&key(1)).is_none());
        c.insert(key(1), p(1.0));
        let got = c.get(&key(1)).expect("hit");
        assert_eq!(got.perplexity, 1.0);
        assert_eq!(c.stats(), (1, 1));
    }

    #[test]
    fn distinct_parameters_are_distinct_keys() {
        let c = SimilarityCache::new(4);
        c.insert(key(1), p(1.0));
        let mut k2 = key(1);
        k2.graph.k = 11;
        assert!(c.get(&k2).is_none(), "different k must miss");
        let mut k3 = key(1);
        k3.perplexity_bits = 9.0f32.to_bits();
        assert!(c.get(&k3).is_none(), "different perplexity must miss");
        let mut k4 = key(1);
        k4.graph.method = KnnMethod::VpTree;
        assert!(c.get(&k4).is_none(), "different method must miss");
    }

    #[test]
    fn lru_evicts_the_coldest() {
        let c = SimilarityCache::new(2);
        c.insert(key(1), p(1.0));
        c.insert(key(2), p(2.0));
        let _ = c.get(&key(1)); // key 2 is now the coldest
        c.insert(key(3), p(3.0));
        assert_eq!(c.len(), 2);
        assert!(c.get(&key(1)).is_some());
        assert!(c.get(&key(2)).is_none(), "LRU entry must be evicted");
        assert!(c.get(&key(3)).is_some());
        assert_eq!(c.p_stats().evictions, 1, "capacity pressure is counted");
    }

    #[test]
    fn get_or_compute_sequential_hit_miss() {
        let c = SimilarityCache::new(4);
        let a = c
            .get_or_compute(&key(1), || Ok(graph(4, 2)), |_| Ok(p(1.0)))
            .unwrap();
        assert_eq!(a.p_source, Source::Computed, "first caller leads");
        assert_eq!(a.graph_source, Some(Source::Computed));
        let b = c
            .get_or_compute(
                &key(1),
                || panic!("must not recompute the graph"),
                |_| panic!("must not recompute P"),
            )
            .unwrap();
        assert_eq!(b.p_source, Source::Memory);
        assert!(b.graph_source.is_none(), "P hit never touches the graph level");
        assert!(Arc::ptr_eq(&a.p, &b.p), "both callers share one matrix");
        assert_eq!(c.stats(), (1, 1));
        assert_eq!(c.computes(), 1);
    }

    #[test]
    fn perplexity_sweep_shares_one_graph() {
        // ROADMAP (b): same (fingerprint, method, k, seed), three
        // perplexities — one kNN computation, three P builds.
        let c = SimilarityCache::new(8);
        for (i, perp) in [4.0f32, 8.0, 16.0].iter().enumerate() {
            let k = SimKey { graph: gkey(1), perplexity_bits: perp.to_bits() };
            let lookup = c
                .get_or_compute(
                    &k,
                    || Ok(graph(6, 3)),
                    |g| {
                        assert_eq!(g.n, 6, "P build sees the shared graph");
                        Ok(p(*perp))
                    },
                )
                .unwrap();
            assert_eq!(lookup.p_source, Source::Computed);
            let expect = if i == 0 { Source::Computed } else { Source::Memory };
            assert_eq!(lookup.graph_source, Some(expect), "perplexity #{i}");
        }
        assert_eq!(c.computes(), 3, "three P builds");
        assert_eq!(c.graph_stats().computes, 1, "exactly one kNN");
        assert_eq!(c.graph_len(), 1);
    }

    #[test]
    fn concurrent_identical_submissions_coalesce_to_one_compute() {
        // Deterministic interleaving: the leader signals from inside its
        // compute closure, the waiter only starts once the pending entry
        // is definitely published, then the leader finishes.
        let c = Arc::new(SimilarityCache::new(4));
        let in_compute = Arc::new((Mutex::new(false), Condvar::new()));
        let release = Arc::new((Mutex::new(false), Condvar::new()));

        let leader = {
            let c = c.clone();
            let in_compute = in_compute.clone();
            let release = release.clone();
            std::thread::spawn(move || {
                c.get_or_compute(
                    &key(7),
                    || Ok(graph(4, 2)),
                    |_| {
                        // Announce we are computing (pending entry live).
                        *in_compute.0.lock().unwrap() = true;
                        in_compute.1.notify_all();
                        // Block until the waiter is in the cache too.
                        let mut go = release.0.lock().unwrap();
                        while !*go {
                            go = release.1.wait(go).unwrap();
                        }
                        Ok(p(7.0))
                    },
                )
                .unwrap()
            })
        };
        {
            let mut started = in_compute.0.lock().unwrap();
            while !*started {
                started = in_compute.1.wait(started).unwrap();
            }
        }
        let waiter = {
            let c = c.clone();
            let release = release.clone();
            std::thread::spawn(move || {
                // Give the waiter a moment to actually block, then let
                // the leader finish. (Ordering is already guaranteed by
                // the pending entry; the sleep only widens the window in
                // which a broken implementation would double-compute.)
                let releaser = std::thread::spawn(move || {
                    std::thread::sleep(std::time::Duration::from_millis(30));
                    *release.0.lock().unwrap() = true;
                    release.1.notify_all();
                });
                let out = c
                    .get_or_compute(
                        &key(7),
                        || panic!("waiter must never compute a graph"),
                        |_| panic!("waiter must never compute P"),
                    )
                    .unwrap();
                releaser.join().unwrap();
                out
            })
        };
        let lead = leader.join().unwrap();
        let wait = waiter.join().unwrap();
        assert_eq!(lead.p_source, Source::Computed, "leader computed");
        assert_eq!(wait.p_source, Source::Memory, "waiter coalesced into a hit");
        assert!(Arc::ptr_eq(&lead.p, &wait.p));
        assert_eq!(c.computes(), 1, "exactly one computation ran");
        assert_eq!(c.stats(), (1, 1));
        assert_eq!(c.p_stats().coalesced, 1, "the wait is broken out for observability");
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn failed_leader_lets_a_waiter_take_over() {
        let c = Arc::new(SimilarityCache::new(4));
        let failed = c.get_or_compute(&key(3), || anyhow::bail!("knn exploded"), |_| p_ok(3.0));
        assert!(failed.is_err());
        assert_eq!(c.len(), 0, "failed computation leaves no entry");
        // The key is free again: the next caller leads and succeeds.
        let got = c.get_or_compute(&key(3), || Ok(graph(4, 2)), |_| p_ok(3.0)).unwrap();
        assert_eq!(got.p_source, Source::Computed);
        assert_eq!(got.p.perplexity, 3.0);
        assert_eq!(c.computes(), 2, "both attempts started a P computation");
        // The graph level cleaned up its failed pending entry too.
        assert_eq!(c.graph_len(), 1, "only the successful graph remains");
    }

    fn p_ok(tag: f32) -> anyhow::Result<Arc<SparseP>> {
        Ok(p(tag))
    }

    #[test]
    fn pending_entries_survive_eviction_pressure() {
        let c = SimilarityCache::new(1);
        // Manually wedge a pending entry, then flood with ready inserts.
        let pending = Arc::new(Pending {
            state: Mutex::new(PendingState::<SparseP>::Computing),
            cv: Condvar::new(),
        });
        c.p_level.map.lock().unwrap().insert(key(9), Slot::Pending(pending));
        c.insert(key(1), p(1.0));
        c.insert(key(2), p(2.0));
        let map = c.p_level.map.lock().unwrap();
        assert!(
            matches!(map.get(&key(9)), Some(Slot::Pending(_))),
            "in-flight entry must never be evicted"
        );
        assert_eq!(map.len(), 2, "one ready + the pending");
    }

    #[test]
    fn disk_store_survives_a_cache_restart() {
        let dir = tmp_dir("restart");
        let first = SimilarityCache::with_disk(2, &dir);
        let a = first.get_or_compute(&key(5), || Ok(graph(4, 2)), |_| p_ok(5.0)).unwrap();
        assert_eq!(a.p_source, Source::Computed);

        // "Restart": a fresh cache over the same directory.
        let second = SimilarityCache::with_disk(2, &dir);
        let b = second
            .get_or_compute(
                &key(5),
                || panic!("graph must come from disk, not recompute"),
                |_| panic!("P must come from disk, not recompute"),
            )
            .unwrap();
        assert_eq!(b.p_source, Source::Disk, "restart serves from the store");
        assert!(b.p_source.is_hit());
        assert_eq!(b.p.perplexity, 5.0);
        assert_eq!(second.computes(), 0);
        assert_eq!(second.graph_stats().computes, 0, "zero recomputed kNN graphs");
        assert_eq!(second.p_stats().disk_hits, 1);

        // A new perplexity over the same data only rebuilds P: the
        // *graph* comes from disk.
        let k2 = SimKey { graph: gkey(5), perplexity_bits: 12.0f32.to_bits() };
        let c2 = second
            .get_or_compute(&k2, || panic!("graph is on disk"), |_| p_ok(12.0))
            .unwrap();
        assert_eq!(c2.p_source, Source::Computed);
        assert_eq!(c2.graph_source, Some(Source::Disk));
        assert_eq!(second.graph_stats().computes, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_disk_entries_degrade_to_recomputation() {
        let dir = tmp_dir("corrupt");
        {
            let c = SimilarityCache::with_disk(2, &dir);
            c.get_or_compute(&key(6), || Ok(graph(4, 2)), |_| p_ok(6.0)).unwrap();
        }
        // Scribble over every record.
        for entry in std::fs::read_dir(&dir).unwrap().flatten() {
            std::fs::write(entry.path(), b"corrupted beyond recognition").unwrap();
        }
        let c = SimilarityCache::with_disk(2, &dir);
        let got = c.get_or_compute(&key(6), || Ok(graph(4, 2)), |_| p_ok(6.5)).unwrap();
        assert_eq!(got.p_source, Source::Computed, "corruption is a miss, not garbage");
        assert_eq!(got.p.perplexity, 6.5);
        assert_eq!(c.p_stats().disk_hits, 0);
        // The recomputation healed the store.
        let c2 = SimilarityCache::with_disk(2, &dir);
        let healed = c2.get_or_compute(&key(6), || Ok(graph(4, 2)), |_| p_ok(7.0)).unwrap();
        assert_eq!(healed.p_source, Source::Disk);
        assert_eq!(healed.p.perplexity, 6.5, "healed record serves the recomputed value");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn eviction_does_not_lose_persisted_entries() {
        // Memory capacity 1 with three keys: evicted entries come back
        // from disk, not from recomputation.
        let dir = tmp_dir("evict");
        let c = SimilarityCache::with_disk(1, &dir);
        for fp in 1..=3u64 {
            c.get_or_compute(&key(fp), || Ok(graph(4, 2)), |_| p_ok(fp as f32)).unwrap();
        }
        assert_eq!(c.len(), 1, "memory stayed bounded");
        let back = c
            .get_or_compute(&key(1), || panic!("on disk"), |_| panic!("on disk"))
            .unwrap();
        assert_eq!(back.p_source, Source::Disk);
        assert_eq!(back.p.perplexity, 1.0);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
