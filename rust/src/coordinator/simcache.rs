//! Coordinator-level similarity cache (DESIGN.md S20, ROADMAP north
//! star): the kNN graph + perplexity calibration + P-matrix build is a
//! pure function of `(dataset content, knn method, k, perplexity, seed)`,
//! and under heavy repeated traffic the same dataset is embedded over and
//! over (engine sweeps, parameter tweaks to the *optimiser*, progressive
//! re-runs). Caching the finished [`SparseP`] lets every repeat job skip
//! straight to optimisation — the paper's entire "similarities" timing
//! row drops to a dataset fingerprint.
//!
//! The cache is a small LRU keyed by [`SimKey`] holding `Arc<SparseP>`
//! (jobs share the matrix; it is immutable after construction). One per
//! [`super::EmbeddingService`]; pipelines run outside a service pass
//! `None` and behave exactly as before.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::hd::SparseP;

use super::job::KnnMethod;

/// Everything the similarity stage's output depends on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SimKey {
    /// `Dataset::fingerprint()` — content hash, not the dataset name.
    pub fingerprint: u64,
    pub method: KnnMethod,
    /// Effective neighbour count (after the `min(n-1)` clamp).
    pub k: usize,
    /// Bit pattern of the *effective* perplexity (after the `min(k)`
    /// clamp); f32 carries no NaN here so bit equality is value equality.
    pub perplexity_bits: u32,
    /// Seed feeding randomised kNN construction (0 for backends whose
    /// output ignores the seed — see `KnnMethod::seed_sensitive`).
    pub seed: u64,
}

struct Entry {
    p: Arc<SparseP>,
    last_used: u64,
}

/// Bounded LRU map from [`SimKey`] to a shared P matrix.
pub struct SimilarityCache {
    map: Mutex<HashMap<SimKey, Entry>>,
    capacity: usize,
    tick: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl SimilarityCache {
    pub fn new(capacity: usize) -> Self {
        Self {
            map: Mutex::new(HashMap::new()),
            capacity: capacity.max(1),
            tick: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Look up a P matrix; counts a hit or miss and refreshes recency.
    pub fn get(&self, key: &SimKey) -> Option<Arc<SparseP>> {
        let tick = self.tick.fetch_add(1, Ordering::Relaxed) + 1;
        let mut map = self.map.lock().unwrap();
        match map.get_mut(key) {
            Some(e) => {
                e.last_used = tick;
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(e.p.clone())
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Insert (or refresh) an entry, evicting the least-recently-used
    /// one when over capacity.
    pub fn insert(&self, key: SimKey, p: Arc<SparseP>) {
        let tick = self.tick.fetch_add(1, Ordering::Relaxed) + 1;
        let mut map = self.map.lock().unwrap();
        map.insert(key, Entry { p, last_used: tick });
        while map.len() > self.capacity {
            let oldest = map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| *k)
                .expect("non-empty map over capacity");
            map.remove(&oldest);
        }
    }

    /// `(hits, misses)` since construction.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }

    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hd::sparse::Csr;

    fn p(tag: f32) -> Arc<SparseP> {
        Arc::new(SparseP {
            csr: Csr::from_rows(1, 1, 1, vec![0], vec![tag]),
            perplexity: tag,
        })
    }

    fn key(fp: u64) -> SimKey {
        SimKey {
            fingerprint: fp,
            method: KnnMethod::Brute,
            k: 10,
            perplexity_bits: 8.0f32.to_bits(),
            seed: 1,
        }
    }

    #[test]
    fn hit_and_miss_accounting() {
        let c = SimilarityCache::new(4);
        assert!(c.get(&key(1)).is_none());
        c.insert(key(1), p(1.0));
        let got = c.get(&key(1)).expect("hit");
        assert_eq!(got.perplexity, 1.0);
        assert_eq!(c.stats(), (1, 1));
    }

    #[test]
    fn distinct_parameters_are_distinct_keys() {
        let c = SimilarityCache::new(4);
        c.insert(key(1), p(1.0));
        let mut k2 = key(1);
        k2.k = 11;
        assert!(c.get(&k2).is_none(), "different k must miss");
        let mut k3 = key(1);
        k3.perplexity_bits = 9.0f32.to_bits();
        assert!(c.get(&k3).is_none(), "different perplexity must miss");
        let mut k4 = key(1);
        k4.method = KnnMethod::VpTree;
        assert!(c.get(&k4).is_none(), "different method must miss");
    }

    #[test]
    fn lru_evicts_the_coldest() {
        let c = SimilarityCache::new(2);
        c.insert(key(1), p(1.0));
        c.insert(key(2), p(2.0));
        let _ = c.get(&key(1)); // key 2 is now the coldest
        c.insert(key(3), p(3.0));
        assert_eq!(c.len(), 2);
        assert!(c.get(&key(1)).is_some());
        assert!(c.get(&key(2)).is_none(), "LRU entry must be evicted");
        assert!(c.get(&key(3)).is_some());
    }
}
