//! Coordinator-level similarity cache (DESIGN.md S20, ROADMAP north
//! star): the kNN graph + perplexity calibration + P-matrix build is a
//! pure function of `(dataset content, knn method, k, perplexity, seed)`,
//! and under heavy repeated traffic the same dataset is embedded over and
//! over (engine sweeps, parameter tweaks to the *optimiser*, progressive
//! re-runs). Caching the finished [`SparseP`] lets every repeat job skip
//! straight to optimisation — the paper's entire "similarities" timing
//! row drops to a dataset fingerprint.
//!
//! The cache is a small LRU keyed by [`SimKey`] holding `Arc<SparseP>`
//! (jobs share the matrix; it is immutable after construction), with
//! **in-flight coalescing**: [`SimilarityCache::get_or_compute`] publishes
//! a *pending* entry before the leader starts computing, so concurrent
//! identical submissions block on the leader's result instead of all
//! missing and recomputing the same kNN graph. Exactly one computation
//! runs per distinct key no matter how many jobs race on it (the
//! `computes` counter is the proof the tests pin). Pending entries are
//! never evicted; if the leader fails, waiters wake, one of them becomes
//! the new leader, and the rest re-wait.
//!
//! One per [`super::EmbeddingService`]; pipelines run outside a service
//! pass `None` and behave exactly as before.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::hd::SparseP;

use super::job::KnnMethod;

/// Everything the similarity stage's output depends on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SimKey {
    /// `Dataset::fingerprint()` — content hash, not the dataset name.
    pub fingerprint: u64,
    pub method: KnnMethod,
    /// Effective neighbour count (after the `min(n-1)` clamp).
    pub k: usize,
    /// Bit pattern of the *effective* perplexity (after the `min(k)`
    /// clamp); f32 carries no NaN here so bit equality is value equality.
    pub perplexity_bits: u32,
    /// Seed feeding randomised kNN construction (0 for backends whose
    /// output ignores the seed — see `KnnMethod::seed_sensitive`).
    pub seed: u64,
}

/// Rendezvous for one in-flight computation.
struct Pending {
    state: Mutex<PendingState>,
    cv: Condvar,
}

enum PendingState {
    Computing,
    Ready(Arc<SparseP>),
    Failed,
}

enum Slot {
    Ready { p: Arc<SparseP>, last_used: u64 },
    Pending(Arc<Pending>),
}

/// Bounded LRU map from [`SimKey`] to a shared P matrix, with in-flight
/// coalescing of concurrent identical computations.
pub struct SimilarityCache {
    map: Mutex<HashMap<SimKey, Slot>>,
    capacity: usize,
    tick: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    /// Similarity computations actually run through `get_or_compute`
    /// (coalesced waiters do not count — that is the point).
    computes: AtomicU64,
}

impl SimilarityCache {
    pub fn new(capacity: usize) -> Self {
        Self {
            map: Mutex::new(HashMap::new()),
            capacity: capacity.max(1),
            tick: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            computes: AtomicU64::new(0),
        }
    }

    fn next_tick(&self) -> u64 {
        self.tick.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Evict least-recently-used *ready* entries down to capacity
    /// (pending entries are in flight and never evicted).
    fn evict_over_capacity(map: &mut HashMap<SimKey, Slot>, capacity: usize) {
        loop {
            let ready = map
                .iter()
                .filter_map(|(k, s)| match s {
                    Slot::Ready { last_used, .. } => Some((*k, *last_used)),
                    Slot::Pending(_) => None,
                })
                .collect::<Vec<_>>();
            if ready.len() <= capacity {
                return;
            }
            let oldest = ready.iter().min_by_key(|(_, t)| *t).map(|(k, _)| *k).unwrap();
            map.remove(&oldest);
        }
    }

    /// Look up a P matrix; counts a hit or miss and refreshes recency.
    /// A pending (in-flight) entry counts as a miss and returns `None`
    /// without waiting — use [`Self::get_or_compute`] to coalesce.
    pub fn get(&self, key: &SimKey) -> Option<Arc<SparseP>> {
        let tick = self.next_tick();
        let mut map = self.map.lock().unwrap();
        match map.get_mut(key) {
            Some(Slot::Ready { p, last_used }) => {
                *last_used = tick;
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(p.clone())
            }
            _ => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Insert (or refresh) a ready entry, evicting the least-recently-
    /// used one when over capacity.
    pub fn insert(&self, key: SimKey, p: Arc<SparseP>) {
        let tick = self.next_tick();
        let mut map = self.map.lock().unwrap();
        map.insert(key, Slot::Ready { p, last_used: tick });
        Self::evict_over_capacity(&mut map, self.capacity);
    }

    /// The coalescing entry point: returns `(P, was_hit)`.
    ///
    /// * Ready entry → hit, immediately.
    /// * Nothing → this caller is the *leader*: a pending entry is
    ///   published, `compute` runs (outside the map lock), the result is
    ///   installed and every waiter woken. Counts one miss + one compute.
    /// * Pending entry → the caller blocks until the leader finishes and
    ///   shares its result (counts a *hit*: no computation ran for it).
    ///   If the leader failed, one waiter takes over as the new leader.
    pub fn get_or_compute(
        &self,
        key: &SimKey,
        compute: impl FnOnce() -> anyhow::Result<Arc<SparseP>>,
    ) -> anyhow::Result<(Arc<SparseP>, bool)> {
        let mut compute = Some(compute);
        loop {
            enum Action {
                Hit(Arc<SparseP>),
                Lead(Arc<Pending>),
                Wait(Arc<Pending>),
            }
            let action = {
                let tick = self.next_tick();
                let mut map = self.map.lock().unwrap();
                match map.get_mut(key) {
                    Some(Slot::Ready { p, last_used }) => {
                        *last_used = tick;
                        self.hits.fetch_add(1, Ordering::Relaxed);
                        Action::Hit(p.clone())
                    }
                    Some(Slot::Pending(pending)) => Action::Wait(pending.clone()),
                    None => {
                        self.misses.fetch_add(1, Ordering::Relaxed);
                        let pending = Arc::new(Pending {
                            state: Mutex::new(PendingState::Computing),
                            cv: Condvar::new(),
                        });
                        map.insert(*key, Slot::Pending(pending.clone()));
                        Action::Lead(pending)
                    }
                }
            };
            match action {
                Action::Hit(p) => return Ok((p, true)),
                Action::Lead(pending) => {
                    let f = compute.take().expect("a caller leads at most once");
                    self.computes.fetch_add(1, Ordering::Relaxed);
                    // Run the computation with no cache lock held; on
                    // success promote the entry, on failure (or panic —
                    // the guard below) remove it so waiters can retry.
                    struct Cleanup<'a> {
                        cache: &'a SimilarityCache,
                        key: SimKey,
                        pending: Arc<Pending>,
                        armed: bool,
                    }
                    impl Drop for Cleanup<'_> {
                        fn drop(&mut self) {
                            if !self.armed {
                                return;
                            }
                            let mut map = self.cache.map.lock().unwrap();
                            if let Some(Slot::Pending(cur)) = map.get(&self.key) {
                                if Arc::ptr_eq(cur, &self.pending) {
                                    map.remove(&self.key);
                                }
                            }
                            drop(map);
                            *self.pending.state.lock().unwrap() = PendingState::Failed;
                            self.pending.cv.notify_all();
                        }
                    }
                    let mut guard =
                        Cleanup { cache: self, key: *key, pending: pending.clone(), armed: true };
                    let result = f();
                    match result {
                        Ok(p) => {
                            guard.armed = false;
                            let tick = self.next_tick();
                            {
                                let mut map = self.map.lock().unwrap();
                                map.insert(*key, Slot::Ready { p: p.clone(), last_used: tick });
                                Self::evict_over_capacity(&mut map, self.capacity);
                            }
                            *pending.state.lock().unwrap() = PendingState::Ready(p.clone());
                            pending.cv.notify_all();
                            return Ok((p, false));
                        }
                        Err(e) => {
                            // Cleanup runs via the guard.
                            drop(guard);
                            return Err(e);
                        }
                    }
                }
                Action::Wait(pending) => {
                    let mut state = pending.state.lock().unwrap();
                    let outcome = loop {
                        let resolved = match &*state {
                            PendingState::Computing => None,
                            PendingState::Ready(p) => Some(Some(p.clone())),
                            PendingState::Failed => Some(None),
                        };
                        match resolved {
                            None => state = pending.cv.wait(state).unwrap(),
                            Some(out) => break out,
                        }
                    };
                    drop(state);
                    if let Some(p) = outcome {
                        // Coalesced: the leader's work served us.
                        self.hits.fetch_add(1, Ordering::Relaxed);
                        return Ok((p, true));
                    }
                    // Leader failed — loop: retry as a potential leader.
                }
            }
        }
    }

    /// `(hits, misses)` since construction.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }

    /// Similarity computations actually executed via `get_or_compute`.
    pub fn computes(&self) -> u64 {
        self.computes.load(Ordering::Relaxed)
    }

    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hd::sparse::Csr;

    fn p(tag: f32) -> Arc<SparseP> {
        Arc::new(SparseP {
            csr: Csr::from_rows(1, 1, 1, vec![0], vec![tag]),
            perplexity: tag,
        })
    }

    fn key(fp: u64) -> SimKey {
        SimKey {
            fingerprint: fp,
            method: KnnMethod::Brute,
            k: 10,
            perplexity_bits: 8.0f32.to_bits(),
            seed: 1,
        }
    }

    #[test]
    fn hit_and_miss_accounting() {
        let c = SimilarityCache::new(4);
        assert!(c.get(&key(1)).is_none());
        c.insert(key(1), p(1.0));
        let got = c.get(&key(1)).expect("hit");
        assert_eq!(got.perplexity, 1.0);
        assert_eq!(c.stats(), (1, 1));
    }

    #[test]
    fn distinct_parameters_are_distinct_keys() {
        let c = SimilarityCache::new(4);
        c.insert(key(1), p(1.0));
        let mut k2 = key(1);
        k2.k = 11;
        assert!(c.get(&k2).is_none(), "different k must miss");
        let mut k3 = key(1);
        k3.perplexity_bits = 9.0f32.to_bits();
        assert!(c.get(&k3).is_none(), "different perplexity must miss");
        let mut k4 = key(1);
        k4.method = KnnMethod::VpTree;
        assert!(c.get(&k4).is_none(), "different method must miss");
    }

    #[test]
    fn lru_evicts_the_coldest() {
        let c = SimilarityCache::new(2);
        c.insert(key(1), p(1.0));
        c.insert(key(2), p(2.0));
        let _ = c.get(&key(1)); // key 2 is now the coldest
        c.insert(key(3), p(3.0));
        assert_eq!(c.len(), 2);
        assert!(c.get(&key(1)).is_some());
        assert!(c.get(&key(2)).is_none(), "LRU entry must be evicted");
        assert!(c.get(&key(3)).is_some());
    }

    #[test]
    fn get_or_compute_sequential_hit_miss() {
        let c = SimilarityCache::new(4);
        let (a, hit) = c.get_or_compute(&key(1), || Ok(p(1.0))).unwrap();
        assert!(!hit, "first caller leads");
        let (b, hit) = c
            .get_or_compute(&key(1), || panic!("must not recompute"))
            .unwrap();
        assert!(hit);
        assert!(Arc::ptr_eq(&a, &b), "both callers share one matrix");
        assert_eq!(c.stats(), (1, 1));
        assert_eq!(c.computes(), 1);
    }

    #[test]
    fn concurrent_identical_submissions_coalesce_to_one_compute() {
        // Deterministic interleaving: the leader signals from inside its
        // compute closure, the waiter only starts once the pending entry
        // is definitely published, then the leader finishes.
        let c = Arc::new(SimilarityCache::new(4));
        let in_compute = Arc::new((Mutex::new(false), Condvar::new()));
        let release = Arc::new((Mutex::new(false), Condvar::new()));

        let leader = {
            let c = c.clone();
            let in_compute = in_compute.clone();
            let release = release.clone();
            std::thread::spawn(move || {
                c.get_or_compute(&key(7), || {
                    // Announce we are computing (pending entry is live).
                    *in_compute.0.lock().unwrap() = true;
                    in_compute.1.notify_all();
                    // Block until the waiter is in the cache too.
                    let mut go = release.0.lock().unwrap();
                    while !*go {
                        go = release.1.wait(go).unwrap();
                    }
                    Ok(p(7.0))
                })
                .unwrap()
            })
        };
        {
            let mut started = in_compute.0.lock().unwrap();
            while !*started {
                started = in_compute.1.wait(started).unwrap();
            }
        }
        let waiter = {
            let c = c.clone();
            let release = release.clone();
            std::thread::spawn(move || {
                // Give the waiter a moment to actually block, then let
                // the leader finish. (Ordering is already guaranteed by
                // the pending entry; the sleep only widens the window in
                // which a broken implementation would double-compute.)
                let releaser = std::thread::spawn(move || {
                    std::thread::sleep(std::time::Duration::from_millis(30));
                    *release.0.lock().unwrap() = true;
                    release.1.notify_all();
                });
                let out = c
                    .get_or_compute(&key(7), || panic!("waiter must never compute"))
                    .unwrap();
                releaser.join().unwrap();
                out
            })
        };
        let (pl, lead_hit) = leader.join().unwrap();
        let (pw, wait_hit) = waiter.join().unwrap();
        assert!(!lead_hit, "leader missed");
        assert!(wait_hit, "waiter coalesced into a hit");
        assert!(Arc::ptr_eq(&pl, &pw));
        assert_eq!(c.computes(), 1, "exactly one computation ran");
        assert_eq!(c.stats(), (1, 1));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn failed_leader_lets_a_waiter_take_over() {
        let c = Arc::new(SimilarityCache::new(4));
        let failed = c.get_or_compute(&key(3), || anyhow::bail!("knn exploded"));
        assert!(failed.is_err());
        assert_eq!(c.len(), 0, "failed computation leaves no entry");
        // The key is free again: the next caller leads and succeeds.
        let (got, hit) = c.get_or_compute(&key(3), || Ok(p(3.0))).unwrap();
        assert!(!hit);
        assert_eq!(got.perplexity, 3.0);
        assert_eq!(c.computes(), 2);
    }

    #[test]
    fn pending_entries_survive_eviction_pressure() {
        let c = SimilarityCache::new(1);
        // Manually wedge a pending entry, then flood with ready inserts.
        let pending = Arc::new(Pending {
            state: Mutex::new(PendingState::Computing),
            cv: Condvar::new(),
        });
        c.map.lock().unwrap().insert(key(9), Slot::Pending(pending));
        c.insert(key(1), p(1.0));
        c.insert(key(2), p(2.0));
        let map = c.map.lock().unwrap();
        assert!(
            matches!(map.get(&key(9)), Some(Slot::Pending(_))),
            "in-flight entry must never be evicted"
        );
        assert_eq!(map.len(), 2, "one ready + the pending");
    }
}
