//! Progress broadcast substrate (no tokio): a multi-subscriber channel
//! over `std::sync::mpsc`, plus the shared job status cell.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};

use super::job::{JobPhase, Snapshot};

/// Clone-fanout broadcast channel: every subscriber gets every message
/// sent after it subscribed. Dead subscribers are pruned on send.
pub struct Broadcast<T: Clone> {
    subs: Mutex<Vec<Sender<T>>>,
}

impl<T: Clone> Default for Broadcast<T> {
    fn default() -> Self {
        Self { subs: Mutex::new(Vec::new()) }
    }
}

impl<T: Clone> Broadcast<T> {
    pub fn subscribe(&self) -> Receiver<T> {
        let (tx, rx) = channel();
        self.subs.lock().unwrap().push(tx);
        rx
    }

    pub fn send(&self, msg: T) {
        let mut subs = self.subs.lock().unwrap();
        subs.retain(|s| s.send(msg.clone()).is_ok());
    }

    pub fn subscriber_count(&self) -> usize {
        self.subs.lock().unwrap().len()
    }
}

/// Shared mutable view of a running job.
#[derive(Clone)]
pub struct JobState {
    phase: Arc<Mutex<JobPhase>>,
    latest: Arc<Mutex<Option<Snapshot>>>,
    stop: Arc<AtomicBool>,
    pub snapshots: Arc<Broadcast<Snapshot>>,
}

impl Default for JobState {
    fn default() -> Self {
        Self {
            phase: Arc::new(Mutex::new(JobPhase::Queued)),
            latest: Arc::new(Mutex::new(None)),
            stop: Arc::new(AtomicBool::new(false)),
            snapshots: Arc::new(Broadcast::default()),
        }
    }
}

impl JobState {
    pub fn phase(&self) -> JobPhase {
        self.phase.lock().unwrap().clone()
    }

    pub fn set_phase(&self, p: JobPhase) {
        *self.phase.lock().unwrap() = p;
    }

    pub fn latest_snapshot(&self) -> Option<Snapshot> {
        self.latest.lock().unwrap().clone()
    }

    pub fn publish(&self, s: Snapshot) {
        *self.latest.lock().unwrap() = Some(s.clone());
        self.snapshots.send(s);
    }

    /// User-driven early termination (the A-tSNE interaction).
    pub fn request_stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
    }

    pub fn stop_requested(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn broadcast_reaches_all_subscribers() {
        let b: Broadcast<u32> = Broadcast::default();
        let r1 = b.subscribe();
        let r2 = b.subscribe();
        b.send(7);
        assert_eq!(r1.recv().unwrap(), 7);
        assert_eq!(r2.recv().unwrap(), 7);
    }

    #[test]
    fn dead_subscribers_are_pruned() {
        let b: Broadcast<u32> = Broadcast::default();
        {
            let _r = b.subscribe();
        } // dropped
        let r2 = b.subscribe();
        b.send(1);
        assert_eq!(b.subscriber_count(), 1);
        assert_eq!(r2.recv().unwrap(), 1);
    }

    #[test]
    fn job_state_roundtrip() {
        let js = JobState::default();
        assert_eq!(js.phase(), JobPhase::Queued);
        js.set_phase(JobPhase::Knn);
        assert_eq!(js.phase(), JobPhase::Knn);
        assert!(!js.stop_requested());
        js.request_stop();
        assert!(js.stop_requested());
        assert!(js.latest_snapshot().is_none());
        js.publish(Snapshot {
            iter: 3,
            kl_est: 1.0,
            elapsed_s: 0.1,
            positions: Arc::new(vec![0.0, 0.0]),
        });
        assert_eq!(js.latest_snapshot().unwrap().iter, 3);
    }
}
