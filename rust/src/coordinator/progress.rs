//! Progress broadcast substrate (no tokio): a bounded multi-subscriber
//! channel, plus the shared job status cell.
//!
//! Each subscriber owns a **bounded queue** ([`SUB_QUEUE_CAP`]): a
//! publish into a full queue drops that subscriber's *oldest* pending
//! message (a live view wants the newest frame, not a complete replay),
//! and a subscriber that stays full for [`EVICT_AFTER_LAGGING`]
//! consecutive publishes is **evicted** — its receiver disconnects, and
//! the publisher stops paying to clone for it. A stalled TCP viewer can
//! therefore cost at most a fixed amount of memory and fanout time,
//! never an unbounded queue. Drops and evictions are counted in
//! `snapshot.dropped_oldest` / `snapshot.subscribers_evicted`.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{RecvError, RecvTimeoutError};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::{Duration, Instant};

use crate::obs;

use super::job::{JobPhase, ParamUpdate, Snapshot};

/// Pending messages a subscriber may buffer before drop-oldest kicks in.
pub const SUB_QUEUE_CAP: usize = 8;

/// Consecutive full-queue publishes before a subscriber is evicted.
pub const EVICT_AFTER_LAGGING: u64 = 32;

/// `snapshot.publish_skipped` — sends that early-returned because nobody
/// was subscribed. The sole production `Broadcast` carries snapshots,
/// hence the metric's name.
fn publish_skipped() -> &'static Arc<obs::Counter> {
    static C: OnceLock<Arc<obs::Counter>> = OnceLock::new();
    C.get_or_init(|| obs::registry().counter("snapshot.publish_skipped"))
}

/// `snapshot.subscribers_dropped` — dead receivers pruned during a send.
fn subscribers_dropped() -> &'static Arc<obs::Counter> {
    static C: OnceLock<Arc<obs::Counter>> = OnceLock::new();
    C.get_or_init(|| obs::registry().counter("snapshot.subscribers_dropped"))
}

/// `snapshot.fanout_ns` — how long one publish spends cloning into
/// subscriber channels.
fn fanout_ns() -> &'static Arc<obs::Histogram> {
    static H: OnceLock<Arc<obs::Histogram>> = OnceLock::new();
    H.get_or_init(|| obs::registry().histogram("snapshot.fanout_ns"))
}

/// `snapshot.dropped_oldest` — messages displaced from a full subscriber
/// queue by a newer publish (drop-oldest backpressure).
fn dropped_oldest() -> &'static Arc<obs::Counter> {
    static C: OnceLock<Arc<obs::Counter>> = OnceLock::new();
    C.get_or_init(|| obs::registry().counter("snapshot.dropped_oldest"))
}

/// `snapshot.subscribers_evicted` — subscribers disconnected for staying
/// full [`EVICT_AFTER_LAGGING`] publishes in a row.
fn subscribers_evicted() -> &'static Arc<obs::Counter> {
    static C: OnceLock<Arc<obs::Counter>> = OnceLock::new();
    C.get_or_init(|| obs::registry().counter("snapshot.subscribers_evicted"))
}

struct SubQueue<T> {
    buf: VecDeque<T>,
    /// Consecutive publishes that found the queue full.
    lagging: u64,
    /// Receiver side dropped; prune on the next send.
    closed: bool,
    /// Sender side gone (broadcast dropped, or this subscriber evicted);
    /// drained receives report disconnection.
    disconnected: bool,
}

struct SubShared<T> {
    q: Mutex<SubQueue<T>>,
    cv: Condvar,
}

impl<T> SubShared<T> {
    fn lock(&self) -> std::sync::MutexGuard<'_, SubQueue<T>> {
        self.q.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// The receiving half of one [`Broadcast`] subscription: a bounded
/// queue with `std::sync::mpsc`-shaped blocking accessors.
pub struct Subscription<T> {
    shared: Arc<SubShared<T>>,
}

impl<T> Subscription<T> {
    /// Block until a message arrives or the sender disconnects (job
    /// broadcast dropped, or this subscriber evicted as too slow).
    pub fn recv(&self) -> Result<T, RecvError> {
        // `snapshot.slow_subscriber`: stall this receiver before it
        // drains, so its bounded queue fills and the drop-oldest /
        // eviction machinery runs under the chaos harness.
        if super::faultinject::fire(super::faultinject::SNAPSHOT_SLOW_SUBSCRIBER) {
            std::thread::sleep(Duration::from_millis(50));
        }
        let mut q = self.shared.lock();
        loop {
            if let Some(v) = q.buf.pop_front() {
                return Ok(v);
            }
            if q.disconnected {
                return Err(RecvError);
            }
            q = self.shared.cv.wait(q).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// [`Self::recv`] with a deadline.
    pub fn recv_timeout(&self, timeout: Duration) -> Result<T, RecvTimeoutError> {
        // Same `snapshot.slow_subscriber` stall as [`Self::recv`].
        if super::faultinject::fire(super::faultinject::SNAPSHOT_SLOW_SUBSCRIBER) {
            std::thread::sleep(Duration::from_millis(50));
        }
        let deadline = Instant::now() + timeout;
        let mut q = self.shared.lock();
        loop {
            if let Some(v) = q.buf.pop_front() {
                return Ok(v);
            }
            if q.disconnected {
                return Err(RecvTimeoutError::Disconnected);
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(RecvTimeoutError::Timeout);
            }
            let (guard, _) = self
                .shared
                .cv
                .wait_timeout(q, deadline - now)
                .unwrap_or_else(|e| e.into_inner());
            q = guard;
        }
    }

    /// Pop without blocking.
    pub fn try_recv(&self) -> Option<T> {
        self.shared.lock().buf.pop_front()
    }

    /// Drain everything currently queued without blocking.
    pub fn try_iter(&self) -> std::vec::IntoIter<T> {
        let mut q = self.shared.lock();
        q.buf.drain(..).collect::<Vec<T>>().into_iter()
    }

    /// True once the publisher evicted this subscriber for lagging.
    pub fn evicted(&self) -> bool {
        let q = self.shared.lock();
        q.disconnected && !q.closed
    }
}

impl<T> Drop for Subscription<T> {
    fn drop(&mut self) {
        self.shared.lock().closed = true;
    }
}

/// Blocking iterator: yields until the sender disconnects (mirrors
/// `mpsc::Receiver`'s `IntoIterator`, so `for s in rx` keeps working).
pub struct SubscriptionIter<T>(Subscription<T>);

impl<T> Iterator for SubscriptionIter<T> {
    type Item = T;
    fn next(&mut self) -> Option<T> {
        self.0.recv().ok()
    }
}

impl<T> IntoIterator for Subscription<T> {
    type Item = T;
    type IntoIter = SubscriptionIter<T>;
    fn into_iter(self) -> SubscriptionIter<T> {
        SubscriptionIter(self)
    }
}

/// Clone-fanout broadcast channel: every subscriber gets every message
/// sent after it subscribed, through a bounded per-subscriber queue
/// (capacity [`SUB_QUEUE_CAP`], drop-oldest when full, eviction after
/// [`EVICT_AFTER_LAGGING`] consecutive full publishes). Dead
/// subscribers are pruned on send; dropping the broadcast disconnects
/// every receiver.
pub struct Broadcast<T: Clone> {
    subs: Mutex<Vec<Arc<SubShared<T>>>>,
    capacity: usize,
    evict_after: u64,
}

impl<T: Clone> Default for Broadcast<T> {
    fn default() -> Self {
        Self::bounded(SUB_QUEUE_CAP, EVICT_AFTER_LAGGING)
    }
}

impl<T: Clone> Broadcast<T> {
    /// A broadcast with explicit backpressure knobs (tests use tiny
    /// queues; production goes through `default()`).
    pub fn bounded(capacity: usize, evict_after: u64) -> Self {
        Self {
            subs: Mutex::new(Vec::new()),
            capacity: capacity.max(1),
            evict_after: evict_after.max(1),
        }
    }

    pub fn subscribe(&self) -> Subscription<T> {
        let shared = Arc::new(SubShared {
            q: Mutex::new(SubQueue {
                buf: VecDeque::with_capacity(self.capacity),
                lagging: 0,
                closed: false,
                disconnected: false,
            }),
            cv: Condvar::new(),
        });
        self.subs.lock().unwrap().push(shared.clone());
        Subscription { shared }
    }

    pub fn send(&self, msg: T) {
        let mut subs = self.subs.lock().unwrap();
        if subs.is_empty() {
            // Don't clone the message (snapshot position buffers are
            // Arc-shared but the wrapper still costs) for nobody.
            publish_skipped().inc();
            return;
        }
        let t0 = obs::now_ns();
        let (mut dead, mut evicted, mut displaced) = (0u64, 0u64, 0u64);
        subs.retain(|s| {
            let mut q = s.lock();
            if q.closed {
                dead += 1;
                return false;
            }
            if q.buf.len() >= self.capacity {
                q.lagging += 1;
                if q.lagging >= self.evict_after {
                    // Still full after evict_after chances to drain:
                    // disconnect it rather than keep paying the clone.
                    q.disconnected = true;
                    s.cv.notify_all();
                    evicted += 1;
                    return false;
                }
                q.buf.pop_front();
                displaced += 1;
            } else {
                q.lagging = 0;
            }
            q.buf.push_back(msg.clone());
            s.cv.notify_all();
            true
        });
        fanout_ns().record(obs::now_ns().saturating_sub(t0));
        subscribers_dropped().add(dead);
        subscribers_evicted().add(evicted);
        dropped_oldest().add(displaced);
    }

    pub fn subscriber_count(&self) -> usize {
        self.subs.lock().unwrap().len()
    }
}

impl<T: Clone> Drop for Broadcast<T> {
    fn drop(&mut self) {
        // Wake every receiver with a disconnect, mirroring what dropping
        // all `mpsc` senders does.
        let subs = self.subs.lock().unwrap_or_else(|e| e.into_inner());
        for s in subs.iter() {
            s.lock().disconnected = true;
            s.cv.notify_all();
        }
    }
}

/// Shared mutable view of a running job: phase, snapshots, and the
/// control surface the scheduler polls between step quanta (stop, pause,
/// pending hyperparameter update).
#[derive(Clone)]
pub struct JobState {
    phase: Arc<Mutex<JobPhase>>,
    latest: Arc<Mutex<Option<Snapshot>>>,
    stop: Arc<AtomicBool>,
    paused: Arc<AtomicBool>,
    pending_update: Arc<Mutex<Option<ParamUpdate>>>,
    pub snapshots: Arc<Broadcast<Snapshot>>,
}

impl Default for JobState {
    fn default() -> Self {
        Self {
            phase: Arc::new(Mutex::new(JobPhase::Queued)),
            latest: Arc::new(Mutex::new(None)),
            stop: Arc::new(AtomicBool::new(false)),
            paused: Arc::new(AtomicBool::new(false)),
            pending_update: Arc::new(Mutex::new(None)),
            snapshots: Arc::new(Broadcast::default()),
        }
    }
}

impl JobState {
    pub fn phase(&self) -> JobPhase {
        self.phase.lock().unwrap().clone()
    }

    pub fn set_phase(&self, p: JobPhase) {
        *self.phase.lock().unwrap() = p;
    }

    pub fn latest_snapshot(&self) -> Option<Snapshot> {
        self.latest.lock().unwrap().clone()
    }

    pub fn publish(&self, s: Snapshot) {
        *self.latest.lock().unwrap() = Some(s.clone());
        self.snapshots.send(s);
    }

    /// User-driven early termination (the A-tSNE interaction).
    pub fn request_stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
    }

    pub fn stop_requested(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }

    /// Ask the scheduler to park this job at the next step boundary.
    pub fn request_pause(&self) {
        self.paused.store(true, Ordering::SeqCst);
    }

    /// Clear the pause flag (the service also re-enqueues the job).
    pub fn clear_pause(&self) {
        self.paused.store(false, Ordering::SeqCst);
    }

    pub fn pause_requested(&self) -> bool {
        self.paused.load(Ordering::SeqCst)
    }

    /// Queue a hyperparameter update for the scheduler to apply at the
    /// next step boundary; updates arriving before the previous one was
    /// consumed merge (later fields win).
    pub fn push_update(&self, update: ParamUpdate) {
        let mut slot = self.pending_update.lock().unwrap();
        *slot = Some(match slot.take() {
            Some(prev) => prev.merged_with(&update),
            None => update,
        });
    }

    /// Claim the pending update, if any.
    pub fn take_update(&self) -> Option<ParamUpdate> {
        self.pending_update.lock().unwrap().take()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn broadcast_reaches_all_subscribers() {
        let b: Broadcast<u32> = Broadcast::default();
        let r1 = b.subscribe();
        let r2 = b.subscribe();
        b.send(7);
        assert_eq!(r1.recv().unwrap(), 7);
        assert_eq!(r2.recv().unwrap(), 7);
    }

    #[test]
    fn dead_subscribers_are_pruned() {
        let b: Broadcast<u32> = Broadcast::default();
        {
            let _r = b.subscribe();
        } // dropped
        let r2 = b.subscribe();
        b.send(1);
        assert_eq!(b.subscriber_count(), 1);
        assert_eq!(r2.recv().unwrap(), 1);
    }

    #[test]
    fn full_subscriber_queue_drops_oldest() {
        let b: Broadcast<u32> = Broadcast::bounded(3, 1000);
        let rx = b.subscribe();
        for i in 0..10 {
            b.send(i);
        }
        // Capacity 3: only the newest three survive, oldest first.
        let got: Vec<u32> = rx.try_iter().collect();
        assert_eq!(got, vec![7, 8, 9]);
        assert!(!rx.evicted());
    }

    #[test]
    fn chronically_full_subscriber_is_evicted() {
        let b: Broadcast<u32> = Broadcast::bounded(2, 4);
        let slow = b.subscribe();
        let fast = b.subscribe();
        let mut fast_got = Vec::new();
        for i in 0..10 {
            b.send(i);
            fast_got.extend(fast.try_iter());
        }
        assert_eq!(b.subscriber_count(), 1, "slow subscriber evicted, fast retained");
        assert!(slow.evicted());
        assert_eq!(slow.recv_timeout(Duration::from_secs(1)), Err(RecvTimeoutError::Disconnected));
        assert_eq!(fast_got, (0..10).collect::<Vec<u32>>(), "fast subscriber saw everything");
        // A lagging-but-recovering subscriber is NOT evicted: the counter
        // resets whenever a publish finds room.
        let choppy = b.subscribe();
        for i in 0..100 {
            b.send(i);
            if i % 3 == 0 {
                let _ = choppy.try_iter();
            }
        }
        assert!(!choppy.evicted());
    }

    #[test]
    fn dropping_the_broadcast_disconnects_receivers() {
        let b: Broadcast<u32> = Broadcast::default();
        let rx = b.subscribe();
        b.send(5);
        drop(b);
        assert_eq!(rx.recv(), Ok(5), "queued messages drain first");
        assert_eq!(rx.recv(), Err(RecvError));
    }

    #[test]
    fn pause_and_update_controls_roundtrip() {
        let js = JobState::default();
        assert!(!js.pause_requested());
        js.request_pause();
        assert!(js.pause_requested());
        js.clear_pause();
        assert!(!js.pause_requested());

        assert!(js.take_update().is_none());
        js.push_update(ParamUpdate { eta: Some(10.0), iters: Some(5), ..Default::default() });
        js.push_update(ParamUpdate { eta: Some(20.0), ..Default::default() });
        let u = js.take_update().expect("merged update pending");
        assert_eq!(u.eta, Some(20.0), "later update wins");
        assert_eq!(u.iters, Some(5), "earlier field survives the merge");
        assert!(js.take_update().is_none(), "take consumes");
    }

    #[test]
    fn job_state_roundtrip() {
        let js = JobState::default();
        assert_eq!(js.phase(), JobPhase::Queued);
        js.set_phase(JobPhase::Knn);
        assert_eq!(js.phase(), JobPhase::Knn);
        assert!(!js.stop_requested());
        js.request_stop();
        assert!(js.stop_requested());
        assert!(js.latest_snapshot().is_none());
        js.publish(Snapshot {
            iter: 3,
            kl_est: 1.0,
            elapsed_s: 0.1,
            positions: Arc::new(vec![0.0, 0.0]),
            published_ns: obs::now_ns(),
        });
        assert_eq!(js.latest_snapshot().unwrap().iter, 3);
    }
}
